"""Version compatibility shims for the supported JAX range.

``shard_map`` moved from ``jax.experimental.shard_map`` (<= 0.4.x, with a
``check_rep`` flag) to ``jax.shard_map`` (>= 0.5, with ``check_vma``).
Everything else we rely on is stable across the pinned range.
"""
from __future__ import annotations

import jax


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis, usable for shape arithmetic.

    ``lax.axis_size`` (jax >= 0.5) with the ``core.axis_frame`` fallback
    for 0.4.x (which returns the bound axis size as a python int).
    """
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    import jax.core
    return int(jax.core.axis_frame(axis_name))


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Uniform shard_map across JAX versions (replication check off by
    default — the DSC program mixes replicated and sharded outputs)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
