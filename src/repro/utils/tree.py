"""Pytree dataclass utilities (no flax/chex dependency).

``pytree_dataclass`` registers a frozen dataclass as a JAX pytree. Fields
annotated with ``static_field()`` become aux-data (hashable, not traced).
"""
from __future__ import annotations

import dataclasses
from typing import Any, TypeVar

import jax

_T = TypeVar("_T")


def static_field(**kwargs: Any) -> dataclasses.Field:
    """A dataclass field treated as static (pytree aux data)."""
    metadata = dict(kwargs.pop("metadata", {}) or {})
    metadata["static"] = True
    return dataclasses.field(metadata=metadata, **kwargs)


def pytree_dataclass(cls: type[_T]) -> type[_T]:
    """Decorator: freeze the dataclass and register it as a pytree node."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    data_fields = []
    meta_fields = []
    for f in dataclasses.fields(cls):
        if f.metadata.get("static", False):
            meta_fields.append(f.name)
        else:
            data_fields.append(f.name)
    jax.tree_util.register_dataclass(
        cls, data_fields=data_fields, meta_fields=meta_fields
    )

    def replace(self: _T, **updates: Any) -> _T:
        return dataclasses.replace(self, **updates)

    cls.replace = replace  # type: ignore[attr-defined]
    return cls
