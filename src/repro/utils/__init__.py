from repro.utils.tree import pytree_dataclass, static_field
from repro.utils.logging import get_logger

__all__ = ["pytree_dataclass", "static_field", "get_logger"]
