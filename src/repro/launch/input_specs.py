"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation ever happens here: everything is abstract (the
shannon/kernels pattern) — weak-type-correct, shardable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES, get_arch
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.train.step import TrainState, make_train_state


def abstract_train_state(cfg: ModelConfig, ep_degree: int = 1) -> TrainState:
    return jax.eval_shape(
        lambda: make_train_state(jax.random.PRNGKey(0), cfg,
                                 ep_degree=ep_degree))


def abstract_params(cfg: ModelConfig, ep_degree: int = 1):
    return jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg,
                              ep_degree=ep_degree))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: tf.init_cache(cfg, batch, max_len))


def input_specs(arch: str, shape: str) -> dict:
    """Abstract model inputs for one cell.  Keys depend on the shape kind:
    train  -> tokens, labels (+ frontend)
    prefill-> tokens (+ frontend), cache
    decode -> tokens, cache, index
    """
    cfg = get_arch(arch)
    sh = SHAPES[shape]
    B, L, kind = sh["global_batch"], sh["seq_len"], sh["kind"]

    def tok(b, l):
        if cfg.family == "audio":
            return jax.ShapeDtypeStruct((b, cfg.n_codebooks, l), jnp.int32)
        return jax.ShapeDtypeStruct((b, l), jnp.int32)

    out = {"cfg": cfg, "kind": kind, "batch": B, "seq": L}
    if kind == "train":
        out["tokens"] = tok(B, L)
        out["labels"] = tok(B, L)
        if cfg.family == "vlm":
            out["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_vision), jnp.float32)
    elif kind == "prefill":
        Lt = L - (cfg.vision_tokens if cfg.family == "vlm" else 0)
        out["tokens"] = tok(B, Lt)
        out["cache"] = abstract_cache(cfg, B, L)
        if cfg.family == "vlm":
            out["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_vision), jnp.float32)
    elif kind == "decode":
        out["tokens"] = tok(B, 1)
        out["cache"] = abstract_cache(cfg, B, L)
        out["index"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out
