"""Production meshes.

Functions, not module-level constants, so importing this module never
touches jax device state (required for the dry-run's device-count forcing).

  make_production_mesh(multi_pod=False)
      (16, 16) ('data', 'model')          — one v5e-256 pod
      (2, 16, 16) ('pod', 'data', 'model')— two pods (DCN over 'pod')

  make_dsc_mesh(multi_pod=False)
      ('part', 'model') view of the same devices for the DSC pipeline:
      'part' = temporal partitions (folded pod x data), 'model' =
      candidate-trajectory parallelism.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dsc_mesh(*, multi_pod: bool = False, model: int = 16):
    n_devices = 512 if multi_pod else 256
    return jax.make_mesh((n_devices // model, model), ("part", "model"))


def make_test_mesh(part: int = 4, model: int = 2):
    """Small mesh for multi-device CPU tests (host-device forcing)."""
    return jax.make_mesh((part, model), ("part", "model"))


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
