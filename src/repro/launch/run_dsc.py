"""DSC launcher: the paper's pipeline end-to-end on (synthetic) data.

``python -m repro.launch.run_dsc --config dsc_synth [--distributed P]``
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_dsc_config
from repro.core.dsc import cluster_summary, run_dsc
from repro.core.partitioning import partition_batch
from repro.core.types import DSCParams
from repro.data.synthetic import (ais_like, default_dsc_params_for,
                                  figure1_scenario)
from repro.utils.logging import get_logger

log = get_logger("run_dsc")


def make_dataset(name: str, n_trajs: int, max_points: int, seed: int = 0):
    if name == "dsc_synth":
        per = max(1, n_trajs // 6)
        return figure1_scenario(n_per_route=per, points_per_leg=32,
                                seed=seed)[0]
    return ais_like(n_vessels=n_trajs, max_points=max_points,
                    n_lanes=8, seed=seed)[0]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="dsc_synth")
    ap.add_argument("--n-trajs", type=int, default=None)
    ap.add_argument("--distributed", type=int, default=0,
                    help="number of temporal partitions (0 = single host)")
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--use-index", action="store_true",
                    help="prune the JOIN phase with the spatiotemporal "
                         "grid index (lossless; single-host and "
                         "distributed)")
    ap.add_argument("--mode", default="materialize",
                    choices=["materialize", "fused"],
                    help="join execution mode: materialize the JoinResult "
                         "cube (parity oracle) or stream it through the "
                         "fused Pallas epilogues (no [T, M, C] buffer)")
    ap.add_argument("--cluster-engine", default="rounds",
                    choices=["rounds", "sequential"],
                    help="Problem 3 engine: round-parallel greedy "
                         "(O(rounds) iterations) or the O(S) sequential "
                         "oracle — label-identical outputs")
    ap.add_argument("--cluster-use-kernel", action="store_true",
                    help="back the round engine with the Pallas tile "
                         "kernels (accelerator path; interpret mode on "
                         "CPU)")
    ap.add_argument("--seg-use-kernel", action="store_true",
                    help="compute the TSA2 Jaccard signal with the fused "
                         "Pallas segmentation kernel (bit-identical cuts; "
                         "interpret mode on CPU; no-op under tsa1)")
    ap.add_argument("--sim-mode", default="dense",
                    choices=["dense", "topk"],
                    help="SP representation: the dense [S, S] similarity "
                         "matrix (parity oracle) or panel-streamed top-K "
                         "neighbor lists — O(S*K) memory, bit-identical "
                         "labels whenever the overflow certificate is "
                         "zero (single-host runs auto-widen K; "
                         "distributed runs fail loudly)")
    ap.add_argument("--sim-topk", type=int, default=None,
                    help="K of the top-K neighbor lists (default 32, "
                         "clamped to S); only with --sim-mode topk")
    ap.add_argument("--segmentation", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rc = get_dsc_config(args.config)
    n_trajs = args.n_trajs or min(rc.n_trajs, 64)
    batch = make_dataset(args.config, n_trajs, rc.max_points, args.seed)
    diam, mean_dt = default_dsc_params_for(batch)
    params = DSCParams(
        eps_sp=0.15 * diam if args.config != "dsc_synth" else 0.42,
        eps_t=1.0 * mean_dt, delta_t=rc.delta_t,
        w=min(rc.w, 6 if args.config == "dsc_synth" else rc.w),
        tau=0.15 if args.config == "dsc_synth" else rc.tau,
        alpha_sigma=-1.0, k_sigma=-1.0,
        max_subtrajs_per_traj=rc.max_subtrajs,
        segmentation=args.segmentation or ("tsa2" if args.config ==
                                           "dsc_synth" else rc.segmentation))

    t0 = time.time()
    if args.distributed:
        from repro.core.distributed import run_dsc_distributed
        P = args.distributed
        if len(jax.devices()) < P * args.model_par:
            raise SystemExit(
                f"need {P * args.model_par} devices; run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{P * args.model_par}")
        mesh = jax.make_mesh((P, args.model_par), ("part", "model"))
        parts = partition_batch(batch, P)
        out = run_dsc_distributed(parts, params, mesh,
                                  use_kernel=args.use_kernel,
                                  use_index=args.use_index,
                                  mode=args.mode,
                                  cluster_engine=args.cluster_engine,
                                  cluster_use_kernel=args.cluster_use_kernel,
                                  seg_use_kernel=args.seg_use_kernel,
                                  sim_mode=args.sim_mode,
                                  sim_topk=args.sim_topk or 32)
        res, table = out.result, out.table
        n_rep = int(np.asarray(res.is_rep).sum())
        n_out = int(np.asarray(res.is_outlier).sum())
        n_mem = int(((np.asarray(res.member_of) >= 0)
                     & ~np.asarray(res.is_rep)).sum())
        log.info("distributed DSC (%d partitions x %d model): "
                 "%d clusters, %d members, %d outliers in %.2fs",
                 P, args.model_par, n_rep, n_mem, n_out, time.time() - t0)
    else:
        out = run_dsc(batch, params, use_kernel=args.use_kernel,
                      use_index=args.use_index, mode=args.mode,
                      cluster_engine=args.cluster_engine,
                      cluster_use_kernel=args.cluster_use_kernel,
                      seg_use_kernel=args.seg_use_kernel,
                      sim_mode=args.sim_mode, sim_topk=args.sim_topk)
        s = cluster_summary(out)
        log.info("DSC: %d clusters, %d outliers, RMSE %.4f, SSCR %.2f "
                 "in %.2fs", s["num_clusters"], len(s["outliers"]),
                 s["rmse"], s["sscr"], time.time() - t0)
        for rep, members in sorted(s["clusters"].items(),
                                   key=lambda kv: -len(kv[1]))[:8]:
            log.info("  cluster rep=%d size=%d", rep, len(members))
    return out


if __name__ == "__main__":
    main()
