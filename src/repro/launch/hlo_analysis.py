"""Post-optimization HLO analysis: loop-corrected FLOPs and collective bytes.

Why not ``cost_analysis()`` alone: our models scan over layers, so the layer
body appears ONCE in the HLO while executing n_layers times — XLA's
HloCostAnalysis (and any naive text scan) undercounts both FLOPs and
collective traffic by ~n_layers.  This module parses the compiled module
text into computations, resolves operand shapes through a symbol table,
discovers ``while`` loops, recovers their trip counts from the loop-condition
constants (scan lowers to a counted loop, so the bound is a literal), and
multiplies instruction costs by the effective trip product.

Accounted per instruction:
  dot                 2 * prod(output dims) * prod(lhs contracting dims)
  collectives         bytes moved per device:
      all-reduce          2 x size        (ring RS + AG)
      all-gather          size            (output includes the group factor)
      reduce-scatter      size x (group-1)
      all-to-all          size
      collective-permute  size

Elementwise/reduction FLOPs are ignored — matmuls dominate all ten
architectures by >100x.  Validated against analytic 6ND in tests.

Two consumer groups share this parser:

* the roofline report (``benchmarks/roofline.py``) feeds
  :func:`analyze_hlo`'s loop-corrected totals into
  ``roofline_position`` to place a program on the TPU v5e roofline;
* the DSC structural gates and the tile-plan autotuner
  (``benchmarks/kernel_bench.py``, ``repro.tune.autotune``) use the
  buffer-assignment helpers — :func:`buffer_inventory`,
  :func:`peak_buffer_stats`, :func:`find_buffers_with_elements` (the
  join-cube fingerprint), and :func:`interface_buffer_stats` (the
  cross-stage HBM footprint, the tuner's primary ranking key).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s*([\w\-]+)")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_WHILE_RE2 = re.compile(
    r"while\(.*?\).*?body=%?([\w.\-]+).*?condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
# lhs operand of a dot, with or without an inline type annotation
# (scheduled HLO prints "dot(f32[128,256]{1,0} %Arg_0.1, ...)")
_DOT_ARGS_RE = re.compile(
    r"\bdot\(\s*(?:(\w+\[[\d,]*\])(?:\{[^}]*\})?\s+)?%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that move no HBM bytes of their own
_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter",
             "constant", "after-all", "iota", "reshape", "broadcast",
             "get-dimension-size", "partition-id", "replica-id",
             "opt-barrier", "bitcast-convert"}
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _dims(s: str):
    return [int(d) for d in s.split(",") if d]


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse(text: str):
    """-> (computations {name: [lines]}, symbols {inst_name: type_str})."""
    comps: dict[str, list[str]] = {}
    symbols: dict[str, str] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.strip()
        if line.endswith("{") and ("(" in line) and "=" not in line.split(
                "(")[0]:
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        comps[cur].append(line)
        dm = _DEF_RE.match(line)
        if dm:
            symbols[dm.group(1)] = dm.group(2)
    return comps, symbols


_SLICE_OPS = {"dynamic-slice", "slice", "gather", "concatenate",
              "transpose", "copy", "convert", "reverse"}
_INPLACE_OPS = {"dynamic-update-slice", "scatter", "select-and-scatter"}
_CONTROL_OPS = {"while", "conditional", "call", "custom-call"}


def _traffic_bytes(line: str, def_name: str, out_type: str, op: str,
                   symbols: dict) -> int:
    """Approximate HBM traffic of one instruction: bytes written (output)
    + bytes read.  Slicing ops read only what they emit (2 x output);
    in-place update ops move ~2 x their update operand; control-flow call
    sites are excluded (their bodies are walked separately).  Post-fusion
    granularity mirrors a fusion-aware TPU HBM model."""
    if op in _FREE_OPS or op in _CONTROL_OPS:
        return 0
    if op in _SLICE_OPS:
        return 2 * _shape_bytes(out_type)
    body = line.split(" metadata=")[0]
    operand_bytes = []
    seen = {def_name}
    for m in _OPERAND_RE.finditer(body):
        name = m.group(1)
        if name in seen:
            continue
        seen.add(name)
        if name in symbols:
            operand_bytes.append(_shape_bytes(symbols[name]))
    if op in _INPLACE_OPS:
        return 2 * (min(operand_bytes) if operand_bytes else 0)
    return _shape_bytes(out_type) + sum(operand_bytes)


def _line_costs(line: str, symbols: dict):
    """(flops, coll_bytes, kind, traffic_raw, traffic_fused) per line.

    ``traffic_raw``  : every instruction's output+reads (CPU-granularity
                       upper bound).
    ``traffic_fused``: only matmul boundaries, slicing, in-place updates
                       and collective payloads — approximates a TPU program
                       where elementwise chains fuse into GEMM epilogues.
    """
    dm = _DEF_RE.match(line)
    if not dm:
        return 0.0, 0, None, 0, 0
    def_name, out_type, op = dm.group(1), dm.group(2), dm.group(3)
    traffic = _traffic_bytes(line, def_name, out_type, op, symbols)
    fused = 0
    if op in _SLICE_OPS or op in _INPLACE_OPS:
        fused = traffic

    if op == "dot" or " dot(" in line:
        out_dims = []
        for _, dims in _SHAPE_RE.findall(out_type):
            out_dims = _dims(dims)
            break
        ma = _DOT_ARGS_RE.search(line)
        contract = 1
        if ma:
            lhs_type = ma.group(1) or symbols.get(ma.group(2), "")
            lhs_dims = []
            for _, dims in _SHAPE_RE.findall(lhs_type):
                lhs_dims = _dims(dims)
                break
            mc = _CONTRACT_RE.search(line)
            if mc and lhs_dims:
                for i in _dims(mc.group(1)):
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
        n = 1
        for d in out_dims:
            n *= d
        return 2.0 * n * contract, 0, None, traffic, traffic

    kind = next((c for c in _COLLECTIVES
                 if op == c or op == c + "-start"), None)
    if kind:
        size = _shape_bytes(out_type)
        group = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            group = gm.group(1).count(",") + 1
        else:
            im = _IOTA_RE.search(line)
            if im:
                group = int(im.group(1))
        if kind == "all-reduce":
            moved = 2 * size
        elif kind == "all-gather":
            moved = size
        elif kind == "reduce-scatter":
            moved = size * max(group - 1, 1)
        else:
            moved = size
        return 0.0, moved, kind, traffic, 2 * size
    return 0.0, 0, None, traffic, fused


# attention score/PV einsum signatures (from op_name metadata): with the
# shipped Pallas flash kernel these intermediates stay in VMEM, so the
# flash_attention=True analysis mode excludes their HBM traffic (FLOPs kept)
_ATTN_DOT_SIGS = ("bqkgh,bmkh->bqkgm", "bqkgm,bmkh->bqkgh",
                  "blkgh,bmkh->blkgm", "blkgm,bmkh->blkgh",
                  "qgh,kh->qgk", "qgk,kh->qgh")


def _is_attention_dot(line: str) -> bool:
    return any(sig in line for sig in _ATTN_DOT_SIGS)


def analyze_hlo(text: str, default_trip: int = 1,
                flash_attention: bool = False) -> dict:
    """Loop-corrected totals: flops, collective bytes (per kind + total).
    ``flash_attention=True`` models the Pallas fused-attention kernel:
    score/probability blocks are VMEM-resident (their HBM traffic is
    excluded; their FLOPs are kept)."""
    comps, symbols = _parse(text)

    body_trip: dict[str, int] = {}
    whiles: list[tuple[str, str]] = []      # (cond, body)
    for name, lines in comps.items():
        for line in lines:
            mw = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
            if mw and "while(" in line:
                g = mw.groups()
                cond, body = (g if _WHILE_RE.search(line) else (g[1], g[0]))
                consts = []
                for cl in comps.get(cond, []):
                    consts += [int(c) for c in _CONST_RE.findall(cl)]
                body_trip[body] = max(consts) if consts else default_trip
                whiles.append((cond, body))

    def find_entry():
        for name in comps:
            if "main" in name:
                return name
        return next(iter(comps))

    mult: dict[str, float] = defaultdict(float)          # flops scope
    mult_t: dict[str, float] = defaultdict(float)        # traffic scope
    loop_depth: dict[str, int] = defaultdict(int)        # while-nesting

    def walk(name: str, factor: float, traffic: bool, depth=0, wdepth=0):
        if name not in comps or depth > 12:
            return
        mult[name] += factor
        loop_depth[name] = max(loop_depth[name], wdepth)
        if traffic:
            mult_t[name] += factor
        for line in comps[name]:
            if "while(" in line:
                mw = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
                if mw:
                    g = mw.groups()
                    cond, body = (g if _WHILE_RE.search(line)
                                  else (g[1], g[0]))
                    trip = body_trip.get(body, default_trip)
                    walk(body, factor * trip, traffic, depth + 1,
                         wdepth + 1)
                    walk(cond, factor, False, depth + 1, wdepth)
                    continue
            for g in _CALL_RE.finditer(line):
                if g.group(1) and g.group(1) != name:
                    # fusion/to_apply bodies: flops yes, HBM traffic no
                    walk(g.group(1), factor, False, depth + 1, wdepth)

    walk(find_entry(), 1.0, True)

    flops = 0.0
    traffic = 0.0
    traffic_fused = 0.0
    coll: dict = defaultdict(lambda: {"count": 0, "bytes": 0.0})
    total_coll = 0.0
    for name, lines in comps.items():
        f = mult.get(name, 0.0)
        ft = mult_t.get(name, 0.0)
        if f <= 0 and ft <= 0:
            continue
        deep = loop_depth.get(name, 0) >= 2
        for line in lines:
            fl, cb, kind, tb, tf = _line_costs(line, symbols)
            flops += f * fl
            if flash_attention and fl > 0 and (
                    _is_attention_dot(line) or deep):
                # inner-scan dots = attention / chunked-recurrence blocks:
                # VMEM-resident under the shipped fused kernels
                tb = tf = 0
            traffic += ft * tb
            traffic_fused += ft * tf
            if cb:
                coll[kind]["count"] += 1
                coll[kind]["bytes"] += f * cb
                total_coll += f * cb
    return {
        "flops": flops,
        "hbm_traffic_bytes": traffic,
        "hbm_traffic_fused_bytes": traffic_fused,
        "collectives": {k: dict(v) for k, v in coll.items()},
        "collective_bytes": total_coll,
        "num_whiles": len(body_trip),
        "trips": {k: int(v) for k, v in body_trip.items()},
    }


def collective_bytes(hlo_text: str) -> dict:
    """Back-compat shim: loop-corrected collective summary."""
    res = analyze_hlo(hlo_text)
    out = dict(res["collectives"])
    out["total_bytes"] = res["collective_bytes"]
    return out


def collective_inventory(hlo_text: str) -> dict:
    """Per-instruction collective accounting of one compiled module.

    Where :func:`analyze_hlo` reports loop-corrected *bytes moved* (an
    all-reduce counts 2x), this reports the raw **payload** of every
    collective instruction in the text — the output buffer each op
    produces — which is what the comm-schedule gates compare: a barrier
    ``all-gather`` materializes the full ``[P, ...]`` stack in one step,
    while a ring schedule's ``collective-permute`` hops each carry a
    ``1/P`` block.  Ring schedules are Python-unrolled (one HLO
    instruction per hop), so no trip correction applies; ``*-start`` ops
    are counted once and their ``*-done`` halves skipped.

    Returns ``{"ops": [{"kind", "dtype", "shape", "payload_bytes"}...],
    "by_kind": {kind: {"count", "payload_bytes", "peak_payload_bytes"}},
    "total_payload_bytes", "peak_payload_bytes"}``.
    """
    ops: list[dict] = []
    for raw in hlo_text.splitlines():
        dm = _DEF_RE.match(raw.strip())
        if not dm:
            continue
        op = dm.group(3)
        kind = next((c for c in _COLLECTIVES
                     if op == c or op == c + "-start"), None)
        if kind is None:
            continue
        for b in _type_buffers(dm.group(2)):
            ops.append({"kind": kind, "dtype": b["dtype"],
                        "shape": b["shape"], "payload_bytes": b["bytes"]})
    by_kind: dict[str, dict] = {}
    for o in ops:
        e = by_kind.setdefault(o["kind"], {"count": 0, "payload_bytes": 0,
                                           "peak_payload_bytes": 0})
        e["count"] += 1
        e["payload_bytes"] += o["payload_bytes"]
        e["peak_payload_bytes"] = max(e["peak_payload_bytes"],
                                      o["payload_bytes"])
    return {
        "ops": ops,
        "by_kind": by_kind,
        "total_payload_bytes": sum(o["payload_bytes"] for o in ops),
        "peak_payload_bytes": max((o["payload_bytes"] for o in ops),
                                  default=0),
    }


# ---------------------------------------------------------------------------
# Buffer-assignment inspection: which arrays does a compiled program actually
# hold?  Used by benchmarks/kernel_bench.py to verify that the fused join
# epilogues never materialize the [T, M, C] JoinResult cube and to estimate
# per-stage peak allocations on backends where ``memory_analysis()`` is
# unavailable (CPU).
# ---------------------------------------------------------------------------


def _type_buffers(type_str: str) -> list[dict]:
    """Array components of one HLO type string (tuples yield one entry
    each): ``{"dtype", "dims", "shape", "elements", "bytes"}``."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        dlist = _dims(dims)
        n = 1
        for d in dlist:
            n *= d
        out.append({"dtype": dt, "dims": dlist,
                    "shape": "x".join(map(str, dlist)),
                    "elements": n, "bytes": n * _DTYPE_BYTES[dt]})
    return out


def buffer_inventory(hlo_text: str) -> list[dict]:
    """Every instruction-output buffer in a (post-optimization) HLO module.

    Tuple-typed outputs contribute one entry per component.  Returns
    ``[{"dtype", "dims", "shape", "elements", "bytes"}]`` unsorted;
    parameters are included (they are live allocations of the executable).
    """
    out = []
    for raw in hlo_text.splitlines():
        dm = _DEF_RE.match(raw.strip())
        if not dm:
            continue
        out.extend(_type_buffers(dm.group(2)))
    return out


def peak_buffer_stats(hlo_text: str, top: int = 5) -> dict:
    """Largest single buffer (the peak-allocation lower bound a program can
    never beat) plus the top-``top`` buffers for context."""
    inv = sorted(buffer_inventory(hlo_text), key=lambda b: -b["bytes"])
    if not inv:
        return {"largest_bytes": 0, "largest": None, "top": []}
    fmt = lambda b: {"dtype": b["dtype"], "shape": b["shape"],
                     "bytes": b["bytes"]}
    return {"largest_bytes": inv[0]["bytes"], "largest": fmt(inv[0]),
            "top": [fmt(b) for b in inv[:top]]}


def find_buffers_with_elements(hlo_text: str, elements: int,
                               dtypes=("f32", "s32")) -> list[dict]:
    """Buffers of the given dtypes holding exactly ``elements`` entries —
    the shape-agnostic fingerprint of a materialized join cube (it may
    appear as [T, M, C], [T*M, C], or flattened)."""
    return [b for b in buffer_inventory(hlo_text)
            if b["dtype"] in dtypes and b["elements"] == elements]


def interface_buffer_stats(hlo_text: str, top: int = 5) -> dict:
    """Parameter and ROOT-output buffers of the ENTRY computation.

    These are the arrays that necessarily live in HBM across the program
    boundary — the honest cross-stage footprint.  Loop-body temporaries
    (e.g. the ``[bp, bc, bm]`` pairwise block a Pallas grid step holds)
    are excluded: on TPU they are VMEM scratch; the CPU interpret lowering
    merely makes them visible as internal HLO buffers.
    """
    in_entry = False
    bufs: list[dict] = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if line.endswith("{") and "(" in line and "=" not in line.split(
                "(")[0]:
            m = _COMP_HDR.match(line)
            if m:
                in_entry = bool(m.group(1))
                continue
        if line.startswith("}"):
            in_entry = False
            continue
        if not in_entry:
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        is_root = line.startswith("ROOT")
        if dm.group(3) != "parameter" and not is_root:
            continue
        kind = "param" if dm.group(3) == "parameter" else "output"
        for b in _type_buffers(dm.group(2)):
            bufs.append({"kind": kind, "dtype": b["dtype"],
                         "shape": b["shape"], "bytes": b["bytes"]})
    bufs.sort(key=lambda b: -b["bytes"])
    return {
        "largest_bytes": bufs[0]["bytes"] if bufs else 0,
        "largest": bufs[0] if bufs else None,
        "total_bytes": sum(b["bytes"] for b in bufs),
        "top": bufs[:top],
    }
