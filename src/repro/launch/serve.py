"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the wave-batched ServeEngine over synthetic requests on a reduced
config (CPU) or the full config (pod).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced_config
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.utils.logging import get_logger

log = get_logger("serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced_config(cfg)
    if cfg.family == "audio":
        raise SystemExit("audio decode is exercised by the dry-run "
                         "(multi-codebook prompts need the EnCodec stub); "
                         "pick a text arch for the serving demo")
    if cfg.family in ("vlm",):
        log.warning("vlm serving demo uses text-only prompts")

    params = tf.init_model(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(params, cfg, n_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        L = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        if cfg.family == "audio":
            prompt = rng.integers(0, cfg.vocab_size,
                                  (cfg.n_codebooks, L)).astype(np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, L).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    log.info("served %d requests, %d tokens in %.2fs (%.1f tok/s); "
             "%d decode steps, %d prefill calls, padding waste %.2f",
             len(done), total_new, dt, total_new / max(dt, 1e-9),
             engine.decode_steps, engine.prefill_calls,
             engine.padding_waste / max(engine.prefill_calls, 1))
    return done


if __name__ == "__main__":
    main()
