"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs reduced configs end-to-end (the full configs
are exercised by the dry-run); on a real pod the same entrypoint runs the
full config on the production mesh with checkpoint/restart.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, reduced_config
from repro.data.pipeline import TokenPipeline
from repro.distributed.straggler import StragglerMonitor
from repro.models import transformer as tf
from repro.train.step import TrainState, make_train_state, train_step
from repro.utils.logging import get_logger

log = get_logger("train")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the full (pod-scale) config, not the reduced")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced_config(cfg)
    pipe = TokenPipeline(cfg, args.batch, args.seq, seed=args.seed)
    state = make_train_state(jax.random.PRNGKey(args.seed), cfg)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr and args.resume:
        try:
            state, start = mgr.restore(state)
            log.info("resumed from step %d", start)
        except FileNotFoundError:
            log.info("no checkpoint found; starting fresh")

    import functools
    step_fn = jax.jit(functools.partial(
        train_step, cfg=cfg, peak_lr=args.lr, warmup=20,
        total_steps=args.steps), donate_argnums=(0,))

    monitor = StragglerMonitor(n_hosts=1)
    losses = []
    t_last = time.time()
    for i in range(start, args.steps):
        batch = pipe.batch_at(i)
        fe = batch.get("frontend")
        if fe is not None:
            state, metrics = step_fn(state, jnp.asarray(batch["tokens"]),
                                     jnp.asarray(batch["labels"]),
                                     frontend_inputs=jnp.asarray(fe))
        else:
            state, metrics = step_fn(state, jnp.asarray(batch["tokens"]),
                                     jnp.asarray(batch["labels"]))
        now = time.time()
        monitor.record(0, now - t_last)
        t_last = now
        losses.append(float(metrics["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            log.info("step %d loss %.4f lr %.2e gnorm %.3f", i,
                     losses[-1], float(metrics["lr"]),
                     float(metrics["grad_norm"]))
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save_async(i + 1, state)
    if mgr:
        mgr.wait()
        mgr.save(args.steps, state)
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    log.info("loss %.4f -> %.4f (%s)", first, last,
             "IMPROVED" if last < first else "no improvement")
    return losses


if __name__ == "__main__":
    main()
