import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (no device allocation — ShapeDtypeStruct only):
  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — XLA's flops/bytes (loop bodies counted 1x)
  * loop-corrected FLOPs + collective bytes (repro.launch.hlo_analysis)
and writes one JSON per cell under results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --dsc dsc_synth --mesh single
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import (ARCHITECTURES, DSC_CONFIGS, SHAPES,
                                    get_arch, get_dsc_config,
                                    shape_applicable)
from repro.distributed import partition
from repro.launch import hlo_analysis
from repro.launch.input_specs import abstract_train_state, input_specs
from repro.launch.mesh import dp_axes_of, make_dsc_mesh, make_production_mesh
from repro.models import transformer as tf
from repro.serve.engine import decode_step, prefill_step
from repro.train.step import train_step
from repro.utils.logging import get_logger

log = get_logger("dryrun")
RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mem_dict(mem) -> dict:
    keys = ["generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes", "host_generated_code_size_in_bytes",
            "host_argument_size_in_bytes", "host_output_size_in_bytes",
            "host_temp_size_in_bytes", "peak_memory_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_dict(cost) -> dict:
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return {k: float(v) for k, v in dict(cost).items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" not in k)}


def run_cell(arch: str, shape: str, multi_pod: bool,
             save_hlo: bool = False, policy: str = "tp",
             moe_quant: bool = False, moe_cap: float = None,
             remat: bool = True, suffix: str = "") -> dict:
    import dataclasses as _dc
    cfg = get_arch(arch)
    if cfg.moe is not None and (moe_quant or moe_cap):
        moe = cfg.moe
        if moe_quant:
            moe = _dc.replace(moe, quantize_dispatch=True)
        if moe_cap:
            moe = _dc.replace(moe, capacity_factor=moe_cap)
        cfg = _dc.replace(cfg, moe=moe)
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape,
           "mesh": "multi" if multi_pod else "single",
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    if not ok:
        rec.update(status="SKIP", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes_of(mesh)
    if policy in ("dp_only", "dp_fsdp"):
        dp = dp + ("model",)
    spec = input_specs(arch, shape)
    kind = spec["kind"]
    ep = mesh.shape.get("model", 1)
    rec["policy"] = policy
    rec["moe_quant"] = moe_quant

    t0 = time.time()
    with mesh:
        if kind == "train":
            state = abstract_train_state(cfg, ep_degree=ep)
            pspecs = partition.param_specs(state.params, cfg, mesh,
                                           policy=policy)
            state_sh = partition.named(mesh, dataclasses.replace(
                state,
                params=pspecs,
                opt=dataclasses.replace(
                    state.opt, step=P(), mu=pspecs, nu=pspecs)))
            dspecs = partition.data_specs(
                cfg, mesh, kind=kind, global_batch=spec["batch"],
                seq_len=spec["seq"], policy=policy)
            tok_sh = NamedSharding(mesh, dspecs["tokens"])
            args = [state, spec["tokens"], spec["labels"]]
            in_sh = [state_sh, tok_sh, tok_sh]
            if "frontend" in spec:
                args.append(spec["frontend"])
                in_sh.append(NamedSharding(mesh, dspecs["frontend"]))

                def fn(st, tok, lab, fe):
                    return train_step(st, tok, lab, cfg,
                                      frontend_inputs=fe, mesh=mesh,
                                      dp_axes=dp, remat=remat)
            else:
                def fn(st, tok, lab):
                    return train_step(st, tok, lab, cfg, mesh=mesh,
                                      dp_axes=dp, remat=remat)
            jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(*args)
        else:
            params = jax.eval_shape(
                lambda: tf.init_model(jax.random.PRNGKey(0), cfg,
                                      ep_degree=ep))
            pspecs = partition.param_specs(params, cfg, mesh,
                                           policy=policy)
            params_sh = partition.named(mesh, pspecs)
            dspecs = partition.data_specs(
                cfg, mesh, kind=kind, global_batch=spec["batch"],
                seq_len=spec["seq"], policy=policy)
            tok_sh = NamedSharding(mesh, dspecs["tokens"])
            cache_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), dspecs["cache"],
                is_leaf=lambda x: isinstance(x, P))
            # align cache sharding tree with the abstract cache pytree
            cache_abs = spec["cache"]
            cache_sh_tree = {k: cache_sh[k] for k in cache_abs}
            if kind == "prefill":
                if "frontend" in spec:
                    def fn(p, tok, cache, fe):
                        return prefill_step(p, tok, cache, cfg,
                                            frontend_inputs=fe, mesh=mesh,
                                            dp_axes=dp)
                    jitted = jax.jit(
                        fn, in_shardings=(params_sh, tok_sh, cache_sh_tree,
                                          NamedSharding(
                                              mesh, dspecs["frontend"])),
                        donate_argnums=(2,))
                    lowered = jitted.lower(params, spec["tokens"],
                                           cache_abs, spec["frontend"])
                else:
                    def fn(p, tok, cache):
                        return prefill_step(p, tok, cache, cfg, mesh=mesh,
                                            dp_axes=dp)
                    jitted = jax.jit(
                        fn, in_shardings=(params_sh, tok_sh, cache_sh_tree),
                        donate_argnums=(2,))
                    lowered = jitted.lower(params, spec["tokens"], cache_abs)
            else:
                def fn(p, tok, cache, idx):
                    return decode_step(p, tok, cache, idx, cfg, mesh=mesh,
                                       dp_axes=dp)
                jitted = jax.jit(
                    fn, in_shardings=(params_sh, tok_sh, cache_sh_tree,
                                      NamedSharding(mesh, P())),
                    donate_argnums=(2,))
                lowered = jitted.lower(params, spec["tokens"], cache_abs,
                                       spec["index"])
        compiled = lowered.compile()

    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    corrected = hlo_analysis.analyze_hlo(text)
    rec.update(
        status="OK",
        compile_seconds=round(t1 - t0, 1),
        memory=_mem_dict(mem),
        cost=_cost_dict(cost),
        corrected_flops=corrected["flops"],
        hbm_traffic_bytes=corrected["hbm_traffic_bytes"],
        hbm_traffic_fused_bytes=corrected["hbm_traffic_fused_bytes"],
        collective_bytes=corrected["collective_bytes"],
        collectives=corrected["collectives"],
        num_whiles=corrected["num_whiles"],
        sharding_report=partition.report_sharding(
            state.params if kind == "train" else params, pspecs),
        devices=int(np.prod(list(mesh.shape.values()))),
        mesh_shape=dict(mesh.shape),
    )
    import gzip
    with gzip.open(
            RESULTS / f"{arch}_{shape}_{rec['mesh']}{suffix}.hlo.txt.gz",
            "wt") as fh:
        fh.write(text)
    del save_hlo
    print(f"[dryrun] {arch} x {shape} x {rec['mesh']}: "
          f"compile {rec['compile_seconds']}s, "
          f"temp {rec['memory'].get('temp_size_in_bytes', 0)/2**30:.2f} GiB, "
          f"flops {rec['corrected_flops']:.3e}, "
          f"coll {rec['collective_bytes']/2**30:.3f} GiB")
    print("memory_analysis:", rec["memory"])
    print("cost_analysis:", {k: f"{v:.3e}" for k, v in rec["cost"].items()
                             if "flops" in k or "bytes" in k})
    return rec


def run_dsc_cell(name: str, multi_pod: bool, sim_strategy: str = "psum",
                 sim_dtype: str = "f32", suffix: str = "") -> dict:
    """Dry-run the paper's own pipeline on the production mesh."""
    from repro.core.distributed import run_dsc_distributed
    from repro.core.partitioning import PartitionedBatch
    from repro.core.types import DSCParams

    rc = get_dsc_config(name)
    mesh = make_dsc_mesh(multi_pod=multi_pod)
    nP = mesh.shape["part"]
    T = max(rc.n_trajs, nP * 16)
    T = -(-T // (nP * 16)) * (nP * 16)      # divisible by both axes
    Mp = rc.max_points
    parts = PartitionedBatch(
        x=jax.ShapeDtypeStruct((nP, T, Mp), jnp.float32),
        y=jax.ShapeDtypeStruct((nP, T, Mp), jnp.float32),
        t=jax.ShapeDtypeStruct((nP, T, Mp), jnp.float32),
        valid=jax.ShapeDtypeStruct((nP, T, Mp), jnp.bool_),
        traj_id=jax.ShapeDtypeStruct((T,), jnp.int32),
        ranges=jax.ShapeDtypeStruct((nP, 2), jnp.float32),
    )
    params = DSCParams(
        eps_sp=rc.eps_sp, eps_t=rc.eps_t, delta_t=rc.delta_t, w=rc.w,
        tau=rc.tau, alpha_sigma=rc.alpha_sigma, k_sigma=rc.k_sigma,
        max_subtrajs_per_traj=rc.max_subtrajs, segmentation=rc.segmentation)

    t0 = time.time()
    from repro.core import distributed as dsc_dist
    import functools

    lowered = jax.jit(
        functools.partial(dsc_dist.run_dsc_distributed_lowerable,
                          params=params, mesh=mesh,
                          sim_strategy=sim_strategy,
                          sim_dtype=sim_dtype)).lower(parts)
    compiled = lowered.compile()
    t1 = time.time()
    text = compiled.as_text()
    corrected = hlo_analysis.analyze_hlo(text)
    rec = {
        "arch": name, "shape": f"T{T}xMp{Mp}",
        "mesh": "multi" if multi_pod else "single",
        "status": "OK", "compile_seconds": round(t1 - t0, 1),
        "memory": _mem_dict(compiled.memory_analysis()),
        "cost": _cost_dict(compiled.cost_analysis()),
        "corrected_flops": corrected["flops"],
        "hbm_traffic_bytes": corrected["hbm_traffic_bytes"],
        "hbm_traffic_fused_bytes": corrected["hbm_traffic_fused_bytes"],
        "collective_bytes": corrected["collective_bytes"],
        "collectives": corrected["collectives"],
        "devices": int(np.prod(list(mesh.shape.values()))),
        "mesh_shape": dict(mesh.shape),
    }
    rec["sim_strategy"] = sim_strategy
    rec["sim_dtype"] = sim_dtype
    import gzip
    with gzip.open(RESULTS / f"{name}_{rec['mesh']}{suffix}.hlo.txt.gz",
                   "wt") as fh:
        fh.write(text)
    print(f"[dryrun] DSC {name} x {rec['mesh']}: compile "
          f"{rec['compile_seconds']}s")
    print("memory_analysis:", rec["memory"])
    print("cost_analysis:", {k: f"{v:.3e}" for k, v in rec["cost"].items()})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--dsc", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--policy", default="tp",
                    choices=["tp", "dp_only", "dp_fsdp"])
    ap.add_argument("--moe-quant", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--moe-cap", type=float, default=None)
    ap.add_argument("--remat-dots", action="store_true")
    ap.add_argument("--sim-strategy", default="psum",
                    choices=["psum", "allgather"])
    ap.add_argument("--sim-dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--suffix", default="",
                    help="output-name suffix for hillclimb variants")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = []
    if args.dsc:
        for mp in meshes:
            cells.append(("dsc", args.dsc, mp))
    elif args.all:
        for arch in ARCHITECTURES:
            for shape in SHAPES:
                for mp in meshes:
                    cells.append(("lm", (arch, shape), mp))
        for name in DSC_CONFIGS:
            for mp in meshes:
                cells.append(("dsc", name, mp))
    else:
        for mp in meshes:
            cells.append(("lm", (args.arch, args.shape), mp))

    failures = 0
    for kind, what, mp in cells:
        key = (f"{what[0]}_{what[1]}" if kind == "lm" else what) + \
            ("_multi" if mp else "_single") + args.suffix
        out_path = RESULTS / f"{key}.json"
        if out_path.exists():
            log.info("skip cached %s", key)
            continue
        try:
            if kind == "lm":
                rec = run_cell(what[0], what[1], mp,
                               save_hlo=args.save_hlo,
                               policy=args.policy,
                               moe_quant=args.moe_quant,
                               moe_cap=args.moe_cap,
                               remat=("dots" if args.remat_dots
                                      else not args.no_remat),
                               suffix=args.suffix)
            else:
                rec = run_dsc_cell(what, mp,
                                   sim_strategy=args.sim_strategy,
                                   sim_dtype=args.sim_dtype,
                                   suffix=args.suffix)
        except Exception as e:      # noqa: BLE001 — record and continue
            rec = {"arch": str(what), "mesh": "multi" if mp else "single",
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            failures += 1
            log.error("FAIL %s: %s", key, e)
        out_path.write_text(json.dumps(rec, indent=1, default=str))
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
