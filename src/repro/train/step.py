"""The jit-compiled training step: loss -> grads -> clip -> AdamW.

This is the function the multi-pod dry-run lowers and compiles for every
(arch x train shape x mesh) cell; buffers are donated so the compiled
memory picture is the steady-state one.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               clip_by_global_norm)
from repro.optim.schedule import cosine_schedule
from repro.utils.tree import pytree_dataclass


@pytree_dataclass
class TrainState:
    params: Any
    opt: AdamWState


def make_train_state(key, cfg: ModelConfig, ep_degree: int = 1) -> TrainState:
    params = tf.init_model(key, cfg, ep_degree=ep_degree)
    return TrainState(params=params, opt=adamw_init(params))


def train_step(state: TrainState, tokens, labels, cfg: ModelConfig, *,
               frontend_inputs=None, mesh=None, dp_axes: tuple = (),
               peak_lr: float = 3e-4, warmup: int = 200,
               total_steps: int = 10_000, grad_clip: float = 1.0,
               remat=True):
    """One optimizer step; returns (new_state, metrics)."""

    def loss_fn(params):
        logits, aux, _ = tf.forward(
            params, tokens, cfg, frontend_inputs=frontend_inputs,
            remat=remat, mesh=mesh, dp_axes=dp_axes)
        mask = (labels >= 0).astype(jnp.float32)
        loss = tf.lm_loss(logits, jnp.maximum(labels, 0), mask)
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_weight * aux["moe_aux"]
        return loss, aux

    (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        state.params)
    grads, gnorm = clip_by_global_norm(grads, grad_clip)
    lr = cosine_schedule(state.opt.step, warmup, total_steps, peak_lr)
    new_params, new_opt = adamw_update(grads, state.opt, state.params, lr=lr)
    metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
               "moe_aux": aux["moe_aux"], "moe_dropped": aux["moe_dropped"]}
    return TrainState(params=new_params, opt=new_opt), metrics


def make_jitted_train_step(cfg: ModelConfig, mesh=None, dp_axes: tuple = (),
                           in_shardings=None, out_shardings=None, **kw):
    fn = functools.partial(train_step, cfg=cfg, mesh=mesh, dp_axes=dp_axes,
                           **kw)

    def wrapper(state, tokens, labels, frontend_inputs=None):
        return fn(state, tokens, labels, frontend_inputs=frontend_inputs)

    return jax.jit(wrapper, donate_argnums=(0,),
                   in_shardings=in_shardings, out_shardings=out_shardings)
