"""Windowed incremental DSC: the long-running streaming service core.

The :class:`StreamDriver` keeps one *active window* of the stream — a
fixed-capacity ``[T, M]`` trajectory store over event times in
``[watermark - horizon, +inf)`` — plus the standing derived state of the
whole DSC pipeline over that window:

* the join **cube** ``best_w/best_idx [T, M, T]`` (DTJ output with the
  window itself as candidate set),
* per-point voting and segmentation (``sub_local``) and the ST relation
  (:class:`~repro.core.types.SubtrajTable`) over ``S = T * max_subs``
  slots,
* **standing neighbor lists** ``[S, K+1]`` — the canonical top-``K+1``
  of the window's similarity panel (the +1 column is the spill that
  feeds the exactness certificate),
* cluster labels from warm-started round-parallel Algorithm 4.

Incrementality contract (DESIGN.md §13.4)
-----------------------------------------
Every window advance computes exactly what a from-scratch batch run over
the current window contents would: the delta path is a *performance*
strategy, never an approximation.  Per advance:

1. admitted records are inserted time-sorted into their object's row;
   the set of touched rows is **dirty**;
2. eviction (event time < ``watermark - horizon``) left-packs rows and
   extends the dirty set;
3. only dirty rows get fresh bounding boxes and a delta join — dirty
   rows vs the whole window (forward) and the whole window vs dirty
   rows (reverse), bbox-pruned by :func:`exact_pair_mask`.  Scattered
   into the cube these reproduce the full batch join bit for bit:
   each ``(r, m, c)`` cell is a pure function of row ``r`` and row
   ``c``'s points, so recomputing the dirty cross sections and keeping
   the clean x clean block is exact;
4. voting / segmentation / ST rebuild from the cube (cheap, [T, M]);
   rows whose segmentation changed join the dirty set for similarity;
5. a **fresh block** recomputes the dirty slots' similarity rows and
   columns from the cube; standing lists merge it: dirty rows are
   replaced outright, clean rows purge dirty/invalid neighbors and
   fold the fresh *column* candidates back in via the canonical
   ``sort_topk_lists`` merge (a set function — order-independent);
6. a clean row whose list was full before the purge and whose new
   ``K+1``-th value does not exceed the old one may have lost mass it
   can no longer prove it never needed: such **stale** rows are
   recomputed outright in a second fresh-block pass *within the same
   advance* (pass 2 purges nothing, so no cascade — two passes always
   suffice).  Standing lists therefore equal the batch top-``K+1`` of
   the current window at every advance boundary, bit for bit;
7. clustering warm-starts: slots whose visit rank, potential flag and
   neighbor list all survived unchanged — the prefix ``[0, r*)`` of the
   visit order — are seeded as already-resolved with their previous
   rep/member verdicts (valid because a slot's verdict in Algorithm 4
   depends only on earlier-ranked slots; requires the *absolute*
   thresholds StreamConfig enforces, so alpha/k cannot drift with
   window statistics);
8. every ``snapshot_every`` advances the full state snapshots through
   :class:`~repro.checkpoint.CheckpointManager` (atomic, CRC-verified,
   schema/config-fingerprinted) so a killed service resumes
   bit-identically; the staging queue is never serialized — snapshots
   land at advance boundaries where it is empty, and the submission
   cursor replays the rest.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, checkpoint_meta
from repro.core.clustering import cluster_rounds_topk, visit_order
from repro.core.geometry import best_match_join, filter_delta_t
from repro.core.segmentation import tsa1, tsa2
from repro.core.similarity import (build_subtraj_table_arrays,
                                   sort_topk_lists, topk_overflow)
from repro.core.types import DSCParams, SubtrajTable, TopKSim, TrajectoryBatch
from repro.core.voting import normalized_voting
from repro.core.windows import pack_bits
from repro.index.grid import TileBoxes, exact_pair_mask
from repro.stream.ingest import Ingestor, Records
from repro.stream.window import BackpressureOverflow, WindowManager

# bump when the snapshot layout changes; resume refuses mismatches
STREAM_SNAPSHOT_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Everything the streaming service needs, in one frozen record.

    Thresholds are **absolute** (``alpha_abs``/``k_abs`` >= 0 required):
    sigma-relative thresholds would drift with the window's similarity
    distribution, invalidating both the warm-start seeding and the
    advance-to-advance comparability of labels.
    """

    t_cap: int                    # window row capacity (objects)
    m_cap: int                    # per-row point capacity
    eps_sp: float
    eps_t: float
    alpha_abs: float
    k_abs: float
    allowed_lateness: float
    horizon: float
    max_subs: int = 4
    k: int = 8                    # neighbor-list width K (lists keep K+1)
    delta_t: float = 0.0
    w: int = 4
    tau: float = 0.4
    segmentation: str = "tsa1"
    queue_cap: int = 4096
    backpressure: str = "shed_oldest"   # "shed_oldest" | "block"
    on_dirty: str = "repair"            # "repair" | "drop" | "fail"
    max_speed: Optional[float] = None
    stall_advances: int = 0
    snapshot_every: int = 0             # 0 disables periodic snapshots
    warm_start: bool = True

    def validate(self) -> "StreamConfig":
        for name in ("t_cap", "m_cap", "max_subs", "k"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be a positive int, got {v!r}")
        if self.alpha_abs < 0 or self.k_abs < 0:
            raise ValueError(
                "streaming requires absolute thresholds: alpha_abs and "
                "k_abs must be >= 0 (sigma-relative thresholds drift with "
                "the window and break warm-start validity)")
        if self.horizon < self.allowed_lateness:
            raise ValueError(
                f"horizon ({self.horizon}) must cover allowed_lateness "
                f"({self.allowed_lateness}): a tolerably-late record must "
                "still land inside the active window")
        if self.segmentation not in ("tsa1", "tsa2"):
            raise ValueError(f"segmentation={self.segmentation!r}")
        if self.backpressure not in ("shed_oldest", "block"):
            raise ValueError(f"backpressure={self.backpressure!r}")
        if self.on_dirty not in ("repair", "drop", "fail"):
            raise ValueError(f"on_dirty={self.on_dirty!r}")
        return self

    @property
    def params(self) -> DSCParams:
        return DSCParams(
            eps_sp=self.eps_sp, eps_t=self.eps_t, delta_t=self.delta_t,
            w=self.w, tau=self.tau, alpha_abs=self.alpha_abs,
            k_abs=self.k_abs, max_subtrajs_per_traj=self.max_subs,
            segmentation=self.segmentation)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "StreamConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"unknown StreamConfig fields "
                             f"{sorted(unknown)}")
        return cls(**d).validate()

    def fingerprint(self) -> str:
        """Stable digest of the config; snapshots embed it and resume
        refuses state written under a different configuration."""
        return hashlib.sha1(
            json.dumps(self.to_dict(), sort_keys=True).encode()).hexdigest()


# --------------------------------------------------------------------------
# jitted pipeline pieces (module-level so retraces are bounded by the
# distinct padded dirty-row bucket sizes, not by driver instances)
# --------------------------------------------------------------------------

@jax.jit
def _delta_join(dx, dy, dt_, dvalid, dobj, wx, wy, wt, wvalid, wobj,
                fwd_mask, eps_sp, eps_t, delta_t):
    """Dirty-rows-vs-window join, both directions.

    Per-cell values equal the full batch join's: ``best_w[r, m, c]`` is a
    function of row ``r``'s and row ``c``'s points alone, and
    ``filter_delta_t`` acts per (ref row, cand col) independently.
    """
    ref = TrajectoryBatch(x=dx, y=dy, t=dt_, valid=dvalid, traj_id=dobj)
    cand = TrajectoryBatch(x=wx, y=wy, t=wt, valid=wvalid, traj_id=wobj)
    dt = jnp.asarray(delta_t, jnp.float32)

    def run(r, c, mask):
        j = best_match_join(r, c, eps_sp, eps_t, prune_mask=mask)
        return jax.lax.cond(dt > 0.0,
                            lambda jj: filter_delta_t(jj, r.t, dt),
                            lambda jj: jj, j)

    fwd = run(ref, cand, fwd_mask)
    rev = run(cand, ref, fwd_mask.T)
    return fwd.best_w, fwd.best_idx, rev.best_w, rev.best_idx


@functools.partial(jax.jit, static_argnames=("segmentation", "w", "max_subs"))
def _window_tables(cube_w, wt, wvalid, tau, *, segmentation, w, max_subs):
    """Vote, segmentation and the ST relation from the standing cube —
    identical ops to ``run_dsc``'s segment stage over the same join."""
    vote = jnp.sum(cube_w, axis=-1)
    if segmentation == "tsa1":
        seg = tsa1(normalized_voting(vote, wvalid), wvalid, w, tau,
                   max_subs)
    else:
        seg = tsa2(pack_bits(cube_w > 0.0), wvalid, w, tau, max_subs)
    table = build_subtraj_table_arrays(wt, wvalid, seg.sub_local, vote,
                                       max_subs)
    return vote, seg.sub_local, table


@functools.partial(jax.jit, static_argnames=("max_subs", "kk"))
def _fresh_block(cube_w, cube_idx, sub_local, card, tvalid, dirty_rows, *,
                 max_subs, kk):
    """Exact similarity rows AND columns of the dirty slots.

    Scatter-adds the dirty rows' raw cube entries (forward: flat
    ``(d, m, c)`` order preserves the batch path's per-cell ``(m, c)``
    contribution subsequence) and the dirty columns (reverse: ``(r, m)``
    per fixed column), symmetrizes with max, then normalizes by
    ``min(card)`` — max-then-divide, which equals the batch path's
    divide-then-max because the denominator is symmetric in the pair and
    IEEE division by a positive value is monotone.

    Returns the dirty slots' own top-``kk`` lists (``fresh_*``) plus, for
    every slot of the window, the top-``min(kk, Sd)`` *candidates coming
    from dirty slots* (``cand_*``) — what clean rows fold into their
    purged standing lists.  Truncating candidates to ``kk`` is safe: a
    dirty-slot value dropped here is below ``kk`` dirty values already in
    the candidate list, so it can never enter a top-``kk``.
    """
    T, M = sub_local.shape
    S = T * max_subs
    Dp = dirty_rows.shape[0]
    Sd = Dp * max_subs
    ok = dirty_rows >= 0
    rsafe = jnp.clip(dirty_rows, 0, T - 1)

    w_rows = jnp.where(ok[:, None, None], cube_w[rsafe], 0.0)
    i_rows = cube_idx[rsafe]
    w_cols = jnp.where(ok[None, None, :], cube_w[:, :, rsafe], 0.0)
    i_cols = cube_idx[:, :, rsafe]
    dsub = sub_local[rsafe]

    # forward: raw rows of the dirty slots
    src_l = jnp.where(ok[:, None] & (dsub >= 0),
                      jnp.arange(Dp)[:, None] * max_subs + dsub, Sd)
    src_l = jnp.broadcast_to(src_l[:, :, None], (Dp, M, T))
    idx = jnp.clip(i_rows, 0, M - 1)
    cand_sub = sub_local[jnp.arange(T)[None, None, :], idx]
    dst_g = jnp.where((i_rows >= 0) & (cand_sub >= 0),
                      jnp.arange(T)[None, None, :] * max_subs + cand_sub, S)
    fwd = jnp.zeros((Sd + 1, S + 1), jnp.float32).at[
        src_l.reshape(-1), dst_g.reshape(-1)].add(w_rows.reshape(-1))

    # reverse: raw columns of the dirty slots
    src_g = jnp.where(sub_local >= 0,
                      jnp.arange(T)[:, None] * max_subs + sub_local, S)
    src_g = jnp.broadcast_to(src_g[:, :, None], (T, M, Dp))
    idxc = jnp.clip(i_cols, 0, M - 1)
    dsub_at = dsub[jnp.arange(Dp)[None, None, :], idxc]
    dst_l = jnp.where(ok[None, None, :] & (i_cols >= 0) & (dsub_at >= 0),
                      jnp.arange(Dp)[None, None, :] * max_subs + dsub_at, Sd)
    rev = jnp.zeros((Sd + 1, S + 1), jnp.float32).at[
        dst_l.reshape(-1), src_g.reshape(-1)].add(w_cols.reshape(-1))

    sym = jnp.maximum(fwd[:Sd, :S], rev[:Sd, :S])
    slot_ids = jnp.where(
        ok[:, None],
        rsafe[:, None] * max_subs + jnp.arange(max_subs)[None, :],
        -1).reshape(Sd).astype(jnp.int32)
    ssafe = jnp.clip(slot_ids, 0, S - 1)
    denom = jnp.minimum(card[ssafe][:, None], card[None, :])
    sim = sym / jnp.maximum(denom, 1).astype(jnp.float32)
    keep = ((slot_ids >= 0)[:, None] & tvalid[ssafe][:, None]
            & tvalid[None, :]
            & (slot_ids[:, None] != jnp.arange(S)[None, :]))
    sim = jnp.where(keep, sim, 0.0)

    vals, idxk = jax.lax.top_k(sim, kk)
    fresh_ids = jnp.where(vals > 0.0, idxk, -1).astype(jnp.int32)
    fresh_sims = jnp.maximum(vals, 0.0)

    kc = min(kk, Sd)
    cvals, cidx = jax.lax.top_k(sim.T, kc)
    cand_ids = jnp.where(cvals > 0.0,
                         slot_ids[cidx], -1).astype(jnp.int32)
    cand_sims = jnp.maximum(cvals, 0.0)
    return slot_ids, fresh_ids, fresh_sims, cand_ids, cand_sims


@jax.jit
def _merge_standing(standing_ids, standing_sims, slot_ids, fresh_ids,
                    fresh_sims, cand_ids, cand_sims, dirty_slot, tvalid):
    """Fold a fresh block into the standing ``[S, kk]`` lists.

    Dirty slots take their fresh lists outright.  Clean slots purge
    neighbors that are dirty or no longer valid, then merge the fresh
    column candidates via the canonical two-key sort (a set function, so
    the result is independent of how evidence arrived).  ``stale`` marks
    clean rows whose post-merge list cannot be proven complete (full
    before the purge, lost entries, and the new tail does not beat the
    old one) — the caller recomputes those outright in a second pass.
    """
    S, kk = standing_ids.shape
    tgt = jnp.where(slot_ids >= 0, slot_ids, S)
    f_ids = jnp.full((S + 1, kk), -1, jnp.int32).at[tgt].set(fresh_ids)[:S]
    f_sims = jnp.zeros((S + 1, kk), jnp.float32).at[tgt].set(
        fresh_sims)[:S]

    sid_safe = jnp.clip(standing_ids, 0, S - 1)
    purge = (standing_ids >= 0) & (dirty_slot[sid_safe]
                                   | ~tvalid[sid_safe])
    pos_before = jnp.sum(standing_ids >= 0, axis=1)
    full_before = pos_before == kk
    v_min = standing_sims[:, kk - 1]
    purged_ids = jnp.where(purge, -1, standing_ids)
    purged_sims = jnp.where(purge, 0.0, standing_sims)
    purged_any = jnp.any(purge, axis=1)

    m_ids, m_sims = sort_topk_lists(
        jnp.concatenate([purged_ids, cand_ids], axis=1),
        jnp.concatenate([purged_sims, cand_sims], axis=1), kk)
    m_ids = jnp.where(m_sims > 0.0, m_ids, -1)
    m_sims = jnp.maximum(m_sims, 0.0)

    new_ids = jnp.where(dirty_slot[:, None], f_ids, m_ids)
    new_sims = jnp.where(dirty_slot[:, None], f_sims, m_sims)
    new_ids = jnp.where(tvalid[:, None], new_ids, -1)
    new_sims = jnp.where(tvalid[:, None], new_sims, 0.0)

    stale = (~dirty_slot & tvalid & full_before & purged_any
             & (new_sims[:, kk - 1] <= v_min))
    changed = jnp.any((new_ids != standing_ids)
                      | (new_sims != standing_sims), axis=1)
    return new_ids, new_sims, stale, changed


@jax.jit
def _scatter_fresh(standing_ids, standing_sims, slot_ids, fresh_ids,
                   fresh_sims, tvalid):
    """Pass 2: overwrite the stale rows with their recomputed lists."""
    S, kk = standing_ids.shape
    tgt = jnp.where(slot_ids >= 0, slot_ids, S)
    new_ids = standing_ids.at[tgt].set(fresh_ids, mode="drop")
    new_sims = standing_sims.at[tgt].set(fresh_sims, mode="drop")
    new_ids = jnp.where(tvalid[:, None], new_ids, -1)
    new_sims = jnp.where(tvalid[:, None], new_sims, 0.0)
    changed = jnp.any((new_ids != standing_ids)
                      | (new_sims != standing_sims), axis=1)
    return new_ids, new_sims, changed


@functools.partial(jax.jit, static_argnames=("K",))
def _cluster_warm(ids, sims, t_start, t_end, voting, card, tvalid,
                  traj_row, params, prev_rank, prev_potential, prev_is_rep,
                  row_changed, has_prev, *, K):
    """Warm-started round-parallel Algorithm 4 over the standing lists.

    Seeds the visit-order prefix ``[0, r*)`` — every slot ranked before
    the first slot whose (rank, potential, list) changed — as resolved
    with its previous verdict.  Valid because a slot's verdict depends
    only on earlier-ranked slots' (rank, potential, list) inputs, all of
    which are unchanged inside the prefix.  The zeroed degree/moment
    fields are never read: StreamConfig enforces absolute thresholds, so
    ``resolve_thresholds`` ignores the moments entirely.
    """
    S, kk = ids.shape
    table = SubtrajTable(t_start=t_start, t_end=t_end, voting=voting,
                         card=card, valid=tvalid, traj_row=traj_row)
    spill = sims[:, K] if kk > K else jnp.zeros((S,), jnp.float32)
    zi = jnp.zeros((S,), jnp.int32)
    zf = jnp.zeros((S,), jnp.float32)
    topk = TopKSim(ids=ids[:, :K], sims=sims[:, :K], spill=spill,
                   degree=zi, row_sum=zf, row_sumsq=zf)

    order, rank = visit_order(table)
    potential = table.valid & (table.voting >= params.k_abs)
    flagged = ((rank != prev_rank) | (potential != prev_potential)
               | row_changed)
    r_star = jnp.min(jnp.where(flagged, rank, S))
    r_star = jnp.where(has_prev, r_star, 0)
    seed_resolved = rank < r_star
    seed_is_rep = prev_is_rep & seed_resolved

    result, rounds = cluster_rounds_topk(
        topk, table, params, with_rounds=True,
        seed_resolved=seed_resolved, seed_is_rep=seed_is_rep)
    overflow = topk_overflow(topk, result.alpha_used)
    return result, rounds, rank, potential, overflow, jnp.sum(seed_resolved)


def _pow2_bucket(n: int, cap: int) -> int:
    """Next power of two >= n, clamped to cap — bounds jit retraces to
    O(log cap) distinct dirty-row shapes."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class StreamDriver:
    """The incremental windowed-DSC service state machine (host side)."""

    def __init__(self, config: StreamConfig, *,
                 checkpoint_dir=None, telemetry=None, injector=None,
                 keep_n: int = 3):
        self.config = config.validate()
        c = self.config
        T, M, mS = c.t_cap, c.m_cap, c.max_subs
        S = T * mS
        self.S = S
        self.kk = min(c.k + 1, S)        # standing list width (K + spill)
        self.K = min(c.k, self.kk)

        self.telemetry = telemetry
        self.injector = injector
        self.ingest = Ingestor(on_dirty=c.on_dirty, max_speed=c.max_speed,
                               known_t_fn=self._known_t)
        self.window = WindowManager(
            allowed_lateness=c.allowed_lateness, horizon=c.horizon,
            queue_cap=c.queue_cap, policy=c.backpressure,
            stall_advances=c.stall_advances)
        self.manager = (CheckpointManager(checkpoint_dir, keep_n=keep_n)
                        if checkpoint_dir is not None else None)

        # ---- window store -------------------------------------------------
        self.obj_of_row = np.full((T,), -1, np.int64)
        self._row_of: dict[int, int] = {}
        self.xs = np.zeros((T, M), np.float32)
        self.ys = np.zeros((T, M), np.float32)
        self.ts = np.zeros((T, M), np.float32)
        self.valid = np.zeros((T, M), bool)
        # ---- standing derived state ---------------------------------------
        self.cube_w = np.zeros((T, M, T), np.float32)
        self.cube_idx = np.full((T, M, T), -1, np.int32)
        self.vote = np.zeros((T, M), np.float32)
        self.sub_local = np.full((T, M), -1, np.int32)
        self.bx_min = np.full((T,), np.inf, np.float32)
        self.bx_max = np.full((T,), -np.inf, np.float32)
        self.by_min = np.full((T,), np.inf, np.float32)
        self.by_max = np.full((T,), -np.inf, np.float32)
        self.bt_min = np.full((T,), np.inf, np.float32)
        self.bt_max = np.full((T,), -np.inf, np.float32)
        self.b_nonempty = np.zeros((T,), bool)
        self.standing_ids = np.full((S, self.kk), -1, np.int32)
        self.standing_sims = np.zeros((S, self.kk), np.float32)
        self.t_start = np.zeros((S,), np.float32)
        self.t_end = np.zeros((S,), np.float32)
        self.voting = np.zeros((S,), np.float32)
        self.card = np.zeros((S,), np.int32)
        self.tvalid = np.zeros((S,), bool)
        self.traj_row = np.repeat(np.arange(T, dtype=np.int32), mS)
        self.member_of = np.full((S,), -1, np.int32)
        self.member_sim = np.zeros((S,), np.float32)
        self.is_rep = np.zeros((S,), bool)
        self.is_outlier = np.zeros((S,), bool)
        self.alpha = float(c.alpha_abs)
        self.k_used = float(c.k_abs)
        self.prev_rank = np.zeros((S,), np.int32)
        self.prev_potential = np.zeros((S,), bool)
        self.prev_is_rep = np.zeros((S,), bool)
        self.has_prev = False
        # ---- counters ------------------------------------------------------
        self.advance_count = 0
        self.cursor = 0                  # next submission-batch index
        self.evicted_points = 0
        self.shed_capacity = 0           # records shed for lack of a row
        self.row_overflow = 0            # oldest points dropped from a row
        self.overflow_events = 0         # advances with topk overflow > 0
        self.inserted_total = 0
        self.last_rounds = 0
        self.warm_prefix = 0

    # ------------------------------------------------------------- plumbing
    def _known_t(self, obj: int) -> np.ndarray:
        r = self._row_of.get(int(obj))
        if r is None:
            return np.empty((0,), np.float32)
        return self.ts[r][self.valid[r]]

    def _emit(self, event: str, **fields):
        if self.telemetry is not None:
            self.telemetry.emit(event, **fields)

    # ------------------------------------------------------------------ api
    def submit(self, recs: Records) -> int:
        """Validate and stage one submission batch; returns its absolute
        index (the fault plan's and the resume cursor's key)."""
        idx = self.cursor
        self.cursor += 1
        if self.injector is not None:
            recs = self.injector.on_stream_batch(idx, recs)
        before = dict(self.ingest.counters)
        admitted = self.ingest.process(recs)      # may raise PoisonRecord
        deltas = {r: self.ingest.counters[r] - before[r]
                  for r in before if self.ingest.counters[r] > before[r]}
        if deltas:
            self._emit("record_quarantined", batch=idx,
                       total=int(sum(deltas.values())), **deltas)
        shed = self.window.stage(admitted)  # may raise BackpressureOverflow
        if shed:
            self._emit("backpressure", batch=idx, kind="queue_shed",
                       shed=int(shed))
        return idx

    def _insert(self, recs: Records, dirty: set) -> None:
        c = self.config
        T, M = c.t_cap, c.m_cap
        for i in range(recs.n):
            obj = int(recs.obj[i])
            r = self._row_of.get(obj)
            if r is None:
                free = np.nonzero(self.obj_of_row < 0)[0]
                if free.size == 0:
                    if self.window.policy == "block":
                        raise BackpressureOverflow(
                            f"window store full ({T} rows) and object "
                            f"{obj} needs a new row")
                    self.shed_capacity += 1
                    self._emit("backpressure", kind="capacity", obj=obj)
                    continue
                r = int(free[0])
                self.obj_of_row[r] = obj
                self._row_of[obj] = r
            n = int(np.sum(self.valid[r]))
            if n >= M:
                # drop the row's oldest point to admit the new one
                self.xs[r, :M - 1] = self.xs[r, 1:]
                self.ys[r, :M - 1] = self.ys[r, 1:]
                self.ts[r, :M - 1] = self.ts[r, 1:]
                n = M - 1
                self.valid[r, :] = False
                self.valid[r, :n] = True
                self.row_overflow += 1
            pos = int(np.searchsorted(self.ts[r, :n],
                                      np.float32(recs.t[i]), side="right"))
            # np.insert allocates a fresh row — safe for the overlapping
            # shift an in-place slice assignment would corrupt
            self.xs[r, :n + 1] = np.insert(self.xs[r, :n], pos, recs.x[i])
            self.ys[r, :n + 1] = np.insert(self.ys[r, :n], pos, recs.y[i])
            self.ts[r, :n + 1] = np.insert(self.ts[r, :n], pos, recs.t[i])
            self.valid[r, n] = True
            self.inserted_total += 1
            dirty.add(r)

    def _evict(self, dirty: set) -> int:
        cutoff = self.window.evict_before()
        if not np.isfinite(cutoff):
            return 0
        evicted = 0
        for r in range(self.config.t_cap):
            if self.obj_of_row[r] < 0:
                continue
            n = int(np.sum(self.valid[r]))
            keep = self.ts[r, :n] >= np.float32(cutoff)
            kn = int(np.sum(keep))
            if kn == n:
                continue
            evicted += n - kn
            self.xs[r, :kn] = self.xs[r, :n][keep]
            self.ys[r, :kn] = self.ys[r, :n][keep]
            self.ts[r, :kn] = self.ts[r, :n][keep]
            self.xs[r, kn:] = 0.0
            self.ys[r, kn:] = 0.0
            self.ts[r, kn:] = 0.0
            self.valid[r, :] = False
            self.valid[r, :kn] = True
            dirty.add(r)
            if kn == 0:
                del self._row_of[int(self.obj_of_row[r])]
                self.obj_of_row[r] = -1
        self.evicted_points += evicted
        return evicted

    def _update_bboxes(self, rows) -> None:
        for r in rows:
            v = self.valid[r]
            if not v.any():
                self.bx_min[r] = self.by_min[r] = self.bt_min[r] = np.inf
                self.bx_max[r] = self.by_max[r] = self.bt_max[r] = -np.inf
                self.b_nonempty[r] = False
                continue
            self.bx_min[r] = self.xs[r][v].min()
            self.bx_max[r] = self.xs[r][v].max()
            self.by_min[r] = self.ys[r][v].min()
            self.by_max[r] = self.ys[r][v].max()
            self.bt_min[r] = self.ts[r][v].min()
            self.bt_max[r] = self.ts[r][v].max()
            self.b_nonempty[r] = True

    def _padded_rows(self, rows: np.ndarray) -> np.ndarray:
        Dp = _pow2_bucket(max(int(rows.size), 1), self.config.t_cap)
        out = np.full((Dp,), -1, np.int64)
        out[:rows.size] = rows
        return out

    def _delta_arrays(self, rows: np.ndarray):
        """Gather padded dirty-row slices of the store (padding rows are
        all-invalid with obj -1, so they join to nothing)."""
        M = self.config.m_cap
        Dp = rows.shape[0]
        dx = np.zeros((Dp, M), np.float32)
        dy = np.zeros((Dp, M), np.float32)
        dt = np.zeros((Dp, M), np.float32)
        dv = np.zeros((Dp, M), bool)
        dobj = np.full((Dp,), -1, np.int32)
        ok = rows >= 0
        sel = rows[ok]
        dx[ok] = self.xs[sel]
        dy[ok] = self.ys[sel]
        dt[ok] = self.ts[sel]
        dv[ok] = self.valid[sel]
        dobj[ok] = self.obj_of_row[sel].astype(np.int32)
        return dx, dy, dt, dv, dobj

    def _boxes(self, rows: np.ndarray = None) -> TileBoxes:
        if rows is None:
            return TileBoxes(
                xmin=jnp.asarray(self.bx_min), xmax=jnp.asarray(self.bx_max),
                ymin=jnp.asarray(self.by_min), ymax=jnp.asarray(self.by_max),
                tmin=jnp.asarray(self.bt_min), tmax=jnp.asarray(self.bt_max),
                nonempty=jnp.asarray(self.b_nonempty))
        ok = rows >= 0
        sel = np.clip(rows, 0, self.config.t_cap - 1)

        def g(a, fill):
            out = a[sel].copy()
            out[~ok] = fill
            return jnp.asarray(out)

        return TileBoxes(
            xmin=g(self.bx_min, np.inf), xmax=g(self.bx_max, -np.inf),
            ymin=g(self.by_min, np.inf), ymax=g(self.by_max, -np.inf),
            tmin=g(self.bt_min, np.inf), tmax=g(self.bt_max, -np.inf),
            nonempty=jnp.asarray(np.where(ok, self.b_nonempty[sel], False)))

    # ------------------------------------------------------------- advance
    def advance(self) -> dict:
        """Drain the staging queue and bring every piece of standing
        state up to date with the new window contents."""
        c = self.config
        if self.injector is not None:
            self.injector.on_window_advance(self.advance_count)
        admitted, n_late = self.window.drain()    # may raise WatermarkStall
        if n_late:
            self._emit("late_dropped", advance=self.advance_count,
                       dropped=int(n_late),
                       watermark=float(self.window.watermark))

        dirty: set = set()
        inserted_before = self.inserted_total
        self._insert(admitted, dirty)
        inserted = self.inserted_total - inserted_before
        evicted = self._evict(dirty)

        if not dirty:
            self.advance_count += 1
            self._emit("window_advanced", advance=self.advance_count - 1,
                       watermark=float(self.window.watermark),
                       admitted=int(admitted.n), late=int(n_late),
                       inserted=0, evicted=0, dirty_rows=0, sim_rows=0,
                       pass2_rows=0, rounds=int(self.last_rounds),
                       warm_prefix=int(self.warm_prefix), noop=True,
                       reps=int(np.sum(self.is_rep)),
                       outliers=int(np.sum(self.is_outlier)), overflow=0)
            self._maybe_snapshot()
            return {"advance": self.advance_count - 1, "dirty_rows": 0,
                    "noop": True}

        D = np.asarray(sorted(dirty), np.int64)
        self._update_bboxes(D)

        # --- delta join ----------------------------------------------------
        rows = self._padded_rows(D)
        dx, dy, dt, dv, dobj = self._delta_arrays(rows)
        fwd_mask = exact_pair_mask(self._boxes(rows), self._boxes(),
                                   np.float32(c.eps_sp),
                                   np.float32(c.eps_t))
        fw, fi, rw, ri = _delta_join(
            dx, dy, dt, dv, dobj,
            jnp.asarray(self.xs), jnp.asarray(self.ys),
            jnp.asarray(self.ts), jnp.asarray(self.valid),
            jnp.asarray(self.obj_of_row.astype(np.int32)),
            fwd_mask, np.float32(c.eps_sp), np.float32(c.eps_t),
            np.float32(c.delta_t))
        fw, fi = np.asarray(fw), np.asarray(fi)
        rw, ri = np.asarray(rw), np.asarray(ri)
        nD = D.size
        self.cube_w[D] = fw[:nD]
        self.cube_idx[D] = fi[:nD]
        self.cube_w[:, :, D] = rw[:, :, :nD]
        self.cube_idx[:, :, D] = ri[:, :, :nD]

        # --- vote / segmentation / ST --------------------------------------
        old_sub = self.sub_local.copy()
        vote, sub_local, table = _window_tables(
            jnp.asarray(self.cube_w), jnp.asarray(self.ts),
            jnp.asarray(self.valid), np.float32(c.tau),
            segmentation=c.segmentation, w=c.w, max_subs=c.max_subs)
        self.vote = np.asarray(vote)
        self.sub_local = np.asarray(sub_local)
        self.t_start = np.asarray(table.t_start)
        self.t_end = np.asarray(table.t_end)
        self.voting = np.asarray(table.voting)
        self.card = np.asarray(table.card)
        self.tvalid = np.asarray(table.valid)

        struct_dirty = np.nonzero(
            np.any(self.sub_local != old_sub, axis=1))[0]
        D_sim = np.union1d(D, struct_dirty).astype(np.int64)

        # --- similarity: fresh block + standing merge ------------------------
        rows_sim = self._padded_rows(D_sim)
        dirty_slot = np.zeros((self.S,), bool)
        for r in D_sim:
            dirty_slot[int(r) * c.max_subs:(int(r) + 1) * c.max_subs] = True

        slot_ids, f_ids, f_sims, cd_ids, cd_sims = _fresh_block(
            jnp.asarray(self.cube_w), jnp.asarray(self.cube_idx),
            jnp.asarray(self.sub_local), jnp.asarray(self.card),
            jnp.asarray(self.tvalid), jnp.asarray(rows_sim),
            max_subs=c.max_subs, kk=self.kk)
        new_ids, new_sims, stale, changed = _merge_standing(
            jnp.asarray(self.standing_ids),
            jnp.asarray(self.standing_sims),
            slot_ids, f_ids, f_sims, cd_ids, cd_sims,
            jnp.asarray(dirty_slot), jnp.asarray(self.tvalid))
        stale = np.asarray(stale)
        changed = np.asarray(changed)

        pass2_rows = 0
        if stale.any():
            # recompute stale rows outright; pass 2 purges nothing, so it
            # cannot create new staleness — two passes always suffice
            rows2 = np.unique(np.nonzero(stale)[0] // c.max_subs)
            pass2_rows = int(rows2.size)
            rows2p = self._padded_rows(rows2.astype(np.int64))
            slot2, f2_ids, f2_sims, _, _ = _fresh_block(
                jnp.asarray(self.cube_w), jnp.asarray(self.cube_idx),
                jnp.asarray(self.sub_local), jnp.asarray(self.card),
                jnp.asarray(self.tvalid), jnp.asarray(rows2p),
                max_subs=c.max_subs, kk=self.kk)
            new_ids, new_sims, changed2 = _scatter_fresh(
                new_ids, new_sims, slot2, f2_ids, f2_sims,
                jnp.asarray(self.tvalid))
            changed = changed | np.asarray(changed2)

        self.standing_ids = np.asarray(new_ids)
        self.standing_sims = np.asarray(new_sims)

        # --- clustering (warm-started) ----------------------------------------
        result, rounds, rank, potential, overflow, warm_n = _cluster_warm(
            jnp.asarray(self.standing_ids),
            jnp.asarray(self.standing_sims),
            jnp.asarray(self.t_start), jnp.asarray(self.t_end),
            jnp.asarray(self.voting), jnp.asarray(self.card),
            jnp.asarray(self.tvalid), jnp.asarray(self.traj_row),
            c.params, jnp.asarray(self.prev_rank),
            jnp.asarray(self.prev_potential),
            jnp.asarray(self.prev_is_rep), jnp.asarray(changed),
            np.bool_(self.has_prev and c.warm_start), K=self.K)
        self.member_of = np.asarray(result.member_of)
        self.member_sim = np.asarray(result.member_sim)
        self.is_rep = np.asarray(result.is_rep)
        self.is_outlier = np.asarray(result.is_outlier)
        self.alpha = float(result.alpha_used)
        self.k_used = float(result.k_used)
        self.last_rounds = int(rounds)
        self.warm_prefix = int(warm_n)
        self.prev_rank = np.asarray(rank)
        self.prev_potential = np.asarray(potential)
        self.prev_is_rep = self.is_rep.copy()
        self.has_prev = True
        n_over = int(np.sum(np.asarray(overflow) > 0))
        if n_over:
            self.overflow_events += 1

        summary = {
            "advance": self.advance_count,
            "watermark": float(self.window.watermark),
            "admitted": int(admitted.n), "late": int(n_late),
            "inserted": int(inserted), "evicted": int(evicted),
            "dirty_rows": int(D.size), "sim_rows": int(D_sim.size),
            "pass2_rows": pass2_rows, "rounds": int(rounds),
            "warm_prefix": int(warm_n),
            "reps": int(np.sum(self.is_rep)),
            "outliers": int(np.sum(self.is_outlier)),
            "overflow": n_over,
        }
        self._emit("window_advanced", **summary)
        self.advance_count += 1
        self._maybe_snapshot()
        return summary

    # ------------------------------------------------------------ snapshots
    def _maybe_snapshot(self):
        if (self.manager is not None and self.config.snapshot_every
                and self.advance_count % self.config.snapshot_every == 0):
            self.snapshot()

    def snapshot(self):
        """Full-state snapshot at an advance boundary (queue must be
        empty — the submission cursor replays anything staged later)."""
        if self.window.queued() > 0:
            raise RuntimeError(
                "snapshot with a non-empty staging queue would lose "
                f"{self.window.queued()} records: advance() first")
        if self.manager is None:
            raise RuntimeError("no checkpoint_dir configured")
        tree = {
            "store": {"obj": self.obj_of_row, "x": self.xs, "y": self.ys,
                      "t": self.ts, "valid": self.valid},
            "cube": {"w": self.cube_w, "idx": self.cube_idx},
            "seg": {"sub_local": self.sub_local},
            "vote": {"vote": self.vote},
            "bbox": {"xmin": self.bx_min, "xmax": self.bx_max,
                     "ymin": self.by_min, "ymax": self.by_max,
                     "tmin": self.bt_min, "tmax": self.bt_max,
                     "nonempty": self.b_nonempty},
            "standing": {"ids": self.standing_ids,
                         "sims": self.standing_sims},
            "table": {"t_start": self.t_start, "t_end": self.t_end,
                      "voting": self.voting, "card": self.card,
                      "valid": self.tvalid},
            "labels": {"member_of": self.member_of,
                       "member_sim": self.member_sim,
                       "is_rep": self.is_rep,
                       "is_outlier": self.is_outlier,
                       "thresholds": np.asarray(
                           [self.alpha, self.k_used], np.float32)},
            "warm": {"prev_rank": self.prev_rank,
                     "prev_potential": self.prev_potential,
                     "prev_is_rep": self.prev_is_rep,
                     "has_prev": np.asarray([self.has_prev])},
            "driver": {"scalars": np.asarray(
                [self.advance_count, self.cursor, self.evicted_points,
                 self.shed_capacity, self.row_overflow,
                 self.overflow_events, self.inserted_total], np.int64)},
            "ingest": self.ingest.state_arrays(),
            "window": self.window.state_arrays(),
        }
        self.manager.save(self.advance_count, tree, meta={
            "schema": STREAM_SNAPSHOT_SCHEMA,
            "fingerprint": self.config.fingerprint()})

    def maybe_resume(self) -> bool:
        """Restore the newest valid snapshot, falling back step by step
        past corrupt ones.  Returns True when state was restored."""
        if self.manager is None:
            return False
        steps = self.manager.available_steps()
        if not steps:
            return False
        for step in reversed(steps):
            meta = checkpoint_meta(self.manager.root, step)
            if not meta or meta.get("schema") != STREAM_SNAPSHOT_SCHEMA \
                    or meta.get("fingerprint") != self.config.fingerprint():
                raise ValueError(
                    f"snapshot step {step} was written under a different "
                    "schema/config — refusing to resume into it")
            try:
                flat, _ = self.manager.restore_flat(step)
            except IOError:
                continue             # corrupt leaves: fall back a step
            self._load(flat)
            return True
        return False

    def _load(self, flat: dict):
        self.obj_of_row = flat["store/obj"].astype(np.int64)
        self.xs = flat["store/x"]
        self.ys = flat["store/y"]
        self.ts = flat["store/t"]
        self.valid = flat["store/valid"].astype(bool)
        self._row_of = {int(o): r for r, o in enumerate(self.obj_of_row)
                        if o >= 0}
        self.cube_w = flat["cube/w"]
        self.cube_idx = flat["cube/idx"]
        self.sub_local = flat["seg/sub_local"]
        self.vote = flat["vote/vote"]
        self.bx_min = flat["bbox/xmin"]
        self.bx_max = flat["bbox/xmax"]
        self.by_min = flat["bbox/ymin"]
        self.by_max = flat["bbox/ymax"]
        self.bt_min = flat["bbox/tmin"]
        self.bt_max = flat["bbox/tmax"]
        self.b_nonempty = flat["bbox/nonempty"].astype(bool)
        self.standing_ids = flat["standing/ids"]
        self.standing_sims = flat["standing/sims"]
        self.t_start = flat["table/t_start"]
        self.t_end = flat["table/t_end"]
        self.voting = flat["table/voting"]
        self.card = flat["table/card"]
        self.tvalid = flat["table/valid"].astype(bool)
        self.member_of = flat["labels/member_of"]
        self.member_sim = flat["labels/member_sim"]
        self.is_rep = flat["labels/is_rep"].astype(bool)
        self.is_outlier = flat["labels/is_outlier"].astype(bool)
        self.alpha = float(flat["labels/thresholds"][0])
        self.k_used = float(flat["labels/thresholds"][1])
        self.prev_rank = flat["warm/prev_rank"]
        self.prev_potential = flat["warm/prev_potential"].astype(bool)
        self.prev_is_rep = flat["warm/prev_is_rep"].astype(bool)
        self.has_prev = bool(flat["warm/has_prev"][0])
        (self.advance_count, self.cursor, self.evicted_points,
         self.shed_capacity, self.row_overflow, self.overflow_events,
         self.inserted_total) = (int(v) for v in flat["driver/scalars"])
        self.ingest.load_state_arrays(
            {k.split("/", 1)[1]: v for k, v in flat.items()
             if k.startswith("ingest/")})
        self.window.load_state_arrays(
            {k.split("/", 1)[1]: v for k, v in flat.items()
             if k.startswith("window/")})

    # -------------------------------------------------------------- queries
    def query(self, obj: int) -> dict:
        """Current subtrajectories + cluster assignment of one object."""
        c = self.config
        out = {"obj": int(obj), "in_window": False,
               "watermark": float(self.window.watermark), "subtrajs": []}
        r = self._row_of.get(int(obj))
        if r is None:
            return out
        out["in_window"] = True
        for s in range(c.max_subs):
            slot = r * c.max_subs + s
            if not self.tvalid[slot]:
                continue
            entry = {"sub": s, "slot": int(slot),
                     "t_start": float(self.t_start[slot]),
                     "t_end": float(self.t_end[slot]),
                     "is_rep": bool(self.is_rep[slot]),
                     "is_outlier": bool(self.is_outlier[slot]),
                     "cluster": None}
            rep = int(self.member_of[slot])
            if rep >= 0:
                entry["cluster"] = {
                    "rep_obj": int(self.obj_of_row[rep // c.max_subs]),
                    "rep_sub": rep % c.max_subs, "rep_slot": rep,
                    "sim": float(self.member_sim[slot])}
            out["subtrajs"].append(entry)
        return out

    def stats(self) -> dict:
        return {
            "advances": self.advance_count,
            "cursor": self.cursor,
            "watermark": float(self.window.watermark),
            "objects": len(self._row_of),
            "points": int(np.sum(self.valid)),
            "submitted": self.ingest.submitted,
            "admitted": self.ingest.admitted,
            "quarantined": dict(self.ingest.counters),
            "repaired_order": self.ingest.repaired_order,
            "late_dropped": self.window.late_dropped,
            "shed_queue": self.window.shed,
            "shed_capacity": self.shed_capacity,
            "row_overflow": self.row_overflow,
            "inserted": self.inserted_total,
            "evicted": self.evicted_points,
            "reps": int(np.sum(self.is_rep)),
            "outliers": int(np.sum(self.is_outlier)),
            "overflow_events": self.overflow_events,
            "last_rounds": self.last_rounds,
            "warm_prefix": self.warm_prefix,
        }

    def accounting(self) -> dict:
        """The no-silent-drops invariant: every submitted record is
        admitted into the store, quarantined, dropped late, shed, or
        still staged — and the books must balance exactly."""
        lhs = self.ingest.submitted
        rhs = (self.ingest.quarantined_total() + self.window.late_dropped
               + self.window.shed + self.shed_capacity
               + self.inserted_total + self.window.queued())
        return {"submitted": int(lhs),
                "quarantined": int(self.ingest.quarantined_total()),
                "late_dropped": int(self.window.late_dropped),
                "shed_queue": int(self.window.shed),
                "shed_capacity": int(self.shed_capacity),
                "inserted": int(self.inserted_total),
                "queued": int(self.window.queued()),
                "balanced": bool(lhs == rhs)}

    def window_batch(self) -> TrajectoryBatch:
        """The active window as a batch — the oracle cross-check feeds
        this straight into ``run_dsc``."""
        return TrajectoryBatch(
            x=jnp.asarray(self.xs), y=jnp.asarray(self.ys),
            t=jnp.asarray(self.ts), valid=jnp.asarray(self.valid),
            traj_id=jnp.asarray(self.obj_of_row.astype(np.int32)))
