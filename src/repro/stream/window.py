"""Bounded-lateness watermark + bounded staging queue (DESIGN.md §13.3).

Event-time semantics follow the standard streaming contract:

* the **watermark** ``W = max admitted event time - allowed_lateness`` is
  monotone (it never moves backwards);
* a record with ``t >= W`` is *on time or tolerably late*: it is handed
  to the driver, which marks its object's row dirty and re-joins exactly
  the affected rows (the scoped re-join);
* a record with ``t < W`` is **beyond the allowed lateness**: it is
  counted in ``late_dropped`` and dropped — never silently folded into
  standing state;
* the active window retains event times in ``[W - horizon, +inf)``;
  points older than that are evicted by the driver at each advance.

The staging queue between ``stage()`` and ``drain()`` is bounded
(``queue_cap`` records).  On overflow the configured backpressure policy
applies: ``"shed_oldest"`` drops (and counts) the oldest staged records
to make room; ``"block"`` raises :class:`BackpressureOverflow` — a real
deployment would block the producer, a single-process service must
surface the pressure loudly instead of OOMing.  A watermark that fails
to advance for ``stall_advances`` consecutive drains while records keep
arriving raises :class:`WatermarkStall`.  Both map to launcher exit
code 8.
"""
from __future__ import annotations

import numpy as np

from repro.stream.ingest import Records, concat_records, take_records


class BackpressureOverflow(RuntimeError):
    """Staging queue exceeded ``queue_cap`` under the ``block`` policy
    (exit code 8)."""


class WatermarkStall(RuntimeError):
    """Watermark failed to advance for ``stall_advances`` consecutive
    drains while records kept arriving (exit code 8)."""


class WindowManager:
    """Watermark bookkeeping + the bounded staging queue."""

    def __init__(self, allowed_lateness: float, horizon: float,
                 queue_cap: int = 4096, policy: str = "shed_oldest",
                 stall_advances: int = 0):
        if policy not in ("shed_oldest", "block"):
            raise ValueError(f"policy={policy!r}: expected 'shed_oldest' "
                             "or 'block'")
        if allowed_lateness < 0 or horizon <= 0:
            raise ValueError("allowed_lateness must be >= 0 and "
                             "horizon > 0")
        if queue_cap < 1:
            raise ValueError("queue_cap must be >= 1")
        self.allowed_lateness = float(allowed_lateness)
        self.horizon = float(horizon)
        self.queue_cap = int(queue_cap)
        self.policy = policy
        self.stall_advances = int(stall_advances)
        self.watermark = -np.inf      # no record admitted yet
        self.late_dropped = 0
        self.shed = 0
        self.staged_total = 0
        self._queue: list[Records] = []
        self._queued_n = 0
        self._stalled = 0             # consecutive non-advancing drains

    # ------------------------------------------------------------------ api
    def queued(self) -> int:
        return self._queued_n

    def stage(self, recs: Records) -> int:
        """Enqueue a validated submission; returns records shed to make
        room (0 unless the shed_oldest policy fired)."""
        if recs.n == 0:
            return 0
        if recs.n > self.queue_cap and self.policy == "block":
            raise BackpressureOverflow(
                f"submission of {recs.n} records exceeds queue_cap="
                f"{self.queue_cap}")
        self.staged_total += recs.n
        self._queue.append(recs)
        self._queued_n += recs.n
        shed_now = 0
        while self._queued_n > self.queue_cap:
            if self.policy == "block":
                # undo the enqueue so the caller can retry after draining
                self._queue.pop()
                self._queued_n -= recs.n
                self.staged_total -= recs.n
                raise BackpressureOverflow(
                    f"staging queue full ({self._queued_n} + {recs.n} > "
                    f"queue_cap={self.queue_cap})")
            oldest = self._queue[0]
            need = self._queued_n - self.queue_cap
            drop = min(need, oldest.n)
            if drop == oldest.n:
                self._queue.pop(0)
            else:
                self._queue[0] = take_records(
                    oldest, np.arange(drop, oldest.n))
            self._queued_n -= drop
            self.shed += drop
            shed_now += drop
        return shed_now

    def drain(self) -> tuple[Records, int]:
        """Pop every staged record; split into (admitted, late_dropped).

        Admitted records advance the watermark; records already beyond
        it are counted and dropped.  The stall counter ticks when
        records arrived but the watermark did not move.
        """
        recs = concat_records(self._queue)
        self._queue = []
        self._queued_n = 0
        if recs.n == 0:
            return recs, 0
        w0 = self.watermark
        t = recs.t.astype(np.float64)
        # watermark first: lateness is judged against the watermark the
        # *batch* establishes, matching an upstream shuffle-free stream
        # where the max-t record may arrive first within the drain
        new_w = max(self.watermark,
                    float(np.max(t)) - self.allowed_lateness)
        late = t < new_w
        n_late = int(np.sum(late))
        self.late_dropped += n_late
        self.watermark = new_w
        if self.watermark <= w0:
            self._stalled += 1
            if self.stall_advances and self._stalled >= self.stall_advances:
                raise WatermarkStall(
                    f"watermark stalled at {self.watermark} for "
                    f"{self._stalled} consecutive drains with records "
                    "still arriving")
        else:
            self._stalled = 0
        return take_records(recs, np.nonzero(~late)[0]), n_late

    def evict_before(self) -> float:
        """Lower edge of the active window (event time)."""
        return self.watermark - self.horizon

    # --------------------------------------------------------- serialization
    def state_arrays(self) -> dict:
        """Snapshot state.  The staging queue is intentionally *not*
        serialized: the driver snapshots at advance boundaries, where the
        queue has just been drained, and the record-source cursor replays
        anything submitted after the snapshot (DESIGN.md §13.5)."""
        return {
            "scalars_f": np.asarray([self.watermark], np.float64),
            "scalars_i": np.asarray(
                [self.late_dropped, self.shed, self.staged_total,
                 self._stalled], np.int64),
        }

    def load_state_arrays(self, st: dict):
        self.watermark = float(st["scalars_f"][0])
        self.late_dropped, self.shed, self.staged_total, self._stalled = (
            int(v) for v in st["scalars_i"])
