"""Streaming ingest/validation: admit clean point records, quarantine dirt.

Live AIS/taxi feeds are full of records the batch pipeline never sees:
NaN positions, duplicated fixes, sensor clocks jumping backwards, GPS
teleports.  The :class:`Ingestor` is the one gate every record passes
before it can touch window state, with three dispositions
(DESIGN.md §13.2):

* ``on_dirty="repair"`` — fix what is mechanically fixable (out-of-order
  timestamps inside a submission are stable-sorted back into order and
  counted as ``repaired_order``), quarantine the rest;
* ``on_dirty="drop"``   — quarantine every dirty record (non-monotone
  timestamps included);
* ``on_dirty="fail"``   — raise :class:`PoisonRecord` on the first dirty
  record (the launcher maps this to exit code 7).

A quarantined record is never silently discarded: every rejection
increments a per-reason counter and lands in a *bounded* quarantine log
(newest-kept ring), so the accounting invariant

    submitted == admitted + quarantined (+ the window layer's
                 late_dropped / shed)

holds exactly — the chaos suite asserts it under fault injection.

Everything here is plain numpy and fully deterministic; the whole
ingest state (counters, per-object last fix, the log ring) serializes to
flat arrays so it rides inside the driver's snapshot.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import numpy as np

# quarantine reasons, in counter order (the snapshot serializes counters
# as one int64 vector aligned with this tuple — order is part of the
# snapshot schema, append only)
QUARANTINE_REASONS = ("nonfinite", "duplicate", "non_monotone", "teleport")

# log ring reason codes == index into QUARANTINE_REASONS
_REASON_CODE = {r: i for i, r in enumerate(QUARANTINE_REASONS)}


class PoisonRecord(RuntimeError):
    """A dirty record arrived under ``on_dirty="fail"`` (exit code 7)."""


class Records(NamedTuple):
    """One submission batch of raw point records (parallel arrays)."""

    obj: np.ndarray   # [N] int32 object ids
    x: np.ndarray     # [N] float32
    y: np.ndarray     # [N] float32
    t: np.ndarray     # [N] float32 event time (seconds)

    @property
    def n(self) -> int:
        return int(self.obj.shape[0])

    @staticmethod
    def build(obj, x, y, t) -> "Records":
        return Records(np.asarray(obj, np.int32),
                       np.asarray(x, np.float32),
                       np.asarray(y, np.float32),
                       np.asarray(t, np.float32))


def take_records(recs: Records, idx) -> Records:
    return Records(recs.obj[idx], recs.x[idx], recs.y[idx], recs.t[idx])


def concat_records(parts: list[Records]) -> Records:
    if not parts:
        return Records.build([], [], [], [])
    return Records(*(np.concatenate([getattr(p, f) for p in parts])
                     for f in Records._fields))


class Ingestor:
    """Stateful validation gate in front of the window store.

    ``known_t_fn(obj) -> np.ndarray`` (optional) exposes the window
    store's admitted event times for an object, so duplicates against
    *already-admitted* fixes are caught, not just duplicates within one
    submission.  ``max_speed`` (units/s) arms the GPS-teleport check
    against the object's last admitted fix; ``None`` disables it.
    """

    def __init__(self, on_dirty: str = "repair",
                 max_speed: Optional[float] = None,
                 quarantine_cap: int = 256,
                 known_t_fn: Optional[Callable] = None):
        if on_dirty not in ("repair", "drop", "fail"):
            raise ValueError(f"on_dirty={on_dirty!r}: expected "
                             "'repair', 'drop', or 'fail'")
        if quarantine_cap < 1:
            raise ValueError("quarantine_cap must be >= 1")
        self.on_dirty = on_dirty
        self.max_speed = max_speed
        self.quarantine_cap = int(quarantine_cap)
        self.known_t_fn = known_t_fn
        self.counters = {r: 0 for r in QUARANTINE_REASONS}
        self.repaired_order = 0
        self.submitted = 0
        self.admitted = 0
        # per-object last admitted fix (teleport baseline)
        self._last: dict[int, tuple[float, float, float]] = {}
        # bounded quarantine log: newest-kept ring of
        # (seq, obj, t, reason_code) rows
        self._log: list[tuple[int, int, float, int]] = []
        self._seq = 0
        # per-object event times admitted from the submission being
        # processed (duplicate / non-monotone checks within one batch)
        self._batch_seen: dict[int, set] = {}

    # ------------------------------------------------------------- internals
    def _quarantine(self, obj: int, t: float, reason: str):
        if self.on_dirty == "fail":
            raise PoisonRecord(
                f"poison record obj={obj} t={t}: {reason} "
                f"(on_dirty='fail')")
        self.counters[reason] += 1
        self._log.append((self._seq, int(obj), float(t),
                          _REASON_CODE[reason]))
        if len(self._log) > self.quarantine_cap:
            del self._log[0]

    def _is_teleport(self, obj: int, x: float, y: float, t: float) -> bool:
        if self.max_speed is None:
            return False
        last = self._last.get(int(obj))
        if last is None:
            return False
        lx, ly, lt = last
        dt = abs(t - lt)
        dist = float(np.hypot(x - lx, y - ly))
        # a zero-dt different-position fix is an infinite-speed jump
        return dist > self.max_speed * max(dt, 1e-9)

    # ------------------------------------------------------------------ api
    def process(self, recs: Records) -> Records:
        """Validate one submission; returns the admitted records (in
        admission order) and books everything else into quarantine."""
        n = recs.n
        self.submitted += n
        if n == 0:
            return recs
        obj = recs.obj.astype(np.int64)
        x = recs.x.astype(np.float64)
        y = recs.y.astype(np.float64)
        t = recs.t.astype(np.float64)

        order = np.arange(n)
        if self.on_dirty == "repair":
            # repair in-batch timestamp swaps: stable sort by (obj, t)
            srt = np.lexsort((t, obj))
            if not np.array_equal(srt, order):
                # count records whose relative position moved
                self.repaired_order += int(np.sum(srt != order))
            order = srt

        keep: list[int] = []
        for i in order:
            oi, xi, yi, ti = int(obj[i]), x[i], y[i], t[i]
            self._seq += 1
            if not (np.isfinite(xi) and np.isfinite(yi)
                    and np.isfinite(ti)):
                self._quarantine(oi, ti if np.isfinite(ti) else 0.0,
                                 "nonfinite")
                continue
            seen = self._batch_seen.get(oi)
            # duplicate: same (obj, t) as an already-admitted fix — in
            # this submission or in the window store
            dup = False
            if seen is not None and ti in seen:
                dup = True
            elif self.known_t_fn is not None:
                known = np.asarray(self.known_t_fn(oi), np.float64)
                dup = bool(known.size) and bool(
                    np.any(known == np.float64(np.float32(ti))))
            last = self._last.get(oi)
            if not dup and last is not None and ti == last[2]:
                dup = True
            if dup:
                self._quarantine(oi, ti, "duplicate")
                continue
            # non-monotone: the fix steps backwards past a fix already
            # admitted from this same submission (a late fix relative to
            # the *store* is the watermark's business, not quarantine's)
            if seen is not None and seen and ti < max(seen):
                self._quarantine(oi, ti, "non_monotone")
                continue
            if self._is_teleport(oi, xi, yi, ti):
                self._quarantine(oi, ti, "teleport")
                continue
            keep.append(int(i))
            if seen is None:
                self._batch_seen[oi] = {ti}
            else:
                seen.add(ti)
            if last is None or ti >= last[2]:
                self._last[oi] = (xi, yi, ti)
        out = take_records(recs, np.asarray(keep, np.int64))
        self.admitted += out.n
        self._batch_seen = {}
        return out

    def quarantined_total(self) -> int:
        return sum(self.counters.values())

    def quarantine_log(self) -> list[dict]:
        """Newest-kept log entries as dicts (bounded by quarantine_cap)."""
        return [{"seq": s, "obj": o, "t": t,
                 "reason": QUARANTINE_REASONS[c]}
                for s, o, t, c in self._log]

    # --------------------------------------------------------- serialization
    def state_arrays(self) -> dict:
        """Flat numpy state (rides inside the driver snapshot)."""
        objs = sorted(self._last)
        log = self._log or []
        return {
            "counters": np.asarray(
                [self.counters[r] for r in QUARANTINE_REASONS], np.int64),
            "scalars": np.asarray(
                [self.submitted, self.admitted, self.repaired_order,
                 self._seq], np.int64),
            "last_obj": np.asarray(objs, np.int64),
            "last_fix": np.asarray(
                [self._last[o] for o in objs], np.float64).reshape(-1, 3),
            "log_seq": np.asarray([e[0] for e in log], np.int64),
            "log_obj": np.asarray([e[1] for e in log], np.int64),
            "log_t": np.asarray([e[2] for e in log], np.float64),
            "log_code": np.asarray([e[3] for e in log], np.int64),
        }

    def load_state_arrays(self, st: dict):
        self.counters = {r: int(c) for r, c in
                         zip(QUARANTINE_REASONS, st["counters"])}
        self.submitted, self.admitted, self.repaired_order, self._seq = (
            int(v) for v in st["scalars"])
        self._last = {int(o): tuple(float(v) for v in fix)
                      for o, fix in zip(st["last_obj"],
                                        st["last_fix"].reshape(-1, 3))}
        self._log = [(int(s), int(o), float(t), int(c))
                     for s, o, t, c in zip(st["log_seq"], st["log_obj"],
                                           st["log_t"], st["log_code"])]
