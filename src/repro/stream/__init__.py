"""Streaming ingestion + windowed incremental DSC (DESIGN.md §13).

``repro.stream.ingest`` admits raw point records and quarantines dirty
ones (per-reason counters, bounded log, ``repair|drop|fail`` policy);
``repro.stream.window`` owns the bounded-lateness watermark, the bounded
staging queue and the backpressure policy; ``repro.stream.driver`` is the
long-running incremental DSC service: per window advance it does delta
bbox-index updates, delta joins of only the dirty rows, standing
``[S, K+1]`` neighbor-list merges, warm-started round-parallel
clustering, and periodic full-state snapshots through the checkpoint
store so a killed service resumes bit-identically.
"""
from repro.stream.ingest import (QUARANTINE_REASONS, Ingestor, PoisonRecord,
                                 Records, concat_records, take_records)
from repro.stream.window import (BackpressureOverflow, WatermarkStall,
                                 WindowManager)
from repro.stream.driver import (STREAM_SNAPSHOT_SCHEMA, StreamConfig,
                                 StreamDriver)

__all__ = [
    "Records", "concat_records", "take_records", "Ingestor",
    "PoisonRecord", "QUARANTINE_REASONS", "WindowManager",
    "BackpressureOverflow", "WatermarkStall", "StreamConfig",
    "StreamDriver", "STREAM_SNAPSHOT_SCHEMA",
]
