"""zamba2-1.2b [hybrid] — Mamba-2 backbone + shared attention block
(arXiv:2411.15242; hf).  ssm_state=64; one shared attn+mlp block applied
every 6 mamba layers (weight-shared, zamba2-style)."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32_000,
    hidden_act="gelu",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=64),
    attn_every=6,
    subquadratic=True,
)
