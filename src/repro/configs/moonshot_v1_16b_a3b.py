"""moonshot-v1-16b-a3b [moe] — kimi/moonlight: 64 routed experts top-6,
2 shared, first layer dense (hf:moonshotai/Moonlight-16B-A3B)."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=11264, vocab_size=163_840,
    rope_theta=50_000.0, hidden_act="silu",
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  first_k_dense=1),
)
