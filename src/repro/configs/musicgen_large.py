"""musicgen-large [audio] — decoder-only over EnCodec tokens
(arXiv:2306.05284; hf).  The EnCodec frontend is a STUB: input tokens are
4 parallel codebooks [B, 4, L], embeddings summed, 4 output heads."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    rope_theta=10_000.0, hidden_act="gelu",
    frontend="encodec_stub", n_codebooks=4,
)
