"""gemma2-2b [dense] — local+global alternating attention, logit softcaps,
pre+post norms (arXiv:2408.00118; hf)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    head_dim=256, d_ff=9216, vocab_size=256_000,
    rope_theta=10_000.0, hidden_act="gelu", tie_embeddings=True,
    attn_softcap=50.0, final_softcap=30.0,
    sliding_window=4096, local_global=True, gemma_norms=True,
    embed_scale=True, query_scale=256 ** -0.5,
    # half the layers are 4k sliding-window; global-layer KV is
    # sequence-shardable -> long_500k decode is admissible
    subquadratic=True,
)
