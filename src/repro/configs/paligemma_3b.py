"""paligemma-3b [vlm] — SigLIP (stub) + gemma decoder, MQA kv=1, prefix-LM
(arXiv:2407.07726; hf).  The modality frontend is a STUB: input_specs()
provides precomputed patch embeddings [B, 256, 1152]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    head_dim=256, d_ff=16_384, vocab_size=257_216,
    rope_theta=10_000.0, hidden_act="gelu", tie_embeddings=True,
    embed_scale=True,
    frontend="siglip_stub", vision_tokens=256, d_vision=1152,
)
