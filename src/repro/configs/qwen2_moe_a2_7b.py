"""qwen2-moe-a2.7b [moe] — 60 routed top-4 + 4 shared experts
(hf:Qwen/Qwen1.5-MoE-A2.7B)."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=5632, vocab_size=151_936,
    rope_theta=1_000_000.0, hidden_act="silu",
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408, n_shared=4,
                  first_k_dense=0),
)
