"""rwkv6-1.6b [ssm] — Finch, data-dependent decay, attention-free
(arXiv:2404.05892)."""
from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="rwkv",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,  # heads = d/64
    d_ff=7168, vocab_size=65_536,
    hidden_act="silu",
    rwkv=RWKVConfig(head_dim=64, lora_w=64, lora_mix=32, chunk=64),
    subquadratic=True,
)
