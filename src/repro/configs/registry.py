"""Architecture & shape registry: the 40 (arch x shape) dry-run cells.

Shapes (LM-family, per the assignment):
    train_4k     seq 4096,    global_batch 256   (training;   train_step)
    prefill_32k  seq 32768,   global_batch 32    (inference;  prefill)
    decode_32k   seq 32768,   global_batch 128   (decode: 1 new token w/ cache)
    long_500k    seq 524288,  global_batch 1     (long-context decode;
                                                  sub-quadratic archs only)
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, MoEConfig, RWKVConfig, SSMConfig

_ARCH_MODULES = {
    "deepseek-7b": "repro.configs.deepseek_7b",
    "smollm-360m": "repro.configs.smollm_360m",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "yi-6b": "repro.configs.yi_6b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1p6b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "musicgen-large": "repro.configs.musicgen_large",
}
ARCHITECTURES = list(_ARCH_MODULES)

SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32_768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32_768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524_288, "global_batch": 1, "kind": "decode"},
}


def get_arch(name: str) -> ModelConfig:
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k dense KV decode is "
                       "out of spec (skip noted in DESIGN.md §5)")
    return True, ""


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    updates = dict(
        n_layers=2 if not cfg.attn_every else 4,
        d_model=64,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16, d_ff=128, vocab_size=256,
        vision_tokens=8, d_vision=32,
        sliding_window=8 if cfg.sliding_window else None,
    )
    if cfg.moe is not None:
        # high capacity factor -> no token drops, so smoke tests exercise
        # routing/cache correctness deterministically
        updates["moe"] = MoEConfig(
            n_experts=8, top_k=2, d_expert=32,
            n_shared=min(cfg.moe.n_shared, 1),
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            capacity_factor=8.0)
    if cfg.ssm is not None:
        updates["ssm"] = SSMConfig(d_state=8, d_conv=4, expand=2,
                                   head_dim=16, chunk=8)
        updates["attn_every"] = 2
    if cfg.rwkv is not None:
        updates["rwkv"] = RWKVConfig(head_dim=16, lora_w=8, lora_mix=8,
                                     chunk=8)
    return dataclasses.replace(cfg, **updates)


# ------------------------- DSC (the paper's own) configs --------------------

@dataclasses.dataclass(frozen=True)
class DSCRunConfig:
    """A DSC pipeline sizing (dataset capacities + parameters)."""
    name: str
    n_trajs: int          # T (row capacity, all partitions)
    max_points: int       # Mp per partition
    n_partitions_hint: int
    eps_sp: float = 0.1
    eps_t: float = 1.0
    delta_t: float = 0.0
    w: int = 10
    tau: float = 0.4
    alpha_sigma: float = 0.0
    k_sigma: float = 0.0
    max_subtrajs: int = 8
    segmentation: str = "tsa1"


DSC_CONFIGS = {
    # synthetic ground-truth scenario (Sec. 6.2)
    "dsc_synth": DSCRunConfig(name="dsc_synth", n_trajs=256, max_points=64,
                              n_partitions_hint=16),
    # Brest AIS-scale: 3.65e5 trajs, 17e6 points -> per-pod slice
    "dsc_brest": DSCRunConfig(name="dsc_brest", n_trajs=4096, max_points=128,
                              n_partitions_hint=32, w=20),
    # SIS urban-scale: 2.2e7 trajs, 7.2e8 points -> per-pod slice
    "dsc_sis": DSCRunConfig(name="dsc_sis", n_trajs=8192, max_points=128,
                            n_partitions_hint=32, w=20),
}


def get_dsc_config(name: str) -> DSCRunConfig:
    return DSC_CONFIGS[name]
