from repro.configs.registry import (ARCHITECTURES, DSC_CONFIGS, get_arch,
                                    get_dsc_config, reduced_config)

__all__ = ["ARCHITECTURES", "DSC_CONFIGS", "get_arch", "get_dsc_config",
           "reduced_config"]
