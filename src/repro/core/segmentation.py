"""Neighborhood-aware trajectory segmentation — TSA1 & TSA2 (Algorithms 2, 3).

Both algorithms slide two adjacent windows ``W1 = [n-w, n-1]`` and
``W2 = [n, n+w-1]`` over a per-point signal and cut where the window
difference ``d[n]`` exceeds ``tau`` *and* is a local maximum.

Interpretation note (DESIGN.md §2.3): the paper's pseudocode line
``d[n] >= d_max`` with ``d_max`` the global maximum would allow a single cut
per trajectory, contradicting the text ("is locally maximized").  We implement
the text: a cut at ``n`` requires ``d[n] > tau`` and ``d[n] == max(d[n-w+1 ..
n+w-1])`` (strict left tie-break), the standard local-maxima picking of the
signal-segmentation literature the paper cites [16, 17].

TSA1 consumes the normalized voting vector (Eq. 5); TSA2 consumes per-point
neighbor *sets* (bit-packed) and uses windowed-union Jaccard dissimilarity.

All window math runs on the shared monoid sliding-window engine
(``repro.core.windows``, DESIGN.md §7): TSA1's window means are two reads
of one prefix sum, the local-max test is the two-pass block cummax, and
TSA2's set unions are the *same* block-scan trick applied to bit-packed
uint32 words — a dense packed-word sweep with no 32x bit-plane expansion
and no serial fold over the word axis.  The retained bit-plane
formulations (``_windowed_union``, ``_window_overlap_counts_bitplane``)
are regression oracles only.  ``tsa2(..., use_kernel=True)`` computes the
Jaccard signal through the fused Pallas kernel
(``repro.kernels.jaccard``) instead of the jnp engine — bit-identical
output either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import SubtrajSegmentation
from repro.core.windows import sliding_reduce, window_pair


def _window_means(sig: jnp.ndarray, valid: jnp.ndarray, w: int):
    """Means of W1=[n-w, n-1] and W2=[n, n+w-1] at every n; [T, M] each."""
    x = jnp.where(valid, sig, 0.0)
    cnt = valid.astype(jnp.float32)
    s1, s2 = window_pair(x, w, "sum")
    c1, c2 = window_pair(cnt, w, "sum")
    return s1 / jnp.maximum(c1, 1.0), s2 / jnp.maximum(c2, 1.0)


def _local_max_cuts(d: jnp.ndarray, valid: jnp.ndarray, w: int, tau,
                    count: jnp.ndarray) -> jnp.ndarray:
    """Cut where d[n] > tau and d[n] is the max of its +-(w-1) window.

    The windowed maximum over [n-w+1, n+w-1] splits into the left-neighbor
    max (strict-left tie break: ``d[n]`` must beat it strictly) and the
    right-neighbor max (``>=`` suffices); both are O(M) prefix/suffix
    block-cummax windows from the shared engine instead of stacking 2w-1
    shifted copies (equality with the stacked formulation is pinned by
    ``tests/test_segmentation.py``)."""
    T, M = d.shape
    n = jnp.arange(M)
    # admissible positions: w+1 .. N-w-1 (1-based paper indexing -> w .. N-w-1)
    admissible = (n[None, :] >= w) & (n[None, :] <= count[:, None] - w - 1)
    d = jnp.where(valid & admissible, d, -jnp.inf)

    left = sliding_reduce(d, -(w - 1), -1, "max")
    right = sliding_reduce(d, 1, w - 1, "max")
    is_max = (d > left) & (d >= right)
    return is_max & (d > tau) & admissible & valid


def _finalize(cut: jnp.ndarray, valid: jnp.ndarray, score: jnp.ndarray,
              max_subs: int) -> SubtrajSegmentation:
    T, M = cut.shape
    first = valid & (jnp.cumsum(valid, axis=1) == 1)
    cut = (cut | first) & valid
    sub_local = jnp.clip(jnp.cumsum(cut, axis=1) - 1, 0, max_subs - 1)
    sub_local = jnp.where(valid, sub_local, -1).astype(jnp.int32)
    num = jnp.max(jnp.where(valid, sub_local, -1), axis=1) + 1
    return SubtrajSegmentation(
        cut=cut, sub_local=sub_local, num_subs=num.astype(jnp.int32),
        score=score)


def tsa1(norm_vote: jnp.ndarray, valid: jnp.ndarray, w: int, tau,
         max_subs: int = 8) -> SubtrajSegmentation:
    """Algorithm 2: density-change segmentation over the voting signal."""
    count = jnp.sum(valid, axis=1)
    m1, m2 = _window_means(norm_vote, valid, w)
    d = jnp.abs(m1 - m2)
    cuts = _local_max_cuts(d, valid, w, tau, count)
    return _finalize(cuts, valid, jnp.where(valid, d, 0.0), max_subs)


def _windowed_union(masks: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray):
    """Bit-plane oracle: OR-reduce packed masks over index window [lo, hi].

    Expands every uint32 word to 32 int32 bit-planes at once
    (``[T, M, W*32]``) and reduces via cumulative counts (OR of 0/1 bits
    == count > 0).  This is the pinned regression oracle for the packed
    windowed-OR production path — never call it from the pipeline.
    """
    T, M, W = masks.shape
    B = W * 32
    bits = ((masks[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & 1)
    bits = bits.astype(jnp.int32).reshape(T, M, B)               # [T, M, B]
    csum = jnp.cumsum(bits, axis=1)

    def take(c, idx):
        idxc = jnp.clip(idx, 0, M - 1)
        return jnp.take_along_axis(
            c, jnp.broadcast_to(idxc[None, :, None], (T, M, B)), axis=1)

    hi_v = jnp.where((hi >= 0)[None, :, None], take(csum, hi), 0)
    lo_v = jnp.where((lo > 0)[None, :, None], take(csum, lo - 1), 0)
    return (hi_v - lo_v) > 0                                     # [T, M, B]


def _window_overlap_counts_bitplane(masks: jnp.ndarray, w: int):
    """Bit-plane chunked W1/W2 intersection and union cardinalities.

    The pre-packed-engine production path, retained as a regression
    oracle and the bench comparator: a ``fori_loop`` folds one 32-bit
    plane chunk at a time, so peak extra memory is ``[T, M, 32]`` int32
    per word-step — 32x the packed masks — and the W iterations form a
    serial dependence chain.  Output equality with both the all-at-once
    expansion and the packed engine is pinned by
    ``tests/test_segmentation.py``.
    """
    T, M, W = masks.shape
    n = jnp.arange(M)

    def body(wi, carry):
        inter, union = carry
        word = jax.lax.dynamic_slice_in_dim(masks, wi, 1, axis=2)
        l1 = _windowed_union(word, n - w, n - 1)              # [T, M, 32]
        l2 = _windowed_union(word, n, n + w - 1)
        return (inter + jnp.sum(l1 & l2, axis=-1, dtype=jnp.int32),
                union + jnp.sum(l1 | l2, axis=-1, dtype=jnp.int32))

    zeros = jnp.zeros((T, M), jnp.int32)
    return jax.lax.fori_loop(0, W, body, (zeros, zeros))


def _window_overlap_counts(masks: jnp.ndarray, w: int):
    """Per-position W1/W2 set-union intersection and union cardinalities.

    Packed-word production path: bitwise OR is associative and idempotent,
    so the windowed set-union is the engine's two-pass block OR-scan
    applied directly to the ``[T, M, W]`` uint32 words, and the Jaccard
    numerator/denominator are popcount sums over the W word axis.  No
    bit-plane expansion, no serial fold over W: every intermediate is the
    size of the packed masks themselves (32x fewer elements than one
    bit-plane chunk, 32·W x fewer than the full expansion).
    """
    l1, l2 = window_pair(masks, w, "or")
    pc = jax.lax.population_count
    inter = jnp.sum(pc(l1 & l2), axis=-1, dtype=jnp.int32)
    union = jnp.sum(pc(l1 | l2), axis=-1, dtype=jnp.int32)
    return inter, union


def tsa2_signal(packed_masks: jnp.ndarray, w: int, *,
                impl: str = "packed") -> jnp.ndarray:
    """TSA2's windowed-Jaccard dissimilarity ``d[n]`` from packed masks.

    ``impl="packed"`` is the production packed-word engine;
    ``impl="bitplane"`` the retained 32x-expanded chunked oracle.  Both
    produce bit-identical ``d`` (same integer counts, same float ops) —
    the bench gates on exactly that plus the structural memory win.
    """
    if impl == "packed":
        inter, union = _window_overlap_counts(packed_masks, w)
    elif impl == "bitplane":
        inter, union = _window_overlap_counts_bitplane(packed_masks, w)
    else:
        raise ValueError(f"unknown tsa2 signal impl {impl!r}")
    inter = inter.astype(jnp.float32)
    union = union.astype(jnp.float32)
    return jnp.where(union > 0, 1.0 - inter / jnp.maximum(union, 1.0), 0.0)


def tsa2(packed_masks: jnp.ndarray, valid: jnp.ndarray, w: int, tau,
         max_subs: int = 8, *, use_kernel: bool = False) -> SubtrajSegmentation:
    """Algorithm 3: composition-change segmentation (windowed Jaccard).

    Masks at invalid positions are zeroed before the windowed union (the
    pipeline's packed masks are already zero there; direct callers may
    pass arbitrary words), so the jnp engine and the Pallas kernel
    (``use_kernel=True``) are bit-identical everywhere, score included.
    """
    count = jnp.sum(valid, axis=1)
    packed_masks = jnp.where(valid[..., None], packed_masks, jnp.uint32(0))
    if use_kernel:
        from repro.kernels.jaccard.ops import window_jaccard
        d = window_jaccard(packed_masks, valid, w=w)
    else:
        d = tsa2_signal(packed_masks, w)
    cuts = _local_max_cuts(d, valid, w, tau, count)
    return _finalize(cuts, valid, jnp.where(valid, d, 0.0), max_subs)


def segment(params_segmentation: str, *, norm_vote=None, packed_masks=None,
            valid=None, w: int = 10, tau=0.4, max_subs: int = 8,
            use_kernel: bool = False) -> SubtrajSegmentation:
    if params_segmentation == "tsa1":
        return tsa1(norm_vote, valid, w, tau, max_subs)
    if params_segmentation == "tsa2":
        return tsa2(packed_masks, valid, w, tau, max_subs,
                    use_kernel=use_kernel)
    raise ValueError(f"unknown segmentation {params_segmentation!r}")


segment_jit = jax.jit(segment, static_argnums=(0,),
                      static_argnames=("w", "max_subs", "use_kernel"))
