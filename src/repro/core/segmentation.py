"""Neighborhood-aware trajectory segmentation — TSA1 & TSA2 (Algorithms 2, 3).

Both algorithms slide two adjacent windows ``W1 = [n-w, n-1]`` and
``W2 = [n, n+w-1]`` over a per-point signal and cut where the window
difference ``d[n]`` exceeds ``tau`` *and* is a local maximum.

Interpretation note (DESIGN.md §2.3): the paper's pseudocode line
``d[n] >= d_max`` with ``d_max`` the global maximum would allow a single cut
per trajectory, contradicting the text ("is locally maximized").  We implement
the text: a cut at ``n`` requires ``d[n] > tau`` and ``d[n] == max(d[n-w+1 ..
n+w-1])`` (strict left tie-break), the standard local-maxima picking of the
signal-segmentation literature the paper cites [16, 17].

TSA1 consumes the normalized voting vector (Eq. 5); TSA2 consumes per-point
neighbor *sets* (bit-packed) and uses windowed-union Jaccard dissimilarity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import SubtrajSegmentation


def _window_means(sig: jnp.ndarray, valid: jnp.ndarray, w: int):
    """Means of W1=[n-w, n-1] and W2=[n, n+w-1] at every n; [T, M] each."""
    x = jnp.where(valid, sig, 0.0)
    csum = jnp.cumsum(x, axis=1)
    cnt = jnp.cumsum(valid.astype(jnp.float32), axis=1)

    def wsum(c, lo, hi):  # sum over [lo, hi] inclusive, per position
        M = c.shape[1]
        hi_v = jnp.where(
            (hi >= 0)[None, :],
            jnp.take_along_axis(
                c, jnp.clip(hi, 0, M - 1)[None, :].repeat(c.shape[0], 0),
                axis=1),
            0.0)
        lo_v = jnp.where(
            (lo > 0)[None, :],
            jnp.take_along_axis(
                c, jnp.clip(lo - 1, 0, M - 1)[None, :].repeat(c.shape[0], 0),
                axis=1),
            0.0)
        return hi_v - lo_v

    M = sig.shape[1]
    n = jnp.arange(M)
    s1 = wsum(csum, n - w, n - 1)
    c1 = wsum(cnt, n - w, n - 1)
    s2 = wsum(csum, n, n + w - 1)
    c2 = wsum(cnt, n, n + w - 1)
    m1 = s1 / jnp.maximum(c1, 1.0)
    m2 = s2 / jnp.maximum(c2, 1.0)
    return m1, m2


def _neighbor_max_left(d: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per position ``n``: max of ``d[n-k .. n-1]`` (−inf outside), via the
    two-pass block cummax trick — an O(M) sliding-window max with no
    ``[T, M, k]`` intermediate.  Any window of size ``k`` spans at most two
    ``k``-aligned blocks, so it is the max of one block-suffix cummax and
    one block-prefix cummax."""
    T, M = d.shape
    nb = -(-M // k)
    y = jnp.pad(d, ((0, 0), (0, nb * k - M)), constant_values=-jnp.inf)
    blk = y.reshape(T, nb, k)
    pre = jax.lax.cummax(blk, axis=2).reshape(T, nb * k)
    suf = jax.lax.cummax(blk, axis=2, reverse=True).reshape(T, nb * k)
    n = jnp.arange(M)
    start = jnp.clip(n - k + 1, 0, None)
    incl = jnp.where(n >= k - 1,                       # max of d[n-k+1 .. n]
                     jnp.maximum(suf[:, start], pre[:, :M]), pre[:, :M])
    return jnp.concatenate(
        [jnp.full((T, 1), -jnp.inf, d.dtype), incl[:, :-1]], axis=1)


def _local_max_cuts(d: jnp.ndarray, valid: jnp.ndarray, w: int, tau,
                    count: jnp.ndarray) -> jnp.ndarray:
    """Cut where d[n] > tau and d[n] is the max of its +-(w-1) window.

    The windowed maximum over [n-w+1, n+w-1] splits into the left-neighbor
    max (strict-left tie break: ``d[n]`` must beat it strictly) and the
    right-neighbor max (``>=`` suffices); both come from the O(M)
    prefix/suffix cummax pass instead of stacking 2w-1 shifted copies
    (equality with the stacked formulation is pinned by
    ``tests/test_segmentation.py``)."""
    T, M = d.shape
    n = jnp.arange(M)
    # admissible positions: w+1 .. N-w-1 (1-based paper indexing -> w .. N-w-1)
    admissible = (n[None, :] >= w) & (n[None, :] <= count[:, None] - w - 1)
    d = jnp.where(valid & admissible, d, -jnp.inf)

    pads = w - 1
    if pads > 0:
        left = _neighbor_max_left(d, pads)
        right = jnp.flip(_neighbor_max_left(jnp.flip(d, axis=1), pads),
                         axis=1)
    else:
        left = right = jnp.full_like(d, -jnp.inf)
    is_max = (d > left) & (d >= right)
    return is_max & (d > tau) & admissible & valid


def _finalize(cut: jnp.ndarray, valid: jnp.ndarray, score: jnp.ndarray,
              max_subs: int) -> SubtrajSegmentation:
    T, M = cut.shape
    first = valid & (jnp.cumsum(valid, axis=1) == 1)
    cut = (cut | first) & valid
    sub_local = jnp.clip(jnp.cumsum(cut, axis=1) - 1, 0, max_subs - 1)
    sub_local = jnp.where(valid, sub_local, -1).astype(jnp.int32)
    num = jnp.max(jnp.where(valid, sub_local, -1), axis=1) + 1
    return SubtrajSegmentation(
        cut=cut, sub_local=sub_local, num_subs=num.astype(jnp.int32),
        score=score)


def tsa1(norm_vote: jnp.ndarray, valid: jnp.ndarray, w: int, tau,
         max_subs: int = 8) -> SubtrajSegmentation:
    """Algorithm 2: density-change segmentation over the voting signal."""
    count = jnp.sum(valid, axis=1)
    m1, m2 = _window_means(norm_vote, valid, w)
    d = jnp.abs(m1 - m2)
    cuts = _local_max_cuts(d, valid, w, tau, count)
    return _finalize(cuts, valid, jnp.where(valid, d, 0.0), max_subs)


def _windowed_union(masks: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray):
    """OR-reduce packed masks over index window [lo, hi] per position.

    ``masks``: [T, M, W] uint32. Windowed OR via prefix/suffix block trick
    is implemented in the Pallas kernel; the reference path uses a
    cumulative *count* per bit (OR of 0/1 bits == count > 0), expanding
    every word to 32 bit-planes at once ([T, M, W*32]).  Callers that only
    need aggregate counts should go through ``_window_overlap_counts``,
    which feeds this one word at a time to bound memory; the full
    expansion here doubles as the regression oracle.
    """
    T, M, W = masks.shape
    B = W * 32
    bits = ((masks[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & 1)
    bits = bits.astype(jnp.int32).reshape(T, M, B)               # [T, M, B]
    csum = jnp.cumsum(bits, axis=1)

    def take(c, idx):
        idxc = jnp.clip(idx, 0, M - 1)
        return jnp.take_along_axis(
            c, jnp.broadcast_to(idxc[None, :, None], (T, M, B)), axis=1)

    hi_v = jnp.where((hi >= 0)[None, :, None], take(csum, hi), 0)
    lo_v = jnp.where((lo > 0)[None, :, None], take(csum, lo - 1), 0)
    return (hi_v - lo_v) > 0                                     # [T, M, B]


def _window_overlap_counts(masks: jnp.ndarray, w: int):
    """Per-position W1/W2 set-union intersection and union cardinalities.

    The naive reference expanded all ``W * 32`` bit-planes to an int32
    cumsum at once — a ``[T, M, W*32]`` intermediate that dwarfs the packed
    masks by 128x and made TSA2 un-runnable at benchmark shapes.  The
    Jaccard numerator/denominator are plain sums over bits, so a
    ``fori_loop`` folds one 32-bit plane chunk at a time: peak extra memory
    is ``[T, M, 32]`` int32 and the traced graph holds ONE copy of the
    chunk body regardless of W.  Output equality with the all-at-once
    expansion is pinned by ``tests/test_segmentation.py``.
    """
    T, M, W = masks.shape
    n = jnp.arange(M)

    def body(wi, carry):
        inter, union = carry
        word = jax.lax.dynamic_slice_in_dim(masks, wi, 1, axis=2)
        l1 = _windowed_union(word, n - w, n - 1)              # [T, M, 32]
        l2 = _windowed_union(word, n, n + w - 1)
        return (inter + jnp.sum(l1 & l2, axis=-1, dtype=jnp.int32),
                union + jnp.sum(l1 | l2, axis=-1, dtype=jnp.int32))

    zeros = jnp.zeros((T, M), jnp.int32)
    return jax.lax.fori_loop(0, W, body, (zeros, zeros))


def tsa2(packed_masks: jnp.ndarray, valid: jnp.ndarray, w: int, tau,
         max_subs: int = 8) -> SubtrajSegmentation:
    """Algorithm 3: composition-change segmentation (windowed Jaccard)."""
    count = jnp.sum(valid, axis=1)
    inter, union = _window_overlap_counts(packed_masks, w)
    inter = inter.astype(jnp.float32)
    union = union.astype(jnp.float32)
    d = jnp.where(union > 0, 1.0 - inter / jnp.maximum(union, 1.0), 0.0)
    cuts = _local_max_cuts(d, valid, w, tau, count)
    return _finalize(cuts, valid, jnp.where(valid, d, 0.0), max_subs)


def segment(params_segmentation: str, *, norm_vote=None, packed_masks=None,
            valid=None, w: int = 10, tau=0.4,
            max_subs: int = 8) -> SubtrajSegmentation:
    if params_segmentation == "tsa1":
        return tsa1(norm_vote, valid, w, tau, max_subs)
    if params_segmentation == "tsa2":
        return tsa2(packed_masks, valid, w, tau, max_subs)
    raise ValueError(f"unknown segmentation {params_segmentation!r}")


segment_jit = jax.jit(segment, static_argnums=(0,), static_argnames=("w", "max_subs"))
