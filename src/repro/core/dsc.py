"""Single-host end-to-end DSC pipeline (Algorithm 1, P = 1).

This is the semantic reference: the distributed pipeline
(``repro.core.distributed``) must produce the same clusters on the same data
(tested).  The stages mirror the paper exactly:

    subtrajectory join (Problem 1)  ->  voting  ->  segmentation (Problem 2)
    ->  ST / SP relations  ->  clustering + outliers (Problem 3)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import geometry, segmentation, similarity, voting
from repro.core.clustering import cluster, rmse, sscr
from repro.core.types import (ClusteringResult, DSCParams, JoinResult,
                              SubtrajSegmentation, SubtrajTable,
                              TrajectoryBatch)
from repro.utils.tree import pytree_dataclass


@pytree_dataclass
class DSCOutput:
    join: JoinResult
    vote: jnp.ndarray               # [T, M] point voting
    seg: SubtrajSegmentation
    table: SubtrajTable
    sim: jnp.ndarray                # [S, S]
    result: ClusteringResult
    sscr: jnp.ndarray               # Eq. 3 objective
    rmse: jnp.ndarray               # Sec. 6.2 quality metric


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def run_dsc(batch: TrajectoryBatch, params: DSCParams,
            use_kernel: bool = False) -> DSCOutput:
    """Run the full DSC pipeline on one host / one partition."""
    if use_kernel:
        from repro.kernels.stjoin import ops as stjoin_ops
        join = stjoin_ops.subtrajectory_join(
            batch, batch, params.eps_sp, params.eps_t, params.delta_t)
    else:
        join = geometry.subtrajectory_join(
            batch, batch, params.eps_sp, params.eps_t, params.delta_t)

    vote = voting.point_voting(join)
    nvote = voting.normalized_voting(vote, batch.valid)

    if params.segmentation == "tsa1":
        seg = segmentation.tsa1(nvote, batch.valid, params.w, params.tau,
                                params.max_subtrajs_per_traj)
    else:
        masks = voting.neighbor_mask_packed(join)
        seg = segmentation.tsa2(masks, batch.valid, params.w, params.tau,
                                params.max_subtrajs_per_traj)

    table = similarity.build_subtraj_table(
        batch, seg, vote, params.max_subtrajs_per_traj)
    sim = similarity.similarity_matrix(
        join, seg, seg.sub_local, table, params.max_subtrajs_per_traj)

    result = cluster(sim, table, params)
    return DSCOutput(join=join, vote=vote, seg=seg, table=table, sim=sim,
                     result=result, sscr=sscr(result, sim),
                     rmse=rmse(result, sim, params.eps_sp))


def cluster_summary(out: DSCOutput) -> dict:
    """Host-side summary: cluster -> member subtraj slots; outliers list."""
    import numpy as np
    member_of = np.asarray(out.result.member_of)
    is_rep = np.asarray(out.result.is_rep)
    is_out = np.asarray(out.result.is_outlier)
    valid = np.asarray(out.table.valid)
    clusters: dict[int, list[int]] = {}
    for s in np.nonzero(valid)[0]:
        if is_rep[s]:
            clusters.setdefault(int(s), []).append(int(s))
        elif member_of[s] >= 0:
            clusters.setdefault(int(member_of[s]), []).append(int(s))
    return {
        "clusters": clusters,
        "outliers": [int(s) for s in np.nonzero(valid & is_out)[0]],
        "num_clusters": len(clusters),
        "sscr": float(out.sscr),
        "rmse": float(out.rmse),
        "alpha": float(out.result.alpha_used),
        "k": float(out.result.k_used),
    }
