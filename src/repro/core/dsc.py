"""Single-host end-to-end DSC pipeline (Algorithm 1, P = 1).

This is the semantic reference: the distributed pipeline
(``repro.core.distributed``) must produce the same clusters on the same data
(tested).  The stages mirror the paper exactly:

    subtrajectory join (Problem 1)  ->  voting  ->  segmentation (Problem 2)
    ->  ST / SP relations  ->  clustering + outliers (Problem 3)

Execution modes (``mode=``, see README §Execution modes / DESIGN.md §3):

* ``"materialize"`` — the parity oracle: the DTJ join cube
  ``JoinResult [T, M, C]`` is built in HBM and re-read by each consumer
  (voting, TSA2 masks, similarity scatter).
* ``"fused"``       — streaming epilogue fusion: two Pallas sweeps
  accumulate the consumers' O(T*M + S^2) outputs directly; the cube never
  exists (``DSCOutput.join is None``).  Pass 2 recomputes the best-match
  tiles after segmentation instead of re-reading them.

``use_index=True`` prunes candidate tiles with the spatiotemporal grid
(``repro.index.grid``) in every mode; pruning is conservative, so outputs
are unchanged.  Index planning is host-driven, so that combination requires
concrete (non-traced) inputs.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core import geometry, segmentation, similarity, voting
from repro.core.clustering import (cluster, rmse, rmse_from_result, sscr,
                                   sscr_from_result)
from repro.core.plan import EnginePlan, resolve_plan
from repro.core.types import (ClusteringResult, DSCParams, JoinResult,
                              SubtrajSegmentation, SubtrajTable, TopKSim,
                              TrajectoryBatch)
from repro.utils.tree import pytree_dataclass

# stage-state donation is best-effort (see repro.core.distributed): when a
# stage's outputs can't alias a donated buffer XLA still frees it at call
# time; silence the per-compile nag about the unused alias
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


@pytree_dataclass
class DSCOutput:
    join: JoinResult | None         # None in fused mode (cube never built)
    vote: jnp.ndarray               # [T, M] point voting
    seg: SubtrajSegmentation
    table: SubtrajTable
    sim: jnp.ndarray | None         # [S, S]; None in sim_mode="topk"
    sim_topk: TopKSim | None        # [S, K] lists in sim_mode="topk"
    sim_overflow: jnp.ndarray | None  # [] i32 certificate violations (topk)
    result: ClusteringResult
    sscr: jnp.ndarray               # Eq. 3 objective
    rmse: jnp.ndarray               # Sec. 6.2 quality metric


# --------------------------------------------------------------------------
# Stage bodies.  The monolithic jits below AND the per-stage entry points
# (run_stage_*) compose these same functions, so a staged run executes
# literally the same traced code per stage as a straight-through run — that
# code-sharing is the resilient runner's bit-identity argument
# (``repro.run.resilient``, DESIGN.md §10).
# --------------------------------------------------------------------------


def _segment_body(batch, params, vote, masks, plan: EnginePlan):
    """Voting signal -> segmentation -> subtrajectory table."""
    nvote = voting.normalized_voting(vote, batch.valid)
    if params.segmentation == "tsa1":
        seg = segmentation.tsa1(nvote, batch.valid, params.w, params.tau,
                                params.max_subtrajs_per_traj)
    else:
        seg = segmentation.tsa2(masks, batch.valid, params.w, params.tau,
                                params.max_subtrajs_per_traj,
                                use_kernel=plan.seg_use_kernel)
    table = similarity.build_subtraj_table(
        batch, seg, vote, params.max_subtrajs_per_traj)
    return seg, table


def _similarity_body(batch, params, join, seg, table, plan: EnginePlan,
                     tile_ids=None):
    """SP relation: returns ``(sim, topk)`` — exactly one is non-None."""
    if plan.sim_mode == "topk":
        # sparse SP relation: panel-streamed top-K lists, never [S, S]
        if join is None:
            from repro.kernels.stjoin import ops as stjoin_ops
            Sb = similarity.plan_panel(table.num_slots, plan.sim_panel)

            def panel_raw(p0):
                return stjoin_ops.stjoin_sim_panel_fused(
                    batch, batch, seg.sub_local, seg.sub_local,
                    params.max_subtrajs_per_traj, params.eps_sp,
                    params.eps_t, params.delta_t, p0=p0, panel=Sb,
                    tile_ids=tile_ids, **_tile_kwargs(plan.fused_tiles))

            topk = similarity.topk_stream(panel_raw, table, k=plan.sim_topk,
                                          panel=Sb)
        else:
            topk = similarity.similarity_topk(
                join, seg, seg.sub_local, table,
                params.max_subtrajs_per_traj, k=plan.sim_topk,
                panel=plan.sim_panel)
        return None, topk

    if join is None:
        from repro.kernels.stjoin import ops as stjoin_ops
        raw = stjoin_ops.stjoin_sim_fused(
            batch, batch, seg.sub_local, seg.sub_local,
            params.max_subtrajs_per_traj, params.eps_sp, params.eps_t,
            params.delta_t, tile_ids=tile_ids,
            **_tile_kwargs(plan.fused_tiles))
        sim = similarity.finalize_sim(raw, table)
    else:
        sim = similarity.similarity_matrix(
            join, seg, seg.sub_local, table, params.max_subtrajs_per_traj)
    return sim, None


def _cluster_body(simlike, table, params, plan: EnginePlan):
    """Problem 3: returns ``(result, overflow)``; overflow is None for the
    dense path (the certificate only exists for truncated top-K lists)."""
    result = cluster(simlike, table, params, engine=plan.cluster_engine,
                     use_kernel=plan.cluster_use_kernel,
                     tiles=plan.cluster_tiles)
    if isinstance(simlike, TopKSim):
        return result, similarity.topk_overflow(simlike, result.alpha_used)
    return result, None


def _score_body(result, sim, params):
    """Quality metrics: moment-based when the dense matrix was skipped."""
    if sim is None:
        return sscr_from_result(result), rmse_from_result(result,
                                                          params.eps_sp)
    return sscr(result, sim), rmse(result, sim, params.eps_sp)


def _finish(batch, params, join, vote, masks, plan: EnginePlan,
            tile_ids=None) -> DSCOutput:
    """Segmentation onward — shared by every join/vote front-end.

    ``plan`` is a resolved :class:`EnginePlan` with a concrete ``sim_topk``
    (the dispatcher clamps K to S before tracing).
    """
    seg, table = _segment_body(batch, params, vote, masks, plan)
    sim, topk = _similarity_body(batch, params, join, seg, table, plan,
                                 tile_ids=tile_ids)
    result, overflow = _cluster_body(topk if topk is not None else sim,
                                     table, params, plan)
    sscr_v, rmse_v = _score_body(result, sim, params)
    return DSCOutput(join=join, vote=vote, seg=seg, table=table, sim=sim,
                     sim_topk=topk, sim_overflow=overflow, result=result,
                     sscr=sscr_v, rmse=rmse_v)


def _vote_from_join_body(params, join):
    vote = voting.point_voting(join)
    masks = (voting.neighbor_mask_packed(join)
             if params.segmentation == "tsa2" else None)
    return vote, masks


def _join_vote_materialize_body(batch, params, plan: EnginePlan):
    if plan.use_kernel:
        from repro.kernels.stjoin import ops as stjoin_ops
        join = stjoin_ops.subtrajectory_join(
            batch, batch, params.eps_sp, params.eps_t, params.delta_t)
    else:
        join = geometry.subtrajectory_join(
            batch, batch, params.eps_sp, params.eps_t, params.delta_t,
            use_index=plan.use_index)
    vote, masks = _vote_from_join_body(params, join)
    return join, vote, masks


@functools.partial(jax.jit, static_argnames=("plan",))
def _run_dsc_materialize(batch: TrajectoryBatch, params: DSCParams,
                         plan: EnginePlan) -> DSCOutput:
    join, vote, masks = _join_vote_materialize_body(batch, params, plan)
    return _finish(batch, params, join, vote, masks, plan)


@functools.partial(jax.jit, static_argnames=("plan",))
def _run_dsc_from_join(batch: TrajectoryBatch, params: DSCParams,
                       join: JoinResult, plan: EnginePlan) -> DSCOutput:
    """Materializing tail for a join produced outside the jit boundary
    (the host-planned index-pruned Pallas join)."""
    vote, masks = _vote_from_join_body(params, join)
    return _finish(batch, params, join, vote, masks, plan)


def _tile_kwargs(fused_tiles):
    """(rows, bc, bm) static tuple -> fused-kernel keyword overrides."""
    if fused_tiles is None:
        return {}
    rows, bc, bm = fused_tiles
    return dict(rows=rows, bc=bc, bm=bm)


def _join_vote_fused_body(batch, params, tile_ids, plan: EnginePlan):
    from repro.kernels.stjoin import ops as stjoin_ops
    return stjoin_ops.stjoin_vote_fused_arrays(
        batch.x, batch.y, batch.t, batch.valid, batch.traj_id,
        batch.x, batch.y, batch.t, batch.valid, batch.traj_id,
        params.eps_sp, params.eps_t, params.delta_t, tile_ids=tile_ids,
        with_masks=params.segmentation == "tsa2",
        **_tile_kwargs(plan.fused_tiles))


@functools.partial(jax.jit, static_argnames=("plan",))
def _run_dsc_fused(batch: TrajectoryBatch, params: DSCParams,
                   tile_ids, plan: EnginePlan) -> DSCOutput:
    vote, masks = _join_vote_fused_body(batch, params, tile_ids, plan)
    return _finish(batch, params, None, vote, masks, plan,
                   tile_ids=tile_ids)


# --------------------------------------------------------------------------
# Per-stage entry points — the checkpointable boundaries of the resilient
# runner (``repro.run.resilient``).  Each jits exactly the body the
# monolithic pipeline runs for that stage, so stage k's output fed into
# stage k+1 reproduces the straight-through run bit for bit.
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("plan",))
def run_stage_join(batch: TrajectoryBatch, params: DSCParams,
                   plan: EnginePlan):
    """Materialize-mode stage 1: join cube + votes (+ TSA2 words)."""
    return _join_vote_materialize_body(batch, params, plan)


@functools.partial(jax.jit, static_argnames=("plan",))
def run_stage_join_fused(batch: TrajectoryBatch, params: DSCParams,
                         tile_ids, plan: EnginePlan):
    """Fused-mode stage 1: ``(vote, masks)`` — the cube never exists."""
    return _join_vote_fused_body(batch, params, tile_ids, plan)


@functools.partial(jax.jit, static_argnames=("plan",))
def run_stage_vote_from_join(batch: TrajectoryBatch, params: DSCParams,
                             join: JoinResult, plan: EnginePlan):
    """Stage 1 tail for a host-planned (index-pruned) join."""
    return _vote_from_join_body(params, join)


@functools.partial(jax.jit, static_argnames=("plan",),
                   donate_argnums=(3,))
def run_stage_segment(batch: TrajectoryBatch, params: DSCParams, vote,
                      masks, plan: EnginePlan):
    """Stage 2: segmentation + subtrajectory table from the vote state.
    The packed TSA2 mask cube is donated — it is dead after this stage,
    and the resilient loop holds host copies of all checkpoint state, so
    donation never invalidates a checkpoint reference (DESIGN.md §12)."""
    return _segment_body(batch, params, vote, masks, plan)


@functools.partial(jax.jit, static_argnames=("plan",),
                   donate_argnums=(2,))
def run_stage_similarity(batch: TrajectoryBatch, params: DSCParams, join,
                         seg: SubtrajSegmentation, table: SubtrajTable,
                         tile_ids, plan: EnginePlan):
    """Stage 3: SP relation — ``(sim, topk)``, exactly one non-None.
    ``plan.sim_topk`` must be concrete (clamp K to S before calling).
    The join cube (the largest stage-state buffer) is donated."""
    return _similarity_body(batch, params, join, seg, table, plan,
                            tile_ids=tile_ids)


@functools.partial(jax.jit, static_argnames=("plan",),
                   donate_argnums=(0,))
def run_stage_cluster(simlike, table: SubtrajTable, params: DSCParams,
                      plan: EnginePlan):
    """Stage 4: clustering — ``(result, overflow)``; the similarity
    state is donated (the score stage re-uploads from the host copy)."""
    return _cluster_body(simlike, table, params, plan)


@functools.partial(jax.jit, donate_argnums=(1,))
def run_stage_score(result: ClusteringResult, sim, params: DSCParams):
    """Stage 5 epilogue: ``(sscr, rmse)`` from the clustering state."""
    return _score_body(result, sim, params)


def plan_fused_tile_ids(batch: TrajectoryBatch, params: DSCParams,
                        plan: EnginePlan):
    """Host-side fused-tile planning (``mode="fused"`` + ``use_index``).

    Returns ``(tile_ids, plan)`` where the plan has the tile plan's
    resolved geometry bound, so every later sweep uses the exact tiling
    the ids were built for.  ``tile_ids`` is None when the index is off.
    Shared by :func:`run_dsc`'s dispatcher and the resilient runner —
    both must plan identically for resume parity.
    """
    if not (plan.mode == "fused" and plan.use_index):
        return None, plan
    from repro.kernels.stjoin import ops as stjoin_ops
    tp = stjoin_ops.plan_fused_tiles(
        batch.x, batch.y, batch.t, batch.valid,
        batch.x, batch.y, batch.t, batch.valid,
        params.eps_sp, params.eps_t, **_tile_kwargs(plan.fused_tiles))
    return tp.tile_ids, plan.replace(fused_rows=tp.rows, fused_bc=tp.bc,
                                     fused_bm=tp.bm)


def run_dsc_lowerable(batch: TrajectoryBatch, params: DSCParams,
                      plan: EnginePlan) -> DSCOutput:
    """Trace-friendly single-host pipeline: one plan, one trace.

    The host-level conveniences of :func:`run_dsc` — grid-index planning
    (concrete inputs) and the top-K overflow retry loop (concrete
    ``sim_overflow``) — don't trace, so this entry point skips both: it
    requires ``use_index=False`` and returns the overflow certificate
    instead of retrying.  This is the surface the autotuner
    (``repro.tune.autotune``) lowers, compiles, and times per candidate
    plan, and what anything embedding the pipeline inside a larger jit
    should call.
    """
    plan = resolve_plan(plan)
    if plan.use_index:
        raise ValueError("run_dsc_lowerable requires use_index=False "
                         "(index planning is host-driven); use run_dsc")
    S = batch.num_trajs * params.max_subtrajs_per_traj
    k = min(plan.sim_topk if plan.sim_topk is not None else 32, S)
    plan = plan.replace(sim_topk=k)
    if plan.mode == "fused":
        return _run_dsc_fused(batch, params, None, plan)
    return _run_dsc_materialize(batch, params, plan)


def run_dsc(batch: TrajectoryBatch, params: DSCParams,
            use_kernel: bool = False, *, use_index: bool = False,
            mode: str = "materialize",
            fused_tiles: tuple[int, int, int] | None = None,
            cluster_engine: str = "rounds",
            cluster_use_kernel: bool = False,
            seg_use_kernel: bool = False,
            sim_mode: str = "dense",
            sim_topk: int | None = None,
            sim_panel: int | None = None,
            sim_topk_retry: bool = True,
            on_overflow: str | None = None,
            plan: EnginePlan | None = None) -> DSCOutput:
    """Run the full DSC pipeline on one host / one partition.

    ``plan=`` is the configuration surface: one :class:`EnginePlan`
    holding every per-stage engine and tile choice (DESIGN.md §9).  The
    per-stage keyword flags below are **deprecated aliases** that
    materialize a plan via :func:`repro.core.plan.resolve_plan`; passing
    both a plan and a non-default flag raises.

    ``mode="fused"`` streams the join (no ``[T, M, C]`` cube;
    ``out.join is None``); ``mode="materialize"`` is the parity oracle.
    ``use_index=True`` additionally prunes candidate tiles — host-driven
    planning, so the inputs must be concrete in that case.
    ``fused_tiles=(rows, bc, bm)`` overrides the fused kernels' tile
    geometry (benchmarks use this to pin one inspected configuration).
    ``cluster_engine`` selects the Problem 3 engine: ``"rounds"``
    (round-parallel, default) or ``"sequential"`` (the O(S) parity
    oracle) — label-identical outputs either way (DESIGN.md §6).
    ``cluster_use_kernel=True`` runs the round engine's per-round scan
    and claim-max through the fused Pallas tile kernels
    (``repro.kernels.cluster``) — the accelerator path; the default jnp
    formulation is faster on CPU, where the kernels run in interpret
    mode.
    ``seg_use_kernel=True`` computes the TSA2 Jaccard signal through the
    fused Pallas segmentation kernel (``repro.kernels.jaccard``) instead
    of the jnp packed-word engine — bit-identical cuts, segmentations,
    and downstream labels (DESIGN.md §7); a no-op under ``tsa1``.

    ``sim_mode="topk"`` replaces the dense ``[S, S]`` SP matrix with the
    panel-streamed ``[S, K]`` neighbor-list representation (DESIGN.md §8):
    similarity memory drops to O(S*K + Sb*S) and clustering consumes the
    edge lists directly.  Labels are bit-identical to the dense path
    whenever the per-row spill certificate holds (``out.sim_overflow ==
    0``); on violation the run auto-retries with K doubled
    (``sim_topk_retry``, host-level — requires concrete inputs) or raises.
    ``sim_topk`` sets K (default 32, clamped to S); ``sim_panel`` bounds
    the streaming panel height Sb (default 128, snapped to a divisor of
    S).  ``out.sim`` is None in this mode (use ``out.sim_topk``).

    ``on_overflow`` names the certificate-violation policy explicitly
    (DESIGN.md §10): ``"widen"`` retries with K doubled, ``"raise"``
    raises immediately, ``"degrade"`` returns the truncated result with
    the violation recorded in ``out.sim_overflow``.  The default (None)
    keeps the legacy ``sim_topk_retry`` behavior; passing both raises.
    """
    plan = resolve_plan(plan, mode=mode, use_kernel=use_kernel,
                        use_index=use_index, fused_tiles=fused_tiles,
                        cluster_engine=cluster_engine,
                        cluster_use_kernel=cluster_use_kernel,
                        seg_use_kernel=seg_use_kernel, sim_mode=sim_mode,
                        sim_topk=sim_topk, sim_panel=sim_panel)

    if on_overflow is not None:
        if on_overflow not in ("raise", "widen", "degrade"):
            raise ValueError(f"on_overflow={on_overflow!r}: expected "
                             "'raise', 'widen', or 'degrade'")
        if not sim_topk_retry:
            raise ValueError("pass either on_overflow or "
                             "sim_topk_retry=False, not both")
        policy = on_overflow
    else:
        policy = "widen" if sim_topk_retry else "raise"

    S = batch.num_trajs * params.max_subtrajs_per_traj
    k = min(plan.sim_topk if plan.sim_topk is not None else 32, S)

    def dispatch(k):
        p = plan.replace(sim_topk=k)
        if p.mode == "fused":
            tile_ids, p = plan_fused_tile_ids(batch, params, p)
            return _run_dsc_fused(batch, params, tile_ids, p)
        if p.use_index and p.use_kernel:
            # grid-pruned Pallas join: host-side planning pass, then
            # jitted tail
            from repro.kernels.stjoin import ops as stjoin_ops
            join = stjoin_ops.subtrajectory_join(
                batch, batch, params.eps_sp, params.eps_t, params.delta_t,
                use_index=True)
            return _run_dsc_from_join(batch, params, join, p)
        return _run_dsc_materialize(batch, params, p)

    if plan.sim_mode == "dense":
        return dispatch(k)
    while True:
        out = dispatch(k)
        overflow = int(out.sim_overflow)
        if overflow == 0 or policy == "degrade":
            return out
        if k >= S:                  # unreachable: K == S cannot spill
            raise AssertionError("overflow with K == S")
        if policy == "raise":
            raise RuntimeError(
                f"sim_topk={k} truncated a potential alpha-edge on "
                f"{overflow} rows (spill >= alpha): labels would not be "
                "exact.  Raise sim_topk or enable sim_topk_retry.")
        k = min(2 * k, S)


def cluster_summary(out: DSCOutput) -> dict:
    """Host-side summary: cluster -> member subtraj slots; outliers list.

    Vectorized numpy grouping (sort-by-owner + unique split) instead of a
    Python loop over every slot — this runs once per evaluation-script
    call, on tables whose slot count grows with T * max_subs.
    """
    import numpy as np
    member_of = np.asarray(out.result.member_of)
    is_rep = np.asarray(out.result.is_rep)
    is_out = np.asarray(out.result.is_outlier)
    valid = np.asarray(out.table.valid)
    owner = np.where(is_rep, np.arange(member_of.shape[0]), member_of)
    slots = np.nonzero(valid & (is_rep | (member_of >= 0)))[0]
    by_owner = slots[np.argsort(owner[slots], kind="stable")]
    reps, starts = np.unique(owner[by_owner], return_index=True)
    clusters: dict[int, list[int]] = {
        int(rep): members.tolist()
        for rep, members in zip(reps, np.split(by_owner, starts[1:]))}
    return {
        "clusters": clusters,
        "outliers": [int(s) for s in np.nonzero(valid & is_out)[0]],
        "num_clusters": len(clusters),
        "sscr": float(out.sscr),
        "rmse": float(out.rmse),
        "alpha": float(out.result.alpha_used),
        "k": float(out.result.k_used),
    }
