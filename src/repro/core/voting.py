"""Voting (Eqs. 4, 5, 6) — the density measure driving TSA1 and clustering.

Deviation from the paper (documented in DESIGN.md §2.1): Eq. 4 as printed sums
``d_s/eps_sp``, which *grows* with distance; we use the proximity weight
``1 - d_s/eps_sp`` (consistent with Eq. 2), so a coincident neighbor votes 1
and a neighbor at the eps_sp boundary votes 0.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import JoinResult
from repro.core.windows import pack_bits


def point_voting(join: JoinResult) -> jnp.ndarray:
    """``V(r_i)`` per point: sum of best-match weights over candidate trajs."""
    return jnp.sum(join.best_w, axis=-1)                     # [T, M] float32


def normalized_voting(vote: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Eq. 5: per-trajectory max-normalized voting vector (0 on padding)."""
    vote = jnp.where(valid, vote, 0.0)
    vmax = jnp.max(vote, axis=1, keepdims=True)
    return jnp.where(valid, vote / jnp.maximum(vmax, 1e-12), 0.0)


def trajectory_voting(vote: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6: mean voting of a trajectory's valid points."""
    n = jnp.maximum(jnp.sum(valid, axis=1), 1)
    return jnp.sum(jnp.where(valid, vote, 0.0), axis=1) / n


def neighbor_mask_packed(join: JoinResult) -> jnp.ndarray:
    """TSA2 input: per-point neighbor *sets* as bit-packed uint32 words.

    Bit ``c`` of word ``c // 32`` is set iff candidate trajectory ``c`` has a
    (delta_t-surviving) match with this point.  Shape: ``[T, M, ceil(C/32)]``.
    Packing is the shared ``repro.core.windows.pack_bits`` word layout.
    """
    return pack_bits(join.best_w > 0.0)                         # [T, M, W]
