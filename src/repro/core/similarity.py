"""Subtrajectory similarity (Eq. 2) and the ST / SP relations.

After segmentation, every join match ``(ref point (r, m)  <->  best point of
candidate trajectory c)`` contributes its weight ``1 - d_s/eps_sp`` to the
(sub(r, m), sub(c, best_idx)) cell of the similarity matrix — the densified SP
relation.  The normalizer is ``min(|r'|, |s'|)`` (Eq. 2's denominator).

The matrix is symmetrized with ``max`` (DESIGN.md §2.4): the paper's LCSS
similarity is symmetric by definition; the dense best-match estimate can differ
slightly between the two viewpoints.

Two representations (DESIGN.md §8)
----------------------------------
* dense ``[S, S]``      — ``similarity_matrix`` / ``finalize_sim``: the
  parity oracle, quadratic in S.
* top-K neighbor lists  — the panel-streamed engine below
  (``similarity_topk`` / ``topk_stream``): the matrix is swept in row
  panels of ``Sb`` slots; each join contribution is scattered into the
  live panel in *both* orientations (forward ``[src - p0, dst]`` and
  reverse ``[dst - p0, src]``), so the panel's rows see every cell of
  ``raw`` AND of ``raw.T`` and the ``max``-symmetrization stays exact
  per panel.  A finished panel is normalized (Eq. 2's symmetric
  ``min(card)`` denominator commutes with the row-wise max, so
  normalize-after-max is bit-identical to ``finalize_sim``'s
  normalize-before-max), reduced to ``[Sb, K]`` (id, sim) lists plus the
  per-row moments ``resolve_thresholds`` needs, and discarded — peak
  similarity memory is O(S*K + Sb*S), never O(S^2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import (JoinResult, SubtrajSegmentation, SubtrajTable,
                              TopKSim, TrajectoryBatch)


def build_subtraj_table(batch: TrajectoryBatch, seg: SubtrajSegmentation,
                        vote: jnp.ndarray, max_subs: int) -> SubtrajTable:
    """The ST relation: (t_start, t_end, V, Card) per (traj, local sub) slot."""
    return build_subtraj_table_arrays(
        batch.t, batch.valid, seg.sub_local, vote, max_subs)


def build_subtraj_table_arrays(t: jnp.ndarray, valid: jnp.ndarray,
                               sub_local: jnp.ndarray, vote: jnp.ndarray,
                               max_subs: int) -> SubtrajTable:
    """Array-level ST construction (used by the distributed pipeline)."""
    T, M = t.shape
    S = T * max_subs
    slot = jnp.where(
        sub_local >= 0,
        jnp.arange(T)[:, None] * max_subs + sub_local, S)        # [T, M]
    flat = slot.reshape(-1)
    big = jnp.float32(3.4e38)

    t_start = jnp.full((S + 1,), big).at[flat].min(
        jnp.where(valid, t, big).reshape(-1))[:S]
    t_end = jnp.full((S + 1,), -big).at[flat].max(
        jnp.where(valid, t, -big).reshape(-1))[:S]
    card = jnp.zeros((S + 1,), jnp.int32).at[flat].add(
        valid.reshape(-1).astype(jnp.int32))[:S]
    vsum = jnp.zeros((S + 1,), jnp.float32).at[flat].add(
        jnp.where(valid, vote, 0.0).reshape(-1))[:S]

    valid = card > 0
    voting = jnp.where(valid, vsum / jnp.maximum(card, 1), 0.0)
    traj_row = jnp.repeat(jnp.arange(T, dtype=jnp.int32), max_subs)
    return SubtrajTable(
        t_start=jnp.where(valid, t_start, 0.0),
        t_end=jnp.where(valid, t_end, 0.0),
        voting=voting, card=card, valid=valid, traj_row=traj_row)


def finalize_sim(raw: jnp.ndarray, table: SubtrajTable) -> jnp.ndarray:
    """Eq. 2 normalization of the raw SP scatter: shared by the
    materializing path (``similarity_matrix``) and the fused streaming path
    (``kernels.stjoin.ops.stjoin_sim_fused``), so both produce the same
    matrix from the same accumulator.
    """
    S = table.num_slots
    denom = jnp.minimum(table.card[:, None], table.card[None, :])
    sim = raw / jnp.maximum(denom, 1).astype(jnp.float32)
    sim = jnp.maximum(sim, sim.T)
    idx = jnp.arange(S)
    keep = (table.valid[:, None] & table.valid[None, :]
            & (idx[:, None] != idx[None, :]))   # index mask, no [S, S] eye
    return jnp.where(keep, sim, 0.0)            # one fused mask pass


def scatter_operands(join: JoinResult, ref_seg: SubtrajSegmentation,
                     cand_seg_sub_local: jnp.ndarray, S: int, max_subs: int):
    """Flat SP-scatter contribution list ``(src [N], dst [N], w [N])``.

    ``src``/``dst`` are subtrajectory slot ids with ``S`` as the sentinel
    for unmatched / unsegmented points.  Shared by the dense scatter
    (``similarity_matrix``) and the panel-streamed top-K sweep
    (``similarity_topk``) so both accumulate the identical contribution
    sequence — per-cell sums are bit-equal.
    """
    T, M, C = join.best_w.shape
    src = jnp.where(
        ref_seg.sub_local >= 0,
        jnp.arange(T)[:, None] * max_subs + ref_seg.sub_local, S)  # [T, M]
    src = jnp.broadcast_to(src[:, :, None], (T, M, C))

    idx = jnp.clip(join.best_idx, 0, cand_seg_sub_local.shape[1] - 1)
    cand_sub = cand_seg_sub_local[
        jnp.arange(C)[None, None, :], idx]                          # [T, M, C]
    dst = jnp.where(
        (join.best_idx >= 0) & (cand_sub >= 0),
        jnp.arange(C)[None, None, :] * max_subs + cand_sub, S)
    return src.reshape(-1), dst.reshape(-1), join.best_w.reshape(-1)


def similarity_matrix(
    join: JoinResult,
    ref_seg: SubtrajSegmentation,
    cand_seg_sub_local: jnp.ndarray,   # [C, Mc] candidate-side point->sub map
    table: SubtrajTable,
    max_subs: int,
) -> jnp.ndarray:
    """Densified SP relation: Sim[S, S] per Eq. 2, symmetrized.

    ``cand_seg_sub_local`` maps each candidate point to its local subtraj id
    (in a self-join this is the same array as ``ref_seg.sub_local``).
    """
    S = table.num_slots
    src, dst, w = scatter_operands(join, ref_seg, cand_seg_sub_local, S,
                                   max_subs)
    raw = jnp.zeros((S + 1, S + 1), jnp.float32)
    raw = raw.at[src, dst].add(w)
    return finalize_sim(raw[:S, :S], table)


# ---------------------------------------------------------------------------
# Panel-streamed top-K engine (DESIGN.md §8): the sparse SP representation.
# ---------------------------------------------------------------------------


def largest_divisor(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` — the one tile /
    panel sizing rule (also the distributed join's block planner)."""
    for b in range(min(n, max(target, 1)), 0, -1):
        if n % b == 0:
            return b
    return 1


def plan_panel(S: int, target: int | None = None) -> int:
    """Panel height ``Sb``: the largest divisor of ``S`` at most ``target``.

    A divisor keeps every panel full — no partially-valid panel rows, so
    the per-panel reductions need no row masking beyond ``table.valid``.
    """
    return largest_divisor(S, target if target is not None else 128)


def _row_tree_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Sum over axis 1 with an explicit pairwise tree (zero-padded to a
    power of two).  The association order depends only on the row LENGTH
    — never on how many rows ride along — unlike ``jnp.sum(axis=1)``,
    whose XLA lowering may reassociate differently for ``[S, S]`` vs
    ``[Sb, S]`` operands and shift the result by ulps."""
    n = x.shape[1]
    p = 1 << max(n - 1, 0).bit_length()
    x = jnp.pad(x, ((0, 0), (0, p - n)))
    while x.shape[1] > 1:
        x = x[:, 0::2] + x[:, 1::2]
    return x[:, 0]


def sim_row_moments(sim_rows: jnp.ndarray, row_valid: jnp.ndarray,
                    col_valid: jnp.ndarray):
    """Per-row (count, sum, sum-of-squares) of the positive similarity
    entries: the sufficient statistics of ``resolve_thresholds``'s alpha.

    Reduction is strictly row-wise with a fixed pairwise tree, so
    computing it on the full ``[S, S]`` matrix or on an ``[Sb, S]`` row
    panel yields bit-identical per-row partials wherever the row content
    matches — the property that keeps the dense and top-K paths'
    thresholds bit-equal.  (Distributed column blocks reduce over
    ``S_loc`` and psum — a different but mode-independent order, so the
    two distributed representations still agree bit for bit.)
    """
    pos = (sim_rows > 0.0) & row_valid[:, None] & col_valid[None, :]
    x = jnp.where(pos, sim_rows, 0.0)
    return (_row_tree_sum(pos.astype(jnp.int32)),
            _row_tree_sum(x), _row_tree_sum(x * x))


def finalize_sim_panel(fwd: jnp.ndarray, rev: jnp.ndarray, p0,
                       table: SubtrajTable,
                       active: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq. 2 finalization of one row panel from its two raw orientations.

    ``fwd[i, j] = raw[p0 + i, j]`` and ``rev[i, j] = raw[j, p0 + i]``, so
    ``max(fwd, rev)`` is exactly the panel's rows of ``max(raw, raw.T)``.
    The symmetric ``min(card)`` denominator commutes with the max (IEEE
    division by a positive denominator is monotone), so dividing after
    the max is bit-identical to ``finalize_sim``'s divide-then-max.
    ``active`` (distributed phase 4) additionally masks rows/columns to
    the partition-active slot set.
    """
    Sb, S = fwd.shape
    rows = p0 + jnp.arange(Sb)
    cols = jnp.arange(S)
    sym = jnp.maximum(fwd, rev)
    denom = jnp.minimum(table.card[rows][:, None], table.card[None, :])
    sim = sym / jnp.maximum(denom, 1).astype(jnp.float32)
    keep = (table.valid[rows][:, None] & table.valid[None, :]
            & (rows[:, None] != cols[None, :]))
    if active is not None:
        keep &= active[rows][:, None] & active[None, :]
    return jnp.where(keep, sim, 0.0)


def _topk_tail(vals: jnp.ndarray, cand_ids: jnp.ndarray, k: int):
    """Shared tail of every top-K reduction: truncate ``lax.top_k``'s
    top-(K+1) ``(vals, candidate ids)`` to the K retained edges (id -1 /
    sim 0 where non-positive) and the spill certificate — the (K+1)-th
    value, clamped non-negative, 0 when it does not exist.  One
    implementation, so the single-host panel reduction and the
    distributed k-way merge can never disagree on the certificate's
    semantics.
    """
    kk = vals.shape[1]
    sims = vals[:, :k]
    ids = jnp.where(sims > 0.0, cand_ids[:, :k], -1).astype(jnp.int32)
    sims = jnp.maximum(sims, 0.0)
    if kk > k:
        spill = jnp.maximum(vals[:, k], 0.0)
    else:
        spill = jnp.zeros((vals.shape[0],), jnp.float32)
    return ids, sims, spill


def topk_reduce_rows(sim_rows: jnp.ndarray, k: int):
    """Reduce finalized similarity rows to their top-K edge lists.

    Returns ``(ids [R, k], sims [R, k], spill [R])``: the K largest
    entries per row (``lax.top_k`` order — descending, ties by ascending
    column) with non-positive entries masked to ``(id=-1, sim=0)``, plus
    the (K+1)-th largest value (0 when it does not exist or is not
    positive) — the exactness certificate of ``TopKSim``.
    """
    kk = min(k + 1, sim_rows.shape[1])
    vals, idx = jax.lax.top_k(sim_rows, kk)
    return _topk_tail(vals, idx, k)


def topk_stream(panel_raw_fn, table: SubtrajTable, *, k: int,
                panel: int | None = None,
                active: jnp.ndarray | None = None) -> TopKSim:
    """Drive the panel sweep: raw orientations -> finalize -> top-K.

    ``panel_raw_fn(p0)`` must return the two raw orientations
    ``(fwd [Sb, S], rev [Sb, S])`` of the rows ``[p0, p0 + Sb)`` — from a
    join-cube scatter (``similarity_topk``) or a fused Pallas re-sweep
    (``kernels.stjoin.ops.stjoin_sim_panel_fused``).  Only one panel's
    ``[Sb, S]`` slabs are ever live; the scan stacks the ``[Sb, K]``
    reductions into the final ``[S, K]`` lists.
    """
    S = table.num_slots
    k = min(k, S)
    Sb = plan_panel(S, panel)

    def body(_, p):
        sim_rows = finalize_sim_panel(*panel_raw_fn(p * Sb), p * Sb, table,
                                      active=active)
        rows = p * Sb + jnp.arange(Sb)
        cnt, rsum, rsumsq = sim_row_moments(
            sim_rows, table.valid[rows], table.valid)
        ids, sims, spill = topk_reduce_rows(sim_rows, k)
        return None, (ids, sims, spill, cnt, rsum, rsumsq)

    _, (ids, sims, spill, cnt, rsum, rsumsq) = jax.lax.scan(
        body, None, jnp.arange(S // Sb))
    return TopKSim(
        ids=ids.reshape(S, k), sims=sims.reshape(S, k),
        spill=spill.reshape(S), degree=cnt.reshape(S),
        row_sum=rsum.reshape(S), row_sumsq=rsumsq.reshape(S))


def contribution_panel_raw(src: jnp.ndarray, dst: jnp.ndarray,
                           w: jnp.ndarray, S: int, Sb: int):
    """``panel_raw(p0)`` closure over a flat contribution list: scatter
    the contributions whose src (fwd) / dst (rev) falls inside the live
    panel, in both orientations, into ``[Sb, S]`` slabs (sentinel row
    ``Sb`` / column ``S`` absorbs the rest).  The one scatter
    implementation behind ``similarity_topk`` and the contribution-level
    CI gate (``benchmarks/kernel_bench.py``).
    """
    def panel_raw(p0):
        ls = jnp.where((src >= p0) & (src < p0 + Sb), src - p0, Sb)
        fwd = jnp.zeros((Sb + 1, S + 1), jnp.float32).at[ls, dst].add(w)
        ld = jnp.where((dst >= p0) & (dst < p0 + Sb), dst - p0, Sb)
        rev = jnp.zeros((Sb + 1, S + 1), jnp.float32).at[ld, src].add(w)
        return fwd[:Sb, :S], rev[:Sb, :S]

    return panel_raw


def similarity_topk(join: JoinResult, ref_seg: SubtrajSegmentation,
                    cand_seg_sub_local: jnp.ndarray, table: SubtrajTable,
                    max_subs: int, *, k: int,
                    panel: int | None = None) -> TopKSim:
    """Sparse SP relation from a materialized join: the panel-streamed
    counterpart of ``similarity_matrix`` — same contribution list
    (``scatter_operands``), same per-cell accumulation order, but the
    ``[S, S]`` matrix never exists.
    """
    S = table.num_slots
    src, dst, w = scatter_operands(join, ref_seg, cand_seg_sub_local, S,
                                   max_subs)
    Sb = plan_panel(S, panel)
    return topk_stream(contribution_panel_raw(src, dst, w, S, Sb), table,
                       k=k, panel=Sb)


def topk_from_dense(sim: jnp.ndarray, table: SubtrajTable, k: int,
                    active: jnp.ndarray | None = None) -> TopKSim:
    """TopKSim of an already-finalized dense matrix (tests / oracles).

    Row content equals what the panel sweep sees, so the lists, spill,
    and moments are bit-identical to ``similarity_topk``'s.
    """
    S = table.num_slots
    k = min(k, S)
    valid = table.valid if active is None else table.valid & active
    if active is not None:
        sim = jnp.where(active[:, None] & active[None, :], sim, 0.0)
    cnt, rsum, rsumsq = sim_row_moments(sim, valid, valid)
    ids, sims, spill = topk_reduce_rows(sim, k)
    return TopKSim(ids=ids, sims=sims, spill=spill, degree=cnt,
                   row_sum=rsum, row_sumsq=rsumsq)


def sort_topk_lists(ids: jnp.ndarray, sims: jnp.ndarray, kk: int):
    """Canonical top-``kk`` of candidate lists: sort rows by the total
    order (sim descending, id ascending) and truncate.

    The two-key ``lax.sort`` makes the result a function of the *set* of
    ``(id, sim)`` pairs alone — independent of column order, block
    splits, or merge grouping — because distinct ids make the order
    total.  That set-function property is what lets the ring similarity
    sweep fold blocks into a running list one step at a time and still
    match the barrier k-way merge bit for bit (DESIGN.md §12), and it is
    pinned by the hypothesis suite in ``tests/test_topk_sim.py``.

    ``sims`` must be non-negative (similarity values) and ids distinct
    within a row; returns ``(ids [S, kk], sims [S, kk])`` untruncated by
    sign — masking to ``(id=-1, sim=0)`` stays in ``_topk_tail``.
    """
    neg_s, ids_s = jax.lax.sort((-sims, ids), dimension=-1, num_keys=2)
    kk = min(kk, sims.shape[1])
    return ids_s[:, :kk], -neg_s[:, :kk]


def merge_topk_lists(ids_a, sims_a, ids_b, sims_b, kk: int):
    """Pairwise canonical merge — one ring step: fold the list that just
    arrived into the standing top-``kk``.  Exact because the top-``kk``
    of a union is contained in the union of the operands' top-``kk``
    lists (selection containment), and canonical because
    ``sort_topk_lists`` is."""
    return sort_topk_lists(jnp.concatenate([ids_a, ids_b], axis=1),
                           jnp.concatenate([sims_a, sims_b], axis=1), kk)


def merge_topk_blocks(ids: jnp.ndarray, sims: jnp.ndarray, k: int):
    """K-way merge of per-block top-(K+1) lists into global top-K + spill.

    ``ids [S, B*(K+1)]`` / ``sims`` concatenate the blocks' candidate
    lists (disjoint column ranges, exact values).  The global top-(K+1)
    of a row is always contained in the union of its blocks' top-(K+1)
    lists, so the merged top-K and the merged (K+1)-th value (the spill
    certificate) are exactly those of the full row.  Ordering is the
    canonical (sim desc, id asc) total order of ``sort_topk_lists`` —
    for the distributed barrier caller this coincides with the historic
    position-stable ``lax.top_k`` tie-break, because rank-major concat
    of per-rank ``top_k`` lists already places equal values in ascending
    global-id order.
    """
    mi, ms = sort_topk_lists(ids, sims, min(k + 1, sims.shape[1]))
    return _topk_tail(ms, mi, k)


def topk_overflow(topk: TopKSim, alpha) -> jnp.ndarray:
    """Per-row exactness-certificate violations (int32 count).

    A row overflows when its spill value — the largest similarity K
    truncated away — is itself a potential alpha-edge: some edge the
    clustering engines need may be missing.  ``overflow == 0`` therefore
    *proves* K bounded every row's true alpha-degree and the top-K labels
    equal the dense oracle's bit for bit.
    """
    over = (topk.spill > 0.0) & (topk.spill >= alpha)
    return jnp.sum(over).astype(jnp.int32)


def finalize_sim_cols(sym_blk: jnp.ndarray, c0, table: SubtrajTable,
                      active: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq. 2 finalization of a symmetrized *column block* ``[S, S_loc]``.

    The distributed top-K path symmetrizes per model rank: each rank owns
    columns ``[c0, c0 + S_loc)`` of ``raw`` and, after the transpose-
    partner all_to_all, the matching rows of ``raw.T`` — so
    ``sym_blk[i, j] = max(raw[i, c0+j], raw[c0+j, i])`` is exact.  Masks
    and normalization mirror ``finalize_sim`` cell for cell.
    """
    S, S_loc = sym_blk.shape
    rows = jnp.arange(S)
    cols = c0 + jnp.arange(S_loc)
    denom = jnp.minimum(table.card[:, None], table.card[cols][None, :])
    sim = sym_blk / jnp.maximum(denom, 1).astype(jnp.float32)
    keep = (table.valid[:, None] & table.valid[cols][None, :]
            & (rows[:, None] != cols[None, :]))
    if active is not None:
        keep &= active[:, None] & active[cols][None, :]
    return jnp.where(keep, sim, 0.0)
