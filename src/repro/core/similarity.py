"""Subtrajectory similarity (Eq. 2) and the ST / SP relations.

After segmentation, every join match ``(ref point (r, m)  <->  best point of
candidate trajectory c)`` contributes its weight ``1 - d_s/eps_sp`` to the
(sub(r, m), sub(c, best_idx)) cell of the similarity matrix — the densified SP
relation.  The normalizer is ``min(|r'|, |s'|)`` (Eq. 2's denominator).

The matrix is symmetrized with ``max`` (DESIGN.md §2.4): the paper's LCSS
similarity is symmetric by definition; the dense best-match estimate can differ
slightly between the two viewpoints.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import (JoinResult, SubtrajSegmentation, SubtrajTable,
                              TrajectoryBatch)


def build_subtraj_table(batch: TrajectoryBatch, seg: SubtrajSegmentation,
                        vote: jnp.ndarray, max_subs: int) -> SubtrajTable:
    """The ST relation: (t_start, t_end, V, Card) per (traj, local sub) slot."""
    return build_subtraj_table_arrays(
        batch.t, batch.valid, seg.sub_local, vote, max_subs)


def build_subtraj_table_arrays(t: jnp.ndarray, valid: jnp.ndarray,
                               sub_local: jnp.ndarray, vote: jnp.ndarray,
                               max_subs: int) -> SubtrajTable:
    """Array-level ST construction (used by the distributed pipeline)."""
    T, M = t.shape
    S = T * max_subs
    slot = jnp.where(
        sub_local >= 0,
        jnp.arange(T)[:, None] * max_subs + sub_local, S)        # [T, M]
    flat = slot.reshape(-1)
    big = jnp.float32(3.4e38)

    t_start = jnp.full((S + 1,), big).at[flat].min(
        jnp.where(valid, t, big).reshape(-1))[:S]
    t_end = jnp.full((S + 1,), -big).at[flat].max(
        jnp.where(valid, t, -big).reshape(-1))[:S]
    card = jnp.zeros((S + 1,), jnp.int32).at[flat].add(
        valid.reshape(-1).astype(jnp.int32))[:S]
    vsum = jnp.zeros((S + 1,), jnp.float32).at[flat].add(
        jnp.where(valid, vote, 0.0).reshape(-1))[:S]

    valid = card > 0
    voting = jnp.where(valid, vsum / jnp.maximum(card, 1), 0.0)
    traj_row = jnp.repeat(jnp.arange(T, dtype=jnp.int32), max_subs)
    return SubtrajTable(
        t_start=jnp.where(valid, t_start, 0.0),
        t_end=jnp.where(valid, t_end, 0.0),
        voting=voting, card=card, valid=valid, traj_row=traj_row)


def finalize_sim(raw: jnp.ndarray, table: SubtrajTable) -> jnp.ndarray:
    """Eq. 2 normalization of the raw SP scatter: shared by the
    materializing path (``similarity_matrix``) and the fused streaming path
    (``kernels.stjoin.ops.stjoin_sim_fused``), so both produce the same
    matrix from the same accumulator.
    """
    S = table.num_slots
    denom = jnp.minimum(table.card[:, None], table.card[None, :])
    sim = raw / jnp.maximum(denom, 1).astype(jnp.float32)
    sim = jnp.maximum(sim, sim.T)
    idx = jnp.arange(S)
    keep = (table.valid[:, None] & table.valid[None, :]
            & (idx[:, None] != idx[None, :]))   # index mask, no [S, S] eye
    return jnp.where(keep, sim, 0.0)            # one fused mask pass


def similarity_matrix(
    join: JoinResult,
    ref_seg: SubtrajSegmentation,
    cand_seg_sub_local: jnp.ndarray,   # [C, Mc] candidate-side point->sub map
    table: SubtrajTable,
    max_subs: int,
) -> jnp.ndarray:
    """Densified SP relation: Sim[S, S] per Eq. 2, symmetrized.

    ``cand_seg_sub_local`` maps each candidate point to its local subtraj id
    (in a self-join this is the same array as ``ref_seg.sub_local``).
    """
    T, M, C = join.best_w.shape
    S = table.num_slots

    src = jnp.where(
        ref_seg.sub_local >= 0,
        jnp.arange(T)[:, None] * max_subs + ref_seg.sub_local, S)  # [T, M]
    src = jnp.broadcast_to(src[:, :, None], (T, M, C))

    idx = jnp.clip(join.best_idx, 0, cand_seg_sub_local.shape[1] - 1)
    cand_sub = cand_seg_sub_local[
        jnp.arange(C)[None, None, :], idx]                          # [T, M, C]
    dst = jnp.where(
        (join.best_idx >= 0) & (cand_sub >= 0),
        jnp.arange(C)[None, None, :] * max_subs + cand_sub, S)

    raw = jnp.zeros((S + 1, S + 1), jnp.float32)
    raw = raw.at[src.reshape(-1), dst.reshape(-1)].add(join.best_w.reshape(-1))
    return finalize_sim(raw[:S, :S], table)
