"""Greedy SSCR clustering and outlier detection (Algorithm 4).

Semantics (DESIGN.md §2.3): subtrajectories are visited in descending voting
order; a visited subtrajectory that is *not yet claimed by any cluster* and has
voting >= k becomes a new representative and claims every adjacent
subtrajectory with Sim >= alpha that is (a) unclaimed, or (b) claimed with a
strictly smaller similarity (the reassignment of lines 16-19).  A visited
unclaimed subtrajectory with voting < k is an outlier.  Representatives are
never claimed by later representatives.

``alpha`` and ``k`` resolve per partition from the similarity / voting
distribution as ``mean + sigma * std`` (paper Sec. 6.1) unless absolute
overrides are provided.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ClusteringResult, DSCParams, SubtrajTable


def resolve_thresholds(params: DSCParams, sim: jnp.ndarray,
                       table: SubtrajTable):
    """Absolute (alpha, k) from sigma-relative settings (Sec. 6.1)."""
    pos = (sim > 0.0) & table.valid[:, None] & table.valid[None, :]
    n_pos = jnp.maximum(jnp.sum(pos), 1)
    s_mean = jnp.sum(jnp.where(pos, sim, 0.0)) / n_pos
    s_var = jnp.sum(jnp.where(pos, (sim - s_mean) ** 2, 0.0)) / n_pos
    alpha = jnp.where(params.alpha_abs >= 0.0, params.alpha_abs,
                      s_mean + params.alpha_sigma * jnp.sqrt(s_var))

    nv = jnp.maximum(jnp.sum(table.valid), 1)
    v_mean = jnp.sum(jnp.where(table.valid, table.voting, 0.0)) / nv
    v_var = jnp.sum(
        jnp.where(table.valid, (table.voting - v_mean) ** 2, 0.0)) / nv
    k = jnp.where(params.k_abs >= 0.0, params.k_abs,
                  v_mean + params.k_sigma * jnp.sqrt(v_var))
    return alpha, k


def cluster(sim: jnp.ndarray, table: SubtrajTable,
            params: DSCParams) -> ClusteringResult:
    """Algorithm 4 over a dense similarity matrix.  O(S) sequential steps,
    each a vectorized [S] claim/reassign update."""
    S = table.num_slots
    alpha, k = resolve_thresholds(params, sim, table)

    # visit order: valid slots by voting desc (invalid parked at the end).
    key = jnp.where(table.valid, table.voting, -jnp.inf)
    order = jnp.argsort(-key)

    member_of0 = jnp.full((S,), -1, jnp.int32)
    member_sim0 = jnp.zeros((S,), jnp.float32)
    is_rep0 = jnp.zeros((S,), bool)
    slots = jnp.arange(S, dtype=jnp.int32)

    def body(i, state):
        member_of, member_sim, is_rep = state
        s = order[i]
        s_valid = table.valid[s]
        unclaimed = member_of[s] < 0
        becomes_rep = s_valid & unclaimed & ~is_rep[s] & (table.voting[s] >= k)

        row = jax.lax.dynamic_slice(sim, (s, 0), (1, S))[0]       # AdjLst of s
        claim = (becomes_rep
                 & table.valid
                 & (row > 0.0)
                 & (row >= alpha)
                 & ~is_rep
                 & (slots != s)
                 & (row > member_sim))
        member_of = jnp.where(claim, s, member_of)
        member_sim = jnp.where(claim, row, member_sim)
        member_of = member_of.at[s].set(
            jnp.where(becomes_rep, s, member_of[s]))
        member_sim = member_sim.at[s].set(
            jnp.where(becomes_rep, jnp.float32(jnp.inf), member_sim[s]))
        is_rep = is_rep.at[s].set(is_rep[s] | becomes_rep)
        return member_of, member_sim, is_rep

    member_of, member_sim, is_rep = jax.lax.fori_loop(
        0, S, body, (member_of0, member_sim0, is_rep0))

    is_outlier = table.valid & (member_of < 0)
    return ClusteringResult(
        member_of=member_of,
        member_sim=jnp.where(is_rep, jnp.inf, member_sim),
        is_rep=is_rep, is_outlier=is_outlier,
        alpha_used=alpha, k_used=k)


cluster_jit = jax.jit(cluster)


def sscr(result: ClusteringResult, sim: jnp.ndarray) -> jnp.ndarray:
    """Eq. 3 objective: sum of member->representative similarities."""
    member = (~result.is_rep) & (result.member_of >= 0)
    rep = jnp.clip(result.member_of, 0, sim.shape[0] - 1)
    vals = sim[jnp.arange(sim.shape[0]), rep]
    return jnp.sum(jnp.where(member, vals, 0.0))


def rmse(result: ClusteringResult, sim: jnp.ndarray,
         eps_sp: float) -> jnp.ndarray:
    """Intra-cluster RMSE (Sec. 6.2's quality metric).

    Via Lemma 1, a member's mean distance to its representative is
    ``eps_sp * (1 - Sim)``; RMSE aggregates that over all members.
    """
    member = (~result.is_rep) & (result.member_of >= 0)
    rep = jnp.clip(result.member_of, 0, sim.shape[0] - 1)
    s = jnp.clip(sim[jnp.arange(sim.shape[0]), rep], 0.0, 1.0)
    d = eps_sp * (1.0 - s)
    n = jnp.maximum(jnp.sum(member), 1)
    return jnp.sqrt(jnp.sum(jnp.where(member, d * d, 0.0)) / n)
