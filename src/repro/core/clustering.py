"""Greedy SSCR clustering and outlier detection (Algorithm 4).

Semantics (DESIGN.md §2.3): subtrajectories are visited in descending voting
order; a visited subtrajectory that is *not yet claimed by any cluster* and has
voting >= k becomes a new representative and claims every adjacent
subtrajectory with Sim >= alpha that is (a) unclaimed, or (b) claimed with a
strictly smaller similarity (the reassignment of lines 16-19).  A visited
unclaimed subtrajectory with voting < k is an outlier.  Representatives are
never claimed by later representatives.

``alpha`` and ``k`` resolve per partition from the similarity / voting
distribution as ``mean + sigma * std`` (paper Sec. 6.1) unless absolute
overrides are provided.

Engines (DESIGN.md §6)
----------------------
* ``engine="sequential"`` — the literal Algorithm 4 transcription: an O(S)
  ``fori_loop`` of data-dependent steps, one ``dynamic_slice`` row of the
  dense ``[S, S]`` matrix per visited slot.  Kept as the parity oracle.
* ``engine="rounds"``     — the round-parallel formulation (default): the
  serial loop only exists to decide the *representative set*, and that
  decision for slot ``s`` depends solely on earlier-visited slots ``u``
  with ``Sim[u, s] >= alpha`` (the slots that could claim ``s`` first).
  Each round therefore resolves EVERY still-undecided slot with no
  undecided predecessor at once; membership afterwards is one vectorized
  claim-max over representative rows.  O(rounds) iterations, rounds
  typically ≪ S.  Label-identical to the oracle (pinned by
  ``tests/test_cluster_rounds.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.similarity import sim_row_moments
from repro.core.types import ClusteringResult, DSCParams, SubtrajTable, TopKSim
from repro.kernels.cluster.ref import claim_max_ref


def resolve_thresholds_from_moments(params: DSCParams, moments,
                                    table: SubtrajTable):
    """Absolute (alpha, k) from per-row similarity moments (Sec. 6.1).

    ``moments = (count [S] i32, sum [S] f32, sumsq [S] f32)`` are the
    per-row statistics of the positive similarity entries
    (``similarity.sim_row_moments``).  Keeping the row axis explicit —
    rather than a pre-reduced scalar — is what lets every producer (the
    dense matrix, the streamed row panels, and the distributed
    column-block psum) hand over bit-identical inputs, so every path
    resolves the exact same alpha.  The variance is ``E[x^2] - E[x]^2``:
    numerically safe here because sim values are O(1).  The voting vector
    is only ``[S]``; it keeps the centered two-pass variance, which stays
    exact even when ``mean >> std`` (e.g. large absolute vote counts).
    """
    cnt, rsum, rsumsq = moments
    n_pos = jnp.maximum(jnp.sum(cnt), 1)
    s_mean = jnp.sum(rsum) / n_pos
    s_var = jnp.maximum(jnp.sum(rsumsq) / n_pos - s_mean * s_mean, 0.0)
    alpha = jnp.where(params.alpha_abs >= 0.0, params.alpha_abs,
                      s_mean + params.alpha_sigma * jnp.sqrt(s_var))

    nv = jnp.maximum(jnp.sum(table.valid), 1)
    v_mean = jnp.sum(jnp.where(table.valid, table.voting, 0.0)) / nv
    v_var = jnp.sum(
        jnp.where(table.valid, (table.voting - v_mean) ** 2, 0.0)) / nv
    k = jnp.where(params.k_abs >= 0.0, params.k_abs,
                  v_mean + params.k_sigma * jnp.sqrt(v_var))
    return alpha, k


def resolve_thresholds(params: DSCParams, sim: jnp.ndarray,
                       table: SubtrajTable, moments=None):
    """Absolute (alpha, k) from a dense similarity matrix.

    One masked row-wise pass collects (count, sum, sumsq) per row; the
    reduction to alpha lives in ``resolve_thresholds_from_moments`` so the
    top-K streaming path (which never holds the matrix) and the
    distributed column-block path resolve bit-identical thresholds.
    ``moments`` overrides the matrix pass with externally-accumulated
    row moments (the distributed program psums per-rank blocks).
    """
    if moments is None:
        moments = sim_row_moments(sim, table.valid, table.valid)
    return resolve_thresholds_from_moments(params, moments, table)


def visit_order(table: SubtrajTable):
    """(order, rank): Algorithm 4's visit sequence — valid slots by voting
    descending, ties by slot index (stable argsort), invalid parked last.
    ``order[p]`` is the slot visited at position ``p``; ``rank`` is the
    inverse permutation (slot -> visit position)."""
    S = table.num_slots
    key = jnp.where(table.valid, table.voting, -jnp.inf)
    order = jnp.argsort(-key).astype(jnp.int32)
    rank = jnp.zeros((S,), jnp.int32).at[order].set(
        jnp.arange(S, dtype=jnp.int32))
    return order, rank


def cluster_sequential(sim: jnp.ndarray, table: SubtrajTable,
                       params: DSCParams,
                       moments=None) -> ClusteringResult:
    """Algorithm 4 over a dense similarity matrix.  O(S) sequential steps,
    each a vectorized [S] claim/reassign update.  The parity oracle for
    ``cluster_rounds``."""
    S = table.num_slots
    alpha, k = resolve_thresholds(params, sim, table, moments=moments)
    order, _ = visit_order(table)

    member_of0 = jnp.full((S,), -1, jnp.int32)
    member_sim0 = jnp.zeros((S,), jnp.float32)
    is_rep0 = jnp.zeros((S,), bool)
    slots = jnp.arange(S, dtype=jnp.int32)

    def body(i, state):
        member_of, member_sim, is_rep = state
        s = order[i]
        s_valid = table.valid[s]
        unclaimed = member_of[s] < 0
        becomes_rep = s_valid & unclaimed & ~is_rep[s] & (table.voting[s] >= k)

        row = jax.lax.dynamic_slice(sim, (s, 0), (1, S))[0]       # AdjLst of s
        claim = (becomes_rep
                 & table.valid
                 & (row > 0.0)
                 & (row >= alpha)
                 & ~is_rep
                 & (slots != s)
                 & (row > member_sim))
        member_of = jnp.where(claim, s, member_of)
        member_sim = jnp.where(claim, row, member_sim)
        member_of = member_of.at[s].set(
            jnp.where(becomes_rep, s, member_of[s]))
        member_sim = member_sim.at[s].set(
            jnp.where(becomes_rep, jnp.float32(jnp.inf), member_sim[s]))
        is_rep = is_rep.at[s].set(is_rep[s] | becomes_rep)
        return member_of, member_sim, is_rep

    member_of, member_sim, is_rep = jax.lax.fori_loop(
        0, S, body, (member_of0, member_sim0, is_rep0))

    is_outlier = table.valid & (member_of < 0)
    return ClusteringResult(
        member_of=member_of,
        member_sim=jnp.where(is_rep, jnp.inf, member_sim),
        is_rep=is_rep, is_outlier=is_outlier,
        alpha_used=alpha, k_used=k)


# ---------------------------------------------------------------------------
# Round-parallel engine
# ---------------------------------------------------------------------------
#
# Two observations collapse Algorithm 4's serial claim loop:
#
# 1. Whether slot ``s`` becomes a representative depends ONLY on whether an
#    earlier-visited representative has an alpha-edge to it
#    (``Sim[u, s] > 0 and >= alpha``): any such claim sets
#    ``member_of[s] >= 0`` before ``s`` is visited, and nothing ever
#    un-claims a slot.  The running ``member_sim`` values are irrelevant to
#    rep eligibility.  So ``is_rep`` satisfies the closed recurrence
#        rep[s] = potential[s] and not OR_u { rep[u] : pred[u, s] }
#    over the DAG ``pred[u, s] = potential[u] & alpha-edge(u, s)
#    & rank[u] < rank[s]`` with ``potential = valid & voting >= k``.
#    A round resolves every undecided slot with no undecided predecessor
#    (its verdict can no longer change) — plus every slot already claimed
#    by a resolved rep (its verdict is already "not rep") — so the loop
#    runs O(rounds) ≪ S iterations instead of S.
#
# 2. The final membership is order-free: the sequential reassignment
#    (lines 16-19, strict ``row > member_sim``) ends with every non-rep
#    claimed slot assigned to the alpha-adjacent representative of maximum
#    similarity, first-visited winning ties.  That is one claim-max
#    reduction over representative rows with (voting desc, slot asc)
#    tie-break — no loop at all, and exactly what the Pallas
#    ``cluster_assign`` kernel tiles.


def cluster_rounds(sim: jnp.ndarray, table: SubtrajTable, params: DSCParams,
                   *, max_rounds: int | None = None, use_kernel: bool = False,
                   with_rounds: bool = False, moments=None, tiles=None):
    """Round-parallel Algorithm 4 — label-identical to the oracle.

    ``max_rounds=None`` runs a ``jax.lax.while_loop`` until every slot is
    resolved (at least one slot resolves per round, so at most S rounds
    execute).  An integer ``max_rounds`` switches to a fixed-trip
    ``fori_loop`` (converged rounds are no-ops) for contexts where a
    data-dependent trip count is unwelcome; because S rounds are always
    sufficient and fewer cannot guarantee convergence, ``max_rounds < S``
    is rejected rather than silently returning partial labels.
    ``use_kernel=True`` runs the per-round scan and the final claim-max
    through the fused Pallas tile kernels (``repro.kernels.cluster``);
    ``tiles=(bu, bs)`` overrides their (row, column) tile geometry
    (``EnginePlan.cluster_tiles`` — the autotuner's swept knob; labels
    are bit-identical across geometries, only padding changes).
    ``with_rounds=True`` additionally returns the number of rounds
    executed (i32 scalar).
    """
    S = table.num_slots
    if max_rounds is not None and max_rounds < S:
        raise ValueError(
            f"max_rounds={max_rounds} < S={S}: the fixed-trip fallback "
            "cannot guarantee convergence below S rounds (labels would "
            "silently be partial); pass max_rounds >= S or use the "
            "while_loop default")
    alpha, k = resolve_thresholds(params, sim, table, moments=moments)
    order, rank = visit_order(table)
    potential = table.valid & (table.voting >= k)

    if use_kernel:
        from repro.kernels import default_interpret
        from repro.kernels.cluster.ops import cluster_assign, cluster_round_scan
        interp = default_interpret()
        bu, bs = tiles if tiles is not None else (8, 128)

        def scan(unresolved, is_rep):
            return cluster_round_scan(sim, rank, unresolved, is_rep, alpha,
                                      bu=bu, bs=bs, interpret=interp)

        def assign(is_rep):
            return cluster_assign(sim, rank, is_rep, table.valid, alpha,
                                  bu=bu, bs=bs, interpret=interp)
    else:
        # the alpha-edge predicate never changes across rounds: build it
        # once and reduce each round to two 0/1 vector-matrix products
        # (exact: row sums are < 2^24, so f32 accumulation is integral) —
        # the Pallas engine instead recomputes the predicate per tile in
        # VMEM, where the rebuild is free and the [S, S] bool matrix
        # would be extra HBM traffic.
        predf = ((sim > 0.0) & (sim >= alpha)
                 & (rank[:, None] < rank[None, :])).astype(jnp.float32)

        def scan(unresolved, is_rep):
            blocked = (unresolved.astype(jnp.float32) @ predf) > 0.0
            claimed = (is_rep.astype(jnp.float32) @ predf) > 0.0
            return blocked, claimed

        def assign(is_rep):
            return claim_max_ref(sim, order, rank, is_rep, table.valid,
                                 alpha)

    def body(state):
        resolved, is_rep, rounds = state
        unresolved = ~resolved
        blocked, claimed = scan(unresolved, is_rep)
        frontier = unresolved & (~blocked | claimed)
        is_rep = is_rep | (frontier & ~claimed)
        resolved = resolved | frontier
        return resolved, is_rep, rounds + jnp.any(unresolved).astype(jnp.int32)

    init = (~potential, jnp.zeros_like(potential),
            jnp.zeros((), jnp.int32))
    if max_rounds is None:
        resolved, is_rep, rounds = jax.lax.while_loop(
            lambda st: ~jnp.all(st[0]), body, init)
    else:
        resolved, is_rep, rounds = jax.lax.fori_loop(
            0, max_rounds, lambda i, st: body(st), init)

    member_sim, member_of = assign(is_rep)

    slots = jnp.arange(S, dtype=jnp.int32)
    member_of = jnp.where(is_rep, slots, member_of)
    member_sim = jnp.where(is_rep, jnp.float32(jnp.inf), member_sim)
    is_outlier = table.valid & (member_of < 0)
    result = ClusteringResult(
        member_of=member_of, member_sim=member_sim,
        is_rep=is_rep, is_outlier=is_outlier,
        alpha_used=alpha, k_used=k)
    return (result, rounds) if with_rounds else result


# ---------------------------------------------------------------------------
# Neighbor-list (top-K) engines — Algorithm 4 on the sparse SP relation
# ---------------------------------------------------------------------------
#
# Every predicate of Algorithm 4 lives on *edges*: rep eligibility and the
# claim-max only ever test ``sim > 0 and sim >= alpha`` pairs.  With the
# max-symmetrized matrix reduced to per-row top-K lists (``TopKSim``), each
# slot's alpha-adjacency is its own list — provided K bounded the row's
# true alpha-degree, which the spill certificate proves per row
# (``similarity.topk_overflow``).  Both engines below are then
# label-identical to their dense counterparts, at O(S*K) per sweep instead
# of O(S^2), and thresholds resolve from the streamed row moments the
# ``TopKSim`` carries — bit-equal to the dense ``resolve_thresholds``.


def _topk_thresholds(topk: TopKSim, table: SubtrajTable, params: DSCParams):
    return resolve_thresholds_from_moments(
        params, (topk.degree, topk.row_sum, topk.row_sumsq), table)


def cluster_sequential_topk(topk: TopKSim, table: SubtrajTable,
                            params: DSCParams) -> ClusteringResult:
    """Algorithm 4 over neighbor lists: the literal sequential transcription
    with each visited slot's adjacency read from its ``[K]`` list row
    instead of a dense ``[S]`` matrix row.  Parity oracle for
    ``cluster_rounds_topk``."""
    S = table.num_slots
    alpha, k = _topk_thresholds(topk, table, params)
    order, _ = visit_order(table)

    member_of0 = jnp.full((S,), -1, jnp.int32)
    member_sim0 = jnp.zeros((S,), jnp.float32)
    is_rep0 = jnp.zeros((S,), bool)

    def body(i, state):
        member_of, member_sim, is_rep = state
        s = order[i]
        s_valid = table.valid[s]
        unclaimed = member_of[s] < 0
        becomes_rep = s_valid & unclaimed & ~is_rep[s] & (table.voting[s] >= k)

        uid = jax.lax.dynamic_slice(topk.ids, (s, 0), (1, topk.k))[0]
        w = jax.lax.dynamic_slice(topk.sims, (s, 0), (1, topk.k))[0]
        safe = jnp.clip(uid, 0, S - 1)
        claim = (becomes_rep
                 & (uid >= 0)
                 & table.valid[safe]
                 & (w > 0.0)
                 & (w >= alpha)
                 & ~is_rep[safe]
                 & (safe != s)
                 & (w > member_sim[safe]))
        tgt = jnp.where(claim, safe, S)          # sentinel S drops
        member_of = member_of.at[tgt].set(s, mode="drop")
        member_sim = member_sim.at[tgt].set(w, mode="drop")
        member_of = member_of.at[s].set(
            jnp.where(becomes_rep, s, member_of[s]))
        member_sim = member_sim.at[s].set(
            jnp.where(becomes_rep, jnp.float32(jnp.inf), member_sim[s]))
        is_rep = is_rep.at[s].set(is_rep[s] | becomes_rep)
        return member_of, member_sim, is_rep

    member_of, member_sim, is_rep = jax.lax.fori_loop(
        0, S, body, (member_of0, member_sim0, is_rep0))

    is_outlier = table.valid & (member_of < 0)
    return ClusteringResult(
        member_of=member_of,
        member_sim=jnp.where(is_rep, jnp.inf, member_sim),
        is_rep=is_rep, is_outlier=is_outlier,
        alpha_used=alpha, k_used=k)


def cluster_rounds_topk(topk: TopKSim, table: SubtrajTable, params: DSCParams,
                        *, max_rounds: int | None = None,
                        use_kernel: bool = False, with_rounds: bool = False,
                        tiles=None, seed_resolved=None, seed_is_rep=None):
    """Round-parallel Algorithm 4 over neighbor lists.

    Same DAG recurrence and claim-max as ``cluster_rounds``, but every
    per-round reduction runs over the ``[S, K]`` edge lists — O(S*K) work
    and memory per round.  ``use_kernel=True`` routes the scan and the
    claim-max through the Pallas list-tile kernels
    (``repro.kernels.cluster``); label-identical either way.  The list
    kernels tile rows only, so of ``tiles=(bu, bs)`` they consume ``bu``
    as their row tile (default 8).

    ``seed_resolved`` / ``seed_is_rep`` ([S] bool) warm-start the rep
    recurrence from a previous solve (streaming driver, DESIGN.md §13.4):
    slots marked resolved enter round 0 already decided, with
    ``seed_is_rep`` as their verdict.  Exactness is the caller's
    obligation — the seeds must be a *visit-order prefix* of the current
    instance whose (rank, potential, list row) inputs are unchanged from
    the solve that produced them, in which case the recurrence resolves
    them identically and the warm run's labels are bit-equal to a cold
    run's.  The final claim-max is always recomputed in full.
    """
    from repro.kernels.cluster.ref import (topk_claim_max_ref,
                                           topk_round_scan_ref)
    S = table.num_slots
    if max_rounds is not None and max_rounds < S:
        raise ValueError(
            f"max_rounds={max_rounds} < S={S}: the fixed-trip fallback "
            "cannot guarantee convergence below S rounds (labels would "
            "silently be partial); pass max_rounds >= S or use the "
            "while_loop default")
    alpha, k = _topk_thresholds(topk, table, params)
    order, rank = visit_order(table)
    potential = table.valid & (table.voting >= k)

    if use_kernel:
        from repro.kernels import default_interpret
        from repro.kernels.cluster.ops import (topk_cluster_assign,
                                               topk_cluster_round_scan)
        interp = default_interpret()
        row_tile = tiles[0] if tiles is not None else 8

        def scan(unresolved, is_rep):
            return topk_cluster_round_scan(
                topk.ids, topk.sims, rank, unresolved, is_rep, alpha,
                bs=row_tile, interpret=interp)

        def assign(is_rep):
            return topk_cluster_assign(
                topk.ids, topk.sims, rank, is_rep, table.valid, alpha,
                bs=row_tile, interpret=interp)
    else:
        def scan(unresolved, is_rep):
            return topk_round_scan_ref(topk.ids, topk.sims, rank,
                                       unresolved, is_rep, alpha)

        def assign(is_rep):
            return topk_claim_max_ref(topk.ids, topk.sims, rank, is_rep,
                                      table.valid, alpha)

    def body(state):
        resolved, is_rep, rounds = state
        unresolved = ~resolved
        blocked, claimed = scan(unresolved, is_rep)
        frontier = unresolved & (~blocked | claimed)
        is_rep = is_rep | (frontier & ~claimed)
        resolved = resolved | frontier
        return resolved, is_rep, rounds + jnp.any(unresolved).astype(jnp.int32)

    resolved0 = ~potential
    rep0 = jnp.zeros_like(potential)
    if seed_resolved is not None:
        resolved0 = resolved0 | seed_resolved
        rep0 = rep0 | (seed_is_rep & seed_resolved & potential)
    init = (resolved0, rep0, jnp.zeros((), jnp.int32))
    if max_rounds is None:
        resolved, is_rep, rounds = jax.lax.while_loop(
            lambda st: ~jnp.all(st[0]), body, init)
    else:
        resolved, is_rep, rounds = jax.lax.fori_loop(
            0, max_rounds, lambda i, st: body(st), init)

    member_sim, member_of = assign(is_rep)

    slots = jnp.arange(S, dtype=jnp.int32)
    member_of = jnp.where(is_rep, slots, member_of)
    member_sim = jnp.where(is_rep, jnp.float32(jnp.inf), member_sim)
    is_outlier = table.valid & (member_of < 0)
    result = ClusteringResult(
        member_of=member_of, member_sim=member_sim,
        is_rep=is_rep, is_outlier=is_outlier,
        alpha_used=alpha, k_used=k)
    return (result, rounds) if with_rounds else result


def sscr_from_result(result: ClusteringResult) -> jnp.ndarray:
    """Eq. 3 from the clustering result alone (no matrix gather).

    ``member_sim`` of a claimed non-rep slot IS its similarity to its
    representative (the claim-max value of the max-symmetrized matrix),
    so the Eq. 3 sum needs no ``sim[s, rep]`` lookup — this is how the
    top-K pipeline scores without ever holding ``[S, S]``.  Bit-equal to
    ``sscr(result, sim)`` on the dense path.
    """
    member = (~result.is_rep) & (result.member_of >= 0)
    return jnp.sum(jnp.where(member, result.member_sim, 0.0))


def rmse_from_result(result: ClusteringResult, eps_sp: float) -> jnp.ndarray:
    """Sec. 6.2 RMSE from the clustering result alone (cf. ``rmse``)."""
    member = (~result.is_rep) & (result.member_of >= 0)
    s = jnp.clip(jnp.where(member, result.member_sim, 0.0), 0.0, 1.0)
    d = eps_sp * (1.0 - s)
    n = jnp.maximum(jnp.sum(member), 1)
    return jnp.sqrt(jnp.sum(jnp.where(member, d * d, 0.0)) / n)


def cluster(sim, table: SubtrajTable, params: DSCParams,
            engine: str = "rounds", *, max_rounds: int | None = None,
            use_kernel: bool = False, moments=None,
            tiles=None) -> ClusteringResult:
    """Problem 3 entry point: dispatch on representation and engine.

    ``sim`` is either the dense ``[S, S]`` matrix or a ``TopKSim``
    neighbor-list structure; ``engine="rounds"`` (default) is the
    round-parallel formulation, ``engine="sequential"`` the O(S) oracle.
    All four combinations produce bit-identical ``member_of`` /
    ``member_sim`` / ``is_rep`` / ``is_outlier`` (for top-K: whenever the
    overflow certificate is zero).  ``moments`` overrides the dense
    threshold statistics (distributed column-block psum); the top-K
    structure carries its own.  ``tiles=(bu, bs)`` pins the Pallas round
    kernels' tile geometry (``EnginePlan.cluster_tiles``; ignored by the
    jnp engines and the sequential oracle).
    """
    if isinstance(sim, TopKSim):
        if engine == "sequential":
            return cluster_sequential_topk(sim, table, params)
        if engine == "rounds":
            return cluster_rounds_topk(sim, table, params,
                                       max_rounds=max_rounds,
                                       use_kernel=use_kernel, tiles=tiles)
        raise ValueError(f"unknown cluster engine {engine!r}")
    if engine == "sequential":
        return cluster_sequential(sim, table, params, moments=moments)
    if engine == "rounds":
        return cluster_rounds(sim, table, params, max_rounds=max_rounds,
                              use_kernel=use_kernel, moments=moments,
                              tiles=tiles)
    raise ValueError(f"unknown cluster engine {engine!r}")


cluster_jit = jax.jit(
    cluster, static_argnames=("engine", "max_rounds", "use_kernel", "tiles"))


def sscr(result: ClusteringResult, sim: jnp.ndarray) -> jnp.ndarray:
    """Eq. 3 objective: sum of member->representative similarities."""
    member = (~result.is_rep) & (result.member_of >= 0)
    rep = jnp.clip(result.member_of, 0, sim.shape[0] - 1)
    vals = sim[jnp.arange(sim.shape[0]), rep]
    return jnp.sum(jnp.where(member, vals, 0.0))


def rmse(result: ClusteringResult, sim: jnp.ndarray,
         eps_sp: float) -> jnp.ndarray:
    """Intra-cluster RMSE (Sec. 6.2's quality metric).

    Via Lemma 1, a member's mean distance to its representative is
    ``eps_sp * (1 - Sim)``; RMSE aggregates that over all members.
    """
    member = (~result.is_rep) & (result.member_of >= 0)
    rep = jnp.clip(result.member_of, 0, sim.shape[0] - 1)
    s = jnp.clip(sim[jnp.arange(sim.shape[0]), rep], 0.0, 1.0)
    d = eps_sp * (1.0 - s)
    n = jnp.maximum(jnp.sum(member), 1)
    return jnp.sqrt(jnp.sum(jnp.where(member, d * d, 0.0)) / n)
