"""Core data model for Distributed Subtrajectory Clustering.

Everything is fixed-shape (TPU-friendly). The canonical layout is
*trajectory-major*: a batch of ``T`` trajectories, each padded to ``M``
timestamped points. Invalid slots carry ``valid == False`` and are ignored by
every operator.

Paper mapping
-------------
* ``TrajectoryBatch``        <- the input dataset ``D`` (Sec. 3)
* ``JoinResult``             <- the DTJ output: per reference point, the
                                best-matching point of every other trajectory
                                (the ``MatchingPoints`` lists, densified)
* ``SubtrajSegmentation``    <- the cutting-point vector CP[] (Problems 2)
* ``SubtrajTable``           <- the ST relation: (t_s, t_e, V, Card) per subtraj
* ``SimilarityMatrix``       <- the SP relation (adjacency lists, densified)
* ``TopKSim``                <- the SP relation kept sparse: per-row top-K
                                neighbor lists + an exactness certificate
* ``ClusteringResult``       <- the sets C (clusters) and O (outliers)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.utils.tree import pytree_dataclass, static_field


@pytree_dataclass
class DSCParams:
    """All parameters of the DSC pipeline (paper Table 1).

    ``alpha``/``k`` follow Sec. 6.1: they are expressed in standard deviations
    around the per-partition mean of the similarity / voting distribution
    (``alpha_sigma``, ``k_sigma``) unless absolute overrides are given.
    """

    eps_sp: float = 0.1        # spatial matching threshold epsilon_sp
    eps_t: float = 0.5         # temporal matching tolerance epsilon_t
    delta_t: float = 0.0       # minimum duration of a match (delta t)
    w: int = static_field(default=10)     # sliding-window size (samples)
    tau: float = 0.4           # segmentation threshold on window difference
    alpha_sigma: float = 0.0   # similarity threshold, in sigmas around mean
    k_sigma: float = 0.0       # voting threshold, in sigmas around mean
    alpha_abs: float = -1.0    # absolute override; active when >= 0
    k_abs: float = -1.0        # absolute override; active when >= 0
    # --- capacities (static; replace the paper's dynamic HashMaps/lists) ---
    max_subtrajs_per_traj: int = static_field(default=8)
    segmentation: str = static_field(default="tsa1")  # "tsa1" | "tsa2"


@pytree_dataclass
class TrajectoryBatch:
    """``T`` trajectories padded to ``M`` points, time-sorted within a row."""

    x: jnp.ndarray        # [T, M] float32
    y: jnp.ndarray        # [T, M] float32
    t: jnp.ndarray        # [T, M] float32 (seconds)
    valid: jnp.ndarray    # [T, M] bool
    traj_id: jnp.ndarray  # [T] int32 global trajectory ids (-1 = padding row)

    @property
    def num_trajs(self) -> int:
        return self.x.shape[0]

    @property
    def max_points(self) -> int:
        return self.x.shape[1]

    @property
    def count(self) -> jnp.ndarray:   # [T] valid points per trajectory
        return jnp.sum(self.valid, axis=1).astype(jnp.int32)

    @staticmethod
    def from_numpy(trajs: list[np.ndarray], max_points: int | None = None,
                   pad_trajs_to: int | None = None) -> "TrajectoryBatch":
        """Build a batch from a list of ``[n_i, 3]`` (x, y, t) arrays."""
        n = len(trajs)
        T = pad_trajs_to or n
        M = max_points or max((len(tr) for tr in trajs), default=1)
        x = np.zeros((T, M), np.float32)
        y = np.zeros((T, M), np.float32)
        t = np.zeros((T, M), np.float32)
        valid = np.zeros((T, M), bool)
        ids = np.full((T,), -1, np.int32)
        for i, tr in enumerate(trajs):
            tr = np.asarray(tr, np.float32)
            order = np.argsort(tr[:, 2], kind="stable")
            tr = tr[order][:M]
            m = len(tr)
            x[i, :m], y[i, :m], t[i, :m] = tr[:, 0], tr[:, 1], tr[:, 2]
            valid[i, :m] = True
            ids[i] = i
        return TrajectoryBatch(
            x=jnp.asarray(x), y=jnp.asarray(y), t=jnp.asarray(t),
            valid=jnp.asarray(valid), traj_id=jnp.asarray(ids))


@pytree_dataclass
class JoinResult:
    """Dense DTJ output (Problem 1), from the reference batch's perspective.

    ``best_w[r, m, c]``  : weight ``1 - d_s/eps_sp`` of the best match between
                           ref point ``(r, m)`` and candidate trajectory ``c``
                           (0 when no point of ``c`` is inside the cylinder).
    ``best_idx[r, m, c]``: point index (within the candidate row) of that best
                           match (-1 when none).
    After ``delta_t`` filtering, matches belonging to a common subsequence
    shorter than ``delta_t`` are zeroed (DTJ's Refine step).
    """

    best_w: jnp.ndarray    # [T, M, C] float32
    best_idx: jnp.ndarray  # [T, M, C] int32


@pytree_dataclass
class SubtrajSegmentation:
    """Output of TSA1/TSA2 (Problem 2) for a trajectory batch.

    ``cut[r, m]``     : True when point m starts a new subtrajectory
                        (cut[., 0] is always True for valid rows).
    ``sub_local[r,m]``: local subtrajectory index (0-based) of each point,
                        clipped to ``max_subtrajs_per_traj - 1``.
    ``num_subs[r]``   : number of subtrajectories of trajectory r.
    """

    cut: jnp.ndarray        # [T, M] bool
    sub_local: jnp.ndarray  # [T, M] int32
    num_subs: jnp.ndarray   # [T] int32
    score: jnp.ndarray      # [T, M] float32 — the window-difference signal d[]


@pytree_dataclass
class SubtrajTable:
    """The ST relation: one row per (traj, local subtraj) slot; S = T * maxS."""

    t_start: jnp.ndarray   # [S] float32
    t_end: jnp.ndarray     # [S] float32
    voting: jnp.ndarray    # [S] float32  (Eq. 6, mean point voting)
    card: jnp.ndarray      # [S] int32    (number of points)
    valid: jnp.ndarray     # [S] bool
    traj_row: jnp.ndarray  # [S] int32    (owning trajectory row)

    @property
    def num_slots(self) -> int:
        return self.t_start.shape[0]


@pytree_dataclass
class TopKSim:
    """Sparse SP relation: per-row top-K neighbor lists of the symmetrized,
    Eq. 2-normalized similarity matrix — the paper's adjacency lists,
    bounded to a static width ``K`` instead of densified to ``[S, S]``.

    Rows are sorted by similarity descending (``lax.top_k`` order: ties by
    ascending neighbor slot).  Entries beyond the row's positive degree
    carry ``ids == -1`` and ``sims == 0``.

    Exactness certificate: ``spill[s]`` is the (K+1)-th largest positive
    similarity of row ``s`` (0 when the row has at most K positive
    entries).  Every dropped entry of row ``s`` is ``<= spill[s]``, so
    whenever ``spill[s] < alpha`` the list provably contains *every*
    alpha-edge of ``s`` — and the clustering engines consuming this
    structure are label-identical to the dense oracle.  ``spill >= alpha``
    anywhere means K may have truncated a real alpha-edge: the overflow
    counter (``repro.core.similarity.topk_overflow``) is then nonzero and
    callers must widen K (``run_dsc`` auto-retries) or fail loudly.

    ``degree`` and the ``row_*`` moments are exact per-row statistics of
    the full (un-truncated) positive row — ``resolve_thresholds`` derives
    the same alpha/k from them as from the dense matrix.
    """

    ids: jnp.ndarray         # [S, K] int32 neighbor slot ids (-1 padding)
    sims: jnp.ndarray        # [S, K] float32, descending per row
    spill: jnp.ndarray       # [S] float32 (K+1)-th largest positive sim
    degree: jnp.ndarray      # [S] int32 positive entries of the full row
    row_sum: jnp.ndarray     # [S] float32 sum of positive entries
    row_sumsq: jnp.ndarray   # [S] float32 sum of squared positive entries

    @property
    def num_slots(self) -> int:
        return self.ids.shape[0]

    @property
    def k(self) -> int:
        return self.ids.shape[1]


@pytree_dataclass
class ClusteringResult:
    """Output of Algorithm 4 (+ Algorithm 5 refinement).

    States: ``member_of[s] == s`` and ``is_rep[s]``  -> representative;
            ``member_of[s] >= 0`` and not rep        -> cluster member;
            ``member_of[s] < 0``  (valid slot)       -> outlier.
    """

    member_of: jnp.ndarray   # [S] int32 (slot id of the cluster representative)
    member_sim: jnp.ndarray  # [S] float32 similarity to the representative
    is_rep: jnp.ndarray      # [S] bool
    is_outlier: jnp.ndarray  # [S] bool
    alpha_used: jnp.ndarray  # [] float32 — resolved absolute alpha
    k_used: jnp.ndarray      # [] float32 — resolved absolute k
