"""Temporal equi-depth partitioning (the paper's Repartitioning phase).

Host-side preprocessing, done once per dataset (paper Sec. 4.2): build an
equi-depth histogram over the temporal dimension (every bin holds ~the same
number of points — the Hadoop InputSampler/TotalOrderPartitioner analogue),
then lay the points out *row-aligned*: partition p holds, for every global
trajectory row r, the points of r falling in p's time range, padded to
``Mp``.  Row alignment is what turns the MapReduce group-by-trajectory
shuffle into a single static ``all_to_all`` (DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import TrajectoryBatch
from repro.utils.tree import pytree_dataclass

import jax.numpy as jnp


@pytree_dataclass
class PartitionedBatch:
    """Row-aligned temporal partitions: ``[P, T, Mp]`` point slabs."""

    x: jnp.ndarray       # [P, T, Mp] float32
    y: jnp.ndarray       # [P, T, Mp]
    t: jnp.ndarray       # [P, T, Mp]
    valid: jnp.ndarray   # [P, T, Mp] bool
    traj_id: jnp.ndarray  # [T] int32 global ids (-1 padding rows)
    ranges: jnp.ndarray  # [P, 2] float32 (t_lo, t_hi) per partition

    @property
    def num_partitions(self) -> int:
        return self.x.shape[0]


def equi_depth_edges(times: np.ndarray, P: int,
                     sample: int | None = 100_000,
                     seed: int = 0) -> np.ndarray:
    """Equi-depth bin edges from a sample of the valid timestamps."""
    times = np.asarray(times).ravel()
    if sample is not None and times.size > sample:
        rng = np.random.default_rng(seed)
        times = rng.choice(times, size=sample, replace=False)
    qs = np.quantile(times, np.linspace(0.0, 1.0, P + 1))
    qs[0], qs[-1] = -np.inf, np.inf
    # guard against duplicate edges on highly skewed data
    for i in range(1, P):
        if qs[i] <= qs[i - 1]:
            qs[i] = np.nextafter(qs[i - 1], np.inf)
    return qs.astype(np.float64)


def partition_batch(batch: TrajectoryBatch, P: int, *, pad_mp_to: int = 8,
                    sample: int | None = 100_000) -> PartitionedBatch:
    """Split a TrajectoryBatch into P row-aligned temporal partitions."""
    x = np.asarray(batch.x)
    y = np.asarray(batch.y)
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    T, M = x.shape

    edges = equi_depth_edges(t[v], P, sample=sample)
    # partition index per point
    pidx = np.searchsorted(edges, t, side="right") - 1
    pidx = np.clip(pidx, 0, P - 1)
    pidx = np.where(v, pidx, -1)

    counts = np.zeros((P, T), np.int64)
    for p in range(P):
        counts[p] = (pidx == p).sum(axis=1)
    Mp = int(counts.max(initial=1))
    Mp = max(pad_mp_to, ((Mp + pad_mp_to - 1) // pad_mp_to) * pad_mp_to)

    px = np.zeros((P, T, Mp), np.float32)
    py = np.zeros((P, T, Mp), np.float32)
    pt = np.zeros((P, T, Mp), np.float32)
    pv = np.zeros((P, T, Mp), bool)
    for p in range(P):
        for r in range(T):
            sel = np.nonzero(pidx[r] == p)[0]
            m = len(sel)
            if m:
                px[p, r, :m] = x[r, sel]
                py[p, r, :m] = y[r, sel]
                pt[p, r, :m] = t[r, sel]
                pv[p, r, :m] = True

    finite_lo = np.where(np.isfinite(edges[:-1]), edges[:-1],
                         t[v].min() - 1.0)
    finite_hi = np.where(np.isfinite(edges[1:]), edges[1:], t[v].max() + 1.0)
    ranges = np.stack([finite_lo, finite_hi], axis=1).astype(np.float32)

    return PartitionedBatch(
        x=jnp.asarray(px), y=jnp.asarray(py), t=jnp.asarray(pt),
        valid=jnp.asarray(pv), traj_id=batch.traj_id,
        ranges=jnp.asarray(ranges))
