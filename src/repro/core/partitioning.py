"""Temporal equi-depth partitioning (the paper's Repartitioning phase).

Host-side preprocessing, done once per dataset (paper Sec. 4.2): build an
equi-depth histogram over the temporal dimension (every bin holds ~the same
number of points — the Hadoop InputSampler/TotalOrderPartitioner analogue),
then lay the points out *row-aligned*: partition p holds, for every global
trajectory row r, the points of r falling in p's time range, padded to
``Mp``.  Row alignment is what turns the MapReduce group-by-trajectory
shuffle into a single static ``all_to_all`` (DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import TrajectoryBatch
from repro.utils.tree import pytree_dataclass

import jax.numpy as jnp


@pytree_dataclass
class PartitionedBatch:
    """Row-aligned temporal partitions: ``[P, T, Mp]`` point slabs."""

    x: jnp.ndarray       # [P, T, Mp] float32
    y: jnp.ndarray       # [P, T, Mp]
    t: jnp.ndarray       # [P, T, Mp]
    valid: jnp.ndarray   # [P, T, Mp] bool
    traj_id: jnp.ndarray  # [T] int32 global ids (-1 padding rows)
    ranges: jnp.ndarray  # [P, 2] float32 (t_lo, t_hi) per partition

    @property
    def num_partitions(self) -> int:
        return self.x.shape[0]


def _float_order_bits(i: np.ndarray) -> np.ndarray:
    """Monotone involution on float64 *bit patterns*: IEEE-754 total order.

    ``key = bits ^ ((bits >> 63) & 0x7FF...F)`` sorts int64 keys exactly
    like the floats they encode.  The xor mask never touches the sign
    bit, so applying the map twice is the identity: the same function
    decodes keys back to bit patterns.  NB the total order gives -0.0 and
    +0.0 *distinct* keys (-1 and 0) while ``nextafter`` treats them as
    one value — ``_float_rank`` collapses that pair.
    """
    i = np.asarray(i, np.int64)
    return i ^ ((i >> 63) & np.int64(0x7FFFFFFFFFFFFFFF))


def _float_rank(v: np.ndarray) -> np.ndarray:
    """float64 -> int64 rank with ``np.nextafter(x, inf) == rank(x) + 1``
    for every ``x < inf`` — which turns the duplicate-edge bump loop into
    one ``np.maximum.accumulate``.  Built from the total-order key by
    merging the two zero keys (ranks are the key shifted up by one on the
    negative side), since ``nextafter(-0.0, inf)`` is the smallest
    subnormal, not +0.0.  ``_rank_float`` inverts (the zero class decodes
    to +0.0, == -0.0 under float comparison)."""
    key = _float_order_bits(np.asarray(v, np.float64).view(np.int64))
    return key + (key < 0)


def _rank_float(rank: np.ndarray) -> np.ndarray:
    key = np.where(rank >= 0, rank, rank - 1)
    return _float_order_bits(key).view(np.float64)


def equi_depth_edges(times: np.ndarray, P: int,
                     sample: int | None = 100_000,
                     seed: int = 0) -> np.ndarray:
    """Equi-depth bin edges from a sample of the valid timestamps."""
    times = np.asarray(times).ravel()
    if sample is not None and times.size > sample:
        rng = np.random.default_rng(seed)
        times = rng.choice(times, size=sample, replace=False)
    qs = np.quantile(times, np.linspace(0.0, 1.0, P + 1))
    qs[0], qs[-1] = -np.inf, np.inf
    # guard against duplicate edges on highly skewed data: the sequential
    # rule r[i] = max(qs[i], nextafter(r[i-1])) is, in rank space
    # (nextafter == +1), the scan r[i] - i = max_{j<=i}(rank[j] - j) — one
    # maximum.accumulate instead of the per-edge Python loop (equality
    # with the loop, under float comparison, is pinned by
    # tests/test_partition.py, -0.0/subnormal edges included).
    rank = _float_rank(qs[:P])
    idx = np.arange(P, dtype=np.int64)
    qs[:P] = _rank_float(np.maximum.accumulate(rank - idx) + idx)
    return qs.astype(np.float64)


def partition_batch(batch: TrajectoryBatch, P: int, *, pad_mp_to: int = 8,
                    sample: int | None = 100_000) -> PartitionedBatch:
    """Split a TrajectoryBatch into P row-aligned temporal partitions."""
    x = np.asarray(batch.x)
    y = np.asarray(batch.y)
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    T, M = x.shape

    edges = equi_depth_edges(t[v], P, sample=sample)
    # partition index per point
    pidx = np.searchsorted(edges, t, side="right") - 1
    pidx = np.clip(pidx, 0, P - 1)
    pidx = np.where(v, pidx, -1)

    # one argsort-by-(partition, row, time-position) + scatter instead of
    # the O(P*T) per-cell np.nonzero double loop (equality with the loop
    # version is pinned by tests/test_partition.py).  Valid flat indices
    # are already (row, m)-ordered, so a stable sort by partition alone
    # yields (p, r, m) order — m order is what the loop's np.nonzero
    # produced per cell.
    rows = np.broadcast_to(np.arange(T)[:, None], (T, M))
    flat = np.nonzero(v.ravel())[0]
    order = flat[np.argsort(pidx.ravel()[flat], kind="stable")]
    p_of = pidx.ravel()[order]
    r_of = rows.ravel()[order]
    grp = p_of * T + r_of                       # contiguous ascending groups
    counts = np.bincount(grp, minlength=P * T).reshape(P, T)
    Mp = int(counts.max(initial=1))
    Mp = max(pad_mp_to, ((Mp + pad_mp_to - 1) // pad_mp_to) * pad_mp_to)

    # slot within the (partition, row) cell: global position minus the
    # cell's start (the exclusive cumulative count of earlier cells)
    start = np.concatenate(([0], np.cumsum(counts.ravel())))[grp]
    slot = np.arange(order.size) - start

    px = np.zeros((P, T, Mp), np.float32)
    py = np.zeros((P, T, Mp), np.float32)
    pt = np.zeros((P, T, Mp), np.float32)
    pv = np.zeros((P, T, Mp), bool)
    px[p_of, r_of, slot] = x.ravel()[order]
    py[p_of, r_of, slot] = y.ravel()[order]
    pt[p_of, r_of, slot] = t.ravel()[order]
    pv[p_of, r_of, slot] = True

    finite_lo = np.where(np.isfinite(edges[:-1]), edges[:-1],
                         t[v].min() - 1.0)
    finite_hi = np.where(np.isfinite(edges[1:]), edges[1:], t[v].max() + 1.0)
    ranges = np.stack([finite_lo, finite_hi], axis=1).astype(np.float32)

    return PartitionedBatch(
        x=jnp.asarray(px), y=jnp.asarray(py), t=jnp.asarray(pt),
        valid=jnp.asarray(pv), traj_id=batch.traj_id,
        ranges=jnp.asarray(ranges))
