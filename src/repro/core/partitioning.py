"""Temporal equi-depth partitioning (the paper's Repartitioning phase).

Host-side preprocessing, done once per dataset (paper Sec. 4.2): build an
equi-depth histogram over the temporal dimension (every bin holds ~the same
number of points — the Hadoop InputSampler/TotalOrderPartitioner analogue),
then lay the points out *row-aligned*: partition p holds, for every global
trajectory row r, the points of r falling in p's time range, padded to
``Mp``.  Row alignment is what turns the MapReduce group-by-trajectory
shuffle into a single static ``all_to_all`` (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import TrajectoryBatch
from repro.utils.tree import pytree_dataclass

import jax.numpy as jnp


@pytree_dataclass
class PartitionedBatch:
    """Row-aligned temporal partitions: ``[P, T, Mp]`` point slabs.

    ``edges`` / ``src_m`` record the layout that produced the slabs (the
    cut edges, float64 so boundary classification survives a round-trip,
    and each slot's source column in the ``[T, M]`` batch).  They are
    host-side numpy arrays, never traced; ``None`` on hand-built batches
    (e.g. dry-run shape structs) — elastic resume / repartitioning
    require them.
    """

    x: jnp.ndarray       # [P, T, Mp] float32
    y: jnp.ndarray       # [P, T, Mp]
    t: jnp.ndarray       # [P, T, Mp]
    valid: jnp.ndarray   # [P, T, Mp] bool
    traj_id: jnp.ndarray  # [T] int32 global ids (-1 padding rows)
    ranges: jnp.ndarray  # [P, 2] float32 (t_lo, t_hi) per partition
    edges: np.ndarray | None = None   # [P+1] float64 cut edges (±inf outer)
    src_m: np.ndarray | None = None   # [P, T, Mp] int32 source column (-1 pad)

    @property
    def num_partitions(self) -> int:
        return self.x.shape[0]


def _float_order_bits(i: np.ndarray) -> np.ndarray:
    """Monotone involution on float64 *bit patterns*: IEEE-754 total order.

    ``key = bits ^ ((bits >> 63) & 0x7FF...F)`` sorts int64 keys exactly
    like the floats they encode.  The xor mask never touches the sign
    bit, so applying the map twice is the identity: the same function
    decodes keys back to bit patterns.  NB the total order gives -0.0 and
    +0.0 *distinct* keys (-1 and 0) while ``nextafter`` treats them as
    one value — ``_float_rank`` collapses that pair.
    """
    i = np.asarray(i, np.int64)
    return i ^ ((i >> 63) & np.int64(0x7FFFFFFFFFFFFFFF))


def _float_rank(v: np.ndarray) -> np.ndarray:
    """float64 -> int64 rank with ``np.nextafter(x, inf) == rank(x) + 1``
    for every ``x < inf`` — which turns the duplicate-edge bump loop into
    one ``np.maximum.accumulate``.  Built from the total-order key by
    merging the two zero keys (ranks are the key shifted up by one on the
    negative side), since ``nextafter(-0.0, inf)`` is the smallest
    subnormal, not +0.0.  ``_rank_float`` inverts (the zero class decodes
    to +0.0, == -0.0 under float comparison)."""
    key = _float_order_bits(np.asarray(v, np.float64).view(np.int64))
    return key + (key < 0)


def _rank_float(rank: np.ndarray) -> np.ndarray:
    key = np.where(rank >= 0, rank, rank - 1)
    return _float_order_bits(key).view(np.float64)


def equi_depth_edges(times: np.ndarray, P: int,
                     sample: int | None = 100_000,
                     seed: int = 0) -> np.ndarray:
    """Equi-depth bin edges from a sample of the valid timestamps."""
    times = np.asarray(times).ravel()
    if sample is not None and times.size > sample:
        rng = np.random.default_rng(seed)
        times = rng.choice(times, size=sample, replace=False)
    qs = np.quantile(times, np.linspace(0.0, 1.0, P + 1))
    qs[0], qs[-1] = -np.inf, np.inf
    # guard against duplicate edges on highly skewed data: the sequential
    # rule r[i] = max(qs[i], nextafter(r[i-1])) is, in rank space
    # (nextafter == +1), the scan r[i] - i = max_{j<=i}(rank[j] - j) — one
    # maximum.accumulate instead of the per-edge Python loop (equality
    # with the loop, under float comparison, is pinned by
    # tests/test_partition.py, -0.0/subnormal edges included).
    rank = _float_rank(qs[:P])
    idx = np.arange(P, dtype=np.int64)
    qs[:P] = _rank_float(np.maximum.accumulate(rank - idx) + idx)
    return qs.astype(np.float64)


def _layout_fields(t: np.ndarray, valid: np.ndarray, edges: np.ndarray,
                   P: int):
    """The deterministic (row, column) -> (partition, slot) map.

    One argsort-by-(partition, row, time-position) + scatter instead of
    the O(P*T) per-cell np.nonzero double loop (equality with the loop
    version is pinned by tests/test_partition.py).  Valid flat indices
    are already (row, m)-ordered, so a stable sort by partition alone
    yields (p, r, m) order — m order is what the loop's np.nonzero
    produced per cell.  Returns ``(order, p_of, r_of, slot, counts)``
    over the valid points; ``counts`` is the ``[P, T]`` cell histogram.
    """
    T, M = t.shape
    pidx = np.searchsorted(edges, t, side="right") - 1
    pidx = np.clip(pidx, 0, P - 1)
    pidx = np.where(valid, pidx, -1)
    rows = np.broadcast_to(np.arange(T)[:, None], (T, M))
    flat = np.nonzero(valid.ravel())[0]
    order = flat[np.argsort(pidx.ravel()[flat], kind="stable")]
    p_of = pidx.ravel()[order]
    r_of = rows.ravel()[order]
    grp = p_of * T + r_of                       # contiguous ascending groups
    counts = np.bincount(grp, minlength=P * T).reshape(P, T)
    # slot within the (partition, row) cell: global position minus the
    # cell's start (the exclusive cumulative count of earlier cells)
    start = np.concatenate(([0], np.cumsum(counts.ravel())))[grp]
    slot = np.arange(order.size) - start
    return order, p_of, r_of, slot, counts


def _pad_mp(counts: np.ndarray, pad_mp_to: int) -> int:
    Mp = int(counts.max(initial=1))
    return max(pad_mp_to, ((Mp + pad_mp_to - 1) // pad_mp_to) * pad_mp_to)


def partition_batch(batch: TrajectoryBatch, P: int, *, pad_mp_to: int = 8,
                    sample: int | None = 100_000) -> PartitionedBatch:
    """Split a TrajectoryBatch into P row-aligned temporal partitions."""
    x = np.asarray(batch.x)
    y = np.asarray(batch.y)
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    T, M = x.shape

    edges = equi_depth_edges(t[v], P, sample=sample)
    return _scatter_batch(x, y, t, v, batch.traj_id, edges, P,
                          pad_mp_to=pad_mp_to)


def _scatter_batch(x, y, t, v, traj_id, edges, P, *,
                   pad_mp_to: int = 8) -> PartitionedBatch:
    """Scatter global ``[T, M]`` point arrays into the row-aligned layout
    defined by ``edges`` (the shared core of :func:`partition_batch` and
    :func:`repartition_batch`)."""
    T, M = x.shape
    order, p_of, r_of, slot, counts = _layout_fields(t, v, edges, P)
    Mp = _pad_mp(counts, pad_mp_to)

    px = np.zeros((P, T, Mp), np.float32)
    py = np.zeros((P, T, Mp), np.float32)
    pt = np.zeros((P, T, Mp), np.float32)
    pv = np.zeros((P, T, Mp), bool)
    src_m = np.full((P, T, Mp), -1, np.int32)
    px[p_of, r_of, slot] = x.ravel()[order]
    py[p_of, r_of, slot] = y.ravel()[order]
    pt[p_of, r_of, slot] = t.ravel()[order]
    pv[p_of, r_of, slot] = True
    src_m[p_of, r_of, slot] = order - r_of * M

    finite_lo = np.where(np.isfinite(edges[:-1]), edges[:-1],
                         t[v].min() - 1.0)
    finite_hi = np.where(np.isfinite(edges[1:]), edges[1:], t[v].max() + 1.0)
    ranges = np.stack([finite_lo, finite_hi], axis=1).astype(np.float32)

    return PartitionedBatch(
        x=jnp.asarray(px), y=jnp.asarray(py), t=jnp.asarray(pt),
        valid=jnp.asarray(pv), traj_id=traj_id,
        ranges=jnp.asarray(ranges),
        edges=np.asarray(edges, np.float64), src_m=src_m)


# ===================================================================== #
# canonical global form: gather / repartition (DESIGN.md §11)           #
# ===================================================================== #
#
# Every per-point stage leaf is laid out ``[P, T, Mp, ...]`` by the same
# deterministic (row, column) -> (partition, slot) map partition_batch
# scatters with, so folding a leaf back to global ``[T, M, ...]`` point
# space — and re-cutting it for a different P or different edges — needs
# only ``(t, valid, edges)``.  That triple is the *canonical layout key*
# a checkpoint records (``meta/*`` leaves in repro.run.resilient), and
# PointLayout is its executable form.
#
# Two leaf kinds exist:
#
# * ``kind="point"`` — values ride with their point (vote, packed TSA2
#   masks, labels, join best_w).  Gather/scatter permute positions only.
# * ``kind="cand_idx"`` — values *index* the join's candidate halo slab
#   ``[own | p-1 | p+1]`` (3*Mp columns, zeros past the edge partitions,
#   per core.distributed._nbr).  Translation goes through the candidate
#   point's global identity: slab column -> (partition, slot) -> global
#   column on gather, and the inverse on scatter.  A candidate outside
#   the new layout's halo maps to column 0 — only reachable for entries
#   whose join weight is 0 (a weight > 0 pair is found identically by a
#   straight-through run at the new layout, which requires the candidate
#   inside its halo), and 0-weight entries are bit-inert downstream.


@dataclasses.dataclass(frozen=True)
class PointLayout:
    """The (row, column) -> (partition, slot) map of one row-aligned
    temporal layout, recomputable from ``(t, valid, edges)`` alone."""

    edges: np.ndarray    # [P+1] float64
    t: np.ndarray        # [T, M] global timestamps (float32)
    valid: np.ndarray    # [T, M] bool
    Mp: int
    p_of: np.ndarray     # [n_valid] partition per point (layout order)
    r_of: np.ndarray     # [n_valid] row per point
    m_of: np.ndarray     # [n_valid] global column per point
    slot: np.ndarray     # [n_valid] slot within the (p, r) cell
    src_m: np.ndarray    # [P, T, Mp] int32 inverse map (-1 padding)

    @property
    def P(self) -> int:
        return len(self.edges) - 1

    @property
    def T(self) -> int:
        return self.t.shape[0]

    @property
    def M(self) -> int:
        return self.t.shape[1]

    @classmethod
    def from_global(cls, t, valid, edges, *, Mp: int | None = None,
                    pad_mp_to: int = 8) -> "PointLayout":
        t = np.asarray(t, np.float32)
        valid = np.asarray(valid, bool)
        edges = np.asarray(edges, np.float64)
        P = len(edges) - 1
        T, M = t.shape
        order, p_of, r_of, slot, counts = _layout_fields(t, valid, edges, P)
        if Mp is None:
            Mp = _pad_mp(counts, pad_mp_to)
        m_of = order - r_of * M
        src_m = np.full((P, T, Mp), -1, np.int32)
        src_m[p_of, r_of, slot] = m_of
        return cls(edges=edges, t=t, valid=valid, Mp=int(Mp), p_of=p_of,
                   r_of=r_of, m_of=m_of, slot=slot, src_m=src_m)

    @classmethod
    def from_parts(cls, parts: PartitionedBatch) -> "PointLayout":
        """Layout of a ``partition_batch``-produced batch (requires the
        recorded ``edges``/``src_m``)."""
        if parts.edges is None or parts.src_m is None:
            raise ValueError(
                "PartitionedBatch carries no layout record (edges/src_m "
                "are None): rebuild it with repro.core.partitioning."
                "partition_batch to enable gather/repartition")
        src = np.asarray(parts.src_m)
        pt = np.asarray(parts.t)
        pv = np.asarray(parts.valid)
        P, T, Mp = src.shape
        M = int(src.max(initial=0)) + 1
        t = np.zeros((T, M), np.float32)
        valid = np.zeros((T, M), bool)
        p, r, s = np.nonzero(pv)
        t[r, src[p, r, s]] = pt[p, r, s]
        valid[r, src[p, r, s]] = True
        return cls.from_global(t, valid, parts.edges, Mp=Mp)

    # ------------------------------------------------------------ queries
    def same_points(self, other: "PointLayout") -> bool:
        return (self.t.shape == other.t.shape
                and np.array_equal(self.valid, other.valid)
                and np.array_equal(self.t[self.valid],
                                   other.t[other.valid]))

    def same_layout(self, other: "PointLayout") -> bool:
        return (self.same_points(other) and self.Mp == other.Mp
                and np.array_equal(self.edges, other.edges))

    # ------------------------------------------------- point-value leaves
    def gather(self, leaf) -> np.ndarray:
        """``[P, T, Mp, ...]`` partitioned leaf -> global ``[T, M, ...]``
        (zeros at invalid positions)."""
        leaf = np.asarray(leaf)
        out = np.zeros((self.T, self.M) + leaf.shape[3:], leaf.dtype)
        out[self.r_of, self.m_of] = leaf[self.p_of, self.r_of, self.slot]
        return out

    def scatter(self, glob) -> np.ndarray:
        """Global ``[T, M, ...]`` -> this layout's ``[P, T, Mp, ...]``."""
        glob = np.asarray(glob)
        out = np.zeros((self.P, self.T, self.Mp) + glob.shape[2:],
                       glob.dtype)
        out[self.p_of, self.r_of, self.slot] = glob[self.r_of, self.m_of]
        return out

    # ----------------------------------------- halo-slab candidate indices
    def gather_cand_idx(self, leaf) -> np.ndarray:
        """``[P, T, Mp, ...]`` leaf of slab column indices -> global
        candidate columns (−1 where the slab position holds padding)."""
        leaf = np.asarray(leaf)
        vals = leaf[self.p_of, self.r_of, self.slot]       # [n, ...]
        block = vals // self.Mp                # 0 own, 1 p-1, 2 p+1
        off = np.where(block == 1, -1, np.where(block == 2, 1, 0))
        q = self.p_of.reshape((-1,) + (1,) * (vals.ndim - 1)) + off
        s = vals % self.Mp
        rc = self._cand_rows(vals.shape)
        ok = (q >= 0) & (q < self.P)
        gm = np.where(ok, self.src_m[np.clip(q, 0, self.P - 1), rc, s], -1)
        out = np.full((self.T, self.M) + leaf.shape[3:], -1, np.int32)
        out[self.r_of, self.m_of] = gm
        return out

    def scatter_cand_idx(self, glob) -> np.ndarray:
        """Global candidate columns -> this layout's slab indices.
        Out-of-halo / invalid candidates map to column 0 (bit-inert:
        their join weight is 0)."""
        glob = np.asarray(glob)
        vals = glob[self.r_of, self.m_of]                  # [n, ...]
        pmap, smap = self._point_ps()
        rc = self._cand_rows(vals.shape)
        ok = vals >= 0
        vc = np.clip(vals, 0, self.M - 1)
        q = np.where(ok, pmap[rc, vc], -1)
        s = smap[rc, vc]
        d = q - self.p_of.reshape((-1,) + (1,) * (vals.ndim - 1))
        j = np.where(d == 0, s,
                     np.where(d == -1, self.Mp + s,
                              np.where(d == 1, 2 * self.Mp + s, 0)))
        j = np.where(ok & (q >= 0), j, 0)
        out = np.zeros((self.P, self.T, self.Mp) + glob.shape[2:],
                       np.int32)
        out[self.p_of, self.r_of, self.slot] = j.astype(np.int32)
        return out

    def _cand_rows(self, shape):
        """Candidate-row index grid for a cube's trailing ``[..., T]``
        axis (the join cube's last axis enumerates global rows)."""
        if len(shape) < 2 or shape[-1] != self.T:
            raise ValueError(
                f"cand_idx leaf trailing shape {shape[1:]} does not end "
                f"in the global row count T={self.T}")
        rc = np.arange(self.T)
        return np.broadcast_to(rc, shape)

    def _point_ps(self):
        """Inverse maps ``[T, M] -> partition / slot`` (−1 invalid)."""
        pmap = np.full((self.T, self.M), -1, np.int32)
        smap = np.zeros((self.T, self.M), np.int32)
        pmap[self.r_of, self.m_of] = self.p_of
        smap[self.r_of, self.m_of] = self.slot
        return pmap, smap


def gather_global(leaf, layout: PointLayout, *,
                  kind: str = "point") -> np.ndarray:
    """Fold one per-partition ``[P, T, Mp, ...]`` stage leaf to the
    canonical global ``[T, M, ...]`` point space (see module comment)."""
    if kind == "point":
        return layout.gather(leaf)
    if kind == "cand_idx":
        return layout.gather_cand_idx(leaf)
    raise ValueError(f"kind={kind!r}: expected 'point' or 'cand_idx'")


def repartition(leaf, old: PointLayout, new: PointLayout, *,
                kind: str = "point") -> np.ndarray:
    """Re-cut one stage leaf from ``old``'s layout to ``new``'s —
    gather to global point space, scatter at the new edges/P/Mp."""
    if not old.same_points(new):
        raise ValueError("repartition across different point sets: the "
                         "checkpoint and the current batch disagree on "
                         "(t, valid)")
    if kind == "point":
        return new.scatter(old.gather(leaf))
    if kind == "cand_idx":
        return new.scatter_cand_idx(old.gather_cand_idx(leaf))
    raise ValueError(f"kind={kind!r}: expected 'point' or 'cand_idx'")


def repartition_batch(parts: PartitionedBatch, edges,
                      *, pad_mp_to: int = 8) -> PartitionedBatch:
    """Re-cut a partitioned batch at explicit ``edges`` (same P or a new
    one) — the apply path of straggler-driven rebalancing and of
    adopting a checkpoint's post-rebalance cut on resume."""
    layout = PointLayout.from_parts(parts)
    x = layout.gather(parts.x)
    y = layout.gather(parts.y)
    t = layout.gather(parts.t)
    edges = np.asarray(edges, np.float64)
    return _scatter_batch(x, y, t, layout.valid, parts.traj_id, edges,
                          len(edges) - 1, pad_mp_to=pad_mp_to)
