"""Shared evaluation metrics: RMSE (Sec. 6.2) and ground-truth scoring.

The RMSE used in the paper's Fig. 7 is "a measure of intra-cluster distance
between the representatives and the cluster members".  We compute it
geometrically and identically for every method: for each member *point*, the
distance to the nearest representative point (within the eps_t temporal
window when timestamps exist, spatial-nearest otherwise), RMS-aggregated.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import TrajectoryBatch


def _slot_points(batch: TrajectoryBatch, sub_local: np.ndarray,
                 slot: int, max_subs: int) -> np.ndarray:
    r, k = divmod(slot, max_subs)
    sel = (sub_local[r] == k)
    x = np.asarray(batch.x)[r][sel]
    y = np.asarray(batch.y)[r][sel]
    t = np.asarray(batch.t)[r][sel]
    return np.stack([x, y, t], axis=1)


def rmse_subtraj(batch: TrajectoryBatch, sub_local: np.ndarray,
                 member_of: np.ndarray, is_rep: np.ndarray,
                 max_subs: int, eps_t: float | None = None) -> float:
    """Point-level intra-cluster RMSE for subtrajectory clusterings."""
    sq, n = 0.0, 0
    for s in range(len(member_of)):
        rep = member_of[s]
        if rep < 0 or is_rep[s] or rep == s:
            continue
        mp = _slot_points(batch, sub_local, s, max_subs)
        rp = _slot_points(batch, sub_local, int(rep), max_subs)
        if len(mp) == 0 or len(rp) == 0:
            continue
        d_sp = np.hypot(mp[:, None, 0] - rp[None, :, 0],
                        mp[:, None, 1] - rp[None, :, 1])
        if eps_t is not None:
            d_t = np.abs(mp[:, None, 2] - rp[None, :, 2])
            masked = np.where(d_t <= eps_t, d_sp, np.inf)
            best = np.min(masked, axis=1)
            best = np.where(np.isfinite(best), best, np.min(d_sp, axis=1))
        else:
            best = np.min(d_sp, axis=1)
        sq += float(np.sum(best ** 2))
        n += len(best)
    return float(np.sqrt(sq / n)) if n else 0.0


def rmse_sim_based(sim: np.ndarray, member_of: np.ndarray,
                   is_rep: np.ndarray, eps_sp: float) -> float:
    """The paper's RMSE ('equivalent to SSCR', Sec. 6.2): via Lemma 1 the
    mean member->representative distance is ``eps_sp * (1 - Sim)``; RMS over
    all cluster members.  Lower is better/tighter."""
    sq, n = 0.0, 0
    for s in range(len(member_of)):
        rep = member_of[s]
        if rep < 0 or is_rep[s]:
            continue
        d = eps_sp * (1.0 - float(np.clip(sim[s, rep], 0.0, 1.0)))
        sq += d * d
        n += 1
    return float(np.sqrt(sq / n)) if n else 0.0


def rmse_traclus(res: dict, eps_sp: float | None = None) -> float:
    """RMSE for TraClus: segment endpoints/midpoint vs representative
    polyline.  When ``eps_sp`` is given, distances are clipped at eps_sp so
    the value is on the same scale as ``rmse_sim_based``."""
    labels = res["labels"]
    sq, n = 0.0, 0
    for i, lab in enumerate(labels):
        if lab < 0 or lab >= len(res["reps"]):
            continue
        rep = res["reps"][lab]
        if rep is None or len(rep) == 0:
            continue
        s, e = res["segments"][i]
        for p in (s, 0.5 * (s + e), e):
            d = np.min(np.hypot(rep[:, 0] - p[0], rep[:, 1] - p[1]))
            if eps_sp is not None:
                d = min(d, eps_sp)
            sq += float(d ** 2)
            n += 1
    return float(np.sqrt(sq / n)) if n else 0.0


def leg_labels(batch: TrajectoryBatch, sub_local: np.ndarray,
               origin_of_traj: np.ndarray, dest_of_traj: np.ndarray,
               t_split: float, max_subs: int) -> dict[int, tuple[str, str]]:
    """Ground-truth label per subtraj slot for the figure-1 scenario.

    A subtrajectory mostly before the midpoint belongs to the *origin* leg
    (e.g. A->O, shared by all A-* routes); mostly after, to the
    *destination* leg (O->B etc.) — the clusters of Fig. 1(b)/Sec. 6.2.
    """
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    out: dict[int, tuple[str, str]] = {}
    T = t.shape[0]
    for r in range(T):
        for k in range(max_subs):
            sel = (sub_local[r] == k) & v[r]
            if not sel.any():
                continue
            if t[r][sel].mean() < t_split:
                out[r * max_subs + k] = ("O", str(origin_of_traj[r]))
            else:
                out[r * max_subs + k] = ("D", str(dest_of_traj[r]))
    return out


def cluster_purity(assign: dict[int, int], truth: dict[int, tuple]) -> float:
    """Weighted purity of clusters w.r.t. ground-truth labels."""
    from collections import Counter, defaultdict
    groups = defaultdict(list)
    for s, c in assign.items():
        if s in truth:
            groups[c].append(truth[s])
    total, pure = 0, 0
    for _, labs in groups.items():
        total += len(labs)
        pure += Counter(labs).most_common(1)[0][1]
    return pure / total if total else 0.0


def pairwise_f1(assign: dict[int, int], truth: dict[int, tuple]) -> float:
    """Pair-counting F-measure between clustering and ground truth."""
    items = [s for s in assign if s in truth]
    tp = fp = fn = 0
    for i in range(len(items)):
        for j in range(i + 1, len(items)):
            a, b = items[i], items[j]
            same_c = assign[a] == assign[b]
            same_t = truth[a] == truth[b]
            tp += same_c and same_t
            fp += same_c and not same_t
            fn += same_t and not same_c
    prec = tp / (tp + fp) if tp + fp else 1.0
    rec = tp / (tp + fn) if tp + fn else 1.0
    return 2 * prec * rec / (prec + rec) if prec + rec else 0.0
