"""Monoid sliding-window engine — one audited windowing primitive for the
whole pipeline (DESIGN.md §7).

Every windowed quantity TSA1/TSA2 need is a reduction of a per-position
signal over the inclusive *offset* window ``[n + lo, n + hi]`` along the
point axis (axis 1), with out-of-range positions contributing the monoid
identity:

    window means   (TSA1)  -> "sum"  over [n-w, n-1] and [n, n+w-1]
    local-max test (both)  -> "max"  over [n-w+1, n-1] and [n+1, n+w-1]
    set unions     (TSA2)  -> "or"   over [n-w, n-1] and [n, n+w-1],
                              directly on bit-packed uint32 words

``sliding_reduce`` dispatches on the algebra of the operator:

* ``"sum"`` has a group inverse, so the window is two reads of one
  prefix-sum array (cumsum + static shifts; no gather).
* ``"max"`` / ``"or"`` are associative **and idempotent**, which is what
  makes the two-pass block-scan trick exact: any window of length ``L``
  spans at most two ``L``-aligned blocks, so its reduction is
  ``op(block-suffix-scan at the window start, block-prefix-scan at the
  window end)`` — and when the window happens to sit inside a single
  block the two reads overlap, which idempotency absorbs (``a op a = a``).
  Sums cannot use this (overlap double-counts), hence the dispatch.

For ``"or"`` the trick applies verbatim to packed uint32 words: bitwise OR
over words *is* per-bit OR, so a windowed set-union over ``[T, M, W]``
masks costs O(M·W) word ops — no 32x bit-plane expansion, no serial loop
over W (the win ``repro.core.segmentation`` TSA2 is built on).

All entry points preserve trailing dims (``sig`` may be ``[T, M]`` or
``[T, M, W]``); windows always slide along axis 1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_OPS = ("sum", "max", "or")


def _identity_scalar(dtype, op: str):
    if op in ("sum", "or"):
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(-jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).min, dtype)


def _fill(sig: jnp.ndarray, n: int, ident) -> jnp.ndarray:
    """[T, n, *rest] block of the identity, matching ``sig``'s layout."""
    shape = (sig.shape[0], n) + sig.shape[2:]
    return jnp.full(shape, ident, sig.dtype)


def _shift(x: jnp.ndarray, k: int, ident, axis: int = 1) -> jnp.ndarray:
    """``x`` shifted so position ``n`` reads ``x[n - k]`` along ``axis``
    (identity off-edge).  ``k`` is a static Python int."""
    if k == 0:
        return x
    n = x.shape[axis]
    kk = min(abs(k), n)
    idx_lo = [slice(None)] * x.ndim
    idx_hi = [slice(None)] * x.ndim
    idx_lo[axis] = slice(0, kk)
    pad = jnp.full_like(x[tuple(idx_lo)], ident)
    if k > 0:
        idx_hi[axis] = slice(0, n - kk)
        return jnp.concatenate([pad, x[tuple(idx_hi)]], axis=axis)
    idx_hi[axis] = slice(kk, None)
    return jnp.concatenate([x[tuple(idx_hi)], pad], axis=axis)


def _prefix_at(csum: jnp.ndarray, k: int) -> jnp.ndarray:
    """``csum[:, n + k]`` with 0 below index 0 and the last column above
    ``M - 1`` (a prefix sum saturates past the end)."""
    M = csum.shape[1]
    if k == 0:
        return csum
    if k < 0:
        return _shift(csum, -k, 0)
    kk = min(k, M)
    edge = jnp.broadcast_to(csum[:, M - 1:M], csum[:, :kk].shape)
    return jnp.concatenate([csum[:, kk:], edge], axis=1)


def _block_scan(blk: jnp.ndarray, op: str, reverse: bool) -> jnp.ndarray:
    """Inclusive scan along axis 2 of ``[T, nb, L, *rest]`` blocks."""
    if op == "max":
        return jax.lax.cummax(blk, axis=2, reverse=reverse)
    # "or": Hillis–Steele doubling — log2(L) static shift+or steps
    L = blk.shape[2]
    sh = 1
    while sh < L:
        blk = blk | _shift(blk, sh if not reverse else -sh, 0, axis=2)
        sh *= 2
    return blk


def sliding_reduce(sig: jnp.ndarray, lo: int, hi: int, op: str) -> jnp.ndarray:
    """Reduce ``sig`` over the inclusive offset window ``[n+lo, n+hi]``.

    ``lo``/``hi`` are static Python ints (either sign); positions outside
    ``[0, M)`` contribute the identity (0 for sum/or, -inf for max).  An
    empty window (``lo > hi``) returns the identity everywhere.  Output
    shape == input shape; windows slide along axis 1.
    """
    if op not in _OPS:
        raise ValueError(f"unknown window op {op!r}")
    M = sig.shape[1]
    ident = _identity_scalar(sig.dtype, op)
    if lo > hi:
        return jnp.full_like(sig, ident)

    if op == "sum":
        csum = jnp.cumsum(sig, axis=1)
        return _prefix_at(csum, hi) - _prefix_at(csum, lo - 1)

    # idempotent two-pass block scan.  First rebase: the window [n+lo,
    # n+hi] of length L is the trailing window [m-L+1, m] read at
    # m = n + hi, so compute incl[m] = reduce(sig[m-L+1 .. m]) once and
    # shift.  incl needs indices up to M-1+hi when hi > 0 -> extend with
    # the identity (exact: identity is absorbing for the tail).
    L = hi - lo + 1
    pad_r = max(hi, 0)
    y = sig if pad_r == 0 else jnp.concatenate(
        [sig, _fill(sig, pad_r, ident)], axis=1)
    Mx = M + pad_r
    nb = -(-Mx // L)
    if nb * L > Mx:
        y = jnp.concatenate([y, _fill(sig, nb * L - Mx, ident)], axis=1)
    blk = y.reshape(y.shape[0], nb, L, *y.shape[2:])
    pre = _block_scan(blk, op, reverse=False).reshape(y.shape)
    suf = _block_scan(blk, op, reverse=True).reshape(y.shape)
    # any L-window spans <= two L-aligned blocks: suffix of the first at
    # the window start (a static right-shift by L-1) op prefix of the
    # second at the window end.  Single-block windows read both scans over
    # overlapping ranges — exact only because op is idempotent.
    combine = jnp.maximum if op == "max" else jnp.bitwise_or
    incl = combine(pre, _shift(suf, L - 1, ident))
    if hi >= 0:
        return incl[:, hi:hi + M]
    return _shift(incl[:, :M], -hi, ident)


def window_pair(sig: jnp.ndarray, w: int, op: str):
    """The adjacent window pair every TSA algorithm slides:
    ``W1 = [n-w, n-1]`` and ``W2 = [n, n+w-1]``.  Returns ``(r1, r2)``."""
    return (sliding_reduce(sig, -w, -1, op),
            sliding_reduce(sig, 0, w - 1, op))


# ---------------------------------------------------------------------------
# Packed-word helpers — the one bit-packing implementation for the whole
# pipeline.  TSA2 neighbor sets travel as uint32 words everywhere (the
# packed-word engine above, the fused join epilogues, the distributed
# all_gather payload); packing previously lived inline at each call site
# (``voting.neighbor_mask_packed``, ``distributed._pack_bits``), which is
# exactly how bit-layout drift starts.  Both now call here (bit-equality
# pinned in tests/test_windows.py).
# ---------------------------------------------------------------------------


def pack_bits(b: jnp.ndarray) -> jnp.ndarray:
    """[..., C] bool -> [..., ceil(C/32)] uint32, bit c of word c // 32."""
    C = b.shape[-1]
    W = -(-C // 32)
    pad = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, W * 32 - C)])
    bits = pad.reshape(*b.shape[:-1], W, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jnp.ndarray, C: int | None = None) -> jnp.ndarray:
    """[..., W] uint32 -> [..., C] bool (inverse of ``pack_bits``)."""
    W = words.shape[-1]
    bits = ((words[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & 1)
    out = bits.astype(bool).reshape(*words.shape[:-1], W * 32)
    return out if C is None else out[..., :C]
