"""Subtrajectory join (Problem 1 / DTJ) — pure-jnp reference path.

The dense formulation: for every reference point ``(r, m)`` and every candidate
trajectory ``c``, find the candidate point inside the spatiotemporal cylinder
(radius ``eps_sp``, half-height ``eps_t``) with the highest proximity weight
``1 - d_s / eps_sp``.  This is exactly the quantity DTJ's Refine step feeds to
the voting (Eq. 4) and to the weighted-LCSS similarity (Eq. 2): the single
matching point ``s_k`` of trajectory ``s`` for point ``r_i``.

The Pallas kernel in ``repro.kernels.stjoin`` computes the same contraction
with explicit VMEM tiling; ``tests/test_kernels_stjoin.py`` asserts allclose
against this reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import JoinResult, TrajectoryBatch


def best_match_join(
    ref: TrajectoryBatch,
    cand: TrajectoryBatch,
    eps_sp: float | jnp.ndarray,
    eps_t: float | jnp.ndarray,
    *,
    exclude_same_id: bool = True,
    prune_mask: jnp.ndarray | None = None,
) -> JoinResult:
    """Dense best-match spatiotemporal join (reference implementation).

    Returns weight/index tensors of shape ``[T_ref, M_ref, T_cand]``.
    Memory is O(T*M*C) — fine for tests; the distributed pipeline streams
    candidate tiles through the Pallas kernel instead.

    ``prune_mask``: optional [T_ref, T_cand] bool from the spatiotemporal
    index (``repro.index.grid.trajectory_pair_mask``); pairs masked False
    are skipped.  A conservative mask leaves the result unchanged.
    """
    # [T, M, 1, 1] vs [1, 1, C, Mc] broadcasting
    dx = ref.x[:, :, None, None] - cand.x[None, None, :, :]
    dy = ref.y[:, :, None, None] - cand.y[None, None, :, :]
    dt = jnp.abs(ref.t[:, :, None, None] - cand.t[None, None, :, :])
    d_sp = jnp.sqrt(dx * dx + dy * dy)

    ok = (d_sp <= eps_sp) & (dt <= eps_t)
    ok &= ref.valid[:, :, None, None] & cand.valid[None, None, :, :]
    if exclude_same_id:
        same = ref.traj_id[:, None] == cand.traj_id[None, :]      # [T, C]
        ok &= ~same[:, None, :, None]
    if prune_mask is not None:
        ok &= prune_mask[:, None, :, None]

    w = jnp.where(ok, 1.0 - d_sp / eps_sp, 0.0)                   # [T, M, C, Mc]
    best_w = jnp.max(w, axis=-1)                                  # [T, M, C]
    best_idx = jnp.where(
        best_w > 0.0, jnp.argmax(w, axis=-1).astype(jnp.int32), -1)
    return JoinResult(best_w=best_w, best_idx=best_idx)


def filter_delta_t(join: JoinResult, ref_t: jnp.ndarray,
                   delta_t: float | jnp.ndarray) -> JoinResult:
    """DTJ Refine: drop matches whose common subsequence lasts < ``delta_t``.

    For each (ref trajectory r, candidate c) pair, the matched reference
    points form runs of consecutive samples; a run whose time extent
    ``t[last] - t[first]`` is below ``delta_t`` is discarded (the paper's
    condition (a) of Problem 1: both matched subtrajectories must span at
    least ``delta_t``).  ``ref_t``: [T, M] reference point times.
    """
    T, M, C = join.best_w.shape
    matched = join.best_w > 0.0                                   # [T, M, C]
    matched_mc = jnp.moveaxis(matched, 1, 2)                      # [T, C, M]

    # run ids: new run whenever the match indicator turns on after a gap.
    starts = matched_mc & ~jnp.pad(matched_mc, ((0, 0), (0, 0), (1, 0)))[..., :M]
    run_id = jnp.cumsum(starts, axis=-1) - 1                      # [T, C, M]
    run_id = jnp.where(matched_mc, run_id, M - 1)                 # park unmatched

    t_b = jnp.broadcast_to(ref_t[:, None, :], (T, C, M))
    big = jnp.float32(jnp.finfo(jnp.float32).max)

    flat_runs = run_id.reshape(T * C, M)
    flat_t = t_b.reshape(T * C, M)
    seg = flat_runs + (jnp.arange(T * C)[:, None] * M)            # global seg ids

    def seg_reduce(vals, fill, op):
        out = jnp.full((T * C * M,), fill, vals.dtype)
        return op(out, seg.reshape(-1), vals.reshape(-1))

    t_min = seg_reduce(jnp.where(matched_mc.reshape(T * C, M), flat_t, big),
                       big, lambda o, s, v: o.at[s].min(v))
    t_max = seg_reduce(jnp.where(matched_mc.reshape(T * C, M), flat_t, -big),
                       -big, lambda o, s, v: o.at[s].max(v))
    dur = (t_max - t_min).reshape(T, C, M)                        # per run id
    keep_run = dur >= delta_t
    keep = jnp.take_along_axis(keep_run, run_id, axis=-1) & matched_mc
    keep = jnp.moveaxis(keep, 2, 1)                               # [T, M, C]

    return JoinResult(
        best_w=jnp.where(keep, join.best_w, 0.0),
        best_idx=jnp.where(keep, join.best_idx, -1),
    )


def subtrajectory_join(ref: TrajectoryBatch, cand: TrajectoryBatch,
                       eps_sp, eps_t, delta_t=0.0, *,
                       use_index: bool = False) -> JoinResult:
    """Problem 1, end to end: cylinder join + delta_t run filtering.

    ``use_index=True`` applies the row-level spatiotemporal prune mask
    (bbox distance test per trajectory pair) before the dense sweep; the
    mask is conservative, so the output is unchanged.
    """
    prune_mask = None
    if use_index:
        from repro.index.grid import trajectory_pair_mask
        prune_mask = trajectory_pair_mask(
            ref.x, ref.y, ref.t, ref.valid,
            cand.x, cand.y, cand.t, cand.valid, eps_sp, eps_t)
    j = best_match_join(ref, cand, eps_sp, eps_t, prune_mask=prune_mask)
    dt = jnp.asarray(delta_t, jnp.float32)
    return jax.lax.cond(
        dt > 0.0, lambda jj: filter_delta_t(jj, ref.t, dt), lambda jj: jj, j)
