"""EnginePlan: the single tuned surface for every per-stage engine choice.

PRs 1-5 grew five per-stage engine switches (join mode, join kernel/index,
TSA2 kernel, clustering engine/kernel, similarity representation), each with
its own tile/block geometry, threaded separately through ``run_dsc``,
``build_dsc_program``, and the launcher CLI.  ``EnginePlan`` collapses that
surface to one frozen, hashable, JSON-serializable dataclass:

* every entry point accepts ``plan=`` (one object, one jit static key);
* the legacy flags survive as **deprecated aliases** that materialize a
  plan (``EnginePlan.from_legacy`` / ``resolve_plan``) — behavior is
  unchanged, so every pre-plan test and CI gate passes as-is;
* the autotuner (``repro.tune.autotune``) sweeps candidate plans and
  caches winners per (shape-bucket, backend, jax version); a stored plan
  round-trips through JSON (``save`` / ``load``).

Field-to-stage map (DESIGN.md §9; §§3-8 introduce each knob):

====================  =====================================================
stage                 plan fields
====================  =====================================================
join (Problem 1)      ``mode`` ("materialize" | "fused"), ``use_kernel``,
                      ``use_index``, fused tile geometry ``fused_rows`` /
                      ``fused_bc`` / ``fused_bm``
                      (``kernels.stjoin.ops.plan_fused_tiles``)
segmentation (P2)     ``seg_use_kernel`` (packed jnp engine vs the fused
                      Pallas Jaccard kernel — bit-identical cuts)
similarity (SP)       ``sim_mode`` ("dense" | "topk"), ``sim_topk`` (K),
                      ``sim_panel`` (Sb panel height); distributed-only:
                      ``sim_strategy``, ``sim_dtype``, ``sim_exchange``
                      ("allgather" barrier | "ring" streamed blocks)
comm (DESIGN.md §12)  ``halo_stream`` ("barrier" gathers every neighbor
                      slab up front | "ring" streams slabs and folds each
                      contribution as it lands), ``sim_exchange`` (above)
clustering (P3)       ``cluster_engine`` ("rounds" | "sequential"),
                      ``cluster_use_kernel``, round-kernel tiles
                      ``cluster_bu`` / ``cluster_bs``
====================  =====================================================

``None`` means "library default, resolved at run time" (e.g.
``fused_rows=None`` lets ``_fused_geometry`` pick the fat-tile default,
``sim_topk=None`` resolves to ``min(32, S)``).  Ints are concrete pins —
what the tuner writes once a sweep has measured a winner.
"""
from __future__ import annotations

import dataclasses
import json

_MODES = ("materialize", "fused")
_ENGINES = ("rounds", "sequential")
_SIM_MODES = ("dense", "topk")
_SIM_STRATEGIES = ("psum", "allgather")
_SIM_DTYPES = ("f32", "bf16")
_HALO_STREAMS = ("barrier", "ring")
_SIM_EXCHANGES = ("allgather", "ring")


@dataclasses.dataclass(frozen=True)
class EnginePlan:
    """One per-stage engine/tile configuration for the whole DSC pipeline.

    Frozen and hashable so a plan can ride directly through ``jax.jit``
    static arguments: one plan == one trace (the one-trace-per-geometry
    invariant the autotuner relies on).
    """

    # ---- join (Problem 1) -------------------------------------------------
    mode: str = "materialize"          # "materialize" | "fused"
    use_kernel: bool = False           # Pallas join kernel (materialize mode)
    use_index: bool = False            # grid candidate-tile pruning
    fused_rows: int | None = None      # fused ref-block rows (None = auto)
    fused_bc: int = 16                 # fused candidate rows per block
    fused_bm: int = 128                # fused candidate point chunk
    # ---- segmentation (Problem 2) ----------------------------------------
    seg_use_kernel: bool = False       # Pallas TSA2 Jaccard kernel
    # ---- similarity (SP relation) ----------------------------------------
    sim_mode: str = "dense"            # "dense" | "topk"
    sim_topk: int | None = None        # K of the top-K lists (None = 32)
    sim_panel: int | None = None       # panel height Sb (None = 128-snap)
    sim_strategy: str = "psum"         # distributed dense collective shape
    sim_dtype: str = "f32"             # distributed dense payload dtype
    # ---- communication schedules (distributed-only) -----------------------
    halo_stream: str = "barrier"       # join halo slabs: "barrier" | "ring"
    sim_exchange: str = "allgather"    # similarity lists: "allgather" | "ring"
    # ---- clustering (Problem 3) ------------------------------------------
    cluster_engine: str = "rounds"     # "rounds" | "sequential"
    cluster_use_kernel: bool = False   # Pallas round-scan/claim-max kernels
    cluster_bu: int = 8                # row tile of the cluster kernels
    cluster_bs: int = 128              # column tile of the cluster kernels

    # ------------------------------------------------------------------ api
    def validate(self) -> "EnginePlan":
        """Raise ``ValueError`` on any inconsistent field; return ``self``.

        The error messages for the three engine selectors are the exact
        strings the pre-plan entry points raised, so existing error-path
        tests keep passing unchanged.
        """
        if self.mode not in _MODES:
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.cluster_engine not in _ENGINES:
            raise ValueError(f"unknown cluster engine {self.cluster_engine!r}")
        if self.sim_mode not in _SIM_MODES:
            raise ValueError(f"unknown sim_mode {self.sim_mode!r}")
        if self.sim_strategy not in _SIM_STRATEGIES:
            raise ValueError(f"unknown sim_strategy {self.sim_strategy!r}")
        if self.sim_dtype not in _SIM_DTYPES:
            raise ValueError(f"unknown sim_dtype {self.sim_dtype!r}")
        if self.halo_stream not in _HALO_STREAMS:
            raise ValueError(f"unknown halo_stream {self.halo_stream!r}")
        if self.sim_exchange not in _SIM_EXCHANGES:
            raise ValueError(f"unknown sim_exchange {self.sim_exchange!r}")
        for name in ("fused_rows", "sim_topk", "sim_panel"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v < 1):
                raise ValueError(f"{name} must be None or a positive int, "
                                 f"got {v!r}")
        for name in ("fused_bc", "fused_bm", "cluster_bu", "cluster_bs"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be a positive int, got {v!r}")
        return self

    def replace(self, **kw) -> "EnginePlan":
        """A copy with fields replaced (validated)."""
        return dataclasses.replace(self, **kw).validate()

    @property
    def fused_tiles(self) -> tuple[int | None, int, int] | None:
        """``(rows, bc, bm)`` fused-kernel geometry, or ``None`` when every
        fused field still holds the library default — callers then pass no
        overrides, which keeps jit cache keys (and therefore traces)
        identical to the pre-plan flag surface."""
        t = (self.fused_rows, self.fused_bc, self.fused_bm)
        return None if t == (None, 16, 128) else t

    @property
    def cluster_tiles(self) -> tuple[int, int]:
        """``(bu, bs)`` tile geometry of the Pallas clustering kernels
        (``kernels.cluster.ops``); the list-tile kernels use ``bu`` as
        their row tile."""
        return (self.cluster_bu, self.cluster_bs)

    # --------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "EnginePlan":
        """Strict inverse of ``to_dict``: unknown keys raise (a stored plan
        from a future schema must fail loudly, not silently drop fields);
        missing keys take the field default."""
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(
                f"unknown EnginePlan fields {sorted(unknown)}; "
                f"known fields: {sorted(names)}")
        return cls(**d).validate()

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "EnginePlan":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "EnginePlan":
        with open(path) as f:
            return cls.from_json(f.read())

    # ------------------------------------------------------- legacy aliases
    @classmethod
    def from_legacy(cls, *, mode: str = "materialize",
                    use_kernel: bool = False, use_index: bool = False,
                    fused_tiles: tuple | None = None,
                    seg_use_kernel: bool = False,
                    cluster_engine: str = "rounds",
                    cluster_use_kernel: bool = False,
                    sim_mode: str = "dense", sim_topk: int | None = None,
                    sim_panel: int | None = None,
                    sim_strategy: str = "psum",
                    sim_dtype: str = "f32",
                    halo_stream: str = "barrier",
                    sim_exchange: str = "allgather") -> "EnginePlan":
        """Materialize a plan from the deprecated per-stage flag set.

        This is the compatibility contract: every legacy flag combination
        maps onto exactly one plan, and running that plan is behaviorally
        identical to the pre-plan entry points (pinned by
        ``tests/test_plan.py``).
        """
        rows, bc, bm = (None, 16, 128) if fused_tiles is None else fused_tiles
        return cls(mode=mode, use_kernel=use_kernel, use_index=use_index,
                   fused_rows=rows, fused_bc=bc, fused_bm=bm,
                   seg_use_kernel=seg_use_kernel,
                   cluster_engine=cluster_engine,
                   cluster_use_kernel=cluster_use_kernel,
                   sim_mode=sim_mode, sim_topk=sim_topk, sim_panel=sim_panel,
                   sim_strategy=sim_strategy,
                   sim_dtype=sim_dtype, halo_stream=halo_stream,
                   sim_exchange=sim_exchange).validate()


_LEGACY_DEFAULTS = {
    "mode": "materialize", "use_kernel": False, "use_index": False,
    "fused_tiles": None, "seg_use_kernel": False,
    "cluster_engine": "rounds", "cluster_use_kernel": False,
    "sim_mode": "dense", "sim_topk": None, "sim_panel": None,
    "sim_strategy": "psum", "sim_dtype": "f32",
    "halo_stream": "barrier", "sim_exchange": "allgather",
}


def resolve_plan(plan: EnginePlan | None = None, **legacy) -> EnginePlan:
    """The one entry-point rule: a plan, or legacy flags — never both.

    ``plan=None`` materializes a plan from the legacy flags (all current
    callers).  With an explicit plan, any legacy flag still at a
    non-default value raises: silently preferring one surface over the
    other would make ``--plan`` + a stray ``--sim-mode`` ambiguous.
    """
    unknown = set(legacy) - set(_LEGACY_DEFAULTS)
    if unknown:
        raise TypeError(f"unknown legacy plan flags {sorted(unknown)}")
    if plan is None:
        return EnginePlan.from_legacy(**legacy)
    clash = {k: v for k, v in legacy.items()
             if v != _LEGACY_DEFAULTS[k] and v is not None}
    if clash:
        raise ValueError(
            f"both plan= and legacy per-stage flags were given ({clash}); "
            "the deprecated flags only exist to materialize a plan — "
            "set the fields on the plan instead")
    return plan.validate()
