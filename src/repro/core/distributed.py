"""Distributed Subtrajectory Clustering — the paper's two MapReduce jobs as a
single jit-compiled ``shard_map`` program (Problem 4).

Mesh axes
---------
``part``  : temporal partitions (the paper's equi-depth bins).  On the
            production mesh this is the folded (pod, data) axes.
``model`` : candidate-trajectory parallelism — the best-match tensor
            ``B[point, cand_traj]`` is column-sharded; votes / similarity
            matrices are psum-reduced.  This is the scale-out lever the
            paper's per-trajectory reduce task lacks.

Phase structure (all inside ONE shard_map body — no host round-trips):

  1. JOIN        ppermute halo exchange of neighbor partition slabs,
                 best-match join (Pallas kernel or jnp ref), delta_t refine,
                 vote psum over 'model'.
  2. REGROUP     all_to_all over 'part': row-aligned partition slabs
                 [T, Mp] -> per-home-shard full trajectories [T/P, P*Mp];
                 compaction (valid-prefix) for windowed segmentation.
  3. SEGMENT     TSA1 / TSA2 on full trajectories (exactly the paper's Job 1
                 reduce); ST relation; labels scattered back via the inverse
                 all_to_all + ppermute of the label halo.
  4. SIMILARITY  per-partition scatter-add of join weights into the dense
                 SP matrix, psum over 'model'; Eq. 2 normalization.
  5. CLUSTER     Algorithm 4 per partition (thresholds resolved per
                 partition, Sec. 6.1).
  6. REFINE      all_gather over 'part' + the Algorithm 5 case-table
                 reduction -> one consistent global result, replicated.

The phases are methods on ``_DSCProgramBuilder`` so two compositions share
them verbatim: :func:`build_dsc_program` (the monolithic program above) and
:func:`build_dsc_stage_programs` (one program per checkpointable stage
boundary, the distributed half of the resilient runner
``repro.run.resilient`` — DESIGN.md §10).  Stage-k output fed to stage k+1
re-enters exactly the code the monolith would have run next, which is the
resume bit-identity argument.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import segmentation as seg_mod
from repro.core.clustering import cluster
from repro.core.geometry import filter_delta_t
from repro.core.partitioning import PartitionedBatch
from repro.core.plan import EnginePlan, resolve_plan
from repro.core.refine import refine_states
from repro.core.similarity import (build_subtraj_table_arrays, finalize_sim,
                                   finalize_sim_cols, largest_divisor,
                                   merge_topk_blocks, merge_topk_lists,
                                   sim_row_moments, topk_overflow)
from repro.core.voting import normalized_voting
from repro.core.types import (ClusteringResult, DSCParams, JoinResult,
                              SubtrajTable, TopKSim)
from repro.core.windows import pack_bits
from repro.utils.compat import shard_map as shard_map_compat
from repro.utils.tree import pytree_dataclass

# stage-state donation is best-effort: when a stage's outputs can't alias
# a donated input buffer XLA still frees it at call time (the memory win
# the resilient loop wants) — silence the per-compile nag about the
# unused alias
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


@pytree_dataclass
class DistributedDSCOutput:
    result: ClusteringResult      # [S] global, replicated
    table: SubtrajTable           # [S] global, replicated
    vote: jnp.ndarray             # [P, T, Mp] partition layout
    active: jnp.ndarray           # [P, S] subtraj-in-partition masks
    sim_diag: jnp.ndarray         # [P, 4] (mean sim>0, alpha, k, topk
                                  # overflow count) per partition


def _nbr(x, axis, shift, n):
    """Slab from the partition at distance ``shift``; zeros at the edge."""
    perm = [(i, i + shift) for i in range(n) if 0 <= i + shift < n]
    return lax.ppermute(x, axis, perm)


def _ring_gather(x, axis, n):
    """Forwarding-ring ``all_gather``: ``n - 1`` ``ppermute`` hops, each
    rank passing along the block it received last step, assembled into the
    same ``[n, ...]`` stack ``lax.all_gather`` returns.

    Pure data movement, so the result is bit-identical to the barrier
    gather — but the per-step wire payload is a constant ``1/n`` of the
    barrier payload, and because each landed block is a separate value in
    the dataflow graph the consumer's compute on block ``s`` can overlap
    the transfer of block ``s + 1`` (DESIGN.md §12)."""
    if n == 1:
        return x[None]
    r = lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, r, 0)
    buf = x
    for s in range(1, n):
        buf = lax.ppermute(buf, axis, perm)
        # after s forwarding hops the buffer holds rank (r - s)'s block
        out = lax.dynamic_update_index_in_dim(out, buf, (r - s) % n, 0)
    return out


# largest-divisor tile sizing shares one implementation with the panel
# planner (repro.core.similarity.largest_divisor)
_pick_block = largest_divisor


def run_dsc_distributed(
    parts: PartitionedBatch,
    params: DSCParams,
    mesh: Mesh,
    *,
    part_axis: str = "part",
    model_axis: str = "model",
    on_overflow: str = "raise",
    plan: EnginePlan | None = None,
    **kw,
) -> DistributedDSCOutput:
    """Compile & run the full distributed pipeline on ``mesh``.

    ``plan=`` takes one :class:`EnginePlan`; the remaining keyword
    arguments are the deprecated per-stage aliases (``use_kernel``,
    ``use_index``, ``mode``, ``sim_mode``, ... — see
    ``build_dsc_program``) that materialize a plan when none is given.

    Under ``sim_mode="topk"`` the per-partition exactness certificate is
    checked on the host and ``on_overflow`` names the policy
    (DESIGN.md §10): ``"raise"`` (default, the historical behavior)
    fails loudly; ``"widen"`` rebuilds the program with K doubled and
    reruns until the certificate holds (the fully-jitted program cannot
    widen in-graph the way ``run_dsc`` retries — the resilient runner's
    stage-level widen restarts from the checkpointed join state
    instead); ``"degrade"`` returns the truncated result, with the
    violation count recorded in ``sim_diag[:, 3]``.
    """
    if on_overflow not in ("raise", "widen", "degrade"):
        raise ValueError(f"on_overflow={on_overflow!r}: expected "
                         "'raise', 'widen', or 'degrade'")
    plan = resolve_plan(plan, **kw)
    S = parts.x.shape[1] * params.max_subtrajs_per_traj
    while True:
        fn = build_dsc_program(parts, params, mesh, part_axis=part_axis,
                               model_axis=model_axis, plan=plan)
        final, table, vote, active, diag = jax.jit(fn)(
            parts.x, parts.y, parts.t, parts.valid, parts.traj_id,
            parts.ranges)
        out = DistributedDSCOutput(
            result=final, table=table, vote=vote, active=active,
            sim_diag=diag)
        if plan.sim_mode != "topk":
            return out
        import numpy as np
        overflow = int(np.asarray(diag)[:, 3].sum())
        if overflow == 0 or on_overflow == "degrade":
            return out
        k = plan.sim_topk if plan.sim_topk is not None else 32
        if on_overflow == "raise":
            raise RuntimeError(
                f"sim_topk={k} truncated potential "
                f"alpha-edges on {overflow} rows across partitions "
                "(spill >= alpha): labels would not be exact.  Rerun "
                "with a larger sim_topk.")
        if k >= S:              # unreachable: K == S cannot spill
            raise AssertionError("overflow with K == S")
        plan = plan.replace(sim_topk=min(2 * k, S))


def run_dsc_distributed_lowerable(parts: PartitionedBatch,
                                  params: DSCParams, mesh: Mesh,
                                  **kw):
    """jit-friendly entry (parts as a pytree arg) for the dry-run."""
    fn = build_dsc_program(parts, params, mesh, **kw)
    return fn(parts.x, parts.y, parts.t, parts.valid, parts.traj_id,
              parts.ranges)


class _DSCProgramBuilder:
    """Mesh geometry + the six phase bodies, shared verbatim by the
    monolithic program and the per-stage programs."""

    def __init__(self, parts: PartitionedBatch, params: DSCParams,
                 mesh: Mesh, part_axis: str, model_axis: str,
                 plan: EnginePlan):
        self.params = params
        self.mesh = mesh
        self.part_axis = part_axis
        self.model_axis = model_axis
        self.plan = plan
        self.mode = plan.mode
        self.use_kernel = plan.use_kernel
        self.use_index = plan.use_index
        self.sim_strategy = plan.sim_strategy
        self.sim_dtype = plan.sim_dtype
        self.halo_stream = plan.halo_stream
        self.sim_exchange = plan.sim_exchange
        self.cluster_engine = plan.cluster_engine
        self.cluster_use_kernel = plan.cluster_use_kernel
        self.seg_use_kernel = plan.seg_use_kernel
        self.sim_mode = plan.sim_mode
        self.sim_topk = plan.sim_topk if plan.sim_topk is not None else 32
        # fused tile-geometry overrides for the streaming sweeps (None =
        # the kernels' own defaults — identical traces to the pre-plan
        # surface)
        self.tile_kw = ({} if plan.fused_tiles is None else
                        dict(zip(("rows", "bc", "bm"), plan.fused_tiles)))
        self.nP = mesh.shape[part_axis]
        self.nM = mesh.shape[model_axis]
        Pn, T, Mp = parts.x.shape
        assert Pn == self.nP, f"partitions {Pn} != mesh axis {self.nP}"
        assert T % self.nP == 0, f"T={T} must divide partitions {self.nP}"
        assert T % self.nM == 0, f"T={T} must divide model axis {self.nM}"
        self.T, self.Mp = T, Mp
        self.maxS = params.max_subtrajs_per_traj
        self.S = T * self.maxS
        self.Tl = T // self.nP       # home trajectories per shard
        self.Tc = T // self.nM       # candidate columns per model rank
        self.Mtot = self.nP * Mp     # full per-trajectory point capacity

    # ------------------------------------------------------------ helpers
    def halo(self, arr):
        l = _nbr(arr, self.part_axis, +1, self.nP)
        r = _nbr(arr, self.part_axis, -1, self.nP)
        return l, r

    def _gather_model(self, x, schedule):
        """Model-axis gather under the named comm schedule — ``"barrier"``
        / ``"allgather"`` is one ``lax.all_gather``, ``"ring"`` the
        forwarding-ring twin (bit-identical stack, 1/nM per-step
        payload)."""
        if schedule == "ring":
            return _ring_gather(x, self.model_axis, self.nM)
        return lax.all_gather(x, self.model_axis)

    def _cand_slice(self):
        """(c0, slicer, per-rank traj-id slicer) for this model rank."""
        mrank = lax.axis_index(self.model_axis)
        c0 = mrank * self.Tc
        sl = lambda a: lax.dynamic_slice_in_dim(a, c0, self.Tc, axis=0)
        return c0, sl

    def halo_points(self, px, py, pt, pv, rng):
        """Phase 1 front half: neighbor slab exchange (+ index pruning
        and the partition time-range mask) -> [T, 3Mp] concatenations."""
        params, nP = self.params, self.nP
        lx, rx = self.halo(px)
        ly, ry = self.halo(py)
        lt, rt = self.halo(pt)
        if self.use_index:
            # index-pruned halo: exchange eps-expanded partition bboxes
            # (6 floats) first, then ship each neighbor only the bucket of
            # points it can actually match (conservative -> same result).
            inf = jnp.float32(jnp.inf)
            own_box = jnp.stack([
                jnp.min(jnp.where(pv, px, inf)),
                jnp.max(jnp.where(pv, px, -inf)),
                jnp.min(jnp.where(pv, py, inf)),
                jnp.max(jnp.where(pv, py, -inf)),
                jnp.min(jnp.where(pv, pt, inf)),
                jnp.max(jnp.where(pv, pt, -inf)),
            ])
            box_l = _nbr(own_box, self.part_axis, +1, nP)  # bbox of rank-1
            box_r = _nbr(own_box, self.part_axis, -1, nP)  # bbox of rank+1
            e_sp = jnp.asarray(params.eps_sp, jnp.float32)
            e_t = jnp.asarray(params.eps_t, jnp.float32)

            def inside(box):
                return ((px >= box[0] - e_sp) & (px <= box[1] + e_sp)
                        & (py >= box[2] - e_sp) & (py <= box[3] + e_sp)
                        & (pt >= box[4] - e_t) & (pt <= box[5] + e_t))

            lv = _nbr(pv & inside(box_r), self.part_axis, +1, nP)
            rv = _nbr(pv & inside(box_l), self.part_axis, -1, nP)
        else:
            lv, rv = self.halo(pv)
        eps_t = jnp.asarray(params.eps_t, jnp.float32)
        lo, hi = rng[0] - eps_t, rng[1] + eps_t
        lv &= (lt >= lo) & (lt <= hi)
        rv &= (rt >= lo) & (rt <= hi)

        cx = jnp.concatenate([px, lx, rx], axis=1)        # [T, 3Mp]
        cy = jnp.concatenate([py, ly, ry], axis=1)
        ct = jnp.concatenate([pt, lt, rt], axis=1)
        cv = jnp.concatenate([pv, lv, rv], axis=1)
        # per-slab views, in concat order (own, left, right): the ring
        # join schedule consumes these directly so the own-slab sweep has
        # no dataflow edge to the neighbor ppermutes — compute on slab s
        # overlaps the transfer of slab s+1
        slabs = ((px, py, pt, pv), (lx, ly, lt, lv), (rx, ry, rt, rv))
        return cx, cy, ct, cv, slabs

    # ---------------- phase 1: halo exchange + join ----------------
    def _join_slab(self, px, py, pt, pv, ref_ids, cid, kx, ky, kt, kv, Mc):
        """One best-match sweep of the candidate point arrays ``[Tc, Mc]``
        — the full ``3Mp`` concat under the barrier schedule, one ``Mp``
        slab at a time under the ring schedule."""
        params, T, Mp, Tc = self.params, self.T, self.Mp, self.Tc
        if self.use_kernel:
            from repro.kernels import default_interpret
            from repro.kernels.stjoin.stjoin import stjoin_pallas
            return stjoin_pallas(
                px.reshape(-1), py.reshape(-1), pt.reshape(-1),
                ref_ids.astype(jnp.int32), pv.reshape(-1),
                kx, ky, kt, cid, kv,
                params.eps_sp, params.eps_t,
                bp=_pick_block(T * Mp, 256), bc=_pick_block(Tc, 8),
                bm=_pick_block(Mc, 128),
                interpret=default_interpret())
        from repro.kernels.stjoin.ref import stjoin_ref
        pair_mask = None
        if self.use_index:
            from repro.index.grid import trajectory_pair_mask
            pmask = trajectory_pair_mask(
                px, py, pt, pv, kx, ky, kt, kv,
                params.eps_sp, params.eps_t)               # [T, Tc]
            pair_mask = jnp.repeat(pmask, Mp, axis=0)      # [T*Mp, Tc]
        return stjoin_ref(
            px.reshape(-1), py.reshape(-1), pt.reshape(-1),
            ref_ids, pv.reshape(-1),
            kx, ky, kt, cid, kv,
            jnp.asarray(params.eps_sp, jnp.float32),
            jnp.asarray(params.eps_t, jnp.float32),
            pair_mask=pair_mask)

    def phase_join(self, px, py, pt, pv, traj_id, cx, cy, ct, cv,
                   slabs=None):
        """Returns ``(join, vote, masks)``; ``join`` is this rank's
        [T, Mp, Tc] column block, or None in fused mode.  The halo slabs
        come from :meth:`halo_points` (computed once per program).

        ``plan.halo_stream="ring"`` streams the materialize join one halo
        slab at a time — the own-slab sweep runs while the neighbor slabs
        are still in flight — and the running (best_w, best_idx) fold is
        bit-identical to the concatenated sweep because the kernels'
        argmax is first-occurrence under strict ``>`` updates, which is
        invariant to how the candidate-point axis is chunked
        (DESIGN.md §12).  Fused mode cannot decompose per slab (the
        in-kernel delta_t run refine needs every candidate point of a
        trajectory at once), so there the ring schedule instead streams
        the phase's model-axis word/mask gathers.
        """
        params, T, Mp, Tc = self.params, self.T, self.Mp, self.Tc
        c0, sl = self._cand_slice()
        cid = lax.dynamic_slice_in_dim(traj_id, c0, Tc, axis=0)

        if self.mode == "fused":
            # streaming join epilogue: per-rank fused sweep over the halo
            # slab — votes and packed neighbor words, never the
            # [T, Mp, Tc] cube.  delta_t refine happens in-kernel on the
            # slab rows.
            from repro.kernels.stjoin.ops import stjoin_vote_fused_arrays
            join = None
            vote_l, words_l = stjoin_vote_fused_arrays(
                px, py, pt, pv, traj_id,
                sl(cx), sl(cy), sl(ct), sl(cv), cid,
                params.eps_sp, params.eps_t, params.delta_t,
                with_masks=params.segmentation == "tsa2", **self.tile_kw)
            vote = lax.psum(vote_l, self.model_axis)       # [T, Mp]
            if params.segmentation == "tsa2":
                allw = self._gather_model(words_l, self.halo_stream)
                masks = jnp.moveaxis(allw, 0, 2).reshape(
                    T, Mp, self.nM * words_l.shape[-1])
            else:
                masks = jnp.zeros((T, Mp, 1), jnp.uint32)
            return join, vote, masks

        ref_ids = jnp.broadcast_to(traj_id[:, None], (T, Mp)).reshape(-1)
        if self.halo_stream == "ring" and slabs is not None:
            # slab-streamed join: fold each slab's sweep as it lands.
            # Slab order mirrors the concat (own, left, right); strict
            # ``>`` keeps the first occurrence of the running max, so the
            # fold reproduces the concatenated argmax bit for bit.
            bw = jnp.zeros((T * Mp, Tc), jnp.float32)
            bidx = jnp.full((T * Mp, Tc), -1, jnp.int32)
            for off, (sx, sy, st, sv) in zip((0, Mp, 2 * Mp), slabs):
                w_s, i_s = self._join_slab(px, py, pt, pv, ref_ids, cid,
                                           sl(sx), sl(sy), sl(st), sl(sv),
                                           Mp)
                better = w_s > bw
                bidx = jnp.where(better, i_s + off, bidx)
                bw = jnp.where(better, w_s, bw)
        else:
            bw, bidx = self._join_slab(px, py, pt, pv, ref_ids, cid,
                                       sl(cx), sl(cy), sl(ct), sl(cv),
                                       3 * Mp)

        join = JoinResult(best_w=bw.reshape(T, Mp, Tc),
                          best_idx=bidx.reshape(T, Mp, Tc))
        dt = jnp.asarray(params.delta_t, jnp.float32)
        join = jax.lax.cond(
            dt > 0.0, lambda j: filter_delta_t(j, pt, dt),
            lambda j: j, join)

        vote = lax.psum(
            jnp.sum(join.best_w, axis=-1), self.model_axis)  # [T, Mp]

        if params.segmentation == "tsa2":
            matched = join.best_w > 0.0                    # [T, Mp, Tc]
            allm = self._gather_model(matched, self.halo_stream)
            allm = jnp.moveaxis(allm, 0, 2).reshape(T, Mp, self.nM * Tc)
            masks = pack_bits(allm)                        # [T, Mp, W]
        else:
            masks = jnp.zeros((T, Mp, 1), jnp.uint32)
        return join, vote, masks

    # ------------- phases 2+3: regroup + segmentation (Job 1) -----------
    def phase_segment(self, pt, pv, vote, masks):
        """Returns ``(table, labels)``: the replicated global subtraj
        table and the per-partition ``sub_local`` labels [T, Mp]."""
        params, nP, Tl, Mp, Mtot = (self.params, self.nP, self.Tl,
                                    self.Mp, self.Mtot)
        maxS, T, S = self.maxS, self.T, self.S

        def regroup(a):      # [T, Mp, ...] -> [Tl, nP * Mp, ...]
            a = a.reshape(nP, Tl, *a.shape[1:])
            a = lax.all_to_all(a, self.part_axis, split_axis=0,
                               concat_axis=1)
            # [Tl, nP, Mp, ...] -> [Tl, nP*Mp, ...]
            return a.reshape(Tl, nP * Mp, *a.shape[3:])

        g_vote = regroup(vote)
        g_t = regroup(pt)
        g_v = regroup(pv)
        g_masks = regroup(masks) if params.segmentation == "tsa2" else None

        # compact: valid points first (windows need a contiguous prefix)
        key = (jnp.where(g_v, 0, 1) * (Mtot + 1)
               + jnp.arange(Mtot)[None, :])
        order = jnp.argsort(key, axis=1)
        inv_order = jnp.argsort(order, axis=1)
        takev = lambda a: jnp.take_along_axis(a, order, axis=1)
        c_vote, c_t, c_v = takev(g_vote), takev(g_t), takev(g_v)

        if params.segmentation == "tsa1":
            # Eq. 5 lives in exactly one place: the single-host voting op
            # applies per-trajectory max-normalization verbatim here
            nvote = normalized_voting(c_vote, c_v)
            seg = seg_mod.tsa1(nvote, c_v, params.w, params.tau, maxS)
        else:
            c_masks = jnp.take_along_axis(
                g_masks, order[..., None], axis=1)
            seg = seg_mod.tsa2(c_masks, c_v, params.w, params.tau, maxS,
                               use_kernel=self.seg_use_kernel)

        table_l = build_subtraj_table_arrays(
            c_t, c_v, seg.sub_local, c_vote, maxS)         # S_l = Tl*maxS

        def gather_table(x):
            g = lax.all_gather(x, self.part_axis)          # [nP, S_l]
            return g.reshape(S, *x.shape[1:])

        table = SubtrajTable(
            t_start=gather_table(table_l.t_start),
            t_end=gather_table(table_l.t_end),
            voting=gather_table(table_l.voting),
            card=gather_table(table_l.card),
            valid=gather_table(table_l.valid),
            traj_row=jnp.repeat(jnp.arange(T, dtype=jnp.int32), maxS))

        # labels back to partition layout
        sub_padded = jnp.take_along_axis(seg.sub_local, inv_order, axis=1)
        sub_padded = sub_padded.reshape(Tl, nP, Mp)
        labels = lax.all_to_all(
            sub_padded, self.part_axis, split_axis=1, concat_axis=0)
        labels = labels.reshape(T, Mp)                    # [T, Mp] sub_local
        return table, labels

    def gids(self, labels, pv, cv):
        """Global subtraj ids for own points + the label halo [T, 3Mp]."""
        T, maxS, S = self.T, self.maxS, self.S
        gid_own = jnp.where(
            (labels >= 0) & pv,
            jnp.arange(T, dtype=jnp.int32)[:, None] * maxS + labels, S)

        # candidate labels: same halo structure as the points
        ll, rl = self.halo(jnp.where(labels >= 0, labels, -1))
        lab_cat = jnp.concatenate(
            [jnp.where(labels >= 0, labels, -1), ll, rl], axis=1)
        gid_cat = jnp.where(
            (lab_cat >= 0) & cv,
            jnp.arange(T, dtype=jnp.int32)[:, None] * maxS + lab_cat, S)
        return gid_own, gid_cat

    # ---------------- phase 4: similarity (SP relation) -----------------
    def phase_similarity(self, px, py, pt, pv, traj_id, cx, cy, ct, cv,
                         join, gid_own, gid_cat, table):
        """Returns ``(sim, topk, moments, active)`` — exactly one of
        ``sim`` / ``topk`` is non-None; ``moments`` rides inside the
        TopKSim in topk mode (None here)."""
        params, T, Mp, Tc, S = self.params, self.T, self.Mp, self.Tc, self.S
        maxS = self.maxS
        c0, sl = self._cand_slice()
        cid = lax.dynamic_slice_in_dim(traj_id, c0, Tc, axis=0)
        gid_cand = sl(gid_cat)                             # [Tc, 3Mp]
        S_loc = Tc * maxS
        c0s = c0 * maxS
        if self.mode != "fused":
            idx = jnp.clip(join.best_idx, 0, 3 * Mp - 1)
            dst = jnp.where(
                join.best_idx >= 0,
                gid_cand[jnp.arange(Tc)[None, None, :], idx],
                S)                                         # [T, Mp, Tc]
            src = jnp.broadcast_to(gid_own[:, :, None], (T, Mp, Tc))

        # subtrajectories active in THIS partition
        active = jnp.zeros((S + 1,), bool).at[gid_own.reshape(-1)].set(
            True, mode="drop")[:S]
        part_table = table.replace(valid=table.valid & active)
        part_valid = part_table.valid

        def rank_raw_block():
            """This rank's [S, S_loc] candidate-column block of ``raw``."""
            if self.mode == "fused":
                # pass 2: re-sweep the halo slab, scatter refined weights
                # into this rank's column block in-kernel
                from repro.kernels.stjoin.ops import stjoin_sim_fused_arrays
                gidc_l = jnp.where(gid_cand < S, gid_cand - c0s, S_loc)
                return stjoin_sim_fused_arrays(
                    px, py, pt, pv, traj_id, gid_own,
                    sl(cx), sl(cy), sl(ct), sl(cv), cid, gidc_l,
                    S, S_loc, params.eps_sp, params.eps_t, params.delta_t,
                    **self.tile_kw)
            dst_l = jnp.where(dst < S, dst - c0s, S_loc)
            raw = jnp.zeros((S + 1, S_loc + 1), jnp.float32)
            raw = raw.at[src.reshape(-1), dst_l.reshape(-1)].add(
                join.best_w.reshape(-1))
            return raw[:S, :S_loc]

        def moments_psum(sim_block):
            """Threshold row moments from this rank's final column block,
            psum'd — both SP representations feed bit-identical inputs,
            so dense and topk resolve the exact same alpha."""
            col_valid = lax.dynamic_slice_in_dim(part_valid, c0s, S_loc)
            cnt, rsum, rsumsq = sim_row_moments(
                sim_block, part_valid, col_valid)
            return (lax.psum(cnt, self.model_axis),
                    lax.psum(rsum, self.model_axis),
                    lax.psum(rsumsq, self.model_axis))

        if self.sim_mode == "topk":
            K = min(self.sim_topk, S)
            raw_blk = rank_raw_block()                     # [S, S_loc]
            if self.sim_exchange == "ring":
                # shifted-ppermute transpose exchange: at step s every
                # rank ships the [S_loc, S_loc] sub-block destined for
                # rank (r + s) in one hop and max-folds the sub-block
                # that just landed into its own band of ``sym``.  Each
                # band is written exactly once with the same operands as
                # the barrier all_to_all, so the fold is bit-identical —
                # but every step's transfer overlaps the previous step's
                # fold (DESIGN.md §12).
                a = raw_blk.reshape(self.nM, S_loc, S_loc)
                mrank = lax.axis_index(self.model_axis)

                def fold(sym, src_rank, chunk):
                    k0 = src_rank * S_loc
                    band = lax.dynamic_slice_in_dim(raw_blk, k0, S_loc,
                                                    axis=0)
                    return lax.dynamic_update_slice_in_dim(
                        sym, jnp.maximum(band, chunk.T), k0, axis=0)

                sym_blk = fold(raw_blk, mrank,
                               lax.dynamic_index_in_dim(a, mrank, 0,
                                                        keepdims=False))
                for s in range(1, self.nM):
                    perm = [(i, (i + s) % self.nM) for i in range(self.nM)]
                    chunk = lax.dynamic_index_in_dim(
                        a, (mrank + s) % self.nM, 0, keepdims=False)
                    sym_blk = fold(sym_blk, (mrank - s) % self.nM,
                                   lax.ppermute(chunk, self.model_axis,
                                                perm))
            else:
                # transpose-partner exchange: rank r sends raw[cols_k,
                # cols_r] to rank k and assembles raw[cols_r, :] — the
                # rows that max-symmetrize its own columns.  Each matrix
                # byte crosses the interconnect exactly once.
                a = raw_blk.reshape(self.nM, S_loc, S_loc)
                a = lax.all_to_all(a, self.model_axis, split_axis=0,
                                   concat_axis=1)
                tpart = a.reshape(S_loc, S)                # raw[cols_r, :]
                sym_blk = jnp.maximum(raw_blk, tpart.T)
            simb = finalize_sim_cols(sym_blk, c0s, table, active)
            cnt, rsum, rsumsq = moments_psum(simb)
            # per-rank top-(K+1) of the exact column block ...
            kk = min(K + 1, S_loc)
            vals, idx_l = jax.lax.top_k(simb, kk)
            lids = c0s + idx_l
            if self.sim_exchange == "ring":
                # ... streamed around the forwarding ring: fold each
                # arriving rank's list into the standing top-(K+1) via
                # the canonical pairwise merge.  Exact and
                # order-invariant (``sort_topk_lists``), so the running
                # merge equals the barrier k-way merge bit for bit while
                # replacing the global [nM, S, K+1] gather with a
                # constant [S, K+1] per-step payload.
                perm = [(i, (i + 1) % self.nM) for i in range(self.nM)]
                run_i, run_v = lids, vals
                buf_i, buf_v = lids, vals
                for s in range(1, self.nM):
                    buf_v = lax.ppermute(buf_v, self.model_axis, perm)
                    buf_i = lax.ppermute(buf_i, self.model_axis, perm)
                    run_i, run_v = merge_topk_lists(
                        run_i, run_v, buf_i, buf_v,
                        min(K + 1, (s + 1) * kk))
                ids, sims, spill = merge_topk_blocks(run_i, run_v, K)
            else:
                # barrier k-way merge of the gathered [S, K+1] lists —
                # the only replicated similarity payload
                g_vals = lax.all_gather(vals, self.model_axis)
                g_ids = lax.all_gather(lids, self.model_axis)
                m_vals = jnp.moveaxis(g_vals, 0, 1).reshape(
                    S, self.nM * kk)
                m_ids = jnp.moveaxis(g_ids, 0, 1).reshape(S, self.nM * kk)
                ids, sims, spill = merge_topk_blocks(m_ids, m_vals, K)
            topk = TopKSim(ids=ids, sims=sims, spill=spill, degree=cnt,
                           row_sum=rsum, row_sumsq=rsumsq)
            return None, topk, None, active

        if self.sim_strategy == "allgather":
            raw = rank_raw_block()
            if self.sim_dtype == "bf16":
                raw = raw.astype(jnp.bfloat16)
            gathered = self._gather_model(raw, self.sim_exchange)
            raw = jnp.moveaxis(gathered, 0, 1).reshape(S, S)
            raw = raw.astype(jnp.float32)
        else:
            if self.mode == "fused":
                from repro.kernels.stjoin.ops import \
                    stjoin_sim_fused_arrays
                raw = stjoin_sim_fused_arrays(
                    px, py, pt, pv, traj_id, gid_own,
                    sl(cx), sl(cy), sl(ct), sl(cv), cid, gid_cat,
                    S, S, params.eps_sp, params.eps_t, params.delta_t,
                    **self.tile_kw)
            else:
                raw = jnp.zeros((S + 1, S + 1), jnp.float32)
                raw = raw.at[src.reshape(-1), dst.reshape(-1)].add(
                    join.best_w.reshape(-1))
                raw = raw[:S, :S]
            if self.sim_dtype == "bf16":
                raw = raw.astype(jnp.bfloat16)
            raw = lax.psum(raw, self.model_axis).astype(jnp.float32)

        # Eq. 2 normalization — shared with the single-host paths (the
        # table.valid mask it adds is a no-op here: weight is only ever
        # scattered into slots that own at least one valid point)
        sim = finalize_sim(raw, table)
        sim = jnp.where(active[:, None] & active[None, :], sim, 0.0)
        moments = moments_psum(
            lax.dynamic_slice_in_dim(sim, c0s, S_loc, axis=1))
        return sim, None, moments, active

    # ------------- phase 5: per-partition clustering --------------------
    def phase_cluster(self, sim, topk, moments, table, active):
        """Returns ``(res_l, diag)`` for THIS partition's shard."""
        part_table = table.replace(valid=table.valid & active)
        if topk is not None:
            res_l = cluster(topk, part_table, self.params,
                            engine=self.cluster_engine,
                            use_kernel=self.cluster_use_kernel,
                            tiles=self.plan.cluster_tiles)
            overflow = topk_overflow(topk, res_l.alpha_used)
            meansim = jnp.sum(topk.row_sum) / jnp.maximum(
                jnp.sum(topk.degree), 1)
        else:
            res_l = cluster(sim, part_table, self.params,
                            engine=self.cluster_engine,
                            use_kernel=self.cluster_use_kernel,
                            moments=moments, tiles=self.plan.cluster_tiles)
            overflow = jnp.zeros((), jnp.int32)
            pos = sim > 0
            meansim = jnp.sum(jnp.where(pos, sim, 0.0)) / jnp.maximum(
                jnp.sum(pos), 1)
        diag = jnp.stack([meansim, res_l.alpha_used, res_l.k_used,
                          overflow.astype(jnp.float32)])
        return res_l, diag


def build_dsc_program(
    parts: PartitionedBatch,
    params: DSCParams,
    mesh: Mesh,
    *,
    part_axis: str = "part",
    model_axis: str = "model",
    plan: EnginePlan | None = None,  # the one tuned surface (DESIGN.md §9)
    use_kernel: bool = False,
    use_index: bool = False,
    mode: str = "materialize",      # "materialize" | "fused"
    sim_strategy: str = "psum",     # "psum" | "allgather" (column-sharded)
    sim_dtype: str = "f32",         # "f32" | "bf16" collective payload
    cluster_engine: str = "rounds",  # "rounds" | "sequential" (oracle)
    cluster_use_kernel: bool = False,  # Pallas tile kernels for phase 5
    seg_use_kernel: bool = False,    # Pallas TSA2 Jaccard kernel, phase 3
    sim_mode: str = "dense",        # "dense" | "topk" SP representation
    sim_topk: int | None = None,    # K of the top-K neighbor lists (32)
    halo_stream: str = "barrier",   # "barrier" | "ring" join halo schedule
    sim_exchange: str = "allgather",  # "allgather" | "ring" sim schedule
):
    """Build the shard_map program (not yet jitted) for ``parts`` shapes.

    ``plan=`` carries every per-stage choice as one :class:`EnginePlan`;
    the per-stage keywords below are **deprecated aliases** that
    materialize a plan (``repro.core.plan.resolve_plan``) — passing both
    a plan and a non-default alias raises.

    ``mode="fused"`` streams the JOIN phase per halo slab: instead of
    building the per-rank ``[T, Mp, Tc]`` join cube and re-reading it for
    votes / TSA2 masks / the SP scatter, two fused Pallas sweeps accumulate
    those outputs directly (pass 2 re-sweeps after segmentation).  The
    collective payloads shrink with the buffers: votes psum as before, TSA2
    neighbor sets all_gather as packed words (32x smaller than the bool
    ``matched`` cube), and the SP accumulator follows ``sim_strategy``
    unchanged.  ``use_index`` composes with it (the halo bbox bucketing is
    join-free); the in-kernel delta_t refine matches ``filter_delta_t`` on
    the partition slab exactly.

    ``sim_strategy="allgather"`` exploits that each model rank's scatter
    targets only ITS candidate-column block of the SP matrix: instead of a
    dense [S, S] psum (2x bytes, 16x memory), each rank all_gathers its
    [S, S/m] block — the §Perf optimization for the DSC cells.
    ``sim_dtype="bf16"`` additionally halves the payload.

    ``use_index=True`` turns on the spatiotemporal candidate-pruning index
    (``repro.index.grid``) in the JOIN phase: partitions first exchange
    their eps-expanded bounding boxes (6 floats) and tighten the validity
    mask of the slab they ship to each neighbor down to the points that
    neighbor can actually match (slab *bytes* are unchanged — fixed
    shapes — but out-of-reach points never enter the join or any
    downstream reduction), and the jnp join path additionally skips
    (ref row, cand row) pairs whose bboxes are provably farther than eps
    apart.  Both filters are conservative, so results are unchanged.

    ``cluster_engine`` selects the phase-5 engine per partition:
    ``"rounds"`` (round-parallel, default) or ``"sequential"`` (the O(S)
    oracle); outputs are label-identical (DESIGN.md §6).
    ``cluster_use_kernel=True`` backs the round engine with the Pallas
    tile kernels (``repro.kernels.cluster``) inside each partition's
    shard — the accelerator path; the jnp formulation is faster on
    CPU.

    ``seg_use_kernel=True`` runs phase 3's TSA2 Jaccard signal through
    the fused Pallas segmentation kernel (``repro.kernels.jaccard``)
    inside each shard instead of the jnp packed-word engine —
    bit-identical cuts and labels (DESIGN.md §7); a no-op under
    ``tsa1``.

    ``sim_mode="topk"`` keeps the SP relation sparse end to end
    (DESIGN.md §8): each model rank builds only its ``[S, S_loc]``
    candidate-column block of the raw scatter (``S_loc = S / m``), an
    all_to_all hands every rank the transpose-partner rows of its block
    (each byte of the matrix moves once, vs. the dense ``[S, S]``
    psum's 2x-all-reduce), rank-exact max-symmetrization + Eq. 2
    normalization happen on the block, and the only replicated payload
    is the all_gather of per-rank top-(K+1) candidate lists —
    ``[S, K+1]`` ids+sims instead of ``[S, S]``.  Phase 5 clusters on
    the merged ``TopKSim`` neighbor lists; labels are bit-identical to
    ``sim_mode="dense"`` whenever the spill certificate holds (the
    per-partition overflow count rides in ``sim_diag[:, 3]``; widen
    ``sim_topk`` when nonzero — there is no in-graph retry).  Threshold
    moments psum per-rank row partials in both modes, so dense and topk
    resolve bit-identical alpha.  ``sim_strategy`` / ``sim_dtype`` only
    shape the dense collective and are ignored under topk.

    ``halo_stream="ring"`` / ``sim_exchange="ring"`` swap the phase
    barriers for P-step ``ppermute`` ring schedules (DESIGN.md §12):
    the materialize join folds one halo slab per step while the next is
    in flight, the topk similarity exchange becomes a shifted-ppermute
    transpose sweep plus a forwarding ring over the per-rank top-(K+1)
    lists with a running canonical merge, and the dense ``allgather``
    strategy assembles its column blocks around the forwarding ring.
    Every ring schedule is bit-identical to its barrier twin; per-step
    wire payloads shrink to 1/nM of the barrier gathers.  Fused mode
    keeps the concatenated halo sweep (the in-kernel delta_t refine is
    not slab-separable) and rings only its word/mask gathers; the dense
    ``psum`` strategy is an all-reduce and ignores ``sim_exchange``."""
    plan = resolve_plan(plan, use_kernel=use_kernel, use_index=use_index,
                        mode=mode, sim_strategy=sim_strategy,
                        sim_dtype=sim_dtype, cluster_engine=cluster_engine,
                        cluster_use_kernel=cluster_use_kernel,
                        seg_use_kernel=seg_use_kernel, sim_mode=sim_mode,
                        sim_topk=sim_topk, halo_stream=halo_stream,
                        sim_exchange=sim_exchange)
    b = _DSCProgramBuilder(parts, params, mesh, part_axis, model_axis, plan)

    def body(px, py, pt, pv, traj_id, ranges):
        px, py, pt, pv = px[0], py[0], pt[0], pv[0]       # [T, Mp]
        rng = ranges[0]                                   # [2]

        # phases 1-3
        cx, cy, ct, cv, slabs = b.halo_points(px, py, pt, pv, rng)
        join, vote, masks = b.phase_join(px, py, pt, pv, traj_id,
                                         cx, cy, ct, cv, slabs)
        table, labels = b.phase_segment(pt, pv, vote, masks)
        gid_own, gid_cat = b.gids(labels, pv, cv)

        # phases 4-5
        sim, topk, moments, active = b.phase_similarity(
            px, py, pt, pv, traj_id, cx, cy, ct, cv,
            join, gid_own, gid_cat, table)
        res_l, diag = b.phase_cluster(sim, topk, moments, table, active)
        alpha, k = res_l.alpha_used, res_l.k_used

        # ---------------- phase 6: cross-partition refinement -----------
        # one packed-payload exchange instead of four separate gathers:
        # member ids ride as bitcast f32 lanes (pure data movement —
        # exact), booleans as 0.0/1.0, so the whole refinement state
        # crosses the interconnect in a single [4, S] collective
        packed = jnp.stack([
            lax.bitcast_convert_type(res_l.member_of, jnp.float32),
            res_l.member_sim,
            res_l.is_rep.astype(jnp.float32),
            active.astype(jnp.float32),
        ])                                                       # [4, S]
        g = lax.all_gather(packed, part_axis)                    # [nP, 4, S]
        final = refine_states(
            lax.bitcast_convert_type(g[:, 0], jnp.int32),
            g[:, 1], g[:, 2] > 0.5, g[:, 3] > 0.5,
            lax.pmean(alpha, part_axis), lax.pmean(k, part_axis))

        return final, table, vote[None], active[None], diag[None]

    part_spec = P(part_axis, None, None)
    in_specs = (part_spec, part_spec, part_spec, part_spec,
                P(), P(part_axis, None))
    out_specs = (P(), P(), P(part_axis, None, None),
                 P(part_axis, None), P(part_axis, None))

    return shard_map_compat(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def refine_stage(member_of, member_sim, is_rep, active, alpha, k):
    """Stage 5 of the staged distributed pipeline: the Algorithm 5
    case-table reduction on host-stacked per-partition states.  ``alpha``
    / ``k`` are the per-partition [P] vectors; their mean reproduces the
    monolith's ``lax.pmean``."""
    return refine_states(member_of, member_sim, is_rep, active,
                         jnp.mean(alpha), jnp.mean(k))


def build_dsc_stage_programs(
    parts: PartitionedBatch,
    params: DSCParams,
    mesh: Mesh,
    *,
    part_axis: str = "part",
    model_axis: str = "model",
    plan: EnginePlan | None = None,
    **kw,
) -> dict:
    """One jitted program per checkpointable stage boundary.

    Each program wraps the SAME phase bodies the monolithic
    :func:`build_dsc_program` composes, inside its own ``shard_map``, so
    running them in sequence replays the monolith's computation with a
    host round-trip (and a checkpoint) between stages.  All inter-stage
    state is exchanged as host-visible arrays:

    ``join``        ``(px..ranges) -> (vote, masks[, best_w, best_idx])``
                    The join cube is model-all_gathered to its full
                    ``[P, T, Mp, T]`` column span in materialize mode so
                    the similarity stage can re-slice each rank's block;
                    fused mode re-sweeps the halo slab instead and ships
                    no cube.
    ``segment``     ``(pt, pv, vote, masks) -> (table..., labels)``
                    (table replicated, labels ``[P, T, Mp]``).
    ``similarity``  points + labels + table (+ cube) ->
                    per-partition TopKSim fields / dense sim + moments,
                    plus the ``active`` masks.
    ``cluster``     sim state + table + active -> per-partition
                    ClusteringResult fields + ``diag [P, 4]``.
    ``refine``      :func:`refine_stage` — a plain jit over the stacked
                    per-partition states; needs no mesh.
    """
    plan = resolve_plan(plan, **kw)
    b = _DSCProgramBuilder(parts, params, mesh, part_axis, model_axis, plan)
    part2 = P(part_axis, None, None)
    part3 = P(part_axis, None, None, None)
    pts_specs = (part2, part2, part2, part2, P(), P(part_axis, None))

    def join_body(px, py, pt, pv, traj_id, ranges):
        px, py, pt, pv = px[0], py[0], pt[0], pv[0]
        cx, cy, ct, cv, slabs = b.halo_points(px, py, pt, pv, ranges[0])
        join, vote, masks = b.phase_join(px, py, pt, pv, traj_id,
                                         cx, cy, ct, cv, slabs)
        if join is None:
            return vote[None], masks[None]
        # gather the model-sharded column blocks to the full [T, Mp, T]
        # cube so the similarity stage can hand each rank its slice back
        # (ring-streamed under plan.halo_stream="ring", same bits)
        gw = b._gather_model(join.best_w, plan.halo_stream)
        gi = b._gather_model(join.best_idx, plan.halo_stream)
        bw = jnp.moveaxis(gw, 0, 2).reshape(b.T, b.Mp, b.T)
        bidx = jnp.moveaxis(gi, 0, 2).reshape(b.T, b.Mp, b.T)
        return vote[None], masks[None], bw[None], bidx[None]

    join_out = ((part2, part3) if plan.mode == "fused" else
                (part2, part3, part3, part3))
    join_fn = jax.jit(shard_map_compat(
        join_body, mesh=mesh, in_specs=pts_specs, out_specs=join_out))

    def segment_body(pt, pv, vote, masks):
        table, labels = b.phase_segment(pt[0], pv[0], vote[0], masks[0])
        return table, labels[None]

    # the TSA2 mask cube is dead after segmentation — donating it keeps
    # checkpoint-restored state single-resident (the resilient loop holds
    # host copies, so donation never aliases a checkpoint reference)
    segment_fn = jax.jit(shard_map_compat(
        segment_body, mesh=mesh,
        in_specs=(part2, part2, part2, part3),
        out_specs=(P(), part2)), donate_argnums=(3,))

    def similarity_body(px, py, pt, pv, traj_id, ranges, labels, table,
                        *cube):
        px, py, pt, pv = px[0], py[0], pt[0], pv[0]
        cx, cy, ct, cv, _ = b.halo_points(px, py, pt, pv, ranges[0])
        if cube:
            c0, _ = b._cand_slice()
            join = JoinResult(
                best_w=lax.dynamic_slice_in_dim(cube[0][0], c0, b.Tc,
                                                axis=2),
                best_idx=lax.dynamic_slice_in_dim(cube[1][0], c0, b.Tc,
                                                  axis=2))
        else:
            join = None
        gid_own, gid_cat = b.gids(labels[0], pv, cv)
        sim, topk, moments, active = b.phase_similarity(
            px, py, pt, pv, traj_id, cx, cy, ct, cv,
            join, gid_own, gid_cat, table)
        if topk is not None:
            return (topk.ids[None], topk.sims[None], topk.spill[None],
                    topk.degree[None], topk.row_sum[None],
                    topk.row_sumsq[None], active[None])
        cnt, rsum, rsumsq = moments
        return (sim[None], cnt[None], rsum[None], rsumsq[None],
                active[None])

    sim_in = pts_specs + (part2, P())
    if plan.mode != "fused":
        sim_in = sim_in + (part3, part3)
    part1 = P(part_axis, None)
    sim_out = ((part2, part2, part1, part1, part1, part1, part1)
               if plan.sim_mode == "topk" else
               (part2, part1, part1, part1, part1))
    # the join cube (the largest inter-stage buffer) is dead once the
    # similarity stage has re-sliced it — donate both halves
    sim_donate = () if plan.mode == "fused" else (8, 9)
    similarity_fn = jax.jit(shard_map_compat(
        similarity_body, mesh=mesh, in_specs=sim_in, out_specs=sim_out),
        donate_argnums=sim_donate)

    def cluster_body(table, active, *state):
        if plan.sim_mode == "topk":
            topk = TopKSim(ids=state[0][0], sims=state[1][0],
                           spill=state[2][0], degree=state[3][0],
                           row_sum=state[4][0], row_sumsq=state[5][0])
            res_l, diag = b.phase_cluster(None, topk, None, table,
                                          active[0])
        else:
            moments = (state[1][0], state[2][0], state[3][0])
            res_l, diag = b.phase_cluster(state[0][0], None, moments,
                                          table, active[0])
        return (res_l.member_of[None], res_l.member_sim[None],
                res_l.is_rep[None], res_l.is_outlier[None],
                res_l.alpha_used[None], res_l.k_used[None], diag[None])

    clu_in = ((P(), part1) + ((part2, part2, part1, part1, part1, part1)
                              if plan.sim_mode == "topk" else
                              (part2, part1, part1, part1)))
    clu_out = (part1, part1, part1, part1, P(part_axis), P(part_axis),
               part1)
    # the similarity state (dense [P, S, S] matrix or the top-K lists) is
    # dead once clustered — donate all of it
    clu_donate = tuple(range(2, len(clu_in)))
    cluster_fn = jax.jit(shard_map_compat(
        cluster_body, mesh=mesh, in_specs=clu_in, out_specs=clu_out),
        donate_argnums=clu_donate)

    return {"join": join_fn, "segment": segment_fn,
            "similarity": similarity_fn, "cluster": cluster_fn,
            "refine": jax.jit(refine_stage, donate_argnums=(0, 1, 2, 3))}
