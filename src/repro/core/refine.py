"""Cross-partition refinement (Algorithm 5).

A subtrajectory replicated in several temporal partitions may receive
contradicting states (Repr / Cluster-member / Outlier).  The paper's case
table (a)-(f) reduces, for every replicated subtrajectory, to a single rule:

    Repr anywhere                      -> Repr          (cases b, d, e)
    else member anywhere               -> member of the cluster with the
                                          max similarity  (cases c, f)
    else                               -> outlier       (case a, dedup)

``refine_states`` implements that reduction over a ``[P, S]`` stack of
per-partition states; the distributed pipeline feeds it p/p+1 neighbor pairs
via ppermute, the single-host path feeds the full stack.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import ClusteringResult


def refine_states(member_of: jnp.ndarray, member_sim: jnp.ndarray,
                  is_rep: jnp.ndarray, valid: jnp.ndarray,
                  alpha: jnp.ndarray, k: jnp.ndarray) -> ClusteringResult:
    """Reduce per-partition states [P, S] to a consistent global state [S].

    ``member_of`` holds *global* representative slot ids (or -1); replicated
    rows agree on slot numbering because subtrajectory slots are globally
    aligned across partitions.
    """
    P, S = member_of.shape
    any_rep = jnp.any(is_rep & valid, axis=0)                     # [S]

    sim_masked = jnp.where(valid & (member_of >= 0) & ~is_rep,
                           member_sim, -jnp.inf)                  # [P, S]
    best_p = jnp.argmax(sim_masked, axis=0)                       # [S]
    best_sim = jnp.take_along_axis(sim_masked, best_p[None, :], axis=0)[0]
    best_of = jnp.take_along_axis(member_of, best_p[None, :], axis=0)[0]
    # the masked stack holds finite sims for real members and -inf
    # elsewhere (rep rows' +inf is masked out by ~is_rep), so finiteness
    # alone decides membership
    has_member = jnp.isfinite(best_sim)

    slot = jnp.arange(S, dtype=jnp.int32)
    member_of_out = jnp.where(
        any_rep, slot, jnp.where(has_member, best_of, -1)).astype(jnp.int32)
    member_sim_out = jnp.where(
        any_rep, jnp.inf, jnp.where(has_member, best_sim, 0.0))
    seen = jnp.any(valid, axis=0)
    is_outlier = seen & ~any_rep & ~has_member

    # a member whose representative was demoted elsewhere cannot occur:
    # representatives are never demoted by the case table (rule "Repr anywhere
    # -> Repr"), so member pointers stay consistent.
    return ClusteringResult(
        member_of=jnp.where(seen, member_of_out, -1),
        member_sim=jnp.where(seen, member_sim_out, 0.0),
        is_rep=any_rep & seen,
        is_outlier=is_outlier,
        alpha_used=alpha, k_used=k)
