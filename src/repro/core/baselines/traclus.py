"""TraClus (Lee, Han, Whang; SIGMOD 2007) — partition-and-group baseline.

Faithful NumPy implementation of the three phases:
  1. MDL-based trajectory partitioning into directed segments (time ignored —
     TraClus is a 2D algorithm, which is exactly the contrast the paper draws);
  2. density-based clustering of segments (DBSCAN with the 3-component
     segment distance: perpendicular + parallel + angular);
  3. representative trajectory per cluster (average sweep).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import TrajectoryBatch


# ------------------------- segment distance --------------------------------

def _seg_dist(si: np.ndarray, sj: np.ndarray) -> float:
    """Lee et al. distance between directed segments si=(s,e), sj=(s,e)."""
    (s1, e1), (s2, e2) = si, sj
    l1 = np.linalg.norm(e1 - s1)
    l2 = np.linalg.norm(e2 - s2)
    if l1 < l2:                       # Li must be the longer one
        (s1, e1, l1), (s2, e2, l2) = (s2, e2, l2), (s1, e1, l1)
    d = e1 - s1
    denom = max(l1 * l1, 1e-12)

    def proj(p):
        u = np.dot(p - s1, d) / denom
        return u, s1 + u * d

    u_s, ps = proj(s2)
    u_e, pe = proj(e2)
    l_perp1 = np.linalg.norm(s2 - ps)
    l_perp2 = np.linalg.norm(e2 - pe)
    d_perp = ((l_perp1 ** 2 + l_perp2 ** 2) / (l_perp1 + l_perp2)
              if (l_perp1 + l_perp2) > 1e-12 else 0.0)

    l_par1 = min(abs(u_s) * l1, abs(u_s - 1.0) * l1)
    l_par2 = min(abs(u_e) * l1, abs(u_e - 1.0) * l1)
    d_par = min(l_par1, l_par2)

    cos_t = np.dot(d, e2 - s2) / max(l1 * l2, 1e-12)
    cos_t = np.clip(cos_t, -1.0, 1.0)
    sin_t = np.sqrt(1.0 - cos_t * cos_t)
    d_ang = l2 * sin_t if cos_t >= 0 else l2
    return d_perp + d_par + d_ang


# ------------------------- MDL partitioning --------------------------------

def _mdl_partition(pts: np.ndarray) -> list[int]:
    """Characteristic point indices via the approximate MDL sweep."""
    n = len(pts)
    if n < 3:
        return list(range(n))
    cps = [0]
    start, length = 0, 1
    while start + length < n:
        curr = start + length
        # cost of replacing pts[start..curr] with one segment
        seg = (pts[start], pts[curr])
        l_h = np.log2(max(np.linalg.norm(pts[curr] - pts[start]), 1e-12) + 1)
        dsum_perp, dsum_ang = 0.0, 0.0
        for k in range(start, curr):
            sub = (pts[k], pts[k + 1])
            dsum_perp += _perp_only(seg, sub)
            dsum_ang += _ang_only(seg, sub)
        l_dh = np.log2(dsum_perp + 1) + np.log2(dsum_ang + 1)
        cost_par = l_h + l_dh
        cost_nopar = sum(
            np.log2(max(np.linalg.norm(pts[k + 1] - pts[k]), 1e-12) + 1)
            for k in range(start, curr))
        if cost_par > cost_nopar:
            cps.append(curr - 1 if curr - 1 > start else curr)
            start = cps[-1]
            length = 1
        else:
            length += 1
    cps.append(n - 1)
    return sorted(set(cps))


def _perp_only(seg, sub) -> float:
    (s1, e1), (s2, e2) = seg, sub
    d = e1 - s1
    denom = max(np.dot(d, d), 1e-12)

    def dist(p):
        u = np.dot(p - s1, d) / denom
        return np.linalg.norm(p - (s1 + u * d))

    l1, l2 = dist(s2), dist(e2)
    return (l1 ** 2 + l2 ** 2) / (l1 + l2) if (l1 + l2) > 1e-12 else 0.0


def _ang_only(seg, sub) -> float:
    (s1, e1), (s2, e2) = seg, sub
    l1 = max(np.linalg.norm(e1 - s1), 1e-12)
    l2 = np.linalg.norm(e2 - s2)
    cos_t = np.clip(np.dot(e1 - s1, e2 - s2) / max(l1 * l2, 1e-12), -1, 1)
    return l2 * np.sqrt(1 - cos_t ** 2)


# ------------------------- main entry ---------------------------------------

def traclus(batch: TrajectoryBatch, eps: float, min_lns: int):
    """Returns dict with segments, labels (-1 noise), representatives."""
    xs = np.asarray(batch.x)
    ys = np.asarray(batch.y)
    vs = np.asarray(batch.valid)
    segments, seg_traj = [], []
    for r in range(xs.shape[0]):
        pts = np.stack([xs[r][vs[r]], ys[r][vs[r]]], axis=1)
        if len(pts) < 2:
            continue
        cps = _mdl_partition(pts)
        for a, b in zip(cps[:-1], cps[1:]):
            if b > a:
                segments.append((pts[a], pts[b]))
            seg_traj.append(r)
    n = len(segments)
    if n == 0:
        return {"segments": [], "labels": np.array([]), "reps": []}

    # pairwise distance matrix (n is small for baseline-scale data)
    D = np.zeros((n, n))
    for i in range(n):
        for j in range(i + 1, n):
            D[i, j] = D[j, i] = _seg_dist(
                np.stack(segments[i]), np.stack(segments[j]))

    # DBSCAN over segments
    labels = np.full(n, -1)
    cid = 0
    visited = np.zeros(n, bool)
    for i in range(n):
        if visited[i]:
            continue
        visited[i] = True
        nbrs = list(np.nonzero(D[i] <= eps)[0])
        if len(nbrs) < min_lns:
            continue
        labels[i] = cid
        queue = [j for j in nbrs if j != i]
        while queue:
            j = queue.pop()
            if labels[j] == -1:
                labels[j] = cid
            if not visited[j]:
                visited[j] = True
                nbrs_j = np.nonzero(D[j] <= eps)[0]
                if len(nbrs_j) >= min_lns:
                    queue.extend(k for k in nbrs_j if labels[k] == -1)
        cid += 1

    reps = []
    for c in range(cid):
        segs = [segments[i] for i in np.nonzero(labels == c)[0]]
        reps.append(_representative(segs, min_lns))
    return {"segments": segments, "labels": labels, "reps": reps,
            "seg_traj": np.asarray(seg_traj[:n])}


def _representative(segs, min_lns: int) -> np.ndarray:
    """Average-sweep representative of a set of segments."""
    vecs = np.stack([e - s for s, e in segs])
    mean_v = vecs.mean(axis=0)
    nrm = np.linalg.norm(mean_v)
    ax = mean_v / nrm if nrm > 1e-12 else np.array([1.0, 0.0])
    rot = np.array([[ax[0], ax[1]], [-ax[1], ax[0]]])
    ends = np.stack([np.stack([rot @ s, rot @ e]) for s, e in segs])
    xs = np.sort(ends[..., 0].ravel())
    pts = []
    for xv in xs:
        ys = []
        for (p, q) in ends:
            x0, x1 = sorted([p[0], q[0]])
            if x0 - 1e-9 <= xv <= x1 + 1e-9 and x1 - x0 > 1e-12:
                tpar = (xv - p[0]) / (q[0] - p[0])
                ys.append(p[1] + tpar * (q[1] - p[1]))
        if len(ys) >= max(min_lns, 2):
            pts.append([xv, float(np.mean(ys))])
    if not pts:
        mid = np.stack([0.5 * (s + e) for s, e in segs]).mean(axis=0)
        return mid[None, :]
    return (np.linalg.inv(rot) @ np.asarray(pts).T).T
