"""Comparison baselines from the paper's experimental study (Sec. 6.2).

These are *centralized* algorithms (the paper's point is precisely that they
do not scale); they are implemented host-side in NumPy, faithful to their
original definitions, and used by ``benchmarks/fig6_groundtruth.py`` and
``benchmarks/fig7_rmse.py``:

  traclus  — TraClus [9]: MDL partitioning + segment-DBSCAN + representative
  s2t      — S2T-Clustering [20]: voting segmentation + SaCO seeds/clusters
  toptics  — T-OPTICS [13]: whole-trajectory OPTICS
"""
