"""S2T-Clustering (Pelekis et al., EDBT 2017) — centralized baseline.

Two phases, per the original paper:
  NaTS — Neighborhood-aware Trajectory Segmentation: per-point voting from
         *continuous* trajectory neighborhoods, then homogeneity-driven
         segmentation (we reuse the windowed change detector).
  SaCO — Sampling, Clustering & Outliers: representatives are sampled as the
         highest-voted subtrajectories that are sufficiently *dissimilar*
         from already-selected ones; every other subtrajectory joins the
         most-similar representative (no delta_t minimum-duration constraint
         and no per-member similarity floor — the two differences the DSC
         paper credits for its lower RMSE in Fig. 7).
"""
from __future__ import annotations

import numpy as np

from repro.core import geometry, segmentation, similarity, voting
from repro.core.types import DSCParams, TrajectoryBatch


def s2t_clustering(batch: TrajectoryBatch, eps_sp: float, eps_t: float,
                   w: int = 10, tau: float = 0.4, n_reps: int | None = None,
                   dissim: float = 0.6, max_subs: int = 8):
    """Returns dict(member_of, is_rep, is_outlier, table, sim)."""
    import jax.numpy as jnp

    # NaTS: voting + segmentation (no delta_t filtering — S2T has none)
    join = geometry.best_match_join(batch, batch, eps_sp, eps_t)
    vote = voting.point_voting(join)
    nvote = voting.normalized_voting(vote, batch.valid)
    seg = segmentation.tsa1(nvote, batch.valid, w, tau, max_subs)
    table = similarity.build_subtraj_table(batch, seg, vote, max_subs)
    sim = similarity.similarity_matrix(join, seg, seg.sub_local, table,
                                       max_subs)

    sim_np = np.asarray(sim)
    voting_np = np.asarray(table.voting)
    valid_np = np.asarray(table.valid)
    S = len(voting_np)

    # SaCO sampling: greedy max-voting, dissimilarity-constrained seeds
    order = np.argsort(-np.where(valid_np, voting_np, -np.inf))
    reps: list[int] = []
    budget = n_reps if n_reps is not None else S
    for s in order:
        if not valid_np[s]:
            continue
        if all(sim_np[s, r] < dissim for r in reps):
            reps.append(int(s))
            if len(reps) >= budget:
                break

    member_of = np.full(S, -1, np.int64)
    member_sim = np.zeros(S)
    is_rep = np.zeros(S, bool)
    for r in reps:
        is_rep[r] = True
        member_of[r] = r
    for s in range(S):
        if not valid_np[s] or is_rep[s]:
            continue
        sims = sim_np[s, reps]
        j = int(np.argmax(sims))
        if sims[j] > 0.0:             # any positive similarity joins
            member_of[s] = reps[j]
            member_sim[s] = sims[j]
    is_outlier = valid_np & (member_of < 0)
    return {"member_of": member_of, "member_sim": member_sim,
            "is_rep": is_rep, "is_outlier": is_outlier,
            "table": table, "sim": sim_np, "seg": seg}
