"""T-OPTICS (Nanni & Pedreschi, 2006) — whole-trajectory clustering baseline.

OPTICS over a trajectory distance: the time-focused mean Euclidean distance
between trajectories over their common temporal span (the paper's Fig. 6
contrast: T-OPTICS recovers the six origin-destination *routes*, never the
shared subtrajectory structure).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import TrajectoryBatch


def trajectory_distance(batch: TrajectoryBatch) -> np.ndarray:
    """[T, T] mean aligned Euclidean distance over the common time span."""
    x = np.asarray(batch.x)
    y = np.asarray(batch.y)
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    T = x.shape[0]
    D = np.full((T, T), np.inf)
    grids = []
    for r in range(T):
        tr = t[r][v[r]]
        grids.append((tr, x[r][v[r]], y[r][v[r]]))
    for i in range(T):
        ti, xi, yi = grids[i]
        if len(ti) < 2:
            continue
        D[i, i] = 0.0
        for j in range(i + 1, T):
            tj, xj, yj = grids[j]
            if len(tj) < 2:
                continue
            lo, hi = max(ti[0], tj[0]), min(ti[-1], tj[-1])
            if hi <= lo:
                continue
            grid = np.linspace(lo, hi, 32)
            xi_g = np.interp(grid, ti, xi)
            yi_g = np.interp(grid, ti, yi)
            xj_g = np.interp(grid, tj, xj)
            yj_g = np.interp(grid, tj, yj)
            D[i, j] = D[j, i] = float(
                np.mean(np.hypot(xi_g - xj_g, yi_g - yj_g)))
    return D


def optics(D: np.ndarray, eps: float, min_pts: int):
    """Classic OPTICS ordering + reachability; returns (order, reach)."""
    n = D.shape[0]
    reach = np.full(n, np.inf)
    processed = np.zeros(n, bool)
    order = []

    def core_distance(p):
        d = np.sort(D[p][D[p] <= eps])
        return d[min_pts - 1] if len(d) >= min_pts else np.inf

    for p0 in range(n):
        if processed[p0]:
            continue
        seeds: dict[int, float] = {p0: np.inf}
        while seeds:
            p = min(seeds, key=seeds.get)
            del seeds[p]
            if processed[p]:
                continue
            processed[p] = True
            order.append(p)
            cd = core_distance(p)
            if np.isfinite(cd):
                for q in np.nonzero(D[p] <= eps)[0]:
                    if processed[q]:
                        continue
                    nr = max(cd, D[p, q])
                    if nr < reach[q]:
                        reach[q] = nr
                        seeds[q] = nr
    return np.asarray(order), reach


def extract_clusters(order: np.ndarray, reach: np.ndarray,
                     xi_eps: float) -> np.ndarray:
    """DBSCAN-style extraction: split ordering where reachability > xi_eps."""
    labels = np.full(len(order), -1)
    cid = -1
    fresh = True
    for idx, p in enumerate(order):
        if reach[p] > xi_eps:
            fresh = True
            continue
        if fresh:
            cid += 1
            fresh = False
            if idx > 0:
                labels[order[idx - 1]] = cid   # the core that opened it
        labels[p] = cid
    return labels


def t_optics(batch: TrajectoryBatch, eps: float, min_pts: int,
             xi_eps: float | None = None):
    D = trajectory_distance(batch)
    finite = D[np.isfinite(D) & (D > 0)]
    if xi_eps is None:
        xi_eps = float(np.percentile(finite, 25)) if len(finite) else eps
    order, reach = optics(D, eps, min_pts)
    labels = extract_clusters(order, reach, xi_eps)
    return {"labels": labels, "order": order, "reach": reach, "D": D}
