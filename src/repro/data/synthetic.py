"""Synthetic trajectory generators.

``figure1_scenario``  — the paper's running example (Sec. 1 / Sec. 6.2):
six origin-destination routes A->B, A->C, A->D, B->A, B->C, B->D through a
common midpoint O, same start time, similar speed.  Ground truth at
subtrajectory level: clusters A->O, B->O, O->C, O->D and, depending on
``outliers_as_clusters``, either 2 outliers (O->A, O->B; Fig. 1) or 6 clusters
(Sec. 6.2's variant where every leg is supported by ``n_per_route`` objects).

``ais_like``          — Brest-area-style maritime traffic: vessels follow a
small set of lanes (great-circle-ish line segments between waypoint pairs)
with per-vessel speed/offset jitter, variable sampling rate and temporal
displacement — the properties the paper's similarity is designed for.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import TrajectoryBatch

# Geometry of the example: A, B on the left, C, D on the right, O in middle.
_POINTS = {
    "A": np.array([0.0, 1.0]),
    "B": np.array([0.0, -1.0]),
    "C": np.array([2.0, 1.0]),
    "D": np.array([2.0, -1.0]),
    "O": np.array([1.0, 0.0]),
}
_ROUTES = [("A", "B"), ("A", "C"), ("A", "D"), ("B", "A"), ("B", "C"),
           ("B", "D")]
ROUTE_ENDPOINTS = list(_ROUTES)


def route_origins_dests(labels):
    """Per-trajectory (origin, destination) names for figure-1 labels."""
    import numpy as np
    origins = np.asarray([ROUTE_ENDPOINTS[r][0] for r in labels])
    dests = np.asarray([ROUTE_ENDPOINTS[r][1] for r in labels])
    return origins, dests


def _leg(p0, p1, n, t0, dt, rng, jitter):
    ts = np.linspace(0.0, 1.0, n, endpoint=False)
    pts = p0[None, :] + ts[:, None] * (p1 - p0)[None, :]
    pts = pts + rng.normal(0.0, jitter, pts.shape)
    t = t0 + np.arange(n) * dt
    return np.concatenate([pts, t[:, None]], axis=1)


def figure1_scenario(n_per_route: int = 5, points_per_leg: int = 32,
                     jitter: float = 0.01, dt: float = 1.0,
                     time_jitter: float = 0.2, seed: int = 0,
                     pad_trajs_to: int | None = None) -> tuple[
                         TrajectoryBatch, np.ndarray]:
    """Returns (batch, route_label[T]) — route label indexes ``_ROUTES``."""
    rng = np.random.default_rng(seed)
    trajs, labels = [], []
    for ridx, (a, b) in enumerate(_ROUTES):
        for _ in range(n_per_route):
            t0 = rng.uniform(0.0, time_jitter * dt)
            leg1 = _leg(_POINTS[a], _POINTS["O"], points_per_leg, t0, dt,
                        rng, jitter)
            leg2 = _leg(_POINTS["O"], _POINTS[b], points_per_leg,
                        t0 + points_per_leg * dt, dt, rng, jitter)
            trajs.append(np.concatenate([leg1, leg2], axis=0))
            labels.append(ridx)
    batch = TrajectoryBatch.from_numpy(
        trajs, max_points=2 * points_per_leg, pad_trajs_to=pad_trajs_to)
    return batch, np.asarray(labels)


def crossing_scenario(n_per_route: int = 3, points_per_leg: int = 16,
                      n_crossers: int = 4, n_fringe: int = 3,
                      fringe_offset: float = 0.32, seed: int = 2):
    """Figure-1 traffic plus two kinds of weak associates of the A->O
    corridor (the paper's Fig. 7 mechanisms):

    * crossers — share the corridor only *briefly* then veer off: rejected by
      DSC's delta_t minimum-match-duration, attachable without it;
    * fringe riders — parallel to the corridor at ~0.75 * eps_sp offset: their
      weighted-LCSS similarity (~0.25) falls below DSC's alpha floor but is
      positive, so floor-less methods (S2T) attach them, inflating RMSE.
    """
    rng = np.random.default_rng(seed)
    batch, labels = figure1_scenario(
        n_per_route=n_per_route, points_per_leg=points_per_leg, seed=seed)
    trajs = []
    T, M = batch.x.shape
    x = np.asarray(batch.x)
    y = np.asarray(batch.y)
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    base = [np.stack([x[r][v[r]], y[r][v[r]], t[r][v[r]]], 1)
            for r in range(T)]
    mid = 0.5 * (_POINTS["A"] + _POINTS["O"])
    direction = (_POINTS["O"] - _POINTS["A"])
    direction = direction / np.linalg.norm(direction)
    normal = np.array([-direction[1], direction[0]])
    touch = max(3, points_per_leg // 4)
    for c in range(n_crossers):
        t0 = 0.3 * points_per_leg + rng.uniform(0, 2.0)
        n = points_per_leg
        pts = np.zeros((n, 3))
        for i in range(n):
            if i < touch:     # brief ride along the corridor
                pos = mid + direction * (i * 0.06) + rng.normal(0, 0.01, 2)
            else:             # veer off perpendicular, far away
                pos = (mid + direction * (touch * 0.06)
                       + normal * ((i - touch) * 0.25)
                       + rng.normal(0, 0.01, 2))
            pts[i] = [pos[0], pos[1], t0 + i]
        trajs.append(pts)
    for f in range(n_fringe):
        t0 = rng.uniform(0, 1.0)
        n = points_per_leg
        off = fringe_offset * (1.0 + 0.1 * rng.standard_normal())
        pts = np.zeros((n, 3))
        seg = (_POINTS["O"] - _POINTS["A"])
        for i in range(n):
            pos = (_POINTS["A"] + seg * (i / n) + normal * off
                   + rng.normal(0, 0.005, 2))
            pts[i] = [pos[0], pos[1], t0 + i]
        trajs.append(pts)
    all_trajs = base + trajs
    out = TrajectoryBatch.from_numpy(all_trajs,
                                     max_points=2 * points_per_leg)
    n_extra = n_crossers + n_fringe
    extra = np.concatenate([np.zeros(T, bool), np.ones(n_extra, bool)])
    return out, np.concatenate([labels, -np.ones(n_extra, int)]), extra


def ais_like(n_vessels: int = 64, n_lanes: int = 4, max_points: int = 128,
             area: float = 100.0, mean_speed: float = 0.4,
             sample_dt: float = 60.0, dt_jitter: float = 0.3,
             lane_width: float = 0.5, seed: int = 0,
             duration: float | None = None,
             pad_trajs_to: int | None = None) -> tuple[
                 TrajectoryBatch, np.ndarray]:
    """Lane-following maritime-style traffic; returns (batch, lane_label)."""
    rng = np.random.default_rng(seed)
    # lanes: pairs of endpoints in the [0, area]^2 box
    lanes = rng.uniform(0.1 * area, 0.9 * area, (n_lanes, 2, 2))
    trajs, labels = [], []
    for v in range(n_vessels):
        lane = int(rng.integers(n_lanes))
        p0, p1 = lanes[lane]
        direction = (p1 - p0) / (np.linalg.norm(p1 - p0) + 1e-9)
        offset = rng.normal(0.0, lane_width, 2)
        speed = mean_speed * rng.uniform(0.7, 1.3)
        n = int(rng.integers(max_points // 2, max_points + 1))
        t0 = rng.uniform(0.0, 0.25 * (duration or n * sample_dt))
        dts = sample_dt * rng.uniform(1.0 - dt_jitter, 1.0 + dt_jitter, n)
        t = t0 + np.cumsum(dts)
        s = speed * (t - t[0])
        s = np.minimum(s, np.linalg.norm(p1 - p0))
        pts = p0[None, :] + offset[None, :] + s[:, None] * direction[None, :]
        pts = pts + rng.normal(0.0, 0.05 * lane_width, pts.shape)
        trajs.append(np.concatenate([pts, t[:, None]], axis=1))
        labels.append(lane)
    batch = TrajectoryBatch.from_numpy(
        trajs, max_points=max_points, pad_trajs_to=pad_trajs_to)
    return batch, np.asarray(labels)


def stream_records(batch: TrajectoryBatch, batch_size: int = 64,
                   order: str = "time"):
    """Replay a :class:`TrajectoryBatch` as a sequence of submission
    batches for the streaming service (``repro.stream``).

    Flattens every valid point to a ``(obj, x, y, t)`` record, orders
    the stream (``"time"``: global event-time order, the realistic feed;
    ``"traj"``: row-major, worst case for the watermark), and yields
    :class:`~repro.stream.ingest.Records` chunks of ``batch_size``.
    Deterministic — the same batch yields the same submission sequence,
    which is what lets a resumed service replay by absolute batch index.
    """
    from repro.stream.ingest import Records
    x = np.asarray(batch.x)
    y = np.asarray(batch.y)
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    ids = np.asarray(batch.traj_id)
    rows, cols = np.nonzero(v)
    obj = ids[rows]
    keep = obj >= 0
    rows, cols, obj = rows[keep], cols[keep], obj[keep]
    if order == "time":
        srt = np.lexsort((obj, t[rows, cols]))
    elif order == "traj":
        srt = np.lexsort((t[rows, cols], obj))
    else:
        raise ValueError(f"order={order!r}: expected 'time' or 'traj'")
    rows, cols, obj = rows[srt], cols[srt], obj[srt]
    out = []
    for i in range(0, len(obj), batch_size):
        s = slice(i, i + batch_size)
        out.append(Records.build(obj[s], x[rows[s], cols[s]],
                                 y[rows[s], cols[s]], t[rows[s], cols[s]]))
    return out


def dirtify(recs_list, *, dup_frac: float = 0.0, nan_frac: float = 0.0,
            swap_frac: float = 0.0, teleport_frac: float = 0.0,
            teleport_dist: float = 50.0, seed: int = 0):
    """Seeded corruptor for a submission sequence — the chaos suite's
    ground truth generator.

    Takes the output of :func:`stream_records` and injects, per batch:

    * ``dup_frac``      — duplicated records (appended verbatim);
    * ``nan_frac``      — records with NaN coordinates;
    * ``swap_frac``     — adjacent same-object timestamp *swaps* (the
      mechanically-repairable dirt ``on_dirty="repair"`` fixes);
    * ``teleport_frac`` — records displaced ``teleport_dist`` away (GPS
      jumps the ``max_speed`` gate quarantines).

    Returns ``(dirty_list, truth)`` where ``truth`` counts exactly what
    was injected — tests assert the ingest counters against it.  Fully
    deterministic in ``seed``.
    """
    from repro.stream.ingest import Records, concat_records
    rng = np.random.default_rng(seed)
    truth = {"dup": 0, "nan": 0, "swap_pairs": 0, "teleport": 0}
    out = []
    seen_objs: set = set()   # teleports need a baseline fix to be seen
    for recs in recs_list:
        obj = recs.obj.copy()
        x = recs.x.copy()
        y = recs.y.copy()
        t = recs.t.copy()
        n = recs.n
        if n and swap_frac > 0:
            # swap timestamps of adjacent same-object record pairs
            cand = np.nonzero(obj[:-1] == obj[1:])[0]
            take = cand[rng.random(cand.size) < swap_frac]
            used = np.zeros(n, bool)
            for i in take:
                if used[i] or used[i + 1] or t[i] == t[i + 1]:
                    continue
                t[i], t[i + 1] = t[i + 1], t[i]
                used[i] = used[i + 1] = True
                truth["swap_pairs"] += 1
        hit = np.zeros(n, bool)     # nan/teleport stay disjoint so the
        if n and teleport_frac > 0:  # truth counts match ingest's counters
            # never displace an object's first-ever record: the speed
            # gate has no baseline fix there, so such a jump would be
            # invisible to ingest and the truth count would overshoot
            eligible = np.zeros(n, bool)
            batch_seen = set(seen_objs)
            for i in range(n):
                o = int(obj[i])
                eligible[i] = o in batch_seen
                batch_seen.add(o)
            take = np.nonzero(
                (rng.random(n) < teleport_frac) & ~hit & eligible)[0]
            x[take] += teleport_dist
            hit[take] = True
            truth["teleport"] += int(take.size)
        if n and nan_frac > 0:
            take = np.nonzero((rng.random(n) < nan_frac) & ~hit)[0]
            x[take] = np.nan
            hit[take] = True
            truth["nan"] += int(take.size)
        dirty = Records(obj, x, y, t)
        if n and dup_frac > 0:
            take = np.nonzero(rng.random(n) < dup_frac)[0]
            if take.size:
                dirty = concat_records(
                    [dirty, Records(obj[take], x[take], y[take], t[take])])
                truth["dup"] += int(take.size)
        seen_objs.update(int(o) for o in obj)
        out.append(dirty)
    return out, truth


def default_dsc_params_for(batch: TrajectoryBatch):
    """Paper Sec. 6.1 heuristics: eps_sp ~ %% of diameter, eps_t/delta_t ~
    multiples of the mean sampling interval."""
    import numpy as np
    x = np.asarray(batch.x)[np.asarray(batch.valid)]
    y = np.asarray(batch.y)[np.asarray(batch.valid)]
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    diam = float(np.hypot(x.max() - x.min(), y.max() - y.min()))
    dts = []
    for r in range(t.shape[0]):
        tr = t[r][v[r]]
        if len(tr) > 1:
            dts.append(np.diff(tr).mean())
    mean_dt = float(np.mean(dts)) if dts else 1.0
    return diam, mean_dt
