"""Deterministic, restart-safe synthetic token pipeline with prefetch.

``TokenPipeline`` is seed+step-indexed: batch(i) is a pure function of
(seed, i), so resuming from a checkpoint at step i reproduces the exact
stream at ANY world size (elasticity requirement).  A double-buffer thread
overlaps host batch synthesis with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


class TokenPipeline:
    """Markov-chain synthetic corpus (learnable structure, not noise)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, order: int = 2):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        V = min(cfg.vocab_size, 512)
        rng = np.random.default_rng(seed)
        # sparse-ish transition structure so the LM has something to learn
        self.next_tok = rng.integers(0, V, (V, 8))
        self.V = V

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, L = self.batch, self.seq_len
        toks = np.zeros((B, L + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.V, B)
        choices = rng.integers(0, 8, (B, L))
        noise = rng.uniform(0, 1, (B, L)) < 0.05
        rand = rng.integers(0, self.V, (B, L))
        for t in range(L):
            nxt = self.next_tok[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "audio":
            nq = self.cfg.n_codebooks
            out = {"tokens": np.repeat(out["tokens"][:, None], nq, 1),
                   "labels": np.repeat(out["labels"][:, None], nq, 1)}
        if self.cfg.family == "vlm":
            out["frontend"] = rng.normal(
                0, 1, (B, self.cfg.vision_tokens,
                       self.cfg.d_vision)).astype(np.float32)
        return out

    def iterate(self, start_step: int = 0,
                prefetch: int = 2) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            i = start_step
            while not stop.is_set():
                q.put(self.batch_at(i))
                i += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
