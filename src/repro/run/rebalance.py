"""Straggler-driven adaptive repartitioning policy (DESIGN.md §11).

A :class:`RebalancePolicy` closes the loop that PR 7 left open: the
``StragglerMonitor`` flags slow partitions and
``suggest_rebalance_edges`` computes a slowdown-weighted equi-depth
re-cut, but nothing consumed it.  The policy — one frozen,
JSON-serializable dataclass in the :class:`repro.run.faults.FaultPlan`
idiom — tells the resilient stage runner what to do with those flags:

* ``mode="off"``      — ignore straggler flags entirely (no suggestion
  telemetry either).
* ``mode="suggest"``  — (default) emit ``rebalance_suggestion`` events
  with the proposed edges; never touch the layout.  This is PR 7's
  behavior.
* ``mode="apply"``    — once ``consecutive`` successive stages flag a
  straggler (and at most ``max_applies`` times per run), re-cut the
  partitioned batch at the suggested edges, repartition all in-flight
  per-point stage state through the canonical global form
  (``repro.core.partitioning.repartition``), rebuild the stage
  programs, checkpoint the post-rebalance state, and emit a
  ``rebalanced`` event carrying the applied edges.

Application only happens at the join/segment stage boundaries: later
stages carry partition-bound state (per-partition subtrajectory moments
and labels) that has no partition-free form — see DESIGN.md §11.  The
rebalanced run is bit-identical to a straight-through run partitioned
at the applied cut from the start.
"""
from __future__ import annotations

import dataclasses
import json

_MODES = ("off", "suggest", "apply")


@dataclasses.dataclass(frozen=True)
class RebalancePolicy:
    """What the stage runner does with straggler flags."""

    mode: str = "suggest"      # off | suggest | apply
    consecutive: int = 1       # flagged stages in a row before applying
    max_applies: int = 1       # applied re-cuts per run

    # ------------------------------------------------------------------ api
    def validate(self) -> "RebalancePolicy":
        if self.mode not in _MODES:
            raise ValueError(f"mode={self.mode!r}: expected one of {_MODES}")
        if not isinstance(self.consecutive, int) or self.consecutive < 1:
            raise ValueError("consecutive must be a positive int, "
                             f"got {self.consecutive!r}")
        if not isinstance(self.max_applies, int) or self.max_applies < 0:
            raise ValueError("max_applies must be a non-negative int, "
                             f"got {self.max_applies!r}")
        return self

    def replace(self, **kw) -> "RebalancePolicy":
        return dataclasses.replace(self, **kw).validate()

    # --------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RebalancePolicy":
        """Strict inverse of ``to_dict``: unknown keys raise (same contract
        as ``FaultPlan.from_dict``); missing keys take field defaults."""
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(
                f"unknown RebalancePolicy fields {sorted(unknown)}; "
                f"known fields: {sorted(names)}")
        return cls(**d).validate()

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "RebalancePolicy":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "RebalancePolicy":
        with open(path) as f:
            return cls.from_json(f.read())
