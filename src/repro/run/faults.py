"""Deterministic fault injection for the resilient runner (DESIGN.md §10).

A :class:`FaultPlan` scripts failures the way :class:`repro.core.plan.
EnginePlan` scripts engines: one frozen, JSON-serializable dataclass that a
test (or the launcher's ``--fault-plan``) hands to the runner, which then
fails *identically* on every run — chaos testing without nondeterminism.

Supported faults, all keyed on the runner's stage boundaries:

* ``crash_at=<stage>`` — raise :class:`InjectedCrash` on entry to the
  stage, i.e. after the previous stage's checkpoint landed; a subsequent
  resume must reproduce the uninterrupted run bit for bit.
* ``transient_at=<stage>`` + ``transient_count=N`` — the stage raises
  :class:`TransientFault` on its first N attempts and succeeds on attempt
  N+1, exercising :func:`retry_with_backoff` (and, for N > max_retries,
  the :class:`RetriesExhausted` path).
* ``corrupt_stage=<stage>`` (+ ``corrupt_leaf``) — after the stage's
  checkpoint is written, flip bytes in one stored leaf while leaving the
  manifest CRC stale, so the next restore detects the mismatch and falls
  back a step.
* ``slow=((stage, partition, seconds), ...)`` — add scripted wall time to
  a (stage, partition) cell of the timing matrix the straggler monitor
  consumes, so flagging and rebalance suggestions are testable without
  real slow hardware.

Stream chaos (DESIGN.md §13.6) — the same plan scripts the streaming
service's failure modes, keyed on the *submission batch index* (so a
resumed run, which replays batches by absolute index, re-applies the
identical transforms):

* ``stream_late_burst=((batch, seconds), ...)`` — shift every record of
  submission ``batch`` back in time by ``seconds`` (a late burst that the
  watermark must count/drop or scoped-rejoin).
* ``stream_dup_storm=(batch, ...)`` — duplicate every record of the
  submission (quarantined as ``duplicate``).
* ``stream_poison=((batch, index), ...)`` — overwrite record ``index`` of
  the submission with NaN coordinates (a poison record).
* ``stream_stall=(batch, ...)`` — suppress the window advance after this
  submission (queue pressure / stalled-watermark scenarios).
* ``crash_at_advance=N`` (>= 0) — raise :class:`InjectedCrash` on entry
  to window advance ``N``, after the previous advance's snapshot landed;
  the kill-and-resume parity suite drives this.

Retry timing is injectable (``sleep=``/monotonic ``clock=``), so the
exponential-backoff schedule is asserted in tests with zero real sleeping.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Callable, Optional

import numpy as np

_STAGES = ("join", "segment", "similarity", "cluster", "refine")


class InjectedCrash(RuntimeError):
    """A scripted hard crash (process death) at a stage boundary."""


class TransientFault(RuntimeError):
    """A scripted recoverable failure (lost worker, flaky collective)."""


class RetriesExhausted(RuntimeError):
    """``retry_with_backoff`` gave up after ``max_retries`` attempts."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One deterministic failure script for a resilient run."""

    crash_at: str | None = None        # stage to die on entry to
    transient_at: str | None = None    # stage that fails transiently...
    transient_count: int = 0           # ...on its first N attempts
    corrupt_stage: str | None = None   # corrupt this stage's checkpoint
    corrupt_leaf: int = 0              # which stored leaf file to damage
    slow: tuple = ()                   # ((stage, partition, seconds), ...)
    # --- stream chaos (keyed on absolute submission-batch index) ---
    stream_late_burst: tuple = ()      # ((batch, seconds), ...)
    stream_dup_storm: tuple = ()       # (batch, ...)
    stream_poison: tuple = ()          # ((batch, record_index), ...)
    stream_stall: tuple = ()           # (batch, ...) — skip the advance
    crash_at_advance: int = -1         # die entering this window advance

    # ------------------------------------------------------------------ api
    def validate(self) -> "FaultPlan":
        for name in ("crash_at", "transient_at", "corrupt_stage"):
            v = getattr(self, name)
            if v is not None and v not in _STAGES:
                raise ValueError(f"{name}={v!r}: expected one of {_STAGES}")
        if not isinstance(self.transient_count, int) or \
                self.transient_count < 0:
            raise ValueError("transient_count must be a non-negative int, "
                             f"got {self.transient_count!r}")
        if self.transient_count and self.transient_at is None:
            raise ValueError("transient_count without transient_at")
        if not isinstance(self.corrupt_leaf, int) or self.corrupt_leaf < 0:
            raise ValueError("corrupt_leaf must be a non-negative int, "
                             f"got {self.corrupt_leaf!r}")
        for entry in self.slow:
            if (len(tuple(entry)) != 3 or tuple(entry)[0] not in _STAGES):
                raise ValueError(f"slow entry {entry!r}: expected "
                                 "(stage, partition, seconds)")
        for name, width in (("stream_late_burst", 2), ("stream_poison", 2)):
            for entry in getattr(self, name):
                e = tuple(entry)
                if len(e) != width or int(e[0]) < 0:
                    raise ValueError(f"{name} entry {entry!r}: expected "
                                     f"a {width}-tuple keyed on a "
                                     "non-negative batch index")
        for name in ("stream_dup_storm", "stream_stall"):
            for b in getattr(self, name):
                if int(b) < 0:
                    raise ValueError(f"{name} entry {b!r}: expected a "
                                     "non-negative batch index")
        if not isinstance(self.crash_at_advance, int) or \
                self.crash_at_advance < -1:
            raise ValueError("crash_at_advance must be an int >= -1 "
                             f"(-1 disables), got {self.crash_at_advance!r}")
        return self

    def replace(self, **kw) -> "FaultPlan":
        return dataclasses.replace(self, **kw).validate()

    def slowdown(self, stage: str, partition: int) -> float:
        """Scripted extra seconds for a (stage, partition) cell."""
        return sum(float(s) for st, p, s in self.slow
                   if st == stage and int(p) == int(partition))

    # --------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for name in ("slow", "stream_late_burst", "stream_poison"):
            d[name] = [list(e) for e in getattr(self, name)]
        for name in ("stream_dup_storm", "stream_stall"):
            d[name] = [int(b) for b in getattr(self, name)]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        """Strict inverse of ``to_dict``: unknown keys raise (same contract
        as ``EnginePlan.from_dict``); missing keys take field defaults."""
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"unknown FaultPlan fields {sorted(unknown)}; "
                             f"known fields: {sorted(names)}")
        d = dict(d)
        for name in ("slow", "stream_late_burst", "stream_poison"):
            if name in d:
                d[name] = tuple(tuple(e) for e in d[name])
        for name in ("stream_dup_storm", "stream_stall"):
            if name in d:
                d[name] = tuple(int(b) for b in d[name])
        return cls(**d).validate()

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())


class FaultInjector:
    """Executes a :class:`FaultPlan` against the runner's stage hooks.

    The injector is stateful per *process* (transient attempt counts),
    while the plan is stateful per *run directory* via the checkpoints —
    matching the real failure model: a transient fault retries in-process,
    a crash kills the process and a new injector starts clean on resume.
    """

    def __init__(self, plan: FaultPlan | None):
        self.plan = plan or FaultPlan()
        self._attempts: dict[str, int] = {}

    def on_stage_enter(self, stage: str) -> None:
        """Raise the scripted failure for this stage, if any."""
        if self.plan.crash_at == stage:
            raise InjectedCrash(f"injected crash at stage {stage!r}")
        if self.plan.transient_at == stage:
            n = self._attempts.get(stage, 0)
            self._attempts[stage] = n + 1
            if n < self.plan.transient_count:
                raise TransientFault(
                    f"injected transient failure at stage {stage!r} "
                    f"(attempt {n + 1}/{self.plan.transient_count})")

    def on_checkpoint_written(self, stage: str, step_dir) -> bool:
        """Damage the stage's freshly-written checkpoint if scripted.
        Returns True when corruption was injected."""
        if self.plan.corrupt_stage != stage:
            return False
        leaves = sorted(Path(step_dir).glob("leaf_*.npy"))
        target = leaves[min(self.plan.corrupt_leaf, len(leaves) - 1)]
        blob = bytearray(target.read_bytes())
        # flip bits in the tail so the .npy header still parses and only
        # the CRC (not the loader) notices
        blob[-1] ^= 0xFF
        blob[len(blob) // 2] ^= 0xFF
        target.write_bytes(bytes(blob))
        return True

    def slowdown(self, stage: str, partition: int) -> float:
        return self.plan.slowdown(stage, partition)

    # ------------------------------------------------------------ stream hooks
    def on_stream_batch(self, batch_idx: int, recs):
        """Apply the scripted dirty-stream transforms to submission
        ``batch_idx`` (pure function of (plan, batch_idx, recs) — a
        resumed run replaying the same batch reproduces the same dirt).
        ``recs`` is a ``repro.stream.ingest.Records``; returns the same
        type."""
        from repro.stream.ingest import Records, concat_records
        obj = np.array(recs.obj, np.int32)
        x = np.array(recs.x, np.float32)
        y = np.array(recs.y, np.float32)
        t = np.array(recs.t, np.float32)
        for b, seconds in self.plan.stream_late_burst:
            if int(b) == batch_idx:
                t = t - np.float32(seconds)
        for b, idx in self.plan.stream_poison:
            if int(b) == batch_idx and recs.n:
                x[int(idx) % recs.n] = np.nan
                y[int(idx) % recs.n] = np.nan
        out = Records(obj, x, y, t)
        if batch_idx in {int(b) for b in self.plan.stream_dup_storm}:
            out = concat_records([out, out])
        return out

    def stall_batch(self, batch_idx: int) -> bool:
        """True when the scripted queue-pressure slowdown suppresses the
        window advance after submission ``batch_idx``."""
        return batch_idx in {int(b) for b in self.plan.stream_stall}

    def on_window_advance(self, advance_idx: int) -> None:
        """Raise the scripted crash on entry to window advance
        ``advance_idx`` (after the previous advance's snapshot landed)."""
        if self.plan.crash_at_advance == advance_idx:
            raise InjectedCrash(
                f"injected crash at window advance {advance_idx}")


def retry_with_backoff(fn: Callable, *, max_retries: int = 3,
                       base_delay: float = 0.5, max_delay: float = 30.0,
                       sleep: Optional[Callable[[float], None]] = None,
                       retry_on: tuple = (TransientFault,),
                       on_retry: Optional[Callable] = None):
    """Call ``fn()`` with bounded exponential backoff on transient errors.

    Delay before attempt ``i`` (1-based retries) is
    ``min(base_delay * 2**(i-1), max_delay)``.  ``sleep`` is injectable so
    tests assert the schedule against a recording fake instead of waiting;
    ``on_retry(attempt, delay, exc)`` feeds the runner's telemetry.
    Raises :class:`RetriesExhausted` (chaining the last error) after
    ``max_retries`` failed retries.
    """
    if sleep is None:                                   # pragma: no cover
        import time
        sleep = time.sleep
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as e:
            attempt += 1
            if attempt > max_retries:
                raise RetriesExhausted(
                    f"gave up after {max_retries} retries: {e}") from e
            delay = min(base_delay * 2.0 ** (attempt - 1), max_delay)
            if on_retry is not None:
                on_retry(attempt, delay, e)
            sleep(delay)
