"""Stage-resumable resilient DSC runner (DESIGN.md §10).

The DSC pipeline decomposes into five checkpointable stage boundaries:

    join/vote -> segment/table -> similarity -> cluster -> refine

Each stage here calls the SAME jitted stage bodies the monolithic entry
points compose (``repro.core.dsc.run_stage_*`` single-host,
``repro.core.distributed.build_dsc_stage_programs`` on a mesh), persists
its outputs as a flat ``{name: array}`` checkpoint through the atomic
CRC-verified :class:`repro.checkpoint.CheckpointManager`, and a rerun
resumes from the first incomplete stage — with final labels / SSCR / RMSE
bit-identical to a straight-through run (the parity-oracle contract PRs
1-6 applied to performance, applied here to recovery; gated by
``tests/test_resilient*.py``).

Checkpoints are *cumulative*: step k holds the full state after stages
1..k, so a resume needs only the newest readable step.  Restores descend
from the newest step and fall back one step per corrupt checkpoint
(``on_corruption="fallback"``; ``"fail"`` raises
:class:`CheckpointCorruption` instead — the launcher maps it to its own
exit code).  ``keep_n`` therefore defaults to every stage + 1.

Failure-class exit codes (``EXIT_CODES``) are what ``launch/run_dsc.py``
returns to the OS, so orchestrators can tell an exactness violation from
a corrupt store from a dead worker without parsing logs.

Top-K certificate violations follow ``on_overflow``:

* ``"widen"``  (default) — drop the similarity/cluster/refine state, double
  K, and re-run *only* those stages from the checkpointed join/segment
  state (the monolithic paths must re-join from scratch).
* ``"raise"``  — raise :class:`OverflowViolation`.
* ``"degrade"`` — finish with truncated lists; the violation count stays
  in ``sim_overflow`` / ``sim_diag[:, 3]`` and is telemetried.

Per-stage wall timings (plus any :class:`repro.run.faults.FaultPlan`
scripted slowdowns) feed the :class:`repro.distributed.straggler.
StragglerMonitor`; what happens to a flag is the
:class:`repro.run.rebalance.RebalancePolicy`'s call — emit an
``equi_depth_edges`` re-cut suggestion (``suggest_rebalance_edges``,
the default), or *apply* it: repartition the batch and all in-flight
per-point stage state at the new cut, rebuild the stage programs, and
checkpoint the post-rebalance state (``rebalanced`` telemetry event).
Everything is emitted as JSONL telemetry next to the checkpoints.

Distributed checkpoints additionally record the canonical layout key
(``meta/*`` leaves: cut edges + global point set + model-axis width), so
``elastic_resume=True`` can restore them onto a mesh with a *different*
partition count: join/segment state folds to global point space and
re-cuts for the new P (``repro.core.partitioning.gather_global`` /
``repartition``), later stages — whose state is partition-bound — rewind
to the segment boundary, and the finished run is bit-identical to a
straight-through run at the new P (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import dsc as dsc_mod
from repro.core.clustering import rmse_from_result, sscr_from_result
from repro.core.partitioning import (PointLayout, repartition,
                                     repartition_batch)
from repro.core.plan import EnginePlan, resolve_plan
from repro.core.types import (ClusteringResult, JoinResult,
                              SubtrajSegmentation, SubtrajTable, TopKSim)
from repro.distributed.straggler import (StragglerMonitor,
                                         suggest_rebalance_edges)
from repro.run.faults import FaultInjector, FaultPlan, retry_with_backoff
from repro.run.rebalance import RebalancePolicy
from repro.utils.logging import get_logger

log = get_logger("resilient")

STAGES = ("join", "segment", "similarity", "cluster", "refine")

# process exit code per failure class (launch/run_dsc.py returns these)
EXIT_CODES = {
    "ok": 0,
    "error": 1,
    "overflow": 3,
    "corruption": 4,
    "retries_exhausted": 5,
    "injected_crash": 6,
    "poison": 7,          # PoisonRecord under on_dirty="fail" (stream)
    "backpressure": 8,    # BackpressureOverflow / WatermarkStall (stream)
}


class CheckpointCorruption(RuntimeError):
    """A checkpoint failed CRC/shape/dtype verification and the policy is
    ``on_corruption="fail"`` (or no intact step remained to fall back to
    after discarding every corrupt one... which resolves to a fresh run,
    so this only fires under ``"fail"``)."""


class OverflowViolation(RuntimeError):
    """Top-K spill certificate violated under ``on_overflow="raise"``."""


# state keys owned by each stage (prefix match) — a widen drops exactly
# the similarity-and-later keys and re-runs from the segment checkpoint
_STAGE_KEYS = {
    "join": ("vote", "masks", "join/"),
    "segment": ("seg/", "table/", "labels"),
    "similarity": ("sim", "topk/", "moments/", "active"),
    "cluster": ("result/", "res/", "overflow", "diag"),
    "refine": ("final/", "sscr", "rmse"),
}

# the repartitionable subset of the distributed state: per-point leaves
# ([P, T, Mp, ...] in the partition layout) and the halo-slab-indexed
# join cube.  Everything else either is layout-free (the replicated
# table) or partition-bound (similarity onward — no partition-free form;
# elastic adaptation rewinds past it instead).
_POINT_LEAVES = ("vote", "masks", "labels", "join/best_w")
_CAND_IDX_LEAVES = ("join/best_idx",)

TELEMETRY_SCHEMA = 1


@dataclasses.dataclass
class ResilientResult:
    """What a resilient run hands back to the caller / launcher."""
    output: Any                    # DSCOutput | DistributedDSCOutput
    sscr: float
    rmse: float
    resumed_from: int              # completed stages found on disk (0=fresh)
    widen_count: int               # overflow-policy re-runs performed
    fallback_steps: list           # checkpoint steps discarded as corrupt
    events: list                   # telemetry events (also JSONL'd)
    rebalance_count: int = 0       # straggler re-cuts applied


class _Telemetry:
    """Append-only JSONL event stream + in-memory copy.

    Every event is flushed *and fsynced* before ``emit`` returns, so a
    crash loses at most the line being written — and
    :func:`read_telemetry` tolerates exactly that torn final line.
    """

    def __init__(self, path: Optional[Path], clock: Callable[[], float]):
        self.path = Path(path) if path is not None else None
        self.clock = clock
        self.events: list[dict] = []

    def emit(self, event: str, **fields):
        ev = {"schema": TELEMETRY_SCHEMA,
              "ts": round(float(self.clock()), 6), "event": event, **fields}
        self.events.append(ev)
        if self.path is not None:
            with open(self.path, "a") as f:
                f.write(json.dumps(ev) + "\n")
                f.flush()
                os.fsync(f.fileno())


# public name: the streaming service writes its events (window_advanced,
# record_quarantined, backpressure, late_dropped) through the same
# fsynced JSONL writer and schema as the batch runner
Telemetry = _Telemetry


def read_telemetry(path) -> list[dict]:
    """Parse a ``telemetry.jsonl`` stream, tolerating a truncated final
    line (the crash-mid-write window ``_Telemetry``'s per-event fsync
    leaves open).  Damage anywhere *before* the final line still raises
    ``ValueError`` — that is corruption, not a torn tail."""
    with open(path) as f:
        lines = f.read().split("\n")
    if lines and lines[-1] == "":
        lines.pop()                    # well-terminated file
    events: list[dict] = []
    for i, line in enumerate(lines):
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                break                  # torn final line: drop it
            raise ValueError(
                f"{path}: malformed telemetry at line {i + 1}") from None
    return events


def _drop_stage_keys(state: dict, stages) -> dict:
    prefixes = tuple(p for s in stages for p in _STAGE_KEYS[s])
    return {k: v for k, v in state.items()
            if not any(k == p or k.startswith(p) for p in prefixes)}


def _restore_with_fallback(mgr: CheckpointManager, on_corruption: str,
                           tel: _Telemetry):
    """Newest readable checkpoint, falling back a step per corrupt one.
    Returns ``(state, step, discarded_steps)`` — ``({}, 0, [...])`` when
    nothing (intact) is on disk."""
    discarded: list[int] = []
    for step in sorted(mgr.available_steps(), reverse=True):
        try:
            state, _ = mgr.restore_flat(step)
            return state, step, discarded
        except (IOError, EOFError, ValueError, KeyError,
                json.JSONDecodeError) as e:
            # EOFError/OSError cover a *truncated* leaf file (np.load
            # dies before the CRC check ever sees the short buffer)
            if on_corruption == "fail":
                raise CheckpointCorruption(
                    f"checkpoint step {step} failed verification: {e}"
                ) from e
            discarded.append(step)
            tel.emit("checkpoint_fallback", step=step, error=str(e))
            log.warning("checkpoint step %d corrupt (%s); falling back",
                        step, e)
    return {}, 0, discarded


def _check_policies(on_overflow: str, on_corruption: str):
    if on_overflow not in ("raise", "widen", "degrade"):
        raise ValueError(f"on_overflow={on_overflow!r}: expected "
                         "'raise', 'widen', or 'degrade'")
    if on_corruption not in ("fallback", "fail"):
        raise ValueError(f"on_corruption={on_corruption!r}: expected "
                         "'fallback' or 'fail'")


class _StageLoop:
    """The stage-graph executor shared by the single-host and distributed
    runners: checkpointing, resume, retries, fault hooks, overflow
    policy, and straggler telemetry.  Subclasses provide the stage bodies
    (``stage_<name>``) and the per-run geometry."""

    def __init__(self, *, plan: EnginePlan, checkpoint_dir, on_overflow,
                 on_corruption, fault_plan, max_retries, sleep, clock,
                 monitor, n_partitions: int, S: int,
                 rebalance: RebalancePolicy | None = None,
                 sync_saves: bool = False):
        _check_policies(on_overflow, on_corruption)
        self.plan = plan
        self.on_overflow = on_overflow
        self.on_corruption = on_corruption
        self.injector = FaultInjector(fault_plan)
        bad = sorted({int(p) for _, p, _ in self.injector.plan.slow
                      if not 0 <= int(p) < n_partitions})
        if bad:
            raise ValueError(
                f"FaultPlan.slow references partition(s) {bad} but this "
                f"run has {n_partitions} partition(s) (valid indices "
                f"0..{n_partitions - 1})")
        self.max_retries = max_retries
        self.sleep = sleep
        self.clock = clock if clock is not None else time.perf_counter
        self.nP = n_partitions
        self.S = S
        self.sync_saves = sync_saves
        self.rebalance = (rebalance if rebalance is not None
                          else RebalancePolicy()).validate()
        self.mgr = None
        tel_path = None
        if checkpoint_dir is not None:
            self.mgr = CheckpointManager(checkpoint_dir,
                                         keep_n=len(STAGES) + 1)
            self.mgr.root.mkdir(parents=True, exist_ok=True)
            tel_path = self.mgr.root / "telemetry.jsonl"
        self.tel = _Telemetry(tel_path, self.clock)
        self.monitor = monitor if monitor is not None else \
            StragglerMonitor(n_partitions)
        self.widen_count = 0
        self.rebalance_count = 0
        self._flag_streak = 0
        self._last_flagged: dict[int, float] = {}

    # ---- hooks a subclass provides -----------------------------------
    def rebalance_inputs(self):
        """``(times, part_of)`` of all valid points, or None (P == 1)."""
        return None

    def current_k(self, state: dict) -> int:
        """K of the top-K lists currently in ``state`` (or planned)."""
        if "topk/ids" in state:
            return int(state["topk/ids"].shape[-1])
        k = self.plan.sim_topk if self.plan.sim_topk is not None else 32
        return min(k, self.S)

    def overflow_count(self, state: dict) -> int:
        raise NotImplementedError

    # ---- elastic / rebalance hooks (distributed loop overrides) ------
    def extra_leaves(self) -> dict:
        """Layout-metadata leaves merged into every checkpoint save."""
        return {}

    def adapt_restored_state(self, state: dict, done: int):
        """Map a restored checkpoint onto this run's layout.  The base
        runner has no layout: just strip the ``meta/*`` leaves
        ``extra_leaves`` may have added."""
        return {k: v for k, v in state.items()
                if not k.startswith("meta/")}, done

    def _maybe_rebalance(self, stage: str, step: int, state: dict):
        return state

    # ---- executor ----------------------------------------------------
    def _comm_schedule(self) -> dict:
        """The active communication schedules (DESIGN.md §12), stamped on
        every straggler-relevant telemetry event so a trace can correlate
        per-partition timings with the collective schedule in force."""
        return {"halo_stream": self.plan.halo_stream,
                "sim_exchange": self.plan.sim_exchange}

    def _run_stage(self, stage: str, state: dict) -> dict:
        def attempt():
            self.injector.on_stage_enter(stage)
            return getattr(self, f"stage_{stage}")(state)

        def on_retry(n, delay, exc):
            self.tel.emit("retry", stage=stage, attempt=n,
                          delay_s=delay, error=str(exc))

        t0 = self.clock()
        updates = retry_with_backoff(attempt, max_retries=self.max_retries,
                                     sleep=self.sleep, on_retry=on_retry)
        wall = self.clock() - t0
        times = [wall + self.injector.slowdown(stage, p)
                 for p in range(self.nP)]
        self.monitor.record_all(times)
        flagged = self.monitor.check()
        self.tel.emit("stage_done", stage=stage,
                      step=STAGES.index(stage) + 1, wall_s=round(wall, 6),
                      per_partition_s=[round(t, 6) for t in times],
                      comm=self._comm_schedule())
        self._flag_streak = self._flag_streak + 1 if flagged else 0
        self._last_flagged = dict(flagged)
        if flagged:
            self.tel.emit("straggler_flagged",
                          stage=stage, partitions={
                              str(p): round(r, 3)
                              for p, r in flagged.items()},
                          comm=self._comm_schedule())
            ri = self.rebalance_inputs() \
                if self.rebalance.mode != "off" else None
            if ri is not None:
                edges = suggest_rebalance_edges(ri[0], ri[1], flagged,
                                                self.nP)
                self.tel.emit("rebalance_suggestion",
                              stage=stage, edges=[
                                  float(e) for e in edges])
        state = dict(state)
        # land every stage output as a HOST copy before it enters the
        # loop state: the stage entry points donate their dead inputs
        # (DESIGN.md §12), and a donated device buffer must never alias a
        # checkpoint leaf (the async save of step k overlaps stage k+1)
        # or a leaf a later stage re-reads (the single-host score stage
        # re-uses the dense sim the cluster stage donates).  numpy inputs
        # are always safely donatable: jit uploads a fresh device copy.
        state.update({k: np.asarray(v) for k, v in updates.items()})
        return state

    def _save(self, step: int, stage: str, state: dict):
        if self.mgr is None:
            return
        tree = dict(state)
        tree.update(self.extra_leaves())
        if self.sync_saves:
            self.mgr.save(step, tree)
        else:
            # async: the save of step k overlaps stage k+1; every save /
            # restore / injection point barriers through mgr.wait()
            self.mgr.save_async(step, tree)
        if self.injector.plan.corrupt_stage == stage:
            self.mgr.wait()     # injection edits files: land them first
            if self.injector.on_checkpoint_written(stage,
                                                   self.mgr.step_dir(step)):
                self.tel.emit("checkpoint_corrupted_injected", stage=stage,
                              step=step)

    def _apply_overflow_policy(self, state, done):
        """Check the spill certificate once the cluster stage is in
        ``state`` (whether it just ran or was restored) and apply
        ``on_overflow``.  Returns ``(state, done)`` — rewound to the
        segment checkpoint for a widen."""
        if (self.plan.sim_mode != "topk"
                or done < STAGES.index("cluster") + 1):
            return state, done
        overflow = self.overflow_count(state)
        if overflow == 0:
            return state, done
        k = self.current_k(state)
        if self.on_overflow == "degrade":
            self.tel.emit("overflow_degraded", k=k, rows=overflow)
            return state, done
        if self.on_overflow == "raise":
            raise OverflowViolation(
                f"sim_topk={k} truncated a potential alpha-edge on "
                f"{overflow} rows (spill >= alpha): labels would not be "
                "exact.  Raise sim_topk or use on_overflow='widen'.")
        if k >= self.S:       # unreachable: K == S cannot spill
            raise AssertionError("overflow with K == S")
        # stage-level widen: similarity onward re-runs from the
        # checkpointed segment state with K doubled
        new_k = min(2 * k, self.S)
        self.widen_count += 1
        self.tel.emit("widen", k_from=k, k_to=new_k, rows=overflow)
        self.plan = self.plan.replace(sim_topk=new_k)
        self.on_plan_widened()
        state = _drop_stage_keys(state,
                                 ("similarity", "cluster", "refine"))
        return state, STAGES.index("segment") + 1

    def run(self):
        try:
            out = self._execute()
        except BaseException:
            # an in-flight async save must land even when the run dies:
            # the resume point is defined by the last *completed* stage,
            # and its checkpoint may still be on the writer thread
            if self.mgr is not None:
                try:
                    self.mgr.wait()
                except Exception as e:  # noqa: BLE001 — crash path
                    log.warning("async save failed during crash: %s", e)
            raise
        if self.mgr is not None:
            self.mgr.wait()     # surface async save errors before return
        return out

    def _execute(self):
        if self.mgr is not None:
            state, done, discarded = _restore_with_fallback(
                self.mgr, self.on_corruption, self.tel)
        else:
            state, done, discarded = {}, 0, []
        state, done = self.adapt_restored_state(state, done)
        resumed_from = done
        self.tel.emit("run_start", resumed_from_step=done,
                      plan_sim_mode=self.plan.sim_mode,
                      on_overflow=self.on_overflow)
        # a crash may have landed between the cluster checkpoint and the
        # widen re-run it demanded — re-apply the policy to restored state
        state, done = self._apply_overflow_policy(state, done)
        while True:
            for step in range(done + 1, len(STAGES) + 1):
                stage = STAGES[step - 1]
                state = self._run_stage(stage, state)
                state = self._maybe_rebalance(stage, step, state)
                self._save(step, stage, state)
                done = step
                if stage == "cluster":
                    state, done = self._apply_overflow_policy(state, done)
                    if done < step:
                        break               # widened: rewind to segment
            else:
                break
        self.tel.emit("run_done", widen_count=self.widen_count)
        return state, resumed_from, discarded

    def on_plan_widened(self):
        """Subclass hook: rebuild anything keyed on plan.sim_topk."""


# ===================================================================== #
# single-host                                                           #
# ===================================================================== #


class _SingleHostLoop(_StageLoop):
    def __init__(self, batch, params, **kw):
        self.batch = batch
        self.params = params
        super().__init__(n_partitions=1,
                         S=batch.num_trajs * params.max_subtrajs_per_traj,
                         **kw)
        # host-side planning is deterministic, so recomputing it on
        # resume reproduces the original run exactly (never checkpointed)
        self.tile_ids, self.plan = dsc_mod.plan_fused_tile_ids(
            batch, params, self.plan)
        self.plan = self.plan.replace(sim_topk=self.current_k({}))

    def overflow_count(self, state):
        return int(state["overflow"])

    # ---- stage bodies (flat-state in, flat-state updates out) --------
    def stage_join(self, state):
        b, p, plan = self.batch, self.params, self.plan
        if plan.mode == "fused":
            vote, masks = dsc_mod.run_stage_join_fused(
                b, p, self.tile_ids, plan)
            join = None
        elif plan.use_index and plan.use_kernel:
            from repro.kernels.stjoin import ops as stjoin_ops
            join = stjoin_ops.subtrajectory_join(
                b, b, p.eps_sp, p.eps_t, p.delta_t, use_index=True)
            vote, masks = dsc_mod.run_stage_vote_from_join(b, p, join, plan)
        else:
            join, vote, masks = dsc_mod.run_stage_join(b, p, plan)
        out = {"vote": vote}
        if masks is not None:
            out["masks"] = masks
        if join is not None:
            out["join/best_w"] = join.best_w
            out["join/best_idx"] = join.best_idx
        return out

    def _join_of(self, state):
        if "join/best_w" not in state:
            return None
        return JoinResult(best_w=np.asarray(state["join/best_w"]),
                          best_idx=np.asarray(state["join/best_idx"]))

    def _seg_of(self, state):
        return SubtrajSegmentation(
            cut=state["seg/cut"], sub_local=state["seg/sub_local"],
            num_subs=state["seg/num_subs"], score=state["seg/score"])

    def _table_of(self, state):
        return SubtrajTable(
            t_start=state["table/t_start"], t_end=state["table/t_end"],
            voting=state["table/voting"], card=state["table/card"],
            valid=state["table/valid"], traj_row=state["table/traj_row"])

    def stage_segment(self, state):
        seg, table = dsc_mod.run_stage_segment(
            self.batch, self.params, state["vote"], state.get("masks"),
            self.plan)
        return {"seg/cut": seg.cut, "seg/sub_local": seg.sub_local,
                "seg/num_subs": seg.num_subs, "seg/score": seg.score,
                "table/t_start": table.t_start,
                "table/t_end": table.t_end, "table/voting": table.voting,
                "table/card": table.card, "table/valid": table.valid,
                "table/traj_row": table.traj_row}

    def stage_similarity(self, state):
        sim, topk = dsc_mod.run_stage_similarity(
            self.batch, self.params, self._join_of(state),
            self._seg_of(state), self._table_of(state), self.tile_ids,
            self.plan)
        if topk is not None:
            return {"topk/ids": topk.ids, "topk/sims": topk.sims,
                    "topk/spill": topk.spill, "topk/degree": topk.degree,
                    "topk/row_sum": topk.row_sum,
                    "topk/row_sumsq": topk.row_sumsq}
        return {"sim": sim}

    def _simlike_of(self, state):
        if "topk/ids" in state:
            return TopKSim(ids=state["topk/ids"], sims=state["topk/sims"],
                           spill=state["topk/spill"],
                           degree=state["topk/degree"],
                           row_sum=state["topk/row_sum"],
                           row_sumsq=state["topk/row_sumsq"])
        return state["sim"]

    def stage_cluster(self, state):
        result, overflow = dsc_mod.run_stage_cluster(
            self._simlike_of(state), self._table_of(state), self.params,
            self.plan)
        out = {"result/member_of": result.member_of,
               "result/member_sim": result.member_sim,
               "result/is_rep": result.is_rep,
               "result/is_outlier": result.is_outlier,
               "result/alpha_used": result.alpha_used,
               "result/k_used": result.k_used,
               "overflow": (overflow if overflow is not None
                            else np.zeros((), np.int32))}
        return out

    def _result_of(self, state):
        return ClusteringResult(
            member_of=state["result/member_of"],
            member_sim=state["result/member_sim"],
            is_rep=state["result/is_rep"],
            is_outlier=state["result/is_outlier"],
            alpha_used=state["result/alpha_used"],
            k_used=state["result/k_used"])

    def stage_refine(self, state):
        # single-host stage 5 is the scoring epilogue (there is no
        # cross-partition state to reconcile)
        sscr_v, rmse_v = dsc_mod.run_stage_score(
            self._result_of(state), state.get("sim"), self.params)
        return {"sscr": sscr_v, "rmse": rmse_v}

    def to_output(self, state) -> dsc_mod.DSCOutput:
        topk = self._simlike_of(state) if "topk/ids" in state else None
        return dsc_mod.DSCOutput(
            join=self._join_of(state), vote=state["vote"],
            seg=self._seg_of(state), table=self._table_of(state),
            sim=state.get("sim"), sim_topk=topk,
            sim_overflow=(state["overflow"]
                          if self.plan.sim_mode == "topk" else None),
            result=self._result_of(state), sscr=state["sscr"],
            rmse=state["rmse"])


def run_resilient(batch, params, *, plan: EnginePlan | None = None,
                  checkpoint_dir=None, on_overflow: str = "widen",
                  on_corruption: str = "fallback",
                  fault_plan: FaultPlan | None = None,
                  max_retries: int = 3, sleep=None, clock=None,
                  monitor: StragglerMonitor | None = None,
                  rebalance: RebalancePolicy | None = None,
                  sync_saves: bool = False,
                  **legacy) -> ResilientResult:
    """Single-host resilient run; see the module docstring.

    ``checkpoint_dir=None`` runs the stage graph without persistence
    (faults still inject; resume is impossible).  ``**legacy`` accepts
    the same deprecated per-stage flags as :func:`repro.core.dsc.run_dsc`.
    """
    plan = resolve_plan(plan, **legacy)
    loop = _SingleHostLoop(batch, params, plan=plan,
                           checkpoint_dir=checkpoint_dir,
                           on_overflow=on_overflow,
                           on_corruption=on_corruption,
                           fault_plan=fault_plan, max_retries=max_retries,
                           sleep=sleep, clock=clock, monitor=monitor,
                           rebalance=rebalance, sync_saves=sync_saves)
    state, resumed, discarded = loop.run()
    out = loop.to_output(state)
    return ResilientResult(output=out, sscr=float(out.sscr),
                           rmse=float(out.rmse), resumed_from=resumed,
                           widen_count=loop.widen_count,
                           fallback_steps=discarded,
                           events=loop.tel.events,
                           rebalance_count=loop.rebalance_count)


# ===================================================================== #
# distributed                                                           #
# ===================================================================== #


class _DistributedLoop(_StageLoop):
    def __init__(self, parts, params, mesh, part_axis, model_axis,
                 elastic_resume: bool = False, **kw):
        self.parts = parts
        self.params = params
        self.mesh = mesh
        self.part_axis = part_axis
        self.model_axis = model_axis
        self.elastic_resume = bool(elastic_resume)
        nP = mesh.shape[part_axis]
        self.nM = mesh.shape[model_axis]
        T = parts.x.shape[1]
        super().__init__(n_partitions=nP,
                         S=T * params.max_subtrajs_per_traj, **kw)
        try:
            self._layout = PointLayout.from_parts(parts)
        except ValueError:
            self._layout = None     # hand-built batch: no edges/src_m
        if self.elastic_resume and self._layout is None:
            raise ValueError(
                "elastic_resume=True needs a PartitionedBatch produced "
                "by partition_batch/repartition_batch (carrying "
                "edges/src_m); a hand-built batch has no canonical "
                "layout to adapt from")
        self.plan = self.plan.replace(sim_topk=self.current_k({}))
        self._build()

    def _build(self):
        from repro.core.distributed import build_dsc_stage_programs
        self.progs = build_dsc_stage_programs(
            self.parts, self.params, self.mesh, part_axis=self.part_axis,
            model_axis=self.model_axis, plan=self.plan)

    def on_plan_widened(self):
        self._build()

    def overflow_count(self, state):
        return int(np.asarray(state["diag"])[:, 3].sum())

    def rebalance_inputs(self):
        pt = np.asarray(self.parts.t)
        pv = np.asarray(self.parts.valid)
        part_of = np.broadcast_to(
            np.arange(pt.shape[0])[:, None, None], pt.shape)
        return pt[pv], part_of[pv]

    # ---- elastic resume + adaptive repartitioning (DESIGN.md §11) ----
    def extra_leaves(self):
        if self._layout is None:
            return {}
        lay = self._layout
        return {"meta/schema": np.int32(1),
                "meta/edges": np.asarray(lay.edges, np.float64),
                "meta/point_t": np.asarray(lay.t),
                "meta/point_valid": np.asarray(lay.valid),
                "meta/model_width": np.int32(self.nM)}

    def _repartition_state(self, state, old, new):
        out = {}
        for k, v in state.items():
            if k in _POINT_LEAVES:
                out[k] = repartition(v, old, new, kind="point")
            elif k in _CAND_IDX_LEAVES:
                out[k] = repartition(v, old, new, kind="cand_idx")
            else:
                out[k] = v      # replicated table/* etc. — layout-free
        return out

    def adapt_restored_state(self, state, done):
        meta = {k: np.asarray(v) for k, v in state.items()
                if k.startswith("meta/")}
        state = {k: v for k, v in state.items()
                 if not k.startswith("meta/")}
        if done == 0 or not meta or self._layout is None:
            # pre-elastic checkpoint / hand-built batch: same-mesh
            # resume only (shape mismatches surface downstream)
            return state, done
        old_edges = np.asarray(meta["meta/edges"], np.float64)
        old_P = old_edges.shape[0] - 1
        if old_P != self.nP and not self.elastic_resume:
            raise ValueError(
                f"checkpoint was written at P={old_P} but this mesh has "
                f"P={self.nP}; pass elastic_resume=True "
                "(--elastic-resume) to adapt it")
        new = self._layout
        old_mp = int(np.asarray(state["vote"]).shape[2])
        old = PointLayout.from_global(meta["meta/point_t"],
                                      meta["meta/point_valid"],
                                      old_edges, Mp=old_mp)
        if not old.same_points(new):
            raise ValueError(
                "elastic resume: the checkpoint's global point set "
                "differs from this run's batch — refusing to mix runs")
        if old.same_layout(new):
            return state, done
        old_nm = int(meta["meta/model_width"])
        if old_nm != self.nM:
            raise ValueError(
                f"checkpoint was written with model-axis width {old_nm} "
                f"but this mesh has {self.nM}; only the partition axis "
                "is elastic")
        if old.P == new.P:
            # same partition count, different cut: a crash after an
            # applied rebalance.  Adopt the checkpoint's layout (re-cut
            # the batch at its edges) instead of repartitioning state —
            # the later-stage partition-bound leaves stay valid, so no
            # rewind is needed.
            self.parts = repartition_batch(self.parts, old_edges)
            self._layout = PointLayout.from_parts(self.parts)
            if not self._layout.same_layout(old):
                raise AssertionError("edge adoption did not converge")
            self._build()
            self.tel.emit("elastic_adopt_edges", step=done,
                          edges=[float(e) for e in old_edges])
            return state, done
        # different partition count: fold the join/segment point state
        # to global row space and re-cut it for this mesh.  Similarity
        # onward is partition-bound (per-partition moments feed the
        # alpha/k statistics), so rewind to the segment boundary.
        new_done = min(done, STAGES.index("segment") + 1)
        if new_done < done:
            state = _drop_stage_keys(state, STAGES[new_done:])
        state = self._repartition_state(state, old, new)
        self.tel.emit("elastic_resume", from_partitions=old.P,
                      to_partitions=new.P, from_step=done,
                      to_step=new_done)
        log.info("elastic resume: P=%d checkpoint (step %d) adapted to "
                 "P=%d (step %d)", old.P, done, new.P, new_done)
        return state, new_done

    def _maybe_rebalance(self, stage, step, state):
        pol = self.rebalance
        if (pol.mode != "apply" or not self._last_flagged
                or self._flag_streak < pol.consecutive
                or self.rebalance_count >= pol.max_applies
                or stage not in ("join", "segment")
                or self._layout is None):
            return state
        times, part_of = self.rebalance_inputs()
        edges = np.asarray(
            suggest_rebalance_edges(times, part_of, self._last_flagged,
                                    self.nP), np.float64)
        old = self._layout
        self.parts = repartition_batch(self.parts, edges)
        self._layout = PointLayout.from_parts(self.parts)
        state = self._repartition_state(state, old, self._layout)
        self._build()
        for p in range(self.nP):
            self.monitor.reset(p)
        self._flag_streak = 0
        self._last_flagged = {}
        self.rebalance_count += 1
        self.tel.emit("rebalanced", stage=stage, step=step,
                      applies=self.rebalance_count,
                      edges=[float(e) for e in self._layout.edges],
                      comm=self._comm_schedule())
        log.info("rebalanced after %s at the straggler-weighted cut "
                 "(apply %d/%d)", stage, self.rebalance_count,
                 pol.max_applies)
        return state

    # ---- stage bodies -------------------------------------------------
    def stage_join(self, state):
        p = self.parts
        st = self.progs["join"](p.x, p.y, p.t, p.valid, p.traj_id,
                                p.ranges)
        out = {"vote": st[0], "masks": st[1]}
        if len(st) == 4:
            out["join/best_w"], out["join/best_idx"] = st[2], st[3]
        return out

    def _table_of(self, state):
        return SubtrajTable(
            t_start=state["table/t_start"], t_end=state["table/t_end"],
            voting=state["table/voting"], card=state["table/card"],
            valid=state["table/valid"], traj_row=state["table/traj_row"])

    def stage_segment(self, state):
        table, labels = self.progs["segment"](
            self.parts.t, self.parts.valid, state["vote"], state["masks"])
        return {"table/t_start": table.t_start,
                "table/t_end": table.t_end, "table/voting": table.voting,
                "table/card": table.card, "table/valid": table.valid,
                "table/traj_row": table.traj_row, "labels": labels}

    def stage_similarity(self, state):
        p = self.parts
        cube = (() if "join/best_w" not in state else
                (state["join/best_w"], state["join/best_idx"]))
        st = self.progs["similarity"](
            p.x, p.y, p.t, p.valid, p.traj_id, p.ranges, state["labels"],
            self._table_of(state), *cube)
        if self.plan.sim_mode == "topk":
            ids, sims, spill, degree, rsum, rsumsq, active = st
            return {"topk/ids": ids, "topk/sims": sims,
                    "topk/spill": spill, "topk/degree": degree,
                    "topk/row_sum": rsum, "topk/row_sumsq": rsumsq,
                    "active": active}
        sim, cnt, rsum, rsumsq, active = st
        return {"sim": sim, "moments/cnt": cnt, "moments/rsum": rsum,
                "moments/rsumsq": rsumsq, "active": active}

    def stage_cluster(self, state):
        if self.plan.sim_mode == "topk":
            sim_state = (state["topk/ids"], state["topk/sims"],
                         state["topk/spill"], state["topk/degree"],
                         state["topk/row_sum"], state["topk/row_sumsq"])
        else:
            sim_state = (state["sim"], state["moments/cnt"],
                         state["moments/rsum"], state["moments/rsumsq"])
        member, msim, rep, outl, alpha, k, diag = self.progs["cluster"](
            self._table_of(state), state["active"], *sim_state)
        return {"res/member_of": member, "res/member_sim": msim,
                "res/is_rep": rep, "res/is_outlier": outl,
                "res/alpha": alpha, "res/k": k, "diag": diag}

    def stage_refine(self, state):
        final = self.progs["refine"](
            state["res/member_of"], state["res/member_sim"],
            state["res/is_rep"], state["active"], state["res/alpha"],
            state["res/k"])
        out = {f"final/{f}": getattr(final, f)
               for f in ("member_of", "member_sim", "is_rep",
                         "is_outlier", "alpha_used", "k_used")}
        out["sscr"] = sscr_from_result(final)
        out["rmse"] = rmse_from_result(final, self.params.eps_sp)
        return out

    def to_output(self, state):
        from repro.core.distributed import DistributedDSCOutput
        final = ClusteringResult(
            member_of=state["final/member_of"],
            member_sim=state["final/member_sim"],
            is_rep=state["final/is_rep"],
            is_outlier=state["final/is_outlier"],
            alpha_used=state["final/alpha_used"],
            k_used=state["final/k_used"])
        return DistributedDSCOutput(
            result=final, table=self._table_of(state),
            vote=state["vote"], active=state["active"],
            sim_diag=state["diag"])


def run_resilient_distributed(parts, params, mesh, *,
                              part_axis: str = "part",
                              model_axis: str = "model",
                              plan: EnginePlan | None = None,
                              checkpoint_dir=None,
                              on_overflow: str = "widen",
                              on_corruption: str = "fallback",
                              fault_plan: FaultPlan | None = None,
                              max_retries: int = 3, sleep=None, clock=None,
                              monitor: StragglerMonitor | None = None,
                              rebalance: RebalancePolicy | None = None,
                              sync_saves: bool = False,
                              elastic_resume: bool = False,
                              **legacy) -> ResilientResult:
    """Distributed resilient run over ``mesh``; see the module docstring.

    Stage programs come from ``build_dsc_stage_programs`` — the same
    phase bodies as the monolithic ``run_dsc_distributed``, one
    ``shard_map`` per stage, with inter-stage state round-tripping
    through the host (and the checkpoint store).  Unlike the monolith's
    ``on_overflow="widen"`` (which rebuilds and re-runs everything), the
    stage-level widen here restarts from the checkpointed segment state.
    """
    plan = resolve_plan(plan, **legacy)
    loop = _DistributedLoop(parts, params, mesh, part_axis, model_axis,
                            plan=plan, checkpoint_dir=checkpoint_dir,
                            on_overflow=on_overflow,
                            on_corruption=on_corruption,
                            fault_plan=fault_plan, max_retries=max_retries,
                            sleep=sleep, clock=clock, monitor=monitor,
                            rebalance=rebalance, sync_saves=sync_saves,
                            elastic_resume=elastic_resume)
    state, resumed, discarded = loop.run()
    out = loop.to_output(state)
    return ResilientResult(output=out, sscr=float(state["sscr"]),
                           rmse=float(state["rmse"]), resumed_from=resumed,
                           widen_count=loop.widen_count,
                           fallback_steps=discarded,
                           events=loop.tel.events,
                           rebalance_count=loop.rebalance_count)
