"""Resilient execution layer: stage-graph runner + deterministic faults.

``repro.run.resilient`` decomposes the DSC pipeline into checkpointable
stage boundaries and resumes from the first incomplete stage;
``repro.run.faults`` scripts deterministic failures (crash, transient
error, checkpoint corruption, slowdown) against those boundaries so the
recovery paths are testable without real crashes (DESIGN.md §10);
``repro.run.rebalance`` decides what the runner does with straggler
flags — suggest or apply a slowdown-weighted repartitioning
(DESIGN.md §11).
"""
from repro.run.faults import (FaultInjector, FaultPlan, InjectedCrash,
                              RetriesExhausted, TransientFault,
                              retry_with_backoff)
from repro.run.rebalance import RebalancePolicy
from repro.run.resilient import (EXIT_CODES, TELEMETRY_SCHEMA,
                                 CheckpointCorruption, ResilientResult,
                                 Telemetry, read_telemetry, run_resilient,
                                 run_resilient_distributed)

__all__ = [
    "FaultPlan", "FaultInjector", "InjectedCrash", "TransientFault",
    "RetriesExhausted", "retry_with_backoff", "CheckpointCorruption",
    "RebalancePolicy", "ResilientResult", "run_resilient",
    "run_resilient_distributed", "read_telemetry", "Telemetry",
    "TELEMETRY_SCHEMA", "EXIT_CODES",
]
