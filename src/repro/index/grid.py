"""Fixed-resolution spatiotemporal grid index for candidate-tile pruning.

The DTJ join is the dominant cost of the whole DSC pipeline; the paper (and
its companion "Distributed Subtrajectory Join on Massive Datasets") gets its
scalability from discarding candidate pairs *before* the expensive refine
step.  This module is that filter, recast for fixed-shape JAX: instead of a
dynamic R-tree over individual points we index *tiles* — the same ``[bp]``
reference-point blocks and ``[bc, Mc]`` candidate-trajectory blocks the
Pallas ``stjoin`` kernel iterates — and emit, per reference block, the
compacted list of candidate tiles that can possibly contain a match.

Cell-size contract (eps-derived)
--------------------------------
A match requires ``d_sp <= eps_sp`` and ``|dt| <= eps_t``, so the natural
grid resolution is the matching threshold itself: cells are
``eps_sp x eps_sp x eps_t`` (spatial x, spatial y, time), clamped so no
axis exceeds ``max_cells_per_axis`` (coarser cells on huge domains — the
index gets less selective, never incorrect).  With cells >= the matching
radius, every point within ``eps`` of a cell lies in that cell's 3^3
neighborhood, which is what makes the coarse cell test below conservative.

Pruning is two-staged and *conservative by construction*:

1. coarse — candidate tiles are bucketed by the grid cell of their bbox
   center (CSR-style: ``order``/``starts`` arrays, built under ``jit``);
   a reference tile keeps the cells overlapping its bbox expanded by
   ``eps + max tile half-extent`` per axis.
2. exact  — surviving tiles are re-checked with the eps-expanded
   bounding-box distance test (Euclidean in space, interval in time), so a
   kept tile really can contain a matching point pair and a dropped tile
   provably cannot.

Because stage 2 never drops a tile that could match, the pruned join is
*bit-identical* to the dense join (``tests/test_index.py`` enforces this),
while the surviving-tile count — the quantity ``benchmarks/kernel_bench.py``
records — shrinks with data clustering exactly as the paper's Fig. 8 run
does.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.utils.tree import pytree_dataclass


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static grid geometry: origin, cell sizes, cell counts per axis.

    Static (hashable) so it can close over ``jit``-compiled functions; the
    data-dependent parts (tile bboxes, CSR tables) are traced arrays.
    """

    x0: float
    y0: float
    t0: float
    cell_sp: float       # spatial cell edge (x and y), >= eps_sp
    cell_t: float        # temporal cell extent, >= eps_t
    nx: int
    ny: int
    nt: int

    @property
    def num_cells(self) -> int:
        return self.nx * self.ny * self.nt


@pytree_dataclass
class TileBoxes:
    """Per-tile axis-aligned bounding boxes over the *valid* points only."""

    xmin: jnp.ndarray    # [n] float32 (+inf for empty tiles)
    xmax: jnp.ndarray    # [n] float32 (-inf for empty tiles)
    ymin: jnp.ndarray
    ymax: jnp.ndarray
    tmin: jnp.ndarray
    tmax: jnp.ndarray
    nonempty: jnp.ndarray  # [n] bool — tile holds >= 1 valid point

    @property
    def num_tiles(self) -> int:
        return self.xmin.shape[0]


@pytree_dataclass
class CellTable:
    """CSR-style cell -> tile-id lists (tiles sorted by their center cell).

    ``order[starts[c]:starts[c+1]]`` are the tile ids whose bbox center
    falls in cell ``c``; empty tiles are parked past ``starts[-1]``.
    """

    order: jnp.ndarray    # [n] int32 tile ids, cell-sorted
    starts: jnp.ndarray   # [num_cells + 1] int32 CSR offsets
    cell_of: jnp.ndarray  # [n] int32 center cell id (num_cells for empties)
    coords: jnp.ndarray   # [n, 3] int32 (ix, iy, it) center cell coords


@pytree_dataclass
class PruneStats:
    """What the index did: dense vs surviving candidate-tile counts."""

    kept_tiles: jnp.ndarray    # [] int32 — sum over ref tiles of survivors
    dense_tiles: int           # static: n_ref_tiles * n_cand_tiles
    max_per_ref: jnp.ndarray   # [] int32 — worst-case survivors per ref tile


# --------------------------------------------------------------------------
# bbox construction
# --------------------------------------------------------------------------

def _masked_boxes(x, y, t, valid):
    """Min/max over the last axis with invalid slots neutralized."""
    inf = jnp.float32(jnp.inf)
    lo = lambda a: jnp.min(jnp.where(valid, a, inf), axis=-1)
    hi = lambda a: jnp.max(jnp.where(valid, a, -inf), axis=-1)
    return TileBoxes(
        xmin=lo(x), xmax=hi(x), ymin=lo(y), ymax=hi(y),
        tmin=lo(t), tmax=hi(t), nonempty=jnp.any(valid, axis=-1))


def point_block_boxes(x, y, t, valid, block: int) -> TileBoxes:
    """Bboxes of consecutive ``block``-point groups of flattened arrays.

    Inputs are ``[P]`` with ``P % block == 0`` (the stjoin kernel's padded
    reference layout); output tiles align with the kernel's ``i`` grid axis.
    """
    P = x.shape[0]
    assert P % block == 0, (P, block)
    n = P // block
    rs = lambda a: a.reshape(n, block)
    return _masked_boxes(rs(x), rs(y), rs(t), rs(valid))


def traj_block_boxes(x, y, t, valid, block: int) -> TileBoxes:
    """Bboxes of ``block`` consecutive trajectory rows (all their points).

    Inputs are ``[C, Mc]`` with ``C % block == 0``; output tiles align with
    the kernel's candidate ``j`` grid axis.
    """
    C, Mc = x.shape
    assert C % block == 0, (C, block)
    n = C // block
    rs = lambda a: a.reshape(n, block * Mc)
    return _masked_boxes(rs(x), rs(y), rs(t), rs(valid))


# --------------------------------------------------------------------------
# grid fitting + CSR cell table
# --------------------------------------------------------------------------

def fit_grid(boxes: TileBoxes, eps_sp: float, eps_t: float, *,
             max_cells_per_axis: int = 64) -> GridSpec:
    """Host-side: derive a static GridSpec from concrete tile bboxes.

    Cell sizes start at the matching thresholds (``eps_sp``, ``eps_t``) and
    are coarsened only when the domain would need more than
    ``max_cells_per_axis`` cells on some axis.  Empty inputs yield a 1-cell
    grid.
    """
    ne = np.asarray(boxes.nonempty)
    eps_sp = float(eps_sp)
    eps_t = float(eps_t)

    def axis(lo_a, hi_a, base):
        if not ne.any():
            return 0.0, max(base, 1e-6), 1
        lo = float(np.min(np.asarray(lo_a)[ne]))
        hi = float(np.max(np.asarray(hi_a)[ne]))
        cell = max(base, 1e-6)
        extent = max(hi - lo, 0.0)
        n = int(np.floor(extent / cell)) + 1
        if n > max_cells_per_axis:
            cell = extent / max_cells_per_axis * (1 + 1e-6)
            n = int(np.floor(extent / cell)) + 1
        return lo, cell, n

    x0, csx, nx = axis(boxes.xmin, boxes.xmax, eps_sp)
    y0, csy, ny = axis(boxes.ymin, boxes.ymax, eps_sp)
    t0, cst, nt = axis(boxes.tmin, boxes.tmax, eps_t)
    # one spatial resolution for both axes (square cells)
    cell_sp = max(csx, csy)
    return GridSpec(x0=x0, y0=y0, t0=t0, cell_sp=cell_sp, cell_t=cst,
                    nx=nx, ny=ny, nt=nt)


def _center_coords(spec: GridSpec, boxes: TileBoxes):
    """Integer cell coords of each tile's bbox center, clipped into range."""
    def quant(lo, hi, origin, cell, n):
        center = 0.5 * (lo + hi)
        ix = jnp.floor((center - origin) / cell).astype(jnp.int32)
        return jnp.clip(ix, 0, n - 1)

    ix = quant(boxes.xmin, boxes.xmax, spec.x0, spec.cell_sp, spec.nx)
    iy = quant(boxes.ymin, boxes.ymax, spec.y0, spec.cell_sp, spec.ny)
    it = quant(boxes.tmin, boxes.tmax, spec.t0, spec.cell_t, spec.nt)
    return ix, iy, it


def build_cell_table(spec: GridSpec, boxes: TileBoxes) -> CellTable:
    """Bucket tiles into grid cells; CSR arrays built under ``jit``.

    The pruning queries below consume only ``coords`` (vectorized cell
    range tests); the ``order``/``starts`` CSR lists exist for consumers
    that gather per-cell tile lists directly — the planned segmentation
    neighbor masks and similarity scatter (ROADMAP).
    """
    n = boxes.num_tiles
    ix, iy, it = _center_coords(spec, boxes)
    cell = (ix * spec.ny + iy) * spec.nt + it
    cell = jnp.where(boxes.nonempty, cell, spec.num_cells)  # park empties
    order = jnp.argsort(cell, stable=True).astype(jnp.int32)
    sorted_cells = cell[order]
    starts = jnp.searchsorted(
        sorted_cells, jnp.arange(spec.num_cells + 1)).astype(jnp.int32)
    coords = jnp.stack([ix, iy, it], axis=-1).astype(jnp.int32)
    coords = jnp.where(boxes.nonempty[:, None], coords, -1)
    return CellTable(order=order, starts=starts,
                     cell_of=cell.astype(jnp.int32), coords=coords)


# --------------------------------------------------------------------------
# candidate queries
# --------------------------------------------------------------------------

def _axis_gap(alo, ahi, blo, bhi):
    """Separation between intervals [alo, ahi] and [blo, bhi] (0 = overlap).

    Empty boxes carry +/-inf bounds; ``maximum(..., 0)`` of inf gaps keeps
    them infinite, so empty tiles never pair with anything.
    """
    return jnp.maximum(jnp.maximum(blo - ahi, alo - bhi), 0.0)


def exact_pair_mask(ref: TileBoxes, cand: TileBoxes, eps_sp, eps_t):
    """[nR, nC] bool: candidate tile can contain a match for the ref tile.

    Euclidean bbox-distance test in space, interval-gap test in time —
    exactly the cylinder predicate of the join lifted to bounding boxes, so
    the mask is conservative: ``False`` proves no point pair can match.
    """
    gx = _axis_gap(ref.xmin[:, None], ref.xmax[:, None],
                   cand.xmin[None, :], cand.xmax[None, :])
    gy = _axis_gap(ref.ymin[:, None], ref.ymax[:, None],
                   cand.ymin[None, :], cand.ymax[None, :])
    gt = _axis_gap(ref.tmin[:, None], ref.tmax[:, None],
                   cand.tmin[None, :], cand.tmax[None, :])
    eps_sp = jnp.float32(eps_sp)
    sp_ok = gx * gx + gy * gy <= eps_sp * eps_sp
    ok = sp_ok & (gt <= jnp.float32(eps_t))
    return ok & ref.nonempty[:, None] & cand.nonempty[None, :]


def coarse_pair_mask(spec: GridSpec, table: CellTable, ref: TileBoxes,
                     cand: TileBoxes, eps_sp, eps_t):
    """[nR, nC] bool coarse cell test (conservative superset of exact).

    A candidate tile is kept when its *center cell* lies inside the ref
    tile's bbox expanded by ``eps`` plus the fleet-wide max candidate tile
    half-extent — the slack that makes center-bucketing safe for tiles
    that straddle cell boundaries.
    """
    ext = lambda lo, hi: jnp.where(cand.nonempty, hi - lo, 0.0)
    half_x = 0.5 * jnp.max(ext(cand.xmin, cand.xmax), initial=0.0)
    half_y = 0.5 * jnp.max(ext(cand.ymin, cand.ymax), initial=0.0)
    half_t = 0.5 * jnp.max(ext(cand.tmin, cand.tmax), initial=0.0)

    def rng(lo, hi, pad, origin, cell, n):
        lo_i = jnp.floor((lo - pad - origin) / cell).astype(jnp.int32)
        hi_i = jnp.floor((hi + pad - origin) / cell).astype(jnp.int32)
        return jnp.clip(lo_i, 0, n - 1), jnp.clip(hi_i, 0, n - 1)

    eps_sp = jnp.float32(eps_sp)
    eps_t = jnp.float32(eps_t)
    xlo, xhi = rng(ref.xmin, ref.xmax, eps_sp + half_x,
                   spec.x0, spec.cell_sp, spec.nx)
    ylo, yhi = rng(ref.ymin, ref.ymax, eps_sp + half_y,
                   spec.y0, spec.cell_sp, spec.ny)
    tlo, thi = rng(ref.tmin, ref.tmax, eps_t + half_t,
                   spec.t0, spec.cell_t, spec.nt)

    cc = table.coords                              # [nC, 3]
    inx = (cc[None, :, 0] >= xlo[:, None]) & (cc[None, :, 0] <= xhi[:, None])
    iny = (cc[None, :, 1] >= ylo[:, None]) & (cc[None, :, 1] <= yhi[:, None])
    int_ = (cc[None, :, 2] >= tlo[:, None]) & (cc[None, :, 2] <= thi[:, None])
    return inx & iny & int_ & ref.nonempty[:, None] & cand.nonempty[None, :]


def candidate_tile_mask(spec: GridSpec, table: CellTable, ref: TileBoxes,
                        cand: TileBoxes, eps_sp, eps_t):
    """Coarse cell test refined by the exact eps-expanded bbox test."""
    coarse = coarse_pair_mask(spec, table, ref, cand, eps_sp, eps_t)
    return coarse & exact_pair_mask(ref, cand, eps_sp, eps_t)


def compact_candidates(mask: jnp.ndarray, max_tiles: int):
    """[nR, nC] bool -> (tile_ids [nR, max_tiles] int32 -1-padded, counts).

    Surviving tile ids are emitted in ascending order (the dense kernel's
    iteration order, which keeps argmax tie-breaking bit-identical).  Ids
    beyond ``max_tiles`` are dropped — callers that need exactness must
    size ``max_tiles >= counts.max()`` (see ``plan_max_tiles``).
    """
    nR, nC = mask.shape
    idx = jnp.arange(nC, dtype=jnp.int32)
    key = jnp.where(mask, idx, nC + idx)          # survivors first, in order
    order = jnp.argsort(key, axis=1)[:, :max_tiles].astype(jnp.int32)
    counts = jnp.sum(mask, axis=1).astype(jnp.int32)
    slot = jnp.arange(max_tiles, dtype=jnp.int32)[None, :]
    tile_ids = jnp.where(slot < counts[:, None], order, -1)
    return tile_ids, counts


def plan_max_tiles(counts, *, multiple_of: int = 1) -> int:
    """Host-side: smallest static K (>= 1) covering every ref tile's list."""
    k = int(np.max(np.asarray(counts), initial=0))
    k = max(k, 1)
    return -(-k // multiple_of) * multiple_of


def prune_stats(counts, n_cand_tiles: int) -> PruneStats:
    n_ref = counts.shape[0]
    return PruneStats(
        kept_tiles=jnp.sum(counts).astype(jnp.int32),
        dense_tiles=int(n_ref * n_cand_tiles),
        max_per_ref=jnp.max(counts, initial=0).astype(jnp.int32))


# --------------------------------------------------------------------------
# row-level (per-trajectory) masks for the pure-jnp reference path
# --------------------------------------------------------------------------

def trajectory_pair_mask(ref_x, ref_y, ref_t, ref_valid,
                         cand_x, cand_y, cand_t, cand_valid,
                         eps_sp, eps_t):
    """[T, C] bool: candidate row can match some point of ref row.

    Row-granularity version of ``exact_pair_mask`` for the dense jnp
    reference join (``repro.core.geometry``) and the shard_map JOIN phase,
    where tiles are whole trajectory rows.  (The distributed halo filter
    in ``repro.core.distributed`` applies the same eps-expanded-bbox test
    per partition, pre-exchange, using the exchanged 6-float bboxes.)
    """
    rb = _masked_boxes(ref_x, ref_y, ref_t, ref_valid)
    cb = _masked_boxes(cand_x, cand_y, cand_t, cand_valid)
    return exact_pair_mask(rb, cb, eps_sp, eps_t)
