"""Spatiotemporal candidate-pruning index for the subtrajectory join.

``grid`` — fixed-resolution (eps-derived) grid over tile bounding boxes:
CSR cell tables, conservative candidate-tile masks, compacted tile lists.
"""
from repro.index.grid import (
    CellTable,
    GridSpec,
    PruneStats,
    TileBoxes,
    build_cell_table,
    candidate_tile_mask,
    coarse_pair_mask,
    compact_candidates,
    exact_pair_mask,
    fit_grid,
    plan_max_tiles,
    point_block_boxes,
    prune_stats,
    traj_block_boxes,
    trajectory_pair_mask,
)

__all__ = [
    "CellTable",
    "GridSpec",
    "PruneStats",
    "TileBoxes",
    "build_cell_table",
    "candidate_tile_mask",
    "coarse_pair_mask",
    "compact_candidates",
    "exact_pair_mask",
    "fit_grid",
    "plan_max_tiles",
    "point_block_boxes",
    "prune_stats",
    "traj_block_boxes",
    "trajectory_pair_mask",
]
