"""Tile-plan autotuner: sweep EnginePlan geometries, verify, cache winners.

Closes the ROADMAP's "tile-plan autotuner" item: every Pallas engine in
this repo runs at hand-picked block shapes (fused join ``(rows, bc, bm)``,
clustering ``(bu, bs)``, similarity panel ``Sb`` and list width ``K``),
and which shape wins is a property of the backend and the workload shape
— not something to hardcode.  The tuner makes the choice measured,
verified, and cached:

* **One trace per geometry.**  Tile geometry rides through ``jax.jit``
  static arguments (a frozen :class:`~repro.core.plan.EnginePlan` IS the
  static key), so each candidate costs exactly one ``lower().compile()``
  plus timed replays of the compiled executable.  That invariant — built
  into every engine since PR 2 — is what makes a sweep affordable: N
  candidates cost N compiles, never N recompiles per call site.
* **Verify before accept.**  A candidate only becomes eligible after its
  output is bit-identical to the stage's engine oracle (final labels for
  the end-to-end join sweep, the jnp reference for the cluster kernels,
  the dense ``topk_reduce_rows`` for the panel sweep).  Tile geometry
  must never buy speed with different answers; a geometry that shifts
  f32 summation enough to flip a label is *rejected*, not ranked.
* **Deterministic winner.**  Candidates are ranked by peak
  interface-buffer bytes (``launch.hlo_analysis.interface_buffer_stats``
  — the honest cross-stage HBM footprint) with wall-clock and candidate
  order only breaking ties.  The default plan is always candidate 0, so
  a tuned plan can never regress the primary key — the property the
  ``tuning`` gate in ``BENCH_pipeline.json`` asserts.
* **Cached per (shape-bucket, backend, jax version).**  Winners land in a
  JSON :class:`PlanStore` keyed by ``stage|bucket|backend|jaxN``: shapes
  bucket to powers of two (a sweep at S=512 serves S=300..512), backends
  tune independently (CPU interpret mode and TPU rank geometries
  differently), and a jax upgrade invalidates the cache rather than
  silently replaying stale winners.

Each candidate record also carries peak-buffer bytes and its roofline
position (``benchmarks.roofline.roofline_position`` over the analyzed
HLO) so a stored plan explains *why* it won, not just that it did.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import EnginePlan
from repro.launch.hlo_analysis import (analyze_hlo, interface_buffer_stats,
                                       peak_buffer_stats)

_LABEL_FIELDS = ("member_of", "is_rep", "is_outlier")


# --------------------------------------------------------------------------
# cache keys and the plan store
# --------------------------------------------------------------------------

def shape_bucket(**dims) -> str:
    """Deterministic shape-bucket string: each dim rounded up to a power
    of two (``T=24 -> T32``), keys sorted.  A sweep tuned at the bucket
    ceiling serves every shape in the bucket — tile validity and relative
    ranking are stable within a 2x band, and exact-shape keys would make
    the cache miss on every workload."""
    parts = []
    for k in sorted(dims):
        v = int(dims[k])
        parts.append(f"{k}{1 if v <= 1 else 2 ** math.ceil(math.log2(v))}")
    return "-".join(parts)


def plan_cache_key(stage: str, bucket: str, backend: str | None = None,
                   jax_version: str | None = None) -> str:
    """``stage|bucket|backend|jaxVERSION`` — the PlanStore key."""
    backend = backend or jax.default_backend()
    jax_version = jax_version or jax.__version__
    return f"{stage}|{bucket}|{backend}|jax{jax_version}"


class PlanStore:
    """JSON store of tuned plans: cache key -> winner record.

    ``get`` returns the cached :class:`EnginePlan` (or None);
    ``put`` records a :class:`TuneResult`'s winner; ``save`` writes the
    whole store (winner plan + per-candidate audit trail) to ``path``.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self.records: dict[str, dict] = {}
        if path is not None and os.path.exists(path):
            with open(path) as f:
                self.records = json.load(f)

    def get(self, stage: str, bucket: str, **key_kw) -> EnginePlan | None:
        rec = self.records.get(plan_cache_key(stage, bucket, **key_kw))
        return None if rec is None else EnginePlan.from_dict(rec["plan"])

    def put(self, result: "TuneResult", **key_kw) -> str:
        key = plan_cache_key(result.stage, result.bucket, **key_kw)
        self.records[key] = result.to_dict()
        return key

    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("PlanStore has no path")
        with open(path, "w") as f:
            json.dump(self.records, f, indent=2, sort_keys=True)
            f.write("\n")
        return path


# --------------------------------------------------------------------------
# measurement: one trace per geometry
# --------------------------------------------------------------------------

def measure_compiled(fn, args, iters: int = 1):
    """(out, wall_s, hlo_text): compile ``fn(*args)`` once, replay timed.

    One ``lower().compile()`` per call — the tuner's entire compile cost
    for a candidate.  The first replay warms the executable (excluded);
    ``wall_s`` is the minimum over ``iters`` timed replays (minimum, not
    median: replay noise is one-sided).
    """
    compiled = jax.jit(fn).lower(*args).compile()
    hlo = compiled.as_text()
    out = jax.block_until_ready(compiled(*args))
    wall = math.inf
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(compiled(*args))
        wall = min(wall, time.perf_counter() - t0)
    return out, wall, hlo


def _roofline(hlo: str) -> dict | None:
    """Roofline position of an analyzed HLO, or None when
    ``benchmarks.roofline`` is not importable (installed-package use —
    the benchmarks tree ships with the repo, not the wheel)."""
    try:
        from benchmarks.roofline import roofline_position
    except ImportError:
        return None
    a = analyze_hlo(hlo)
    hbm = a["hbm_traffic_fused_bytes"] or a["hbm_traffic_bytes"]
    return roofline_position(a["flops"], hbm, a["collective_bytes"])


# --------------------------------------------------------------------------
# the sweep
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CandidateRecord:
    plan: EnginePlan
    wall_s: float
    verified: bool
    peak_interface_bytes: int
    peak_buffer_bytes: int
    roofline: dict | None
    note: str = ""

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["plan"] = self.plan.to_dict()
        d["wall_s"] = None if math.isinf(self.wall_s) else self.wall_s
        return d


@dataclasses.dataclass
class TuneResult:
    stage: str
    bucket: str
    candidates: list[CandidateRecord]
    default: CandidateRecord        # candidates[0] — the untuned baseline
    winner: CandidateRecord

    def to_dict(self) -> dict:
        return {
            "stage": self.stage, "bucket": self.bucket,
            "backend": jax.default_backend(), "jax": jax.__version__,
            "plan": self.winner.plan.to_dict(),
            "winner": self.winner.to_dict(),
            "default": self.default.to_dict(),
            "candidates": [c.to_dict() for c in self.candidates],
        }


def sweep(stage: str, bucket: str, candidates, measure, verify,
          store: PlanStore | None = None, **store_kw) -> TuneResult:
    """Measure every candidate plan once, verify, pick the winner.

    ``measure(plan) -> (out, wall_s, hlo_text)`` is the one-trace
    measurement; ``verify(out, plan) -> bool`` is the bit-identity check
    against the stage oracle.  Both are injectable so tests can pin a
    fixed candidate set (determinism) or plant a deliberately-wrong
    candidate (rejection).  Candidate 0 must be the stage's default plan;
    a candidate whose measurement *raises* (invalid geometry) is recorded
    as unverified rather than aborting the sweep.  Winner = the verified
    candidate minimizing ``(peak_interface_bytes, wall_s, index)`` —
    fully deterministic given the measurements.
    """
    records: list[CandidateRecord] = []
    for plan in candidates:
        try:
            out, wall, hlo = measure(plan)
        except Exception as e:  # noqa: BLE001 — geometry rejected, not fatal
            records.append(CandidateRecord(
                plan=plan, wall_s=math.inf, verified=False,
                peak_interface_bytes=-1, peak_buffer_bytes=-1,
                roofline=None, note=f"measure failed: {e}"))
            continue
        ok = bool(verify(out, plan))
        records.append(CandidateRecord(
            plan=plan, wall_s=wall, verified=ok,
            peak_interface_bytes=interface_buffer_stats(hlo)["largest_bytes"],
            peak_buffer_bytes=peak_buffer_stats(hlo)["largest_bytes"],
            roofline=_roofline(hlo),
            note="" if ok else "rejected: not bit-identical to the oracle"))
    eligible = [(r.peak_interface_bytes, r.wall_s, i)
                for i, r in enumerate(records) if r.verified]
    if not eligible:
        raise RuntimeError(
            f"tune[{stage}]: no candidate survived verification "
            f"({[r.note for r in records]})")
    winner = records[min(eligible)[2]]
    result = TuneResult(stage=stage, bucket=bucket, candidates=records,
                        default=records[0], winner=winner)
    if store is not None:
        store.put(result, **store_kw)
    return result


def _labels_equal(res_a, res_b) -> bool:
    return all(np.array_equal(np.asarray(getattr(res_a, f)),
                              np.asarray(getattr(res_b, f)))
               for f in _LABEL_FIELDS)


# --------------------------------------------------------------------------
# stage drivers
# --------------------------------------------------------------------------

def join_candidates(T: int, M: int, base: EnginePlan) -> list[EnginePlan]:
    """Join-stage candidate lattice for a ``[T, M]`` self-join.

    Candidate 0 is ``base`` untouched (the library default — on the
    default plan that is the materializing oracle, so the sweep measures
    the cube path and the fused geometries side by side and the recorded
    wall-clocks ARE the fused-vs-kernel-path gap, per backend).  The rest
    are fused plans on a small deterministic ``(rows, bc, bm)`` lattice
    around the fat-tile default; ``plan_fused_tiles``-style clamping
    happens inside the kernels, so duplicates after clamping are dropped
    here by their pre-clamp key only.
    """
    cands = [base]
    seen = set()

    def add(rows, bc, bm):
        rows = None if rows is None else max(1, min(int(rows), T))
        bm = max(8, min(int(bm), M))
        key = (rows, bc, bm)
        if key in seen:
            return
        seen.add(key)
        cands.append(base.replace(mode="fused", fused_rows=rows,
                                  fused_bc=bc, fused_bm=bm))

    add(None, 16, 128)                       # the fused library default
    auto_rows = max(1, 2048 // max(M, 1))
    for rows in (1, 4, auto_rows):
        for bc, bm in ((8, 64), (16, 128), (32, 32)):
            if len(cands) >= 8:
                return cands
            add(rows, bc, bm)
    return cands


def tune_join(batch, params, base: EnginePlan | None = None,
              candidates: list[EnginePlan] | None = None,
              store: PlanStore | None = None, iters: int = 1,
              oracle=None) -> TuneResult:
    """Tune join mode + fused tile geometry by running the whole pipeline.

    Measurement is end-to-end (``run_dsc_lowerable``) on purpose: one
    trace per candidate covers timing, HLO inspection, AND verification
    output, and the interface-buffer key then reflects what the geometry
    actually changes — whether the ``[T, M, C]`` cube crosses a stage
    boundary, and how much tile padding the fused sweeps carry.
    Verification is final-label bit-identity against the materializing
    oracle (fused vote/sim values are only allclose across geometries —
    f32 summation order — but labels are the pipeline's bit-exact
    contract, and a geometry that flips one is rejected).
    """
    from repro.core.dsc import run_dsc_lowerable
    T, M = batch.x.shape
    base = (base or EnginePlan()).validate()
    if candidates is None:
        candidates = join_candidates(T, M, base)

    def measure(plan):
        return measure_compiled(
            lambda b: run_dsc_lowerable(b, params, plan), (batch,),
            iters=iters)

    oracle_res = oracle if oracle is not None else \
        measure_compiled(lambda b: run_dsc_lowerable(
            b, params, base.replace(mode="materialize")), (batch,))[0]

    def verify(out, plan):
        return _labels_equal(out.result, oracle_res.result)

    return sweep("join", shape_bucket(T=T, M=M), candidates,
                 measure, verify, store=store)


def cluster_candidates(S: int, base: EnginePlan) -> list[EnginePlan]:
    """Cluster-stage candidates: the base engine untouched (candidate 0 —
    jnp unless the base plan already picked the kernels), then the Pallas
    round kernels over a small (bu, bs) tile lattice."""
    cands = [base]
    for bu, bs in ((8, 128), (8, 64), (16, 128), (8, 256), (16, 64)):
        plan = base.replace(cluster_engine="rounds",
                            cluster_use_kernel=True,
                            cluster_bu=bu, cluster_bs=bs)
        if plan not in cands:
            cands.append(plan)
    return cands


def tune_cluster_tiles(sim, table, params, base: EnginePlan | None = None,
                       candidates: list[EnginePlan] | None = None,
                       store: PlanStore | None = None,
                       iters: int = 1) -> TuneResult:
    """Tune the Problem 3 engine + round-kernel tiles on a dense instance.

    Oracle: the jnp round engine (bit-identical to the sequential
    transcription by the PR 3 contract).  The Pallas kernels are
    bit-identical to it for any tile geometry — padding only adds slots
    that join no reduction — so verification here compares ALL result
    fields, not just labels, and any geometry that breaks the padding
    invariant is rejected.
    """
    from repro.core.clustering import cluster_rounds
    S = int(table.num_slots)
    base = (base or EnginePlan()).validate()
    if candidates is None:
        candidates = cluster_candidates(S, base)

    def fn_for(plan):
        return lambda s, t: cluster_rounds(
            s, t, params, use_kernel=plan.cluster_use_kernel,
            tiles=plan.cluster_tiles)

    oracle_res = measure_compiled(
        lambda s, t: cluster_rounds(s, t, params), (sim, table))[0]

    def measure(plan):
        return measure_compiled(fn_for(plan), (sim, table), iters=iters)

    def verify(out, plan):
        return all(np.array_equal(np.asarray(getattr(out, f)),
                                  np.asarray(getattr(oracle_res, f)))
                   for f in ("member_of", "member_sim", "is_rep",
                             "is_outlier", "alpha_used", "k_used"))

    return sweep("cluster", shape_bucket(S=S), candidates,
                 measure, verify, store=store)


def panel_candidates(S: int, base: EnginePlan) -> list[EnginePlan]:
    """Similarity-stage candidates: the base panel (candidate 0), then a
    small Sb ladder.  ``plan_panel`` snaps each target to the largest
    divisor of S, so targets that collapse to the same Sb dedupe here."""
    from repro.core.similarity import plan_panel
    cands, seen = [], set()
    for target in (base.sim_panel, 32, 64, 128, 256):
        Sb = plan_panel(S, target)
        if Sb in seen:
            continue
        seen.add(Sb)
        cands.append(base.replace(sim_mode="topk", sim_panel=Sb))
    return cands


def tune_sim_panel(src, dst, w, table, params,
                   base: EnginePlan | None = None,
                   candidates: list[EnginePlan] | None = None,
                   store: PlanStore | None = None,
                   iters: int = 1) -> TuneResult:
    """Tune the top-K panel height Sb on a contribution-list instance.

    Oracle: the dense path — scatter, ``finalize_sim``, then one
    ``topk_reduce_rows`` over full rows.  The streamed panel sweep is
    bitwise-equal to it for EVERY divisor Sb (PR 5's fixed pairwise-tree
    contract), so verification compares ids, sims, the spill certificate,
    and the threshold moments bit for bit; a panel height that breaks the
    tree invariant is rejected.
    """
    from repro.core.similarity import (contribution_panel_raw, finalize_sim,
                                       sim_row_moments, topk_reduce_rows,
                                       topk_stream)
    S = int(table.num_slots)
    base = (base or EnginePlan()).replace(sim_mode="topk")
    K = min(base.sim_topk if base.sim_topk is not None else 32, S)
    base = base.replace(sim_topk=K)
    if candidates is None:
        candidates = panel_candidates(S, base)

    def dense_oracle(src, dst, w):
        raw = jnp.zeros((S + 1, S + 1), jnp.float32).at[src, dst].add(w)
        sim = finalize_sim(raw[:S, :S], table)
        ids, sims, spill = topk_reduce_rows(sim, K)
        cnt, rsum, rsumsq = sim_row_moments(sim, table.valid, table.valid)
        return ids, sims, spill, cnt, rsum, rsumsq

    o_ids, o_sims, o_spill, o_cnt, o_sum, o_sumsq = measure_compiled(
        dense_oracle, (src, dst, w))[0]

    def measure(plan):
        def fn(src, dst, w):
            return topk_stream(
                contribution_panel_raw(src, dst, w, S, plan.sim_panel),
                table, k=K, panel=plan.sim_panel)
        return measure_compiled(fn, (src, dst, w), iters=iters)

    def verify(topk, plan):
        pairs = ((topk.ids, o_ids), (topk.sims, o_sims),
                 (topk.spill, o_spill), (topk.degree, o_cnt),
                 (topk.row_sum, o_sum), (topk.row_sumsq, o_sumsq))
        return all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in pairs)

    return sweep("similarity", shape_bucket(S=S, K=K), candidates,
                 measure, verify, store=store)


def comm_candidates(base: EnginePlan) -> list[EnginePlan]:
    """Comm-schedule candidates (DESIGN.md §12): candidate 0 is the
    barrier baseline — the tuner's no-regression anchor — then each ring
    schedule alone, then both.  Unlike tile geometries, ring and barrier
    schedules are bit-identical by construction, so a verification
    failure here is a real bug, not a rejected candidate."""
    barrier = base.replace(halo_stream="barrier", sim_exchange="allgather")
    cands = [barrier]
    for hs, se in (("ring", "allgather"), ("barrier", "ring"),
                   ("ring", "ring")):
        p = barrier.replace(halo_stream=hs, sim_exchange=se)
        if p not in cands:
            cands.append(p)
    return cands


def tune_comm(parts, params, mesh, *, part_axis: str = "part",
              model_axis: str = "model", base: EnginePlan | None = None,
              candidates: list[EnginePlan] | None = None,
              store: PlanStore | None = None, iters: int = 1) -> TuneResult:
    """Tune the distributed communication schedules on a live mesh.

    Each candidate compiles the full distributed monolith once
    (``halo_stream`` / ``sim_exchange`` are plan fields, hence jit static
    keys — one trace per schedule).  Verification is final-label
    bit-identity against candidate 0, the barrier baseline: a ring
    schedule is a pure reordering of the same data movement, so anything
    short of bit-identical labels is rejected.  The interface-bytes
    primary key ties across schedules (the program boundary is
    unchanged); wall-clock — where overlap actually shows up — breaks
    the tie, with candidate order keeping the result deterministic.
    """
    from repro.core.distributed import build_dsc_program
    base = (base or EnginePlan()).validate()
    if candidates is None:
        candidates = comm_candidates(base)
    args = (parts.x, parts.y, parts.t, parts.valid, parts.traj_id,
            parts.ranges)

    def measure(plan):
        prog = build_dsc_program(parts, params, mesh, part_axis=part_axis,
                                 model_axis=model_axis, plan=plan)
        return measure_compiled(prog, args, iters=iters)

    oracle_final = measure(candidates[0])[0][0]

    def verify(out, plan):
        return _labels_equal(out[0], oracle_final)

    bucket = shape_bucket(T=parts.x.shape[1], P=mesh.shape[part_axis],
                          M=mesh.shape[model_axis])
    return sweep("comm", bucket, candidates, measure, verify, store=store)


def tune_pipeline(batch, params, base: EnginePlan | None = None,
                  store: PlanStore | None = None,
                  iters: int = 1):
    """(tuned plan, {stage: TuneResult}): tune all three swept stages.

    The join sweep runs end to end on ``batch``; the cluster sweep reuses
    the join oracle's dense similarity + slot table as its instance (the
    real downstream inputs at this shape); the panel sweep runs on the
    positive entries of that matrix as a contribution list.  The merged
    plan takes each stage's winner fields — they compose freely because
    every stage's geometry knob is independent by construction.
    """
    from repro.core.dsc import run_dsc_lowerable
    base = (base or EnginePlan()).validate()
    oracle = measure_compiled(
        lambda b: run_dsc_lowerable(b, params,
                                    base.replace(mode="materialize")),
        (batch,))[0]
    results = {
        "join": tune_join(batch, params, base=base, store=store,
                          iters=iters, oracle=oracle)}

    sim, table = oracle.sim, oracle.table
    results["cluster"] = tune_cluster_tiles(sim, table, params, base=base,
                                            store=store, iters=iters)

    S = int(table.num_slots)
    sim_np = np.asarray(sim)
    src_np, dst_np = np.nonzero(sim_np)
    contribs = (jnp.asarray(src_np, jnp.int32),
                jnp.asarray(dst_np, jnp.int32),
                jnp.asarray(sim_np[src_np, dst_np], jnp.float32))
    results["similarity"] = tune_sim_panel(*contribs, table, params,
                                           base=base, store=store,
                                           iters=iters)

    cw = results["cluster"].winner.plan
    sw = results["similarity"].winner.plan
    tuned = results["join"].winner.plan.replace(
        cluster_engine=cw.cluster_engine,
        cluster_use_kernel=cw.cluster_use_kernel,
        cluster_bu=cw.cluster_bu, cluster_bs=cw.cluster_bs,
        sim_panel=sw.sim_panel)
    return tuned, results
