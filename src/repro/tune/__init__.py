"""Tile-plan autotuning for the DSC engines (see ``repro.tune.autotune``)."""
from repro.tune.autotune import (CandidateRecord, PlanStore,  # noqa: F401
                                 TuneResult, plan_cache_key, shape_bucket,
                                 sweep, tune_cluster_tiles, tune_join,
                                 tune_pipeline, tune_sim_panel)
