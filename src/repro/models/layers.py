"""Shared transformer building blocks — pure-pytree parameters, no flax.

Every block is a pair of functions: ``init_*(key, cfg) -> params`` and
``apply(params, x, ...) -> y``.  Parameters are plain dicts of jnp arrays so
the whole model is a pytree that pjit/GSPMD shards via the rules in
``repro.distributed.partition``.

Compute dtype is bf16 (TPU-native), parameters & reductions f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale)


# ----------------------------- norms ----------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-6, unit_offset=True):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + p["scale"]) if unit_offset else p["scale"]
    return (x * scale).astype(dt)


# ----------------------------- rope ------------------------------------------

def rope(x, positions, theta=10_000.0):
    """x: [..., L, H, hd]; positions: [..., L]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # [...,L,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------- attention -------------------------------------

def init_attention(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d, cfg.n_heads, hd)),
        "wk": _dense_init(ks[1], (d, cfg.n_kv_heads, hd)),
        "wv": _dense_init(ks[2], (d, cfg.n_kv_heads, hd)),
        "wo": _dense_init(ks[3], (cfg.n_heads, hd, d)),
    }


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap)


ATTN_Q_CHUNK = 512
ATTN_KV_CHUNK = 1024
ATTN_CHUNK_THRESHOLD = 2048   # use online-softmax path when L_q > this


def _mask_block(q_pos, kv_pos, sliding_window, prefix_len, max_kv):
    """[Lq, Lkv] bool mask from position vectors (causal/window/prefix)."""
    causal = kv_pos[None, :] <= q_pos[:, None]
    if prefix_len is not None:
        bidir = (kv_pos[None, :] < prefix_len) & (q_pos[:, None] < prefix_len)
        causal = causal | bidir
    if sliding_window is not None:
        causal &= kv_pos[None, :] > (q_pos[:, None] - sliding_window)
    if max_kv is not None:
        causal &= kv_pos[None, :] <= max_kv
    return causal


def _attend_dense(qg, k, v, q_pos, kv_pos, cfg, sliding_window, prefix_len,
                  max_kv):
    """Reference path: materializes [B, Lq, KV, G, M] logits."""
    logits = jnp.einsum("blkgh,bmkh->blkgm", qg, k)
    if cfg.attn_softcap is not None:
        logits = _softcap(logits, cfg.attn_softcap)
    mask = _mask_block(q_pos, kv_pos, sliding_window, prefix_len, max_kv)
    logits = jnp.where(mask[None, :, None, None, :],
                       logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(qg.dtype)
    return jnp.einsum("blkgm,bmkh->blkgh", probs, v)


def _attend_online(qg, k, v, q_pos, kv_pos, cfg, sliding_window, prefix_len,
                   max_kv):
    """Online-softmax (flash-style, pure XLA): outer map over query chunks,
    inner scan over KV chunks with running (max, denom, acc) — peak memory
    O(Bq_chunk x kv_chunk) instead of O(Lq x Lkv).  This is the memory shape
    a fused TPU attention kernel would have; it keeps the dry-run's
    memory_analysis honest at 32k/500k sequence lengths."""
    B, Lq, KV, G, hd = qg.shape
    M = k.shape[1]
    qc, kc = ATTN_Q_CHUNK, ATTN_KV_CHUNK
    qc = min(qc, Lq)
    while Lq % qc:
        qc //= 2
    kc = min(kc, M)
    while M % kc:
        kc //= 2
    nq, nk = Lq // qc, M // kc

    kb = k.reshape(B, nk, kc, KV, hd)
    vb = v.reshape(B, nk, kc, KV, hd)
    kv_pos_b = kv_pos.reshape(nk, kc)

    def q_chunk(args):
        qi, qp = args                              # [B, qc, KV, G, hd], [qc]

        def kv_step(carry, xs):
            m, l, acc = carry
            kj, vj, kvp = xs                       # [B, kc, KV, hd], [kc]
            logits = jnp.einsum("bqkgh,bmkh->bqkgm", qi, kj)
            if cfg.attn_softcap is not None:
                logits = _softcap(logits, cfg.attn_softcap)
            msk = _mask_block(qp, kvp, sliding_window, prefix_len, max_kv)
            logits = jnp.where(msk[None, :, None, None, :],
                               logits.astype(jnp.float32), -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            scale_old = jnp.exp(m - m_new)
            p_blk = jnp.exp(logits - m_new[..., None])
            l_new = l * scale_old + p_blk.sum(axis=-1)
            acc_new = (acc * scale_old[..., None]
                       + jnp.einsum("bqkgm,bmkh->bqkgh",
                                    p_blk.astype(qi.dtype), vj))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, qc, KV, G), -1e30, jnp.float32)
        l0 = jnp.zeros((B, qc, KV, G), jnp.float32)
        a0 = jnp.zeros((B, qc, KV, G, hd), jnp.float32)
        xs = (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kv_pos_b)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), xs)
        return acc / jnp.maximum(l, 1e-30)[..., None]

    qb = qg.reshape(B, nq, qc, KV, G, hd)
    out = jax.lax.map(q_chunk,
                      (jnp.moveaxis(qb, 1, 0), q_pos.reshape(nq, qc)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Lq, KV, G, hd)
    return out.astype(qg.dtype)


def attention(p, x, cfg: ModelConfig, positions, *, mask=None,
              cache: Optional[dict] = None, cache_index=None,
              sliding_window: Optional[int] = None,
              prefix_len: Optional[int] = None):
    """GQA attention with optional RoPE cache, softcap, sliding window and
    prefix-LM (bidirectional prefix) masking.

    x: [B, L, D].  With ``cache`` given (decode), L == 1 and ``cache_index``
    is the write position; cache layout: k/v [B, L_max, KV, hd].
    Long sequences take the online-softmax (flash-style) path.
    Returns (out, new_cache).
    """
    del mask
    B, L, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    xc = x.astype(COMPUTE_DTYPE)

    q = jnp.einsum("bld,dhk->blhk", xc, p["wq"].astype(COMPUTE_DTYPE))
    k = jnp.einsum("bld,dhk->blhk", xc, p["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("bld,dhk->blhk", xc, p["wv"].astype(COMPUTE_DTYPE))

    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    scale = cfg.query_scale if cfg.query_scale is not None else hd ** -0.5
    q = q * scale

    q_pos = positions
    if cache is not None:
        k = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = {"k": k, "v": v}
        kv_positions = jnp.arange(k.shape[1], dtype=jnp.int32)
        q_pos = cache_index + jnp.arange(L, dtype=jnp.int32)
        max_kv = cache_index + L - 1
    else:
        new_cache = None
        kv_positions = positions
        max_kv = None

    G = H // KV
    qg = q.reshape(B, L, KV, G, hd)
    if L > ATTN_CHUNK_THRESHOLD:
        out = _attend_online(qg, k, v, q_pos, kv_positions, cfg,
                             sliding_window, prefix_len, max_kv)
    else:
        out = _attend_dense(qg, k, v, q_pos, kv_positions, cfg,
                            sliding_window, prefix_len, max_kv)

    out = out.reshape(B, L, H, hd).astype(COMPUTE_DTYPE)
    out = jnp.einsum("blhk,hkd->bld", out, p["wo"].astype(COMPUTE_DTYPE))
    return out.astype(x.dtype), new_cache


# ----------------------------- mlp -------------------------------------------

def init_mlp(key, d, d_ff):
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": _dense_init(ks[0], (d, d_ff)),
        "wi_up": _dense_init(ks[1], (d, d_ff)),
        "wo": _dense_init(ks[2], (d_ff, d)),
    }


def mlp(p, x, act="silu"):
    xc = x.astype(COMPUTE_DTYPE)
    g = jnp.einsum("bld,df->blf", xc, p["wi_gate"].astype(COMPUTE_DTYPE))
    u = jnp.einsum("bld,df->blf", xc, p["wi_up"].astype(COMPUTE_DTYPE))
    if act == "silu":
        h = jax.nn.silu(g) * u
    elif act == "gelu":
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        raise ValueError(act)
    out = jnp.einsum("blf,fd->bld", h, p["wo"].astype(COMPUTE_DTYPE))
    return out.astype(x.dtype)


# ----------------------------- embeddings ------------------------------------

def init_embedding(key, vocab, d):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(p, tokens, scale_by_sqrt_d=False):
    x = jnp.take(p["table"].astype(COMPUTE_DTYPE), tokens, axis=0)
    if scale_by_sqrt_d:
        x = x * jnp.asarray(p["table"].shape[1] ** 0.5, COMPUTE_DTYPE)
    return x


def unembed(p, x, tied_table=None, final_softcap=None):
    table = (tied_table if tied_table is not None else p["table"])
    logits = jnp.einsum("bld,vd->blv", x.astype(COMPUTE_DTYPE),
                        table.astype(COMPUTE_DTYPE)).astype(jnp.float32)
    if final_softcap is not None:
        logits = _softcap(logits, final_softcap)
    return logits
