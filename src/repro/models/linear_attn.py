"""Chunked linear attention with per-step decay — the shared TPU-native core
of RWKV-6 (Finch) and Mamba-2 (SSD).

Both recurrences are instances of

    S_t = diag(exp(ld_t)) . S_{t-1} + k_t v_t^T          (state [K, V])
    mamba mode:  y_t = q_t . S_t
    rwkv  mode:  y_t = q_t . (S_{t-1} + (u (.) k_t) v_t^T)

A naive scan is sequential and (on TPU) leaves the MXU idle; the chunked form
processes Q-step chunks with dense matmuls (intra-chunk via cumulative
log-decay differences, inter-chunk via the carried state) — the standard
SSD/FLA decomposition, adapted here once for both archs.

Numerical note: intra-chunk factors use exponents relative to the chunk
start, clamped at +-CLAMP; pairs whose true factor underflows are ~0 anyway.
Validated against the naive scan oracle in tests/test_linear_attn.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

CLAMP = 25.0


def _chunk_scan(q, k, v, ld, u, mode: str, state0, chunk: int):
    """q,k: [B,H,L,K]; v: [B,H,L,V]; ld: [B,H,L,K] (or broadcastable);
    state0: [B,H,K,V].  Returns (y [B,H,L,V], state [B,H,K,V])."""
    B, H, L, K = q.shape
    V = v.shape[-1]
    assert L % chunk == 0, (L, chunk)
    n = L // chunk

    qc = q.reshape(B, H, n, chunk, K)
    kc = k.reshape(B, H, n, chunk, K)
    vc = v.reshape(B, H, n, chunk, V)
    ldc = jnp.broadcast_to(ld, (B, H, L, K)).reshape(B, H, n, chunk, K)
    ldc = ldc.astype(jnp.float32)

    # inclusive cumulative log-decay within each chunk
    csum = jnp.cumsum(ldc, axis=3)                     # [B,H,n,Q,K]
    total = csum[..., -1, :]                           # [B,H,n,K]

    # factors relative to chunk start
    q_fac = csum if mode == "mamba" else csum - ldc    # c_i vs c_{i-1}
    qs = qc * jnp.exp(jnp.clip(q_fac, -CLAMP, CLAMP)).astype(qc.dtype)
    ks = kc * jnp.exp(jnp.clip(-csum, -CLAMP, CLAMP)).astype(kc.dtype)

    # intra-chunk attention
    att = jnp.einsum("bhnik,bhnjk->bhnij", qs, ks)     # [B,H,n,Q,Q]
    ii = jnp.arange(chunk)
    if mode == "mamba":
        m = ii[:, None] >= ii[None, :]
    else:
        m = ii[:, None] > ii[None, :]
    att = jnp.where(m[None, None, None], att, 0.0)
    y_intra = jnp.einsum("bhnij,bhnjv->bhniv", att, vc)
    if mode == "rwkv":
        bonus = jnp.einsum("bhnik,bhniv->bhniv",
                           qc * (u[None, :, None, None, :] * kc), vc)
        y_intra = y_intra + bonus

    # inter-chunk: scan the carried state over chunks
    k_tail = kc * jnp.exp(
        jnp.clip(total[..., None, :] - csum, -CLAMP, CLAMP)).astype(kc.dtype)

    def body(S, xs):
        qs_i, k_tail_i, v_i, total_i = xs
        y_state = jnp.einsum("bhik,bhkv->bhiv", qs_i, S.astype(qs_i.dtype))
        S = (S * jnp.exp(jnp.clip(total_i, -CLAMP, CLAMP))[..., None]
             + jnp.einsum("bhik,bhiv->bhkv", k_tail_i,
                          v_i).astype(jnp.float32))
        return S, y_state

    xs = (jnp.moveaxis(qs, 2, 0), jnp.moveaxis(k_tail, 2, 0),
          jnp.moveaxis(vc, 2, 0), jnp.moveaxis(total, 2, 0))
    state, y_state = jax.lax.scan(body, state0.astype(jnp.float32), xs)
    y = y_intra + jnp.moveaxis(y_state, 0, 2)
    return y.reshape(B, H, L, V), state


def chunked_linear_attn(q, k, v, log_decay, *, mode: str = "mamba",
                        u=None, state0=None, chunk: int = 64):
    """Public entry.  Pads L to a chunk multiple; see module docstring."""
    B, H, L, K = q.shape
    V = v.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, K, V), jnp.float32)
    pad = (-L) % chunk
    if pad:
        zq = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q, k, v = zq(q), zq(k), zq(v)
        log_decay = jnp.pad(
            jnp.broadcast_to(log_decay, (B, H, L, K)),
            ((0, 0), (0, 0), (0, pad), (0, 0)))
    if u is None:
        u = jnp.zeros((H, K), q.dtype)
    y, state = _chunk_scan(q, k, v, log_decay, u, mode, state0, chunk)
    return y[:, :, :L], state


def linear_attn_step(q, k, v, log_decay, state, *, mode="mamba", u=None):
    """Single decode step.  q,k: [B,H,K]; v: [B,H,V]; state [B,H,K,V]."""
    a = jnp.exp(log_decay.astype(jnp.float32))         # [B,H,K] or [B,H,1]
    kv = jnp.einsum("bhk,bhv->bhkv", k, v).astype(jnp.float32)
    if mode == "mamba":
        state = state * a[..., None] + kv
        y = jnp.einsum("bhk,bhkv->bhv", q, state.astype(q.dtype))
    else:
        mix = state + (u[None] * k).astype(jnp.float32)[..., None] * \
            v.astype(jnp.float32)[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", q, mix.astype(q.dtype))
        state = state * a[..., None] + kv
    return y, state


def naive_scan_ref(q, k, v, log_decay, *, mode="mamba", u=None, state0=None):
    """O(L) sequential oracle used by tests."""
    B, H, L, K = q.shape
    V = v.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, K, V), jnp.float32)
    if u is None:
        u = jnp.zeros((H, K), q.dtype)
    ld = jnp.broadcast_to(log_decay, (B, H, L, K))

    def body(S, xs):
        q_t, k_t, v_t, ld_t = xs
        y, S = linear_attn_step(q_t, k_t, v_t, ld_t, S, mode=mode, u=u)
        return S, y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (q, k, v, ld))
    state, ys = jax.lax.scan(body, state0, xs)
    return jnp.moveaxis(ys, 0, 2), state
