"""Mixture-of-Experts FFN with explicit expert parallelism.

Production path (``axis_name`` given, inside shard_map): tokens are routed
top-k, packed into fixed-capacity per-expert buffers, exchanged with a single
``lax.all_to_all`` over the 'model' mesh axis (EP), processed as dense
[E_local, cap, D] GEMMs on the expert owners, and returned with the inverse
all_to_all — the canonical EP schedule whose collective bytes are visible to
the roofline pass.

Fallback path (``axis_name=None``): identical math on one device (m=1), used
by smoke tests and the reference oracle.

Capacity: ``C = ceil(N*k/E * capacity_factor)``; overflow tokens are dropped
(their gate mass is lost — standard drop-token semantics, surfaced via the
returned ``dropped`` fraction).  Experts are padded up to a multiple of the
EP degree; padded experts are masked out of the router.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import MoEConfig
from repro.utils.compat import axis_size as axis_size_compat
from repro.utils.compat import shard_map as shard_map_compat

COMPUTE_DTYPE = jnp.bfloat16


def padded_experts(cfg: MoEConfig, ep_degree: int) -> int:
    return -(-cfg.n_experts // ep_degree) * ep_degree


def init_moe(key, d_model: int, cfg: MoEConfig, ep_degree: int = 1):
    E = padded_experts(cfg, ep_degree)
    ks = jax.random.split(key, 7)
    s = 1.0 / jnp.sqrt(d_model)
    p = {
        "router": jax.random.normal(ks[0], (d_model, E), jnp.float32) * s,
        "w_gate": jax.random.normal(
            ks[1], (E, d_model, cfg.d_expert), jnp.float32) * s,
        "w_up": jax.random.normal(
            ks[2], (E, d_model, cfg.d_expert), jnp.float32) * s,
        "w_down": jax.random.normal(
            ks[3], (E, cfg.d_expert, d_model), jnp.float32)
        * (1.0 / jnp.sqrt(cfg.d_expert)),
    }
    if cfg.n_shared > 0:
        f = cfg.n_shared * cfg.d_expert
        p["ws_gate"] = jax.random.normal(ks[4], (d_model, f), jnp.float32) * s
        p["ws_up"] = jax.random.normal(ks[5], (d_model, f), jnp.float32) * s
        p["ws_down"] = jax.random.normal(
            ks[6], (f, d_model), jnp.float32) * (1.0 / jnp.sqrt(f))
    return p


def _capacity(n_tokens: int, k: int, E: int, factor: float) -> int:
    c = int(n_tokens * k / E * factor) + 1
    return -(-c // 4) * 4


def _quant_dispatch(buf):
    """Per-row symmetric int8 quantization for the EP all_to_all payload
    (DeepSeek-V3-style low-precision dispatch): 2x fewer bytes on the wire
    vs bf16; scales ride along as f32 per (expert, slot)."""
    amax = jnp.max(jnp.abs(buf.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(buf.astype(jnp.float32) / scale), -127,
                 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_dispatch(q, scale):
    return (q.astype(jnp.float32) * scale).astype(COMPUTE_DTYPE)


def moe_ffn(p, x, cfg: MoEConfig, *, axis_name: str | None = None,
            quantize_dispatch: bool = False,
            shared_sharded: bool = False):
    """x: [B, L, D] (device-local when inside shard_map).
    Returns (y, aux_loss, dropped_fraction)."""
    B, L, D = x.shape
    N = B * L
    xt = x.reshape(N, D).astype(COMPUTE_DTYPE)
    m = 1 if axis_name is None else axis_size_compat(axis_name)
    E = p["router"].shape[1]
    E_loc = E // m
    k = cfg.top_k
    C = _capacity(N, k, E, cfg.capacity_factor)

    # ---- routing ----
    logits = (xt @ p["router"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    if cfg.n_experts < E:                       # mask padded experts
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = lax.top_k(probs, k)           # [N, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # switch-style load-balance aux: E * sum_e f_e * p_e
    onehot_top1 = jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32)
    f_e = onehot_top1.mean(0)
    p_e = probs.mean(0)
    aux = E * jnp.sum(f_e * p_e)

    # ---- dispatch bookkeeping (sort-based ranking) ----
    flat_e = eidx.reshape(-1)                   # [N*k]
    flat_g = gates.reshape(-1).astype(COMPUTE_DTYPE)
    src_row = jnp.arange(N * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(N * k, dtype=jnp.int32) - first[sorted_e]
    rank = jnp.zeros((N * k,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < C
    dropped = 1.0 - keep.mean()

    dst_e = jnp.where(keep, flat_e, E)          # E = garbage bin row
    dst_c = jnp.where(keep, rank, 0)

    buf = jnp.zeros((E + 1, C, D), COMPUTE_DTYPE)
    buf = buf.at[dst_e, dst_c].set(xt[src_row], mode="drop")
    buf = buf[:E]                               # [E, C, D]

    # ---- EP exchange ----
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    if axis_name is not None:
        if quantize_dispatch:
            q, scale = _quant_dispatch(buf)
            qs = lax.all_to_all(q.reshape(m, E_loc, C, D), axis_name,
                                split_axis=0, concat_axis=0)
            ss = lax.all_to_all(scale.reshape(m, E_loc, C, 1), axis_name,
                                split_axis=0, concat_axis=0)
            recv = _dequant_dispatch(qs, ss)
        else:
            send = buf.reshape(m, E_loc, C, D)
            recv = lax.all_to_all(send, axis_name, split_axis=0,
                                  concat_axis=0)
        # [m(src), E_loc, C, D] -> [E_loc, m*C, D]
        hbuf = jnp.moveaxis(recv, 0, 1).reshape(E_loc, m * C, D)
    else:
        hbuf = buf

    g = jnp.einsum("ecd,edf->ecf", hbuf, w_gate.astype(COMPUTE_DTYPE))
    u = jnp.einsum("ecd,edf->ecf", hbuf, w_up.astype(COMPUTE_DTYPE))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(COMPUTE_DTYPE))

    if axis_name is not None:
        back = jnp.moveaxis(out.reshape(E_loc, m, C, D), 1, 0)
        ret = lax.all_to_all(back, axis_name, split_axis=0, concat_axis=0)
        ret = ret.reshape(E, C, D)
    else:
        ret = out

    # ---- combine ----
    vals = ret[jnp.clip(dst_e, 0, E - 1), dst_c]             # [N*k, D]
    vals = vals * (flat_g * keep.astype(COMPUTE_DTYPE))[:, None]
    y = jnp.zeros((N, D), COMPUTE_DTYPE).at[src_row].add(vals)

    # ---- shared experts (always-on) ----
    if "ws_gate" in p:
        sg = jax.nn.silu(xt @ p["ws_gate"].astype(COMPUTE_DTYPE))
        su = xt @ p["ws_up"].astype(COMPUTE_DTYPE)
        ysh = (sg * su) @ p["ws_down"].astype(COMPUTE_DTYPE)
        if shared_sharded and axis_name is not None:
            # column-sharded shared experts under EP: partial sums
            ysh = lax.psum(ysh, axis_name)
        y = y + ysh

    return y.reshape(B, L, D).astype(x.dtype), aux, dropped


def moe_ffn_shard_map(p, x, cfg: MoEConfig, mesh, dp_axes: tuple,
                      model_axis: str = "model",
                      quantize_dispatch: bool = False):
    """EP wrapper: runs ``moe_ffn`` inside shard_map on the ambient mesh so
    the dispatch/return all_to_alls are real collectives over ``model``."""
    from jax.sharding import PartitionSpec as P

    bspec = dp_axes if len(dp_axes) != 1 else dp_axes[0]
    x_spec = P(bspec, None, None)

    def pspec(path_leaf_name, leaf):
        name = path_leaf_name
        if name in ("w_gate", "w_up", "w_down"):
            return P(*(("model",) + (None,) * (leaf.ndim - 1)))
        if name in ("ws_gate", "ws_up"):
            return P(None, "model") if leaf.shape[1] % mesh.shape[
                model_axis] == 0 else P()
        if name == "ws_down":
            return P("model", None) if leaf.shape[0] % mesh.shape[
                model_axis] == 0 else P()
        return P()

    p_specs = {k: pspec(k, v) for k, v in p.items()}
    all_axes = tuple(dp_axes) + (model_axis,)

    shared_sharded = ("ws_gate" in p and p["ws_gate"].shape[1]
                      % mesh.shape[model_axis] == 0)

    def body(p_l, x_l):
        y, aux, dropped = moe_ffn(p_l, x_l, cfg, axis_name=model_axis,
                                  quantize_dispatch=quantize_dispatch,
                                  shared_sharded=shared_sharded)
        aux = lax.pmean(aux, all_axes)
        dropped = lax.pmean(dropped, all_axes)
        return y, aux, dropped

    fn = shard_map_compat(
        body, mesh=mesh, in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P(), P()))
    return fn(p, x)


def moe_ffn_dense_ref(p, x, cfg: MoEConfig):
    """Oracle: computes every expert densely and combines with router
    weights — no capacity, no drops.  For tests only (O(E) compute)."""
    B, L, D = x.shape
    xt = x.reshape(B * L, D).astype(jnp.float32)
    E = p["router"].shape[1]
    logits = xt @ p["router"]
    if cfg.n_experts < E:
        logits = jnp.where(jnp.arange(E)[None] >= cfg.n_experts, -1e30,
                           logits)
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    g = jnp.einsum("nd,edf->enf", xt, p["w_gate"])
    u = jnp.einsum("nd,edf->enf", xt, p["w_up"])
    h = jax.nn.silu(g) * u
    out = jnp.einsum("enf,efd->end", h, p["w_down"])     # [E, N, D]
    w = jnp.zeros((B * L, E)).at[
        jnp.arange(B * L)[:, None], eidx].add(gates)
    y = jnp.einsum("ne,end->nd", w, out)
    if "ws_gate" in p:
        y = y + (jax.nn.silu(xt @ p["ws_gate"]) * (xt @ p["ws_up"])) \
            @ p["ws_down"]
    return y.reshape(B, L, D).astype(x.dtype)
