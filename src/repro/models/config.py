"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int          # routed experts
    top_k: int
    d_expert: int           # per-expert FFN hidden size
    n_shared: int = 0       # always-on shared experts
    first_k_dense: int = 0  # leading dense layers (DeepSeek/Moonlight style)
    router_aux_weight: float = 0.01
    capacity_factor: float = 1.25
    quantize_dispatch: bool = False   # int8 EP all_to_all payload


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64       # N (per-head state dim)
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64      # P (channels per head); heads = expand*d/head_dim
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    lora_w: int = 64        # decay LoRA rank
    lora_mix: int = 32      # token-mix ddlerp LoRA rank
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str             # dense | moe | rwkv | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    hidden_act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = False
    # gemma-2 specifics
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None    # local-attention window
    local_global: bool = False              # alternate local/global layers
    gemma_norms: bool = False               # (1+g) RMSNorm + post-norms
    embed_scale: bool = False               # multiply embeddings by sqrt(d)
    query_scale: Optional[float] = None
    # mixtures / ssm / rwkv
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    attn_every: Optional[int] = None        # hybrid: shared attn each k layers
    # multimodal frontends (stubs per the brief)
    frontend: Optional[str] = None          # siglip_stub | encodec_stub
    vision_tokens: int = 256
    d_vision: int = 1152
    n_codebooks: int = 1
    # sub-quadratic flag (decides long_500k eligibility)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.moe is not None:
            e = self.moe
            ff_routed = 3 * d * e.d_expert * e.n_experts
            ff_shared = 3 * d * e.d_expert * e.n_shared
            router = d * e.n_experts
            dense_ff = 3 * d * self.d_ff
            n_moe = self.n_layers - e.first_k_dense
            ff_total = (n_moe * (ff_routed + ff_shared + router)
                        + e.first_k_dense * dense_ff)
        else:
            ff_total = self.n_layers * 3 * d * self.d_ff
        if self.rwkv is not None:
            # r,k,v,g,o (d*d each) + decay/mix loras + channel-mix (2 mats)
            tm = (5 * d * d + 2 * d * self.rwkv.lora_w
                  + 2 * 5 * d * self.rwkv.lora_mix)
            cm = d * self.d_ff + self.d_ff * d
            core = self.n_layers * (tm + cm)
        elif self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            heads = d_in // s.head_dim
            per_mamba = (d * (2 * d_in + 2 * s.d_state + heads) + d_in * d
                         + s.d_conv * (d_in + 2 * s.d_state))
            core = self.n_layers * per_mamba
            if self.attn_every:   # one SHARED attn+mlp block (zamba2-style)
                core += attn + 3 * d * self.d_ff
        else:
            core = self.n_layers * attn + ff_total
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.frontend == "encodec_stub":
            embed *= max(1, self.n_codebooks)
        return int(core + embed)

    def active_param_count(self) -> int:
        """Active (per-token) parameters — the MoE 6*N_active*D factor."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        e = self.moe
        attn = (d * (self.n_heads * self.resolved_head_dim)
                + 2 * d * (self.n_kv_heads * self.resolved_head_dim)
                + (self.n_heads * self.resolved_head_dim) * d)
        ff_active = 3 * d * e.d_expert * (e.top_k + e.n_shared)
        n_moe = self.n_layers - e.first_k_dense
        core = (self.n_layers * attn + n_moe * ff_active
                + e.first_k_dense * 3 * d * self.d_ff)
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(core + embed)
