"""RWKV-6 (Finch) block: data-dependent-decay time mix + channel mix.

Faithful to arXiv:2404.05892's structure: token-shift ddlerp mixing with a
low-rank (LoRA) data-dependent part for the five mix vectors, a LoRA'd
data-dependent per-channel decay ``w``, the u-bonus WKV recurrence, and the
squared-ReLU channel mix.  The WKV recurrence runs through the shared
chunked linear-attention core (``repro.models.linear_attn``) so prefill is
dense matmuls; decode is the O(1) state step.

State per layer: (shift_tm [B, D], shift_cm [B, D], wkv [B, H, K, K]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, init_rmsnorm, rmsnorm
from repro.models.linear_attn import chunked_linear_attn, linear_attn_step


def init_rwkv_block(key, cfg: ModelConfig):
    d = cfg.d_model
    r = cfg.rwkv
    ks = jax.random.split(key, 16)
    s = 1.0 / jnp.sqrt(d)
    H = d // r.head_dim
    p = {
        "ln_tm": init_rmsnorm(d), "ln_cm": init_rmsnorm(d),
        # ddlerp base mixes (5: r, k, v, w, g) + LoRA
        "mix_base": jax.random.uniform(ks[0], (5, d), jnp.float32),
        "mix_lora_a": jax.random.normal(ks[1], (d, r.lora_mix), jnp.float32) * s,
        "mix_lora_b": jax.random.normal(
            ks[2], (5, r.lora_mix, d), jnp.float32) * 0.01,
        "mix_first": jax.random.uniform(ks[3], (d,), jnp.float32),
        # projections
        "wr": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        "wk": jax.random.normal(ks[5], (d, d), jnp.float32) * s,
        "wv": jax.random.normal(ks[6], (d, d), jnp.float32) * s,
        "wg": jax.random.normal(ks[7], (d, d), jnp.float32) * s,
        "wo": jax.random.normal(ks[8], (d, d), jnp.float32) * s,
        # decay: w = exp(-exp(w0 + lora(x)))
        "w0": jnp.full((d,), -2.0, jnp.float32)
        + jax.random.normal(ks[9], (d,), jnp.float32) * 0.1,
        "w_lora_a": jax.random.normal(ks[10], (d, r.lora_w), jnp.float32) * s,
        "w_lora_b": jax.random.normal(
            ks[11], (r.lora_w, d), jnp.float32) * 0.01,
        "u": jax.random.normal(ks[12], (H, r.head_dim), jnp.float32) * 0.1,
        "ln_x": init_rmsnorm(d),
        # channel mix
        "cm_mix": jax.random.uniform(ks[13], (2, d), jnp.float32),
        "cm_k": jax.random.normal(ks[14], (d, cfg.d_ff), jnp.float32) * s,
        "cm_v": jax.random.normal(
            ks[15], (cfg.d_ff, d), jnp.float32) / jnp.sqrt(cfg.d_ff),
        "cm_r": jax.random.normal(ks[7], (d, d), jnp.float32) * s,
    }
    return p


def _shift(x, shift_state):
    """Token shift: x_prev[t] = x[t-1]; position 0 reads the carried state.
    x: [B, L, D]; shift_state: [B, D] -> (x_prev, new_state)."""
    prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    return prev, x[:, -1]


def rwkv_block(p, x, cfg: ModelConfig, state=None):
    """x: [B, L, D].  state: dict(shift_tm, shift_cm, wkv) or None.
    Returns (y, new_state)."""
    B, L, D = x.shape
    r = cfg.rwkv
    H, K = D // r.head_dim, r.head_dim
    if state is None:
        state = {
            "shift_tm": jnp.zeros((B, D), x.dtype),
            "shift_cm": jnp.zeros((B, D), x.dtype),
            "wkv": jnp.zeros((B, H, K, K), jnp.float32),
        }

    # ---- time mix ----
    xa = rmsnorm(p["ln_tm"], x, cfg.norm_eps)
    prev, tm_last = _shift(xa, state["shift_tm"])
    dx = prev - xa
    mix_x = xa + dx * p["mix_first"][None, None]
    lora = jnp.einsum("bld,dr->blr", mix_x.astype(COMPUTE_DTYPE),
                      p["mix_lora_a"].astype(COMPUTE_DTYPE))
    lora = jnp.tanh(lora)
    dyn = jnp.einsum("blr,srd->sbld", lora,
                     p["mix_lora_b"].astype(COMPUTE_DTYPE))
    mixes = p["mix_base"][:, None, None, :].astype(COMPUTE_DTYPE) + dyn
    xr, xk, xv, xw, xg = [xa + dx * mixes[i] for i in range(5)]

    rq = (xr.astype(COMPUTE_DTYPE) @ p["wr"].astype(COMPUTE_DTYPE))
    kk = (xk.astype(COMPUTE_DTYPE) @ p["wk"].astype(COMPUTE_DTYPE))
    vv = (xv.astype(COMPUTE_DTYPE) @ p["wv"].astype(COMPUTE_DTYPE))
    gg = jax.nn.silu(xg.astype(COMPUTE_DTYPE) @ p["wg"].astype(COMPUTE_DTYPE))

    wl = jnp.tanh(xw.astype(COMPUTE_DTYPE) @ p["w_lora_a"].astype(
        COMPUTE_DTYPE)) @ p["w_lora_b"].astype(COMPUTE_DTYPE)
    log_w = -jnp.exp(
        jnp.clip(p["w0"][None, None].astype(jnp.float32)
                 + wl.astype(jnp.float32), -8.0, 2.0))      # [B, L, D] (<0)

    def heads(a):
        return a.reshape(B, L, H, K).transpose(0, 2, 1, 3)

    y, wkv = chunked_linear_attn(
        heads(rq), heads(kk), heads(vv),
        heads(log_w.astype(jnp.float32)), mode="rwkv",
        u=p["u"].astype(COMPUTE_DTYPE), state0=state["wkv"], chunk=r.chunk)
    y = y.transpose(0, 2, 1, 3).reshape(B, L, D)
    y = rmsnorm(p["ln_x"], y, cfg.norm_eps) * gg
    y = (y @ p["wo"].astype(COMPUTE_DTYPE)).astype(x.dtype)
    x = x + y

    # ---- channel mix ----
    xb = rmsnorm(p["ln_cm"], x, cfg.norm_eps)
    prev_c, cm_last = _shift(xb, state["shift_cm"])
    dxc = prev_c - xb
    xk2 = xb + dxc * p["cm_mix"][0][None, None]
    xr2 = xb + dxc * p["cm_mix"][1][None, None]
    kcm = jnp.square(jax.nn.relu(
        xk2.astype(COMPUTE_DTYPE) @ p["cm_k"].astype(COMPUTE_DTYPE)))
    vcm = kcm @ p["cm_v"].astype(COMPUTE_DTYPE)
    gate = jax.nn.sigmoid(
        xr2.astype(COMPUTE_DTYPE) @ p["cm_r"].astype(COMPUTE_DTYPE))
    x = x + (vcm * gate).astype(x.dtype)

    new_state = {"shift_tm": tm_last.astype(x.dtype),
                 "shift_cm": cm_last.astype(x.dtype), "wkv": wkv}
    return x, new_state
