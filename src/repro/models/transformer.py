"""Unified decoder LM covering all assigned architecture families.

Families
--------
dense   llama-style (deepseek-7b, smollm-360m, yi-6b) and gemma-2 variants
        (local/global alternation, softcaps, pre+post norms)
moe     qwen2-moe / moonlight (routed + shared experts, first-k dense)
rwkv    RWKV-6 Finch (attention-free)
hybrid  zamba2 (Mamba-2 backbone + one *shared* attention block every k)
vlm     paligemma (SigLIP-stub prefix + gemma backbone, prefix-LM mask)
audio   musicgen (EnCodec-stub: 4 codebooks summed in, 4 heads out)

Layers are stacked with ``lax.scan`` (stacked [L, ...] params) so the HLO
stays small at 30-50 layers; per-layer static variation (sliding window,
first-k-dense) is carried as scanned flag arrays.  ``jax.checkpoint`` wraps
the scan body under ``remat=True``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as Lyr
from repro.models import mamba2, moe as moe_mod, rwkv6
from repro.models.config import ModelConfig

GLOBAL_WINDOW = 1 << 30


# --------------------------- block init -------------------------------------

def init_dense_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": Lyr.init_rmsnorm(cfg.d_model),
        "attn": Lyr.init_attention(k1, cfg),
        "ln2": Lyr.init_rmsnorm(cfg.d_model),
        "mlp": Lyr.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }
    if cfg.gemma_norms:
        p["post_ln1"] = Lyr.init_rmsnorm(cfg.d_model)
        p["post_ln2"] = Lyr.init_rmsnorm(cfg.d_model)
    return p


def init_moe_block(key, cfg: ModelConfig, ep_degree: int):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": Lyr.init_rmsnorm(cfg.d_model),
        "attn": Lyr.init_attention(k1, cfg),
        "ln2": Lyr.init_rmsnorm(cfg.d_model),
        "moe": moe_mod.init_moe(k2, cfg.d_model, cfg.moe, ep_degree),
    }


# --------------------------- block apply ------------------------------------

def dense_block(p, x, cfg, positions, window, cache=None, cache_index=None,
                prefix_len=None):
    h = Lyr.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, new_cache = Lyr.attention(
        p["attn"], h, cfg, positions, cache=cache, cache_index=cache_index,
        sliding_window=window, prefix_len=prefix_len)
    if cfg.gemma_norms:
        a = Lyr.rmsnorm(p["post_ln1"], a, cfg.norm_eps)
    x = x + a
    h = Lyr.rmsnorm(p["ln2"], x, cfg.norm_eps)
    m = Lyr.mlp(p["mlp"], h, cfg.hidden_act)
    if cfg.gemma_norms:
        m = Lyr.rmsnorm(p["post_ln2"], m, cfg.norm_eps)
    return x + m, new_cache


def moe_block(p, x, cfg, positions, cache=None, cache_index=None,
              mesh=None, dp_axes=()):
    h = Lyr.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, new_cache = Lyr.attention(
        p["attn"], h, cfg, positions, cache=cache, cache_index=cache_index)
    x = x + a
    h = Lyr.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if mesh is not None:
        m, aux, dropped = moe_mod.moe_ffn_shard_map(
            p["moe"], h, cfg.moe, mesh, dp_axes,
            quantize_dispatch=cfg.moe.quantize_dispatch)
    else:
        m, aux, dropped = moe_mod.moe_ffn(p["moe"], h, cfg.moe)
    return x + m, new_cache, aux, dropped


# --------------------------- model init -------------------------------------

def _stacked(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_model(key, cfg: ModelConfig, *, ep_degree: int = 1):
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": Lyr.init_embedding(
            keys[0],
            cfg.vocab_size * (cfg.n_codebooks
                              if cfg.frontend == "encodec_stub" else 1),
            cfg.d_model),
        "final_norm": Lyr.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Lyr.init_embedding(
            keys[1],
            cfg.vocab_size * (cfg.n_codebooks
                              if cfg.frontend == "encodec_stub" else 1),
            cfg.d_model)
    if cfg.frontend == "siglip_stub":
        params["vision_proj"] = Lyr._dense_init(
            keys[2], (cfg.d_vision, cfg.d_model))

    if cfg.family in ("dense", "vlm", "audio"):
        params["layers"] = _stacked(
            lambda k: init_dense_block(k, cfg), keys[3], cfg.n_layers)
    elif cfg.family == "moe":
        fk = cfg.moe.first_k_dense
        if fk:
            params["dense_layers"] = _stacked(
                lambda k: init_dense_block(k, cfg), keys[4], fk)
        params["layers"] = _stacked(
            lambda k: init_moe_block(k, cfg, ep_degree), keys[3],
            cfg.n_layers - fk)
    elif cfg.family == "rwkv":
        params["layers"] = _stacked(
            lambda k: rwkv6.init_rwkv_block(k, cfg), keys[3], cfg.n_layers)
    elif cfg.family == "hybrid":
        params["layers"] = _stacked(
            lambda k: mamba2.init_mamba_block(k, cfg), keys[3], cfg.n_layers)
        params["shared_attn"] = init_dense_block(keys[5], cfg)
    else:
        raise ValueError(cfg.family)
    return params


# --------------------------- cache init -------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """KV/state caches, stacked along the scanned-layer axis."""
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    if cfg.family in ("dense", "vlm", "audio"):
        return {"k": jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), dtype),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), dtype)}
    if cfg.family == "moe":
        fk = cfg.moe.first_k_dense
        c = {"k": jnp.zeros((cfg.n_layers - fk, batch, max_len, KV, hd),
                            dtype),
             "v": jnp.zeros((cfg.n_layers - fk, batch, max_len, KV, hd),
                            dtype)}
        if fk:
            c["dense_k"] = jnp.zeros((fk, batch, max_len, KV, hd), dtype)
            c["dense_v"] = jnp.zeros((fk, batch, max_len, KV, hd), dtype)
        return c
    if cfg.family == "rwkv":
        H = cfg.d_model // cfg.rwkv.head_dim
        K = cfg.rwkv.head_dim
        L = cfg.n_layers
        return {"shift_tm": jnp.zeros((L, batch, cfg.d_model), dtype),
                "shift_cm": jnp.zeros((L, batch, cfg.d_model), dtype),
                "wkv": jnp.zeros((L, batch, H, K, K), jnp.float32)}
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        H = d_in // s.head_dim
        conv_ch = d_in + 2 * s.d_state
        L = cfg.n_layers
        n_attn = cfg.n_layers // cfg.attn_every
        return {
            "conv": jnp.zeros((L, batch, s.d_conv - 1, conv_ch), dtype),
            "ssd": jnp.zeros((L, batch, H, s.d_state, s.head_dim),
                             jnp.float32),
            "k": jnp.zeros((n_attn, batch, max_len, KV, hd), dtype),
            "v": jnp.zeros((n_attn, batch, max_len, KV, hd), dtype),
        }
    raise ValueError(cfg.family)


# --------------------------- forward ----------------------------------------

def _layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer sliding-window sizes (gemma2 alternation)."""
    if cfg.local_global and cfg.sliding_window:
        w = [cfg.sliding_window if i % 2 == 0 else GLOBAL_WINDOW
             for i in range(cfg.n_layers)]
    elif cfg.sliding_window:
        w = [cfg.sliding_window] * cfg.n_layers
    else:
        w = [GLOBAL_WINDOW] * cfg.n_layers
    return jnp.asarray(w, jnp.int32)


def _remat(body, remat):
    """remat=True: full recompute; remat="dots": save GEMM outputs and
    recompute only the cheap elementwise chain (selective checkpointing)."""
    if remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_saveable)
    if remat:
        return jax.checkpoint(body)
    return body


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "remat", "mesh", "dp_axes",
                     "prefix_len_static"))
def forward(params, tokens, cfg: ModelConfig, *,
            positions=None, cache=None, cache_index=None,
            frontend_inputs=None, remat=False,
            mesh=None, dp_axes: tuple = (),
            prefix_len_static: Optional[int] = None):
    """Returns (logits, aux_metrics, new_cache).

    tokens: [B, L] int32 — or [B, n_codebooks, L] for the audio family.
    frontend_inputs: [B, vision_tokens, d_vision] for the vlm family.
    cache/cache_index: decode mode (L is typically 1).
    """
    aux = {"moe_aux": jnp.float32(0.0), "moe_dropped": jnp.float32(0.0)}

    # ---- embed ----
    if cfg.family == "audio":
        B, nq, L = tokens.shape
        offs = (jnp.arange(nq, dtype=jnp.int32) * cfg.vocab_size)[None, :,
                                                                  None]
        x = Lyr.embed(params["embed"], tokens + offs)
        x = x.sum(axis=1)                                   # [B, L, D]
    else:
        B, L = tokens.shape
        x = Lyr.embed(params["embed"], tokens,
                      scale_by_sqrt_d=cfg.embed_scale)

    prefix_len = None
    if cfg.family == "vlm" and frontend_inputs is not None:
        vis = (frontend_inputs.astype(Lyr.COMPUTE_DTYPE)
               @ params["vision_proj"].astype(Lyr.COMPUTE_DTYPE))
        x = jnp.concatenate([vis, x], axis=1)
        L = x.shape[1]
        prefix_len = cfg.vision_tokens
    elif prefix_len_static is not None:
        prefix_len = prefix_len_static

    if positions is None:
        if cache_index is not None:
            positions = cache_index + jnp.arange(L, dtype=jnp.int32)
        else:
            positions = jnp.arange(L, dtype=jnp.int32)

    new_cache = dict(cache) if cache is not None else None

    # ---- layer stacks ----
    if cfg.family in ("dense", "vlm", "audio"):
        windows = _layer_windows(cfg)

        def body(x, xs):
            lp, win, ck, cv = xs
            c = None if ck is None else {"k": ck, "v": cv}
            y, nc = dense_block(lp, x, cfg, positions, win, cache=c,
                                cache_index=cache_index,
                                prefix_len=prefix_len)
            return y, (None if nc is None else (nc["k"], nc["v"]))

        body = _remat(body, remat)
        if cache is None:
            x, _ = lax.scan(body, x, (params["layers"], windows, None, None))
        else:
            x, kv = lax.scan(body, x,
                             (params["layers"], windows, cache["k"],
                              cache["v"]))
            new_cache["k"], new_cache["v"] = kv

    elif cfg.family == "moe":
        fk = cfg.moe.first_k_dense
        for i in range(fk):
            lp = jax.tree.map(lambda a: a[i], params["dense_layers"])
            c = (None if cache is None else
                 {"k": cache["dense_k"][i], "v": cache["dense_v"][i]})
            x, nc = dense_block(lp, x, cfg, positions, GLOBAL_WINDOW,
                                cache=c, cache_index=cache_index)
            if nc is not None:
                new_cache["dense_k"] = new_cache["dense_k"].at[i].set(
                    nc["k"])
                new_cache["dense_v"] = new_cache["dense_v"].at[i].set(
                    nc["v"])

        def body(carry, xs):
            x, aux_s, drop_s = carry
            lp, ck, cv = xs
            c = None if ck is None else {"k": ck, "v": cv}
            y, nc, a, d = moe_block(lp, x, cfg, positions, cache=c,
                                    cache_index=cache_index,
                                    mesh=mesh, dp_axes=dp_axes)
            return ((y, aux_s + a, drop_s + d),
                    None if nc is None else (nc["k"], nc["v"]))

        if remat:
            body = jax.checkpoint(body)
        zero = jnp.float32(0.0)
        if cache is None:
            (x, aux_sum, drop_sum), _ = lax.scan(
                body, (x, zero, zero), (params["layers"], None, None))
        else:
            (x, aux_sum, drop_sum), kv = lax.scan(
                body, (x, zero, zero),
                (params["layers"], cache["k"], cache["v"]))
            new_cache["k"], new_cache["v"] = kv
        n_moe = cfg.n_layers - fk
        aux["moe_aux"] = aux_sum / n_moe
        aux["moe_dropped"] = drop_sum / n_moe

    elif cfg.family == "rwkv":
        def body(x, xs):
            lp, stm, scm, wkv = xs
            st = (None if stm is None else
                  {"shift_tm": stm, "shift_cm": scm, "wkv": wkv})
            y, ns = rwkv6.rwkv_block(lp, x, cfg, st)
            return y, (ns["shift_tm"], ns["shift_cm"], ns["wkv"])

        body = _remat(body, remat)
        if cache is None:
            x, _ = lax.scan(body, x, (params["layers"], None, None, None))
        else:
            x, st = lax.scan(body, x,
                             (params["layers"], cache["shift_tm"],
                              cache["shift_cm"], cache["wkv"]))
            (new_cache["shift_tm"], new_cache["shift_cm"],
             new_cache["wkv"]) = st

    elif cfg.family == "hybrid":
        k_every = cfg.attn_every
        n_groups = cfg.n_layers // k_every
        rem = cfg.n_layers - n_groups * k_every
        n_main = n_groups * k_every
        main = jax.tree.map(
            lambda a: a[:n_main].reshape(n_groups, k_every, *a.shape[1:]),
            params["layers"])

        def mamba_body(x, xs):
            lp, cst, sst = xs
            st = (None if cst is None else {"conv": cst, "ssd": sst})
            y, ns = mamba2.mamba_block(lp, x, cfg, st)
            return y, (ns["conv"], ns["ssd"])

        mamba_body = _remat(mamba_body, remat)

        def group_body(x, xs):
            gp, cst, sst, ck, cv = xs
            x, (ncst, nsst) = lax.scan(mamba_body, x, (gp, cst, sst))
            c = None if ck is None else {"k": ck, "v": cv}
            x, nc = dense_block(params["shared_attn"], x, cfg, positions,
                                GLOBAL_WINDOW, cache=c,
                                cache_index=cache_index)
            kv = None if nc is None else (nc["k"], nc["v"])
            return x, (ncst, nsst, kv)

        if cache is None:
            x, _ = lax.scan(group_body, x, (main, None, None, None, None))
            for li in range(n_main, cfg.n_layers):
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                x, _ = mamba2.mamba_block(lp, x, cfg, None)
        else:
            rs = lambda a: a[:n_main].reshape(n_groups, k_every,
                                              *a.shape[1:])
            x, (ncst, nsst, kv) = lax.scan(
                group_body, x,
                (main, rs(cache["conv"]), rs(cache["ssd"]),
                 cache["k"], cache["v"]))
            new_cache["conv"] = jnp.concatenate(
                [ncst.reshape(n_main, *ncst.shape[2:]),
                 cache["conv"][n_main:]], axis=0)
            new_cache["ssd"] = jnp.concatenate(
                [nsst.reshape(n_main, *nsst.shape[2:]),
                 cache["ssd"][n_main:]], axis=0)
            new_cache["k"], new_cache["v"] = kv
            for li in range(n_main, cfg.n_layers):
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                st = {"conv": cache["conv"][li], "ssd": cache["ssd"][li]}
                x, ns = mamba2.mamba_block(lp, x, cfg, st)
                new_cache["conv"] = new_cache["conv"].at[li].set(ns["conv"])
                new_cache["ssd"] = new_cache["ssd"].at[li].set(ns["ssd"])
    else:
        raise ValueError(cfg.family)

    # ---- head ----
    x = Lyr.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.family == "audio":
        logits = Lyr.unembed({"table": head["table"]}, x,
                             final_softcap=cfg.final_softcap)
        Lq = logits.shape[1]
        logits = logits.reshape(B, Lq, cfg.n_codebooks, cfg.vocab_size)
    else:
        logits = Lyr.unembed({"table": head["table"]}, x,
                             final_softcap=cfg.final_softcap)
        if cfg.family == "vlm" and frontend_inputs is not None:
            logits = logits[:, cfg.vision_tokens:]
    return logits, aux, new_cache


def lm_loss(logits, labels, mask=None):
    """Cross entropy; labels [B, L] (or [B, nq, L] for the audio family)."""
    if logits.ndim == 4:       # audio: [B, L, nq, V], labels [B, nq, L]
        labels = jnp.moveaxis(labels, 1, 2)
        if mask is not None and mask.ndim == 3:
            mask = jnp.moveaxis(mask, 1, 2)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones(ll.shape, jnp.float32)
    else:
        while mask.ndim < ll.ndim:
            mask = mask[..., None]
        mask = jnp.broadcast_to(mask, ll.shape).astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
