"""Mamba-2 block (SSD form), used by zamba2-1.2b.

Structure per arXiv:2405.21060: fused input projection producing
(z, x, B, C, dt), short causal depthwise conv over (x, B, C), scalar-per-head
data-dependent decay ``a_t = exp(-dt * exp(A_log))``, the SSD recurrence via
the shared chunked linear-attention core, gated output.

State per layer: (conv [B, d_conv-1, d_conv_ch], ssd [B, H, N, P]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, init_rmsnorm, rmsnorm
from repro.models.linear_attn import chunked_linear_attn, linear_attn_step


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.d_state      # x + B + C go through the conv
    return s, d_in, H, conv_ch


def init_mamba_block(key, cfg: ModelConfig):
    s, d_in, H, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * s.d_state + H     # z, x, B, C, dt
    return {
        "ln": init_rmsnorm(d),
        "in_proj": jax.random.normal(
            ks[0], (d, proj_out), jnp.float32) / jnp.sqrt(d),
        "conv_w": jax.random.normal(
            ks[1], (s.d_conv, conv_ch), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(jax.random.uniform(
                ks[2], (H,), jnp.float32, 1e-3, 0.1))),
        "norm": init_rmsnorm(d_in),
        "out_proj": jax.random.normal(
            ks[3], (d_in, d), jnp.float32) / jnp.sqrt(d_in),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv.  x: [B, L, C]; w: [K, C]; state [B, K-1, C]."""
    Kc = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], Kc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None]
              for i in range(Kc))
    new_state = xp[:, -(Kc - 1):]
    return out + b[None, None], new_state


def mamba_block(p, x, cfg: ModelConfig, state=None):
    """x: [B, L, D] -> (y, new_state)."""
    B, L, D = x.shape
    s, d_in, H, conv_ch = _dims(cfg)
    P, N = s.head_dim, s.d_state
    if state is None:
        state = {
            "conv": jnp.zeros((B, s.d_conv - 1, conv_ch), COMPUTE_DTYPE),
            "ssd": jnp.zeros((B, H, N, P), jnp.float32),
        }

    xa = rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = (xa.astype(COMPUTE_DTYPE)
            @ p["in_proj"].astype(COMPUTE_DTYPE))    # [B, L, proj_out]
    z, xc, Bv, Cv, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)

    conv_in = jnp.concatenate([xc, Bv, Cv], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, p["conv_w"].astype(COMPUTE_DTYPE),
        p["conv_b"].astype(COMPUTE_DTYPE), state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xc, Bv, Cv = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])        # [B, L, H]
    a = -jnp.exp(p["A_log"])[None, None] * dt               # log decay < 0

    # heads: x -> v [B,H,L,P]; B -> k [B,H,L,N]; C -> q
    v = xc.reshape(B, L, H, P).transpose(0, 2, 1, 3)
    v = v * dt.transpose(0, 2, 1)[..., None].astype(v.dtype)  # dt-scaled input
    k = jnp.broadcast_to(Bv[:, None], (B, H, L, N))
    q = jnp.broadcast_to(Cv[:, None], (B, H, L, N))
    ld = a.transpose(0, 2, 1)[..., None]                     # [B,H,L,1]

    y, ssd = chunked_linear_attn(q, k, v, ld, mode="mamba",
                                 state0=state["ssd"], chunk=s.chunk)
    y = y + p["D"][None, :, None, None].astype(y.dtype) * \
        xc.reshape(B, L, H, P).transpose(0, 2, 1, 3)   # skip path
    y = y.transpose(0, 2, 1, 3).reshape(B, L, d_in)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = (y.astype(COMPUTE_DTYPE)
           @ p["out_proj"].astype(COMPUTE_DTYPE)).astype(x.dtype)
    new_state = {"conv": conv_state, "ssd": ssd}
    return x + out, new_state
