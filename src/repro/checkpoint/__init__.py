from repro.checkpoint.checkpointer import (CheckpointManager, checkpoint_meta,
                                           latest_step, load_checkpoint,
                                           load_checkpoint_flat,
                                           save_checkpoint)

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "load_checkpoint_flat", "latest_step", "checkpoint_meta"]
