"""Fault-tolerant checkpointing: atomic, async, keep-N, mesh-elastic.

Layout (one directory per step):
    <root>/step_000000123.tmp-<nonce>/   while writing
    <root>/step_000000123/               after atomic rename
        manifest.json     pytree structure, shapes, dtypes, crc32 per leaf
        leaf_00000.npy ...

Guarantees
----------
* **Atomicity**: a checkpoint directory appears only via rename(2); readers
  never observe partial state.  A crashed writer leaves only ``.tmp-*``
  litter that the next writer garbage-collects.
* **Integrity**: every leaf carries a CRC32; restore verifies before use.
* **Elasticity**: leaves are stored as *global* arrays (gathered on save);
  ``load_checkpoint(..., shardings=...)`` re-shards onto ANY mesh shape, so
  restarts may change (pod, data, model) freely.  (At 1000+-node scale the
  same manifest format holds per-shard files; the gather becomes a
  distributed write — noted in DESIGN.md.)
* **Async**: ``CheckpointManager.save_async`` snapshots to host then hands
  the serialization to a worker thread; training continues immediately.
* **Keep-N** GC + a ``latest`` pointer written last.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.logging import get_logger

log = get_logger("checkpoint")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(root: str | Path, step: int, tree: Any,
                    meta: Optional[dict] = None) -> Path:
    """Synchronous atomic save of a pytree of (possibly sharded) arrays.

    ``meta`` (optional, JSON-serializable) rides inside the manifest —
    schema versions, config fingerprints, anything a reader must check
    before trusting the leaves (``checkpoint_meta`` reads it back without
    touching the arrays)."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    for stale in root.glob("*.tmp-*"):
        shutil.rmtree(stale, ignore_errors=True)

    final = root / f"step_{step:09d}"
    tmp = root / f"step_{step:09d}.tmp-{os.getpid()}_{time.time_ns()}"
    tmp.mkdir()

    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    if meta is not None:
        manifest["meta"] = meta
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    (root / "latest.tmp").write_text(str(step))
    os.rename(root / "latest.tmp", root / "latest")
    log.info("saved checkpoint step=%d (%d leaves)", step, len(leaves))
    return final


def latest_step(root: str | Path) -> Optional[int]:
    root = Path(root)
    marker = root / "latest"
    if marker.exists():
        s = int(marker.read_text())
        if (root / f"step_{s:09d}" / "manifest.json").exists():
            return s
    steps = sorted(int(p.name.split("_")[1]) for p in root.glob("step_*")
                   if p.is_dir() and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def _verified_leaf(d: Path, e: dict, step: int, verify: bool) -> np.ndarray:
    arr = np.load(d / e["file"])
    if verify:
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != e["crc32"]:
            raise IOError(
                f"checksum mismatch for {e['path']} at step {step}")
    return arr


def load_checkpoint(root: str | Path, tree_like: Any,
                    step: Optional[int] = None, *, shardings: Any = None,
                    verify: bool = True) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; optionally placing each
    leaf with ``shardings`` (pytree of NamedSharding) — any mesh works."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())

    paths, leaves, treedef = _flatten_with_paths(tree_like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    sh_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(leaves))

    out = []
    for path, ref, sh in zip(paths, leaves, sh_leaves):
        e = by_path[path]
        arr = _verified_leaf(d, e, step, verify)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"shape mismatch {path}: ckpt {arr.shape} vs {ref.shape}")
        if str(arr.dtype) != str(np.dtype(ref.dtype)):
            # a wrong-dtype leaf would otherwise restore silently (same
            # shape, different bits) and corrupt downstream bitwise parity
            raise ValueError(
                f"dtype mismatch {path}: ckpt {arr.dtype} vs "
                f"{np.dtype(ref.dtype)}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), step


def checkpoint_meta(root: str | Path,
                    step: Optional[int] = None) -> Optional[dict]:
    """The manifest's ``meta`` dict (None when absent) without loading
    any leaf — how resuming services validate schema/config fingerprints
    before paying for the array restore."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    return manifest.get("meta")


def load_checkpoint_flat(root: str | Path, step: Optional[int] = None, *,
                         verify: bool = True) -> tuple[dict, int]:
    """Manifest-driven restore: ``{path: np.ndarray}`` with no ``tree_like``.

    The manifest itself carries every path/shape/dtype, so a flat-dict
    checkpoint (the stage-boundary states of ``repro.run.resilient``)
    round-trips without the caller pre-declaring the structure — which is
    what lets a resumed run restore a stage whose shapes it cannot know
    yet (e.g. a top-K list widened by the overflow policy before the
    crash).  CRC verification is identical to :func:`load_checkpoint`.
    """
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    return {e["path"]: _verified_leaf(d, e, step, verify)
            for e in manifest["leaves"]}, step


class CheckpointManager:
    """Async keep-N manager around save/load."""

    def __init__(self, root: str | Path, keep_n: int = 3):
        self.root = Path(root)
        self.keep_n = keep_n
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.root, step, host_tree, meta=meta)
                self._gc()
            except BaseException as e:   # noqa: BLE001 — surfaced in wait()
                self._error = e

        self._worker = threading.Thread(target=work, daemon=True)
        self._worker.start()

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()
        save_checkpoint(self.root, step, tree, meta=meta)
        self._gc()

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None):
        self.wait()
        return load_checkpoint(self.root, tree_like, step,
                               shardings=shardings)

    def restore_flat(self, step: Optional[int] = None):
        """Manifest-driven ``{path: array}`` restore (no ``tree_like``)."""
        self.wait()
        return load_checkpoint_flat(self.root, step)

    def step_dir(self, step: int) -> Path:
        return self.root / f"step_{step:09d}"

    def available_steps(self) -> list:
        """Ascending steps with a manifest on disk (corrupt leaves are only
        detected at restore time — callers fall back step by step)."""
        return sorted(int(p.name.split("_")[1])
                      for p in self.root.glob("step_*")
                      if p.is_dir() and (p / "manifest.json").exists())

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.root.glob("step_*") if p.is_dir())
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)
