"""GSPMD partitioning rules: parameter/optimizer/activation PartitionSpecs.

Policy (TP over 'model', DP over ('pod','data'), optional ZeRO/FSDP over
'data'):

  embeddings / lm head   [V, D]      -> (model, None)        vocab-sharded
  attn q proj            [D, H, hd]  -> (None, model, None)  head-sharded
  attn kv projs          [D, KV, hd] -> (None, model, None)  if KV % m == 0
  attn out proj          [H, hd, D]  -> (model, None, None)
  mlp in projs           [D, F]      -> (None, model)
  mlp out proj           [F, D]      -> (model, None)
  MoE expert weights     [E, D, F]   -> (model, None, None)  EP
  MoE router / norms / small vectors -> replicated
  rwkv square projs      [D, D]      -> (None, model) in / (model, None) out
  mamba in_proj/conv     replicated (interleaved head layout); out_proj
                         [d_in, D]  -> (model, None)

A dimension is sharded only when divisible by the axis size; otherwise the
leaf silently falls back to replication (surfaced by ``report_sharding``).
Stacked (scanned) parameters get a leading ``None``.  Optimizer moments
reuse the same rule (ZeRO-1 for the sharded dims).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _rule(name: str, shape: tuple[int, ...], m: int,
          fsdp_axis=None, fsdp: int = 1):
    """Base spec (without the stacked leading axis)."""
    def div(i, n=m):
        return shape[i] % n == 0

    leaf = name.split("/")[-1]
    parent = name.split("/")[-2] if "/" in name else ""

    if parent in ("embed", "lm_head") and leaf == "table":
        return P("model", None) if div(0) else P()
    if leaf == "vision_proj":
        return P(None, "model") if div(1) else P()
    if parent == "attn":
        if leaf == "wq":
            return P(None, "model", None) if div(1) else P()
        if leaf in ("wk", "wv"):
            return P(None, "model", None) if div(1) else P()
        if leaf == "wo":
            return P("model", None, None) if div(0) else P()
    if parent == "mlp":
        if leaf in ("wi_gate", "wi_up"):
            return P(None, "model") if div(1) else P()
        if leaf == "wo":
            return P("model", None) if div(0) else P()
    if parent == "moe":
        if leaf in ("w_gate", "w_up", "w_down"):
            return P("model", None, None) if div(0) else P()
        if leaf in ("ws_gate", "ws_up"):
            return P(None, "model") if div(1) else P()
        if leaf == "ws_down":
            return P("model", None) if div(0) else P()
        return P()   # router + misc
    # rwkv
    if leaf in ("wr", "wk", "wv", "wg", "cm_k", "cm_r") and len(shape) == 2:
        return P(None, "model") if div(1) else P()
    if leaf in ("wo", "cm_v") and len(shape) == 2:
        return P("model", None) if div(0) else P()
    # mamba
    if leaf == "out_proj":
        return P("model", None) if div(0) else P()
    if leaf == "in_proj":
        return P()
    return P()


def param_specs(params, cfg: ModelConfig, mesh: Mesh,
                *, fsdp: bool = False, policy: str = "tp"):
    """PartitionSpec pytree matching ``params``.

    policy="tp"      : tensor parallel over 'model' (default, rules above)
    policy="dp_only" : no tensor parallelism — the 'model' axis is treated
                       as extra data parallelism and parameters are
                       FSDP-sharded over BOTH axes on their largest dim.
                       Right operating point for small models whose heads
                       don't divide the TP degree (e.g. smollm's 15 heads).
    """
    m = mesh.shape.get("model", 1)
    d = mesh.shape.get("data", 1)

    def one(path, leaf):
        name = _path_str(path)
        stacked = name.startswith(("layers/", "dense_layers/"))
        shape = leaf.shape[1:] if stacked else leaf.shape
        if policy == "dp_only":
            # pure data parallelism: params replicated (XLA's partial-sum
            # heuristics turn FSDP shards into activation all-reduces for
            # small models — measured in EXPERIMENTS.md §Perf)
            parts = [None] * len(shape)
        elif policy == "dp_fsdp":
            parts = [None] * len(shape)
            order = sorted(range(len(shape)), key=lambda i: -shape[i])
            for ax_name, ax_size in (("model", m), ("data", d)):
                for i in order:
                    if (parts[i] is None and ax_size > 1
                            and shape[i] % ax_size == 0
                            and shape[i] >= ax_size):
                        parts[i] = ax_name
                        break
        else:
            spec = _rule(name, shape, m)
            parts = list(spec)
            while len(parts) < len(shape):
                parts.append(None)
            if fsdp and d > 1:
                # ZeRO-3-style: additionally shard the largest unsharded dim
                for i, pp in enumerate(parts):
                    if pp is None and shape[i] % d == 0 and shape[i] >= d * 8:
                        parts[i] = "data"
                        break
        if stacked:
            parts = [None] + parts
        return P(*parts)

    return jax.tree_util.tree_map_with_path(one, params)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def report_sharding(params, specs) -> dict:
    """Bytes sharded vs replicated — surfaces silent replication fallbacks."""
    total = 0
    replicated = 0
    flat = jax.tree_util.tree_leaves_with_path(params)
    sflat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat, sflat):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        total += nbytes
        if all(s is None for s in spec):
            replicated += nbytes
    return {"total_bytes": total, "replicated_bytes": replicated,
            "replicated_frac": replicated / max(total, 1)}


# --------------------------- activation specs --------------------------------

def batch_spec(mesh: Mesh) -> P:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(dp if len(dp) > 1 else dp[0] if dp else None)


def data_specs(cfg: ModelConfig, mesh: Mesh, *, kind: str,
               global_batch: int, seq_len: int, policy: str = "tp"):
    """Input/cache PartitionSpecs for a (shape kind, arch) cell."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if policy in ("dp_only", "dp_fsdp"):
        dp_axes = dp_axes + tuple(
            a for a in ("model",) if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    m = mesh.shape.get("model", 1)
    bspec = dp_axes if global_batch % max(dp, 1) == 0 and dp > 1 else None
    if bspec is None and dp > 1 and global_batch > 1:
        # surfaced, not silent: replicated batch means every device computes
        # the full global batch (EXPERIMENTS.md §Perf portfolio check)
        import warnings
        warnings.warn(
            f"global_batch={global_batch} does not divide the data-parallel "
            f"degree {dp} ({dp_axes}); batch will be REPLICATED on every "
            "device — compute will not scale", stacklevel=2)
    if isinstance(bspec, tuple) and len(bspec) == 1:
        bspec = bspec[0]

    if cfg.family == "audio":
        tok = P(bspec, None, None)
    else:
        tok = P(bspec, None)

    specs = {"tokens": tok}
    if cfg.family == "vlm":
        specs["frontend"] = P(bspec, None, None)
    if kind == "train":
        specs["labels"] = tok
        return specs

    # decode: cache specs
    seq_axis = None
    if bspec is None and "data" in mesh.shape and seq_len % mesh.shape[
            "data"] == 0:
        seq_axis = "data"       # long-context: shard the KV cache sequence
    kv_ax = ("model" if cfg.n_kv_heads % m == 0 and m > 1
             and policy == "tp" else None)

    cache = {}
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        cache["k"] = P(None, bspec, seq_axis, kv_ax, None)
        cache["v"] = P(None, bspec, seq_axis, kv_ax, None)
        if cfg.family == "moe" and cfg.moe.first_k_dense:
            cache["dense_k"] = cache["k"]
            cache["dense_v"] = cache["v"]
    if cfg.family == "rwkv":
        h_ax = ("model" if (cfg.d_model // cfg.rwkv.head_dim) % m == 0
                and policy == "tp" else None)
        cache["shift_tm"] = P(None, bspec, None)
        cache["shift_cm"] = P(None, bspec, None)
        cache["wkv"] = P(None, bspec, h_ax, None, None)
    if cfg.family == "hybrid":
        s = cfg.ssm
        h = (s.expand * cfg.d_model) // s.head_dim
        h_ax = "model" if h % m == 0 and policy == "tp" else None
        cache["conv"] = P(None, bspec, None, None)
        cache["ssd"] = P(None, bspec, h_ax, None, None)
        cache["k"] = P(None, bspec, seq_axis, kv_ax, None)
        cache["v"] = P(None, bspec, seq_axis, kv_ax, None)
    specs["cache"] = cache
    return specs
