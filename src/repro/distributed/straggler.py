"""Straggler detection — the paper's windowed change detector, repurposed.

TSA1 cuts a trajectory when the mean of two adjacent sliding windows over its
voting signal diverges; a straggling host is the same signal shape: its
step-time series departs from the fleet's.  ``StragglerMonitor`` keeps a
per-host ring buffer of step durations and flags hosts whose recent window
mean exceeds the fleet median by ``threshold`` sigmas (or ratio).

Hooks: ``on_straggler`` receives (host_id, ratio); production deployments
wire this to the elastic controller (checkpoint-evict-restart, or re-split
the equi-depth partitions the way the paper rebalances time bins —
``suggest_rebalance_edges`` computes that re-split; the resilient runner
``repro.run.resilient`` records both in its JSONL telemetry).
"""
from __future__ import annotations

import collections
import warnings
from typing import Callable, Optional

import numpy as np


class StragglerMonitor:
    def __init__(self, n_hosts: int, window: int = 16,
                 ratio_threshold: float = 1.5,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.n_hosts = n_hosts
        self.window = window
        self.ratio_threshold = ratio_threshold
        self.on_straggler = on_straggler
        self.history = [collections.deque(maxlen=2 * window)
                        for _ in range(n_hosts)]
        self.flagged: dict[int, float] = {}

    def record(self, host: int, step_seconds: float):
        self.history[host].append(step_seconds)

    def record_all(self, step_seconds):
        for h, s in enumerate(step_seconds):
            self.record(h, float(s))

    def reset(self, host: int):
        """Drop a host's history (and any current flag) — an evicted or
        rebalanced rank restarts with a clean series, so its pre-eviction
        step times can't keep re-flagging it."""
        self.history[host].clear()
        self.flagged.pop(host, None)

    def check(self) -> dict[int, float]:
        """Returns {host: ratio} for currently-flagged stragglers."""
        means = []
        for h in range(self.n_hosts):
            buf = list(self.history[h])[-self.window:]
            means.append(np.mean(buf) if buf else np.nan)
        means = np.asarray(means)
        with warnings.catch_warnings():
            # hosts with no samples contribute NaN; an all-NaN fleet is a
            # legal "no data yet" state, not a RuntimeWarning
            warnings.simplefilter("ignore", RuntimeWarning)
            fleet = np.nanmedian(means)
        self.flagged = {}
        if not np.isfinite(fleet) or fleet <= 0:
            return self.flagged
        for h in range(self.n_hosts):
            if np.isfinite(means[h]):
                ratio = float(means[h] / fleet)
                if ratio >= self.ratio_threshold:
                    self.flagged[h] = ratio
                    if self.on_straggler:
                        self.on_straggler(h, ratio)
        return self.flagged

    def change_detected(self, host: int, tau: float = 0.5) -> bool:
        """TSA1-style: |mean(W1) - mean(W2)| / mean(W1) > tau on the host's
        own series — catches a host that *becomes* slow (vs. always-slow)."""
        buf = list(self.history[host])
        if len(buf) < 2 * self.window:
            return False
        w1 = np.mean(buf[-2 * self.window:-self.window])
        w2 = np.mean(buf[-self.window:])
        return abs(w2 - w1) / max(w1, 1e-9) > tau


def suggest_rebalance_edges(times, part_of: np.ndarray,
                            flagged: dict[int, float],
                            P: int) -> np.ndarray:
    """Slowdown-weighted equi-depth re-split of the temporal bins.

    ``times``/``part_of`` give each valid point's timestamp and current
    partition; a point in a flagged partition is weighted by that
    partition's slowdown ratio, so the weighted equi-depth quantiles
    narrow the slow partitions' time ranges proportionally — the paper's
    time-bin rebalancing driven by the monitor's flags instead of the
    input histogram.  Returns ``P + 1`` edges shaped like
    ``repro.core.partitioning.equi_depth_edges`` (±inf outer edges).
    """
    times = np.asarray(times, np.float64).ravel()
    part_of = np.asarray(part_of).ravel()
    w = np.ones_like(times)
    for p, ratio in flagged.items():
        w[part_of == p] = max(float(ratio), 1.0)
    order = np.argsort(times, kind="stable")
    times, w = times[order], w[order]
    cum = np.cumsum(w)
    targets = cum[-1] * np.arange(1, P) / P
    inner = times[np.searchsorted(cum, targets, side="left")]
    inner = np.maximum.accumulate(inner)
    return np.concatenate(([-np.inf], inner, [np.inf]))
