"""Straggler detection — the paper's windowed change detector, repurposed.

TSA1 cuts a trajectory when the mean of two adjacent sliding windows over its
voting signal diverges; a straggling host is the same signal shape: its
step-time series departs from the fleet's.  ``StragglerMonitor`` keeps a
per-host ring buffer of step durations and flags hosts whose recent window
mean exceeds the fleet median by ``threshold`` sigmas (or ratio).

Hooks: ``on_straggler`` receives (host_id, ratio); production deployments
wire this to the elastic controller (checkpoint-evict-restart, or re-split
the equi-depth partitions the way the paper rebalances time bins).
"""
from __future__ import annotations

import collections
from typing import Callable, Optional

import numpy as np


class StragglerMonitor:
    def __init__(self, n_hosts: int, window: int = 16,
                 ratio_threshold: float = 1.5,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.n_hosts = n_hosts
        self.window = window
        self.ratio_threshold = ratio_threshold
        self.on_straggler = on_straggler
        self.history = [collections.deque(maxlen=2 * window)
                        for _ in range(n_hosts)]
        self.flagged: dict[int, float] = {}

    def record(self, host: int, step_seconds: float):
        self.history[host].append(step_seconds)

    def record_all(self, step_seconds):
        for h, s in enumerate(step_seconds):
            self.record(h, float(s))

    def check(self) -> dict[int, float]:
        """Returns {host: ratio} for currently-flagged stragglers."""
        means = []
        for h in range(self.n_hosts):
            buf = list(self.history[h])[-self.window:]
            means.append(np.mean(buf) if buf else np.nan)
        means = np.asarray(means)
        fleet = np.nanmedian(means)
        self.flagged = {}
        if not np.isfinite(fleet) or fleet <= 0:
            return self.flagged
        for h in range(self.n_hosts):
            if np.isfinite(means[h]):
                ratio = float(means[h] / fleet)
                if ratio >= self.ratio_threshold:
                    self.flagged[h] = ratio
                    if self.on_straggler:
                        self.on_straggler(h, ratio)
        return self.flagged

    def change_detected(self, host: int, tau: float = 0.5) -> bool:
        """TSA1-style: |mean(W1) - mean(W2)| / mean(W1) > tau on the host's
        own series — catches a host that *becomes* slow (vs. always-slow)."""
        buf = list(self.history[host])
        if len(buf) < 2 * self.window:
            return False
        w1 = np.mean(buf[-2 * self.window:-self.window])
        w2 = np.mean(buf[-self.window:])
        return abs(w2 - w1) / max(w1, 1e-9) > tau
