"""Pipeline parallelism over the 'pod' axis (GPipe schedule, differentiable).

Design: on a multi-pod mesh the 'pod' axis crosses DCN, where bandwidth is
an order of magnitude below ICI — the natural mapping is *pipeline* stages
per pod (activations cross DCN once per microbatch, instead of gradient
all-reduces every step).  This module implements a GPipe forward schedule
with ``lax.ppermute`` between stages inside ``shard_map``; JAX reverse-mode
differentiates through the ppermutes (the backward schedule is the reversed
pipeline), so the same code trains.

The schedule runs ``n_micro + n_stages - 1`` ticks; each tick every stage
processes one microbatch slot (bubble slots compute on zeros — the classic
GPipe bubble, fraction (S-1)/(M+S-1)).

Usage (see tests/test_pipeline.py):

    fn = pipeline_apply(stage_fn, mesh, stage_axis="pod", n_micro=4)
    y = fn(stage_params, x)     # stage_params sharded over 'pod' on axis 0
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map as shard_map_compat


def pipeline_apply(stage_fn: Callable, mesh, *, stage_axis: str = "pod",
                   n_micro: int, data_axes: tuple = ("data",)):
    """Build a pipelined forward over ``stage_axis``.

    ``stage_fn(params_stage, x_micro) -> y_micro`` is one stage's compute
    (e.g. a block of layers).  ``x`` is [B, ...] with B divisible by
    n_micro; stage 0 feeds microbatches in, stage S-1 collects outputs.
    Returns a function (stage_params, x) -> y where ``stage_params`` leaves
    have a leading stage dimension.
    """
    S = mesh.shape[stage_axis]

    def body(params_st, x):
        # params_st leaves arrive as [1, ...] (this stage's shard) — strip
        # the stage dim; x: full local batch on every stage (only stage 0's
        # copy is fed in).
        params_st = jax.tree.map(lambda a: a[0], params_st)
        sid = lax.axis_index(stage_axis)
        B = x.shape[0]
        assert B % n_micro == 0
        mb = B // n_micro
        micro = x.reshape(n_micro, mb, *x.shape[1:])

        n_ticks = n_micro + S - 1
        fwd_perm = [(i, i + 1) for i in range(S - 1)]

        def tick(carry, t):
            buf, out = carry          # buf: [mb, ...] stage input register
            # stage 0 loads microbatch t (if in range)
            feed = jnp.where(t < n_micro,
                             micro[jnp.clip(t, 0, n_micro - 1)],
                             jnp.zeros_like(buf))
            cur = jnp.where(sid == 0, feed, buf)
            y = stage_fn(params_st, cur)
            # last stage stores its result at slot t - (S - 1)
            slot = t - (S - 1)
            store = (sid == S - 1) & (slot >= 0)
            out = jax.lax.cond(
                store,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None], (jnp.maximum(slot, 0),) + (0,) * y.ndim),
                lambda o: o, out)
            nxt = lax.ppermute(y, stage_axis, fwd_perm)
            return (nxt, out), None

        buf0 = jnp.zeros_like(micro[0])
        out0 = jnp.zeros((n_micro,) + micro.shape[1:], x.dtype)
        (_, out), _ = lax.scan(tick, (buf0, out0),
                               jnp.arange(n_ticks))
        # broadcast final outputs from the last stage to all stages so the
        # result is replicated over the pipeline axis (masked psum)
        out = lax.psum(
            jnp.where(sid == S - 1, out, jnp.zeros_like(out)), stage_axis)
        return out.reshape(B, *x.shape[1:])

    dspec = data_axes if len(data_axes) != 1 else data_axes[0]
    in_specs = (P(stage_axis), P(dspec))
    out_specs = P(dspec)

    def wrapped(stage_params, x):
        fn = shard_map_compat(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: in_specs[0], stage_params),
                      in_specs[1]),
            out_specs=out_specs)
        return fn(stage_params, x)

    return wrapped


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
