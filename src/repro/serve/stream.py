"""StreamService: the process-level loop around :class:`StreamDriver`.

The driver owns the math (delta joins, standing lists, warm-started
clustering); the service owns the *process* concerns:

* resume-on-start: restore the newest valid snapshot and fast-forward
  the submission source to the driver's replay cursor, so a killed
  service relaunched over the same batch sequence lands bit-identically
  on the uninterrupted run's state (the kill-and-resume parity suite
  asserts exactly this);
* pacing: one window advance every ``pump_every`` submissions, with the
  fault injector's ``stall_batch`` able to suppress advances (queue
  pressure scenarios);
* a final drain + snapshot on shutdown, so nothing stays staged.

Submission batches are identified by their absolute index in the source
sequence — the same key the fault plan uses — which is what makes replay
after resume deterministic: batch ``i`` gets the same scripted dirt on
every run that processes it.
"""
from __future__ import annotations

from typing import Iterable, Optional

from repro.stream.driver import StreamConfig, StreamDriver
from repro.stream.ingest import Records


class StreamService:
    """Long-running ingest-advance-query loop over one record source."""

    def __init__(self, config: StreamConfig, *, checkpoint_dir=None,
                 telemetry=None, injector=None, keep_n: int = 3):
        self.driver = StreamDriver(
            config, checkpoint_dir=checkpoint_dir, telemetry=telemetry,
            injector=injector, keep_n=keep_n)
        self.injector = injector
        self.resumed = self.driver.maybe_resume()

    def run(self, batches: Iterable[Records], *,
            pump_every: int = 1, max_batches: Optional[int] = None) -> dict:
        """Feed the batch sequence through submit/advance.

        Batches whose absolute index is below the driver's replay cursor
        were already folded into the restored snapshot and are skipped —
        the resume fast-forward.  Returns the final ``stats()``.
        """
        for i, recs in enumerate(batches):
            if max_batches is not None and i >= max_batches:
                break
            if i < self.driver.cursor:
                continue                      # already in the snapshot
            idx = self.driver.submit(recs)
            stalled = (self.injector is not None
                       and self.injector.stall_batch(idx))
            if not stalled and (idx + 1) % pump_every == 0:
                self.driver.advance()
        if self.driver.window.queued() > 0:
            self.driver.advance()             # final drain
        if self.driver.manager is not None:
            self.driver.snapshot()
        return self.driver.stats()

    # thin passthroughs — the query surface of the service
    def query(self, obj: int) -> dict:
        return self.driver.query(obj)

    def stats(self) -> dict:
        return self.driver.stats()

    def accounting(self) -> dict:
        return self.driver.accounting()
