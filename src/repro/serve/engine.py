"""Serving: prefill / decode steps + a wave-based batched-request engine.

``prefill_step`` and ``decode_step`` are the functions the dry-run lowers for
the prefill_32k / decode_32k / long_500k cells (cache donated, so the
compiled memory picture is steady-state serving).

``ServeEngine`` batches requests into *waves*: up to ``n_slots`` queued
requests are admitted together (prompts right-padded to the wave maximum),
prefilled in one call, then decoded in lockstep with per-request stop
bookkeeping; the next wave starts when the wave drains.  Wave formation
sorts the queue by prompt length — the paper's equi-depth balancing idea
applied to request scheduling (padding waste is minimized the same way the
temporal histogram equalizes partition sizes).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.models.config import ModelConfig


@functools.partial(jax.jit, static_argnames=("cfg", "mesh", "dp_axes"),
                   donate_argnums=(2,))
def prefill_step(params, tokens, cache, cfg: ModelConfig, *,
                 frontend_inputs=None, mesh=None, dp_axes: tuple = (),
                 last_positions=None):
    """Fill the cache with full prompts; returns (last_logits, cache).
    ``last_positions`` ([B] int32): per-request true last index (right-padded
    prompts); defaults to the final position for all."""
    logits, _, cache = tf.forward(
        params, tokens, cfg, cache=cache, cache_index=jnp.int32(0),
        frontend_inputs=frontend_inputs, mesh=mesh, dp_axes=dp_axes)
    if last_positions is None:
        return logits[:, -1], cache
    out = logits[jnp.arange(logits.shape[0]), last_positions]
    return out, cache


@functools.partial(jax.jit, static_argnames=("cfg", "mesh", "dp_axes"),
                   donate_argnums=(2,))
def decode_step(params, tokens, cache, index, cfg: ModelConfig, *,
                mesh=None, dp_axes: tuple = ()):
    """One token for every sequence in the batch; returns (logits, cache)."""
    logits, _, cache = tf.forward(
        params, tokens, cfg, cache=cache, cache_index=index,
        mesh=mesh, dp_axes=dp_axes)
    return logits[:, -1], cache


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [L] int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Wave-based batched serving (host loop around the jitted steps)."""

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int,
                 max_len: int, temperature: float = 0.0, seed: int = 0,
                 eos_id: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self.rng = np.random.default_rng(seed)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.decode_steps = 0
        self.prefill_calls = 0
        self.padding_waste = 0.0

    def submit(self, req: Request):
        self.queue.append(req)

    def _sample(self, logits):
        if self.temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / self.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _run_wave(self, wave: list[Request]):
        B = self.n_slots
        Lmax = max(len(r.prompt) for r in wave)
        tokens = np.zeros((B, Lmax), np.int32)
        lens = np.zeros(B, np.int64)
        for i, r in enumerate(wave):
            tokens[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        self.padding_waste += float(1.0 - lens[:len(wave)].sum()
                                    / (len(wave) * Lmax))
        cache = tf.init_cache(self.cfg, B, self.max_len)
        logits, cache = prefill_step(
            self.params, jnp.asarray(tokens), cache, self.cfg,
            last_positions=jnp.asarray(np.maximum(lens - 1, 0), jnp.int32))
        self.prefill_calls += 1
        logits = np.asarray(logits)
        cur = np.zeros(B, np.int32)
        for i, r in enumerate(wave):
            cur[i] = self._sample(logits[i])
            r.out.append(int(cur[i]))
        pos = int(Lmax)
        alive = {i for i, r in enumerate(wave) if r.max_new > 1}
        while alive and pos < self.max_len - 1:
            logits, cache = decode_step(
                self.params, jnp.asarray(cur[:, None]), cache,
                jnp.int32(pos), self.cfg)
            self.decode_steps += 1
            logits = np.asarray(logits)
            for i in list(alive):
                r = wave[i]
                tok = self._sample(logits[i])
                r.out.append(tok)
                cur[i] = tok
                if (len(r.out) >= r.max_new
                        or (self.eos_id is not None and tok == self.eos_id)):
                    alive.discard(i)
            pos += 1
        for r in wave:
            r.done = True
            self.completed.append(r)

    def run(self):
        """Drain the queue wave by wave."""
        self.queue.sort(key=lambda r: len(r.prompt))
        while self.queue:
            wave = [self.queue.pop(0)
                    for _ in range(min(self.n_slots, len(self.queue)))]
            self._run_wave(wave)
        return self.completed
