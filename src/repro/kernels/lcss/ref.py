"""Pure-jnp oracle: weighted + classical LCSS via row-scan DP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def lcss_ref(rx, ry, rt, rv, sx, sy, st, sv, eps_sp, eps_t):
    """Batched DP, [B, N] x [B, M] -> scores [B, 2] (weighted, count)."""
    dx = rx[:, :, None] - sx[:, None, :]
    dy = ry[:, :, None] - sy[:, None, :]
    dt = jnp.abs(rt[:, :, None] - st[:, None, :])
    d = jnp.sqrt(dx * dx + dy * dy)
    ok = (d <= eps_sp) & (dt <= eps_t) & rv[:, :, None] & sv[:, None, :]
    w = jnp.where(ok, 1.0 - d / eps_sp, NEG)
    u = jnp.where(ok, 1.0, NEG)
    wu = jnp.stack([w, u], axis=1)                      # [B, 2, N, M]

    B, ch, N, M = wu.shape

    def row_step(prev_row, w_row):
        # prev_row: [B, 2, M] = L[i-1, :]; w_row: [B, 2, M] = w[i, :]
        diag = jnp.concatenate(
            [jnp.zeros((B, ch, 1)), prev_row[..., :-1]], axis=-1)
        cand = diag + w_row                             # match option

        def col_scan(carry, xs):
            up, c = xs                                  # [B, 2] each
            cur = jnp.maximum(jnp.maximum(up, carry), c)
            cur = jnp.maximum(cur, 0.0)
            return cur, cur

        xs = (jnp.moveaxis(prev_row, -1, 0), jnp.moveaxis(cand, -1, 0))
        _, cols = jax.lax.scan(col_scan, jnp.zeros((B, ch)), xs)
        return jnp.moveaxis(cols, 0, -1), None

    rows = jnp.moveaxis(wu, 2, 0)                       # [N, B, 2, M]
    last_row, _ = jax.lax.scan(
        lambda c, r: row_step(c, r), jnp.zeros((B, ch, M)), rows)
    return last_row[..., -1]                            # [B, 2]


def lcss_similarity_ref(rx, ry, rt, rv, sx, sy, st, sv, eps_sp, eps_t):
    """Eq. 1 / Eq. 2 similarities in [0, 1]: returns [B, 2]."""
    scores = lcss_ref(rx, ry, rt, rv, sx, sy, st, sv, eps_sp, eps_t)
    n = jnp.sum(rv, axis=1)
    m = jnp.sum(sv, axis=1)
    denom = jnp.maximum(jnp.minimum(n, m), 1).astype(jnp.float32)
    return scores / denom[:, None]
