"""Pallas TPU kernel: weighted LCSS dynamic program (Eq. 2), wavefront form.

The recurrence (max-weight common subsequence under the (eps_sp, eps_t)
matching predicate; DESIGN.md §2.2):

    L[i, j] = max(L[i-1, j], L[i, j-1], L[i-1, j-1] + w[i, j])

with ``w[i, j] = 1 - d_sp/eps_sp`` for matching pairs and -inf otherwise.
A second channel runs the same recurrence with unit weights — the *classical*
LCSS length of Eq. 1.

TPU adaptation: the DP has a strict diagonal dependency, useless for the MXU
but perfectly vectorizable along anti-diagonals on the VPU.  The host wrapper
*shears* the weight matrix (row i shifted right by i) so that every
anti-diagonal ``d = i + j`` becomes a contiguous column of the sheared tensor
``Ws[i, d]`` — turning the wavefront into ``N+M-1`` vectorized column steps
with two carried diagonal vectors, no strided VMEM access.

Block layout: one (pair) program instance owns ``Ws[2, N, D]`` in VMEM
(N=M=128 -> 2*128*256*4B = 256 KiB) plus three [2, N] carries; the grid is
the batch of pairs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(ws_ref, out_ref):
    ws = ws_ref[...]                      # [1, 2, N, D]
    _, ch, N, D = ws.shape
    ws = ws.reshape(ch, N, D)

    def shift_down(v):                    # index i reads previous i-1
        return jnp.concatenate(
            [jnp.zeros((ch, 1), v.dtype), v[:, :-1]], axis=1)

    def body(d, carry):
        d1, d2 = carry                    # diagonals d-1, d-2; [2, N]
        w_col = jax.lax.dynamic_slice(ws, (0, 0, d), (ch, N, 1))[..., 0]
        cand = shift_down(d2) + w_col     # match at (i, d-i)
        d0 = jnp.maximum(jnp.maximum(d1, shift_down(d1)), cand)
        d0 = jnp.maximum(d0, 0.0)         # L >= 0 everywhere
        return d0, d1

    zero = jnp.zeros((ch, N), jnp.float32)
    dlast, _ = jax.lax.fori_loop(0, D, body, (zero, zero))
    out_ref[...] = dlast[:, -1][None, :]  # L at (N-1, M-1), both channels


@functools.partial(jax.jit, static_argnames=("interpret",))
def lcss_pallas(ws: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """``ws``: [B, 2, N, D] sheared weights (channel 0 weighted, 1 unit).
    Returns scores [B, 2]."""
    B, ch, N, D = ws.shape
    assert ch == 2
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, 2, N, D), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 2), jnp.float32),
        interpret=interpret,
    )(ws)


def shear_weights(rx, ry, rt, rv, sx, sy, st, sv, eps_sp, eps_t):
    """Host-side (jnp) preparation: match weights, sheared to [B, 2, N, D].

    Inputs are [B, N] / [B, M] point coordinates + validity.
    """
    B, N = rx.shape
    M = sx.shape[1]
    dx = rx[:, :, None] - sx[:, None, :]
    dy = ry[:, :, None] - sy[:, None, :]
    dt = jnp.abs(rt[:, :, None] - st[:, None, :])
    d = jnp.sqrt(dx * dx + dy * dy)
    ok = (d <= eps_sp) & (dt <= eps_t) & rv[:, :, None] & sv[:, None, :]
    w = jnp.where(ok, 1.0 - d / eps_sp, NEG)              # [B, N, M]
    u = jnp.where(ok, 1.0, NEG)

    D = N + M - 1
    # shear: Ws[b, i, i + j] = w[b, i, j]
    cols = jnp.arange(N)[:, None] + jnp.arange(M)[None, :]   # [N, M]
    ws = jnp.full((B, 2, N, D), NEG, jnp.float32)
    bi = jnp.arange(B)[:, None, None]
    ii = jnp.broadcast_to(jnp.arange(N)[None, :, None], (B, N, M))
    cc = jnp.broadcast_to(cols[None], (B, N, M))
    ws = ws.at[bi, 0, ii, cc].set(w)
    ws = ws.at[bi, 1, ii, cc].set(u)
    return ws
