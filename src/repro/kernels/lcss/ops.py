"""Public wrapper: exact pairwise (weighted) LCSS similarity via Pallas."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.lcss.lcss import lcss_pallas, shear_weights


@functools.partial(jax.jit, static_argnames=("interpret",))
def lcss_scores(rx, ry, rt, rv, sx, sy, st, sv, eps_sp, eps_t,
                *, interpret: bool | None = None) -> jnp.ndarray:
    """[B, 2] raw DP scores (weighted Eq. 2 numerator, classical count).

    One batched sheared-wavefront DP per (reference, candidate) pair:
    ``shear_weights`` precomputes the [B, N, M] match/weight planes, the
    Pallas kernel sweeps the anti-diagonals.  Scores are clamped at zero
    (an all-invalid pair yields an empty DP, not a negative score).
    """
    if interpret is None:
        interpret = default_interpret()
    ws = shear_weights(rx, ry, rt, rv, sx, sy, st, sv, eps_sp, eps_t)
    scores = lcss_pallas(ws, interpret=interpret)
    return jnp.maximum(scores, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lcss_similarity(rx, ry, rt, rv, sx, sy, st, sv, eps_sp, eps_t,
                    *, interpret: bool | None = None) -> jnp.ndarray:
    """Eq. 1 (channel 1) and Eq. 2 (channel 0) similarities, [B, 2].

    The raw DP scores normalized by ``min(|r|, |s|)`` valid points — the
    paper's LCSS similarity in both its classical (count) and
    voting-weighted forms.  Used by the evaluation harness as the
    continuous-curve similarity reference.
    """
    scores = lcss_scores(rx, ry, rt, rv, sx, sy, st, sv, eps_sp, eps_t,
                         interpret=interpret)
    n = jnp.sum(rv, axis=1)
    m = jnp.sum(sv, axis=1)
    denom = jnp.maximum(jnp.minimum(n, m), 1).astype(jnp.float32)
    return scores / denom[:, None]
