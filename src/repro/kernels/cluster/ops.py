"""Public wrappers for the round-parallel clustering kernels.

``plan_tiles`` resolves the tile geometry; the wrappers own the padding —
callers pass natural ``[S]`` / ``[S, S]`` operands and get ``[S]`` results
back, so the padding invariants (padded slots carry False state, zero
similarity, and fresh distinct ranks, and therefore join no reduction)
live in exactly one place.  On CPU the kernels run in interpret mode
(``repro.kernels.default_interpret``); the jnp oracle in ``ref.py`` is
the semantics they are tested against.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.kernels.cluster.cluster import assign_pallas, round_scan_pallas


def plan_tiles(S: int, target_bu: int = 8, target_bs: int = 128):
    """(bu, bs, S_padded): row/column tile sizes and the padded slot count.

    Mirrors the stjoin convention (f32 (8, 128) register tiles); ``S`` is
    padded up to a common multiple so both tilings divide it.  The targets
    are taken verbatim (padding absorbs any S), so ``(target_bu,
    target_bs)`` IS the resolved geometry — ``EnginePlan.cluster_tiles``
    threads the pair here unchanged, and the autotuner
    (``repro.tune.autotune.tune_cluster_tiles``) sweeps it against the
    jnp oracle: any tile pair is bit-identical by the padding invariant
    (padded slots join no reduction), so tiles only move the
    VMEM-residency/grid-overhead trade-off, never the labels.
    """
    bu, bs = target_bu, target_bs
    q = math.lcm(bu, bs)
    return bu, bs, -(-S // q) * q


def _padded(sim, rank, vecs, bu: int, bs: int):
    """Pad the matrix, ranks, and bool state vectors to the tile multiple.

    Padded slots get zero similarity rows/columns, all-False state, and
    distinct out-of-range ranks — they contribute to no reduction and are
    sliced off by the callers.
    """
    S = sim.shape[0]
    _, _, Sp = plan_tiles(S, bu, bs)
    if Sp == S:
        return sim, rank, vecs
    sim_p = jnp.pad(sim, ((0, Sp - S), (0, Sp - S)))
    rank_p = jnp.concatenate(
        [rank.astype(jnp.int32), jnp.arange(S, Sp, dtype=jnp.int32)])
    vecs_p = [jnp.pad(v, (0, Sp - S), constant_values=False) for v in vecs]
    return sim_p, rank_p, vecs_p


def cluster_round_scan(sim, rank, unresolved, is_rep, alpha, *,
                       bu: int = 8, bs: int = 128, interpret: bool = True):
    """(blocked [S], claimed [S]) — one fused round scan."""
    S = sim.shape[0]
    sim_p, rank_p, (unres_p, rep_p) = _padded(
        sim, rank, [unresolved, is_rep], bu, bs)
    blocked, claimed = round_scan_pallas(
        sim_p, rank_p, unres_p, rep_p, alpha, bu=bu, bs=bs,
        interpret=interpret)
    return blocked[:S], claimed[:S]


def cluster_assign(sim, rank, is_rep, valid, alpha, *,
                   bu: int = 8, bs: int = 128, interpret: bool = True):
    """(best_w [S], best_slot [S]) — final claim-max over rep rows."""
    S = sim.shape[0]
    sim_p, rank_p, (rep_p, valid_p) = _padded(
        sim, rank, [is_rep, valid], bu, bs)
    w, slot = assign_pallas(sim_p, rank_p, rep_p, valid_p, alpha,
                            bu=bu, bs=bs, interpret=interpret)
    return w[:S], slot[:S]


def _padded_topk(ids, sims, rank, vecs, bs: int):
    """Pad neighbor-list operands to the row-tile multiple.

    Padded slots carry empty lists (``ids == -1``, zero sims), all-False
    state, and fresh distinct ranks, so they join no reduction and are
    sliced off by the callers.
    """
    S = ids.shape[0]
    Sp = -(-S // bs) * bs
    if Sp == S:
        return ids, sims, rank, vecs
    pad = Sp - S
    ids_p = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    sims_p = jnp.pad(sims, ((0, pad), (0, 0)))
    rank_p = jnp.concatenate(
        [rank.astype(jnp.int32), jnp.arange(S, Sp, dtype=jnp.int32)])
    vecs_p = [jnp.pad(v, (0, pad), constant_values=False) for v in vecs]
    return ids_p, sims_p, rank_p, vecs_p


def topk_cluster_round_scan(ids, sims, rank, unresolved, is_rep, alpha, *,
                            bs: int = 8, interpret: bool = True):
    """(blocked [S], claimed [S]) — one round scan over [S, K] lists."""
    from repro.kernels.cluster.cluster import topk_round_scan_pallas
    S = ids.shape[0]
    ids_p, sims_p, rank_p, (unres_p, rep_p) = _padded_topk(
        ids, sims, rank, [unresolved, is_rep], bs)
    blocked, claimed = topk_round_scan_pallas(
        ids_p, sims_p, rank_p, unres_p, rep_p, alpha, bs=bs,
        interpret=interpret)
    return blocked[:S], claimed[:S]


def topk_cluster_assign(ids, sims, rank, is_rep, valid, alpha, *,
                        bs: int = 8, interpret: bool = True):
    """(best_w [S], best_slot [S]) — claim-max over [S, K] lists."""
    from repro.kernels.cluster.cluster import topk_assign_pallas
    S = ids.shape[0]
    ids_p, sims_p, rank_p, (rep_p, valid_p) = _padded_topk(
        ids, sims, rank, [is_rep, valid], bs)
    w, slot = topk_assign_pallas(ids_p, sims_p, rank_p, rep_p, valid_p,
                                 alpha, bs=bs, interpret=interpret)
    return w[:S], slot[:S]
