"""Round-parallel greedy clustering kernels (Algorithm 4, Problem 3).

``cluster.py`` — fused Pallas tile kernels over the dense ``[S, S]``
similarity matrix: the per-round eligibility scan (blocked/claimed) and the
final claim-max membership reduction.
``ops.py``     — jit'd wrappers with the tile-geometry planning / padding.
``ref.py``     — the pure-jnp oracle used by the core engine and the tests.
"""
