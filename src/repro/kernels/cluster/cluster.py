"""Pallas tile kernels for the round-parallel greedy clustering engine.

Contract (see ``repro.core.clustering.cluster_rounds`` and ``ref.py``)
---------------------------------------------------------------------
The round engine iterates two ``[S, S]`` reductions over the dense
similarity matrix:

* ``_round_scan_kernel``  per round: for every column (slot) ``s``, OR-
  reduce over rows ``u`` the alpha-edge predicate
  ``sim[u, s] > 0 and sim[u, s] >= alpha and rank[u] < rank[s]`` masked by
  the round state — ``unresolved[u]`` yields ``blocked[s]`` (s must wait),
  ``is_rep[u]`` yields ``claimed[s]`` (s resolves as non-rep now).  One
  sweep fuses the eligibility scan, the threshold test and both masks.
* ``_assign_kernel``      once, after the representative set converges:
  per column, the running (max weight, min visit rank, slot) accumulator
  over representative rows — the claim-max that replaces Algorithm 4's
  sequential reassignment updates.

Tiling
------
grid = (S/bs, S/bu); column block ``j`` (axis 0) owns the output block and
is revisited across the row-block axis ``i`` (axis 1, fastest) with the
accumulator resident in VMEM — the same "contraction last axis" layout as
``kernels/stjoin/stjoin.py``.  Per-tile working set at the (8, 128)
default is a single f32 VPU register tile plus [bs] accumulators, so VMEM
holds the entire round state; the only HBM traffic per round is one read
of the ``[S, S]`` matrix and O(S) state vectors — compare the sequential
oracle, which makes S dependent row reads that no pipeline can overlap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG_RANK = 2**31 - 1          # python int: kernels may not capture arrays


def _round_scan_kernel(sim, rank_r, rank_c, unresolved, is_rep, thr,
                       out_blocked, out_claimed):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_blocked[...] = jnp.zeros_like(out_blocked)
        out_claimed[...] = jnp.zeros_like(out_claimed)

    alpha = thr[0]
    s = sim[...]                                   # [bu, bs]
    pred = ((s > 0.0) & (s >= alpha)
            & (rank_r[...][:, None] < rank_c[...][None, :]))
    out_blocked[...] |= jnp.any(pred & unresolved[...][:, None], axis=0)
    out_claimed[...] |= jnp.any(pred & is_rep[...][:, None], axis=0)


def _assign_kernel(sim, rank_r, is_rep, valid_c, thr,
                   out_w, out_rank, out_slot, *, bu: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_w[...] = jnp.zeros_like(out_w)
        out_rank[...] = jnp.full_like(out_rank, _BIG_RANK)
        out_slot[...] = jnp.full_like(out_slot, -1)

    alpha = thr[0]
    s = sim[...]                                   # [bu, bs]
    claim = (is_rep[...][:, None] & valid_c[...][None, :]
             & (s > 0.0) & (s >= alpha))
    w = jnp.where(claim, s, 0.0)
    loc_w = jnp.max(w, axis=0)                     # [bs]
    cand = claim & (w == loc_w[None, :]) & (loc_w[None, :] > 0.0)
    r = jnp.where(cand, rank_r[...][:, None], _BIG_RANK)
    loc_rank = jnp.min(r, axis=0)
    loc_slot = i * bu + jnp.argmin(r, axis=0).astype(jnp.int32)

    acc_w = out_w[...]
    acc_rank = out_rank[...]
    # lexicographic (weight desc, visit rank asc) — ties across row blocks
    # resolve exactly like the full-matrix argmin in ref.claim_max_ref
    better = (loc_w > acc_w) | ((loc_w == acc_w) & (loc_rank < acc_rank))
    out_w[...] = jnp.where(better, loc_w, acc_w)
    out_rank[...] = jnp.where(better, loc_rank, acc_rank)
    out_slot[...] = jnp.where(better, loc_slot, out_slot[...])


def _specs(bu: int, bs: int):
    sim_spec = pl.BlockSpec((bu, bs), lambda j, i: (i, j))
    row_spec = pl.BlockSpec((bu,), lambda j, i: (i,))
    col_spec = pl.BlockSpec((bs,), lambda j, i: (j,))
    thr_spec = pl.BlockSpec((1,), lambda j, i: (0,))
    out_spec = pl.BlockSpec((bs,), lambda j, i: (j,))
    return sim_spec, row_spec, col_spec, thr_spec, out_spec


@functools.partial(jax.jit, static_argnames=("bu", "bs", "interpret"))
def round_scan_pallas(sim, rank, unresolved, is_rep, alpha, *,
                      bu: int = 8, bs: int = 128, interpret: bool = True):
    """(blocked [S], claimed [S]) for one round; S divisible by bu and bs."""
    S = sim.shape[0]
    assert sim.shape == (S, S) and S % bu == 0 and S % bs == 0, \
        (sim.shape, bu, bs)
    thr = jnp.asarray(alpha, jnp.float32).reshape(1)
    sim_spec, row_spec, col_spec, thr_spec, out_spec = _specs(bu, bs)
    return pl.pallas_call(
        _round_scan_kernel,
        grid=(S // bs, S // bu),
        in_specs=[sim_spec, row_spec, col_spec, row_spec, row_spec,
                  thr_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((S,), jnp.bool_)] * 2,
        interpret=interpret,
    )(sim, rank.astype(jnp.int32), rank.astype(jnp.int32),
      unresolved.astype(jnp.bool_), is_rep.astype(jnp.bool_), thr)


@functools.partial(jax.jit, static_argnames=("bu", "bs", "interpret"))
def assign_pallas(sim, rank, is_rep, valid, alpha, *,
                  bu: int = 8, bs: int = 128, interpret: bool = True):
    """(best_w [S], best_slot [S]) claim-max over representative rows."""
    S = sim.shape[0]
    assert sim.shape == (S, S) and S % bu == 0 and S % bs == 0, \
        (sim.shape, bu, bs)
    thr = jnp.asarray(alpha, jnp.float32).reshape(1)
    sim_spec, row_spec, col_spec, thr_spec, out_spec = _specs(bu, bs)
    w, _, slot = pl.pallas_call(
        functools.partial(_assign_kernel, bu=bu),
        grid=(S // bs, S // bu),
        in_specs=[sim_spec, row_spec, row_spec, col_spec, thr_spec],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((S,), jnp.float32),
                   jax.ShapeDtypeStruct((S,), jnp.int32),
                   jax.ShapeDtypeStruct((S,), jnp.int32)],
        interpret=interpret,
    )(sim, rank.astype(jnp.int32), is_rep.astype(jnp.bool_),
      valid.astype(jnp.bool_), thr)
    return w, jnp.where(w > 0.0, slot, -1)


# ---------------------------------------------------------------------------
# Neighbor-list (top-K) kernels: the same two reductions on the sparse
# ``TopKSim`` rows.  The [S, S] matrix sweep becomes a [S, K] list sweep —
# one row-tile grid, no contraction axis (a slot's whole adjacency fits its
# K-entry list row), with the rank/state vectors resident per instance and
# read through an in-tile gather.  O(S*K) HBM traffic per round.
# ---------------------------------------------------------------------------


def _topk_round_scan_kernel(ids, sims, rank_rows, rank_full, unresolved,
                            is_rep, thr, out_blocked, out_claimed):
    alpha = thr[0]
    uid = ids[...]                                 # [bs, K]
    v = sims[...]
    rk = rank_full[...]                            # [Sp]
    S = rk.shape[0]
    safe = jnp.clip(uid, 0, S - 1)
    edge = (uid >= 0) & (v > 0.0) & (v >= alpha)
    pred = edge & (rk[safe] < rank_rows[...][:, None])
    out_blocked[...] = jnp.any(pred & unresolved[...][safe], axis=1)
    out_claimed[...] = jnp.any(pred & is_rep[...][safe], axis=1)


def _topk_assign_kernel(ids, sims, rank_full, is_rep, valid_rows, thr,
                        out_w, out_slot):
    alpha = thr[0]
    uid = ids[...]                                 # [bs, K]
    v = sims[...]
    rk = rank_full[...]
    S = rk.shape[0]
    bs = uid.shape[0]
    safe = jnp.clip(uid, 0, S - 1)
    claim = ((uid >= 0) & valid_rows[...][:, None] & (v > 0.0)
             & (v >= alpha) & is_rep[...][safe])
    w = jnp.where(claim, v, 0.0)
    best_w = jnp.max(w, axis=1)                    # [bs]
    cand = claim & (w == best_w[:, None]) & (best_w[:, None] > 0.0)
    r = jnp.where(cand, rk[safe], _BIG_RANK)
    e = jnp.argmin(r, axis=1).astype(jnp.int32)
    slot = safe[jnp.arange(bs), e]
    out_w[...] = best_w
    out_slot[...] = jnp.where(best_w > 0.0, slot, -1)


def _topk_specs(bs: int, K: int, Sp: int):
    list_spec = pl.BlockSpec((bs, K), lambda i: (i, 0))
    row_spec = pl.BlockSpec((bs,), lambda i: (i,))
    full_spec = pl.BlockSpec((Sp,), lambda i: (0,))
    thr_spec = pl.BlockSpec((1,), lambda i: (0,))
    return list_spec, row_spec, full_spec, thr_spec


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def topk_round_scan_pallas(ids, sims, rank, unresolved, is_rep, alpha, *,
                           bs: int = 8, interpret: bool = True):
    """(blocked [S], claimed [S]) for one round; S divisible by bs."""
    S, K = ids.shape
    assert S % bs == 0, (S, bs)
    thr = jnp.asarray(alpha, jnp.float32).reshape(1)
    list_spec, row_spec, full_spec, thr_spec = _topk_specs(bs, K, S)
    return pl.pallas_call(
        _topk_round_scan_kernel,
        grid=(S // bs,),
        in_specs=[list_spec, list_spec, row_spec, full_spec, full_spec,
                  full_spec, thr_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((S,), jnp.bool_)] * 2,
        interpret=interpret,
    )(ids.astype(jnp.int32), sims, rank.astype(jnp.int32),
      rank.astype(jnp.int32), unresolved.astype(jnp.bool_),
      is_rep.astype(jnp.bool_), thr)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def topk_assign_pallas(ids, sims, rank, is_rep, valid, alpha, *,
                       bs: int = 8, interpret: bool = True):
    """(best_w [S], best_slot [S]) claim-max over neighbor lists."""
    S, K = ids.shape
    assert S % bs == 0, (S, bs)
    thr = jnp.asarray(alpha, jnp.float32).reshape(1)
    list_spec, row_spec, full_spec, thr_spec = _topk_specs(bs, K, S)
    w, slot = pl.pallas_call(
        _topk_assign_kernel,
        grid=(S // bs,),
        in_specs=[list_spec, list_spec, full_spec, full_spec, row_spec,
                  thr_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((S,), jnp.float32),
                   jax.ShapeDtypeStruct((S,), jnp.int32)],
        interpret=interpret,
    )(ids.astype(jnp.int32), sims, rank.astype(jnp.int32),
      is_rep.astype(jnp.bool_), valid.astype(jnp.bool_), thr)
    return w, slot
