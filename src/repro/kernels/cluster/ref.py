"""jnp oracle for the round-parallel clustering primitives.

These two reductions are the entire per-iteration work of the
round-parallel engine (``repro.core.clustering.cluster_rounds``); the
Pallas kernels in ``cluster.py`` tile exactly this math over ``[S, S]``
blocks and must match it bit for bit (``tests/test_cluster_rounds.py``).
"""
from __future__ import annotations

import jax.numpy as jnp


def round_scan_ref(sim, rank, unresolved, is_rep, alpha):
    """One round's fused eligibility scan over the full matrix.

    ``blocked[s]``: an unresolved earlier-visited slot still has an
    alpha-edge to ``s`` (``sim[u, s] > 0`` and ``>= alpha`` with
    ``rank[u] < rank[s]``) — s's verdict could still change, it must wait.
    ``claimed[s]``: a resolved representative claims ``s`` — s resolves as
    a non-representative immediately, whatever its other predecessors do.

    Row masks (``unresolved``, ``is_rep``) are subsets of the
    potential-representative set (valid & voting >= k), so no separate
    validity test is needed; ``rank[u] < rank[s]`` excludes the diagonal
    because ``rank`` is a strict permutation.
    """
    pred = (sim > 0.0) & (sim >= alpha) & (rank[:, None] < rank[None, :])
    blocked = jnp.any(pred & unresolved[:, None], axis=0)
    claimed = jnp.any(pred & is_rep[:, None], axis=0)
    return blocked, claimed


def claim_max_ref(sim, order, rank, is_rep, valid, alpha):
    """Final membership claim-max: per column ``s``, the representative row
    of maximum similarity, earliest visit position (minimum rank) winning
    ties — the fixed point of Algorithm 4's strict ``row > member_sim``
    reassignment.

    The tie-break is a second min-reduction over the rank column vector
    (masked to the argmax set) followed by one [S] gather through
    ``order`` — row gathers / argmin over the [S, S] matrix are
    deliberately avoided (pathological on CPU backends).  Returns
    ``(best_w [S] f32, best_slot [S] i32)``; ``(0.0, -1)`` where no
    representative claims the column.
    """
    S = sim.shape[0]
    claim = (is_rep[:, None] & valid[None, :]
             & (sim > 0.0) & (sim >= alpha))
    w = jnp.where(claim, sim, 0.0)
    best_w = jnp.max(w, axis=0)
    cand = claim & (w == best_w[None, :])
    r = jnp.where(cand, rank[:, None], S)
    best_rank = jnp.min(r, axis=0)                 # min rank among maxima
    best_slot = order[jnp.clip(best_rank, 0, S - 1)]
    return best_w, jnp.where(best_w > 0.0, best_slot, -1)
