"""jnp oracle for the round-parallel clustering primitives.

These two reductions are the entire per-iteration work of the
round-parallel engine (``repro.core.clustering.cluster_rounds``); the
Pallas kernels in ``cluster.py`` tile exactly this math over ``[S, S]``
blocks and must match it bit for bit (``tests/test_cluster_rounds.py``).
"""
from __future__ import annotations

import jax.numpy as jnp


def round_scan_ref(sim, rank, unresolved, is_rep, alpha):
    """One round's fused eligibility scan over the full matrix.

    ``blocked[s]``: an unresolved earlier-visited slot still has an
    alpha-edge to ``s`` (``sim[u, s] > 0`` and ``>= alpha`` with
    ``rank[u] < rank[s]``) — s's verdict could still change, it must wait.
    ``claimed[s]``: a resolved representative claims ``s`` — s resolves as
    a non-representative immediately, whatever its other predecessors do.

    Row masks (``unresolved``, ``is_rep``) are subsets of the
    potential-representative set (valid & voting >= k), so no separate
    validity test is needed; ``rank[u] < rank[s]`` excludes the diagonal
    because ``rank`` is a strict permutation.
    """
    pred = (sim > 0.0) & (sim >= alpha) & (rank[:, None] < rank[None, :])
    blocked = jnp.any(pred & unresolved[:, None], axis=0)
    claimed = jnp.any(pred & is_rep[:, None], axis=0)
    return blocked, claimed


def claim_max_ref(sim, order, rank, is_rep, valid, alpha):
    """Final membership claim-max: per column ``s``, the representative row
    of maximum similarity, earliest visit position (minimum rank) winning
    ties — the fixed point of Algorithm 4's strict ``row > member_sim``
    reassignment.

    The tie-break is a second min-reduction over the rank column vector
    (masked to the argmax set) followed by one [S] gather through
    ``order`` — row gathers / argmin over the [S, S] matrix are
    deliberately avoided (pathological on CPU backends).  Returns
    ``(best_w [S] f32, best_slot [S] i32)``; ``(0.0, -1)`` where no
    representative claims the column.
    """
    S = sim.shape[0]
    claim = (is_rep[:, None] & valid[None, :]
             & (sim > 0.0) & (sim >= alpha))
    w = jnp.where(claim, sim, 0.0)
    best_w = jnp.max(w, axis=0)
    cand = claim & (w == best_w[None, :])
    r = jnp.where(cand, rank[:, None], S)
    best_rank = jnp.min(r, axis=0)                 # min rank among maxima
    best_slot = order[jnp.clip(best_rank, 0, S - 1)]
    return best_w, jnp.where(best_w > 0.0, best_slot, -1)


# ---------------------------------------------------------------------------
# Neighbor-list (top-K) variants: the same two reductions on the sparse
# ``TopKSim`` representation.  Edges live on rows — row ``s`` holds ``s``'s
# alpha-adjacency (the matrix is max-symmetrized, so sim[u, s] == sim[s, u]
# and either endpoint's list carries the edge).  O(S * K) per call instead
# of O(S^2); exact whenever the per-row spill certificate holds
# (``TopKSim`` docstring).
# ---------------------------------------------------------------------------


def topk_round_scan_ref(ids, sims, rank, unresolved, is_rep, alpha):
    """One round's eligibility scan over ``[S, K]`` neighbor lists.

    For column slot ``s`` (a list row), an entry ``u = ids[s, e]`` is a
    predecessor when the edge is an alpha-edge and ``rank[u] < rank[s]``
    — exactly ``round_scan_ref``'s predicate read from ``s``'s side of
    the symmetric matrix.
    """
    S = rank.shape[0]
    safe = jnp.clip(ids, 0, S - 1)
    edge = (ids >= 0) & (sims > 0.0) & (sims >= alpha)
    pred = edge & (rank[safe] < rank[:, None])
    blocked = jnp.any(pred & unresolved[safe], axis=1)
    claimed = jnp.any(pred & is_rep[safe], axis=1)
    return blocked, claimed


def topk_claim_max_ref(ids, sims, rank, is_rep, valid, alpha):
    """Final membership claim-max over ``[S, K]`` neighbor lists.

    Per slot ``s``: the representative neighbor of maximum similarity,
    minimum visit rank among ties — ``claim_max_ref`` restricted to the
    retained edges.  Returns ``(best_w [S], best_slot [S])`` with
    ``(0.0, -1)`` where no representative claims the slot.
    """
    S = rank.shape[0]
    safe = jnp.clip(ids, 0, S - 1)
    claim = ((ids >= 0) & valid[:, None] & (sims > 0.0) & (sims >= alpha)
             & is_rep[safe])
    w = jnp.where(claim, sims, 0.0)
    best_w = jnp.max(w, axis=1)
    cand = claim & (w == best_w[:, None]) & (best_w[:, None] > 0.0)
    r = jnp.where(cand, rank[safe], S)
    e = jnp.argmin(r, axis=1)
    best_slot = jnp.take_along_axis(safe, e[:, None], axis=1)[:, 0]
    return best_w, jnp.where(best_w > 0.0, best_slot, -1)
