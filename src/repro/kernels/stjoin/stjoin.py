"""Pallas TPU kernel: best-match spatiotemporal join (DTJ's Join step).

Contract
--------
Given ``P`` reference points (flattened, with per-point trajectory ids) and
``C`` candidate trajectories of up to ``Mc`` points each, compute for every
(ref point p, candidate trajectory c):

    best_w[p, c]   = max over candidate points m of
                     (1 - d_sp(p, (c,m)) / eps_sp)
                     subject to d_sp <= eps_sp, |dt| <= eps_t,
                     validity, and traj_id[p] != cand_id[c]
    best_idx[p, c] = argmax m (or -1)

Tiling
------
grid = (P/bp, C/bc, Mc/bm); the (i, j) output tile [bp, bc] is revisited
across the k (candidate-point) grid axis and accumulated with a running
max/argmax in VMEM — the classic "contraction last axis" Pallas pattern.

Per-tile working set (defaults bp=256, bc=8, bm=128):
    ref slabs        4 * bp * 4B               =   4 KiB
    cand slabs       4 * bc * bm * 4B          =  16 KiB
    pairwise temps   ~4 * bp * bc * bm * 4B    =   4 MiB
    accumulators     2 * bp * bc * 4B          =  16 KiB
well under the ~16 MiB v5e VMEM budget; bp/bm are multiples of the f32
(8, 128) tile so the VPU operates on full registers.

Distance is computed with a broadcast subtract on the VPU: the contraction
depth is 2 (x, y), far too shallow for the MXU to pay off — this kernel is
HBM-bandwidth- and VPU-bound by design, which is exactly why minimizing
bytes (best-match streaming instead of materializing [P, C, Mc]) matters.
A tile whose time range is provably farther than eps_t from the ref tile's
range contributes nothing; time-sorted inputs make those tiles cheap
(mask-all-zero), and the grid dimension ordering keeps the accumulator hot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ref_x, ref_y, ref_t, ref_id, ref_ok,
            cand_x, cand_y, cand_t, cand_id, cand_ok,
            eps, out_w, out_idx):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_w[...] = jnp.zeros_like(out_w)
        out_idx[...] = jnp.full_like(out_idx, -1)

    eps_sp = eps[0]
    eps_t = eps[1]

    rx = ref_x[...]                       # [bp]
    ry = ref_y[...]
    rt = ref_t[...]
    rid = ref_id[...]
    rok = ref_ok[...]

    cx = cand_x[...]                      # [bc, bm]
    cy = cand_y[...]
    ct = cand_t[...]
    cid = cand_id[...]                    # [bc]
    cok = cand_ok[...]

    bp = rx.shape[0]
    bc, bm = cx.shape

    dx = rx[:, None, None] - cx[None, :, :]          # [bp, bc, bm]
    dy = ry[:, None, None] - cy[None, :, :]
    dt = jnp.abs(rt[:, None, None] - ct[None, :, :])
    d2 = dx * dx + dy * dy

    ok = (d2 <= eps_sp * eps_sp) & (dt <= eps_t)
    ok &= rok[:, None, None] & cok[None, :, :]
    ok &= rid[:, None, None] != cid[None, :, None]

    w = jnp.where(ok, 1.0 - jnp.sqrt(d2) / eps_sp, -1.0)  # [bp, bc, bm]

    tile_w = jnp.max(w, axis=-1)                          # [bp, bc]
    tile_arg = jnp.argmax(w, axis=-1).astype(jnp.int32)   # [bp, bc]
    tile_idx = jnp.where(tile_w > 0.0, tile_arg + k * bm, -1)
    tile_w = jnp.maximum(tile_w, 0.0)

    run_w = out_w[...]
    run_idx = out_idx[...]
    better = tile_w > run_w
    out_w[...] = jnp.where(better, tile_w, run_w)
    out_idx[...] = jnp.where(better, tile_idx, run_idx)


def _pruned_kernel(ref_x, ref_y, ref_t, ref_id, ref_ok,
                   cand_x, cand_y, cand_t, cand_id, cand_ok,
                   eps, out_w, out_idx):
    """Same contraction as ``_kernel`` but over gathered candidate tiles.

    Grid is (ref block i, surviving-tile slot s, cand-point chunk k); the
    candidate operands were pre-gathered to ``[nRb, K, bc, Mc]`` so the
    block index map stays static.  The k-axis accumulation is identical to
    the dense kernel's, which keeps surviving tiles bit-identical.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_w[...] = jnp.zeros_like(out_w)
        out_idx[...] = jnp.full_like(out_idx, -1)

    eps_sp = eps[0]
    eps_t = eps[1]

    rx = ref_x[...]                       # [bp]
    ry = ref_y[...]
    rt = ref_t[...]
    rid = ref_id[...]
    rok = ref_ok[...]

    cx = cand_x[0, 0]                     # [bc, bm]
    cy = cand_y[0, 0]
    ct = cand_t[0, 0]
    cid = cand_id[0, 0]                   # [bc]
    cok = cand_ok[0, 0]

    bm = cx.shape[-1]

    dx = rx[:, None, None] - cx[None, :, :]          # [bp, bc, bm]
    dy = ry[:, None, None] - cy[None, :, :]
    dt = jnp.abs(rt[:, None, None] - ct[None, :, :])
    d2 = dx * dx + dy * dy

    ok = (d2 <= eps_sp * eps_sp) & (dt <= eps_t)
    ok &= rok[:, None, None] & cok[None, :, :]
    ok &= rid[:, None, None] != cid[None, :, None]

    w = jnp.where(ok, 1.0 - jnp.sqrt(d2) / eps_sp, -1.0)  # [bp, bc, bm]

    tile_w = jnp.max(w, axis=-1)                          # [bp, bc]
    tile_arg = jnp.argmax(w, axis=-1).astype(jnp.int32)
    tile_idx = jnp.where(tile_w > 0.0, tile_arg + k * bm, -1)
    tile_w = jnp.maximum(tile_w, 0.0)

    run_w = out_w[0, 0]
    run_idx = out_idx[0, 0]
    better = tile_w > run_w
    out_w[0, 0] = jnp.where(better, tile_w, run_w)
    out_idx[0, 0] = jnp.where(better, tile_idx, run_idx)


# ---------------------------------------------------------------------------
# Fused epilogue variants (streaming join): the dense [P, C] best-match cube
# never leaves VMEM.  The flash-attention idiom (kernels/attention/flash.py)
# applied to the join: one program instance owns a (ref block, cand block)
# tile, scans the candidate points in ``bm`` slabs with a running max/argmax
# carry, and — instead of writing the [bp, bc] tile to HBM — folds it into
# the consumers' accumulators in-kernel:
#
#   pass 1 (``_vote_kernel``)  per-point vote sums [P] (Eq. 4) + bit-packed
#                              neighbor words [P, C/32] (TSA2, Alg. 3), with
#                              the delta_t run refine applied in-kernel.
#   pass 2 (``_sim_kernel``)   scatter-add of refined best-match weights into
#                              the [S+1, S+1] similarity accumulator (Eq. 2),
#                              re-sweeping the same tiles (recompute instead
#                              of a second HBM read of the cube).
#
# HBM traffic drops from O(T*M*C) (f32 + i32 cubes, written once and re-read
# once per consumer) to O(T*M + T*M*C/32 + S^2) accumulator bytes.
# ---------------------------------------------------------------------------


def _sweep_best(rx, ry, rt, rid, rok, cx, cy, ct, cid, cok,
                eps_sp, eps_t, bm: int, with_idx: bool):
    """Running best-match over candidate-point slabs, VMEM-resident.

    ``cx``/``cy``/``ct``/``cok``: [bc, Mc] block values; scanned in ``bm``
    chunks with a (max, argmax) carry — the same contraction as the
    materializing kernels' k grid axis, but kept entirely in registers.
    Returns ``w [bp, bc]`` (and ``idx`` when ``with_idx``), where ties keep
    the lowest candidate-point index (argmax-first, bit-identical to the
    dense kernel's chunked accumulation).
    """
    bp = rx.shape[0]
    bc, Mc = cx.shape

    def chunk(k, carry):
        cxk = jax.lax.dynamic_slice_in_dim(cx, k * bm, bm, axis=1)
        cyk = jax.lax.dynamic_slice_in_dim(cy, k * bm, bm, axis=1)
        ctk = jax.lax.dynamic_slice_in_dim(ct, k * bm, bm, axis=1)
        cokk = jax.lax.dynamic_slice_in_dim(cok, k * bm, bm, axis=1)

        dx = rx[:, None, None] - cxk[None, :, :]          # [bp, bc, bm]
        dy = ry[:, None, None] - cyk[None, :, :]
        dt = jnp.abs(rt[:, None, None] - ctk[None, :, :])
        d2 = dx * dx + dy * dy

        ok = (d2 <= eps_sp * eps_sp) & (dt <= eps_t)
        ok &= rok[:, None, None] & cokk[None, :, :]
        ok &= rid[:, None, None] != cid[None, :, None]

        w = jnp.where(ok, 1.0 - jnp.sqrt(d2) / eps_sp, -1.0)

        tile_w = jnp.max(w, axis=-1)                      # [bp, bc]
        if with_idx:
            run_w, run_idx = carry
            tile_arg = jnp.argmax(w, axis=-1).astype(jnp.int32)
            tile_idx = jnp.where(tile_w > 0.0, tile_arg + k * bm, -1)
            tile_w = jnp.maximum(tile_w, 0.0)
            better = tile_w > run_w
            return (jnp.where(better, tile_w, run_w),
                    jnp.where(better, tile_idx, run_idx))
        return (jnp.maximum(jnp.maximum(tile_w, 0.0), carry[0]),)

    init = (jnp.zeros((bp, bc), jnp.float32),)
    if with_idx:
        init = init + (jnp.full((bp, bc), -1, jnp.int32),)
    out = jax.lax.fori_loop(0, Mc // bm, chunk, init)
    return out if with_idx else out[0]


def _run_refine(w, rt, rows: int, M: int, delta_t):
    """In-kernel DTJ Refine (delta_t): zero matches in short runs.

    ``w``: [bp, bc] best weights for ``rows`` whole trajectory rows of ``M``
    points each (``bp == rows * M`` — the fused wrappers enforce row-aligned
    ref blocks precisely so runs never cross a block boundary).  A run is a
    maximal streak of consecutive matched ref points for one candidate; it
    survives iff its time extent ``t[last] - t[first] >= delta_t``.  Because
    ``t`` is ascending within a row, each point's run boundaries are the
    latest start at-or-before it (forward cummax of start times) and the
    earliest end at-or-after it (reverse cummin of end times) — no gather or
    scatter, so the whole refine stays in VMEM.  Matches
    ``repro.core.geometry.filter_delta_t`` exactly (delta_t == 0 is the
    identity on matched points: every run has extent >= 0).
    """
    bp, bc = w.shape
    m = w.reshape(rows, M, bc)
    matched = m > 0.0
    prev = jnp.pad(matched, ((0, 0), (1, 0), (0, 0)))[:, :M]
    nxt = jnp.pad(matched, ((0, 0), (0, 1), (0, 0)))[:, 1:]
    t3 = jnp.broadcast_to(rt.reshape(rows, M)[:, :, None], (rows, M, bc))
    big = jnp.float32(3.4e38)
    start_t = jax.lax.cummax(
        jnp.where(matched & ~prev, t3, -big), axis=1)
    end_t = jax.lax.cummin(
        jnp.where(matched & ~nxt, t3, big), axis=1, reverse=True)
    keep = matched & ((end_t - start_t) >= delta_t)
    return jnp.where(keep, m, 0.0).reshape(bp, bc)


def _vote_word_epilogue(w, shift_base, bc: int, out_vote, out_word, first_j,
                        first_word):
    """Fold a refined [bp, bc] tile into the vote / packed-word accumulators.

    ``shift_base``: bit offset of this candidate block inside its uint32
    word (``(j * bc) % 32``; ``bc`` divides 32, so a block never straddles a
    word boundary).  Bits of distinct blocks are disjoint, so ``+=`` is OR.
    """
    @pl.when(first_j)
    def _init_vote():
        out_vote[...] = jnp.zeros_like(out_vote)

    @pl.when(first_word)
    def _init_word():
        out_word[...] = jnp.zeros_like(out_word)

    out_vote[...] += jnp.sum(w, axis=1)
    weights = (jnp.uint32(1)
               << (shift_base.astype(jnp.uint32)
                   + jnp.arange(bc, dtype=jnp.uint32)))
    bits = (w > 0.0).astype(jnp.uint32)
    out_word[...] += jnp.sum(bits * weights[None, :], axis=1,
                             keepdims=True)


def _vote_kernel(ref_x, ref_y, ref_t, ref_id, ref_ok,
                 cand_x, cand_y, cand_t, cand_id, cand_ok,
                 eps, out_vote, *outs, rows: int, M: int, bc: int,
                 bm: int):
    """Dense pass 1; ``outs`` holds the packed-word ref only when the
    caller needs TSA2 neighbor sets (vote-only otherwise)."""
    j = pl.program_id(1)
    w = _sweep_best(ref_x[...], ref_y[...], ref_t[...], ref_id[...],
                    ref_ok[...], cand_x[...], cand_y[...], cand_t[...],
                    cand_id[...], cand_ok[...], eps[0], eps[1], bm, False)
    w = _run_refine(w, ref_t[...], rows, M, eps[2])
    if outs:
        _vote_word_epilogue(w, (j * bc) % 32, bc, out_vote, outs[0],
                            j == 0, (j * bc) % 32 == 0)
    else:
        @pl.when(j == 0)
        def _init_vote():
            out_vote[...] = jnp.zeros_like(out_vote)

        out_vote[...] += jnp.sum(w, axis=1)


def _vote_kernel_pruned(ref_x, ref_y, ref_t, ref_id, ref_ok,
                        cand_x, cand_y, cand_t, cand_id, cand_ok,
                        tile_id, eps, out_vote, *outs, rows: int,
                        M: int, bc: int, bm: int):
    """Pruned-grid pass 1: grid (ref block i, surviving-tile slot s).

    The candidate operands were gathered to ``[nRb, K, bc, Mc]`` (same
    layout as ``stjoin_pallas_pruned``); dead slots carry ``cand_ok ==
    False`` everywhere, so they contribute no votes and no bits.  The packed
    word cannot be routed by an index map (the word column depends on the
    *value* of ``tile_id``), so each slot emits its [bp] word contribution
    at (i, s) and the wrapper scatter-adds it into the [nRb, bp, W] layout
    (disjoint bit ranges -> add == OR).  ``outs`` is empty on the
    vote-only (TSA1) path.
    """
    s = pl.program_id(1)
    w = _sweep_best(ref_x[...], ref_y[...], ref_t[...], ref_id[...],
                    ref_ok[...], cand_x[0, 0], cand_y[0, 0], cand_t[0, 0],
                    cand_id[0, 0], cand_ok[0, 0], eps[0], eps[1], bm, False)
    w = _run_refine(w, ref_t[...], rows, M, eps[2])

    @pl.when(s == 0)
    def _init_vote():
        out_vote[...] = jnp.zeros_like(out_vote)

    out_vote[...] += jnp.sum(w, axis=1)
    if outs:
        jt = jnp.maximum(tile_id[0, 0], 0)
        weights = (jnp.uint32(1)
                   << (((jt * bc) % 32).astype(jnp.uint32)
                       + jnp.arange(bc, dtype=jnp.uint32)))
        bits = (w > 0.0).astype(jnp.uint32)
        outs[0][0, 0] = jnp.sum(bits * weights[None, :], axis=1)


def _sim_epilogue(w, idx, ref_gid, cand_gid, out_sim, first):
    """Scatter a refined tile into the [Sr+1, Sc+1] similarity accumulator.

    Mirrors ``repro.core.similarity.similarity_matrix``: the destination is
    the candidate *point*'s subtrajectory slot (gathered from ``cand_gid``
    at the best-match index); unmatched / unsegmented entries go to the
    sentinel row/column and are sliced off by the wrapper.  Weights are
    already delta_t-refined, so a dropped match adds exactly 0.
    """
    bc, Mc = cand_gid.shape
    sent_c = out_sim.shape[1] - 1

    @pl.when(first)
    def _init():
        out_sim[...] = jnp.zeros_like(out_sim)

    dstg = cand_gid[jnp.arange(bc)[None, :], jnp.clip(idx, 0, Mc - 1)]
    dst = jnp.where((w > 0.0) & (idx >= 0), dstg, sent_c)    # [bp, bc]
    src = jnp.broadcast_to(ref_gid[:, None], w.shape)
    out_sim[...] = out_sim[...].at[src, dst].add(w)


def _sim_kernel(ref_x, ref_y, ref_t, ref_id, ref_ok, ref_gid,
                cand_x, cand_y, cand_t, cand_id, cand_ok, cand_gid,
                eps, out_sim, *, rows: int, M: int, bc: int, bm: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    w, idx = _sweep_best(ref_x[...], ref_y[...], ref_t[...], ref_id[...],
                         ref_ok[...], cand_x[...], cand_y[...], cand_t[...],
                         cand_id[...], cand_ok[...], eps[0], eps[1], bm,
                         True)
    w = _run_refine(w, ref_t[...], rows, M, eps[2])
    _sim_epilogue(w, idx, ref_gid[...], cand_gid[...], out_sim,
                  (i == 0) & (j == 0))


def _sim_kernel_pruned(ref_x, ref_y, ref_t, ref_id, ref_ok, ref_gid,
                       cand_x, cand_y, cand_t, cand_id, cand_ok, cand_gid,
                       eps, out_sim, *, rows: int, M: int, bc: int, bm: int):
    i = pl.program_id(0)
    s = pl.program_id(1)
    w, idx = _sweep_best(ref_x[...], ref_y[...], ref_t[...], ref_id[...],
                         ref_ok[...], cand_x[0, 0], cand_y[0, 0],
                         cand_t[0, 0], cand_id[0, 0], cand_ok[0, 0],
                         eps[0], eps[1], bm, True)
    w = _run_refine(w, ref_t[...], rows, M, eps[2])
    _sim_epilogue(w, idx, ref_gid[...], cand_gid[0, 0], out_sim,
                  (i == 0) & (s == 0))


def _sim_panel_epilogue(w, idx, ref_gid, ref_lgid, cand_gid, cand_lgid,
                        out_fwd, out_rev, first):
    """Scatter a refined tile into one row panel — in BOTH orientations.

    The top-K streaming engine (DESIGN.md §8) consumes the similarity
    matrix one ``Sb``-row panel at a time and needs each panel's rows of
    ``raw`` *and* of ``raw.T`` so the max-symmetrization stays exact
    without ever holding ``[S, S]``:

        fwd[src - p0, dst] += w      (the panel's rows of ``raw``)
        rev[dst - p0, src] += w      (the panel's rows of ``raw.T``)

    ``ref_lgid`` / ``cand_lgid`` are the panel-localized slot maps
    (sentinel ``Sb`` outside the panel, computed by the wrapper), so both
    scatters hit a ``[Sb + 1, S + 1]`` accumulator.  Contributions arrive
    in the same tile order as ``_sim_epilogue``'s dense scatter, keeping
    per-cell sums bit-equal to the dense raw matrix's.
    """
    bc, Mc = cand_gid.shape
    sent_c = out_fwd.shape[1] - 1
    sent_r = out_fwd.shape[0] - 1

    @pl.when(first)
    def _init():
        out_fwd[...] = jnp.zeros_like(out_fwd)
        out_rev[...] = jnp.zeros_like(out_rev)

    cols = jnp.arange(bc)[None, :]
    safe = jnp.clip(idx, 0, Mc - 1)
    ok = (w > 0.0) & (idx >= 0)
    dst = jnp.where(ok, cand_gid[cols, safe], sent_c)        # [bp, bc]
    dst_l = jnp.where(ok, cand_lgid[cols, safe], sent_r)
    src = jnp.broadcast_to(ref_gid[:, None], w.shape)
    src_l = jnp.broadcast_to(ref_lgid[:, None], w.shape)
    out_fwd[...] = out_fwd[...].at[src_l, dst].add(w)
    out_rev[...] = out_rev[...].at[dst_l, src].add(w)


def _sim_panel_kernel(ref_x, ref_y, ref_t, ref_id, ref_ok, ref_gid, ref_lgid,
                      cand_x, cand_y, cand_t, cand_id, cand_ok, cand_gid,
                      cand_lgid, eps, out_fwd, out_rev, *, rows: int, M: int,
                      bc: int, bm: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    w, idx = _sweep_best(ref_x[...], ref_y[...], ref_t[...], ref_id[...],
                         ref_ok[...], cand_x[...], cand_y[...], cand_t[...],
                         cand_id[...], cand_ok[...], eps[0], eps[1], bm,
                         True)
    w = _run_refine(w, ref_t[...], rows, M, eps[2])
    _sim_panel_epilogue(w, idx, ref_gid[...], ref_lgid[...], cand_gid[...],
                        cand_lgid[...], out_fwd, out_rev,
                        (i == 0) & (j == 0))


@functools.partial(
    jax.jit,
    static_argnames=("rows", "M", "bc", "bm", "n_src", "n_dst", "panel",
                     "interpret"))
def stjoin_sim_panel_fused_flat(ref_x, ref_y, ref_t, ref_id, ref_ok, ref_gid,
                                ref_lgid, cand_x, cand_y, cand_t, cand_id,
                                cand_ok, cand_gid, cand_lgid, eps_sp, eps_t,
                                delta_t, *, rows: int, M: int, n_src: int,
                                n_dst: int, panel: int, bc: int = 8,
                                bm: int = 128, interpret: bool = True):
    """Fused pass 2, panel-streamed: ``(fwd [Sb, n_dst], rev [Sb, n_src])``.

    Identical tile sweep to ``stjoin_sim_fused_flat`` (same recompute of
    the best-match contraction after segmentation), but the epilogue
    accumulates only one ``Sb``-row panel of the similarity scatter — in
    both orientations — so the whole call's output is O(Sb * S) instead of
    O(S^2).  ``ref_lgid`` / ``cand_lgid`` hold the panel-localized slot of
    each point (``panel`` = Sb sentinel for out-of-panel slots); the
    caller sweeps panels by re-invoking with shifted localizations (a
    traced offset — one trace covers every panel).
    """
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    bp = rows * M
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)

    eps = _fused_eps(eps_sp, eps_t, delta_t)
    grid = (P // bp, C // bc)
    ref_spec = pl.BlockSpec((bp,), lambda i, j: (i,))
    cand_spec = pl.BlockSpec((bc, Mc), lambda i, j: (j, 0))
    cid_spec = pl.BlockSpec((bc,), lambda i, j: (j,))
    eps_spec = pl.BlockSpec((3,), lambda i, j: (0,))
    fwd_spec = pl.BlockSpec((panel + 1, n_dst + 1), lambda i, j: (0, 0))
    rev_spec = pl.BlockSpec((panel + 1, n_src + 1), lambda i, j: (0, 0))

    fwd, rev = pl.pallas_call(
        functools.partial(_sim_panel_kernel, rows=rows, M=M, bc=bc, bm=bm),
        grid=grid,
        in_specs=[ref_spec] * 5 + [ref_spec] * 2 + [cand_spec] * 3
        + [cid_spec, cand_spec, cand_spec, cand_spec, eps_spec],
        out_specs=[fwd_spec, rev_spec],
        out_shape=[
            jax.ShapeDtypeStruct((panel + 1, n_dst + 1), jnp.float32),
            jax.ShapeDtypeStruct((panel + 1, n_src + 1), jnp.float32),
        ],
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), ref_gid.astype(jnp.int32),
      ref_lgid.astype(jnp.int32), cand_x, cand_y, cand_t,
      cand_id.astype(jnp.int32), cand_ok.astype(jnp.bool_),
      cand_gid.astype(jnp.int32), cand_lgid.astype(jnp.int32), eps)
    return fwd[:panel, :n_dst], rev[:panel, :n_src]


def _sim_panel_kernel_pruned(ref_x, ref_y, ref_t, ref_id, ref_ok, ref_gid,
                             ref_lgid, cand_x, cand_y, cand_t, cand_id,
                             cand_ok, cand_gid, cand_lgid, eps, out_fwd,
                             out_rev, *, rows: int, M: int, bc: int,
                             bm: int):
    i = pl.program_id(0)
    s = pl.program_id(1)
    w, idx = _sweep_best(ref_x[...], ref_y[...], ref_t[...], ref_id[...],
                         ref_ok[...], cand_x[0, 0], cand_y[0, 0],
                         cand_t[0, 0], cand_id[0, 0], cand_ok[0, 0],
                         eps[0], eps[1], bm, True)
    w = _run_refine(w, ref_t[...], rows, M, eps[2])
    _sim_panel_epilogue(w, idx, ref_gid[...], ref_lgid[...], cand_gid[0, 0],
                        cand_lgid[0, 0], out_fwd, out_rev,
                        (i == 0) & (s == 0))


@functools.partial(
    jax.jit,
    static_argnames=("rows", "M", "bc", "bm", "n_src", "n_dst", "panel",
                     "interpret"))
def stjoin_sim_panel_fused_pruned_flat(ref_x, ref_y, ref_t, ref_id, ref_ok,
                                       ref_gid, ref_lgid, cand_x, cand_y,
                                       cand_t, cand_id, cand_ok, cand_gid,
                                       cand_lgid, tile_ids, eps_sp, eps_t,
                                       delta_t, *, rows: int, M: int,
                                       n_src: int, n_dst: int, panel: int,
                                       bc: int = 8, bm: int = 128,
                                       interpret: bool = True):
    """Panel-streamed fused pass 2 over the index-pruned tile plan.

    Same gather layout as ``stjoin_sim_fused_pruned_flat``; only the
    plan's surviving tiles are swept per panel, yet the (fwd, rev) slabs
    equal the dense panel sweep's (skipped tiles contribute exactly 0).
    """
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    bp = rows * M
    nRb = P // bp
    nCb = C // bc
    K = tile_ids.shape[1]
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)
    assert tile_ids.shape[0] == nRb, (tile_ids.shape, nRb)

    live = tile_ids >= 0
    safe = jnp.clip(tile_ids, 0, nCb - 1)
    gather = lambda a: a.reshape(nCb, bc, Mc)[safe]
    gx, gy, gt = gather(cand_x), gather(cand_y), gather(cand_t)
    gok = gather(cand_ok.astype(jnp.bool_)) & live[:, :, None, None]
    gid = cand_id.astype(jnp.int32).reshape(nCb, bc)[safe]
    ggid = gather(cand_gid.astype(jnp.int32))
    glgid = gather(cand_lgid.astype(jnp.int32))

    eps = _fused_eps(eps_sp, eps_t, delta_t)
    grid = (nRb, K)
    ref_spec = pl.BlockSpec((bp,), lambda i, s: (i,))
    cand_spec = pl.BlockSpec((1, 1, bc, Mc), lambda i, s: (i, s, 0, 0))
    cid_spec = pl.BlockSpec((1, 1, bc), lambda i, s: (i, s, 0))
    eps_spec = pl.BlockSpec((3,), lambda i, s: (0,))
    fwd_spec = pl.BlockSpec((panel + 1, n_dst + 1), lambda i, s: (0, 0))
    rev_spec = pl.BlockSpec((panel + 1, n_src + 1), lambda i, s: (0, 0))

    fwd, rev = pl.pallas_call(
        functools.partial(_sim_panel_kernel_pruned, rows=rows, M=M, bc=bc,
                          bm=bm),
        grid=grid,
        in_specs=[ref_spec] * 5 + [ref_spec] * 2 + [cand_spec] * 3
        + [cid_spec, cand_spec, cand_spec, cand_spec, eps_spec],
        out_specs=[fwd_spec, rev_spec],
        out_shape=[
            jax.ShapeDtypeStruct((panel + 1, n_dst + 1), jnp.float32),
            jax.ShapeDtypeStruct((panel + 1, n_src + 1), jnp.float32),
        ],
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), ref_gid.astype(jnp.int32),
      ref_lgid.astype(jnp.int32), gx, gy, gt, gid, gok, ggid, glgid, eps)
    return fwd[:panel, :n_dst], rev[:panel, :n_src]


def _fused_eps(eps_sp, eps_t, delta_t):
    return jnp.stack([jnp.asarray(eps_sp, jnp.float32),
                      jnp.asarray(eps_t, jnp.float32),
                      jnp.asarray(delta_t, jnp.float32)])


@functools.partial(
    jax.jit,
    static_argnames=("rows", "M", "bc", "bm", "with_words", "interpret"))
def stjoin_vote_fused_flat(ref_x, ref_y, ref_t, ref_id, ref_ok,
                           cand_x, cand_y, cand_t, cand_id, cand_ok,
                           eps_sp, eps_t, delta_t, *, rows: int, M: int,
                           bc: int = 8, bm: int = 128,
                           with_words: bool = True,
                           interpret: bool = True):
    """Fused pass 1 over the dense tile grid.

    Ref points are flattened ``[P]`` with ``P = n_rows_total * M`` and block
    size ``bp = rows * M`` (whole trajectory rows per block — required by
    the in-kernel delta_t refine).  Returns ``(vote [P] f32,
    words [P, C/32] uint32 | None)``; C must be a multiple of 32 and ``bc``
    a divisor of 32 so every candidate block lands inside one uint32 word.
    ``with_words=False`` (the TSA1 path) skips the packed-word accumulator
    entirely — no bit packing, no extra output traffic.
    """
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    bp = rows * M
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)
    assert C % 32 == 0 and 32 % bc == 0, (C, bc)
    W = C // 32

    eps = _fused_eps(eps_sp, eps_t, delta_t)
    grid = (P // bp, C // bc)
    ref_spec = pl.BlockSpec((bp,), lambda i, j: (i,))
    cand_spec = pl.BlockSpec((bc, Mc), lambda i, j: (j, 0))
    cid_spec = pl.BlockSpec((bc,), lambda i, j: (j,))
    eps_spec = pl.BlockSpec((3,), lambda i, j: (0,))

    out_specs = [pl.BlockSpec((bp,), lambda i, j: (i,))]
    out_shape = [jax.ShapeDtypeStruct((P,), jnp.float32)]
    if with_words:
        out_specs.append(
            pl.BlockSpec((bp, 1), lambda i, j: (i, (j * bc) // 32)))
        out_shape.append(jax.ShapeDtypeStruct((P, W), jnp.uint32))

    out = pl.pallas_call(
        functools.partial(_vote_kernel, rows=rows, M=M, bc=bc, bm=bm),
        grid=grid,
        in_specs=[ref_spec] * 5 + [cand_spec] * 3 + [cid_spec, cand_spec,
                                                     eps_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), cand_x, cand_y, cand_t,
      cand_id.astype(jnp.int32), cand_ok.astype(jnp.bool_), eps)
    return (out[0], out[1]) if with_words else (out[0], None)


@functools.partial(
    jax.jit,
    static_argnames=("rows", "M", "bc", "bm", "with_words", "interpret"))
def stjoin_vote_fused_pruned_flat(ref_x, ref_y, ref_t, ref_id, ref_ok,
                                  cand_x, cand_y, cand_t, cand_id, cand_ok,
                                  tile_ids, eps_sp, eps_t, delta_t, *,
                                  rows: int, M: int, bc: int = 8,
                                  bm: int = 128, with_words: bool = True,
                                  interpret: bool = True):
    """Fused pass 1 over the index-pruned tile plan (``tile_ids [nRb, K]``).

    Same gather layout as ``stjoin_pallas_pruned``; only surviving tiles are
    swept, yet the outputs are the full dense-equivalent accumulators
    (pruning is conservative, so skipped tiles contribute exactly 0).
    ``with_words=False`` skips the packed-word contributions and scatter.
    """
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    bp = rows * M
    nRb = P // bp
    nCb = C // bc
    K = tile_ids.shape[1]
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)
    assert C % 32 == 0 and 32 % bc == 0, (C, bc)
    assert tile_ids.shape[0] == nRb, (tile_ids.shape, nRb)
    W = C // 32

    live = tile_ids >= 0                                    # [nRb, K]
    safe = jnp.clip(tile_ids, 0, nCb - 1)
    gather = lambda a: a.reshape(nCb, bc, Mc)[safe]         # [nRb, K, bc, Mc]
    gx, gy, gt = gather(cand_x), gather(cand_y), gather(cand_t)
    gok = gather(cand_ok.astype(jnp.bool_)) & live[:, :, None, None]
    gid = cand_id.astype(jnp.int32).reshape(nCb, bc)[safe]  # [nRb, K, bc]

    eps = _fused_eps(eps_sp, eps_t, delta_t)
    grid = (nRb, K)
    ref_spec = pl.BlockSpec((bp,), lambda i, s: (i,))
    cand_spec = pl.BlockSpec((1, 1, bc, Mc), lambda i, s: (i, s, 0, 0))
    cid_spec = pl.BlockSpec((1, 1, bc), lambda i, s: (i, s, 0))
    tid_spec = pl.BlockSpec((1, 1), lambda i, s: (i, s))
    eps_spec = pl.BlockSpec((3,), lambda i, s: (0,))

    out_specs = [pl.BlockSpec((bp,), lambda i, s: (i,))]
    out_shape = [jax.ShapeDtypeStruct((P,), jnp.float32)]
    if with_words:
        out_specs.append(pl.BlockSpec((1, 1, bp), lambda i, s: (i, s, 0)))
        out_shape.append(jax.ShapeDtypeStruct((nRb, K, bp), jnp.uint32))

    out = pl.pallas_call(
        functools.partial(_vote_kernel_pruned, rows=rows, M=M, bc=bc, bm=bm),
        grid=grid,
        in_specs=[ref_spec] * 5 + [cand_spec] * 3 + [cid_spec, cand_spec,
                                                     tid_spec, eps_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), gx, gy, gt, gid, gok,
      tile_ids.astype(jnp.int32), eps)
    if not with_words:
        return out[0], None
    vote, contrib = out

    # host-side word scatter: slot s of ref block i carries the bits of
    # candidate block tile_ids[i, s]; distinct slots of one word hold
    # disjoint bit ranges, so scatter-add == OR.  Dead slots -> dummy col W.
    word_col = jnp.where(live, (safe * bc) // 32, W)        # [nRb, K]
    rows_ix = jnp.arange(nRb, dtype=jnp.int32)[:, None]
    words = jnp.zeros((nRb, W + 1, bp), jnp.uint32)
    words = words.at[rows_ix, word_col].add(contrib, mode="drop")
    words = words[:, :W].transpose(0, 2, 1).reshape(P, W)
    return vote, words


@functools.partial(
    jax.jit,
    static_argnames=("rows", "M", "bc", "bm", "n_src", "n_dst", "interpret"))
def stjoin_sim_fused_flat(ref_x, ref_y, ref_t, ref_id, ref_ok, ref_gid,
                          cand_x, cand_y, cand_t, cand_id, cand_ok, cand_gid,
                          eps_sp, eps_t, delta_t, *, rows: int, M: int,
                          n_src: int, n_dst: int, bc: int = 8, bm: int = 128,
                          interpret: bool = True):
    """Fused pass 2 (dense grid): raw similarity scatter ``[n_src, n_dst]``.

    ``ref_gid [P]``: subtrajectory slot of each ref point (``n_src`` =
    sentinel for unsegmented/padding).  ``cand_gid [C, Mc]``: slot of each
    candidate *point* (``n_dst`` sentinel).  Returns the un-normalized
    scatter of refined best-match weights — ``similarity_matrix``'s ``raw``
    — with the sentinel row/column already sliced off.

    Capacity note: the whole ``[n_src+1, n_dst+1]`` accumulator is one
    revisited output block, so on real TPU (interpret=False) ``S`` is
    capped by VMEM (~16 MiB -> S up to ~2000 slots f32).  Beyond that,
    tile the accumulator columns and run one sweep per column block — the
    distributed ``sim_strategy="allgather"`` path already has exactly that
    shape (each model rank owns an ``[S, S/m]`` block); on one chip the
    same column loop applies.  CPU interpret (the correctness path) has no
    such cap.
    """
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    bp = rows * M
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)

    eps = _fused_eps(eps_sp, eps_t, delta_t)
    grid = (P // bp, C // bc)
    ref_spec = pl.BlockSpec((bp,), lambda i, j: (i,))
    cand_spec = pl.BlockSpec((bc, Mc), lambda i, j: (j, 0))
    cid_spec = pl.BlockSpec((bc,), lambda i, j: (j,))
    eps_spec = pl.BlockSpec((3,), lambda i, j: (0,))

    raw = pl.pallas_call(
        functools.partial(_sim_kernel, rows=rows, M=M, bc=bc, bm=bm),
        grid=grid,
        in_specs=[ref_spec] * 5 + [ref_spec] + [cand_spec] * 3
        + [cid_spec, cand_spec, cand_spec, eps_spec],
        out_specs=pl.BlockSpec((n_src + 1, n_dst + 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_src + 1, n_dst + 1), jnp.float32),
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), ref_gid.astype(jnp.int32),
      cand_x, cand_y, cand_t, cand_id.astype(jnp.int32),
      cand_ok.astype(jnp.bool_), cand_gid.astype(jnp.int32), eps)
    return raw[:n_src, :n_dst]


@functools.partial(
    jax.jit,
    static_argnames=("rows", "M", "bc", "bm", "n_src", "n_dst", "interpret"))
def stjoin_sim_fused_pruned_flat(ref_x, ref_y, ref_t, ref_id, ref_ok,
                                 ref_gid, cand_x, cand_y, cand_t, cand_id,
                                 cand_ok, cand_gid, tile_ids, eps_sp, eps_t,
                                 delta_t, *, rows: int, M: int, n_src: int,
                                 n_dst: int, bc: int = 8, bm: int = 128,
                                 interpret: bool = True):
    """Fused pass 2 over the index-pruned tile plan (same plan as pass 1)."""
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    bp = rows * M
    nRb = P // bp
    nCb = C // bc
    K = tile_ids.shape[1]
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)
    assert tile_ids.shape[0] == nRb, (tile_ids.shape, nRb)

    live = tile_ids >= 0
    safe = jnp.clip(tile_ids, 0, nCb - 1)
    gather = lambda a: a.reshape(nCb, bc, Mc)[safe]
    gx, gy, gt = gather(cand_x), gather(cand_y), gather(cand_t)
    gok = gather(cand_ok.astype(jnp.bool_)) & live[:, :, None, None]
    gid = cand_id.astype(jnp.int32).reshape(nCb, bc)[safe]
    ggid = gather(cand_gid.astype(jnp.int32))

    eps = _fused_eps(eps_sp, eps_t, delta_t)
    grid = (nRb, K)
    ref_spec = pl.BlockSpec((bp,), lambda i, s: (i,))
    cand_spec = pl.BlockSpec((1, 1, bc, Mc), lambda i, s: (i, s, 0, 0))
    cid_spec = pl.BlockSpec((1, 1, bc), lambda i, s: (i, s, 0))
    eps_spec = pl.BlockSpec((3,), lambda i, s: (0,))

    raw = pl.pallas_call(
        functools.partial(_sim_kernel_pruned, rows=rows, M=M, bc=bc, bm=bm),
        grid=grid,
        in_specs=[ref_spec] * 5 + [ref_spec] + [cand_spec] * 3
        + [cid_spec, cand_spec, cand_spec, eps_spec],
        out_specs=pl.BlockSpec((n_src + 1, n_dst + 1), lambda i, s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_src + 1, n_dst + 1), jnp.float32),
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), ref_gid.astype(jnp.int32),
      gx, gy, gt, gid, gok, ggid, eps)
    return raw[:n_src, :n_dst]


@functools.partial(
    jax.jit,
    static_argnames=("bp", "bc", "bm", "interpret"))
def stjoin_pallas_pruned(ref_x, ref_y, ref_t, ref_id, ref_ok,
                         cand_x, cand_y, cand_t, cand_id, cand_ok,
                         tile_ids, eps_sp, eps_t, *, bp: int = 256,
                         bc: int = 8, bm: int = 128,
                         interpret: bool = True):
    """Sparse-grid join: visit only the surviving (ref block, cand tile)
    pairs named by ``tile_ids``.

    ``tile_ids``: [nRb, K] int32 — per reference block, the candidate
    j-block ids (``C // bc`` of them exist) whose bounding boxes intersect
    the eps-expanded reference-block box, -1 padded, ascending.  Produced
    by ``repro.index.grid.compact_candidates``.

    Returns dense (best_w [P, C], best_idx [P, C]); entries of pruned
    tiles are (0, -1) — exactly what the dense kernel yields for them,
    because pruning is conservative.

    Memory note: the gather materializes the surviving candidate tiles as
    ``[nRb, K, bc, Mc]`` arrays (duplication factor ~nRb*K/nCb over the
    raw candidate set), which keeps the block index maps static at the
    cost of HBM footprint.  The TPU follow-up is a scalar-prefetch grid
    (``tile_ids`` as a prefetch operand indexing the original [C, Mc]
    arrays) that removes the duplication; on CPU interpret this is the
    correctness-path layout.
    """
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    nRb = P // bp
    nCb = C // bc
    K = tile_ids.shape[1]
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)
    assert tile_ids.shape[0] == nRb, (tile_ids.shape, nRb)

    live = tile_ids >= 0                                    # [nRb, K]
    safe = jnp.clip(tile_ids, 0, nCb - 1)

    # gather candidate j-blocks per reference block: [nRb, K, bc, Mc]
    gather = lambda a: a.reshape(nCb, bc, Mc)[safe]
    gx, gy, gt = gather(cand_x), gather(cand_y), gather(cand_t)
    gok = gather(cand_ok.astype(jnp.bool_)) & live[:, :, None, None]
    gid = cand_id.astype(jnp.int32).reshape(nCb, bc)[safe]  # [nRb, K, bc]

    eps = jnp.stack([jnp.asarray(eps_sp, jnp.float32),
                     jnp.asarray(eps_t, jnp.float32)])

    grid = (nRb, K, Mc // bm)
    ref_spec = pl.BlockSpec((bp,), lambda i, s, k: (i,))
    cand_spec = pl.BlockSpec((1, 1, bc, bm), lambda i, s, k: (i, s, 0, k))
    cid_spec = pl.BlockSpec((1, 1, bc), lambda i, s, k: (i, s, 0))
    eps_spec = pl.BlockSpec((2,), lambda i, s, k: (0,))
    out_spec = pl.BlockSpec((1, 1, bp, bc), lambda i, s, k: (i, s, 0, 0))

    tw, tidx = pl.pallas_call(
        _pruned_kernel,
        grid=grid,
        in_specs=[ref_spec] * 5 + [cand_spec] * 3 + [cid_spec, cand_spec,
                                                     eps_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nRb, K, bp, bc), jnp.float32),
            jax.ShapeDtypeStruct((nRb, K, bp, bc), jnp.int32),
        ],
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), gx, gy, gt, gid, gok, eps)

    # scatter surviving tiles back to the dense [P, C] layout; each (i, j)
    # appears at most once in a row of tile_ids, so .set is exact.
    col = jnp.where(live, safe, nCb)                        # dummy col nCb
    rows = jnp.arange(nRb, dtype=jnp.int32)[:, None]
    w = jnp.zeros((nRb, nCb + 1, bp, bc), jnp.float32)
    idx = jnp.full((nRb, nCb + 1, bp, bc), -1, jnp.int32)
    w = w.at[rows, col].set(tw, mode="drop")
    idx = idx.at[rows, col].set(tidx, mode="drop")
    w = w[:, :nCb].transpose(0, 2, 1, 3).reshape(P, C)
    idx = idx[:, :nCb].transpose(0, 2, 1, 3).reshape(P, C)
    return w, idx


@functools.partial(
    jax.jit,
    static_argnames=("bp", "bc", "bm", "interpret"))
def stjoin_pallas(ref_x, ref_y, ref_t, ref_id, ref_ok,
                  cand_x, cand_y, cand_t, cand_id, cand_ok,
                  eps_sp, eps_t, *, bp: int = 256, bc: int = 8,
                  bm: int = 128, interpret: bool = True):
    """Returns (best_w[P, C] f32, best_idx[P, C] i32)."""
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)

    eps = jnp.stack([jnp.asarray(eps_sp, jnp.float32),
                     jnp.asarray(eps_t, jnp.float32)])

    grid = (P // bp, C // bc, Mc // bm)
    ref_spec = pl.BlockSpec((bp,), lambda i, j, k: (i,))
    cand_spec = pl.BlockSpec((bc, bm), lambda i, j, k: (j, k))
    cid_spec = pl.BlockSpec((bc,), lambda i, j, k: (j,))
    eps_spec = pl.BlockSpec((2,), lambda i, j, k: (0,))
    out_spec = pl.BlockSpec((bp, bc), lambda i, j, k: (i, j))

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[ref_spec] * 5 + [cand_spec] * 3 + [cid_spec, cand_spec,
                                                     eps_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((P, C), jnp.float32),
            jax.ShapeDtypeStruct((P, C), jnp.int32),
        ],
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), cand_x, cand_y, cand_t,
      cand_id.astype(jnp.int32), cand_ok.astype(jnp.bool_), eps)
