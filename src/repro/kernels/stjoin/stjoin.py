"""Pallas TPU kernel: best-match spatiotemporal join (DTJ's Join step).

Contract
--------
Given ``P`` reference points (flattened, with per-point trajectory ids) and
``C`` candidate trajectories of up to ``Mc`` points each, compute for every
(ref point p, candidate trajectory c):

    best_w[p, c]   = max over candidate points m of
                     (1 - d_sp(p, (c,m)) / eps_sp)
                     subject to d_sp <= eps_sp, |dt| <= eps_t,
                     validity, and traj_id[p] != cand_id[c]
    best_idx[p, c] = argmax m (or -1)

Tiling
------
grid = (P/bp, C/bc, Mc/bm); the (i, j) output tile [bp, bc] is revisited
across the k (candidate-point) grid axis and accumulated with a running
max/argmax in VMEM — the classic "contraction last axis" Pallas pattern.

Per-tile working set (defaults bp=256, bc=8, bm=128):
    ref slabs        4 * bp * 4B               =   4 KiB
    cand slabs       4 * bc * bm * 4B          =  16 KiB
    pairwise temps   ~4 * bp * bc * bm * 4B    =   4 MiB
    accumulators     2 * bp * bc * 4B          =  16 KiB
well under the ~16 MiB v5e VMEM budget; bp/bm are multiples of the f32
(8, 128) tile so the VPU operates on full registers.

Distance is computed with a broadcast subtract on the VPU: the contraction
depth is 2 (x, y), far too shallow for the MXU to pay off — this kernel is
HBM-bandwidth- and VPU-bound by design, which is exactly why minimizing
bytes (best-match streaming instead of materializing [P, C, Mc]) matters.
A tile whose time range is provably farther than eps_t from the ref tile's
range contributes nothing; time-sorted inputs make those tiles cheap
(mask-all-zero), and the grid dimension ordering keeps the accumulator hot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ref_x, ref_y, ref_t, ref_id, ref_ok,
            cand_x, cand_y, cand_t, cand_id, cand_ok,
            eps, out_w, out_idx):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_w[...] = jnp.zeros_like(out_w)
        out_idx[...] = jnp.full_like(out_idx, -1)

    eps_sp = eps[0]
    eps_t = eps[1]

    rx = ref_x[...]                       # [bp]
    ry = ref_y[...]
    rt = ref_t[...]
    rid = ref_id[...]
    rok = ref_ok[...]

    cx = cand_x[...]                      # [bc, bm]
    cy = cand_y[...]
    ct = cand_t[...]
    cid = cand_id[...]                    # [bc]
    cok = cand_ok[...]

    bp = rx.shape[0]
    bc, bm = cx.shape

    dx = rx[:, None, None] - cx[None, :, :]          # [bp, bc, bm]
    dy = ry[:, None, None] - cy[None, :, :]
    dt = jnp.abs(rt[:, None, None] - ct[None, :, :])
    d2 = dx * dx + dy * dy

    ok = (d2 <= eps_sp * eps_sp) & (dt <= eps_t)
    ok &= rok[:, None, None] & cok[None, :, :]
    ok &= rid[:, None, None] != cid[None, :, None]

    w = jnp.where(ok, 1.0 - jnp.sqrt(d2) / eps_sp, -1.0)  # [bp, bc, bm]

    tile_w = jnp.max(w, axis=-1)                          # [bp, bc]
    tile_arg = jnp.argmax(w, axis=-1).astype(jnp.int32)   # [bp, bc]
    tile_idx = jnp.where(tile_w > 0.0, tile_arg + k * bm, -1)
    tile_w = jnp.maximum(tile_w, 0.0)

    run_w = out_w[...]
    run_idx = out_idx[...]
    better = tile_w > run_w
    out_w[...] = jnp.where(better, tile_w, run_w)
    out_idx[...] = jnp.where(better, tile_idx, run_idx)


def _pruned_kernel(ref_x, ref_y, ref_t, ref_id, ref_ok,
                   cand_x, cand_y, cand_t, cand_id, cand_ok,
                   eps, out_w, out_idx):
    """Same contraction as ``_kernel`` but over gathered candidate tiles.

    Grid is (ref block i, surviving-tile slot s, cand-point chunk k); the
    candidate operands were pre-gathered to ``[nRb, K, bc, Mc]`` so the
    block index map stays static.  The k-axis accumulation is identical to
    the dense kernel's, which keeps surviving tiles bit-identical.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_w[...] = jnp.zeros_like(out_w)
        out_idx[...] = jnp.full_like(out_idx, -1)

    eps_sp = eps[0]
    eps_t = eps[1]

    rx = ref_x[...]                       # [bp]
    ry = ref_y[...]
    rt = ref_t[...]
    rid = ref_id[...]
    rok = ref_ok[...]

    cx = cand_x[0, 0]                     # [bc, bm]
    cy = cand_y[0, 0]
    ct = cand_t[0, 0]
    cid = cand_id[0, 0]                   # [bc]
    cok = cand_ok[0, 0]

    bm = cx.shape[-1]

    dx = rx[:, None, None] - cx[None, :, :]          # [bp, bc, bm]
    dy = ry[:, None, None] - cy[None, :, :]
    dt = jnp.abs(rt[:, None, None] - ct[None, :, :])
    d2 = dx * dx + dy * dy

    ok = (d2 <= eps_sp * eps_sp) & (dt <= eps_t)
    ok &= rok[:, None, None] & cok[None, :, :]
    ok &= rid[:, None, None] != cid[None, :, None]

    w = jnp.where(ok, 1.0 - jnp.sqrt(d2) / eps_sp, -1.0)  # [bp, bc, bm]

    tile_w = jnp.max(w, axis=-1)                          # [bp, bc]
    tile_arg = jnp.argmax(w, axis=-1).astype(jnp.int32)
    tile_idx = jnp.where(tile_w > 0.0, tile_arg + k * bm, -1)
    tile_w = jnp.maximum(tile_w, 0.0)

    run_w = out_w[0, 0]
    run_idx = out_idx[0, 0]
    better = tile_w > run_w
    out_w[0, 0] = jnp.where(better, tile_w, run_w)
    out_idx[0, 0] = jnp.where(better, tile_idx, run_idx)


@functools.partial(
    jax.jit,
    static_argnames=("bp", "bc", "bm", "interpret"))
def stjoin_pallas_pruned(ref_x, ref_y, ref_t, ref_id, ref_ok,
                         cand_x, cand_y, cand_t, cand_id, cand_ok,
                         tile_ids, eps_sp, eps_t, *, bp: int = 256,
                         bc: int = 8, bm: int = 128,
                         interpret: bool = True):
    """Sparse-grid join: visit only the surviving (ref block, cand tile)
    pairs named by ``tile_ids``.

    ``tile_ids``: [nRb, K] int32 — per reference block, the candidate
    j-block ids (``C // bc`` of them exist) whose bounding boxes intersect
    the eps-expanded reference-block box, -1 padded, ascending.  Produced
    by ``repro.index.grid.compact_candidates``.

    Returns dense (best_w [P, C], best_idx [P, C]); entries of pruned
    tiles are (0, -1) — exactly what the dense kernel yields for them,
    because pruning is conservative.

    Memory note: the gather materializes the surviving candidate tiles as
    ``[nRb, K, bc, Mc]`` arrays (duplication factor ~nRb*K/nCb over the
    raw candidate set), which keeps the block index maps static at the
    cost of HBM footprint.  The TPU follow-up is a scalar-prefetch grid
    (``tile_ids`` as a prefetch operand indexing the original [C, Mc]
    arrays) that removes the duplication; on CPU interpret this is the
    correctness-path layout.
    """
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    nRb = P // bp
    nCb = C // bc
    K = tile_ids.shape[1]
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)
    assert tile_ids.shape[0] == nRb, (tile_ids.shape, nRb)

    live = tile_ids >= 0                                    # [nRb, K]
    safe = jnp.clip(tile_ids, 0, nCb - 1)

    # gather candidate j-blocks per reference block: [nRb, K, bc, Mc]
    gather = lambda a: a.reshape(nCb, bc, Mc)[safe]
    gx, gy, gt = gather(cand_x), gather(cand_y), gather(cand_t)
    gok = gather(cand_ok.astype(jnp.bool_)) & live[:, :, None, None]
    gid = cand_id.astype(jnp.int32).reshape(nCb, bc)[safe]  # [nRb, K, bc]

    eps = jnp.stack([jnp.asarray(eps_sp, jnp.float32),
                     jnp.asarray(eps_t, jnp.float32)])

    grid = (nRb, K, Mc // bm)
    ref_spec = pl.BlockSpec((bp,), lambda i, s, k: (i,))
    cand_spec = pl.BlockSpec((1, 1, bc, bm), lambda i, s, k: (i, s, 0, k))
    cid_spec = pl.BlockSpec((1, 1, bc), lambda i, s, k: (i, s, 0))
    eps_spec = pl.BlockSpec((2,), lambda i, s, k: (0,))
    out_spec = pl.BlockSpec((1, 1, bp, bc), lambda i, s, k: (i, s, 0, 0))

    tw, tidx = pl.pallas_call(
        _pruned_kernel,
        grid=grid,
        in_specs=[ref_spec] * 5 + [cand_spec] * 3 + [cid_spec, cand_spec,
                                                     eps_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nRb, K, bp, bc), jnp.float32),
            jax.ShapeDtypeStruct((nRb, K, bp, bc), jnp.int32),
        ],
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), gx, gy, gt, gid, gok, eps)

    # scatter surviving tiles back to the dense [P, C] layout; each (i, j)
    # appears at most once in a row of tile_ids, so .set is exact.
    col = jnp.where(live, safe, nCb)                        # dummy col nCb
    rows = jnp.arange(nRb, dtype=jnp.int32)[:, None]
    w = jnp.zeros((nRb, nCb + 1, bp, bc), jnp.float32)
    idx = jnp.full((nRb, nCb + 1, bp, bc), -1, jnp.int32)
    w = w.at[rows, col].set(tw, mode="drop")
    idx = idx.at[rows, col].set(tidx, mode="drop")
    w = w[:, :nCb].transpose(0, 2, 1, 3).reshape(P, C)
    idx = idx[:, :nCb].transpose(0, 2, 1, 3).reshape(P, C)
    return w, idx


@functools.partial(
    jax.jit,
    static_argnames=("bp", "bc", "bm", "interpret"))
def stjoin_pallas(ref_x, ref_y, ref_t, ref_id, ref_ok,
                  cand_x, cand_y, cand_t, cand_id, cand_ok,
                  eps_sp, eps_t, *, bp: int = 256, bc: int = 8,
                  bm: int = 128, interpret: bool = True):
    """Returns (best_w[P, C] f32, best_idx[P, C] i32)."""
    P = ref_x.shape[0]
    C, Mc = cand_x.shape
    assert P % bp == 0 and C % bc == 0 and Mc % bm == 0, (P, C, Mc, bp, bc, bm)

    eps = jnp.stack([jnp.asarray(eps_sp, jnp.float32),
                     jnp.asarray(eps_t, jnp.float32)])

    grid = (P // bp, C // bc, Mc // bm)
    ref_spec = pl.BlockSpec((bp,), lambda i, j, k: (i,))
    cand_spec = pl.BlockSpec((bc, bm), lambda i, j, k: (j, k))
    cid_spec = pl.BlockSpec((bc,), lambda i, j, k: (j,))
    eps_spec = pl.BlockSpec((2,), lambda i, j, k: (0,))
    out_spec = pl.BlockSpec((bp, bc), lambda i, j, k: (i, j))

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[ref_spec] * 5 + [cand_spec] * 3 + [cid_spec, cand_spec,
                                                     eps_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((P, C), jnp.float32),
            jax.ShapeDtypeStruct((P, C), jnp.int32),
        ],
        interpret=interpret,
    )(ref_x, ref_y, ref_t, ref_id.astype(jnp.int32),
      ref_ok.astype(jnp.bool_), cand_x, cand_y, cand_t,
      cand_id.astype(jnp.int32), cand_ok.astype(jnp.bool_), eps)
