"""Pure-jnp oracle for the stjoin kernel (same flattened contract)."""
from __future__ import annotations

import jax.numpy as jnp


def stjoin_ref(ref_x, ref_y, ref_t, ref_id, ref_ok,
               cand_x, cand_y, cand_t, cand_id, cand_ok,
               eps_sp, eps_t, *, pair_mask=None):
    """Returns (best_w[P, C] f32, best_idx[P, C] i32).

    ``pair_mask``: optional [P, C] bool candidate-pruning mask from the
    spatiotemporal index; a conservative mask leaves the output unchanged.
    """
    dx = ref_x[:, None, None] - cand_x[None, :, :]
    dy = ref_y[:, None, None] - cand_y[None, :, :]
    dt = jnp.abs(ref_t[:, None, None] - cand_t[None, :, :])
    d2 = dx * dx + dy * dy
    ok = (d2 <= eps_sp * eps_sp) & (dt <= eps_t)
    ok &= ref_ok[:, None, None] & cand_ok[None, :, :]
    ok &= ref_id[:, None, None] != cand_id[None, :, None]
    if pair_mask is not None:
        ok &= pair_mask[:, :, None]
    w = jnp.where(ok, 1.0 - jnp.sqrt(d2) / eps_sp, -1.0)
    best_w = jnp.max(w, axis=-1)
    best_idx = jnp.where(best_w > 0.0,
                         jnp.argmax(w, axis=-1).astype(jnp.int32), -1)
    return jnp.maximum(best_w, 0.0), best_idx
