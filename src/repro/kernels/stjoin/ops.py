"""Public jit'd wrapper: TrajectoryBatch-level subtrajectory join via Pallas."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.geometry import filter_delta_t
from repro.core.types import JoinResult, TrajectoryBatch
from repro.kernels import default_interpret
from repro.kernels.stjoin.stjoin import stjoin_pallas


def _pad_to(x: jnp.ndarray, mult: int, axis: int, fill):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("bp", "bc", "bm", "interpret"))
def best_match_join_kernel(ref: TrajectoryBatch, cand: TrajectoryBatch,
                           eps_sp, eps_t, *, bp=256, bc=8, bm=128,
                           interpret: bool | None = None) -> JoinResult:
    if interpret is None:
        interpret = default_interpret()
    T, M = ref.x.shape
    C, Mc = cand.x.shape

    rx = _pad_to(ref.x.reshape(-1), bp, 0, 0.0)
    ry = _pad_to(ref.y.reshape(-1), bp, 0, 0.0)
    rt = _pad_to(ref.t.reshape(-1), bp, 0, 0.0)
    rok = _pad_to(ref.valid.reshape(-1), bp, 0, False)
    rid = _pad_to(
        jnp.broadcast_to(ref.traj_id[:, None], (T, M)).reshape(-1), bp, 0, -1)

    cx = _pad_to(_pad_to(cand.x, bm, 1, 0.0), bc, 0, 0.0)
    cy = _pad_to(_pad_to(cand.y, bm, 1, 0.0), bc, 0, 0.0)
    ct = _pad_to(_pad_to(cand.t, bm, 1, 0.0), bc, 0, 0.0)
    cok = _pad_to(_pad_to(cand.valid, bm, 1, False), bc, 0, False)
    cid = _pad_to(cand.traj_id, bc, 0, -2)

    w, idx = stjoin_pallas(rx, ry, rt, rid, rok, cx, cy, ct, cid, cok,
                           eps_sp, eps_t, bp=bp, bc=bc, bm=bm,
                           interpret=interpret)
    w = w[:T * M, :C].reshape(T, M, C)
    idx = idx[:T * M, :C].reshape(T, M, C)
    return JoinResult(best_w=w, best_idx=idx)


def subtrajectory_join(ref: TrajectoryBatch, cand: TrajectoryBatch,
                       eps_sp, eps_t, delta_t=0.0, **kw) -> JoinResult:
    """Kernel-backed Problem 1 (join + delta_t refine)."""
    j = best_match_join_kernel(ref, cand, eps_sp, eps_t, **kw)
    dt = jnp.asarray(delta_t, jnp.float32)
    return jax.lax.cond(
        dt > 0.0, lambda jj: filter_delta_t(jj, ref.t, dt), lambda jj: jj, j)
