"""Public jit'd wrapper: TrajectoryBatch-level subtrajectory join via Pallas.

Two entry points:

* ``best_match_join_kernel``  — the dense join: every (ref block, cand
  block) tile is visited.  Fallback and parity oracle.
* ``best_match_join_pruned``  — index-accelerated join: a spatiotemporal
  grid over tile bounding boxes (``repro.index.grid``) first emits, per
  reference block, the compacted list of candidate tiles that can contain
  a match; only those tiles enter the Pallas kernel.  Output is
  bit-identical to the dense join (pruning is conservative).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.geometry import filter_delta_t
from repro.core.types import JoinResult, TrajectoryBatch
from repro.index import grid as gridx
from repro.kernels import default_interpret
from repro.kernels.stjoin.stjoin import stjoin_pallas, stjoin_pallas_pruned


def _pad_to(x: jnp.ndarray, mult: int, axis: int, fill):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("bp", "bc", "bm", "interpret"))
def best_match_join_kernel(ref: TrajectoryBatch, cand: TrajectoryBatch,
                           eps_sp, eps_t, *, bp=256, bc=8, bm=128,
                           interpret: bool | None = None) -> JoinResult:
    if interpret is None:
        interpret = default_interpret()
    T, M = ref.x.shape
    C, Mc = cand.x.shape

    rx = _pad_to(ref.x.reshape(-1), bp, 0, 0.0)
    ry = _pad_to(ref.y.reshape(-1), bp, 0, 0.0)
    rt = _pad_to(ref.t.reshape(-1), bp, 0, 0.0)
    rok = _pad_to(ref.valid.reshape(-1), bp, 0, False)
    rid = _pad_to(
        jnp.broadcast_to(ref.traj_id[:, None], (T, M)).reshape(-1), bp, 0, -1)

    cx = _pad_to(_pad_to(cand.x, bm, 1, 0.0), bc, 0, 0.0)
    cy = _pad_to(_pad_to(cand.y, bm, 1, 0.0), bc, 0, 0.0)
    ct = _pad_to(_pad_to(cand.t, bm, 1, 0.0), bc, 0, 0.0)
    cok = _pad_to(_pad_to(cand.valid, bm, 1, False), bc, 0, False)
    cid = _pad_to(cand.traj_id, bc, 0, -2)

    w, idx = stjoin_pallas(rx, ry, rt, rid, rok, cx, cy, ct, cid, cok,
                           eps_sp, eps_t, bp=bp, bc=bc, bm=bm,
                           interpret=interpret)
    w = w[:T * M, :C].reshape(T, M, C)
    idx = idx[:T * M, :C].reshape(T, M, C)
    return JoinResult(best_w=w, best_idx=idx)


def _padded_operands(ref: TrajectoryBatch, cand: TrajectoryBatch,
                     bp: int, bc: int, bm: int):
    """The dense wrapper's padding, shared with the pruned path."""
    T, M = ref.x.shape
    rx = _pad_to(ref.x.reshape(-1), bp, 0, 0.0)
    ry = _pad_to(ref.y.reshape(-1), bp, 0, 0.0)
    rt = _pad_to(ref.t.reshape(-1), bp, 0, 0.0)
    rok = _pad_to(ref.valid.reshape(-1), bp, 0, False)
    rid = _pad_to(
        jnp.broadcast_to(ref.traj_id[:, None], (T, M)).reshape(-1), bp, 0, -1)

    cx = _pad_to(_pad_to(cand.x, bm, 1, 0.0), bc, 0, 0.0)
    cy = _pad_to(_pad_to(cand.y, bm, 1, 0.0), bc, 0, 0.0)
    ct = _pad_to(_pad_to(cand.t, bm, 1, 0.0), bc, 0, 0.0)
    cok = _pad_to(_pad_to(cand.valid, bm, 1, False), bc, 0, False)
    cid = _pad_to(cand.traj_id, bc, 0, -2)
    return (rx, ry, rt, rid, rok), (cx, cy, ct, cid, cok)


def plan_join_index(ref: TrajectoryBatch, cand: TrajectoryBatch,
                    eps_sp, eps_t, *, bp=256, bc=8, use_cells: bool = True):
    """Candidate-tile mask + per-ref-block survivor counts.

    Host-driven (not jitted as a whole: the grid geometry is fitted from
    the concrete data, and baking it in as a static jit argument would
    retrace on every new batch).  The array math inside is plain jnp.
    Returns ``(mask [nRb, nCb] bool, counts [nRb] i32, spec | None)``.
    """
    (rx, ry, rt, _, rok), (cx, cy, ct, _, cok) = _padded_operands(
        ref, cand, bp, bc, 1)
    rboxes = gridx.point_block_boxes(rx, ry, rt, rok, bp)
    cboxes = gridx.traj_block_boxes(cx, cy, ct, cok, bc)
    spec = None
    if use_cells:
        spec = gridx.fit_grid(cboxes, float(eps_sp), float(eps_t))
        table = gridx.build_cell_table(spec, cboxes)
        mask = gridx.candidate_tile_mask(
            spec, table, rboxes, cboxes, eps_sp, eps_t)
    else:
        mask = gridx.exact_pair_mask(rboxes, cboxes, eps_sp, eps_t)
    counts = jnp.sum(mask, axis=1).astype(jnp.int32)
    return mask, counts, spec


def best_match_join_pruned(ref: TrajectoryBatch, cand: TrajectoryBatch,
                           eps_sp, eps_t, *, bp=256, bc=8, bm=128,
                           max_tiles: int | None = None,
                           use_cells: bool = True,
                           interpret: bool | None = None,
                           return_stats: bool = False):
    """Index-pruned best-match join; bit-identical to the dense kernel.

    Host-driven planning (concrete inputs required): fits the eps-derived
    grid, compacts the surviving candidate-tile lists to a static width
    ``K`` (``max_tiles`` or the observed maximum), then runs the sparse
    Pallas kernel over only those tiles.  Raises if ``max_tiles`` is too
    small to keep every survivor, since dropping one would break parity.
    """
    if interpret is None:
        interpret = default_interpret()
    T, M = ref.x.shape
    C, _ = cand.x.shape

    # planning pass: bboxes only, bm-independent
    mask, counts, _ = plan_join_index(
        ref, cand, eps_sp, eps_t, bp=bp, bc=bc, use_cells=use_cells)

    need = gridx.plan_max_tiles(counts)
    K = max_tiles if max_tiles is not None else need
    if int(np.max(np.asarray(counts), initial=0)) > K:
        raise ValueError(
            f"max_tiles={K} drops candidate tiles (need {need}); "
            "the pruned join would no longer match the dense join")
    tile_ids, counts = gridx.compact_candidates(mask, K)

    (rx, ry, rt, rid, rok), (cx, cy, ct, cid, cok) = _padded_operands(
        ref, cand, bp, bc, bm)
    w, idx = stjoin_pallas_pruned(
        rx, ry, rt, rid, rok, cx, cy, ct, cid, cok, tile_ids,
        eps_sp, eps_t, bp=bp, bc=bc, bm=bm, interpret=interpret)
    out = JoinResult(best_w=w[:T * M, :C].reshape(T, M, C),
                     best_idx=idx[:T * M, :C].reshape(T, M, C))
    if return_stats:
        return out, gridx.prune_stats(counts, mask.shape[1])
    return out


def subtrajectory_join(ref: TrajectoryBatch, cand: TrajectoryBatch,
                       eps_sp, eps_t, delta_t=0.0, *, use_index: bool = False,
                       **kw) -> JoinResult:
    """Kernel-backed Problem 1 (join + delta_t refine).

    ``use_index=True`` routes through the grid-pruned kernel (requires
    concrete inputs for the host-side planning pass); output is identical.
    """
    if use_index:
        j = best_match_join_pruned(ref, cand, eps_sp, eps_t, **kw)
    else:
        j = best_match_join_kernel(ref, cand, eps_sp, eps_t, **kw)
    dt = jnp.asarray(delta_t, jnp.float32)
    return jax.lax.cond(
        dt > 0.0, lambda jj: filter_delta_t(jj, ref.t, dt), lambda jj: jj, j)
