"""Public jit'd wrapper: TrajectoryBatch-level subtrajectory join via Pallas.

Two entry points:

* ``best_match_join_kernel``  — the dense join: every (ref block, cand
  block) tile is visited.  Fallback and parity oracle.
* ``best_match_join_pruned``  — index-accelerated join: a spatiotemporal
  grid over tile bounding boxes (``repro.index.grid``) first emits, per
  reference block, the compacted list of candidate tiles that can contain
  a match; only those tiles enter the Pallas kernel.  Output is
  bit-identical to the dense join (pruning is conservative).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.geometry import filter_delta_t
from repro.core.types import JoinResult, TrajectoryBatch
from repro.index import grid as gridx
from repro.kernels import default_interpret
from repro.kernels.stjoin.stjoin import (
    stjoin_pallas,
    stjoin_pallas_pruned,
    stjoin_sim_fused_flat,
    stjoin_sim_fused_pruned_flat,
    stjoin_sim_panel_fused_flat,
    stjoin_sim_panel_fused_pruned_flat,
    stjoin_vote_fused_flat,
    stjoin_vote_fused_pruned_flat,
)


def _pad_to(x: jnp.ndarray, mult: int, axis: int, fill):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("bp", "bc", "bm", "interpret"))
def best_match_join_kernel(ref: TrajectoryBatch, cand: TrajectoryBatch,
                           eps_sp, eps_t, *, bp=256, bc=8, bm=128,
                           interpret: bool | None = None) -> JoinResult:
    if interpret is None:
        interpret = default_interpret()
    T, M = ref.x.shape
    C, Mc = cand.x.shape

    rx = _pad_to(ref.x.reshape(-1), bp, 0, 0.0)
    ry = _pad_to(ref.y.reshape(-1), bp, 0, 0.0)
    rt = _pad_to(ref.t.reshape(-1), bp, 0, 0.0)
    rok = _pad_to(ref.valid.reshape(-1), bp, 0, False)
    rid = _pad_to(
        jnp.broadcast_to(ref.traj_id[:, None], (T, M)).reshape(-1), bp, 0, -1)

    cx = _pad_to(_pad_to(cand.x, bm, 1, 0.0), bc, 0, 0.0)
    cy = _pad_to(_pad_to(cand.y, bm, 1, 0.0), bc, 0, 0.0)
    ct = _pad_to(_pad_to(cand.t, bm, 1, 0.0), bc, 0, 0.0)
    cok = _pad_to(_pad_to(cand.valid, bm, 1, False), bc, 0, False)
    cid = _pad_to(cand.traj_id, bc, 0, -2)

    w, idx = stjoin_pallas(rx, ry, rt, rid, rok, cx, cy, ct, cid, cok,
                           eps_sp, eps_t, bp=bp, bc=bc, bm=bm,
                           interpret=interpret)
    w = w[:T * M, :C].reshape(T, M, C)
    idx = idx[:T * M, :C].reshape(T, M, C)
    return JoinResult(best_w=w, best_idx=idx)


def _padded_operands(ref: TrajectoryBatch, cand: TrajectoryBatch,
                     bp: int, bc: int, bm: int):
    """The dense wrapper's padding, shared with the pruned path."""
    T, M = ref.x.shape
    rx = _pad_to(ref.x.reshape(-1), bp, 0, 0.0)
    ry = _pad_to(ref.y.reshape(-1), bp, 0, 0.0)
    rt = _pad_to(ref.t.reshape(-1), bp, 0, 0.0)
    rok = _pad_to(ref.valid.reshape(-1), bp, 0, False)
    rid = _pad_to(
        jnp.broadcast_to(ref.traj_id[:, None], (T, M)).reshape(-1), bp, 0, -1)

    cx = _pad_to(_pad_to(cand.x, bm, 1, 0.0), bc, 0, 0.0)
    cy = _pad_to(_pad_to(cand.y, bm, 1, 0.0), bc, 0, 0.0)
    ct = _pad_to(_pad_to(cand.t, bm, 1, 0.0), bc, 0, 0.0)
    cok = _pad_to(_pad_to(cand.valid, bm, 1, False), bc, 0, False)
    cid = _pad_to(cand.traj_id, bc, 0, -2)
    return (rx, ry, rt, rid, rok), (cx, cy, ct, cid, cok)


def plan_join_index(ref: TrajectoryBatch, cand: TrajectoryBatch,
                    eps_sp, eps_t, *, bp=256, bc=8, use_cells: bool = True):
    """Candidate-tile mask + per-ref-block survivor counts.

    Host-driven (not jitted as a whole: the grid geometry is fitted from
    the concrete data, and baking it in as a static jit argument would
    retrace on every new batch).  The array math inside is plain jnp.
    Returns ``(mask [nRb, nCb] bool, counts [nRb] i32, spec | None)``.
    """
    (rx, ry, rt, _, rok), (cx, cy, ct, _, cok) = _padded_operands(
        ref, cand, bp, bc, 1)
    rboxes = gridx.point_block_boxes(rx, ry, rt, rok, bp)
    cboxes = gridx.traj_block_boxes(cx, cy, ct, cok, bc)
    spec = None
    if use_cells:
        spec = gridx.fit_grid(cboxes, float(eps_sp), float(eps_t))
        table = gridx.build_cell_table(spec, cboxes)
        mask = gridx.candidate_tile_mask(
            spec, table, rboxes, cboxes, eps_sp, eps_t)
    else:
        mask = gridx.exact_pair_mask(rboxes, cboxes, eps_sp, eps_t)
    counts = jnp.sum(mask, axis=1).astype(jnp.int32)
    return mask, counts, spec


def best_match_join_pruned(ref: TrajectoryBatch, cand: TrajectoryBatch,
                           eps_sp, eps_t, *, bp=256, bc=8, bm=128,
                           max_tiles: int | None = None,
                           use_cells: bool = True,
                           interpret: bool | None = None,
                           return_stats: bool = False):
    """Index-pruned best-match join; bit-identical to the dense kernel.

    Host-driven planning (concrete inputs required): fits the eps-derived
    grid, compacts the surviving candidate-tile lists to a static width
    ``K`` (``max_tiles`` or the observed maximum), then runs the sparse
    Pallas kernel over only those tiles.  Raises if ``max_tiles`` is too
    small to keep every survivor, since dropping one would break parity.
    """
    if interpret is None:
        interpret = default_interpret()
    T, M = ref.x.shape
    C, _ = cand.x.shape

    # planning pass: bboxes only, bm-independent
    mask, counts, _ = plan_join_index(
        ref, cand, eps_sp, eps_t, bp=bp, bc=bc, use_cells=use_cells)

    need = gridx.plan_max_tiles(counts)
    K = max_tiles if max_tiles is not None else need
    if int(np.max(np.asarray(counts), initial=0)) > K:
        raise ValueError(
            f"max_tiles={K} drops candidate tiles (need {need}); "
            "the pruned join would no longer match the dense join")
    tile_ids, counts = gridx.compact_candidates(mask, K)

    (rx, ry, rt, rid, rok), (cx, cy, ct, cid, cok) = _padded_operands(
        ref, cand, bp, bc, bm)
    w, idx = stjoin_pallas_pruned(
        rx, ry, rt, rid, rok, cx, cy, ct, cid, cok, tile_ids,
        eps_sp, eps_t, bp=bp, bc=bc, bm=bm, interpret=interpret)
    out = JoinResult(best_w=w[:T * M, :C].reshape(T, M, C),
                     best_idx=idx[:T * M, :C].reshape(T, M, C))
    if return_stats:
        return out, gridx.prune_stats(counts, mask.shape[1])
    return out


# ---------------------------------------------------------------------------
# Fused streaming join (epilogue fusion): the [T, M, C] JoinResult cube is
# never materialized.  Pass 1 (``stjoin_vote_fused``) returns the per-point
# vote sums and the bit-packed TSA2 neighbor words; pass 2
# (``stjoin_sim_fused``) re-sweeps the same tiles after segmentation and
# scatter-adds refined weights straight into the raw similarity accumulator.
# Both accept an optional pre-computed ``tile_ids`` plan (``plan_fused_tiles``)
# to sweep only the index-surviving candidate tiles.
# ---------------------------------------------------------------------------


def _fused_geometry(T: int, M: int, Mc: int, rows: int | None, bc: int,
                    bm: int):
    """Resolve the fused kernels' tile geometry for raw [T, M]/[C, Mc] data.

    Ref blocks must hold whole trajectory rows (in-kernel delta_t refine),
    so the block is ``rows`` rows of ``M`` points; ``bc`` is clamped to a
    divisor of 32 (a candidate block must stay inside one packed word);
    ``bm`` is clamped to the candidate row length.  Defaults favor fat
    tiles (~2048 ref points per block, capped at the whole batch): the
    fused kernels write no per-tile output blocks, so fewer grid steps is
    pure win; the per-chunk working set ``[bp, bc, bm]`` stays VMEM-sized
    via the inner ``bm`` loop.
    """
    rows = rows if rows is not None else max(1, 2048 // max(M, 1))
    rows = min(rows, max(T, 1))
    bc = max(d for d in (1, 2, 4, 8, 16, 32) if d <= max(bc, 1))
    bm = min(bm, Mc)
    mc_pad = (-Mc) % bm
    return rows, bc, bm, mc_pad


def _fused_ref_operands(rx, ry, rt, rvalid, rid, rows: int):
    """Pad to whole ref blocks and flatten row-major (rows stay contiguous)."""
    T, M = rx.shape
    padT = (-T) % rows
    pad2 = lambda a, f: jnp.pad(a, ((0, padT), (0, 0)), constant_values=f)
    rid_full = jnp.broadcast_to(rid[:, None], (T, M))
    return (pad2(rx, 0.0).reshape(-1), pad2(ry, 0.0).reshape(-1),
            pad2(rt, 0.0).reshape(-1),
            pad2(rid_full.astype(jnp.int32), -1).reshape(-1),
            pad2(rvalid, False).reshape(-1))


def _fused_cand_operands(cx, cy, ct, cvalid, cid, bm: int, mc_pad: int):
    """Pad candidates to whole words (C -> multiple of 32) and bm chunks.

    Returned in kernel operand order: ``(x, y, t, id, ok)``.
    """
    C, _ = cx.shape
    padC = (-C) % 32
    pad = lambda a, f: jnp.pad(a, ((0, padC), (0, mc_pad)), constant_values=f)
    return (pad(cx, 0.0), pad(cy, 0.0), pad(ct, 0.0),
            jnp.pad(cid.astype(jnp.int32), (0, padC), constant_values=-2),
            pad(cvalid, False))


class FusedTilePlan(NamedTuple):
    """A candidate-tile plan bound to the geometry it was built for.

    ``tile_ids`` column values index candidate *blocks of ``bc`` rows*, so
    reusing a plan under a different geometry would silently mis-address
    candidates — the fused entry points therefore verify these fields
    against their own resolved geometry before sweeping.
    """

    tile_ids: jnp.ndarray     # [nRb, K] int32, -1 padded, ascending
    rows: int
    bc: int
    bm: int


def _resolve_plan(tile_ids, rows: int, bc: int, bm: int):
    """Unpack a FusedTilePlan (geometry-checked) or pass a raw array."""
    if tile_ids is None:
        return None
    if isinstance(tile_ids, FusedTilePlan):
        if (tile_ids.rows, tile_ids.bc, tile_ids.bm) != (rows, bc, bm):
            raise ValueError(
                f"tile plan was built for geometry rows={tile_ids.rows}, "
                f"bc={tile_ids.bc}, bm={tile_ids.bm} but the sweep resolved "
                f"rows={rows}, bc={bc}, bm={bm}; candidate blocks would be "
                "mis-addressed")
        return tile_ids.tile_ids
    return tile_ids


def plan_fused_tiles(rx, ry, rt, rvalid, cx, cy, ct, cvalid, eps_sp, eps_t,
                     *, rows: int | None = None, bc: int = 16, bm: int = 128,
                     use_cells: bool = True, max_tiles: int | None = None):
    """Host-driven candidate-tile plan for the fused kernels.

    Same two-stage conservative pruning as ``plan_join_index`` but on raw
    ``[T, M]`` / ``[C, Mc]`` arrays with the fused row-aligned block
    geometry.  Returns a ``FusedTilePlan`` (tile ids -1 padded, ascending,
    plus the resolved geometry) ready for the ``*_pruned`` fused entry
    points, which reject a plan whose geometry differs from their own.
    Raises if ``max_tiles`` would drop a survivor.

    Geometry knobs (``rows``, ``bc``, ``bm``) are the fused tile plan of
    ``EnginePlan.fused_tiles`` (DESIGN.md §9): ``rows`` reference-trajectory
    rows per block (``None`` = the fat-tile default ``max(1, 2048 // M)``),
    ``bc`` candidate trajectories per block, ``bm`` candidate points per
    chunk.  Pruning quality depends on them — smaller blocks give the grid
    tighter boxes to reject, larger blocks amortize sweep overhead — which
    is why the dispatcher re-binds the *resolved* geometry into the plan
    before tracing: the sweep must run the exact tiling the tile ids were
    built for.  The autotuner (``repro.tune.autotune.tune_join``) sweeps
    this lattice rather than guessing.
    """
    M = rx.shape[1]
    rows, bc, bm, mc_pad = _fused_geometry(
        rx.shape[0], M, cx.shape[1], rows, bc, bm)
    bp = rows * M
    frx, fry, frt, _, frok = _fused_ref_operands(
        rx, ry, rt, rvalid, jnp.zeros((rx.shape[0],), jnp.int32), rows)
    fcx, fcy, fct, _, fcok = _fused_cand_operands(
        cx, cy, ct, cvalid, jnp.zeros((cx.shape[0],), jnp.int32), bm, mc_pad)

    rboxes = gridx.point_block_boxes(frx, fry, frt, frok, bp)
    cboxes = gridx.traj_block_boxes(fcx, fcy, fct, fcok, bc)
    if use_cells:
        spec = gridx.fit_grid(cboxes, float(eps_sp), float(eps_t))
        table = gridx.build_cell_table(spec, cboxes)
        mask = gridx.candidate_tile_mask(
            spec, table, rboxes, cboxes, eps_sp, eps_t)
    else:
        mask = gridx.exact_pair_mask(rboxes, cboxes, eps_sp, eps_t)
    counts = jnp.sum(mask, axis=1).astype(jnp.int32)
    need = gridx.plan_max_tiles(counts)
    # K >= 1 even when nothing survives: a zero-width slot axis would give
    # the pruned kernels an empty grid and leave their accumulators
    # uninitialized
    K = max(max_tiles, 1) if max_tiles is not None else need
    if int(np.max(np.asarray(counts), initial=0)) > K:
        raise ValueError(
            f"max_tiles={K} drops candidate tiles (need {need}); "
            "the fused pruned sweep would no longer match the dense sweep")
    tile_ids, _ = gridx.compact_candidates(mask, K)
    return FusedTilePlan(tile_ids=tile_ids, rows=rows, bc=bc, bm=bm)


def stjoin_vote_fused_arrays(rx, ry, rt, rvalid, rid, cx, cy, ct, cvalid,
                             cid, eps_sp, eps_t, delta_t=0.0, *,
                             rows: int | None = None, bc: int = 16,
                             bm: int = 128, tile_ids=None,
                             with_masks: bool = True,
                             interpret: bool | None = None):
    """Fused pass 1 on raw arrays: ``(vote [T, M], words [T, M, ceil(C/32)])``.

    Subsumes ``voting.point_voting`` and ``voting.neighbor_mask_packed``
    over a delta_t-refined join without materializing it.  ``tile_ids``
    (from ``plan_fused_tiles`` with identical geometry) switches to the
    index-pruned sweep; identical output either way.  ``with_masks=False``
    (segmentation won't consume neighbor sets, i.e. TSA1) returns
    ``(vote, None)`` and skips the packed-word accumulator entirely.
    """
    if interpret is None:
        interpret = default_interpret()
    T, M = rx.shape
    C, Mc = cx.shape
    rows, bc, bm, mc_pad = _fused_geometry(T, M, Mc, rows, bc, bm)
    tile_ids = _resolve_plan(tile_ids, rows, bc, bm)
    ref_ops = _fused_ref_operands(rx, ry, rt, rvalid, rid, rows)
    cand_ops = _fused_cand_operands(cx, cy, ct, cvalid, cid, bm, mc_pad)

    if tile_ids is None:
        vote, words = stjoin_vote_fused_flat(
            *ref_ops, *cand_ops, eps_sp, eps_t, delta_t, rows=rows, M=M,
            bc=bc, bm=bm, with_words=with_masks, interpret=interpret)
    else:
        vote, words = stjoin_vote_fused_pruned_flat(
            *ref_ops, *cand_ops, tile_ids, eps_sp, eps_t, delta_t,
            rows=rows, M=M, bc=bc, bm=bm, with_words=with_masks,
            interpret=interpret)
    vote = vote[:T * M].reshape(T, M)
    if words is None:
        return vote, None
    W = -(-C // 32)
    return vote, words[:T * M].reshape(T, M, -1)[:, :, :W]


def stjoin_vote_fused(ref: TrajectoryBatch, cand: TrajectoryBatch,
                      eps_sp, eps_t, delta_t=0.0, *, use_index: bool = False,
                      use_cells: bool = True, max_tiles: int | None = None,
                      rows: int | None = None, bc: int = 16, bm: int = 128,
                      with_masks: bool = True,
                      interpret: bool | None = None):
    """Batch-level fused pass 1 (vote sums + packed neighbor words)."""
    tile_ids = None
    if use_index:
        tile_ids = plan_fused_tiles(
            ref.x, ref.y, ref.t, ref.valid, cand.x, cand.y, cand.t,
            cand.valid, eps_sp, eps_t, rows=rows, bc=bc, bm=bm,
            use_cells=use_cells, max_tiles=max_tiles)
    return stjoin_vote_fused_arrays(
        ref.x, ref.y, ref.t, ref.valid, ref.traj_id, cand.x, cand.y,
        cand.t, cand.valid, cand.traj_id, eps_sp, eps_t, delta_t,
        rows=rows, bc=bc, bm=bm, tile_ids=tile_ids,
        with_masks=with_masks, interpret=interpret)


def stjoin_sim_fused_arrays(rx, ry, rt, rvalid, rid, ref_gid, cx, cy, ct,
                            cvalid, cid, cand_gid, n_src: int, n_dst: int,
                            eps_sp, eps_t, delta_t=0.0, *,
                            rows: int | None = None, bc: int = 16,
                            bm: int = 128, tile_ids=None,
                            interpret: bool | None = None):
    """Fused pass 2 on raw arrays: raw similarity scatter ``[n_src, n_dst]``.

    ``ref_gid [T, M]``: destination row of each ref point (``n_src`` =
    sentinel).  ``cand_gid [C, Mc]``: destination column of each candidate
    point (``n_dst`` = sentinel).  Subsumes the materializing
    ``similarity_matrix`` gather/scatter over T*M*C elements; normalization
    is left to ``similarity.finalize_sim`` so both paths share the math.
    """
    if interpret is None:
        interpret = default_interpret()
    T, M = rx.shape
    C, Mc = cx.shape
    rows, bc, bm, mc_pad = _fused_geometry(T, M, Mc, rows, bc, bm)
    tile_ids = _resolve_plan(tile_ids, rows, bc, bm)
    ref_ops = _fused_ref_operands(rx, ry, rt, rvalid, rid, rows)
    padT = (-T) % rows
    gid_flat = jnp.pad(ref_gid.astype(jnp.int32), ((0, padT), (0, 0)),
                       constant_values=n_src).reshape(-1)
    cand_ops = _fused_cand_operands(cx, cy, ct, cvalid, cid, bm, mc_pad)
    padC = (-C) % 32
    cgid = jnp.pad(cand_gid.astype(jnp.int32), ((0, padC), (0, mc_pad)),
                   constant_values=n_dst)

    if tile_ids is None:
        return stjoin_sim_fused_flat(
            *ref_ops, gid_flat, *cand_ops, cgid, eps_sp, eps_t, delta_t,
            rows=rows, M=M, n_src=n_src, n_dst=n_dst, bc=bc, bm=bm,
            interpret=interpret)
    return stjoin_sim_fused_pruned_flat(
        *ref_ops, gid_flat, *cand_ops, cgid, tile_ids, eps_sp, eps_t,
        delta_t, rows=rows, M=M, n_src=n_src, n_dst=n_dst, bc=bc, bm=bm,
        interpret=interpret)


def stjoin_sim_fused(ref: TrajectoryBatch, cand: TrajectoryBatch,
                     ref_sub_local, cand_sub_local, max_subs: int,
                     eps_sp, eps_t, delta_t=0.0, *, tile_ids=None,
                     rows: int | None = None, bc: int = 16, bm: int = 128,
                     interpret: bool | None = None):
    """Batch-level fused pass 2: un-normalized ``raw [S_ref, S_cand]``.

    Slot maps mirror ``similarity_matrix``: ref point (r, m) scatters into
    row ``r * max_subs + sub_local[r, m]``; the matched candidate point
    (c, best_idx) into column ``c * max_subs + cand_sub_local[c, idx]``.
    """
    T, M = ref.x.shape
    C, Mc = cand.x.shape
    n_src = T * max_subs
    n_dst = C * max_subs
    ref_gid = jnp.where(
        ref_sub_local >= 0,
        jnp.arange(T, dtype=jnp.int32)[:, None] * max_subs
        + ref_sub_local, n_src)
    cand_gid = jnp.where(
        cand_sub_local >= 0,
        jnp.arange(C, dtype=jnp.int32)[:, None] * max_subs
        + cand_sub_local, n_dst)
    return stjoin_sim_fused_arrays(
        ref.x, ref.y, ref.t, ref.valid, ref.traj_id, ref_gid,
        cand.x, cand.y, cand.t, cand.valid, cand.traj_id, cand_gid,
        n_src, n_dst, eps_sp, eps_t, delta_t, rows=rows, bc=bc, bm=bm,
        tile_ids=tile_ids, interpret=interpret)


def stjoin_sim_panel_fused_arrays(rx, ry, rt, rvalid, rid, ref_gid, cx, cy,
                                  ct, cvalid, cid, cand_gid, n_src: int,
                                  n_dst: int, eps_sp, eps_t, delta_t, p0,
                                  *, panel: int, tile_ids=None,
                                  rows: int | None = None,
                                  bc: int = 16, bm: int = 128,
                                  interpret: bool | None = None):
    """Fused pass 2 on raw arrays, panel-streamed: one ``Sb``-row panel of
    the raw similarity scatter in both orientations.

    Returns ``(fwd [panel, n_dst], rev [panel, n_src])`` where
    ``fwd[i, j] = raw[p0 + i, j]`` and ``rev[i, j] = raw[j, p0 + i]`` of
    the dense accumulator ``stjoin_sim_fused_arrays`` would build —
    bit-equal cell sums, panel rows only.  ``p0`` may be traced (the
    panel loop re-invokes one trace); ``panel`` is static.  ``tile_ids``
    (from ``plan_fused_tiles`` with identical geometry) sweeps only the
    index-surviving candidate tiles per panel; identical output either
    way (pruned tiles contribute exactly 0).
    """
    if interpret is None:
        interpret = default_interpret()
    T, M = rx.shape
    C, Mc = cx.shape
    rows, bc, bm, mc_pad = _fused_geometry(T, M, Mc, rows, bc, bm)
    tile_ids = _resolve_plan(tile_ids, rows, bc, bm)
    ref_ops = _fused_ref_operands(rx, ry, rt, rvalid, rid, rows)
    padT = (-T) % rows
    gid_flat = jnp.pad(ref_gid.astype(jnp.int32), ((0, padT), (0, 0)),
                       constant_values=n_src).reshape(-1)
    cand_ops = _fused_cand_operands(cx, cy, ct, cvalid, cid, bm, mc_pad)
    padC = (-C) % 32
    cgid = jnp.pad(cand_gid.astype(jnp.int32), ((0, padC), (0, mc_pad)),
                   constant_values=n_dst)

    p0 = jnp.asarray(p0, jnp.int32)
    lgid = jnp.where((gid_flat >= p0) & (gid_flat < p0 + panel),
                     gid_flat - p0, panel)
    clgid = jnp.where((cgid >= p0) & (cgid < p0 + panel), cgid - p0, panel)

    if tile_ids is None:
        return stjoin_sim_panel_fused_flat(
            *ref_ops, gid_flat, lgid, *cand_ops, cgid, clgid, eps_sp,
            eps_t, delta_t, rows=rows, M=M, n_src=n_src, n_dst=n_dst,
            panel=panel, bc=bc, bm=bm, interpret=interpret)
    return stjoin_sim_panel_fused_pruned_flat(
        *ref_ops, gid_flat, lgid, *cand_ops, cgid, clgid, tile_ids,
        eps_sp, eps_t, delta_t, rows=rows, M=M, n_src=n_src, n_dst=n_dst,
        panel=panel, bc=bc, bm=bm, interpret=interpret)


def stjoin_sim_panel_fused(ref: TrajectoryBatch, cand: TrajectoryBatch,
                           ref_sub_local, cand_sub_local, max_subs: int,
                           eps_sp, eps_t, delta_t=0.0, *, p0, panel: int,
                           tile_ids=None, rows: int | None = None,
                           bc: int = 16, bm: int = 128,
                           interpret: bool | None = None):
    """Batch-level panel-streamed fused pass 2 (cf. ``stjoin_sim_fused``).

    Slot maps mirror ``similarity_matrix``; the returned orientations feed
    ``repro.core.similarity.topk_stream``'s panel finalization.
    """
    T, M = ref.x.shape
    C, Mc = cand.x.shape
    n_src = T * max_subs
    n_dst = C * max_subs
    ref_gid = jnp.where(
        ref_sub_local >= 0,
        jnp.arange(T, dtype=jnp.int32)[:, None] * max_subs
        + ref_sub_local, n_src)
    cand_gid = jnp.where(
        cand_sub_local >= 0,
        jnp.arange(C, dtype=jnp.int32)[:, None] * max_subs
        + cand_sub_local, n_dst)
    return stjoin_sim_panel_fused_arrays(
        ref.x, ref.y, ref.t, ref.valid, ref.traj_id, ref_gid,
        cand.x, cand.y, cand.t, cand.valid, cand.traj_id, cand_gid,
        n_src, n_dst, eps_sp, eps_t, delta_t, p0, panel=panel,
        tile_ids=tile_ids, rows=rows, bc=bc, bm=bm, interpret=interpret)


def subtrajectory_join(ref: TrajectoryBatch, cand: TrajectoryBatch,
                       eps_sp, eps_t, delta_t=0.0, *, use_index: bool = False,
                       **kw) -> JoinResult:
    """Kernel-backed Problem 1 (join + delta_t refine).

    ``use_index=True`` routes through the grid-pruned kernel (requires
    concrete inputs for the host-side planning pass); output is identical.
    """
    if use_index:
        j = best_match_join_pruned(ref, cand, eps_sp, eps_t, **kw)
    else:
        j = best_match_join_kernel(ref, cand, eps_sp, eps_t, **kw)
    dt = jnp.asarray(delta_t, jnp.float32)
    return jax.lax.cond(
        dt > 0.0, lambda jj: filter_delta_t(jj, ref.t, dt), lambda jj: jj, j)
