"""Public wrapper for the flash attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.attention.flash import flash_attention_fwd

HUGE = 1 << 30


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def flash_attention(q, k, v, q_positions, kv_positions, *, window=None,
                    prefix=None, max_kv=None, softcap=None,
                    interpret=None):
    if interpret is None:
        interpret = default_interpret()
    window = HUGE if window is None else window
    prefix = 0 if prefix is None else prefix
    max_kv = HUGE if max_kv is None else max_kv
    return flash_attention_fwd(
        q, k, v, q_positions, kv_positions, window, prefix, max_kv,
        softcap=softcap, interpret=interpret)
