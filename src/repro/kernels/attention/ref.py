"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, q_positions, kv_positions, window, prefix,
                        max_kv, softcap=None):
    """q: [B, Lq, KV, G, hd]; k/v: [B, M, KV, hd] -> [B, Lq, KV, G, hd]."""
    s = jnp.einsum("blkgh,bmkh->blkgm", q, k).astype(jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp, kp = q_positions, kv_positions
    causal = kp[None, :] <= qp[:, None]
    causal &= kp[None, :] > (qp[:, None] - window)
    bidir = (kp[None, :] < prefix) & (qp[:, None] < prefix)
    ok = (causal | bidir) & (kp[None, :] <= max_kv)
    s = jnp.where(ok[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("blkgm,bmkh->blkgh", p.astype(q.dtype), v)
