"""Pallas TPU kernel: fused (flash) GQA attention forward.

Motivation (EXPERIMENTS.md §Perf, smollm hillclimb): the XLA online-softmax
path keeps numerics right and peak memory low, but its score / probability
blocks still cross HBM between the two dots — for [B,L,H] = [1, 4096, 15]
that round-trip dominates the memory roofline term.  This kernel keeps the
whole (q-block x kv-block) working set in VMEM: scores, the running
(max, denom) and the output accumulator never leave the chip.

Grid: (B*KV, Lq/bq) — one program instance owns a q-block for one kv-head
group and scans the kv sequence in bk-sized slabs with the standard
online-softmax update.  Working set (bq=256, bk=512, G<=8, hd<=256):
    q block      bq*G*hd*4           =  2 MiB   (f32, G=8, hd=256)
    k/v slabs    2*bk*hd*4           =  1 MiB
    scores       bq*G*bk*4           =  4 MiB
    accumulators bq*G*(hd+2)*4       =  2 MiB
comfortably inside the ~16 MiB VMEM budget.

Supports causal masking, sliding windows, softcap and prefix-LM — the same
mask algebra as ``repro.models.layers._mask_block``.  Backward runs through
the jnp reference (``ops.flash_attention`` wraps with jax.custom_vjp-free
recompute); on real TPU a paired backward kernel would follow the same
tiling.  Validated against ref.py in tests/test_flash_attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, meta_ref, o_ref, *,
            bk: int, softcap: float | None):
    """One q-block vs the full kv sequence (scanned in bk slabs)."""
    q = q_ref[0]              # [bq, G, hd]
    bq, G, hd = q.shape
    M = k_ref.shape[1]
    qpos = qpos_ref[...]      # [bq]
    window = meta_ref[0]
    prefix = meta_ref[1]
    max_kv = meta_ref[2]

    def body(i, carry):
        m, l, acc = carry
        # jnp scalar (not python int) index: pallas' dynamic-index check
        # requires every non-slice index to carry a shape
        zero = jnp.int32(0)
        k = pl.load(k_ref, (zero, pl.ds(i * bk, bk), slice(None)))  # [bk, hd]
        v = pl.load(v_ref, (zero, pl.ds(i * bk, bk), slice(None)))
        kpos = pl.load(kpos_ref, (pl.ds(i * bk, bk),))

        s = jnp.einsum("qgh,kh->qgk", q, k)                    # [bq, G, bk]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        causal = kpos[None, :] <= qpos[:, None]
        causal &= kpos[None, :] > (qpos[:, None] - window)
        bidir = (kpos[None, :] < prefix) & (qpos[:, None] < prefix)
        ok = causal | bidir
        ok &= kpos[None, :] <= max_kv
        s = jnp.where(ok[:, None, :], s.astype(jnp.float32), NEG)

        m_new = jnp.maximum(m, s.max(axis=-1))
        scale = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * scale + p.sum(axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "qgk,kh->qgh", p.astype(v.dtype), v)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, G), NEG, jnp.float32)
    l0 = jnp.zeros((bq, G), jnp.float32)
    a0 = jnp.zeros((bq, G, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, M // bk, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "softcap", "interpret"))
def flash_attention_fwd(q, k, v, q_positions, kv_positions, window, prefix,
                        max_kv, *, bq: int = 256, bk: int = 512,
                        softcap: float | None = None,
                        interpret: bool = True):
    """q: [B, Lq, KV, G, hd]; k/v: [B, M, KV, hd].  Returns [B, Lq, KV, G,
    hd].  Positions are int32 vectors; window/prefix/max_kv int32 scalars
    (use huge values to disable)."""
    B, Lq, KV, G, hd = q.shape
    M = k.shape[1]
    bq = min(bq, Lq)
    while Lq % bq:
        bq //= 2
    bk = min(bk, M)
    while M % bk:
        bk //= 2

    meta = jnp.stack([jnp.asarray(window, jnp.int32),
                      jnp.asarray(prefix, jnp.int32),
                      jnp.asarray(max_kv, jnp.int32)])

    # flatten (B, KV) into the grid's first axis
    qf = q.transpose(0, 2, 1, 3, 4).reshape(B * KV, Lq, G, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, M, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, M, hd)

    grid = (B * KV, Lq // bq)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, G, hd), lambda h, i: (h, i, 0, 0)),
            pl.BlockSpec((1, M, hd), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, M, hd), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((bq,), lambda h, i: (i,)),
            pl.BlockSpec((M,), lambda h, i: (0,)),
            pl.BlockSpec((3,), lambda h, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bq, G, hd), lambda h, i: (h, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, Lq, G, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, q_positions.astype(jnp.int32),
      kv_positions.astype(jnp.int32), meta)
    return out.reshape(B, KV, Lq, G, hd).transpose(0, 2, 1, 3, 4)
