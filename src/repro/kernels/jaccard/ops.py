"""Public wrapper for the fused TSA2 segmentation kernel (windowed Jaccard).

Padding-owning contract: callers hand raw ``[T, M, W]`` packed masks and
the ``[T, M]`` validity mask; the wrapper zeroes invalid positions (zero
is the OR identity, so padding never leaks into a window union) and the
kernel pads the trajectory axis to whole ``bt`` blocks internally.  The
returned ``d`` is bit-identical to the jnp packed engine
(``repro.core.segmentation.tsa2_signal``) — ``tsa2(use_kernel=True)``
relies on that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.jaccard.jaccard import jaccard_pallas


@functools.partial(jax.jit, static_argnames=("w", "interpret"))
def window_jaccard(masks: jnp.ndarray, valid: jnp.ndarray, *, w: int,
                   interpret: bool | None = None) -> jnp.ndarray:
    """TSA2's d[] signal from packed neighbor masks ([T, M, W], [T, M]).

    ``d[t, i]`` is the windowed Jaccard *distance* between the union of
    the ``w`` neighbor sets before point ``i`` and the ``w`` sets from
    ``i`` on (Problem 2's change signal); peaks in ``d`` become TSA2 cut
    candidates.  This is the engine ``EnginePlan.seg_use_kernel`` selects
    — bit-identical to the jnp packed path, so the choice is purely a
    substrate decision (Pallas on accelerators, interpret mode on CPU).
    """
    if interpret is None:
        interpret = default_interpret()
    masks = jnp.where(valid[..., None], masks, jnp.uint32(0))
    return jaccard_pallas(masks, w=w, interpret=interpret)
