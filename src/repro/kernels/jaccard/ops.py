"""Public wrapper for the sliding-window Jaccard kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.jaccard.jaccard import jaccard_pallas


@functools.partial(jax.jit, static_argnames=("w", "interpret"))
def window_jaccard(masks: jnp.ndarray, valid: jnp.ndarray, *, w: int,
                   interpret: bool | None = None) -> jnp.ndarray:
    """TSA2's d[] signal from packed neighbor masks ([T, M, W], [T, M])."""
    if interpret is None:
        interpret = default_interpret()
    masks = jnp.where(valid[..., None], masks, jnp.uint32(0))
    return jaccard_pallas(masks, w=w, interpret=interpret)
