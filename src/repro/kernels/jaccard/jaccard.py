"""Pallas TPU kernel: TSA2's sliding-window set-union Jaccard dissimilarity.

Input: per-point neighbor sets, bit-packed as uint32 words ``[T, M, W]``
(bit c of word c//32 set iff candidate trajectory c matches the point).
For every position n the kernel forms the unions

    l1 = OR of masks[n-w .. n-1]        l2 = OR of masks[n .. n+w-1]

and emits ``d[n] = 1 - popcount(l1 & l2) / popcount(l1 | l2)`` (Algorithm 3
line 7).  The window OR is an unrolled sequence of ``w`` static shifts along
the point axis — pure integer VPU work (no MXU), ``O(M * w * W)`` ops per
trajectory; bit-packing gives a 32x reduction in both bytes and ops versus
the boolean-expanded reference.

Block layout: a [bt, M, W] slab per program instance (bt=8, M<=512, W<=32 ->
512 KiB) — the whole trajectory must be resident because windows straddle
tile borders.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(masks_ref, out_d_ref, *, w: int):
    masks = masks_ref[...]                         # [bt, M, W] uint32
    bt, M, W = masks.shape

    def shifted(k):
        """masks shifted so position n reads masks[n - k] (zeros off-edge)."""
        if k == 0:
            return masks
        if k > 0:
            pad = jnp.zeros((bt, k, W), masks.dtype)
            return jnp.concatenate([pad, masks[:, :M - k]], axis=1)
        pad = jnp.zeros((bt, -k, W), masks.dtype)
        return jnp.concatenate([masks[:, -k:], pad], axis=1)

    l1 = jnp.zeros_like(masks)
    for k in range(1, w + 1):                      # W1 = [n-w, n-1]
        l1 = l1 | shifted(k)
    l2 = jnp.zeros_like(masks)
    for k in range(0, w):                          # W2 = [n, n+w-1]
        l2 = l2 | shifted(-k)

    inter = jnp.sum(jax.lax.population_count(l1 & l2), axis=-1)
    union = jnp.sum(jax.lax.population_count(l1 | l2), axis=-1)
    inter = inter.astype(jnp.float32)
    union = union.astype(jnp.float32)
    out_d_ref[...] = jnp.where(
        union > 0, 1.0 - inter / jnp.maximum(union, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("w", "bt", "interpret"))
def jaccard_pallas(masks: jnp.ndarray, *, w: int, bt: int = 8,
                   interpret: bool = True) -> jnp.ndarray:
    """[T, M, W] packed masks -> [T, M] window Jaccard dissimilarity."""
    T, M, W = masks.shape
    padT = (-T) % bt
    if padT:
        masks = jnp.pad(masks, ((0, padT), (0, 0), (0, 0)))
    Tp = T + padT

    out = pl.pallas_call(
        functools.partial(_kernel, w=w),
        grid=(Tp // bt,),
        in_specs=[pl.BlockSpec((bt, M, W), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bt, M), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, M), jnp.float32),
        interpret=interpret,
    )(masks)
    return out[:T]
