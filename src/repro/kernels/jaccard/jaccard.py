"""Pallas TPU kernel: TSA2's sliding-window set-union Jaccard dissimilarity.

This is the segmentation kernel package's fused TSA2 sweep: packed
windowed-OR + popcount -> Jaccard dissimilarity ``d[n]`` in one pass.

Input: per-point neighbor sets, bit-packed as uint32 words ``[T, M, W]``
(bit c of word c//32 set iff candidate trajectory c matches the point).
For every position n the kernel forms the unions

    l1 = OR of masks[n-w .. n-1]        l2 = OR of masks[n .. n+w-1]

and emits ``d[n] = 1 - popcount(l1 & l2) / popcount(l1 | l2)`` (Algorithm 3
line 7).  The window OR uses the same idempotent-monoid decomposition as
``repro.core.windows`` (DESIGN.md §7), in its in-register doubling form:
a trailing window of length ``c`` doubles to ``c + min(c, w - c)`` with a
single static shift+OR, so the full window costs ``ceil(log2 w)``
shift+OR steps over the resident ``[bt, M + w - 1, W]`` slab — pure
integer VPU work (no MXU, no gathers), ``O(M * log(w) * W)`` ops per
trajectory where the bit-expanded reference spends ``O(M * w * W * 32)``.
Both windows fall out of ONE trailing-window array: ``l1[n] = incl[n-1]``
and ``l2[n] = incl[n+w-1]`` — two more static shifts.

Block layout (stjoin tile conventions): the grid walks blocks of ``bt``
whole trajectories; the whole point axis is resident per program instance
(windows straddle any smaller tiling), so a block is ``[bt, M, W]``
(bt=8, M<=512, W<=32 -> 512 KiB of VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(masks_ref, out_d_ref, *, w: int):
    masks = masks_ref[...]                         # [bt, M, W] uint32
    bt, M, W = masks.shape

    def shifted_right(a, k):
        """``a`` shifted so position m reads ``a[m - k]`` (zeros off-edge)."""
        if k == 0:
            return a
        Ma = a.shape[1]
        kk = min(k, Ma)
        pad = jnp.zeros((bt, kk, W), a.dtype)
        return jnp.concatenate([pad, a[:, :Ma - kk]], axis=1)

    # trailing-window union incl[m] = OR(masks[max(m-w+1, 0) .. m]) on the
    # slab extended by w-1 zero columns (zero is the OR identity, so the
    # extension exactly models the off-end positions l2 reads)
    x = masks if w <= 1 else jnp.concatenate(
        [masks, jnp.zeros((bt, w - 1, W), masks.dtype)], axis=1)
    incl, c = x, 1
    while c < w:                                   # doubling windowed OR
        step = min(c, w - c)
        incl = incl | shifted_right(incl, step)
        c += step

    l1 = shifted_right(incl, 1)[:, :M]             # W1 = [n-w, n-1]
    l2 = incl[:, w - 1:w - 1 + M]                  # W2 = [n, n+w-1]

    pc = jax.lax.population_count
    inter = jnp.sum(pc(l1 & l2), axis=-1).astype(jnp.float32)
    union = jnp.sum(pc(l1 | l2), axis=-1).astype(jnp.float32)
    out_d_ref[...] = jnp.where(
        union > 0, 1.0 - inter / jnp.maximum(union, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("w", "bt", "interpret"))
def jaccard_pallas(masks: jnp.ndarray, *, w: int, bt: int = 8,
                   interpret: bool = True) -> jnp.ndarray:
    """[T, M, W] packed masks -> [T, M] window Jaccard dissimilarity."""
    T, M, W = masks.shape
    padT = (-T) % bt
    if padT:
        masks = jnp.pad(masks, ((0, padT), (0, 0), (0, 0)))
    Tp = T + padT

    out = pl.pallas_call(
        functools.partial(_kernel, w=w),
        grid=(Tp // bt,),
        in_specs=[pl.BlockSpec((bt, M, W), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bt, M), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, M), jnp.float32),
        interpret=interpret,
    )(masks)
    return out[:T]
