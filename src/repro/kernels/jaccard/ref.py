"""Pure-jnp oracle for the TSA2 segmentation kernel (bit-expanded).

Deliberately the *opposite* formulation from the production paths: every
packed word is expanded to 32 booleans and the window union is the
w-unrolled shift chain, so kernel/engine bugs cannot hide behind a shared
derivation.  O(M * w * W * 32) work — test shapes only.
"""
from __future__ import annotations

import jax.numpy as jnp


def jaccard_ref(masks: jnp.ndarray, w: int) -> jnp.ndarray:
    """[T, M, W] uint32 packed -> [T, M] Jaccard dissimilarity d[n]."""
    T, M, W = masks.shape
    bits = ((masks[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & 1)
    bits = bits.astype(bool).reshape(T, M, W * 32)

    def union_over(lo, hi):          # inclusive index window per position
        out = jnp.zeros_like(bits)
        for k in range(lo, hi + 1):
            if k <= 0:
                src = jnp.pad(bits[:, -k:], ((0, 0), (0, -k), (0, 0)))
            else:
                src = jnp.pad(bits[:, :M - k], ((0, 0), (k, 0), (0, 0)))
            out = out | src
        return out

    l1 = union_over(1, w)            # positions n-w .. n-1
    l2 = union_over(-(w - 1), 0)     # positions n .. n+w-1
    inter = jnp.sum(l1 & l2, axis=-1).astype(jnp.float32)
    union = jnp.sum(l1 | l2, axis=-1).astype(jnp.float32)
    return jnp.where(union > 0, 1.0 - inter / jnp.maximum(union, 1.0), 0.0)
