"""Pallas TPU kernels for the DSC hot spots.

Each kernel package ships three modules:
  <name>.py — the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py    — the jit'd public wrapper (interpret=True on CPU)
  ref.py    — the pure-jnp oracle used by tests/benchmarks

Kernels:
  stjoin    — best-match spatiotemporal join (the paper's dominant cost)
  cluster   — round-parallel greedy clustering (Algorithm 4) round scan +
              claim-max over [S, S] tiles
  lcss      — weighted-LCSS dynamic program (Eq. 2), anti-diagonal wavefront
  jaccard   — the TSA2 segmentation kernel: packed windowed-OR + popcount
              -> sliding-window Jaccard d[n] in one sweep
              (``seg_use_kernel=True`` from every pipeline entry point)
  attention — flash attention for the LM serving path (optional)
"""

import jax


def default_interpret() -> bool:
    """Interpret kernels in Python unless we are actually on TPU."""
    return jax.default_backend() != "tpu"
