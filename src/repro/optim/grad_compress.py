"""Error-feedback gradient compression for the DP all-reduce.

Two compressors, both with error feedback (the residual between the true and
the compressed gradient is carried in optimizer-side state and added back the
next step, preserving convergence):

  int8   — per-leaf symmetric quantization: the all-reduce moves 1/4 the
           bytes (int8 payload + one f32 scale per leaf).
  topk   — per-leaf magnitude top-k (k = ratio * size): the all-reduce moves
           values+indices of the k survivors.

On a real pod these wrap ``psum``; under GSPMD the compressed representation
is what crosses the 'data' axis.  Here the transform is expressed as
compress -> (all-reduce) -> decompress so the collective payload in the HLO
is the compressed tensor.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_int8(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_int8(grads, ef_state):
    """Returns (payload pytree to all-reduce, new residuals)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = _quantize_int8(g32)
        deq = _dequantize_int8(q, s)
        return (q, s), g32 - deq
    flat = jax.tree.map(one, grads, ef_state,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))
    payload = jax.tree.map(lambda t: t[0], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return payload, resid


def decompress_int8(payload):
    return jax.tree.map(lambda t: _dequantize_int8(*t), payload,
                        is_leaf=lambda x: isinstance(x, tuple))


def compress_topk(grads, ef_state, ratio: float = 0.05):
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        flatg = g32.reshape(-1)
        k = max(1, int(flatg.shape[0] * ratio))
        vals, idx = jax.lax.top_k(jnp.abs(flatg), k)
        kept = flatg[idx]
        sparse = jnp.zeros_like(flatg).at[idx].set(kept)
        return (kept, idx.astype(jnp.int32), flatg.shape[0]), \
            (flatg - sparse).reshape(g.shape)
    flat = jax.tree.map(one, grads, ef_state,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))
    payload = jax.tree.map(lambda t: t[0], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return payload, resid


def decompress_topk(payload, shapes):
    def one(t, shape):
        kept, idx, n = t
        return jnp.zeros((n,), jnp.float32).at[idx].set(kept).reshape(shape)
    return jax.tree.map(one, payload, shapes,
                        is_leaf=lambda x: isinstance(x, tuple))
