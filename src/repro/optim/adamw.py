"""AdamW optimizer as pure pytree transforms (no optax dependency).

Moments are kept in f32 regardless of parameter dtype; the update is fused
into a single tree_map per moment for XLA-friendly fusion.  Optimizer state
shards exactly like the parameters (same pytree structure), so GSPMD ZeRO-1
falls out of the sharding rules for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.tree import pytree_dataclass


@pytree_dataclass
class AdamWState:
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
            p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    mu = treedef.unflatten([o[0] for o in out])
    nu = treedef.unflatten([o[1] for o in out])
    new_params = treedef.unflatten([o[2] for o in out])
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
