"""Elastic recovery on the 8-device mesh (DESIGN.md §11).

Two halves, same subprocess pattern as ``test_resilient_dist.py``:

* an in-process driver that kills a P=8 checkpointed run at every stage
  boundary and resumes it on P∈{8,4,2,1} meshes (``elastic_resume``),
  asserting bit-identity against straight-through runs at the *new* P —
  plus the straggler-driven ``RebalancePolicy(mode="apply")`` path
  against its own oracle (a straight-through run partitioned at the
  applied cut from the start);
* a launcher matrix asserting the CLI exit codes: injected crash at
  P=8, elastic resume at P=4 → ok, cross-P resume *without* the flag →
  a plain error.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

pytestmark = [pytest.mark.distributed, pytest.mark.slow,
              pytest.mark.faults]

_STAGES = ("join", "segment", "similarity", "cluster", "refine")
_RESUME_PS = (4, 2, 1)

_DRIVER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import shutil
    import tempfile
    import numpy as np
    import jax
    from repro.data.synthetic import figure1_scenario
    from repro.core.types import DSCParams
    from repro.core.partitioning import partition_batch, repartition_batch
    from repro.run import (FaultPlan, InjectedCrash, RebalancePolicy,
                           read_telemetry, run_resilient_distributed)
    from repro.run.resilient import STAGES

    batch, _ = figure1_scenario(n_per_route=4, points_per_leg=24, seed=0)
    params = DSCParams(eps_sp=0.42, eps_t=1.0, delta_t=0.0, w=6, tau=0.15,
                       alpha_sigma=-1.0, k_sigma=-1.0, segmentation="tsa2")
    tmp = tempfile.mkdtemp()
    report = {}

    def mesh_for(P):
        return jax.make_mesh((P, 1), ("part", "model"))

    def sig(res):
        o = res.output
        return (np.asarray(o.result.member_of),
                np.asarray(o.result.is_rep),
                np.asarray(o.result.is_outlier),
                float(res.sscr), float(res.rmse))

    def same(a, b):
        return bool(all(np.array_equal(x, y) for x, y in zip(a, b)))

    # straight-through oracles at every target P
    oracle = {P: sig(run_resilient_distributed(
                  partition_batch(batch, P), params, mesh_for(P)))
              for P in (8, 4, 2, 1)}

    # kill at every stage boundary at P=8; resume elastically at the
    # smaller meshes (and once on the writing mesh: adaptation no-ops)
    for stage in STAGES:
        targets = (8, 4, 2, 1) if stage == "cluster" else (4, 2, 1)
        for newP in targets:
            root = f"{tmp}/el_{stage}_{newP}"
            try:
                run_resilient_distributed(
                    partition_batch(batch, 8), params, mesh_for(8),
                    checkpoint_dir=root,
                    fault_plan=FaultPlan(crash_at=stage))
                report[f"crash_{stage}_raised"] = False
            except InjectedCrash:
                report[f"crash_{stage}_raised"] = True
            res = run_resilient_distributed(
                partition_batch(batch, newP), params, mesh_for(newP),
                checkpoint_dir=root, elastic_resume=True)
            report[f"elastic_{stage}_{newP}_agree"] = same(
                sig(res), oracle[newP])
            report[f"elastic_{stage}_{newP}_from"] = res.resumed_from

    # cross-P resume WITHOUT the flag must refuse loudly
    root = f"{tmp}/noflag"
    try:
        run_resilient_distributed(
            partition_batch(batch, 8), params, mesh_for(8),
            checkpoint_dir=root, fault_plan=FaultPlan(crash_at="cluster"))
    except InjectedCrash:
        pass
    try:
        run_resilient_distributed(partition_batch(batch, 4), params,
                                  mesh_for(4), checkpoint_dir=root)
        report["noflag_error"] = None
    except ValueError as e:
        report["noflag_error"] = str(e)

    # rebalance apply: scripted slowdown on partition 1 triggers the
    # re-cut after join; oracle = straight-through at the applied cut
    rbroot = f"{tmp}/rb"
    parts4 = partition_batch(batch, 4)
    slow = FaultPlan(slow=(("join", 1, 30.0),))
    res_rb = run_resilient_distributed(
        parts4, params, mesh_for(4), checkpoint_dir=rbroot,
        fault_plan=slow, rebalance=RebalancePolicy(mode="apply"))
    rb_events = [e for e in read_telemetry(rbroot + "/telemetry.jsonl")
                 if e["event"] == "rebalanced"]
    report["rebalanced_events"] = len(rb_events)
    report["rebalance_count"] = res_rb.rebalance_count
    report["rebalanced_stage"] = (rb_events[0]["stage"] if rb_events
                                  else None)
    if rb_events:
        edges = np.asarray(rb_events[0]["edges"], np.float64)
        report["rebalanced_edge_count"] = int(edges.shape[0])
        res_or = run_resilient_distributed(
            repartition_batch(parts4, edges), params, mesh_for(4))
        report["rebalance_agree"] = same(sig(res_rb), sig(res_or))

    # crash after the applied rebalance: a plain (non-elastic) resume
    # adopts the checkpoint's edges and stays bit-identical
    rb2 = f"{tmp}/rb2"
    try:
        run_resilient_distributed(
            partition_batch(batch, 4), params, mesh_for(4),
            checkpoint_dir=rb2, rebalance=RebalancePolicy(mode="apply"),
            fault_plan=slow.replace(crash_at="cluster"))
    except InjectedCrash:
        pass
    res_ad = run_resilient_distributed(
        partition_batch(batch, 4), params, mesh_for(4),
        checkpoint_dir=rb2)
    ad_events = [e for e in read_telemetry(rb2 + "/telemetry.jsonl")
                 if e["event"] == "elastic_adopt_edges"]
    report["adopt_events"] = len(ad_events)
    report["adopt_agree"] = same(sig(res_ad), sig(res_rb))

    # ring comm schedules under kill-and-resume: a ring-plan run crashed
    # at the similarity boundary on a (4, 2) mesh resumes elastically on
    # (2, 2) — the ring similarity exchange reruns on the new mesh — and
    # stays bit-identical to the barrier plan's straight-through run;
    # telemetry events carry the active comm schedule
    ring_kw = dict(sim_mode="topk", sim_topk=48, halo_stream="ring",
                   sim_exchange="ring")
    mesh42 = jax.make_mesh((4, 2), ("part", "model"))
    mesh22 = jax.make_mesh((2, 2), ("part", "model"))
    oracle_ring = sig(run_resilient_distributed(
        partition_batch(batch, 2), params, mesh22,
        sim_mode="topk", sim_topk=48))          # barrier twin
    ringroot = f"{tmp}/ring"
    try:
        run_resilient_distributed(
            partition_batch(batch, 4), params, mesh42,
            checkpoint_dir=ringroot,
            fault_plan=FaultPlan(crash_at="similarity"), **ring_kw)
    except InjectedCrash:
        pass
    res_ring = run_resilient_distributed(
        partition_batch(batch, 2), params, mesh22,
        checkpoint_dir=ringroot, elastic_resume=True, **ring_kw)
    report["ring_elastic_agree"] = same(sig(res_ring), oracle_ring)
    report["ring_elastic_from"] = res_ring.resumed_from
    done = [e for e in read_telemetry(ringroot + "/telemetry.jsonl")
            if e["event"] == "stage_done"]
    report["ring_telemetry_comm"] = done[0].get("comm") if done else None

    # rebalance mode="off" emits neither suggestions nor applications
    res_off = run_resilient_distributed(
        parts4, params, mesh_for(4), fault_plan=slow,
        rebalance=RebalancePolicy(mode="off"))
    report["off_suggestions"] = sum(
        e["event"] in ("rebalance_suggestion", "rebalanced")
        for e in res_off.events)

    print("JSON" + json.dumps(report))
""")


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("JSON")][-1]
    return json.loads(line[4:])


@pytest.mark.parametrize("stage", _STAGES)
@pytest.mark.parametrize("newP", _RESUME_PS)
def test_elastic_resume_bit_identity(report, stage, newP):
    """P=8 checkpoint, killed at ``stage``, resumed on a P=``newP``
    mesh: bit-identical labels/SSCR/RMSE to straight-through at newP."""
    assert report[f"crash_{stage}_raised"]
    assert report[f"elastic_{stage}_{newP}_agree"]
    # join/segment state adapts in place; later stages rewind to the
    # segment boundary (their state is partition-bound)
    expect = min(_STAGES.index(stage), 2)
    assert report[f"elastic_{stage}_{newP}_from"] == expect


def test_elastic_resume_same_mesh_is_noop(report):
    assert report["elastic_cluster_8_agree"]
    assert report["elastic_cluster_8_from"] == _STAGES.index("cluster")


def test_cross_p_resume_without_flag_refuses(report):
    assert report["noflag_error"] is not None
    assert "elastic_resume" in report["noflag_error"]


def test_rebalance_apply_matches_oracle_cut(report):
    assert report["rebalanced_events"] == 1
    assert report["rebalance_count"] == 1
    assert report["rebalanced_stage"] == "join"
    assert report["rebalanced_edge_count"] == 5     # P+1 edges
    assert report["rebalance_agree"]


def test_resume_after_rebalance_adopts_edges(report):
    assert report["adopt_events"] == 1
    assert report["adopt_agree"]


def test_ring_elastic_resume_bit_identity(report):
    """Ring comm schedules survive kill-and-resume across meshes: the
    (4, 2) ring-plan checkpoint resumed on (2, 2) reruns the ring
    similarity exchange and matches the barrier twin bit for bit, and
    telemetry is tagged with the active comm schedule."""
    assert report["ring_elastic_from"] == _STAGES.index("similarity")
    assert report["ring_elastic_agree"]
    assert report["ring_telemetry_comm"] == {"halo_stream": "ring",
                                             "sim_exchange": "ring"}


def test_rebalance_off_is_silent(report):
    assert report["off_suggestions"] == 0


# ------------------------------------------------- launcher exit codes


@pytest.fixture(scope="module")
def launcher_codes(tmp_path_factory):
    from repro.run import FaultPlan
    from repro.run.resilient import EXIT_CODES
    tmp = tmp_path_factory.mktemp("elastic_cli")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

    def run(extra):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.run_dsc",
             "--n-trajs", "24"] + extra,
            env=env, capture_output=True, text=True, timeout=900)
        return proc.returncode, proc.stderr

    crash = tmp / "crash.json"
    FaultPlan(crash_at="cluster").save(crash)
    ckpt = str(tmp / "ckpt")
    codes = {}
    codes["crash8"] = run(["--distributed", "8", "--resume-dir", ckpt,
                           "--fault-plan", str(crash)])
    codes["noflag4"] = run(["--distributed", "4", "--resume-dir", ckpt])
    codes["elastic4"] = run(["--distributed", "4", "--resume-dir", ckpt,
                             "--elastic-resume"])
    codes["elastic_alone"] = run(["--elastic-resume"])
    codes["expected"] = EXIT_CODES
    return codes


def test_launcher_elastic_exit_codes(launcher_codes):
    c, exit_codes = launcher_codes, launcher_codes["expected"]
    assert c["crash8"][0] == exit_codes["injected_crash"]
    # cross-P without the flag: refused (unclassified error), told how
    assert c["noflag4"][0] not in (0, exit_codes["injected_crash"])
    assert "elastic" in c["noflag4"][1]
    assert c["elastic4"][0] == exit_codes["ok"]
    # --elastic-resume without --resume-dir/--distributed: usage error
    assert c["elastic_alone"][0] == 2
