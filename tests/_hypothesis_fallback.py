"""Deterministic stand-in for ``hypothesis`` when it is not installed.

CI installs the real ``hypothesis`` (declared in the ``test`` extra of
``pyproject.toml``) and this module is then never activated.  On bare
machines that only have the pinned runtime deps, ``tests/conftest.py``
registers this shim under ``sys.modules["hypothesis"]`` *before* the test
modules import it, so collection succeeds and every ``@given`` property
test still runs — against a fixed, deterministic sample of examples
instead of hypothesis' adaptive search.

Only the tiny surface the test-suite uses is provided:

* ``strategies.integers(lo, hi)``
* ``strategies.sampled_from(elements)``
* ``@given(*strategies)`` — runs the test body for ``_NUM_EXAMPLES``
  deterministic draws (seeded per test name, so failures reproduce)
* ``@settings(...)`` — accepted and ignored
"""
from __future__ import annotations

import random
import types

_NUM_EXAMPLES = 5


class _IntegersStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def example(self, rng: random.Random) -> int:
        # Always include the bounds in the sampled set via the first draws.
        return rng.choice((self.lo, self.hi, rng.randint(self.lo, self.hi)))


def integers(min_value: int, max_value: int) -> _IntegersStrategy:
    return _IntegersStrategy(min_value, max_value)


class _SampledFromStrategy:
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, rng: random.Random):
        return rng.choice(self.elements)


def sampled_from(elements) -> _SampledFromStrategy:
    return _SampledFromStrategy(elements)


def given(*strats):
    def deco(fn):
        def wrapper(*args, **kw):
            rng = random.Random(fn.__name__)
            for _ in range(_NUM_EXAMPLES):
                fn(*args, *(s.example(rng) for s in strats), **kw)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.hypothesis_fallback = True
        return wrapper

    return deco


def settings(**_kw):
    def deco(fn):
        return fn

    return deco


def build_module() -> types.ModuleType:
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__is_fallback__ = True
    return mod
