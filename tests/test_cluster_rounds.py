"""Round-parallel clustering engine: label identity with the sequential
oracle (hypothesis-driven), kernel parity, and the degenerate extremes.

The engine contract (DESIGN.md §6) is *bit identity*: ``member_of``,
``member_sim``, ``is_rep`` and ``is_outlier`` must equal the sequential
Algorithm 4 transcription exactly — including argsort tie-break
determinism under tied voting values — on any similarity matrix.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.clustering import (cluster, cluster_rounds,
                                   cluster_sequential, visit_order)
from repro.core.types import DSCParams, SubtrajTable
from repro.kernels.cluster.ops import (cluster_assign, cluster_round_scan,
                                       plan_tiles)
from repro.kernels.cluster.ref import claim_max_ref, round_scan_ref

FIELDS = ("member_of", "member_sim", "is_rep", "is_outlier")

PARAM_GRID = (
    DSCParams(alpha_sigma=0.0, k_sigma=0.0),
    DSCParams(alpha_sigma=0.5, k_sigma=-0.5),
    DSCParams(alpha_abs=0.2, k_abs=1.0),
    DSCParams(alpha_abs=0.0, k_abs=0.0),
)


def _instance(seed, S=24, tied_voting=False, symmetric=True):
    rng = np.random.default_rng(seed)
    raw = rng.uniform(0, 1, (S, S)).astype(np.float32)
    sim = raw * (rng.uniform(0, 1, (S, S)) > 0.5)
    if symmetric:
        sim = np.maximum(sim, sim.T)
    np.fill_diagonal(sim, 0.0)
    valid = rng.uniform(0, 1, S) > 0.1
    # tied voting: draw from a 3-value set so most slots collide and the
    # stable-argsort (slot-index) tie break decides the visit order
    voting = (rng.integers(0, 3, S).astype(np.float32) if tied_voting
              else rng.uniform(0, 5, S).astype(np.float32))
    table = SubtrajTable(
        t_start=jnp.zeros(S), t_end=jnp.ones(S),
        voting=jnp.asarray(voting),
        card=jnp.asarray(rng.integers(1, 20, S).astype(np.int32)),
        valid=jnp.asarray(valid),
        traj_row=jnp.arange(S, dtype=jnp.int32))
    return jnp.asarray(sim.astype(np.float32)), table


def _assert_identical(res_a, res_b, ctx=""):
    for f in FIELDS:
        a, b = np.asarray(getattr(res_a, f)), np.asarray(getattr(res_b, f))
        assert np.array_equal(a, b), (f, ctx, a, b)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_rounds_match_sequential(seed):
    sim, table = _instance(seed)
    for params in PARAM_GRID:
        seq = cluster_sequential(sim, table, params)
        rp, rounds = cluster_rounds(sim, table, params, with_rounds=True)
        _assert_identical(seq, rp, f"seed={seed}")
        assert int(rounds) <= table.num_slots


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_rounds_match_sequential_tied_voting(seed):
    """Voting drawn from {0, 1, 2}: ties everywhere — the visit order (and
    therefore every claim) hinges on stable-argsort determinism."""
    sim, table = _instance(seed, tied_voting=True)
    for params in PARAM_GRID:
        _assert_identical(cluster_sequential(sim, table, params),
                          cluster_rounds(sim, table, params),
                          f"seed={seed}")


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_rounds_match_sequential_asymmetric(seed):
    """The engine must not assume a symmetrized matrix: claims always read
    the claiming representative's row, in either engine."""
    sim, table = _instance(seed, symmetric=False)
    params = DSCParams(alpha_sigma=0.0, k_sigma=0.0)
    _assert_identical(cluster_sequential(sim, table, params),
                      cluster_rounds(sim, table, params), f"seed={seed}")


def test_all_outlier_extreme():
    """No similarity and an unreachable k: every valid slot is an outlier,
    resolved in zero rounds (no potential representatives)."""
    S = 16
    table = SubtrajTable(
        t_start=jnp.zeros(S), t_end=jnp.ones(S),
        voting=jnp.ones(S), card=jnp.ones(S, jnp.int32),
        valid=jnp.ones(S, bool), traj_row=jnp.arange(S, dtype=jnp.int32))
    params = DSCParams(alpha_abs=0.5, k_abs=100.0)
    sim = jnp.zeros((S, S))
    seq = cluster_sequential(sim, table, params)
    rp, rounds = cluster_rounds(sim, table, params, with_rounds=True)
    _assert_identical(seq, rp)
    assert bool(np.asarray(rp.is_outlier).all())
    assert int(rounds) == 0


def test_all_one_cluster_extreme():
    """Uniform high similarity, k=0: the first-visited slot claims every
    other slot; the round engine needs exactly 2 rounds however large S."""
    S = 32
    sim = np.full((S, S), 0.9, np.float32)
    np.fill_diagonal(sim, 0.0)
    table = SubtrajTable(
        t_start=jnp.zeros(S), t_end=jnp.ones(S),
        voting=jnp.ones(S), card=jnp.ones(S, jnp.int32),
        valid=jnp.ones(S, bool), traj_row=jnp.arange(S, dtype=jnp.int32))
    params = DSCParams(alpha_abs=0.5, k_abs=0.0)
    seq = cluster_sequential(jnp.asarray(sim), table, params)
    rp, rounds = cluster_rounds(jnp.asarray(sim), table, params,
                                with_rounds=True)
    _assert_identical(seq, rp)
    assert int(np.asarray(rp.is_rep).sum()) == 1
    assert int(rounds) == 2


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_kernel_engine_matches_sequential(seed):
    """use_kernel=True (Pallas round scan + claim-max, padded tiles) is
    bit-identical to the oracle."""
    sim, table = _instance(seed, tied_voting=(seed % 2 == 0))
    params = DSCParams(alpha_sigma=0.0, k_sigma=0.0)
    _assert_identical(cluster_sequential(sim, table, params),
                      cluster_rounds(sim, table, params, use_kernel=True),
                      f"seed={seed}")


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_kernel_primitives_match_ref(seed):
    """The tiled round scan / claim-max equal the jnp oracle on padded
    operands with mid-convergence round state."""
    rng = np.random.default_rng(seed)
    sim, table = _instance(seed, S=40)       # S=40: forces internal padding
    S = table.num_slots
    assert plan_tiles(S)[2] > S              # wrappers must pad this shape
    alpha = jnp.float32(0.3)
    order, rank = visit_order(table)

    potential = np.asarray(table.valid)
    unresolved = jnp.asarray(potential & (rng.uniform(0, 1, S) > 0.4))
    is_rep = jnp.asarray(potential & (rng.uniform(0, 1, S) > 0.6)
                         & ~np.asarray(unresolved))

    blk, clm = cluster_round_scan(sim, rank, unresolved, is_rep, alpha)
    blk_r, clm_r = round_scan_ref(sim, rank, unresolved, is_rep, alpha)
    assert np.array_equal(np.asarray(blk), np.asarray(blk_r))
    assert np.array_equal(np.asarray(clm), np.asarray(clm_r))

    w, slot = cluster_assign(sim, rank, is_rep, table.valid, alpha)
    w_r, slot_r = claim_max_ref(sim, order, rank, is_rep, table.valid,
                                alpha)
    assert np.array_equal(np.asarray(w), np.asarray(w_r))
    assert np.array_equal(np.asarray(slot), np.asarray(slot_r))


def test_fixed_trip_fallback_matches_while():
    """max_rounds=S (fori_loop fallback) equals the while_loop engine —
    converged rounds are no-ops; max_rounds < S is rejected (it could
    silently return partial labels)."""
    sim, table = _instance(7)
    params = DSCParams(alpha_sigma=0.0, k_sigma=0.0)
    _assert_identical(
        cluster_rounds(sim, table, params, max_rounds=table.num_slots),
        cluster_rounds(sim, table, params))
    with pytest.raises(ValueError):
        cluster_rounds(sim, table, params, max_rounds=table.num_slots - 1)


def test_voting_threshold_large_mean_small_std():
    """k from sigma-relative voting stats must not collapse under
    mean >> std (centered variance, not the E[x^2]-E[x]^2 identity)."""
    S = 16
    rng = np.random.default_rng(0)
    voting = (10000.0 + rng.uniform(-0.005, 0.005, S)).astype(np.float32)
    table = SubtrajTable(
        t_start=jnp.zeros(S), t_end=jnp.ones(S),
        voting=jnp.asarray(voting), card=jnp.ones(S, jnp.int32),
        valid=jnp.ones(S, bool), traj_row=jnp.arange(S, dtype=jnp.int32))
    from repro.core.clustering import resolve_thresholds
    params = DSCParams(alpha_sigma=0.0, k_sigma=1.0)
    _, k = resolve_thresholds(params, jnp.zeros((S, S)), table)
    v64 = voting.astype(np.float64)
    want = v64.mean() + v64.std()
    assert abs(float(k) - want) < 1e-3, (float(k), want)


def test_dispatcher_engines():
    sim, table = _instance(11)
    params = DSCParams(alpha_sigma=0.0, k_sigma=0.0)
    _assert_identical(cluster(sim, table, params, engine="sequential"),
                      cluster(sim, table, params, engine="rounds"))
    with pytest.raises(ValueError):
        cluster(sim, table, params, engine="bogus")


def test_engine_parity_through_pipeline(fig1, fig1_params):
    """run_dsc with cluster_engine="rounds" (default) equals the
    sequential-engine run end to end, single host."""
    from repro.core.dsc import run_dsc
    batch, _ = fig1
    out_r = run_dsc(batch, fig1_params)
    out_s = run_dsc(batch, fig1_params, cluster_engine="sequential")
    _assert_identical(out_r.result, out_s.result)
    assert float(out_r.sscr) == float(out_s.sscr)


def test_kernel_cluster_through_pipeline():
    """run_dsc(cluster_use_kernel=True) — the production entry to the
    Pallas cluster kernels — matches the jnp engine end to end (small
    instance: interpret mode pays per program instance)."""
    from repro.core.dsc import run_dsc
    from repro.data.synthetic import ais_like
    batch, _ = ais_like(n_vessels=8, max_points=24, seed=3)
    params = DSCParams(eps_sp=3.0, eps_t=600.0, w=4, tau=0.2,
                       alpha_sigma=0.0, k_sigma=0.0,
                       max_subtrajs_per_traj=4)
    out = run_dsc(batch, params)
    out_k = run_dsc(batch, params, cluster_use_kernel=True)
    _assert_identical(out.result, out_k.result)


@pytest.mark.slow
def test_engine_parity_distributed_single_device(fig1, fig1_params):
    """Distributed program (P=1 mesh on the single real device): the
    per-partition round engine matches the sequential engine exactly."""
    import jax
    from repro.core.distributed import run_dsc_distributed
    from repro.core.partitioning import partition_batch
    batch, _ = fig1
    mesh = jax.make_mesh((1, 1), ("part", "model"))
    parts = partition_batch(batch, 1)
    out_r = run_dsc_distributed(parts, fig1_params, mesh)
    out_s = run_dsc_distributed(parts, fig1_params, mesh,
                                cluster_engine="sequential")
    _assert_identical(out_r.result, out_s.result)
