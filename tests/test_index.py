"""Spatiotemporal candidate-pruning index: the pruned join must be
*bit-identical* to the dense join — the index is a pure accelerator, never
an approximation.  Covers random batches (property test), edge cells
(points exactly on cell/eps boundaries), and all-invalid tiles."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.geometry import subtrajectory_join as geo_join
from repro.core.types import TrajectoryBatch
from repro.index import grid as gridx
from repro.kernels.stjoin.ops import (
    best_match_join_kernel,
    best_match_join_pruned,
)


def _batch(rng, T, M, *, invalid_rows=(), invalid_frac=0.15, scale=10.0):
    x = rng.uniform(0, scale, (T, M)).astype(np.float32)
    y = rng.uniform(0, scale, (T, M)).astype(np.float32)
    t = np.sort(rng.uniform(0, 50, (T, M)), axis=1).astype(np.float32)
    v = rng.uniform(0, 1, (T, M)) > invalid_frac
    for r in invalid_rows:
        v[r] = False
    return TrajectoryBatch(
        x=jnp.asarray(x), y=jnp.asarray(y), t=jnp.asarray(t),
        valid=jnp.asarray(v), traj_id=jnp.arange(T, dtype=jnp.int32))


def _assert_bitwise_equal(dense, pruned):
    assert np.array_equal(np.asarray(dense.best_w),
                          np.asarray(pruned.best_w))
    assert np.array_equal(np.asarray(dense.best_idx),
                          np.asarray(pruned.best_idx))


# ---------------------------- grid structure --------------------------------

def test_cell_table_is_partition_of_nonempty_tiles():
    rng = np.random.default_rng(0)
    b = _batch(rng, 8, 32)
    boxes = gridx.traj_block_boxes(b.x, b.y, b.t, b.valid, 2)
    spec = gridx.fit_grid(boxes, 2.0, 10.0)
    table = gridx.build_cell_table(spec, boxes)
    order = np.asarray(table.order)
    starts = np.asarray(table.starts)
    cell_of = np.asarray(table.cell_of)
    nonempty = np.asarray(boxes.nonempty)
    # order is a permutation of all tile ids
    assert sorted(order.tolist()) == list(range(boxes.num_tiles))
    # CSR covers exactly the nonempty tiles
    assert starts[-1] == nonempty.sum()
    for c in range(spec.num_cells):
        for tid in order[starts[c]:starts[c + 1]]:
            assert cell_of[tid] == c
    # empty tiles are parked past the end
    assert (cell_of[~nonempty] == spec.num_cells).all()


def test_fit_grid_cell_size_is_eps_derived():
    """Docstring contract: cells start at (eps_sp, eps_t) and only coarsen
    when an axis would exceed max_cells_per_axis."""
    rng = np.random.default_rng(1)
    b = _batch(rng, 4, 16, scale=5.0)
    boxes = gridx.traj_block_boxes(b.x, b.y, b.t, b.valid, 2)
    spec = gridx.fit_grid(boxes, 2.0, 10.0)
    assert spec.cell_sp >= 2.0 and spec.cell_t >= 10.0
    tiny = gridx.fit_grid(boxes, 0.001, 0.001, max_cells_per_axis=4)
    assert tiny.nx <= 4 and tiny.ny <= 4 and tiny.nt <= 4


def test_coarse_mask_is_superset_of_exact():
    rng = np.random.default_rng(2)
    ref = _batch(rng, 8, 32)
    cand = _batch(rng, 8, 32)
    rb = gridx.point_block_boxes(ref.x.reshape(-1), ref.y.reshape(-1),
                                 ref.t.reshape(-1), ref.valid.reshape(-1), 32)
    cb = gridx.traj_block_boxes(cand.x, cand.y, cand.t, cand.valid, 2)
    spec = gridx.fit_grid(cb, 2.0, 10.0)
    table = gridx.build_cell_table(spec, cb)
    coarse = np.asarray(gridx.coarse_pair_mask(spec, table, rb, cb, 2.0, 10.0))
    exact = np.asarray(gridx.exact_pair_mask(rb, cb, 2.0, 10.0))
    assert (coarse | ~exact).all()      # exact => coarse


# ------------------------- pruned == dense parity ---------------------------

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_pruned_join_matches_dense(seed):
    rng = np.random.default_rng(seed)
    ref = _batch(rng, 8, 32)
    cand = _batch(rng, 8, 32)
    dense = best_match_join_kernel(ref, cand, 2.0, 10.0, bp=32, bc=2, bm=16)
    pruned = best_match_join_pruned(ref, cand, 2.0, 10.0, bp=32, bc=2, bm=16)
    _assert_bitwise_equal(dense, pruned)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=5, deadline=None)
def test_pruned_join_matches_dense_without_cells(seed):
    """Exact-bbox-only planning path (use_cells=False)."""
    rng = np.random.default_rng(seed)
    ref = _batch(rng, 4, 16)
    cand = _batch(rng, 8, 16)
    dense = best_match_join_kernel(ref, cand, 3.0, 20.0, bp=16, bc=2, bm=16)
    pruned = best_match_join_pruned(ref, cand, 3.0, 20.0, bp=16, bc=2, bm=16,
                                    use_cells=False)
    _assert_bitwise_equal(dense, pruned)


def test_pruned_join_edge_cells():
    """Points exactly at eps distance and on cell boundaries must be kept:
    the bbox test uses <=, mirroring the join's cylinder predicate."""
    T, M = 2, 16
    x = np.zeros((T, M), np.float32)
    y = np.zeros((T, M), np.float32)
    t = np.tile(np.arange(M, dtype=np.float32), (T, 1))
    # row 1 sits exactly eps_sp away from row 0 in x
    x[1] = 2.0
    b = TrajectoryBatch(x=jnp.asarray(x), y=jnp.asarray(y), t=jnp.asarray(t),
                        valid=jnp.ones((T, M), bool),
                        traj_id=jnp.arange(T, dtype=jnp.int32))
    dense = best_match_join_kernel(b, b, 2.0, 1.0, bp=16, bc=1, bm=16)
    pruned = best_match_join_pruned(b, b, 2.0, 1.0, bp=16, bc=1, bm=16)
    _assert_bitwise_equal(dense, pruned)
    # the eps-boundary pair really matches (w == 1 - eps/eps == 0 is culled;
    # nudge inside to see a positive weight)
    x[1] = 1.999
    b2 = b.replace(x=jnp.asarray(x))
    dense2 = best_match_join_kernel(b2, b2, 2.0, 1.0, bp=16, bc=1, bm=16)
    pruned2 = best_match_join_pruned(b2, b2, 2.0, 1.0, bp=16, bc=1, bm=16)
    _assert_bitwise_equal(dense2, pruned2)
    assert float(np.asarray(pruned2.best_w).max()) > 0.0


def test_pruned_join_all_invalid_tiles():
    rng = np.random.default_rng(7)
    ref = _batch(rng, 8, 16, invalid_rows=(1, 2, 5))
    cand = _batch(rng, 8, 16, invalid_rows=(0, 3))
    dense = best_match_join_kernel(ref, cand, 2.0, 10.0, bp=16, bc=2, bm=16)
    pruned = best_match_join_pruned(ref, cand, 2.0, 10.0, bp=16, bc=2, bm=16)
    _assert_bitwise_equal(dense, pruned)


def test_pruned_join_everything_invalid():
    rng = np.random.default_rng(8)
    ref = _batch(rng, 4, 16, invalid_rows=range(4))
    cand = _batch(rng, 4, 16, invalid_rows=range(4))
    dense = best_match_join_kernel(ref, cand, 2.0, 10.0, bp=16, bc=2, bm=16)
    pruned, stats = best_match_join_pruned(
        ref, cand, 2.0, 10.0, bp=16, bc=2, bm=16, return_stats=True)
    _assert_bitwise_equal(dense, pruned)
    assert int(stats.kept_tiles) == 0
    assert (np.asarray(pruned.best_w) == 0).all()
    assert (np.asarray(pruned.best_idx) == -1).all()


def test_pruned_join_prunes_separated_clusters():
    """Two well-separated clusters: cross-cluster tiles must be pruned and
    the surviving-tile count strictly below dense."""
    rng = np.random.default_rng(9)
    near = _batch(rng, 4, 16, scale=1.0)
    far = _batch(rng, 4, 16, scale=1.0)
    batch = TrajectoryBatch(
        x=jnp.concatenate([near.x, far.x + 100.0]),
        y=jnp.concatenate([near.y, far.y + 100.0]),
        t=jnp.concatenate([near.t, far.t]),
        valid=jnp.concatenate([near.valid, far.valid]),
        traj_id=jnp.arange(8, dtype=jnp.int32))
    dense = best_match_join_kernel(batch, batch, 2.0, 10.0, bp=16, bc=2, bm=16)
    pruned, stats = best_match_join_pruned(
        batch, batch, 2.0, 10.0, bp=16, bc=2, bm=16, return_stats=True)
    _assert_bitwise_equal(dense, pruned)
    assert int(stats.kept_tiles) < stats.dense_tiles
    assert int(stats.kept_tiles) > 0


def test_max_tiles_too_small_raises():
    rng = np.random.default_rng(10)
    b = _batch(rng, 8, 16, scale=0.5)      # everything close -> no pruning
    with pytest.raises(ValueError, match="max_tiles"):
        best_match_join_pruned(b, b, 2.0, 50.0, bp=16, bc=2, bm=16,
                               max_tiles=1)


# --------------------- reference-path & API integration ---------------------

def test_geometry_join_use_index_is_lossless():
    rng = np.random.default_rng(11)
    ref = _batch(rng, 6, 24)
    cand = _batch(rng, 6, 24)
    base = geo_join(ref, cand, 2.0, 10.0)
    idx = geo_join(ref, cand, 2.0, 10.0, use_index=True)
    _assert_bitwise_equal(base, idx)


def test_kernel_subtrajectory_join_use_index():
    from repro.kernels.stjoin.ops import subtrajectory_join as k_join
    rng = np.random.default_rng(12)
    ref = _batch(rng, 4, 32)
    cand = _batch(rng, 4, 32)
    base = k_join(ref, cand, 2.0, 10.0, delta_t=3.0, bp=32, bc=2, bm=16)
    idx = k_join(ref, cand, 2.0, 10.0, delta_t=3.0, use_index=True,
                 bp=32, bc=2, bm=16)
    _assert_bitwise_equal(base, idx)
