"""Distributed pipeline tests.

Multi-device cases run in a subprocess with
``--xla_force_host_platform_device_count`` so the main test session keeps a
single real device (required by the harness contract).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_DRIVER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.data.synthetic import figure1_scenario
    from repro.core.types import DSCParams
    from repro.core.partitioning import partition_batch
    from repro.core.distributed import run_dsc_distributed
    from repro.core.dsc import run_dsc

    batch, labels = figure1_scenario(n_per_route=4, points_per_leg=24, seed=0)
    params = DSCParams(eps_sp=0.42, eps_t=1.0, delta_t=0.0, w=6, tau=0.15,
                       alpha_sigma=-1.0, k_sigma=-1.0, segmentation="tsa2")
    report = {}

    # single-host reference
    ref = run_dsc(batch, params)
    report["ref_reps"] = int(np.asarray(ref.result.is_rep).sum())
    report["ref_outliers"] = int(np.asarray(ref.result.is_outlier).sum())

    # P=1 distributed == single host (same partition content)
    mesh1 = jax.make_mesh((1, 2), ("part", "model"))
    parts1 = partition_batch(batch, 1)
    out1 = run_dsc_distributed(parts1, params, mesh1)
    report["p1_member_agree"] = float(
        (np.asarray(out1.result.member_of)
         == np.asarray(ref.result.member_of)).mean())
    report["p1_rep_agree"] = float(
        (np.asarray(out1.result.is_rep)
         == np.asarray(ref.result.is_rep)).mean())

    # P=4 x model=2
    mesh = jax.make_mesh((4, 2), ("part", "model"))
    parts = partition_batch(batch, 4)
    out = run_dsc_distributed(parts, params, mesh)
    res, valid = out.result, np.asarray(out.table.valid)
    member_of = np.asarray(res.member_of)
    is_rep = np.asarray(res.is_rep)
    is_out = np.asarray(res.is_outlier)
    report["p4_reps"] = int(is_rep.sum())
    report["p4_outliers"] = int(is_out.sum())
    report["p4_members"] = int(((member_of >= 0) & ~is_rep).sum())
    # every member's target is a representative
    ok = True
    for s in np.nonzero(valid & (member_of >= 0) & ~is_rep)[0]:
        ok &= bool(is_rep[member_of[s]])
    report["p4_members_point_at_reps"] = bool(ok)
    # states partition valid slots
    seen = np.asarray(out.active).any(0)
    state = is_rep.astype(int) + ((member_of >= 0) & ~is_rep) + is_out
    report["p4_state_partition"] = bool((state[seen] == 1).all())

    # kernel-backed join agrees
    out_k = run_dsc_distributed(parts, params, mesh, use_kernel=True)
    report["p4_kernel_agree"] = float(
        (np.asarray(out_k.result.member_of) == member_of).mean())

    # spatiotemporal-index-pruned join agrees exactly
    out_i = run_dsc_distributed(parts, params, mesh, use_index=True)
    report["p4_index_agree"] = float(
        (np.asarray(out_i.result.member_of) == member_of).mean())

    # fused streaming mode: identical clusters with no per-rank join cube
    out_f = run_dsc_distributed(parts, params, mesh, mode="fused")
    report["p4_fused_agree"] = float(
        (np.asarray(out_f.result.member_of) == member_of).mean())
    report["p4_fused_vote_close"] = bool(np.allclose(
        np.asarray(out_f.vote), np.asarray(out.vote), atol=1e-4))

    # fused Pallas TSA2 segmentation kernel: bit-identical labels to the
    # jnp packed-word engine (tsa2 is the params' segmentation)
    out_sk = run_dsc_distributed(parts, params, mesh, seg_use_kernel=True)
    report["p4_seg_kernel_agree"] = bool(
        (np.asarray(out_sk.result.member_of) == member_of).all()
        and (np.asarray(out_sk.result.is_rep) == is_rep).all()
        and (np.asarray(out_sk.result.is_outlier) == is_out).all())

    # sequential clustering oracle: the round-parallel per-partition
    # engine (the default above) must be label-identical
    out_s = run_dsc_distributed(parts, params, mesh,
                                cluster_engine="sequential")
    report["p4_cluster_engine_agree"] = bool(
        (np.asarray(out_s.result.member_of) == member_of).all()
        and (np.asarray(out_s.result.is_rep) == is_rep).all()
        and (np.asarray(out_s.result.is_outlier) == is_out).all())

    # sparse SP relation (sim_mode="topk"): per-rank column blocks +
    # transpose all_to_all + top-(K+1) allgather merge — bit-identical
    # global labels whenever the spill certificate is clean, in both
    # execution modes
    for key, kw in (("p4_topk", {}), ("p4_topk_fused", {"mode": "fused"})):
        out_t = run_dsc_distributed(parts, params, mesh, sim_mode="topk",
                                    sim_topk=48, **kw)
        report[key + "_overflow"] = int(
            np.asarray(out_t.sim_diag)[:, 3].sum())
        report[key + "_agree"] = bool(
            (np.asarray(out_t.result.member_of) == member_of).all()
            and (np.asarray(out_t.result.member_sim)
                 == np.asarray(res.member_sim)).all()
            and (np.asarray(out_t.result.is_rep) == is_rep).all()
            and (np.asarray(out_t.result.is_outlier) == is_out).all())

    # ring-pipelined collectives: the P-step ppermute schedules must be
    # bit-identical to their barrier twins on every label field, for the
    # dense and the top-K similarity paths, materializing and fused
    ring_cells = (
        ("p4_ring_dense", dict(halo_stream="ring"), out),
        ("p4_ring_dense_simring", dict(halo_stream="ring",
                                       sim_exchange="ring"), out),
        ("p4_ring_topk", dict(sim_mode="topk", sim_topk=48,
                              halo_stream="ring", sim_exchange="ring"),
         out),                 # barrier top-K == dense (asserted above)
        ("p4_ring_fused", dict(mode="fused", halo_stream="ring"), out_f),
    )
    for key, kw, twin in ring_cells:
        out_r = run_dsc_distributed(parts, params, mesh, **kw)
        report[key + "_agree"] = bool(all(
            (np.asarray(getattr(out_r.result, f))
             == np.asarray(getattr(twin.result, f))).all()
            for f in ("member_of", "member_sim", "is_rep", "is_outlier")))

    # the comm-schedule autotuner sweep: all four schedule candidates
    # must verify bit-identical against the barrier oracle, and the
    # winner must be a verified candidate
    from repro.tune.autotune import tune_comm
    tr = tune_comm(parts, params, mesh)
    report["comm_sweep_candidates"] = len(tr.candidates)
    report["comm_sweep_verified"] = sum(c.verified for c in tr.candidates)
    report["comm_winner_verified"] = bool(tr.winner.verified)

    print("JSON" + json.dumps(report))
""")


@pytest.fixture(scope="module")
def dist_report():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


@pytest.mark.distributed
@pytest.mark.slow
def test_p1_matches_single_host(dist_report):
    assert dist_report["p1_member_agree"] >= 0.999
    assert dist_report["p1_rep_agree"] >= 0.999


@pytest.mark.distributed
@pytest.mark.slow
def test_p4_structure(dist_report):
    assert dist_report["p4_reps"] > 0
    assert dist_report["p4_members"] > 0
    assert dist_report["p4_members_point_at_reps"]
    assert dist_report["p4_state_partition"]


@pytest.mark.distributed
@pytest.mark.slow
def test_p4_kernel_path(dist_report):
    assert dist_report["p4_kernel_agree"] >= 0.98


@pytest.mark.distributed
@pytest.mark.slow
def test_p4_index_pruned_join_agrees(dist_report):
    """use_index=True (halo bbox buckets + pair pruning) is lossless."""
    assert dist_report["p4_index_agree"] == 1.0


@pytest.mark.distributed
@pytest.mark.slow
def test_p4_fused_streaming_agrees(dist_report):
    """mode="fused" (no per-rank join cube) matches the materializing run."""
    assert dist_report["p4_fused_agree"] == 1.0
    assert dist_report["p4_fused_vote_close"]


@pytest.mark.distributed
@pytest.mark.slow
def test_p4_seg_kernel_identical(dist_report):
    """seg_use_kernel=True (fused Pallas TSA2 Jaccard kernel in phase 3)
    is bit-identical to the jnp packed-word engine end to end."""
    assert dist_report["p4_seg_kernel_agree"]


@pytest.mark.distributed
@pytest.mark.slow
def test_p4_cluster_engines_identical(dist_report):
    """Round-parallel vs sequential clustering engine, per partition +
    Algorithm 5 refinement: bit-identical global labels."""
    assert dist_report["p4_cluster_engine_agree"]


@pytest.mark.distributed
@pytest.mark.slow
def test_p4_topk_sim_identical(dist_report):
    """sim_mode="topk" (sparse SP relation: [S, K+1] allgather instead of
    the dense [S, S] psum) is bit-identical to the dense runs in both
    execution modes, with a clean exactness certificate."""
    for key in ("p4_topk", "p4_topk_fused"):
        assert dist_report[key + "_overflow"] == 0
        assert dist_report[key + "_agree"]


@pytest.mark.distributed
@pytest.mark.slow
def test_p4_ring_schedules_identical(dist_report):
    """halo_stream="ring" / sim_exchange="ring" (P-step ppermute schedules,
    DESIGN.md §12) are bit-identical to their barrier twins on every label
    field — dense and top-K similarity, materializing and fused."""
    for key in ("p4_ring_dense", "p4_ring_dense_simring", "p4_ring_topk",
                "p4_ring_fused"):
        assert dist_report[key + "_agree"], key


@pytest.mark.distributed
@pytest.mark.slow
def test_comm_schedule_sweep_all_verified(dist_report):
    """tune_comm: every barrier/ring schedule candidate verifies
    bit-identical against the barrier oracle; the winner is verified."""
    assert dist_report["comm_sweep_candidates"] == 4
    assert dist_report["comm_sweep_verified"] == 4
    assert dist_report["comm_winner_verified"]


def test_partitioning_is_equi_depth():
    from repro.core.partitioning import partition_batch
    from repro.data.synthetic import ais_like
    batch, _ = ais_like(n_vessels=32, max_points=64, seed=5)
    parts = partition_batch(batch, 4)
    counts = np.asarray(parts.valid).sum(axis=(1, 2))
    total = counts.sum()
    assert total == int(np.asarray(batch.valid).sum())
    assert counts.min() >= 0.5 * total / 4, counts  # balanced within 2x
    # every point's time inside its partition range
    t = np.asarray(parts.t)
    v = np.asarray(parts.valid)
    rng = np.asarray(parts.ranges)
    for p in range(4):
        tp = t[p][v[p]]
        if len(tp):
            assert (tp >= rng[p, 0] - 1e-5).all()
            assert (tp <= rng[p, 1] + 1e-5).all()
