"""Shared fixtures.  NOTE: no XLA_FLAGS device-count forcing here — smoke
tests and benches must see the real (single) device.  Multi-device tests
spawn subprocesses with their own flags (see test_distributed.py)."""
import sys

import numpy as np
import pytest

try:                                    # real hypothesis when installed (CI)
    import hypothesis  # noqa: F401
except ImportError:                     # deterministic fallback otherwise
    import importlib.util
    import os

    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"))
    _fb = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_fb)
    _mod = _fb.build_module()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

from repro.core.types import DSCParams
from repro.data.synthetic import ais_like, figure1_scenario


@pytest.fixture(scope="session")
def fig1():
    batch, labels = figure1_scenario(n_per_route=4, points_per_leg=24, seed=0)
    return batch, labels


@pytest.fixture(scope="session")
def ais():
    return ais_like(n_vessels=24, max_points=96, seed=1)


@pytest.fixture(scope="session")
def fig1_params():
    return DSCParams(eps_sp=0.42, eps_t=1.0, delta_t=0.0, w=6, tau=0.15,
                     alpha_sigma=-1.0, k_sigma=-1.0, segmentation="tsa2")
