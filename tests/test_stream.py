"""Streaming ingestion + windowed incremental DSC (DESIGN.md §13).

Covers the full robustness surface: the quarantine matrix per reason and
policy, watermark/lateness semantics, backpressure under both policies,
the streaming-vs-batch bit-parity anchor (standing lists, spill, labels
vs ``run_dsc`` over the same window), warm-vs-cold clustering identity,
kill-and-resume bit-identity after every Nth advance, dirty/late chaos
with the exact accounting invariant, the telemetry event stream, and the
launcher's stream exit codes (7 poison, 8 backpressure) as real
subprocesses.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.synthetic import dirtify, figure1_scenario, stream_records
from repro.run.faults import FaultInjector, FaultPlan, InjectedCrash
from repro.run.resilient import EXIT_CODES, Telemetry, read_telemetry
from repro.serve.stream import StreamService
from repro.stream import (QUARANTINE_REASONS, BackpressureOverflow,
                          Ingestor, PoisonRecord, Records, StreamConfig,
                          StreamDriver, WatermarkStall, WindowManager)

pytestmark = pytest.mark.stream


def small_config(**kw):
    base = dict(t_cap=16, m_cap=16, eps_sp=0.3, eps_t=2.0, alpha_abs=0.1,
                k_abs=0.0, allowed_lateness=4.0, horizon=1000.0,
                max_subs=4, k=8, w=2)
    base.update(kw)
    return StreamConfig(**base)


def small_stream(batch_size=24, **kw):
    batch, _ = figure1_scenario(n_per_route=2, points_per_leg=8, **kw)
    return batch, stream_records(batch, batch_size=batch_size)


# ---------------------------------------------------------------- ingest

def test_ingest_quarantine_reasons():
    ing = Ingestor(on_dirty="drop", max_speed=1.0)
    # clean baseline fix for obj 1 (teleport anchor)
    ing.process(Records.build([1], [0.0], [0.0], [0.0]))
    # nonfinite / duplicate / non-monotone / teleport in one submission
    recs = Records.build([1, 1, 1, 1, 1],
                         [np.nan, 1.0, 1.1, 99.0, 1.2],
                         [0.0, 0.0, 0.0, 0.0, 0.0],
                         [1.0, 2.0, 1.5, 3.0, 4.0])
    out = ing.process(recs)
    assert ing.counters["nonfinite"] == 1
    assert ing.counters["non_monotone"] == 1   # t=1.5 after t=2.0 admitted
    assert ing.counters["teleport"] == 1       # 98 units in 1s vs max 1/s
    assert out.n == 2                          # t=2.0 and t=4.0 survive
    dup = ing.process(Records.build([1], [1.2], [0.0], [4.0]))
    assert dup.n == 0 and ing.counters["duplicate"] == 1
    # every rejection is logged with its reason
    reasons = sorted(e["reason"] for e in ing.quarantine_log())
    assert reasons == sorted(
        ["nonfinite", "non_monotone", "teleport", "duplicate"])
    assert ing.submitted == 7
    assert ing.admitted + ing.quarantined_total() == 7


def test_ingest_repair_sorts_in_batch_swaps():
    rep = Ingestor(on_dirty="repair")
    out = rep.process(Records.build([5, 5, 5], [0.0, 1.0, 2.0],
                                    [0.0, 0.0, 0.0], [2.0, 1.0, 3.0]))
    assert out.n == 3 and list(out.t) == [1.0, 2.0, 3.0]
    assert rep.repaired_order > 0
    assert rep.counters["non_monotone"] == 0
    # drop mode quarantines the same swap instead of fixing it
    drp = Ingestor(on_dirty="drop")
    out = drp.process(Records.build([5, 5, 5], [0.0, 1.0, 2.0],
                                    [0.0, 0.0, 0.0], [2.0, 1.0, 3.0]))
    assert out.n == 2 and drp.counters["non_monotone"] == 1


def test_ingest_fail_mode_raises_poison():
    ing = Ingestor(on_dirty="fail")
    with pytest.raises(PoisonRecord):
        ing.process(Records.build([1], [np.nan], [0.0], [0.0]))


def test_ingest_state_roundtrip():
    ing = Ingestor(on_dirty="drop", max_speed=1.0)
    ing.process(Records.build([1, 2, 1], [0.0, 1.0, np.nan],
                              [0.0, 0.0, 0.0], [0.0, 0.0, 1.0]))
    st = ing.state_arrays()
    ing2 = Ingestor(on_dirty="drop", max_speed=1.0)
    ing2.load_state_arrays(st)
    assert ing2.counters == ing.counters
    assert ing2.submitted == ing.submitted
    assert ing2.admitted == ing.admitted
    assert ing2.quarantine_log() == ing.quarantine_log()
    assert ing2._last == ing._last


# --------------------------------------------------------------- dirtify

def test_dirtify_deterministic_with_ground_truth_counts():
    batch, batches = small_stream()
    d1, t1 = dirtify(batches, dup_frac=0.1, nan_frac=0.05,
                     teleport_frac=0.05, seed=11)
    d2, t2 = dirtify(batches, dup_frac=0.1, nan_frac=0.05,
                     teleport_frac=0.05, seed=11)
    assert t1 == t2
    for a, b in zip(d1, d2):
        for f in Records._fields:
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    assert t1["dup"] > 0 and t1["nan"] > 0 and t1["teleport"] > 0
    # ingest counters match the injected ground truth exactly — one
    # corruption at a time (a dup of a teleported record quarantines as
    # teleport, so combined runs only bound the per-reason totals)
    for kw, reason, key in ((dict(nan_frac=0.1), "nonfinite", "nan"),
                            (dict(dup_frac=0.1), "duplicate", "dup"),
                            (dict(teleport_frac=0.1), "teleport",
                             "teleport")):
        dirty, truth = dirtify(batches, seed=7, **kw)
        ing = Ingestor(on_dirty="drop", max_speed=5.0)
        for recs in dirty:
            ing.process(recs)
        assert truth[key] > 0
        assert ing.counters[reason] == truth[key], (reason, ing.counters)


def test_dirtify_swaps_are_repairable():
    batch, batches = small_stream()
    # traj-order stream has adjacent same-object records to swap
    batches = stream_records(batch, batch_size=24, order="traj")
    dirty, truth = dirtify(batches, swap_frac=0.5, seed=3)
    assert truth["swap_pairs"] > 0
    ing = Ingestor(on_dirty="repair")
    n_admitted = sum(ing.process(r).n for r in dirty)
    assert ing.counters["non_monotone"] == 0      # repair fixed every swap
    assert n_admitted == sum(r.n for r in dirty)
    assert ing.repaired_order > 0


# ---------------------------------------------------------------- window

def test_watermark_monotone_and_late_dropped():
    wm = WindowManager(allowed_lateness=2.0, horizon=10.0)
    wm.stage(Records.build([1, 1], [0, 0], [0, 0], [10.0, 5.0]))
    admitted, late = wm.drain()
    assert wm.watermark == 8.0            # max(10) - 2
    assert late == 1 and admitted.n == 1  # t=5 < 8 dropped, counted
    # watermark never regresses
    wm.stage(Records.build([1], [0], [0], [3.0]))
    admitted, late = wm.drain()
    assert wm.watermark == 8.0 and late == 1 and admitted.n == 0
    assert wm.late_dropped == 2
    assert wm.evict_before() == pytest.approx(-2.0)


def test_watermark_stall_raises():
    wm = WindowManager(allowed_lateness=5.0, horizon=10.0,
                       stall_advances=2)
    wm.stage(Records.build([1], [0], [0], [100.0]))
    wm.drain()                                     # W = 95
    wm.stage(Records.build([1], [0], [0], [10.0]))
    wm.drain()                                     # stalled once
    wm.stage(Records.build([1], [0], [0], [11.0]))
    with pytest.raises(WatermarkStall):
        wm.drain()                                 # stalled twice


def test_backpressure_shed_oldest_counts_everything():
    wm = WindowManager(allowed_lateness=1.0, horizon=10.0, queue_cap=5,
                       policy="shed_oldest")
    wm.stage(Records.build(np.arange(4), np.zeros(4), np.zeros(4),
                           np.arange(4, dtype=float)))
    shed = wm.stage(Records.build(np.arange(4), np.zeros(4), np.zeros(4),
                                  4.0 + np.arange(4, dtype=float)))
    assert shed == 3 and wm.shed == 3 and wm.queued() == 5
    assert wm.staged_total == 8            # nothing vanished unaccounted


def test_backpressure_block_raises_and_undoes():
    wm = WindowManager(allowed_lateness=5.0, horizon=10.0, queue_cap=5,
                       policy="block")
    wm.stage(Records.build(np.arange(4), np.zeros(4), np.zeros(4),
                           np.arange(4, dtype=float)))
    with pytest.raises(BackpressureOverflow):
        wm.stage(Records.build(np.arange(4), np.zeros(4), np.zeros(4),
                               4.0 + np.arange(4, dtype=float)))
    assert wm.queued() == 4                # the enqueue was rolled back
    admitted, _ = wm.drain()
    assert admitted.n == 4                 # earlier records intact


# ------------------------------------------------- streaming == batch DSC

def drive(cfg, batches, **svc_kw):
    svc = StreamService(cfg, **svc_kw)
    svc.run(batches)
    return svc


def assert_matches_batch_oracle(drv):
    """Standing lists, spill and labels == run_dsc over the same window."""
    from repro.core.dsc import run_dsc
    out = run_dsc(drv.window_batch(), drv.config.params, sim_mode="topk",
                  sim_topk=drv.config.k, on_overflow="degrade")
    K = drv.config.k
    np.testing.assert_array_equal(np.asarray(out.sim_topk.ids),
                                  drv.standing_ids[:, :K])
    np.testing.assert_array_equal(np.asarray(out.sim_topk.sims),
                                  drv.standing_sims[:, :K])
    np.testing.assert_array_equal(np.asarray(out.sim_topk.spill),
                                  drv.standing_sims[:, K])
    r = out.result
    np.testing.assert_array_equal(np.asarray(r.member_of), drv.member_of)
    np.testing.assert_array_equal(np.asarray(r.member_sim), drv.member_sim)
    np.testing.assert_array_equal(np.asarray(r.is_rep), drv.is_rep)
    np.testing.assert_array_equal(np.asarray(r.is_outlier), drv.is_outlier)


def test_streaming_matches_batch_at_every_advance():
    batch, batches = small_stream()
    cfg = small_config()
    svc = StreamService(cfg)
    for i, recs in enumerate(batches):
        svc.driver.submit(recs)
        svc.driver.advance()
        assert_matches_batch_oracle(svc.driver)
    assert svc.accounting()["balanced"]
    assert svc.stats()["reps"] > 0


def test_streaming_matches_batch_with_eviction():
    batch, batches = small_stream()
    cfg = small_config(horizon=8.0, allowed_lateness=2.0)
    svc = StreamService(cfg)
    evicted = 0
    for recs in batches:
        svc.driver.submit(recs)
        s = svc.driver.advance()
        evicted += s.get("evicted", 0) if isinstance(s, dict) else 0
        assert_matches_batch_oracle(svc.driver)
    assert evicted > 0                    # the horizon actually evicted
    assert svc.accounting()["balanced"]


def test_warm_start_labels_equal_cold_start():
    batch, batches = small_stream()
    warm = drive(small_config(warm_start=True), batches)
    cold = drive(small_config(warm_start=False), batches)
    for attr in ("standing_ids", "standing_sims", "member_of",
                 "member_sim", "is_rep", "is_outlier"):
        np.testing.assert_array_equal(getattr(warm.driver, attr),
                                      getattr(cold.driver, attr))


def test_row_capacity_overflow_drops_oldest_and_counts():
    cfg = small_config(t_cap=4, m_cap=4, allowed_lateness=100.0)
    drv = StreamDriver(cfg)
    recs = Records.build([7] * 6, np.arange(6, dtype=float),
                         np.zeros(6), np.arange(6, dtype=float))
    drv.submit(recs)
    drv.advance()
    assert drv.row_overflow == 2          # 6 points into a 4-slot row
    r = drv._row_of[7]
    np.testing.assert_array_equal(drv.ts[r][drv.valid[r]],
                                  [2.0, 3.0, 4.0, 5.0])
    assert drv.accounting()["balanced"]


# ---------------------------------------------------------- kill + resume

def reference_run(cfg, batches, tmp_path, tag):
    svc = StreamService(cfg, checkpoint_dir=str(tmp_path / tag))
    svc.run(batches)
    return svc


def state_fingerprint(svc):
    d = svc.driver
    return {
        "ids": d.standing_ids.copy(), "sims": d.standing_sims.copy(),
        "member_of": d.member_of.copy(), "is_rep": d.is_rep.copy(),
        "is_outlier": d.is_outlier.copy(), "valid": d.valid.copy(),
        "ts": d.ts.copy(), "quarantine": dict(d.ingest.counters),
        "stats": svc.stats(), "accounting": svc.accounting(),
        "qlog": d.ingest.quarantine_log(),
    }


def assert_same_state(a, b):
    for k in a:
        if isinstance(a[k], np.ndarray):
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
        else:
            assert a[k] == b[k], k


@pytest.mark.slow
def test_kill_and_resume_at_every_advance(tmp_path):
    """Kill at every Nth window advance; the resumed service must land
    bit-identically on the uninterrupted run — lists, labels, window
    contents, quarantine books, the lot."""
    batch, batches = small_stream()
    cfg = small_config(snapshot_every=1)
    ref = state_fingerprint(reference_run(cfg, batches, tmp_path, "ref"))
    n_adv = len(batches)
    for kill_at in range(1, n_adv):
        ck = str(tmp_path / f"kill{kill_at}")
        inj = FaultInjector(FaultPlan(crash_at_advance=kill_at))
        svc = StreamService(cfg, checkpoint_dir=ck, injector=inj)
        with pytest.raises(InjectedCrash):
            svc.run(batches)
        # resumed run: NO fault plan (the crash already happened)
        svc2 = StreamService(cfg, checkpoint_dir=ck)
        assert svc2.resumed and svc2.driver.advance_count == kill_at
        svc2.run(batches)
        assert_same_state(ref, state_fingerprint(svc2))


def test_kill_and_resume_once(tmp_path):
    """Tier-1-speed single-kill variant of the full matrix above."""
    batch, batches = small_stream()
    cfg = small_config(snapshot_every=1)
    ref = state_fingerprint(reference_run(cfg, batches, tmp_path, "ref"))
    ck = str(tmp_path / "kill")
    inj = FaultInjector(FaultPlan(crash_at_advance=3))
    svc = StreamService(cfg, checkpoint_dir=ck, injector=inj)
    with pytest.raises(InjectedCrash):
        svc.run(batches)
    svc2 = StreamService(cfg, checkpoint_dir=ck)
    assert svc2.resumed
    svc2.run(batches)
    assert_same_state(ref, state_fingerprint(svc2))


def test_resume_refuses_other_config(tmp_path):
    batch, batches = small_stream()
    cfg = small_config(snapshot_every=1)
    svc = StreamService(cfg, checkpoint_dir=str(tmp_path / "ck"))
    svc.run(batches[:2])
    other = small_config(snapshot_every=1, eps_sp=0.31)
    with pytest.raises(ValueError, match="different schema/config"):
        StreamService(other, checkpoint_dir=str(tmp_path / "ck"))


def test_snapshot_refuses_nonempty_queue(tmp_path):
    batch, batches = small_stream()
    cfg = small_config()
    drv = StreamDriver(cfg, checkpoint_dir=str(tmp_path / "ck"))
    drv.submit(batches[0])
    with pytest.raises(RuntimeError, match="staging queue"):
        drv.snapshot()


# ------------------------------------------------------------ chaos suite

def test_chaos_never_crashes_and_accounts_for_everything():
    """Under scripted dirty/late/dup chaos the service must keep
    serving — no exception, and the accounting invariant holds exactly:
    every submitted record is inserted, quarantined, late-dropped, shed,
    or still queued."""
    batch, batches = small_stream()
    plan = FaultPlan(stream_late_burst=((2, 50.0), (5, 120.0)),
                     stream_dup_storm=(3, 6), stream_poison=((1, 4),),
                     stream_stall=(4,))
    svc = StreamService(small_config(max_speed=100.0),
                        injector=FaultInjector(plan))
    svc.run(batches)
    acc = svc.accounting()
    assert acc["balanced"], acc
    assert acc["quarantined"] > 0          # poison + dup storms were booked
    assert acc["late_dropped"] > 0         # the late bursts were counted
    assert svc.driver.ingest.counters["nonfinite"] >= 1
    assert svc.driver.ingest.counters["duplicate"] > 0


def test_chaos_dirty_stream_still_matches_batch_of_admitted():
    """Even under chaos the standing state equals the batch pipeline run
    over exactly the records that were admitted."""
    batch, batches = small_stream()
    plan = FaultPlan(stream_poison=((0, 2), (3, 7)))
    svc = StreamService(small_config(), injector=FaultInjector(plan))
    svc.run(batches)
    assert_matches_batch_oracle(svc.driver)


def test_stall_batches_defer_advance():
    batch, batches = small_stream()
    plan = FaultPlan(stream_stall=tuple(range(len(batches) - 1)))
    svc = StreamService(small_config(), injector=FaultInjector(plan))
    svc.run(batches)
    # all advances deferred to the final drain => exactly one advance
    assert svc.driver.advance_count == 1
    assert svc.accounting()["balanced"]


# ------------------------------------------------------------- telemetry

def test_stream_telemetry_events(tmp_path):
    path = tmp_path / "telemetry.jsonl"
    clock_t = [0.0]

    def clock():
        clock_t[0] += 1.0
        return clock_t[0]

    batch, batches = small_stream()
    plan = FaultPlan(stream_late_burst=((2, 80.0),), stream_poison=((1, 0),))
    svc = StreamService(small_config(queue_cap=16),
                        telemetry=Telemetry(path, clock),
                        injector=FaultInjector(plan))
    svc.run(batches)
    events = read_telemetry(path)
    by = {}
    for e in events:
        by.setdefault(e["event"], []).append(e)
    assert "window_advanced" in by
    assert "record_quarantined" in by       # the poison record
    assert "late_dropped" in by             # the late burst
    assert "backpressure" in by             # queue_cap=30 < batch size
    adv = by["window_advanced"][-1]
    for key in ("advance", "watermark", "dirty_rows", "rounds",
                "warm_prefix", "reps", "outliers"):
        assert key in adv, key
    q = by["record_quarantined"][0]
    assert q["total"] >= 1 and q.get("nonfinite", 0) >= 1
    # events survive a reader round-trip with the schema tag intact
    assert all(e["schema"] == 1 for e in events)


# ------------------------------------------------------- config validation

def test_stream_config_validation():
    with pytest.raises(ValueError, match="absolute thresholds"):
        small_config(alpha_abs=-1.0).validate()
    with pytest.raises(ValueError, match="horizon"):
        small_config(horizon=1.0, allowed_lateness=5.0).validate()
    with pytest.raises(ValueError, match="segmentation"):
        small_config(segmentation="nope").validate()
    with pytest.raises(ValueError, match="backpressure"):
        small_config(backpressure="nope").validate()
    cfg = small_config()
    assert StreamConfig.from_dict(cfg.to_dict()) == cfg
    assert cfg.fingerprint() == StreamConfig.from_dict(
        cfg.to_dict()).fingerprint()
    assert cfg.fingerprint() != small_config(eps_sp=0.31).fingerprint()


def test_fault_plan_stream_fields_roundtrip():
    plan = FaultPlan(stream_late_burst=((2, 50.0),), stream_dup_storm=(3,),
                     stream_poison=((1, 4),), stream_stall=(4,),
                     crash_at_advance=7)
    back = FaultPlan.from_json(plan.to_json())
    assert back == plan
    with pytest.raises(ValueError, match="crash_at_advance"):
        FaultPlan(crash_at_advance=-2).validate()
    with pytest.raises(ValueError, match="stream_poison"):
        FaultPlan(stream_poison=((-1, 0),)).validate()


# ---------------------------------------------------------------- queries

def test_query_api_reports_cluster_membership():
    batch, batches = small_stream()
    svc = drive(small_config(), batches)
    drv = svc.driver
    seen_rep = seen_member = False
    for obj in np.asarray(drv.obj_of_row):
        if obj < 0:
            continue
        q = svc.query(int(obj))
        assert q["in_window"] and q["subtrajs"]
        for sub in q["subtrajs"]:
            assert sub["t_end"] >= sub["t_start"]
            if sub["is_rep"]:
                seen_rep = True
                assert sub["cluster"]["rep_slot"] == sub["slot"]
            elif sub["cluster"] is not None:
                seen_member = True
                assert sub["cluster"]["rep_obj"] >= 0
    assert seen_rep and seen_member
    assert not svc.query(99999)["in_window"]


# ------------------------------------------------------ launcher exit codes

def run_launcher(*flags):
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.run_dsc", "--stream",
         "--n-trajs", "12", "--stream-batch-size", "48", *flags],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.mark.slow
def test_launcher_stream_exit_codes(tmp_path):
    poison = tmp_path / "poison.json"
    poison.write_text(json.dumps({"stream_poison": [[1, 3]]}))
    ok = run_launcher()
    assert ok.returncode == EXIT_CODES["ok"], ok.stderr[-2000:]
    po = run_launcher("--on-dirty", "fail", "--fault-plan", str(poison))
    assert po.returncode == EXIT_CODES["poison"] == 7, po.stderr[-2000:]
    bp = run_launcher("--backpressure", "block", "--queue-cap", "10")
    assert bp.returncode == EXIT_CODES["backpressure"] == 8, \
        bp.stderr[-2000:]


@pytest.mark.slow
def test_launcher_stream_resume_roundtrip(tmp_path):
    crash = tmp_path / "crash.json"
    crash.write_text(json.dumps({"crash_at_advance": 3}))
    ck = str(tmp_path / "svc")
    first = run_launcher("--resume-dir", ck, "--fault-plan", str(crash))
    assert first.returncode == EXIT_CODES["injected_crash"] == 6
    second = run_launcher("--resume-dir", ck)
    assert second.returncode == 0, second.stderr[-2000:]
    assert "resumed stream service" in second.stderr
    events = read_telemetry(os.path.join(ck, "telemetry.jsonl"))
    assert any(e["event"] == "window_advanced" for e in events)
