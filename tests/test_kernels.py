"""Per-kernel validation: shape/dtype sweeps + allclose vs the ref.py oracle,
plus hypothesis property tests on the kernels' invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels.jaccard.ops import window_jaccard
from repro.kernels.jaccard.ref import jaccard_ref
from repro.kernels.lcss.ops import lcss_scores, lcss_similarity
from repro.kernels.lcss.ref import lcss_ref
from repro.kernels.stjoin.ops import best_match_join_kernel
from repro.kernels.stjoin.ref import stjoin_ref


def _rand_points(rng, T, M):
    x = rng.uniform(0, 10, (T, M)).astype(np.float32)
    y = rng.uniform(0, 10, (T, M)).astype(np.float32)
    t = np.sort(rng.uniform(0, 50, (T, M)), axis=1).astype(np.float32)
    v = rng.uniform(0, 1, (T, M)) > 0.15
    ids = np.arange(T, dtype=np.int32)
    return x, y, t, v, ids


# ----------------------------- stjoin ---------------------------------------

@pytest.mark.parametrize("T,M,C,Mc,bp,bc,bm", [
    (8, 32, 8, 32, 64, 4, 32),
    (4, 64, 8, 16, 32, 8, 16),
    (16, 16, 4, 64, 256, 2, 32),
    (3, 24, 5, 40, 8, 1, 8),       # ragged -> exercises padding
])
def test_stjoin_shapes(T, M, C, Mc, bp, bc, bm):
    rng = np.random.default_rng(T * 100 + M)
    rx, ry, rt, rv, rid = _rand_points(rng, T, M)
    cx, cy, ct, cv, cid = _rand_points(rng, C, Mc)
    from repro.core.types import TrajectoryBatch
    ref_b = TrajectoryBatch(x=jnp.asarray(rx), y=jnp.asarray(ry),
                            t=jnp.asarray(rt), valid=jnp.asarray(rv),
                            traj_id=jnp.asarray(rid))
    cand_b = TrajectoryBatch(x=jnp.asarray(cx), y=jnp.asarray(cy),
                             t=jnp.asarray(ct), valid=jnp.asarray(cv),
                             traj_id=jnp.asarray(cid))
    got = best_match_join_kernel(ref_b, cand_b, 2.0, 10.0,
                                 bp=bp, bc=bc, bm=bm)
    want_w, want_idx = stjoin_ref(
        jnp.asarray(rx.reshape(-1)), jnp.asarray(ry.reshape(-1)),
        jnp.asarray(rt.reshape(-1)),
        jnp.asarray(np.repeat(rid, M)), jnp.asarray(rv.reshape(-1)),
        jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(ct),
        jnp.asarray(cid), jnp.asarray(cv), 2.0, 10.0)
    np.testing.assert_allclose(np.asarray(got.best_w).reshape(T * M, C),
                               np.asarray(want_w), atol=1e-5)
    assert (np.asarray(got.best_idx).reshape(T * M, C)
            == np.asarray(want_idx)).all()


def test_stjoin_symmetry_of_matching():
    """If r_i matches some point of trajectory s, then s has a point whose
    best match set includes r's trajectory (cylinder symmetry)."""
    rng = np.random.default_rng(7)
    x, y, t, v, ids = _rand_points(rng, 6, 32)
    from repro.core.types import TrajectoryBatch
    b = TrajectoryBatch(x=jnp.asarray(x), y=jnp.asarray(y), t=jnp.asarray(t),
                        valid=jnp.asarray(v), traj_id=jnp.asarray(ids))
    got = best_match_join_kernel(b, b, 3.0, 10.0, bp=8, bc=2, bm=8)
    w = np.asarray(got.best_w)          # [T, M, C]
    pair = w.sum(axis=1) > 0            # [T, C] r matched c somewhere
    assert (pair == pair.T).all()


def test_stjoin_excludes_self():
    rng = np.random.default_rng(3)
    x, y, t, v, ids = _rand_points(rng, 4, 16)
    from repro.core.types import TrajectoryBatch
    b = TrajectoryBatch(x=jnp.asarray(x), y=jnp.asarray(y), t=jnp.asarray(t),
                        valid=jnp.asarray(v), traj_id=jnp.asarray(ids))
    got = best_match_join_kernel(b, b, 100.0, 1e9, bp=8, bc=2, bm=8)
    w = np.asarray(got.best_w)
    for r in range(4):
        assert (w[r, :, r] == 0).all()


# ----------------------------- lcss -----------------------------------------

@pytest.mark.parametrize("B,N,M", [(2, 16, 16), (3, 8, 24), (1, 33, 17)])
def test_lcss_matches_ref(B, N, M):
    rng = np.random.default_rng(B * 7 + N)
    rx = jnp.asarray(rng.uniform(0, 5, (B, N)), jnp.float32)
    ry = jnp.asarray(rng.uniform(0, 5, (B, N)), jnp.float32)
    rt = jnp.asarray(np.sort(rng.uniform(0, 50, (B, N)), 1), jnp.float32)
    rv = jnp.asarray(rng.uniform(0, 1, (B, N)) > 0.1)
    sx = jnp.asarray(rng.uniform(0, 5, (B, M)), jnp.float32)
    sy = jnp.asarray(rng.uniform(0, 5, (B, M)), jnp.float32)
    stm = jnp.asarray(np.sort(rng.uniform(0, 50, (B, M)), 1), jnp.float32)
    sv = jnp.asarray(rng.uniform(0, 1, (B, M)) > 0.1)
    want = np.maximum(np.asarray(
        lcss_ref(rx, ry, rt, rv, sx, sy, stm, sv, 2.0, 25.0)), 0.0)
    got = np.asarray(lcss_scores(rx, ry, rt, rv, sx, sy, stm, sv, 2.0, 25.0))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_lcss_identical_sequences_full_score():
    rng = np.random.default_rng(0)
    N = 24
    x = jnp.asarray(rng.uniform(0, 5, (1, N)), jnp.float32)
    y = jnp.asarray(rng.uniform(0, 5, (1, N)), jnp.float32)
    t = jnp.asarray(np.sort(rng.uniform(0, 50, (1, N)), 1), jnp.float32)
    v = jnp.ones((1, N), bool)
    sim = np.asarray(lcss_similarity(x, y, t, v, x, y, t, v, 2.0, 25.0))
    np.testing.assert_allclose(sim[0, 0], 1.0, atol=1e-5)  # weighted
    np.testing.assert_allclose(sim[0, 1], 1.0, atol=1e-5)  # classic


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_lcss_symmetry(seed):
    rng = np.random.default_rng(seed)
    N, M = 12, 12
    rx = jnp.asarray(rng.uniform(0, 5, (1, N)), jnp.float32)
    ry = jnp.asarray(rng.uniform(0, 5, (1, N)), jnp.float32)
    rt = jnp.asarray(np.sort(rng.uniform(0, 20, (1, N)), 1), jnp.float32)
    sx = jnp.asarray(rng.uniform(0, 5, (1, M)), jnp.float32)
    sy = jnp.asarray(rng.uniform(0, 5, (1, M)), jnp.float32)
    stm = jnp.asarray(np.sort(rng.uniform(0, 20, (1, M)), 1), jnp.float32)
    v = jnp.ones((1, N), bool)
    a = np.asarray(lcss_scores(rx, ry, rt, v, sx, sy, stm, v, 2.0, 10.0))
    b = np.asarray(lcss_scores(sx, sy, stm, v, rx, ry, rt, v, 2.0, 10.0))
    np.testing.assert_allclose(a, b, atol=1e-4)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_lcss_bounds(seed):
    """0 <= weighted score <= classic count <= min(n, m)."""
    rng = np.random.default_rng(seed)
    N, M = 10, 14
    rx = jnp.asarray(rng.uniform(0, 5, (1, N)), jnp.float32)
    ry = jnp.asarray(rng.uniform(0, 5, (1, N)), jnp.float32)
    rt = jnp.asarray(np.sort(rng.uniform(0, 20, (1, N)), 1), jnp.float32)
    rv = jnp.asarray(rng.uniform(0, 1, (1, N)) > 0.2)
    sx = jnp.asarray(rng.uniform(0, 5, (1, M)), jnp.float32)
    sy = jnp.asarray(rng.uniform(0, 5, (1, M)), jnp.float32)
    stm = jnp.asarray(np.sort(rng.uniform(0, 20, (1, M)), 1), jnp.float32)
    sv = jnp.asarray(rng.uniform(0, 1, (1, M)) > 0.2)
    s = np.asarray(lcss_scores(rx, ry, rt, rv, sx, sy, stm, sv, 2.0, 10.0))[0]
    n = int(np.asarray(rv).sum())
    m = int(np.asarray(sv).sum())
    assert 0.0 <= s[0] <= s[1] + 1e-5
    assert s[1] <= min(n, m) + 1e-5


# ----------------------------- jaccard --------------------------------------

@pytest.mark.parametrize("T,M,W,w", [(4, 32, 1, 4), (8, 64, 3, 7),
                                     (2, 128, 2, 16), (5, 40, 4, 5)])
def test_jaccard_matches_ref(T, M, W, w):
    rng = np.random.default_rng(T + M + W)
    masks = jnp.asarray(
        rng.integers(0, 2 ** 31, (T, M, W)).astype(np.uint32))
    valid = jnp.asarray(rng.uniform(0, 1, (T, M)) > 0.1)
    masked = jnp.where(valid[..., None], masks, jnp.uint32(0))
    want = np.asarray(jaccard_ref(masked, w))
    got = np.asarray(window_jaccard(masks, valid, w=w))
    np.testing.assert_allclose(got, want, atol=1e-6)


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_jaccard_range_and_identical_windows(seed, w):
    rng = np.random.default_rng(seed)
    T, M, W = 2, 32, 2
    # constant masks -> identical windows -> d == 0 in the interior
    row = rng.integers(0, 2 ** 31, (1, 1, W)).astype(np.uint32)
    masks = jnp.asarray(np.broadcast_to(row, (T, M, W)).copy())
    valid = jnp.ones((T, M), bool)
    d = np.asarray(window_jaccard(masks, valid, w=w))
    assert (d >= 0).all() and (d <= 1).all()
    assert np.allclose(d[:, w:M - w], 0.0, atol=1e-6)
