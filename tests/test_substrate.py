"""Substrate tests: checkpointing, optimizer, compression, straggler
monitor, data pipeline, serving engine."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.checkpointer import latest_step
from repro.data.pipeline import TokenPipeline
from repro.distributed.straggler import StragglerMonitor
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.grad_compress import (compress_int8, decompress_int8,
                                       ef_init)


# --------------------------- checkpoint --------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 7, tree)
    got, step = load_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, tree))
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    assert latest_step(tmp_path) == 4


def test_checkpoint_async_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(5)
    mgr.save_async(11, tree)
    mgr.wait()
    got, step = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 11
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_detects_corruption(tmp_path):
    tree = _tree()
    path = save_checkpoint(tmp_path, 1, tree)
    victim = sorted(path.glob("leaf_*.npy"))[0]
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        load_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, tree))


def test_checkpoint_atomicity_tmp_litter(tmp_path):
    (tmp_path / "step_000000009.tmp-zombie").mkdir(parents=True)
    save_checkpoint(tmp_path, 9, _tree())
    assert not list(tmp_path.glob("*.tmp-*"))
    assert latest_step(tmp_path) == 9


# --------------------------- optimizer ----------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - jnp.asarray([1.0, 2.0, -1.0])) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        g, _ = clip_by_global_norm(g, 10.0)
        params, opt = adamw_update(g, opt, params, lr=0.05,
                                   weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_grad_clip_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_int8_error_feedback_preserves_signal():
    """Sum of dequantized payloads + final residual == sum of true grads
    (error feedback conserves gradient mass)."""
    rng = np.random.default_rng(0)
    grads_seq = [
        {"w": jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)}
        for _ in range(20)]
    ef = ef_init(grads_seq[0])
    applied = jnp.zeros(32)
    true = jnp.zeros(32)
    for g in grads_seq:
        payload, ef = compress_int8(g, ef)
        deq = decompress_int8(payload)
        applied = applied + deq["w"]
        true = true + g["w"].astype(jnp.float32)
    resid = ef["w"]
    np.testing.assert_allclose(np.asarray(applied + resid),
                               np.asarray(true), atol=1e-4)


# --------------------------- straggler ---------------------------------------

def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(n_hosts=4, window=8, ratio_threshold=1.4)
    for step in range(20):
        times = [0.10, 0.11, 0.10, 0.10]
        times[2] = 0.25          # host 2 is slow
        mon.record_all(times)
    flagged = mon.check()
    assert 2 in flagged and flagged[2] > 1.4
    assert all(h == 2 for h in flagged)


def test_straggler_change_detection():
    mon = StragglerMonitor(n_hosts=1, window=8)
    for _ in range(8):
        mon.record(0, 0.1)
    for _ in range(8):
        mon.record(0, 0.3)       # becomes slow
    assert mon.change_detected(0, tau=0.5)


# --------------------------- data pipeline -----------------------------------

def test_pipeline_deterministic_and_restartable():
    from repro.configs import get_arch, reduced_config
    cfg = reduced_config(get_arch("smollm-360m"))
    p1 = TokenPipeline(cfg, batch=4, seq_len=32, seed=3)
    p2 = TokenPipeline(cfg, batch=4, seq_len=32, seed=3)
    b5a = p1.batch_at(5)
    b5b = p2.batch_at(5)          # fresh instance, same (seed, step)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    b6 = p1.batch_at(6)
    assert not np.array_equal(b5a["tokens"], b6["tokens"])


def test_pipeline_is_learnable_structure():
    from repro.configs import get_arch, reduced_config
    cfg = reduced_config(get_arch("smollm-360m"))
    p = TokenPipeline(cfg, batch=8, seq_len=64, seed=0)
    b = p.batch_at(0)
    # consecutive-token entropy must be far below uniform
    V = p.V
    pairs = {}
    toks, labs = b["tokens"], b["labels"]
    for i in range(toks.shape[0]):
        for t in range(toks.shape[1]):
            pairs.setdefault(int(toks[i, t]), set()).add(int(labs[i, t]))
    branching = np.mean([len(v) for v in pairs.values()])
    assert branching <= 12, branching   # ~8 successors + noise << V


# --------------------------- serving engine ----------------------------------

def test_serve_engine_waves_complete():
    from repro.configs import get_arch, reduced_config
    from repro.models import transformer as tf
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced_config(get_arch("smollm-360m"))
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, n_slots=3, max_len=48)
    rng = np.random.default_rng(0)
    for uid in range(7):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
            max_new=5))
    done = eng.run()
    assert len(done) == 7
    assert all(r.done and len(r.out) == 5 for r in done)
    assert eng.prefill_calls == 3     # 3+3+1 requests in 3 waves


def test_serve_greedy_matches_forward():
    """Engine greedy decode == argmax chain from repeated full forwards."""
    from repro.configs import get_arch, reduced_config
    from repro.models import transformer as tf
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced_config(get_arch("yi-6b"))
    params = tf.init_model(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)

    eng = ServeEngine(params, cfg, n_slots=1, max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=4))
    out = eng.run()[0].out

    seq = list(prompt)
    want = []
    for _ in range(4):
        logits, _, _ = tf.forward(
            params, jnp.asarray(np.asarray(seq)[None]), cfg)
        tok = int(np.argmax(np.asarray(logits)[0, -1]))
        want.append(tok)
        seq.append(tok)
    assert out == want
