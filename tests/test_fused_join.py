"""Fused streaming join (epilogue fusion) vs the materializing oracle.

The fused kernels never build the [T, M, C] JoinResult cube; these tests pin
their three accumulators — vote sums (Eq. 4), bit-packed neighbor words
(Alg. 3 input), and the raw similarity scatter (Eq. 2) — against the
materializing reference path, including delta_t refinement, all-padding
rows, and shapes that leave ragged last tiles after padding.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import geometry, segmentation, similarity, voting
from repro.core.dsc import run_dsc
from repro.core.types import DSCParams, TrajectoryBatch
from repro.kernels.stjoin import ops as stjoin_ops


def _rand_batch(rng, T, M, pad_row=None):
    x = rng.uniform(0, 10, (T, M)).astype(np.float32)
    y = rng.uniform(0, 10, (T, M)).astype(np.float32)
    t = np.sort(rng.uniform(0, 50, (T, M)), axis=1).astype(np.float32)
    v = rng.uniform(0, 1, (T, M)) > 0.15
    ids = np.arange(T, dtype=np.int32)
    if pad_row is not None:
        v[pad_row] = False
        ids[pad_row] = -1
    return TrajectoryBatch(x=jnp.asarray(x), y=jnp.asarray(y),
                           t=jnp.asarray(t), valid=jnp.asarray(v),
                           traj_id=jnp.asarray(ids))


def _reference(ref, cand, eps_sp, eps_t, delta_t):
    join = geometry.subtrajectory_join(ref, cand, eps_sp, eps_t, delta_t)
    return (join, voting.point_voting(join),
            voting.neighbor_mask_packed(join))


def _reference_raw_sim(join, ref_seg, cand_seg, max_subs):
    """Un-normalized SP scatter straight from the cube (cross-join form)."""
    T, M, C = join.best_w.shape
    Mc = cand_seg.sub_local.shape[1]
    n_src, n_dst = T * max_subs, C * max_subs
    src = jnp.where(ref_seg.sub_local >= 0,
                    jnp.arange(T)[:, None] * max_subs + ref_seg.sub_local,
                    n_src)
    src = jnp.broadcast_to(src[:, :, None], (T, M, C))
    idx = jnp.clip(join.best_idx, 0, Mc - 1)
    csub = cand_seg.sub_local[jnp.arange(C)[None, None, :], idx]
    dst = jnp.where((join.best_idx >= 0) & (csub >= 0),
                    jnp.arange(C)[None, None, :] * max_subs + csub, n_dst)
    raw = jnp.zeros((n_src + 1, n_dst + 1), jnp.float32)
    raw = raw.at[src.reshape(-1), dst.reshape(-1)].add(
        join.best_w.reshape(-1))
    return raw[:n_src, :n_dst]


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0.0, 4.0, 20.0]))
@settings(max_examples=8, deadline=None)
def test_fused_vote_and_masks_match_reference(seed, delta_t):
    rng = np.random.default_rng(seed)
    b = _rand_batch(rng, 5, 20, pad_row=int(seed) % 5)
    join, want_vote, want_words = _reference(b, b, 2.5, 12.0, delta_t)
    vote, words = stjoin_ops.stjoin_vote_fused(
        b, b, 2.5, 12.0, delta_t, rows=2, bc=2, bm=8)
    np.testing.assert_allclose(np.asarray(vote), np.asarray(want_vote),
                               atol=1e-5)
    assert (np.asarray(words) == np.asarray(want_words)).all()


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=6, deadline=None)
def test_fused_pruned_matches_dense_fused(seed):
    """The index-pruned fused sweep is lossless (conservative pruning)."""
    rng = np.random.default_rng(seed)
    b = _rand_batch(rng, 6, 16)
    _, want_vote, want_words = _reference(b, b, 2.0, 10.0, 3.0)
    tiles = stjoin_ops.plan_fused_tiles(
        b.x, b.y, b.t, b.valid, b.x, b.y, b.t, b.valid, 2.0, 10.0,
        rows=2, bc=2, bm=8)
    vote, words = stjoin_ops.stjoin_vote_fused_arrays(
        b.x, b.y, b.t, b.valid, b.traj_id,
        b.x, b.y, b.t, b.valid, b.traj_id,
        2.0, 10.0, 3.0, rows=2, bc=2, bm=8, tile_ids=tiles)
    np.testing.assert_allclose(np.asarray(vote), np.asarray(want_vote),
                               atol=1e-5)
    assert (np.asarray(words) == np.asarray(want_words)).all()


@pytest.mark.parametrize("T,M,C,Mc,rows,bc,bm,delta_t", [
    (5, 17, 7, 13, 3, 8, 8, 0.0),      # everything ragged
    (5, 17, 7, 13, 3, 8, 8, 7.0),
    (3, 40, 35, 11, 2, 32, 128, 0.0),  # bc == word width; bm > Mc
    (4, 8, 4, 8, 8, 4, 4, 7.0),        # rows > T (whole batch one block)
])
def test_fused_sim_matches_reference_cross_join(T, M, C, Mc, rows, bc, bm,
                                                delta_t):
    """Pass 2 against the cube scatter, with independent candidate-side
    segmentation (the cross-join form the distributed pipeline uses)."""
    rng = np.random.default_rng(T * 1000 + C)
    b = _rand_batch(rng, T, M, pad_row=0)
    c = _rand_batch(rng, C, Mc)
    max_subs = 4
    join, vote, _ = _reference(b, c, 2.5, 12.0, delta_t)
    cjoin, cvote, _ = _reference(c, c, 2.5, 12.0, delta_t)
    seg = segmentation.tsa1(
        voting.normalized_voting(vote, b.valid), b.valid, 3, 0.1, max_subs)
    cseg = segmentation.tsa1(
        voting.normalized_voting(cvote, c.valid), c.valid, 3, 0.1, max_subs)
    want = _reference_raw_sim(join, seg, cseg, max_subs)
    raw = stjoin_ops.stjoin_sim_fused(
        b, c, seg.sub_local, cseg.sub_local, max_subs, 2.5, 12.0, delta_t,
        rows=rows, bc=bc, bm=bm)
    np.testing.assert_allclose(np.asarray(raw), np.asarray(want), atol=1e-5)

    tiles = stjoin_ops.plan_fused_tiles(
        b.x, b.y, b.t, b.valid, c.x, c.y, c.t, c.valid, 2.5, 12.0,
        rows=rows, bc=bc, bm=bm)
    raw_p = stjoin_ops.stjoin_sim_fused(
        b, c, seg.sub_local, cseg.sub_local, max_subs, 2.5, 12.0, delta_t,
        tile_ids=tiles, rows=rows, bc=bc, bm=bm)
    np.testing.assert_allclose(np.asarray(raw_p), np.asarray(want),
                               atol=1e-5)


@pytest.mark.parametrize("kw", [
    dict(mode="fused"),
    dict(mode="fused", use_index=True),
])
def test_run_dsc_fused_matches_materializing(fig1, fig1_params, kw):
    """Acceptance: identical clustering output, sim allclose, no join cube."""
    batch, _ = fig1
    a = run_dsc(batch, fig1_params)
    b = run_dsc(batch, fig1_params, **kw)
    assert b.join is None
    assert (np.asarray(a.result.member_of)
            == np.asarray(b.result.member_of)).all()
    assert (np.asarray(a.result.is_rep) == np.asarray(b.result.is_rep)).all()
    assert (np.asarray(a.result.is_outlier)
            == np.asarray(b.result.is_outlier)).all()
    np.testing.assert_allclose(np.asarray(a.sim), np.asarray(b.sim),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(a.vote), np.asarray(b.vote),
                               atol=1e-4)


def test_run_dsc_fused_tsa1_delta_t(fig1):
    """Fused mode with TSA1 segmentation and an active delta_t refine."""
    batch, _ = fig1
    params = DSCParams(eps_sp=0.42, eps_t=1.0, delta_t=0.3, w=6, tau=0.15,
                       alpha_sigma=-1.0, k_sigma=-1.0, segmentation="tsa1")
    a = run_dsc(batch, params)
    b = run_dsc(batch, params, mode="fused")
    assert (np.asarray(a.result.member_of)
            == np.asarray(b.result.member_of)).all()
    np.testing.assert_allclose(np.asarray(a.sim), np.asarray(b.sim),
                               atol=1e-5)


def test_fused_vote_only_skips_masks():
    """with_masks=False (the TSA1 path) returns (vote, None) — identical
    votes, no packed-word accumulator built at all."""
    rng = np.random.default_rng(11)
    b = _rand_batch(rng, 5, 20)
    want_vote, _ = stjoin_ops.stjoin_vote_fused(
        b, b, 2.5, 12.0, 3.0, rows=2, bc=2, bm=8)
    vote, words = stjoin_ops.stjoin_vote_fused(
        b, b, 2.5, 12.0, 3.0, rows=2, bc=2, bm=8, with_masks=False)
    assert words is None
    np.testing.assert_allclose(np.asarray(vote), np.asarray(want_vote),
                               atol=1e-6)
    tiles = stjoin_ops.plan_fused_tiles(
        b.x, b.y, b.t, b.valid, b.x, b.y, b.t, b.valid, 2.5, 12.0,
        rows=2, bc=2, bm=8)
    vote_p, words_p = stjoin_ops.stjoin_vote_fused_arrays(
        b.x, b.y, b.t, b.valid, b.traj_id,
        b.x, b.y, b.t, b.valid, b.traj_id,
        2.5, 12.0, 3.0, rows=2, bc=2, bm=8, tile_ids=tiles,
        with_masks=False)
    assert words_p is None
    np.testing.assert_allclose(np.asarray(vote_p), np.asarray(want_vote),
                               atol=1e-6)


def test_fused_tile_plan_geometry_mismatch_rejected():
    """A plan reused under a different tile geometry would mis-address
    candidate blocks; the sweep must reject it instead of silently
    dropping candidates."""
    rng = np.random.default_rng(13)
    b = _rand_batch(rng, 6, 16)
    plan = stjoin_ops.plan_fused_tiles(
        b.x, b.y, b.t, b.valid, b.x, b.y, b.t, b.valid, 2.0, 10.0,
        rows=2, bc=2, bm=8)
    with pytest.raises(ValueError, match="geometry"):
        stjoin_ops.stjoin_vote_fused_arrays(
            b.x, b.y, b.t, b.valid, b.traj_id,
            b.x, b.y, b.t, b.valid, b.traj_id,
            2.0, 10.0, 0.0, rows=2, bc=4, bm=8, tile_ids=plan)


def test_fused_all_invalid_batch():
    """Degenerate input: no valid points anywhere -> zero accumulators."""
    T, M = 3, 12
    z = jnp.zeros((T, M), jnp.float32)
    b = TrajectoryBatch(x=z, y=z, t=z, valid=jnp.zeros((T, M), bool),
                        traj_id=jnp.full((T,), -1, jnp.int32))
    vote, words = stjoin_ops.stjoin_vote_fused(b, b, 1.0, 1.0, 0.0,
                                               rows=2, bc=2, bm=4)
    assert (np.asarray(vote) == 0).all()
    assert (np.asarray(words) == 0).all()
