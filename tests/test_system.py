"""End-to-end behaviour tests: the paper's own validation scenario (Sec. 6.2).

The Fig. 1 synthetic has known ground truth: subtrajectory clusters per
(origin/destination leg).  DSC must recover the leg structure with perfect
cluster purity; a whole-trajectory method (T-OPTICS) can only see the six
routes.  This mirrors the paper's "Accuracy = 100%, F-measure = 1" check.
"""
import numpy as np
import pytest

from repro.core.dsc import cluster_summary, run_dsc
from repro.core.evaluation import cluster_purity, leg_labels, pairwise_f1
from repro.core.types import DSCParams
from repro.data.synthetic import figure1_scenario, route_origins_dests


def _truth(batch, route, out, max_subs):
    origins, dests = route_origins_dests(route)
    sub_local = np.asarray(out.seg.sub_local)
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    t_split = float(t[v].max()) / 2
    return leg_labels(batch, sub_local, origins, dests, t_split, max_subs)


@pytest.fixture(scope="module")
def dsc_out(fig1, fig1_params):
    batch, labels = fig1
    return run_dsc(batch, fig1_params)


def _assignments(out):
    member_of = np.asarray(out.result.member_of)
    is_rep = np.asarray(out.result.is_rep)
    valid = np.asarray(out.table.valid)
    assign = {}
    for s in np.nonzero(valid)[0]:
        if is_rep[s]:
            assign[int(s)] = int(s)
        elif member_of[s] >= 0:
            assign[int(s)] = int(member_of[s])
    return assign


def test_groundtruth_recovery(fig1, fig1_params, dsc_out):
    """Near-perfect purity of clusters w.r.t. the leg ground truth (TSA2)."""
    batch, route = fig1
    out = dsc_out
    assign = _assignments(out)
    assert len(assign) > 0
    truth = _truth(batch, route, out, fig1_params.max_subtrajs_per_traj)
    purity = cluster_purity(assign, truth)
    assert purity >= 0.95, f"purity {purity}"
    f1 = pairwise_f1(assign, truth)
    assert f1 >= 0.5, f"pairwise F1 {f1}"


def test_outliers_are_the_unshared_legs(fig1, fig1_params, dsc_out):
    """O->A and O->B legs (4 supporters each) fall below the voting
    threshold and are isolated — the Fig. 1(b) structure."""
    batch, route = fig1
    out = dsc_out
    outliers = np.nonzero(np.asarray(out.result.is_outlier))[0]
    truth = _truth(batch, route, out, fig1_params.max_subtrajs_per_traj)
    # outliers should be dominated by the low-support destination legs
    # O->A and O->B (Fig. 1(b))
    tails = [truth[s] for s in outliers if s in truth]
    assert tails, "expected some outliers"
    frac = np.mean([t in [("D", "A"), ("D", "B")] for t in tails])
    assert frac >= 0.9, f"outlier composition {tails}"


def test_sscr_positive_and_rmse_bounded(dsc_out, fig1_params):
    assert float(dsc_out.sscr) > 0.0
    # Lemma 1: member mean distance <= eps_sp * (1 - alpha); the RMSE proxy
    # is bounded by eps_sp
    assert float(dsc_out.rmse) <= fig1_params.eps_sp


def test_tsa1_finds_flock_through_O(fig1):
    """TSA1 (density) merges across O (Example 2's contrast with TSA2)."""
    batch, route = fig1
    params = DSCParams(eps_sp=0.42, eps_t=1.0, w=6, tau=0.15,
                       alpha_sigma=-1.0, k_sigma=-1.0, segmentation="tsa1")
    out = run_dsc(batch, params)
    s = cluster_summary(out)
    assert s["num_clusters"] >= 1
    out2 = run_dsc(batch, params.replace(segmentation="tsa2"))
    s2 = cluster_summary(out2)
    assert s["num_clusters"] <= s2["num_clusters"]


def test_toptics_sees_routes_not_legs(fig1):
    from repro.core.baselines.toptics import t_optics
    batch, route = fig1
    res = t_optics(batch, eps=2.0, min_pts=3, xi_eps=0.2)
    labels = res["labels"]
    assert (labels >= 0).any()
    for c in set(labels) - {-1}:
        rs = set(route[np.nonzero(labels == c)[0]])
        assert len(rs) == 1


def test_figure1_outlier_variant():
    """Low-support tails (O->A / O->B) become outliers."""
    batch, route = figure1_scenario(n_per_route=2, points_per_leg=24, seed=3)
    params = DSCParams(eps_sp=0.42, eps_t=1.0, w=6, tau=0.2,
                       alpha_sigma=-1.0, k_sigma=-1.0, segmentation="tsa2")
    out = run_dsc(batch, params)
    assert int(np.asarray(out.result.is_outlier).sum()) >= 2


def test_kernel_path_matches_reference(fig1, fig1_params):
    """The Pallas stjoin-backed pipeline reproduces the reference output."""
    batch, _ = fig1
    a = run_dsc(batch, fig1_params, use_kernel=False)
    b = run_dsc(batch, fig1_params, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a.vote), np.asarray(b.vote),
                               atol=1e-4)
    assert (np.asarray(a.result.member_of) ==
            np.asarray(b.result.member_of)).mean() > 0.99
