"""Resilient stage runner + deterministic fault injection (DESIGN.md §10).

Everything here carries the ``faults`` marker (the chaos CI job runs
``-m faults``); the cheap in-process cases also run in tier-1.  The
launcher exit-code matrix spawns real subprocesses and is additionally
``slow``.  8-device coverage lives in ``test_resilient_dist.py``.
"""
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              load_checkpoint_flat, save_checkpoint)
from repro.core.dsc import run_dsc
from repro.core.types import DSCParams
from repro.data.synthetic import figure1_scenario
from repro.distributed.straggler import (StragglerMonitor,
                                         suggest_rebalance_edges)
from repro.run import (CheckpointCorruption, FaultInjector, FaultPlan,
                       InjectedCrash, RetriesExhausted, TransientFault,
                       retry_with_backoff, run_resilient)
from repro.run.resilient import EXIT_CODES, STAGES, OverflowViolation

pytestmark = pytest.mark.faults

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def scenario():
    batch, _ = figure1_scenario(n_per_route=2, points_per_leg=16, seed=0)
    params = DSCParams(eps_sp=0.42, eps_t=1.0, delta_t=0.0, w=6, tau=0.15,
                       alpha_sigma=-1.0, k_sigma=-1.0, segmentation="tsa2")
    return batch, params


@pytest.fixture(scope="module")
def reference(scenario):
    batch, params = scenario
    return run_dsc(batch, params)


def assert_bit_identical(out, ref):
    r, q = out.result, ref.result
    assert (np.asarray(r.member_of) == np.asarray(q.member_of)).all()
    assert (np.asarray(r.is_rep) == np.asarray(q.is_rep)).all()
    assert (np.asarray(r.is_outlier) == np.asarray(q.is_outlier)).all()
    assert (np.asarray(r.member_sim) == np.asarray(q.member_sim)).all()
    assert float(out.sscr) == float(ref.sscr)
    assert float(out.rmse) == float(ref.rmse)


# ------------------------------------------------------------ stage graph


def test_fresh_run_matches_monolith(scenario, reference):
    batch, params = scenario
    res = run_resilient(batch, params)
    assert res.resumed_from == 0
    assert res.widen_count == 0
    assert res.fallback_steps == []
    assert_bit_identical(res.output, reference)


def test_checkpointed_run_writes_every_stage(scenario, reference, tmp_path):
    batch, params = scenario
    res = run_resilient(batch, params, checkpoint_dir=tmp_path / "ckpt")
    assert_bit_identical(res.output, reference)
    mgr = CheckpointManager(tmp_path / "ckpt")
    assert mgr.available_steps() == list(range(1, len(STAGES) + 1))
    # telemetry JSONL exists and replays the in-memory event stream
    lines = [json.loads(line) for line in
             (tmp_path / "ckpt" / "telemetry.jsonl").open()]
    assert [e["event"] for e in lines] == [e["event"] for e in res.events]
    assert sum(e["event"] == "stage_done" for e in lines) == len(STAGES)


@pytest.mark.parametrize("stage", STAGES)
def test_resume_bit_identity_after_crash(scenario, reference, tmp_path,
                                         stage):
    """Kill at every stage boundary; the resumed run must reproduce the
    uninterrupted run bit for bit (the tentpole acceptance gate)."""
    batch, params = scenario
    root = tmp_path / "ckpt"
    with pytest.raises(InjectedCrash):
        run_resilient(batch, params, checkpoint_dir=root,
                      fault_plan=FaultPlan(crash_at=stage))
    res = run_resilient(batch, params, checkpoint_dir=root)
    assert res.resumed_from == STAGES.index(stage)
    assert_bit_identical(res.output, reference)


# -------------------------------------------------------- overflow policy


def test_overflow_widen_recovers_dense_labels(scenario, reference):
    batch, params = scenario
    res = run_resilient(batch, params, sim_mode="topk", sim_topk=2,
                        on_overflow="widen")
    assert res.widen_count >= 1
    r, q = res.output.result, reference.result
    assert (np.asarray(r.member_of) == np.asarray(q.member_of)).all()
    assert (np.asarray(r.is_rep) == np.asarray(q.is_rep)).all()
    assert int(res.output.sim_overflow) == 0


def test_overflow_degrade_records_certificate(scenario):
    batch, params = scenario
    res = run_resilient(batch, params, sim_mode="topk", sim_topk=2,
                        on_overflow="degrade")
    assert res.widen_count == 0
    assert int(res.output.sim_overflow) > 0
    assert any(e["event"] == "overflow_degraded" for e in res.events)


def test_overflow_raise(scenario):
    batch, params = scenario
    with pytest.raises(OverflowViolation, match="sim_topk"):
        run_resilient(batch, params, sim_mode="topk", sim_topk=2,
                      on_overflow="raise")


def test_overflow_widen_applies_to_restored_state(scenario, reference,
                                                  tmp_path):
    """A run directory whose newest checkpoint holds an overflowed
    cluster state (here: a completed degrade run) must widen on resume
    under on_overflow='widen' — the policy applies to restored state,
    not only to freshly-computed cluster output."""
    batch, params = scenario
    root = tmp_path / "ckpt"
    res0 = run_resilient(batch, params, checkpoint_dir=root,
                         sim_mode="topk", sim_topk=2,
                         on_overflow="degrade")
    assert int(res0.output.sim_overflow) > 0
    res = run_resilient(batch, params, checkpoint_dir=root,
                        sim_mode="topk", sim_topk=2, on_overflow="widen")
    assert res.widen_count >= 1
    r, q = res.output.result, reference.result
    assert (np.asarray(r.member_of) == np.asarray(q.member_of)).all()
    assert int(res.output.sim_overflow) == 0


def test_bad_policy_values(scenario):
    batch, params = scenario
    with pytest.raises(ValueError, match="on_overflow"):
        run_resilient(batch, params, on_overflow="explode")
    with pytest.raises(ValueError, match="on_corruption"):
        run_resilient(batch, params, on_corruption="shrug")


# ------------------------------------------------------- transient faults


def test_transient_retry_schedule(scenario, reference):
    batch, params = scenario
    delays = []
    res = run_resilient(batch, params,
                        fault_plan=FaultPlan(transient_at="segment",
                                             transient_count=2),
                        max_retries=3, sleep=delays.append)
    assert delays == [0.5, 1.0]      # bounded exponential backoff
    retries = [e for e in res.events if e["event"] == "retry"]
    assert [e["attempt"] for e in retries] == [1, 2]
    assert all(e["stage"] == "segment" for e in retries)
    assert_bit_identical(res.output, reference)


def test_transient_retries_exhausted(scenario):
    batch, params = scenario
    with pytest.raises(RetriesExhausted):
        run_resilient(batch, params,
                      fault_plan=FaultPlan(transient_at="similarity",
                                           transient_count=9),
                      max_retries=2, sleep=lambda s: None)


# -------------------------------------------------- checkpoint corruption


def test_corrupted_checkpoint_falls_back_a_step(scenario, reference,
                                                tmp_path):
    batch, params = scenario
    root = tmp_path / "ckpt"
    with pytest.raises(InjectedCrash):
        run_resilient(batch, params, checkpoint_dir=root,
                      fault_plan=FaultPlan(corrupt_stage="similarity",
                                           crash_at="cluster"))
    res = run_resilient(batch, params, checkpoint_dir=root)
    sim_step = STAGES.index("similarity") + 1
    assert res.fallback_steps == [sim_step]
    assert res.resumed_from == sim_step - 1
    assert any(e["event"] == "checkpoint_fallback" for e in res.events)
    assert_bit_identical(res.output, reference)


def test_corruption_fail_policy(scenario, tmp_path):
    batch, params = scenario
    root = tmp_path / "ckpt"
    with pytest.raises(InjectedCrash):
        run_resilient(batch, params, checkpoint_dir=root,
                      fault_plan=FaultPlan(corrupt_stage="segment",
                                           crash_at="similarity"))
    with pytest.raises(CheckpointCorruption):
        run_resilient(batch, params, checkpoint_dir=root,
                      on_corruption="fail")


# ---------------------------------------------------------- FaultPlan api


def test_fault_plan_roundtrip(tmp_path):
    fp = FaultPlan(crash_at="cluster", transient_at="join",
                   transient_count=2, corrupt_stage="segment",
                   corrupt_leaf=3, slow=(("join", 1, 2.5),))
    assert FaultPlan.from_json(fp.to_json()) == fp
    p = tmp_path / "faults.json"
    fp.save(p)
    assert FaultPlan.load(p) == fp


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="crash_at"):
        FaultPlan(crash_at="warmup").validate()
    with pytest.raises(ValueError, match="transient_count"):
        FaultPlan(transient_count=-1).validate()
    with pytest.raises(ValueError, match="without transient_at"):
        FaultPlan(transient_count=2).validate()
    with pytest.raises(ValueError, match="slow entry"):
        FaultPlan(slow=(("join", 0),)).validate()
    with pytest.raises(ValueError, match="unknown FaultPlan fields"):
        FaultPlan.from_dict({"crash_on": "join"})
    # replace() re-validates
    with pytest.raises(ValueError, match="corrupt_leaf"):
        FaultPlan().replace(corrupt_leaf=-1)


def test_fault_plan_slowdown_accumulates():
    fp = FaultPlan(slow=(("join", 1, 2.0), ("join", 1, 0.5),
                         ("cluster", 0, 9.0)))
    assert fp.slowdown("join", 1) == 2.5
    assert fp.slowdown("join", 0) == 0.0
    assert fp.slowdown("cluster", 0) == 9.0


def test_injector_transient_counts_are_per_process():
    inj = FaultInjector(FaultPlan(transient_at="join", transient_count=2))
    for _ in range(2):
        with pytest.raises(TransientFault):
            inj.on_stage_enter("join")
    inj.on_stage_enter("join")       # third attempt succeeds
    inj.on_stage_enter("segment")    # other stages never fault


# ---------------------------------------------------- retry_with_backoff


def test_retry_backoff_schedule_caps_at_max_delay():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 5:
            raise TransientFault("boom")
        return "ok"

    delays = []
    out = retry_with_backoff(flaky, max_retries=8, base_delay=1.0,
                             max_delay=4.0, sleep=delays.append)
    assert out == "ok"
    assert delays == [1.0, 2.0, 4.0, 4.0, 4.0]


def test_retry_backoff_exhaustion_chains_cause():
    def always():
        raise TransientFault("persistent")

    with pytest.raises(RetriesExhausted) as ei:
        retry_with_backoff(always, max_retries=2, sleep=lambda s: None)
    assert isinstance(ei.value.__cause__, TransientFault)


def test_retry_backoff_ignores_nonretryable():
    def bad():
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        retry_with_backoff(bad, sleep=lambda s: None)


# ------------------------------------------------------------ checkpointer


def test_checkpoint_flat_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.int32).reshape(2, 3),
            "b/c": np.linspace(0.0, 1.0, 4, dtype=np.float32)}
    save_checkpoint(tmp_path, 3, tree)
    got, step = load_checkpoint_flat(tmp_path)
    assert step == 3
    assert set(got) == set(tree)
    for k in tree:
        assert got[k].dtype == tree[k].dtype
        assert (got[k] == tree[k]).all()


def test_checkpoint_dtype_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"x": np.zeros(4, np.float32)})
    with pytest.raises(ValueError, match="dtype"):
        load_checkpoint(tmp_path, {"x": np.zeros(4, np.int32)}, step=1)


def test_checkpoint_crc_detects_bitrot(tmp_path):
    save_checkpoint(tmp_path, 1, {"x": np.arange(64, dtype=np.float64)})
    inj = FaultInjector(FaultPlan(corrupt_stage="join", corrupt_leaf=0))
    assert inj.on_checkpoint_written("join", tmp_path / "step_000000001")
    with pytest.raises(IOError, match="checksum mismatch"):
        load_checkpoint_flat(tmp_path, step=1)
    # verify=False reads the damaged bytes without the integrity gate
    got, _ = load_checkpoint_flat(tmp_path, step=1, verify=False)
    assert got["x"].shape == (64,)


# --------------------------------------------------------------- straggler


def test_straggler_monitor_empty_is_silent():
    mon = StragglerMonitor(n_hosts=4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert mon.check() == {}


def test_straggler_flag_and_reset():
    mon = StragglerMonitor(n_hosts=4, window=4)
    for _ in range(4):
        mon.record_all([1.0, 1.0, 1.0, 5.0])
    flagged = mon.check()
    assert list(flagged) == [3] and flagged[3] >= 1.5
    mon.reset(3)
    assert mon.flagged == {} and len(mon.history[3]) == 0
    # a clean restart of the rank must not re-flag from stale history
    mon.record_all([1.0, 1.0, 1.0, 1.0])
    assert mon.check() == {}


def test_suggest_rebalance_edges_narrows_slow_partition():
    rng = np.random.default_rng(0)
    times = np.sort(rng.uniform(0.0, 100.0, size=400))
    part_of = np.minimum(np.arange(400) // 100, 3)
    edges = suggest_rebalance_edges(times, part_of, {1: 3.0}, P=4)
    assert edges.shape == (5,)
    assert edges[0] == -np.inf and edges[-1] == np.inf
    assert (np.diff(edges[1:-1]) >= 0).all()
    # partition 1's time span shrinks: its points weigh 3x, so the
    # weighted equi-depth quantiles pull both its edges inward
    old_span = times[199] - times[100]
    new_span = edges[2] - edges[1]
    assert new_span < old_span


def test_slowdown_feeds_straggler_telemetry(scenario):
    """Scripted slowdowns on one partition must surface as per-partition
    timings in the stage_done telemetry (the wiring the distributed
    driver asserts end to end with flags + rebalance edges)."""
    batch, params = scenario
    slow = tuple((s, 0, 30.0) for s in STAGES)
    res = run_resilient(batch, params, fault_plan=FaultPlan(slow=slow))
    done = [e for e in res.events if e["event"] == "stage_done"]
    assert len(done) == len(STAGES)
    assert all(e["per_partition_s"][0] >= 30.0 for e in done)


# ------------------------------------------------- launcher exit codes


@pytest.fixture(scope="module")
def launcher_codes(tmp_path_factory):
    """One subprocess per failure class through the real CLI; returns
    {name: returncode}."""
    tmp = tmp_path_factory.mktemp("launcher")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)

    def run(extra):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.run_dsc",
             "--n-trajs", "12"] + extra,
            env=env, capture_output=True, text=True, timeout=600)
        return proc.returncode

    crash = tmp / "crash.json"
    FaultPlan(crash_at="cluster").save(crash)
    transient = tmp / "transient.json"
    FaultPlan(transient_at="segment", transient_count=9).save(transient)
    corrupt = tmp / "corrupt.json"
    FaultPlan(corrupt_stage="segment", crash_at="similarity").save(corrupt)

    codes = {}
    codes["crash"] = run(["--resume-dir", str(tmp / "c1"),
                          "--fault-plan", str(crash)])
    codes["resume"] = run(["--resume-dir", str(tmp / "c1")])
    codes["retries"] = run(["--fault-plan", str(transient),
                            "--max-retries", "1"])
    codes["corrupt_crash"] = run(["--resume-dir", str(tmp / "c2"),
                                  "--fault-plan", str(corrupt)])
    codes["resume_fail"] = run(["--resume-dir", str(tmp / "c2"),
                                "--on-corruption", "fail"])
    codes["resume_fallback"] = run(["--resume-dir", str(tmp / "c2")])
    codes["overflow_raise"] = run(["--sim-mode", "topk", "--sim-topk", "2",
                                   "--on-overflow", "raise"])
    codes["overflow_widen"] = run(["--sim-mode", "topk", "--sim-topk", "2",
                                   "--on-overflow", "widen"])
    return codes


@pytest.mark.slow
def test_launcher_exit_code_matrix(launcher_codes):
    c = launcher_codes
    assert c["crash"] == EXIT_CODES["injected_crash"]
    assert c["corrupt_crash"] == EXIT_CODES["injected_crash"]
    assert c["retries"] == EXIT_CODES["retries_exhausted"]
    assert c["resume_fail"] == EXIT_CODES["corruption"]
    assert c["overflow_raise"] == EXIT_CODES["overflow"]
    # every failure class maps to a distinct nonzero code
    fails = [c["crash"], c["retries"], c["resume_fail"],
             c["overflow_raise"]]
    assert 0 not in fails and len(set(fails)) == len(fails)


@pytest.mark.slow
def test_launcher_recovers_after_faults(launcher_codes):
    assert launcher_codes["resume"] == EXIT_CODES["ok"]
    assert launcher_codes["resume_fallback"] == EXIT_CODES["ok"]
    assert launcher_codes["overflow_widen"] == EXIT_CODES["ok"]


# -------------------------------------------- telemetry hardening (S2)


def test_telemetry_schema_and_crash_parse(scenario, tmp_path):
    """Kill mid-run; the fsynced JSONL must parse completely, and every
    event carries the schema version."""
    from repro.run import TELEMETRY_SCHEMA, read_telemetry
    batch, params = scenario
    root = tmp_path / "ckpt"
    with pytest.raises(InjectedCrash):
        run_resilient(batch, params, checkpoint_dir=root,
                      fault_plan=FaultPlan(crash_at="cluster"))
    events = read_telemetry(root / "telemetry.jsonl")
    assert events and all(e["schema"] == TELEMETRY_SCHEMA for e in events)
    assert [e for e in events if e["event"] == "stage_done"]


def test_read_telemetry_tolerates_torn_tail(tmp_path):
    from repro.run import read_telemetry
    p = tmp_path / "telemetry.jsonl"
    with open(p, "w") as f:
        f.write(json.dumps({"schema": 1, "event": "a"}) + "\n")
        f.write(json.dumps({"schema": 1, "event": "b"}) + "\n")
        f.write('{"schema": 1, "event": "c", "tru')      # crash mid-write
    events = read_telemetry(p)
    assert [e["event"] for e in events] == ["a", "b"]


def test_read_telemetry_rejects_mid_file_damage(tmp_path):
    from repro.run import read_telemetry
    p = tmp_path / "telemetry.jsonl"
    with open(p, "w") as f:
        f.write(json.dumps({"event": "a"}) + "\n")
        f.write("garbage not json\n")
        f.write(json.dumps({"event": "b"}) + "\n")
    with pytest.raises(ValueError, match="line 2"):
        read_telemetry(p)


# ------------------------------------------------- async saves (S3)


def test_sync_saves_escape_hatch_same_resume(scenario, reference,
                                             tmp_path):
    """Async (default) and synchronous checkpointing must leave
    identical resume points and bit-identical outputs."""
    batch, params = scenario
    results = {}
    for name, sync in (("async", False), ("sync", True)):
        root = tmp_path / name
        with pytest.raises(InjectedCrash):
            run_resilient(batch, params, checkpoint_dir=root,
                          fault_plan=FaultPlan(crash_at="cluster"),
                          sync_saves=sync)
        mgr = CheckpointManager(root)
        assert mgr.available_steps() == [1, 2, 3], name
        res = run_resilient(batch, params, checkpoint_dir=root,
                            sync_saves=sync)
        assert res.resumed_from == STAGES.index("cluster")
        assert_bit_identical(res.output, reference)
        results[name] = res
    assert results["async"].sscr == results["sync"].sscr


# ------------------------------- retry bounds + truncated leaves (S4)


def test_retry_exact_attempt_count_on_exhaustion():
    calls = {"n": 0}

    def always():
        calls["n"] += 1
        raise TransientFault("persistent")

    with pytest.raises(RetriesExhausted):
        retry_with_backoff(always, max_retries=3, sleep=lambda s: None)
    assert calls["n"] == 4              # 1 initial + max_retries retries


def test_retry_zero_retries_fails_after_first_attempt():
    calls = {"n": 0}

    def always():
        calls["n"] += 1
        raise TransientFault("persistent")

    with pytest.raises(RetriesExhausted):
        retry_with_backoff(always, max_retries=0, sleep=lambda s: None)
    assert calls["n"] == 1


def test_injected_clock_drives_telemetry_timestamps(scenario):
    batch, params = scenario
    tick = {"n": 0}

    def clock():
        tick["n"] += 1
        return float(tick["n"])

    res = run_resilient(batch, params, clock=clock)
    ts = [e["ts"] for e in res.events]
    assert ts == sorted(ts) and all(float(t).is_integer() for t in ts)


def test_truncated_checkpoint_leaf_detected_and_skipped(scenario,
                                                        reference,
                                                        tmp_path):
    """A leaf file cut short (disk-full / partial write) must fail the
    load — np.load or the CRC gate — and fallback must recover from the
    previous step."""
    batch, params = scenario
    root = tmp_path / "ckpt"
    run_resilient(batch, params, checkpoint_dir=root)
    mgr = CheckpointManager(root)
    last = mgr.available_steps()[-1]
    leaves = sorted(mgr.step_dir(last).glob("leaf_*.npy"))
    os.truncate(leaves[0], max(1, leaves[0].stat().st_size // 2))
    with pytest.raises((IOError, EOFError, ValueError)):
        load_checkpoint_flat(root, step=last)
    res = run_resilient(batch, params, checkpoint_dir=root)
    assert res.fallback_steps == [last]
    assert res.resumed_from == last - 1
    assert_bit_identical(res.output, reference)


# ----------------------------------- FaultPlan/P validation (S1)


def test_slow_partition_out_of_range_raises(scenario):
    batch, params = scenario
    with pytest.raises(ValueError, match="partition"):
        run_resilient(batch, params,
                      fault_plan=FaultPlan(slow=(("join", 3, 1.0),)))


# ------------------------------------------------- RebalancePolicy api


def test_rebalance_policy_roundtrip(tmp_path):
    from repro.run import RebalancePolicy
    pol = RebalancePolicy(mode="apply", consecutive=2, max_applies=3)
    assert RebalancePolicy.from_json(pol.to_json()) == pol
    p = tmp_path / "rebalance.json"
    pol.save(p)
    assert RebalancePolicy.load(p) == pol


def test_rebalance_policy_validation():
    from repro.run import RebalancePolicy
    with pytest.raises(ValueError, match="mode"):
        RebalancePolicy(mode="sometimes").validate()
    with pytest.raises(ValueError, match="consecutive"):
        RebalancePolicy(consecutive=0).validate()
    with pytest.raises(ValueError, match="max_applies"):
        RebalancePolicy(max_applies=-1).validate()
    with pytest.raises(ValueError, match="unknown RebalancePolicy"):
        RebalancePolicy.from_dict({"mode": "apply", "threshold": 2})


def test_rebalance_policy_rejected_at_run_start(scenario):
    from repro.run import RebalancePolicy
    batch, params = scenario
    with pytest.raises(ValueError, match="mode"):
        run_resilient(batch, params,
                      rebalance=RebalancePolicy(mode="bogus"))
