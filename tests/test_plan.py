"""EnginePlan surface + autotuner: serialization, legacy-alias
equivalence, sweep determinism, and bit-identity rejection."""
import json
import os
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.plan import EnginePlan, resolve_plan
from repro.tune.autotune import (PlanStore, plan_cache_key, shape_bucket,
                                 sweep, tune_cluster_tiles, tune_join)

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


# --------------------------------------------------------------- the plan


def test_plan_json_round_trip(tmp_path):
    plan = EnginePlan(mode="fused", fused_rows=4, fused_bc=8, fused_bm=32,
                      sim_mode="topk", sim_topk=16, sim_panel=64,
                      cluster_use_kernel=True, cluster_bu=16, cluster_bs=64)
    assert EnginePlan.from_json(plan.to_json()) == plan
    p = tmp_path / "plan.json"
    plan.save(p)
    assert EnginePlan.load(p) == plan
    # stored JSON is plain field->value, no nesting
    assert json.loads(p.read_text())["fused_bm"] == 32


def test_plan_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown EnginePlan fields"):
        EnginePlan.from_dict({"mode": "fused", "warp_speed": 9})


def test_plan_validation_keeps_legacy_error_strings():
    with pytest.raises(ValueError, match="unknown mode 'stream'"):
        EnginePlan(mode="stream").validate()
    with pytest.raises(ValueError, match="unknown cluster engine 'greedy'"):
        EnginePlan(cluster_engine="greedy").validate()
    with pytest.raises(ValueError, match="unknown sim_mode 'sparse'"):
        EnginePlan(sim_mode="sparse").validate()
    with pytest.raises(ValueError, match="sim_topk"):
        EnginePlan(sim_topk=0).validate()


def test_plan_is_hashable_jit_static():
    # one plan == one trace: the frozen dataclass must hash stably and
    # compare equal across reconstruction
    a = EnginePlan(mode="fused", sim_topk=16)
    b = EnginePlan.from_dict(a.to_dict())
    assert hash(a) == hash(b) and a == b
    assert a.replace(sim_topk=32) != a


def test_fused_tiles_collapse_to_none_at_defaults():
    # default fused fields -> None so default plans keep the pre-plan jit
    # cache keys (no retrace on upgrade)
    assert EnginePlan().fused_tiles is None
    assert EnginePlan(fused_bm=32).fused_tiles == (None, 16, 32)
    assert EnginePlan().cluster_tiles == (8, 128)


def test_resolve_plan_legacy_and_conflicts():
    legacy = resolve_plan(None, mode="fused", sim_mode="topk", sim_topk=16)
    assert legacy == EnginePlan(mode="fused", sim_mode="topk", sim_topk=16)
    with pytest.raises(ValueError, match="both plan= and legacy"):
        resolve_plan(EnginePlan(), mode="fused")
    with pytest.raises(TypeError, match="unknown legacy plan flags"):
        resolve_plan(None, warp_speed=9)
    # a plan plus all-default flags is fine (how run_dsc forwards kwargs)
    assert resolve_plan(EnginePlan(mode="fused"),
                        mode="materialize") == EnginePlan(mode="fused")


def test_legacy_flags_and_plan_produce_identical_labels(fig1, fig1_params):
    from repro.core.dsc import run_dsc
    fig1, _ = fig1
    out_legacy = run_dsc(fig1, fig1_params, mode="fused",
                         fused_tiles=(2, 8, 16))
    out_plan = run_dsc(fig1, fig1_params,
                       plan=EnginePlan(mode="fused", fused_rows=2,
                                       fused_bc=8, fused_bm=16))
    for f in ("member_of", "is_rep", "is_outlier"):
        np.testing.assert_array_equal(
            np.asarray(getattr(out_legacy.result, f)),
            np.asarray(getattr(out_plan.result, f)))


# ----------------------------------------------------- cache keys + store


def test_shape_bucket_and_cache_key():
    assert shape_bucket(T=24, M=96) == "M128-T32"
    assert shape_bucket(S=256) == shape_bucket(S=129) == "S256"
    assert shape_bucket(S=1) == "S1"
    key = plan_cache_key("join", "M128-T32", backend="cpu",
                         jax_version="0.4.37")
    assert key == "join|M128-T32|cpu|jax0.4.37"


def test_plan_store_round_trip(tmp_path):
    path = tmp_path / "plans.json"
    store = PlanStore(str(path))
    res = _run_fixed_sweep(store=store)
    store.save()
    again = PlanStore(str(path))
    got = again.get("unit", res.bucket, backend=jax.default_backend(),
                    jax_version=jax.__version__)
    assert got == res.winner.plan


# ------------------------------------------------------------- the sweep


def _fake_measure(sizes, walls):
    """Injectable measure: real (tiny) HLO per candidate so the buffer
    stats are exercised, candidate-keyed wall-clock, no compile per call
    beyond the tiny identity program."""
    def measure(plan):
        n = sizes[plan.cluster_bs]
        x = jnp.zeros((n,), jnp.float32)
        hlo = jax.jit(lambda v: v + 1.0).lower(x).compile().as_text()
        return plan.cluster_bs, walls[plan.cluster_bs], hlo
    return measure


_CANDS = [EnginePlan(),                       # default: bs=128
          EnginePlan(cluster_bs=64),
          EnginePlan(cluster_bs=32)]
_SIZES = {128: 1024, 64: 512, 32: 256}        # interface bytes = 4n
_WALLS = {128: 3e-3, 64: 2e-3, 32: 1e-3}


def _run_fixed_sweep(verify=None, store=None):
    return sweep("unit", "S256", _CANDS,
                 _fake_measure(_SIZES, _WALLS),
                 verify or (lambda out, plan: True), store=store)


def test_sweep_deterministic_on_fixed_candidates():
    a = _run_fixed_sweep()
    b = _run_fixed_sweep()
    assert a.winner.plan == b.winner.plan == EnginePlan(cluster_bs=32)
    assert [c.plan for c in a.candidates] == [c.plan for c in b.candidates]
    assert ([c.peak_interface_bytes for c in a.candidates]
            == [c.peak_interface_bytes for c in b.candidates])
    # candidate 0 is the default plan; the winner can't be worse on the
    # primary key
    assert a.default.plan == EnginePlan()
    assert a.winner.peak_interface_bytes <= a.default.peak_interface_bytes


def test_sweep_rejects_bit_unidentical_candidate():
    # the cheapest candidate (bs=32) fails verification -> the sweep must
    # NOT pick it, even though it wins on every ranking key
    res = _run_fixed_sweep(
        verify=lambda out, plan: plan.cluster_bs != 32)
    rejected = [c for c in res.candidates if not c.verified]
    assert len(rejected) == 1 and rejected[0].plan.cluster_bs == 32
    assert "not bit-identical" in rejected[0].note
    assert res.winner.plan == EnginePlan(cluster_bs=64)


def test_sweep_raises_when_nothing_verifies():
    with pytest.raises(RuntimeError, match="no candidate survived"):
        _run_fixed_sweep(verify=lambda out, plan: False)


def test_sweep_survives_a_failing_measure():
    def measure(plan):
        if plan.cluster_bs == 64:
            raise ValueError("invalid geometry")
        return _fake_measure(_SIZES, _WALLS)(plan)
    res = sweep("unit", "S256", _CANDS, measure, lambda o, p: True)
    failed = [c for c in res.candidates if "measure failed" in c.note]
    assert len(failed) == 1 and not failed[0].verified
    assert res.winner.plan == EnginePlan(cluster_bs=32)


# -------------------------------------------------- real stage sweeps


def _tiny_cluster_instance(S=32, seed=0):
    from repro.core.types import SubtrajTable
    rng = np.random.default_rng(seed)
    raw = rng.uniform(0, 1, (S, S)).astype(np.float32)
    sim = np.maximum(raw, raw.T) * (rng.uniform(0, 1, (S, S)) > 0.7)
    np.fill_diagonal(sim, 0.0)
    table = SubtrajTable(
        t_start=jnp.zeros(S), t_end=jnp.ones(S),
        voting=jnp.asarray(rng.uniform(0, 5, S).astype(np.float32)),
        card=jnp.ones(S, jnp.int32), valid=jnp.ones(S, bool),
        traj_row=jnp.arange(S, dtype=jnp.int32))
    return jnp.asarray(np.maximum(sim, sim.T)), table


def test_tune_cluster_tiles_verifies_against_jnp_oracle():
    from repro.core.types import DSCParams
    sim, table = _tiny_cluster_instance()
    res = tune_cluster_tiles(sim, table,
                             DSCParams(alpha_sigma=0.0, k_sigma=0.0),
                             candidates=[EnginePlan(),
                                         EnginePlan(cluster_use_kernel=True,
                                                    cluster_bu=8,
                                                    cluster_bs=16)])
    assert all(c.verified for c in res.candidates)
    assert res.winner.peak_interface_bytes <= res.default.peak_interface_bytes
    assert res.bucket == "S32"


def test_tune_join_rejects_and_accepts_end_to_end(fig1, fig1_params):
    # two candidates: the materializing default and one fused geometry —
    # both must pass label verification; the winner must not regress the
    # interface-bytes key (candidate 0 is the default)
    fig1, _ = fig1
    res = tune_join(fig1, fig1_params,
                    candidates=[EnginePlan(),
                                EnginePlan(mode="fused", fused_rows=2,
                                           fused_bc=8, fused_bm=16)])
    assert all(c.verified for c in res.candidates)
    assert res.default.plan == EnginePlan()
    assert res.winner.peak_interface_bytes <= res.default.peak_interface_bytes
    # the audit record carries the roofline position when benchmarks/ is
    # importable (repo-root pytest runs)
    if res.winner.roofline is not None:
        assert res.winner.roofline["dominant"] in ("compute", "memory",
                                                   "collective")


# ------------------------------------------------------------- docs sync


def test_readme_cli_table_in_sync():
    from repro.launch.run_dsc import check_readme_cli_table
    readme = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "README.md")
    diff = check_readme_cli_table(readme)
    assert not diff, ("README CLI table out of sync; regenerate with "
                      "--print-cli-table:\n" + "\n".join(diff))


def test_launcher_rejects_plan_plus_legacy_flag(tmp_path):
    from repro.launch.run_dsc import build_parser, plan_from_args
    p = tmp_path / "plan.json"
    EnginePlan(mode="fused").save(p)
    ap = build_parser()
    args = ap.parse_args(["--plan", str(p)])
    assert plan_from_args(args, ap) == EnginePlan(mode="fused")
    args = ap.parse_args(["--plan", str(p), "--sim-mode", "topk"])
    with pytest.raises(SystemExit):
        plan_from_args(args, ap)
