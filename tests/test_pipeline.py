"""Pipeline parallelism (GPipe over a stage axis): subprocess multi-device
test — forward equals sequential composition; gradients flow."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = [pytest.mark.distributed, pytest.mark.slow]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_DRIVER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.distributed.pipeline import pipeline_apply, bubble_fraction

    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    S, D = 4, 16
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.normal(0, 0.5, (S, D, D)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (8, D)), jnp.float32)

    def stage_fn(W, xb):
        return jnp.tanh(xb @ W)

    fn = pipeline_apply(stage_fn, mesh, stage_axis="pod", n_micro=4,
                        data_axes=("data",))
    y = fn(Ws, x)

    yref = x
    for s in range(S):
        yref = jnp.tanh(yref @ Ws[s])

    report = {
        "fwd_close": bool(np.allclose(np.asarray(y), np.asarray(yref),
                                      atol=1e-5)),
        "bubble": bubble_fraction(4, 4),
    }

    def loss(Ws):
        return jnp.sum(fn(Ws, x) ** 2)

    def loss_ref(Ws):
        yy = x
        for s in range(S):
            yy = jnp.tanh(yy @ Ws[s])
        return jnp.sum(yy ** 2)

    g = jax.grad(loss)(Ws)
    gref = jax.grad(loss_ref)(Ws)
    report["grad_close"] = bool(np.allclose(np.asarray(g),
                                            np.asarray(gref), atol=1e-4))
    print("JSON" + json.dumps(report))
""")


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


def test_pipeline_forward_matches_sequential(report):
    assert report["fwd_close"]


def test_pipeline_gradients_flow(report):
    assert report["grad_close"]


def test_bubble_fraction(report):
    assert report["bubble"] == pytest.approx(3 / 7)
