"""Baseline implementations: sanity + the paper's Fig. 7 quality ordering."""
import numpy as np
import pytest

from repro.core.baselines.s2t import s2t_clustering
from repro.core.baselines.traclus import traclus, _seg_dist
from repro.core.evaluation import rmse_sim_based, rmse_subtraj, rmse_traclus
from repro.core.dsc import run_dsc
from repro.data.synthetic import figure1_scenario


@pytest.fixture(scope="module")
def small_fig1():
    return figure1_scenario(n_per_route=3, points_per_leg=16, seed=2)


def test_seg_dist_properties():
    a = np.array([[0.0, 0.0], [1.0, 0.0]])
    b = np.array([[0.0, 0.1], [1.0, 0.1]])
    assert _seg_dist(a, a) == pytest.approx(0.0, abs=1e-9)
    assert _seg_dist(a, b) == pytest.approx(0.1, abs=1e-6)
    assert _seg_dist(a, b) == pytest.approx(_seg_dist(b, a), abs=1e-9)
    # perpendicular segment: angular distance dominates
    c = np.array([[0.5, 0.0], [0.5, 1.0]])
    assert _seg_dist(a, c) > 0.5


def test_traclus_runs_and_clusters(small_fig1):
    batch, _ = small_fig1
    res = traclus(batch, eps=0.35, min_lns=3)
    assert len(res["segments"]) > 0
    assert (res["labels"] >= 0).any(), "expected at least one cluster"
    assert len(res["reps"]) == res["labels"].max() + 1


def test_s2t_runs_and_clusters(small_fig1):
    batch, _ = small_fig1
    res = s2t_clustering(batch, eps_sp=0.42, eps_t=1.0, w=5, tau=0.2)
    assert res["is_rep"].sum() > 0
    members = (res["member_of"] >= 0) & ~res["is_rep"]
    assert members.sum() > 0
    for s in np.nonzero(members)[0]:
        assert res["is_rep"][res["member_of"][s]]


def test_fig7_rmse_ordering():
    """DSC <= S2T <= TraClus in intra-cluster RMSE (paper Fig. 7).

    The data contains 'crossers' that share the A->O corridor only briefly:
    DSC's delta_t minimum-match-duration rejects them; S2T (no delta_t, no
    similarity floor) attaches them; TraClus's density expansion produces
    spatially extended clusters — the paper's explanation of the ordering.
    """
    from repro.core.types import DSCParams
    from repro.data.synthetic import crossing_scenario
    batch, _, _ = crossing_scenario(n_per_route=3, points_per_leg=16,
                                    n_crossers=4, seed=2)
    eps_sp = 0.42
    params = DSCParams(eps_sp=eps_sp, eps_t=1.0, delta_t=6.0, w=5, tau=0.2,
                       alpha_sigma=0.0, k_sigma=-1.0, segmentation="tsa1")
    out = run_dsc(batch, params)
    r_dsc = rmse_sim_based(
        np.asarray(out.sim), np.asarray(out.result.member_of),
        np.asarray(out.result.is_rep), eps_sp)
    n_reps = int(np.asarray(out.result.is_rep).sum())

    # same representative budget for a like-for-like comparison
    s2t = s2t_clustering(batch, eps_sp=eps_sp, eps_t=1.0, w=5, tau=0.2,
                         n_reps=n_reps)
    r_s2t = rmse_sim_based(s2t["sim"], s2t["member_of"], s2t["is_rep"],
                           eps_sp)

    tc = traclus(batch, eps=0.35, min_lns=3)
    r_tc = rmse_traclus(tc, eps_sp=eps_sp)

    assert r_dsc > 0 and r_s2t > 0 and r_tc > 0
    assert r_dsc <= r_s2t * 1.02, (r_dsc, r_s2t)
    assert r_s2t <= r_tc * 1.25, (r_s2t, r_tc)
