"""Per-architecture smoke tests: reduced config, one forward + train-ish step
on CPU, asserting output shapes and no NaNs; plus a decode-vs-prefill
consistency check per family."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, get_arch, reduced_config
from repro.models import transformer as tf
from repro.models.config import ModelConfig


def _inputs(cfg: ModelConfig, B=2, L=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "audio":
        tokens = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, cfg.n_codebooks, L)),
            jnp.int32)
    else:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)),
                             jnp.int32)
    fe = None
    if cfg.family == "vlm":
        fe = jnp.asarray(
            rng.normal(0, 1, (B, cfg.vision_tokens, cfg.d_vision)),
            jnp.float32)
    return tokens, fe


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_arch(arch))
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    B, L = 2, 16
    tokens, fe = _inputs(cfg, B, L)
    logits, aux, _ = tf.forward(params, tokens, cfg, frontend_inputs=fe)
    if cfg.family == "audio":
        assert logits.shape == (B, L, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, L, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    if cfg.family == "moe":
        assert float(aux["moe_aux"]) >= 0.0


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_train_step_decreases_loss(arch):
    """One SGD step on one batch decreases its own loss (sanity + grads
    finite)."""
    cfg = reduced_config(get_arch(arch))
    params = tf.init_model(jax.random.PRNGKey(1), cfg)
    B, L = 2, 16
    tokens, fe = _inputs(cfg, B, L, seed=1)
    if cfg.family == "audio":
        labels = tokens
    else:
        labels = jnp.roll(tokens, -1, axis=-1)

    def loss_fn(p):
        logits, aux, _ = tf.forward(p, tokens, cfg, frontend_inputs=fe,
                                    remat=True)
        return tf.lm_loss(logits, labels) + 0.01 * aux["moe_aux"]

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0
    lr = 0.1 / max(float(gnorm), 1.0)
    params2 = jax.tree.map(
        lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0), (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_decode_matches_prefill(arch):
    """Last-token logits from (prefill L) == logits from (prefill L-1 +
    one decode step) — validates every family's cache/state machinery."""
    cfg = reduced_config(get_arch(arch))
    params = tf.init_model(jax.random.PRNGKey(2), cfg)
    B, L = 2, 12
    tokens, fe = _inputs(cfg, B, L, seed=2)

    full_logits, _, _ = tf.forward(params, tokens, cfg, frontend_inputs=fe)

    max_len = L + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    cache = tf.init_cache(cfg, B, max_len)
    head = tokens[..., :L - 1]
    last = tokens[..., L - 1:]
    _, _, cache = tf.forward(params, head, cfg, frontend_inputs=fe,
                             cache=cache, cache_index=jnp.int32(0))
    prefix = cfg.vision_tokens if cfg.family == "vlm" else 0
    dec_logits, _, _ = tf.forward(
        params, last, cfg, cache=cache,
        cache_index=jnp.int32(prefix + L - 1))
    a = np.asarray(full_logits)[:, -1]
    b = np.asarray(dec_logits)[:, -1]
    np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)


def test_moe_kernel_matches_dense_ref():
    from repro.models.moe import init_moe, moe_ffn, moe_ffn_dense_ref
    from repro.models.config import MoEConfig
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, n_shared=1,
                    capacity_factor=8.0)    # high capacity -> no drops
    p = init_moe(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    y, aux, dropped = moe_ffn(p, x, cfg)
    yref = moe_ffn_dense_ref(p, x, cfg)
    assert float(dropped) == 0.0
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yref, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_param_counts_are_in_published_ballpark():
    """Analytic parameter counts land near the published model sizes."""
    expected = {
        "deepseek-7b": (6.0e9, 8.0e9),
        "smollm-360m": (3.0e8, 4.5e8),
        "gemma2-2b": (2.0e9, 3.3e9),
        "yi-6b": (5.5e9, 7.0e9),
        # NOTE: the assignment fixes 48 layers (the published Moonlight-16B
        # has 27); with 48L x 64e the analytic total is ~28B. The config
        # follows the assignment verbatim (see DESIGN.md §5).
        "moonshot-v1-16b-a3b": (26e9, 31e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "rwkv6-1.6b": (1.3e9, 2.2e9),
        "paligemma-3b": (2.0e9, 3.5e9),   # backbone only (frontend stubbed)
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "musicgen-large": (1.5e9, 3.5e9),  # gated-MLP variant of the backbone
    }
    for arch, (lo, hi) in expected.items():
        n = get_arch(arch).param_count()
        assert lo <= n <= hi, (arch, n)
