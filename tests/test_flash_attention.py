"""Flash attention Pallas kernel vs jnp oracle (shape/feature sweep)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.attention.ops import HUGE, flash_attention
from repro.kernels.attention.ref import flash_attention_ref


def _qkv(seed, B=2, Lq=32, M=32, KV=2, G=3, hd=16):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (B, Lq, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, M, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, M, KV, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("Lq,M,KV,G,hd", [
    (32, 32, 2, 3, 16), (64, 64, 1, 8, 32), (16, 64, 4, 1, 8)])
def test_flash_matches_ref_causal(Lq, M, KV, G, hd):
    q, k, v = _qkv(0, Lq=Lq, M=M, KV=KV, G=G, hd=hd)
    qp = jnp.arange(Lq, dtype=jnp.int32)
    kp = jnp.arange(M, dtype=jnp.int32)
    got = flash_attention(q, k, v, qp, kp)
    want = flash_attention_ref(q, k, v, qp, kp, HUGE, 0, HUGE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_flash_window_prefix_softcap():
    q, k, v = _qkv(1, Lq=48, M=48)
    qp = jnp.arange(48, dtype=jnp.int32)
    kp = jnp.arange(48, dtype=jnp.int32)
    got = flash_attention(q, k, v, qp, kp, window=8, prefix=12, softcap=30.0)
    want = flash_attention_ref(q, k, v, qp, kp, 8, 12, HUGE, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_flash_decode_max_kv():
    """Decode shape: 1 query attending a bounded cache region."""
    q, k, v = _qkv(2, Lq=1, M=64)
    qp = jnp.asarray([40], jnp.int32)
    kp = jnp.arange(64, dtype=jnp.int32)
    got = flash_attention(q, k, v, qp, kp, max_kv=40)
    want = flash_attention_ref(q, k, v, qp, kp, HUGE, 0, 40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)
