"""Resilient runner on the 8-device distributed pipeline.

Same subprocess pattern as ``test_distributed.py``: one driver under
``--xla_force_host_platform_device_count=8`` exercises crash/resume at
every stage boundary, the overflow policies, and the straggler
telemetry, and prints a JSON report the tests assert on.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_DRIVER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import tempfile
    import numpy as np
    import jax
    from repro.data.synthetic import figure1_scenario
    from repro.core.types import DSCParams
    from repro.core.partitioning import partition_batch
    from repro.core.distributed import run_dsc_distributed
    from repro.run import FaultPlan, InjectedCrash, run_resilient_distributed
    from repro.run.resilient import STAGES

    batch, _ = figure1_scenario(n_per_route=4, points_per_leg=24, seed=0)
    params = DSCParams(eps_sp=0.42, eps_t=1.0, delta_t=0.0, w=6, tau=0.15,
                       alpha_sigma=-1.0, k_sigma=-1.0, segmentation="tsa2")
    mesh = jax.make_mesh((4, 2), ("part", "model"))
    parts = partition_batch(batch, 4)
    tmp = tempfile.mkdtemp()
    report = {}

    # monolithic dense run = the bit-identity reference
    ref = run_dsc_distributed(parts, params, mesh)
    rm = np.asarray(ref.result.member_of)
    rr = np.asarray(ref.result.is_rep)
    ro = np.asarray(ref.result.is_outlier)
    rs = np.asarray(ref.result.member_sim)

    def agrees(out):
        r = out.result
        return bool((np.asarray(r.member_of) == rm).all()
                    and (np.asarray(r.is_rep) == rr).all()
                    and (np.asarray(r.is_outlier) == ro).all()
                    and (np.asarray(r.member_sim) == rs).all())

    # fresh staged run (no persistence) reproduces the monolith
    res = run_resilient_distributed(parts, params, mesh)
    report["fresh_agree"] = agrees(res.output)

    # kill at every stage boundary; resume must be bit-identical
    for stage in STAGES:
        root = f"{tmp}/crash_{stage}"
        try:
            run_resilient_distributed(
                parts, params, mesh, checkpoint_dir=root,
                fault_plan=FaultPlan(crash_at=stage))
            report[f"crash_{stage}_raised"] = False
        except InjectedCrash:
            report[f"crash_{stage}_raised"] = True
        r2 = run_resilient_distributed(parts, params, mesh,
                                       checkpoint_dir=root)
        report[f"resume_{stage}_from"] = r2.resumed_from
        report[f"resume_{stage}_agree"] = agrees(r2.output)

    # stage-level widen from a spilling K recovers the dense labels
    rw = run_resilient_distributed(parts, params, mesh, sim_mode="topk",
                                   sim_topk=4, on_overflow="widen")
    report["widen_count"] = rw.widen_count
    report["widen_agree"] = agrees(rw.output)
    report["widen_overflow"] = int(
        np.asarray(rw.output.sim_diag)[:, 3].sum())

    # degrade completes and records the nonzero certificate
    rd = run_resilient_distributed(parts, params, mesh, sim_mode="topk",
                                   sim_topk=4, on_overflow="degrade")
    report["degrade_overflow"] = int(
        np.asarray(rd.output.sim_diag)[:, 3].sum())

    # the monolithic driver's on_overflow="widen" completes too
    # (acceptance criterion: no raise, clean certificate, same labels)
    om = run_dsc_distributed(parts, params, mesh, sim_mode="topk",
                             sim_topk=4, on_overflow="widen")
    report["monolith_widen_agree"] = agrees(om)
    report["monolith_widen_overflow"] = int(
        np.asarray(om.sim_diag)[:, 3].sum())

    # scripted slowdown on partition 2: flag + rebalance suggestion
    slow = tuple((s, 2, 30.0) for s in STAGES)
    rsl = run_resilient_distributed(parts, params, mesh,
                                    fault_plan=FaultPlan(slow=slow))
    flags = [e for e in rsl.events if e["event"] == "straggler_flagged"]
    rebal = [e for e in rsl.events
             if e["event"] == "rebalance_suggestion"]
    report["straggler_flagged_p2"] = bool(
        flags and all("2" in e["partitions"] for e in flags))
    report["rebalance_edges"] = rebal[-1]["edges"] if rebal else None
    print("JSON" + json.dumps(report))
""")


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("JSON")][-1]
    return json.loads(line[4:])


pytestmark = [pytest.mark.distributed, pytest.mark.slow,
              pytest.mark.faults]

_STAGES = ("join", "segment", "similarity", "cluster", "refine")


def test_fresh_staged_run_matches_monolith(report):
    assert report["fresh_agree"]


@pytest.mark.parametrize("stage", _STAGES)
def test_resume_bit_identity(report, stage):
    assert report[f"crash_{stage}_raised"]
    assert report[f"resume_{stage}_from"] == _STAGES.index(stage)
    assert report[f"resume_{stage}_agree"]


def test_widen_recovers_dense_labels(report):
    assert report["widen_count"] >= 1
    assert report["widen_agree"]
    assert report["widen_overflow"] == 0


def test_degrade_records_certificate(report):
    assert report["degrade_overflow"] > 0


def test_monolith_widen_policy(report):
    assert report["monolith_widen_agree"]
    assert report["monolith_widen_overflow"] == 0


def test_straggler_flag_and_rebalance(report):
    assert report["straggler_flagged_p2"]
    edges = report["rebalance_edges"]
    assert edges is not None and len(edges) == 5
    assert edges[0] == -float("inf") and edges[-1] == float("inf")
