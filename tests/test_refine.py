"""Property suite for cross-partition refinement (Algorithm 5).

``refine_states`` reduces a ``[P, S]`` stack of per-partition clustering
states to one consistent global state via the paper's case table (a)-(f):

    (a) outlier everywhere            -> outlier, deduplicated
    (b) Repr in every partition       -> Repr
    (c) member of several clusters    -> member of the max-similarity one
    (d) Repr here, member there       -> Repr
    (e) Repr here, outlier there      -> Repr
    (f) member here, outlier there    -> member

Each case gets a pinned construction, and a hypothesis-driven comparison
against a literal numpy transcription of the table covers the mixtures
(tie-breaks, all-invalid rows, replicated rep-vs-member conflicts).
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.refine import refine_states

A, K = jnp.float32(0.5), jnp.float32(1.0)


def _refine(member_of, member_sim, is_rep, valid):
    return refine_states(jnp.asarray(member_of, jnp.int32),
                         jnp.asarray(member_sim, jnp.float32),
                         jnp.asarray(is_rep), jnp.asarray(valid), A, K)


def _oracle(member_of, member_sim, is_rep, valid):
    """Literal per-slot case-table reduction (numpy, O(P*S) loops)."""
    P, S = member_of.shape
    out_of = np.full(S, -1, np.int32)
    out_sim = np.zeros(S, np.float32)
    out_rep = np.zeros(S, bool)
    out_out = np.zeros(S, bool)
    for s in range(S):
        seen = [p for p in range(P) if valid[p, s]]
        if not seen:
            continue
        if any(is_rep[p, s] for p in seen):          # cases b, d, e
            out_rep[s] = True
            out_of[s] = s
            out_sim[s] = np.inf
            continue
        members = [p for p in seen
                   if member_of[p, s] >= 0 and not is_rep[p, s]]
        if members:                                   # cases c, f
            best = max(members, key=lambda p: (member_sim[p, s], -p))
            out_of[s] = member_of[best, s]
            out_sim[s] = member_sim[best, s]
        else:                                         # case a
            out_out[s] = True
    return out_of, out_sim, out_rep, out_out


def test_case_a_outlier_dedup():
    out = _refine([[-1], [-1]], [[0.0], [0.0]],
                  [[False], [False]], [[True], [True]])
    assert bool(out.is_outlier[0]) and int(out.member_of[0]) == -1


def test_case_b_rep_everywhere():
    out = _refine([[0], [0]], [[np.inf], [np.inf]],
                  [[True], [True]], [[True], [True]])
    assert bool(out.is_rep[0]) and int(out.member_of[0]) == 0
    assert not bool(out.is_outlier[0])


def test_case_c_member_max_similarity_wins():
    """Member of cluster 1 (sim 0.4) in P0, of cluster 2 (sim 0.9) in P1."""
    member_of = [[-1, 1, -1], [-1, 2, -1]]
    member_sim = [[0.0, 0.4, 0.0], [0.0, 0.9, 0.0]]
    is_rep = [[True, False, False], [False, False, True]]
    valid = [[True, True, False], [False, True, True]]
    out = _refine(member_of, member_sim, is_rep, valid)
    assert int(out.member_of[1]) == 2
    assert float(out.member_sim[1]) == pytest.approx(0.9)


def test_case_d_rep_beats_member():
    out = _refine([[0, 0], [1, -1]], [[np.inf, 0.7], [np.inf, 0.0]],
                  [[True, False], [True, False]],
                  [[True, True], [True, True]])
    # slot 1: member of 0 in P0, rep in... nowhere; stays a member
    assert int(out.member_of[1]) == 0
    # slot 0: rep in P0 AND (as slot 1's target) rep in P1 -> rep
    assert bool(out.is_rep[0])


def test_case_d_rep_vs_member_conflict():
    """Replicated slot: claimed as a member in P0, representative in P1."""
    out = _refine([[2, -1], [0, -1]], [[0.8, 0.0], [np.inf, 0.0]],
                  [[False, False], [True, False]],
                  [[True, False], [True, False]])
    assert bool(out.is_rep[0])
    assert int(out.member_of[0]) == 0
    assert float(out.member_sim[0]) == np.inf


def test_case_e_rep_beats_outlier():
    out = _refine([[0], [-1]], [[np.inf], [0.0]],
                  [[True], [False]], [[True], [True]])
    assert bool(out.is_rep[0]) and not bool(out.is_outlier[0])


def test_case_f_member_beats_outlier():
    out = _refine([[3], [-1]], [[0.6], [0.0]],
                  [[False], [False]], [[True], [True]])
    assert int(out.member_of[0]) == 3
    assert float(out.member_sim[0]) == pytest.approx(0.6)
    assert not bool(out.is_outlier[0])


def test_all_invalid_rows_carry_no_state():
    out = _refine([[5], [7]], [[0.9], [0.3]],
                  [[False], [False]], [[False], [False]])
    assert int(out.member_of[0]) == -1
    assert float(out.member_sim[0]) == 0.0
    assert not bool(out.is_rep[0]) and not bool(out.is_outlier[0])


def test_member_sim_tie_breaks_first_partition():
    """Equal member similarities: argmax picks the lowest partition index,
    deterministically."""
    member_of = [[4], [6]]
    member_sim = [[0.5], [0.5]]
    flags = [[False], [False]]
    out = _refine(member_of, member_sim, flags, [[True], [True]])
    assert int(out.member_of[0]) == 4


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_matches_case_table_oracle(seed):
    """Random state stacks (reps with +inf sims, members, outliers,
    invalid rows) reduce exactly like the literal case table."""
    rng = np.random.default_rng(seed)
    P, S = rng.integers(1, 5), rng.integers(1, 12)
    valid = rng.uniform(0, 1, (P, S)) > 0.3
    state = rng.integers(0, 3, (P, S))          # 0 outlier, 1 member, 2 rep
    is_rep = state == 2
    member_of = np.where(is_rep, np.arange(S)[None, :], -1).astype(np.int32)
    member_sim = np.where(is_rep, np.inf, 0.0).astype(np.float32)
    is_member = state == 1
    member_of = np.where(is_member, rng.integers(0, S, (P, S)), member_of)
    # draw from a 3-value set so cross-partition similarity ties occur
    member_sim = np.where(
        is_member, rng.choice([0.25, 0.5, 0.75], (P, S)), member_sim
    ).astype(np.float32)

    out = _refine(member_of, member_sim, is_rep, valid)
    o_of, o_sim, o_rep, o_out = _oracle(member_of, member_sim, is_rep, valid)
    assert np.array_equal(np.asarray(out.member_of), o_of)
    assert np.array_equal(np.asarray(out.member_sim), o_sim)
    assert np.array_equal(np.asarray(out.is_rep), o_rep)
    assert np.array_equal(np.asarray(out.is_outlier), o_out)


def test_collapsed_membership_predicate_pinned():
    """The simplified ``isfinite(best_sim)`` membership test equals the
    former ``isfinite & (> -inf)`` conjunction on every reachable input:
    the masked stack holds finite sims (members), -inf (masked), and the
    mask removes rep rows' +inf before the argmax."""
    rng = np.random.default_rng(0)
    vals = np.concatenate([rng.uniform(0, 1, 64).astype(np.float32),
                           np.full(8, -np.inf, np.float32),
                           np.full(8, np.inf, np.float32)])
    old = np.isfinite(vals) & (vals > -np.inf)
    new = np.isfinite(vals)
    assert np.array_equal(old, new)
