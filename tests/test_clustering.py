"""Property tests on Algorithm 4's invariants (hypothesis-driven)."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.clustering import cluster, rmse, sscr
from repro.core.refine import refine_states
from repro.core.types import DSCParams, SubtrajTable


def _random_instance(seed, S=24):
    rng = np.random.default_rng(seed)
    raw = rng.uniform(0, 1, (S, S)).astype(np.float32)
    sim = np.maximum(raw, raw.T) * (rng.uniform(0, 1, (S, S)) > 0.5)
    sim = np.maximum(sim, sim.T)
    np.fill_diagonal(sim, 0.0)
    valid = rng.uniform(0, 1, S) > 0.1
    table = SubtrajTable(
        t_start=jnp.zeros(S), t_end=jnp.ones(S),
        voting=jnp.asarray(rng.uniform(0, 5, S).astype(np.float32)),
        card=jnp.asarray((rng.integers(1, 20, S)).astype(np.int32)),
        valid=jnp.asarray(valid),
        traj_row=jnp.arange(S, dtype=jnp.int32))
    return jnp.asarray(sim), table


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_cluster_invariants(seed):
    sim, table = _random_instance(seed)
    params = DSCParams(alpha_sigma=0.0, k_sigma=0.0)
    res = cluster(sim, table, params)
    member_of = np.asarray(res.member_of)
    is_rep = np.asarray(res.is_rep)
    is_out = np.asarray(res.is_outlier)
    valid = np.asarray(table.valid)
    sim_np = np.asarray(sim)
    alpha = float(res.alpha_used)

    # states partition the valid slots
    state_count = (is_rep.astype(int)
                   + ((member_of >= 0) & ~is_rep).astype(int)
                   + is_out.astype(int))
    assert (state_count[valid] == 1).all()
    # invalid slots carry no state
    assert not is_rep[~valid].any() and not is_out[~valid].any()
    # representatives point at themselves
    assert (member_of[is_rep] == np.nonzero(is_rep)[0]).all() if is_rep.any() else True
    # members meet the alpha similarity floor (Lemma 1 precondition)
    members = valid & ~is_rep & (member_of >= 0)
    for s in np.nonzero(members)[0]:
        assert sim_np[s, member_of[s]] >= alpha - 1e-5
        assert is_rep[member_of[s]]
    # voting floor for representatives
    k = float(res.k_used)
    voting = np.asarray(table.voting)
    assert (voting[is_rep] >= k - 1e-5).all()


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_lemma1_bound(seed):
    """Avg member->rep distance <= eps_sp * (1 - alpha) (Lemma 1)."""
    sim, table = _random_instance(seed)
    params = DSCParams(eps_sp=2.0, alpha_sigma=0.0, k_sigma=-1.0)
    res = cluster(sim, table, params)
    members = (np.asarray(table.valid) & ~np.asarray(res.is_rep)
               & (np.asarray(res.member_of) >= 0))
    alpha = float(res.alpha_used)
    sim_np = np.asarray(sim)
    for s in np.nonzero(members)[0]:
        s_rep = sim_np[s, np.asarray(res.member_of)[s]]
        d_avg = params.eps_sp * (1.0 - s_rep)      # Lemma 1 inversion
        assert d_avg <= params.eps_sp * (1.0 - alpha) + 1e-5


def test_members_prefer_more_similar_rep():
    """Reassignment (lines 16-19): member ends at the best-similarity rep
    among reps that claimed it."""
    S = 6
    sim = np.zeros((S, S), np.float32)
    # slots 0 and 1 are high-voted reps; slot 2 similar to both
    sim[0, 2] = sim[2, 0] = 0.6
    sim[1, 2] = sim[2, 1] = 0.9
    table = SubtrajTable(
        t_start=jnp.zeros(S), t_end=jnp.ones(S),
        voting=jnp.asarray([5.0, 4.0, 1.0, 0.0, 0.0, 0.0]),
        card=jnp.ones(S, jnp.int32),
        valid=jnp.asarray([True, True, True, False, False, False]),
        traj_row=jnp.arange(S, dtype=jnp.int32))
    params = DSCParams(alpha_abs=0.5, k_abs=2.0)
    res = cluster(jnp.asarray(sim), table, params)
    assert bool(res.is_rep[0]) and bool(res.is_rep[1])
    assert int(res.member_of[2]) == 1          # reassigned to the 0.9 rep


def test_refine_case_table():
    """Algorithm 5: Repr beats member beats outlier; best-sim member wins."""
    S = 4
    member_of = jnp.asarray([[0, 0, -1, 3], [0, 1, -1, -1]])
    member_sim = jnp.asarray([[np.inf, 0.4, 0.0, 0.7],
                              [np.inf, np.inf, 0.0, 0.0]])
    is_rep = jnp.asarray([[True, False, False, False],
                          [True, True, False, False]])
    valid = jnp.asarray([[True, True, True, True],
                         [True, True, True, False]])
    out = refine_states(member_of, member_sim, is_rep, valid,
                        jnp.float32(0.5), jnp.float32(1.0))
    # slot 0: rep in both -> rep (case b)
    assert bool(out.is_rep[0])
    # slot 1: member in P0, rep in P1 -> rep (case d)
    assert bool(out.is_rep[1])
    # slot 2: outlier in both -> outlier once (case a)
    assert bool(out.is_outlier[2])
    # slot 3: member in P0 only (case f) -> member, not outlier
    assert int(out.member_of[3]) == 3 and not bool(out.is_outlier[3])


def test_sscr_and_rmse_consistency():
    sim, table = _random_instance(0)
    params = DSCParams(eps_sp=1.0, alpha_sigma=0.0, k_sigma=0.0)
    res = cluster(sim, table, params)
    assert float(sscr(res, sim)) >= 0.0
    assert 0.0 <= float(rmse(res, sim, params.eps_sp)) <= params.eps_sp
