"""Roofline position: the reusable core the autotuner records per plan."""
import os
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS,  # noqa: E402
                                 roofline_position)
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402


def test_roofline_position_fields_and_dominant():
    pos = roofline_position(flops=197e12, hbm_bytes=0.0)
    assert pos["compute_s"] == 1.0 and pos["dominant"] == "compute"
    assert pos["bound_s"] == 1.0 and pos["intensity"] == 0.0

    pos = roofline_position(flops=0.0, hbm_bytes=819e9)
    assert pos["memory_s"] == 1.0 and pos["dominant"] == "memory"

    pos = roofline_position(flops=1.0, hbm_bytes=1.0, coll_bytes=50e9)
    assert pos["collective_s"] == 1.0 and pos["dominant"] == "collective"


def test_roofline_position_consistent_with_constants():
    flops, hbm, coll = 2e12, 8e9, 1e9
    pos = roofline_position(flops, hbm, coll)
    assert pos["compute_s"] == flops / PEAK_FLOPS
    assert pos["memory_s"] == hbm / HBM_BW
    assert pos["collective_s"] == coll / LINK_BW
    assert pos["bound_s"] == max(pos["compute_s"], pos["memory_s"],
                                 pos["collective_s"])
    assert pos["intensity"] == flops / hbm


def test_roofline_from_analyzed_hlo_bench_shape():
    # the autotuner's exact path: compiled HLO -> analyze_hlo ->
    # roofline_position, at a small matmul whose FLOPs are known
    n = 128
    a = jnp.zeros((n, n), jnp.float32)
    hlo = jax.jit(lambda x: x @ x).lower(a).compile().as_text()
    res = analyze_hlo(hlo)
    assert res["flops"] == 2.0 * n * n * n
    pos = roofline_position(res["flops"],
                            res["hbm_traffic_fused_bytes"]
                            or res["hbm_traffic_bytes"],
                            res["collective_bytes"])
    assert pos["bound_s"] > 0
    assert pos["dominant"] in ("compute", "memory")
