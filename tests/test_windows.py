"""Monoid sliding-window engine: naive-oracle equality for every op,
offset-window shape, and the idempotent block-scan edge cases."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.windows import sliding_reduce, window_pair


def _naive(sig: np.ndarray, lo: int, hi: int, op: str) -> np.ndarray:
    """Per-position reduce over [n+lo, n+hi] ∩ [0, M) — the definition."""
    M = sig.shape[1]
    ident = {"sum": 0, "max": -np.inf, "or": 0}[op]
    f = {"sum": np.add, "max": np.maximum, "or": np.bitwise_or}[op]
    out = np.empty_like(sig)
    for n in range(M):
        acc = np.full(sig.shape[2:] or (), ident, sig.dtype)
        for k in range(max(n + lo, 0), min(n + hi, M - 1) + 1):
            acc = f(acc, sig[:, k])
        out[:, n] = acc
    return out


WINDOWS = [(-3, -1), (0, 2), (1, 4), (-5, 3), (-1, -1), (2, 2),
           (-2, 0), (-40, 40), (-40, -30), (30, 40)]


@pytest.mark.parametrize("lo,hi", WINDOWS)
def test_sum_and_max_match_naive(lo, hi):
    rng = np.random.default_rng(abs(lo) * 100 + abs(hi))
    d = rng.uniform(0, 1, (3, 17)).astype(np.float32)
    for op in ("sum", "max"):
        got = np.asarray(sliding_reduce(jnp.asarray(d), lo, hi, op))
        want = _naive(d, lo, hi, op).astype(np.float32)
        np.testing.assert_allclose(got, want, atol=1e-5, err_msg=op)


@pytest.mark.parametrize("lo,hi", WINDOWS)
def test_packed_or_matches_naive(lo, hi):
    """The block OR-scan on uint32 words with trailing dims is exact."""
    rng = np.random.default_rng(abs(lo) * 7 + abs(hi))
    m = rng.integers(0, 2 ** 31, (3, 17, 2)).astype(np.uint32)
    got = np.asarray(sliding_reduce(jnp.asarray(m), lo, hi, "or"))
    assert (got == _naive(m, lo, hi, "or")).all()


def test_empty_window_is_identity():
    d = jnp.ones((2, 9), jnp.float32)
    assert (np.asarray(sliding_reduce(d, 1, 0, "sum")) == 0).all()
    assert (np.asarray(sliding_reduce(d, 1, 0, "max")) == -np.inf).all()
    m = jnp.full((2, 9, 1), 7, jnp.uint32)
    assert (np.asarray(sliding_reduce(m, 1, 0, "or")) == 0).all()


def test_unknown_op_rejected():
    with pytest.raises(ValueError):
        sliding_reduce(jnp.ones((1, 4)), -1, 1, "mean")


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_random_windows_property(seed):
    """Random (lo, hi, M) sweeps, including single-element and window-
    larger-than-array shapes, for all three monoids."""
    rng = np.random.default_rng(seed)
    M = int(rng.integers(1, 33))
    lo = int(rng.integers(-M - 2, M + 2))
    hi = lo + int(rng.integers(0, M + 3))
    d = rng.uniform(-1, 1, (2, M)).astype(np.float32)
    for op in ("sum", "max"):
        got = np.asarray(sliding_reduce(jnp.asarray(d), lo, hi, op))
        np.testing.assert_allclose(got, _naive(d, lo, hi, op), atol=1e-5)
    m = rng.integers(0, 2 ** 31, (2, M, 3)).astype(np.uint32)
    got = np.asarray(sliding_reduce(jnp.asarray(m), lo, hi, "or"))
    assert (got == _naive(m, lo, hi, "or")).all()


def test_window_pair_is_w1_w2():
    rng = np.random.default_rng(3)
    d = jnp.asarray(rng.uniform(0, 1, (2, 21)).astype(np.float32))
    w = 4
    r1, r2 = window_pair(d, w, "sum")
    np.testing.assert_allclose(np.asarray(r1),
                               _naive(np.asarray(d), -w, -1, "sum"),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(r2),
                               _naive(np.asarray(d), 0, w - 1, "sum"),
                               atol=1e-5)


def test_pack_bits_pins_former_inline_packers():
    """The shared packed-word helper is bit-equal to the two inline
    packers it replaced (``voting.neighbor_mask_packed``'s reshape
    formula and ``distributed._pack_bits``), round-trips through
    ``unpack_bits``, and keeps the bit-c-of-word-c//32 layout the
    Jaccard kernels and the fused join epilogues assume."""
    from repro.core.windows import pack_bits, unpack_bits

    rng = np.random.default_rng(7)
    for shape, C in (((3, 5, 70), 70), ((4, 33), 33), ((2, 2, 64), 64)):
        b = rng.uniform(0, 1, shape) > 0.5
        got = np.asarray(pack_bits(jnp.asarray(b)))

        # the retired inline formula, transcribed verbatim
        W = -(-C // 32)
        pad = np.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, W * 32 - C)])
        bits = pad.reshape(*b.shape[:-1], W, 32).astype(np.uint32)
        want = np.sum(bits << np.arange(32, dtype=np.uint32), axis=-1,
                      dtype=np.uint32)
        assert np.array_equal(got, want)
        assert np.array_equal(
            np.asarray(unpack_bits(jnp.asarray(got), C)), b)
        # layout: bit c lives in word c // 32 at position c % 32
        idx = np.ndindex(*shape)
        c0 = next(iter(np.argwhere(b.reshape(-1, C)[0])), None)
        if c0 is not None:
            c = int(c0[0])
            assert (got.reshape(-1, W)[0, c // 32] >> (c % 32)) & 1
