"""Loop-corrected HLO accounting: validated against known-FLOPs programs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (
    analyze_hlo,
    buffer_inventory,
    find_buffers_with_elements,
    interface_buffer_stats,
    peak_buffer_stats,
)


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_buffer_inventory_sees_program_arrays():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    text = _hlo(lambda x, y: x @ y, a, b)
    inv = buffer_inventory(text)
    sizes = {b["bytes"] for b in inv}
    assert 128 * 256 * 4 in sizes          # parameter
    assert 128 * 64 * 4 in sizes           # output
    assert peak_buffer_stats(text)["largest_bytes"] >= 128 * 256 * 4


def test_find_buffers_with_elements_fingerprint():
    a = jnp.zeros((16, 32), jnp.float32)
    text = _hlo(lambda x: x[:, :, None] * jnp.ones((16, 32, 8)), a)
    assert find_buffers_with_elements(text, 16 * 32 * 8, ("f32",))
    assert not find_buffers_with_elements(text, 12345, ("f32",))


def test_interface_buffer_stats_params_and_root():
    a = jnp.zeros((64, 64), jnp.float32)
    b = jnp.zeros((64, 16), jnp.float32)
    stats = interface_buffer_stats(_hlo(lambda x, y: x @ y, a, b))
    kinds = {t["kind"] for t in stats["top"]}
    assert kinds == {"param", "output"}
    # params (16K + 4K) + output (4K); internal temporaries excluded
    assert stats["total_bytes"] == 64 * 64 * 4 + 2 * 64 * 16 * 4
    assert stats["largest_bytes"] == 64 * 64 * 4


def test_single_dot_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    res = analyze_hlo(_hlo(lambda x, y: x @ y, a, b))
    want = 2 * 128 * 256 * 64
    assert res["flops"] == pytest.approx(want, rel=0.01)


def test_scan_multiplies_by_trip_count():
    """A matmul inside a 10-step scan must count 10x."""
    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def fn(x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    res = analyze_hlo(_hlo(fn, x))
    want = 10 * 2 * 8 * 64 * 64
    assert res["num_whiles"] >= 1
    assert res["flops"] == pytest.approx(want, rel=0.05), res


def test_nested_scan_multiplies():
    w = jnp.zeros((32, 32), jnp.float32)
    x = jnp.zeros((4, 32), jnp.float32)

    def fn(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    res = analyze_hlo(_hlo(fn, x))
    want = 3 * 5 * 2 * 4 * 32 * 32
    assert res["flops"] == pytest.approx(want, rel=0.05), res


def test_batched_dot_flops():
    a = jnp.zeros((4, 16, 32), jnp.float32)
    b = jnp.zeros((4, 32, 8), jnp.float32)
    res = analyze_hlo(_hlo(lambda x, y: jnp.einsum("bij,bjk->bik", x, y),
                           a, b))
    want = 2 * 4 * 16 * 32 * 8
    assert res["flops"] == pytest.approx(want, rel=0.01)
