"""Expert-parallel MoE: shard_map all_to_all path vs single-device path
(subprocess with forced host devices), incl. int8 dispatch quantization."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = [pytest.mark.distributed, pytest.mark.slow]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_DRIVER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.config import MoEConfig
    from repro.models.moe import (init_moe, moe_ffn, moe_ffn_shard_map,
                                  moe_ffn_dense_ref)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, n_shared=1,
                    capacity_factor=8.0)
    D = 32
    p = init_moe(jax.random.PRNGKey(0), D, cfg, ep_degree=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, D), jnp.float32)

    with mesh:
        y_ep, aux_ep, drop_ep = jax.jit(
            lambda p, x: moe_ffn_shard_map(p, x, cfg, mesh, ("data",)))(p, x)
    y_ref = moe_ffn_dense_ref(p, x, cfg)
    report = {
        "ep_close": bool(np.allclose(np.asarray(y_ep, np.float32),
                                     np.asarray(y_ref, np.float32),
                                     atol=5e-2, rtol=5e-2)),
        "dropped": float(drop_ep),
    }

    with mesh:
        y_q, _, _ = jax.jit(
            lambda p, x: moe_ffn_shard_map(p, x, cfg, mesh, ("data",),
                                           quantize_dispatch=True))(p, x)
    err = np.abs(np.asarray(y_q, np.float32) - np.asarray(y_ref,
                                                          np.float32))
    scale = np.abs(np.asarray(y_ref, np.float32)).max()
    report["quant_rel_err"] = float(err.max() / max(scale, 1e-9))
    print("JSON" + json.dumps(report))
""")


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


def test_ep_matches_dense_reference(report):
    assert report["ep_close"]
    assert report["dropped"] == 0.0


def test_quantized_dispatch_small_error(report):
    """int8 dispatch introduces bounded (~1%) relative error."""
    assert report["quant_rel_err"] < 0.05, report["quant_rel_err"]
