"""Sharding-rule unit tests: policies, divisibility fallbacks, data specs —
plus the temporal-partitioning ingest pins (vectorized vs loop versions)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHITECTURES, get_arch
from repro.core import partitioning as pz
from repro.core.types import TrajectoryBatch
from repro.distributed import partition
from repro.models import transformer as tf


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@pytest.fixture(scope="module")
def smollm_params():
    cfg = get_arch("smollm-360m")
    return cfg, jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg))


def test_tp_respects_divisibility(smollm_params):
    """15 q-heads and 5 kv-heads don't divide 16 -> attention replicated;
    mlp (2560) and embeddings (49152) shard."""
    cfg, params = smollm_params
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="tp")
    flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))}
    assert flat["embed/table"] == P("model", None)
    # stacked layer leaves have the leading scan dim
    assert all(a is None for a in flat["layers/attn/wq"])   # 15 % 16 != 0
    assert flat["layers/mlp/wi_gate"] == P(None, None, "model")
    assert flat["layers/mlp/wo"] == P(None, "model", None)


def test_tp_shards_divisible_heads():
    cfg = get_arch("yi-6b")       # 32 heads, kv=4
    params = jax.eval_shape(lambda: tf.init_model(jax.random.PRNGKey(0),
                                                  cfg))
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="tp")
    flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))}
    assert flat["layers/attn/wq"] == P(None, None, "model", None)
    assert all(a is None for a in flat["layers/attn/wk"])   # kv=4 % 16
    assert flat["layers/attn/wo"] == P(None, "model", None, None)


def test_dp_only_replicates_everything(smollm_params):
    cfg, params = smollm_params
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="dp_only")
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert all(a is None for a in s)


def test_dp_fsdp_shards_every_large_leaf(smollm_params):
    cfg, params = smollm_params
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="dp_fsdp")
    rep = partition.report_sharding(params, specs)
    assert rep["replicated_frac"] < 0.02


def test_moe_expert_sharding():
    cfg = get_arch("qwen2-moe-a2.7b")
    params = jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg, ep_degree=16))
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="tp")
    flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))}
    assert flat["layers/moe/w_gate"] == P(None, "model", None, None)
    assert all(a is None for a in flat["layers/moe/router"])


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_all_archs_have_consistent_specs(arch):
    """Every spec's sharded dims divide the axis sizes (GSPMD requirement)."""
    cfg = get_arch(arch)
    params = jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg, ep_degree=16))
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="tp")
    for (path, leaf), spec in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, (arch, path, spec,
                                                 leaf.shape)


def test_decode_data_specs_long_context():
    """long_500k decode (B=1): cache sequence axis sharded over 'data'."""
    cfg = get_arch("gemma2-2b")
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.data_specs(cfg, mesh, kind="decode", global_batch=1,
                                 seq_len=524_288)
    assert specs["cache"]["k"][2] == "data"
    # kv=4 indivisible by 16 -> head axis replicated
    assert specs["cache"]["k"][3] is None


def test_decode_data_specs_batched():
    cfg = get_arch("deepseek-7b")
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.data_specs(cfg, mesh, kind="decode", global_batch=128,
                                 seq_len=32_768)
    assert specs["cache"]["k"][1] == ("data",) or \
        specs["cache"]["k"][1] == "data"
    assert specs["cache"]["k"][3] == "model"          # kv=32 divides 16


# ---------------------------------------------------------------------------
# Temporal equi-depth partitioning ingest: the vectorized argsort+scatter
# pass and the ordered-int duplicate-edge scan are pinned against the
# original Python-loop formulations they replaced.
# ---------------------------------------------------------------------------


def _equi_depth_edges_loop(times, Pn, sample=100_000, seed=0):
    """The former per-edge bump loop, kept as the regression oracle."""
    times = np.asarray(times).ravel()
    if sample is not None and times.size > sample:
        rng = np.random.default_rng(seed)
        times = rng.choice(times, size=sample, replace=False)
    qs = np.quantile(times, np.linspace(0.0, 1.0, Pn + 1))
    qs[0], qs[-1] = -np.inf, np.inf
    for i in range(1, Pn):
        if qs[i] <= qs[i - 1]:
            qs[i] = np.nextafter(qs[i - 1], np.inf)
    return qs.astype(np.float64)


def _partition_batch_loop(batch, Pn, pad_mp_to=8, sample=100_000):
    """The former O(P*T) per-cell np.nonzero double loop."""
    x = np.asarray(batch.x)
    y = np.asarray(batch.y)
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    T, M = x.shape
    edges = _equi_depth_edges_loop(t[v], Pn, sample=sample)
    pidx = np.searchsorted(edges, t, side="right") - 1
    pidx = np.clip(pidx, 0, Pn - 1)
    pidx = np.where(v, pidx, -1)
    counts = np.zeros((Pn, T), np.int64)
    for p in range(Pn):
        counts[p] = (pidx == p).sum(axis=1)
    Mp = int(counts.max(initial=1))
    Mp = max(pad_mp_to, ((Mp + pad_mp_to - 1) // pad_mp_to) * pad_mp_to)
    px = np.zeros((Pn, T, Mp), np.float32)
    py = np.zeros((Pn, T, Mp), np.float32)
    pt = np.zeros((Pn, T, Mp), np.float32)
    pv = np.zeros((Pn, T, Mp), bool)
    for p in range(Pn):
        for r in range(T):
            sel = np.nonzero(pidx[r] == p)[0]
            m = len(sel)
            if m:
                px[p, r, :m] = x[r, sel]
                py[p, r, :m] = y[r, sel]
                pt[p, r, :m] = t[r, sel]
                pv[p, r, :m] = True
    return px, py, pt, pv


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_equi_depth_edges_match_loop(seed):
    """Rank-space maximum.accumulate == per-edge nextafter loop (float ==
    semantics) — including all-duplicate and few-distinct-value time
    arrays (cascading bumps) and data whose quantiles land on -0.0 or
    subnormals, where the raw IEEE total order and nextafter disagree
    (the -0.0/+0.0 key pair)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 60))
    Pn = int(rng.integers(1, 9))
    kind = seed % 6
    if kind == 0:
        times = rng.uniform(-100, 100, n)
    elif kind == 1:
        times = np.full(n, rng.uniform(0, 10))          # every edge collides
    elif kind == 2:
        times = rng.choice([0.0, 1.0, np.nextafter(1.0, 2.0), -5.0], n)
    elif kind == 3:
        times = rng.choice([-1e-323, -5e-324, -0.0, 0.0, 5e-324], n)
    elif kind == 4:
        times = np.full(n, -5e-324)    # bump chain crosses the zero class
    else:
        times = np.round(rng.uniform(0, 3, n))
    got = pz.equi_depth_edges(times, Pn, sample=None)
    want = _equi_depth_edges_loop(times, Pn, sample=None)
    assert np.array_equal(got, want), (seed, got, want)
    # the guard's actual contract: interior edges strictly increase
    assert (np.diff(got[:-1]) > 0).all(), (seed, got)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_partition_batch_matches_loop(seed):
    """argsort+scatter ingest == per-cell double loop, bit for bit —
    slot order, padding, and all-invalid rows included."""
    rng = np.random.default_rng(seed)
    T, M = int(rng.integers(1, 10)), int(rng.integers(1, 28))
    Pn = int(rng.integers(1, 6))
    x = rng.uniform(0, 10, (T, M)).astype(np.float32)
    y = rng.uniform(0, 10, (T, M)).astype(np.float32)
    t = np.sort(rng.uniform(0, 50, (T, M)), axis=1).astype(np.float32)
    if seed % 3 == 0:
        t = np.round(t)                                 # duplicate times
    v = rng.uniform(0, 1, (T, M)) > 0.3
    if seed % 5 == 0:
        v[0] = False                                    # all-invalid row
    if not v.any():
        v[0, 0] = True
    batch = TrajectoryBatch(
        x=jnp.asarray(x), y=jnp.asarray(y), t=jnp.asarray(t),
        valid=jnp.asarray(v), traj_id=jnp.arange(T, dtype=jnp.int32))
    got = pz.partition_batch(batch, Pn)
    want = _partition_batch_loop(batch, Pn)
    for g, w_, name in zip((got.x, got.y, got.t, got.valid), want,
                           ("x", "y", "t", "valid")):
        assert np.array_equal(np.asarray(g), w_), (seed, name)


# ---------------------------------------------------------------------------
# Canonical global form: PointLayout gather/scatter + repartition
# (the elastic-resume substrate, DESIGN.md §11)
# ---------------------------------------------------------------------------


def _random_batch(seed, T=6, M=24):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 10, (T, M)).astype(np.float32)
    y = rng.uniform(0, 10, (T, M)).astype(np.float32)
    t = np.sort(rng.uniform(0, 50, (T, M)), axis=1).astype(np.float32)
    v = rng.uniform(0, 1, (T, M)) > 0.25
    v[:, 0] = True
    return TrajectoryBatch(
        x=jnp.asarray(x), y=jnp.asarray(y), t=jnp.asarray(t),
        valid=jnp.asarray(v), traj_id=jnp.arange(T, dtype=jnp.int32))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_point_layout_gather_scatter_roundtrip(seed):
    batch = _random_batch(seed)
    parts = pz.partition_batch(batch, 4)
    lay = pz.PointLayout.from_parts(parts)
    # from_parts reconstructs the same layout from_global would build
    lay2 = pz.PointLayout.from_global(np.asarray(batch.t),
                                      np.asarray(batch.valid),
                                      parts.edges, Mp=lay.Mp)
    assert lay.same_layout(lay2)
    assert np.array_equal(lay.src_m, lay2.src_m)
    rng = np.random.default_rng(seed)
    leaf = rng.normal(size=(4, lay.t.shape[0], lay.Mp, 3)) \
        .astype(np.float32)
    leaf[~np.asarray(parts.valid)] = 0.0
    glob = pz.gather_global(leaf, lay)
    back = lay.scatter(glob)
    assert np.array_equal(back, leaf)
    # gather places each slot at its recorded global row
    pv = np.asarray(parts.valid)
    assert glob[np.asarray(batch.valid)].shape[0] == int(pv.sum())


@pytest.mark.parametrize("newP", [1, 2, 8])
def test_repartition_point_leaf_preserves_global_rows(newP):
    batch = _random_batch(7)
    parts4 = pz.partition_batch(batch, 4)
    old = pz.PointLayout.from_parts(parts4)
    partsN = pz.partition_batch(batch, newP)
    new = pz.PointLayout.from_parts(partsN)
    rng = np.random.default_rng(7)
    leaf = rng.normal(size=(4, old.t.shape[0], old.Mp)).astype(np.float32)
    leaf[~np.asarray(parts4.valid)] = 0.0
    moved = pz.repartition(leaf, old, new)
    assert moved.shape == (newP, new.t.shape[0], new.Mp)
    assert np.array_equal(pz.gather_global(moved, new),
                          pz.gather_global(leaf, old))


def test_repartition_cand_idx_tracks_global_identity():
    """A candidate-index leaf (values index the local halo slab) keeps
    pointing at the same *global* points after a re-cut."""
    rng = np.random.default_rng(3)
    T, M = 6, 24
    # one shared time axis across rows, so the self-referencing
    # candidates below stay inside the halo at every cut
    t = np.broadcast_to(np.sort(rng.uniform(0, 50, M))
                        .astype(np.float32), (T, M))
    batch = TrajectoryBatch(
        x=jnp.asarray(rng.uniform(0, 10, (T, M)).astype(np.float32)),
        y=jnp.asarray(rng.uniform(0, 10, (T, M)).astype(np.float32)),
        t=jnp.asarray(t), valid=jnp.ones((T, M), bool),
        traj_id=jnp.arange(T, dtype=jnp.int32))
    parts4 = pz.partition_batch(batch, 4)
    old = pz.PointLayout.from_parts(parts4)
    glob = np.broadcast_to(np.arange(M, dtype=np.int32)[None, :, None],
                           (T, M, T)).copy()
    leaf4 = old.scatter_cand_idx(glob)
    assert np.array_equal(old.gather_cand_idx(leaf4)[np.asarray(
        batch.valid)], glob[np.asarray(batch.valid)])
    parts2 = pz.partition_batch(batch, 2)
    new = pz.PointLayout.from_parts(parts2)
    leaf2 = pz.repartition(leaf4, old, new, kind="cand_idx")
    assert np.array_equal(new.gather_cand_idx(leaf2)[np.asarray(
        batch.valid)], glob[np.asarray(batch.valid)])


def test_repartition_batch_equals_fresh_partition():
    """Re-cutting a partitioned batch at another cut's edges reproduces
    partition_batch at those edges bit for bit — px/py/pt/pv/src_m."""
    batch = _random_batch(11)
    parts4 = pz.partition_batch(batch, 4)
    parts2 = pz.partition_batch(batch, 2)
    recut = pz.repartition_batch(parts4, parts2.edges)
    for name in ("x", "y", "t", "valid", "src_m", "edges"):
        assert np.array_equal(np.asarray(getattr(recut, name)),
                              np.asarray(getattr(parts2, name))), name


def test_repartition_rejects_mismatched_point_sets():
    a = pz.PointLayout.from_parts(pz.partition_batch(_random_batch(0), 2))
    b = pz.PointLayout.from_parts(pz.partition_batch(_random_batch(1), 2))
    leaf = np.zeros((2, a.t.shape[0], a.Mp), np.float32)
    with pytest.raises(ValueError, match="point sets"):
        pz.repartition(leaf, a, b)


def test_from_parts_requires_ingest_metadata():
    parts = pz.partition_batch(_random_batch(0), 2)
    import dataclasses as _dc
    bare = _dc.replace(parts, edges=None, src_m=None)
    with pytest.raises(ValueError, match="partition_batch"):
        pz.PointLayout.from_parts(bare)
