"""Sharding-rule unit tests: policies, divisibility fallbacks, data specs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHITECTURES, get_arch
from repro.distributed import partition
from repro.models import transformer as tf


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@pytest.fixture(scope="module")
def smollm_params():
    cfg = get_arch("smollm-360m")
    return cfg, jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg))


def test_tp_respects_divisibility(smollm_params):
    """15 q-heads and 5 kv-heads don't divide 16 -> attention replicated;
    mlp (2560) and embeddings (49152) shard."""
    cfg, params = smollm_params
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="tp")
    flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))}
    assert flat["embed/table"] == P("model", None)
    # stacked layer leaves have the leading scan dim
    assert all(a is None for a in flat["layers/attn/wq"])   # 15 % 16 != 0
    assert flat["layers/mlp/wi_gate"] == P(None, None, "model")
    assert flat["layers/mlp/wo"] == P(None, "model", None)


def test_tp_shards_divisible_heads():
    cfg = get_arch("yi-6b")       # 32 heads, kv=4
    params = jax.eval_shape(lambda: tf.init_model(jax.random.PRNGKey(0),
                                                  cfg))
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="tp")
    flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))}
    assert flat["layers/attn/wq"] == P(None, None, "model", None)
    assert all(a is None for a in flat["layers/attn/wk"])   # kv=4 % 16
    assert flat["layers/attn/wo"] == P(None, "model", None, None)


def test_dp_only_replicates_everything(smollm_params):
    cfg, params = smollm_params
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="dp_only")
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert all(a is None for a in s)


def test_dp_fsdp_shards_every_large_leaf(smollm_params):
    cfg, params = smollm_params
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="dp_fsdp")
    rep = partition.report_sharding(params, specs)
    assert rep["replicated_frac"] < 0.02


def test_moe_expert_sharding():
    cfg = get_arch("qwen2-moe-a2.7b")
    params = jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg, ep_degree=16))
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="tp")
    flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))}
    assert flat["layers/moe/w_gate"] == P(None, "model", None, None)
    assert all(a is None for a in flat["layers/moe/router"])


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_all_archs_have_consistent_specs(arch):
    """Every spec's sharded dims divide the axis sizes (GSPMD requirement)."""
    cfg = get_arch(arch)
    params = jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg, ep_degree=16))
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    specs = partition.param_specs(params, cfg, mesh, policy="tp")
    for (path, leaf), spec in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, (arch, path, spec,
                                                 leaf.shape)


def test_decode_data_specs_long_context():
    """long_500k decode (B=1): cache sequence axis sharded over 'data'."""
    cfg = get_arch("gemma2-2b")
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.data_specs(cfg, mesh, kind="decode", global_batch=1,
                                 seq_len=524_288)
    assert specs["cache"]["k"][2] == "data"
    # kv=4 indivisible by 16 -> head axis replicated
    assert specs["cache"]["k"][3] is None


def test_decode_data_specs_batched():
    cfg = get_arch("deepseek-7b")
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = partition.data_specs(cfg, mesh, kind="decode", global_batch=128,
                                 seq_len=32_768)
    assert specs["cache"]["k"][1] == ("data",) or \
        specs["cache"]["k"][1] == "data"
    assert specs["cache"]["k"][3] == "model"          # kv=32 divides 16
