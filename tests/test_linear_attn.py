"""Chunked linear attention (shared RWKV6/Mamba2 core) vs the scan oracle."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.models.linear_attn import (chunked_linear_attn, linear_attn_step,
                                      naive_scan_ref)


def _data(seed, B=2, H=2, L=37, K=8, V=16, decay_scale=0.15, scalar=False):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (B, H, L, K)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, H, L, K)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, H, L, V)), jnp.float32)
    shape = (B, H, L, 1) if scalar else (B, H, L, K)
    ld = jnp.asarray(-np.abs(rng.normal(0, decay_scale, shape)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 1, (H, K)), jnp.float32)
    return q, k, v, ld, u


@pytest.mark.parametrize("mode", ["mamba", "rwkv"])
@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_chunked_matches_scan(mode, chunk):
    q, k, v, ld, u = _data(0)
    y1, s1 = chunked_linear_attn(q, k, v, ld, mode=mode, u=u, chunk=chunk)
    y2, s2 = naive_scan_ref(q, k, v, ld, mode=mode, u=u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=3e-3)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_chunked_property(seed):
    q, k, v, ld, u = _data(seed, L=21, decay_scale=0.1)
    y1, s1 = chunked_linear_attn(q, k, v, ld, mode="mamba", chunk=8)
    y2, s2 = naive_scan_ref(q, k, v, ld, mode="mamba")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-3)


def test_state_carry_composes():
    """Processing [0:L1] then [L1:L] with the carried state == full pass."""
    q, k, v, ld, u = _data(3, L=32)
    y_full, s_full = chunked_linear_attn(q, k, v, ld, mode="mamba", chunk=8)
    y_a, s_a = chunked_linear_attn(q[:, :, :20], k[:, :, :20], v[:, :, :20],
                                   ld[:, :, :20], mode="mamba", chunk=4)
    y_b, s_b = chunked_linear_attn(q[:, :, 20:], k[:, :, 20:], v[:, :, 20:],
                                   ld[:, :, 20:], mode="mamba", chunk=4,
                                   state0=s_a)
    np.testing.assert_allclose(np.asarray(y_full[:, :, 20:]),
                               np.asarray(y_b), atol=3e-3)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_b),
                               atol=3e-3)


def test_decode_step_matches_scan_tail():
    """One linear_attn_step after a prefix == last position of a full pass."""
    q, k, v, ld, u = _data(4, L=16)
    y_full, s_full = naive_scan_ref(q, k, v, ld, mode="rwkv", u=u)
    _, s_prefix = naive_scan_ref(q[:, :, :15], k[:, :, :15], v[:, :, :15],
                                 ld[:, :, :15], mode="rwkv", u=u)
    y_t, s_t = linear_attn_step(q[:, :, 15], k[:, :, 15], v[:, :, 15],
                                ld[:, :, 15], s_prefix, mode="rwkv", u=u)
    np.testing.assert_allclose(np.asarray(y_full[:, :, 15]),
                               np.asarray(y_t), atol=3e-3)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_t),
                               atol=3e-3)


def test_online_attention_paths_agree():
    """Dense vs online-softmax attention (layers.py) on window+prefix."""
    import jax
    from repro.models import layers as lyr
    from repro.configs import get_arch, reduced_config
    cfg = reduced_config(get_arch("gemma2-2b"))
    p = lyr.init_attention(jax.random.PRNGKey(0), cfg)
    B, L = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model),
                          jnp.float32)
    pos = jnp.arange(L)
    dense, _ = lyr.attention(p, x, cfg, pos, sliding_window=jnp.int32(8),
                             prefix_len=jnp.int32(12))
    old = (lyr.ATTN_CHUNK_THRESHOLD, lyr.ATTN_Q_CHUNK, lyr.ATTN_KV_CHUNK)
    try:
        lyr.ATTN_CHUNK_THRESHOLD, lyr.ATTN_Q_CHUNK, lyr.ATTN_KV_CHUNK = \
            16, 16, 16
        online, _ = lyr.attention(p, x, cfg, pos,
                                  sliding_window=jnp.int32(8),
                                  prefix_len=jnp.int32(12))
    finally:
        (lyr.ATTN_CHUNK_THRESHOLD, lyr.ATTN_Q_CHUNK,
         lyr.ATTN_KV_CHUNK) = old
    d = np.abs(np.asarray(dense, np.float32) - np.asarray(online,
                                                          np.float32))
    scale = np.abs(np.asarray(dense, np.float32)).max()
    assert (d <= 0.02 * scale + 0.02).all(), d.max()
