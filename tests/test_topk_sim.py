"""Panel-streamed top-K similarity + neighbor-list clustering (DESIGN.md §8).

The contract is *certified bit identity*: whenever the per-row spill
certificate reports zero overflow, every consumer of the ``TopKSim``
representation — thresholds, both clustering engines, the Pallas list
kernels, and the full pipeline — must equal the dense ``[S, S]`` path
bit for bit.  When K truncates a potential alpha-edge, the certificate
must say so.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.clustering import (cluster, cluster_rounds_topk,
                                   cluster_sequential, cluster_sequential_topk,
                                   resolve_thresholds,
                                   resolve_thresholds_from_moments)
from repro.core.similarity import (similarity_topk, topk_from_dense,
                                   topk_overflow)
from repro.core.types import DSCParams, SubtrajTable

FIELDS = ("member_of", "member_sim", "is_rep", "is_outlier")


def _instance(seed, S=24, density=0.5, tied_voting=False):
    rng = np.random.default_rng(seed)
    raw = rng.uniform(0, 1, (S, S)).astype(np.float32)
    sim = np.maximum(raw, raw.T) * (rng.uniform(0, 1, (S, S)) > density)
    sim = np.maximum(sim, sim.T)
    np.fill_diagonal(sim, 0.0)
    valid = rng.uniform(0, 1, S) > 0.1
    sim = sim * (valid[:, None] & valid[None, :])
    voting = (rng.integers(0, 3, S).astype(np.float32) if tied_voting
              else rng.uniform(0, 5, S).astype(np.float32))
    table = SubtrajTable(
        t_start=jnp.zeros(S), t_end=jnp.ones(S),
        voting=jnp.asarray(voting),
        card=jnp.asarray(rng.integers(1, 20, S).astype(np.int32)),
        valid=jnp.asarray(valid),
        traj_row=jnp.arange(S, dtype=jnp.int32))
    return jnp.asarray(sim.astype(np.float32)), table


def _assert_identical(res_a, res_b, ctx=""):
    for f in FIELDS:
        a, b = np.asarray(getattr(res_a, f)), np.asarray(getattr(res_b, f))
        assert np.array_equal(a, b), (f, ctx, a, b)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_full_k_engines_match_dense_oracle(seed):
    """K = S cannot truncate: every top-K engine is bit-identical to the
    dense sequential oracle, overflow provably zero."""
    sim, table = _instance(seed, tied_voting=(seed % 2 == 0))
    S = table.num_slots
    params = DSCParams(alpha_sigma=0.0, k_sigma=0.0)
    dense = cluster_sequential(sim, table, params)
    tk = topk_from_dense(sim, table, S)
    assert int(topk_overflow(tk, dense.alpha_used)) == 0
    _assert_identical(dense, cluster_sequential_topk(tk, table, params))
    _assert_identical(dense, cluster_rounds_topk(tk, table, params))
    _assert_identical(dense,
                      cluster_rounds_topk(tk, table, params,
                                          use_kernel=True))


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_truncated_k_certified_or_flagged(seed):
    """Any K: either the spill certificate is clean and labels equal the
    dense oracle bit for bit, or overflow is flagged — never a silent
    divergence."""
    rng = np.random.default_rng(seed)
    sim, table = _instance(seed, density=0.8)       # sparse rows
    params = DSCParams(alpha_sigma=0.0, k_sigma=0.0)
    dense = cluster_sequential(sim, table, params)
    for K in (2, 4, 8, 16):
        tk = topk_from_dense(sim, table, K)
        res = cluster_rounds_topk(tk, table, params)
        if int(topk_overflow(tk, res.alpha_used)) == 0:
            _assert_identical(dense, res, f"seed={seed} K={K}")
        else:
            pass                                     # flagged, no claim


def test_overflow_fires_on_truncated_alpha_edges():
    """A hub row with more alpha-edges than K must raise the counter."""
    S = 12
    sim = np.zeros((S, S), np.float32)
    sim[0, 1:9] = sim[1:9, 0] = 0.9                  # degree-8 hub
    table = SubtrajTable(
        t_start=jnp.zeros(S), t_end=jnp.ones(S),
        voting=jnp.ones(S), card=jnp.ones(S, jnp.int32),
        valid=jnp.ones(S, bool), traj_row=jnp.arange(S, dtype=jnp.int32))
    tk = topk_from_dense(jnp.asarray(sim), table, 4)
    assert int(topk_overflow(tk, jnp.float32(0.5))) > 0
    tk_wide = topk_from_dense(jnp.asarray(sim), table, 8)
    assert int(topk_overflow(tk_wide, jnp.float32(0.5))) == 0


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_thresholds_bitwise_from_streamed_moments(seed):
    """alpha/k from the TopKSim row moments equal the dense
    ``resolve_thresholds`` bit for bit — whatever K is."""
    sim, table = _instance(seed)
    params = DSCParams(alpha_sigma=0.7, k_sigma=-0.3)
    a_d, k_d = resolve_thresholds(params, sim, table)
    tk = topk_from_dense(sim, table, 4)
    a_t, k_t = resolve_thresholds_from_moments(
        params, (tk.degree, tk.row_sum, tk.row_sumsq), table)
    assert float(a_d) == float(a_t)
    assert float(k_d) == float(k_t)


def test_dispatcher_routes_topk():
    sim, table = _instance(3)
    params = DSCParams(alpha_sigma=0.0, k_sigma=0.0)
    tk = topk_from_dense(sim, table, table.num_slots)
    _assert_identical(cluster(tk, table, params, engine="sequential"),
                      cluster(tk, table, params, engine="rounds"))
    with pytest.raises(ValueError):
        cluster(tk, table, params, engine="bogus")


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_topk_kernel_primitives_match_ref(seed):
    """Pallas list-tile round scan / claim-max == jnp oracles, including
    on shapes that force internal row padding."""
    from repro.core.clustering import visit_order
    from repro.kernels.cluster.ops import (topk_cluster_assign,
                                           topk_cluster_round_scan)
    from repro.kernels.cluster.ref import (topk_claim_max_ref,
                                           topk_round_scan_ref)
    rng = np.random.default_rng(seed)
    sim, table = _instance(seed, S=21)               # 21 % 8 != 0: pads
    S = table.num_slots
    tk = topk_from_dense(sim, table, 5)
    alpha = jnp.float32(0.3)
    _, rank = visit_order(table)
    potential = np.asarray(table.valid)
    unresolved = jnp.asarray(potential & (rng.uniform(0, 1, S) > 0.4))
    is_rep = jnp.asarray(potential & (rng.uniform(0, 1, S) > 0.6)
                         & ~np.asarray(unresolved))

    blk, clm = topk_cluster_round_scan(tk.ids, tk.sims, rank, unresolved,
                                       is_rep, alpha)
    blk_r, clm_r = topk_round_scan_ref(tk.ids, tk.sims, rank, unresolved,
                                       is_rep, alpha)
    assert np.array_equal(np.asarray(blk), np.asarray(blk_r))
    assert np.array_equal(np.asarray(clm), np.asarray(clm_r))

    w, slot = topk_cluster_assign(tk.ids, tk.sims, rank, is_rep,
                                  table.valid, alpha)
    w_r, slot_r = topk_claim_max_ref(tk.ids, tk.sims, rank, is_rep,
                                     table.valid, alpha)
    assert np.array_equal(np.asarray(w), np.asarray(w_r))
    assert np.array_equal(np.asarray(slot), np.asarray(slot_r))


# ---------------------------------------------------------------------------
# Panel streaming: construction parity
# ---------------------------------------------------------------------------


def _pipeline_pieces(seed=3):
    from repro.core import similarity, voting
    from repro.core.segmentation import tsa2
    from repro.data.synthetic import ais_like
    from repro.kernels.stjoin.ops import subtrajectory_join
    batch, _ = ais_like(n_vessels=8, max_points=24, seed=seed)
    eps_sp, eps_t, delta_t, maxS, w, tau = 3.0, 600.0, 0.0, 4, 4, 0.2
    join = subtrajectory_join(batch, batch, eps_sp, eps_t, delta_t)
    vote = voting.point_voting(join)
    masks = voting.neighbor_mask_packed(join)
    seg = tsa2(masks, batch.valid, w, tau, maxS)
    table = similarity.build_subtraj_table(batch, seg, vote, maxS)
    return batch, join, seg, table, maxS, (eps_sp, eps_t, delta_t)


@pytest.mark.parametrize("panel", [4, 8, 32, None])
def test_panel_stream_equals_dense_reduction(panel):
    """``similarity_topk`` (scatter per panel, both orientations) is
    bit-identical to reducing the dense ``similarity_matrix`` — lists,
    spill, degree, and moments — for every panel height."""
    from repro.core import similarity
    batch, join, seg, table, maxS, _ = _pipeline_pieces()
    dense = similarity.similarity_matrix(join, seg, seg.sub_local, table,
                                         maxS)
    want = topk_from_dense(dense, table, 8)
    got = similarity_topk(join, seg, seg.sub_local, table, maxS, k=8,
                          panel=panel)
    for f in ("ids", "sims", "spill", "degree", "row_sum", "row_sumsq"):
        assert np.array_equal(np.asarray(getattr(got, f)),
                              np.asarray(getattr(want, f))), (panel, f)


def test_fused_panel_kernel_orientations_bitwise():
    """The panel-emitting fused kernel's (fwd, rev) slabs equal the dense
    fused raw accumulator's rows and transposed rows bit for bit."""
    from repro.kernels.stjoin.ops import (stjoin_sim_fused,
                                          stjoin_sim_panel_fused)
    batch, _, seg, table, maxS, (eps_sp, eps_t, dt) = _pipeline_pieces()
    S = table.num_slots
    kw = dict(rows=2, bc=4, bm=8)
    raw = np.asarray(stjoin_sim_fused(
        batch, batch, seg.sub_local, seg.sub_local, maxS, eps_sp, eps_t,
        dt, **kw))
    Sb = 8
    for p in range(S // Sb):
        fwd, rev = stjoin_sim_panel_fused(
            batch, batch, seg.sub_local, seg.sub_local, maxS, eps_sp,
            eps_t, dt, p0=p * Sb, panel=Sb, **kw)
        assert np.array_equal(np.asarray(fwd), raw[p * Sb:(p + 1) * Sb])
        assert np.array_equal(np.asarray(rev), raw.T[p * Sb:(p + 1) * Sb])


# ---------------------------------------------------------------------------
# Ring-merge algebra (DESIGN.md §12)
# ---------------------------------------------------------------------------


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_topk_merge_split_and_order_invariant(seed):
    """The ring fold's algebra: for distinct-id candidate lists the
    canonical merge is a set function of the (id, sim) pairs — any
    contiguous column-block split, any block permutation, and any
    pairwise merge grouping (left fold or random binary tree) yield
    bit-identical [S, K] id/sim lists *and* spill certificate.  This is
    the property that makes the ring similarity sweep's running
    one-block-at-a-time fold exact against the barrier k-way merge."""
    from repro.core.similarity import (merge_topk_blocks, merge_topk_lists,
                                       sort_topk_lists)
    rng = np.random.default_rng(seed)
    S, N, K = 7, 40, 5
    kk = K + 1
    # distinct ids per row (a permutation), sims nonnegative with zeros —
    # the (id=-1, sim=0) masking edge and the spill tail are both hit
    ids = jnp.asarray(np.stack([rng.permutation(N) for _ in range(S)])
                      .astype(np.int32))
    sims = np.asarray(rng.uniform(0, 1, (S, N)), np.float32)
    sims = jnp.asarray(sims * (rng.uniform(0, 1, (S, N)) > 0.3))
    ref = merge_topk_blocks(ids, sims, K)

    def check(got, ctx):
        for name, a, b in zip(("ids", "sims", "spill"), got, ref):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                seed, ctx, name)

    for trial in range(3):
        ncuts = int(rng.integers(1, 6))
        cuts = np.sort(rng.choice(np.arange(1, N), ncuts, replace=False))
        # each block pre-truncated to its own canonical top-(K+1) — what
        # every rank ships on the ring (selection containment keeps the
        # global top-(K+1), hence the spill, exact)
        blocks = [sort_topk_lists(ids[:, a:b], sims[:, a:b], kk)
                  for a, b in zip([0, *cuts], [*cuts, N])]
        order = [int(j) for j in rng.permutation(len(blocks))]

        ci = jnp.concatenate([blocks[j][0] for j in order], axis=1)
        cs = jnp.concatenate([blocks[j][1] for j in order], axis=1)
        check(merge_topk_blocks(ci, cs, K), f"concat trial={trial}")

        fi, fs = blocks[order[0]]
        for j in order[1:]:
            fi, fs = merge_topk_lists(fi, fs, *blocks[j], kk)
        check(merge_topk_blocks(fi, fs, K), f"fold trial={trial}")

        work = [blocks[j] for j in order]
        while len(work) > 1:
            i = int(rng.integers(0, len(work) - 1))
            a, b = work.pop(i), work.pop(i)
            work.insert(i, merge_topk_lists(a[0], a[1], b[0], b[1], kk))
        check(merge_topk_blocks(*work[0], K), f"tree trial={trial}")


# ---------------------------------------------------------------------------
# End-to-end pipeline parity
# ---------------------------------------------------------------------------


def test_run_dsc_topk_bit_identical(fig1, fig1_params):
    """sim_mode="topk" on both execution modes: bit-identical labels,
    SSCR, and RMSE; no dense matrix in the output; certified exact."""
    from repro.core.dsc import run_dsc
    batch, _ = fig1
    ref = run_dsc(batch, fig1_params)
    for kw in (dict(), dict(mode="fused"),
               dict(mode="fused", use_index=True),
               dict(cluster_engine="sequential"),
               dict(cluster_use_kernel=True)):
        out = run_dsc(batch, fig1_params, sim_mode="topk", **kw)
        assert out.sim is None and out.sim_topk is not None
        assert int(out.sim_overflow) == 0
        _assert_identical(ref.result, out.result, str(kw))
        assert float(out.sscr) == float(ref.sscr)
        assert float(out.rmse) == float(ref.rmse)


def test_run_dsc_topk_auto_widens_or_raises(fig1, fig1_params):
    """An undersized K either auto-widens to the certified fixed point
    (default) or raises loudly when retries are disabled."""
    from repro.core.dsc import run_dsc
    batch, _ = fig1
    ref = run_dsc(batch, fig1_params)
    out = run_dsc(batch, fig1_params, sim_mode="topk", sim_topk=2)
    assert int(out.sim_overflow) == 0
    assert out.sim_topk.k > 2                        # widened
    _assert_identical(ref.result, out.result)
    with pytest.raises(RuntimeError, match="sim_topk"):
        run_dsc(batch, fig1_params, sim_mode="topk", sim_topk=2,
                sim_topk_retry=False)
