"""TSA1/TSA2 property tests: valid partitions, step-change detection."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.segmentation import (_local_max_cuts, _window_overlap_counts,
                                     _window_overlap_counts_bitplane,
                                     _windowed_union, tsa1, tsa2, tsa2_signal)
from repro.core.voting import neighbor_mask_packed
from repro.core.types import JoinResult


def _pack_bools(matched: np.ndarray) -> jnp.ndarray:
    """[T, M, C] bool -> [T, M, ceil(C/32)] uint32 (same layout as
    ``voting.neighbor_mask_packed``); C need not be a multiple of 32."""
    T, M, C = matched.shape
    W = -(-C // 32)
    pad = np.zeros((T, M, W * 32 - C), bool)
    bits = np.concatenate([matched, pad], axis=-1).reshape(T, M, W, 32)
    weights = (np.uint32(1) << np.arange(32, dtype=np.uint32))
    return jnp.asarray((bits.astype(np.uint32) * weights).sum(-1,
                                                              dtype=np.uint32))


def test_tsa1_detects_step_change():
    """A clean step in the voting signal yields exactly one interior cut at
    the step position."""
    M, w = 64, 6
    sig = np.concatenate([np.ones(32), 0.2 * np.ones(32)])[None, :]
    valid = np.ones((1, M), bool)
    seg = tsa1(jnp.asarray(sig, jnp.float32), jnp.asarray(valid), w, 0.3, 8)
    cuts = np.nonzero(np.asarray(seg.cut)[0])[0]
    assert list(cuts[:1]) == [0]
    interior = [c for c in cuts if c > 0]
    assert len(interior) == 1 and abs(interior[0] - 32) <= 1
    assert int(seg.num_subs[0]) == 2


def test_tsa1_flat_signal_no_cuts():
    M, w = 64, 6
    sig = 0.7 * np.ones((1, M))
    valid = np.ones((1, M), bool)
    seg = tsa1(jnp.asarray(sig, jnp.float32), jnp.asarray(valid), w, 0.2, 8)
    assert int(seg.num_subs[0]) == 1


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_tsa1_partition_validity(seed):
    """Subtrajectory labels are a monotone non-decreasing partition of the
    valid prefix; padding labelled -1; num_subs consistent."""
    rng = np.random.default_rng(seed)
    T, M, w = 3, 48, 5
    sig = rng.uniform(0, 1, (T, M)).astype(np.float32)
    count = rng.integers(10, M + 1, T)
    valid = np.arange(M)[None, :] < count[:, None]
    seg = tsa1(jnp.asarray(sig), jnp.asarray(valid), w, 0.25, 8)
    sl = np.asarray(seg.sub_local)
    for r in range(T):
        labs = sl[r][valid[r]]
        assert labs[0] == 0
        assert (np.diff(labs) >= 0).all() and (np.diff(labs) <= 1).all()
        assert (sl[r][~valid[r]] == -1).all()
        assert int(seg.num_subs[r]) == labs.max() + 1


def test_tsa2_detects_composition_change():
    """Neighbor set flips completely at midpoint with constant density ->
    TSA2 cuts, TSA1 does not (Example 2)."""
    T, M, C = 1, 64, 64
    w = 6
    best_w = np.zeros((T, M, C), np.float32)
    best_w[0, :32, :8] = 0.9       # first half: neighbors 0..7
    best_w[0, 32:, 8:16] = 0.9     # second half: neighbors 8..15
    join = JoinResult(best_w=jnp.asarray(best_w),
                      best_idx=jnp.zeros((T, M, C), jnp.int32))
    masks = neighbor_mask_packed(join)
    valid = jnp.ones((T, M), bool)
    seg2 = tsa2(masks, valid, w, 0.4, 8)
    assert int(seg2.num_subs[0]) == 2
    cuts = np.nonzero(np.asarray(seg2.cut)[0])[0]
    assert abs([c for c in cuts if c > 0][0] - 32) <= 1
    # density signal is flat -> TSA1 sees nothing
    vote = jnp.asarray(best_w.sum(-1) / best_w.sum(-1).max())
    seg1 = tsa1(vote, valid, w, 0.4, 8)
    assert int(seg1.num_subs[0]) == 1


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_tsa2_partition_validity(seed):
    rng = np.random.default_rng(seed)
    T, M, W, w = 2, 40, 2, 4
    masks = jnp.asarray(rng.integers(0, 2 ** 31, (T, M, W)).astype(np.uint32))
    count = rng.integers(12, M + 1, T)
    valid = jnp.asarray(np.arange(M)[None, :] < count[:, None])
    seg = tsa2(masks, valid, w, 0.3, 8)
    sl = np.asarray(seg.sub_local)
    v = np.asarray(valid)
    for r in range(T):
        labs = sl[r][v[r]]
        assert labs[0] == 0
        assert (np.diff(labs) >= 0).all() and (np.diff(labs) <= 1).all()


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_tsa2_packed_and_chunked_match_full_expansion(seed):
    """Both production-history paths — the packed windowed-OR engine and
    the retained bit-plane chunked fold — must equal the all-at-once
    ``[T, M, W*32]`` expansion bit for bit."""
    rng = np.random.default_rng(seed)
    T, M, W, w = 2, 36, 3, 5
    masks = jnp.asarray(rng.integers(0, 2 ** 31, (T, M, W)).astype(np.uint32))

    n = jnp.arange(M)
    l1 = _windowed_union(masks, n - w, n - 1)        # full [T, M, W*32]
    l2 = _windowed_union(masks, n, n + w - 1)
    want_inter = np.asarray(jnp.sum(l1 & l2, axis=-1))
    want_union = np.asarray(jnp.sum(l1 | l2, axis=-1))
    for impl in (_window_overlap_counts, _window_overlap_counts_bitplane):
        inter, union = impl(masks, w)
        assert (np.asarray(inter) == want_inter).all(), impl.__name__
        assert (np.asarray(union) == want_union).all(), impl.__name__


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_packed_windowed_or_vs_bitplane_oracle_property(seed):
    """The packed-word engine equals the pinned bit-plane oracle across
    the edge cases the block OR-scan has to get right: w=1, w >= M,
    all-padding (zero-mask) rows, and C not a multiple of 32."""
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 4))
    M = int(rng.integers(2, 48))
    C = int(rng.integers(1, 100))          # frequently not a multiple of 32
    w = int(rng.choice([1, 2, 3, M, M + 4]))
    matched = rng.uniform(0, 1, (T, M, C)) < 0.3
    matched[0] = False                     # an all-padding trajectory
    masks = _pack_bools(matched)

    ip, up = _window_overlap_counts(masks, w)
    ib, ub = _window_overlap_counts_bitplane(masks, w)
    assert (np.asarray(ip) == np.asarray(ib)).all(), (seed, w)
    assert (np.asarray(up) == np.asarray(ub)).all(), (seed, w)

    d_p = np.asarray(tsa2_signal(masks, w))
    d_b = np.asarray(tsa2_signal(masks, w, impl="bitplane"))
    assert (d_p == d_b).all(), (seed, w)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_tsa2_kernel_matches_jnp_engine(seed):
    """tsa2(use_kernel=True) — the fused Pallas segmentation kernel — is
    bit-identical to the jnp packed engine: cuts, labels, and score."""
    rng = np.random.default_rng(seed)
    T, M, W = 3, 40, 2
    w = int(rng.integers(1, 8))
    masks = jnp.asarray(rng.integers(0, 2 ** 31, (T, M, W)).astype(np.uint32))
    count = rng.integers(4, M + 1, T)
    valid = jnp.asarray(np.arange(M)[None, :] < count[:, None])
    a = tsa2(masks, valid, w, 0.3, 8)
    b = tsa2(masks, valid, w, 0.3, 8, use_kernel=True)
    for f in ("cut", "sub_local", "num_subs", "score"):
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), (seed, w, f)


@pytest.mark.parametrize("mode", ["materialize", "fused"])
def test_tsa2_end_to_end_seg_kernel_parity(fig1, fig1_params, mode):
    """run_dsc with seg_use_kernel=True: bit-identical TSA2 cut masks,
    segmentations, and downstream cluster labels in both join modes."""
    from repro.core.dsc import run_dsc
    batch, _ = fig1
    a = run_dsc(batch, fig1_params, mode=mode)
    b = run_dsc(batch, fig1_params, mode=mode, seg_use_kernel=True)
    for f in ("cut", "sub_local", "num_subs"):
        assert np.array_equal(np.asarray(getattr(a.seg, f)),
                              np.asarray(getattr(b.seg, f))), (mode, f)
    for f in ("member_of", "is_rep", "is_outlier"):
        assert np.array_equal(np.asarray(getattr(a.result, f)),
                              np.asarray(getattr(b.result, f))), (mode, f)


def _local_max_cuts_stacked(d, valid, w, tau, count):
    """The former implementation of ``_local_max_cuts``: materializes all
    2w-1 shifted copies as a ``[T, M, 2w-1]`` stack.  Kept here as the
    regression oracle for the O(M) prefix/suffix cummax rewrite."""
    T, M = d.shape
    n = jnp.arange(M)
    admissible = (n[None, :] >= w) & (n[None, :] <= count[:, None] - w - 1)
    d = jnp.where(valid & admissible, d, -jnp.inf)

    neg_inf = -jnp.inf
    pads = w - 1
    dp = jnp.pad(d, ((0, 0), (pads, pads)), constant_values=neg_inf)
    windows = jnp.stack(
        [dp[:, k:k + M] for k in range(2 * pads + 1)], axis=-1)
    wmax = jnp.max(windows, axis=-1)
    left = (jnp.max(windows[..., :pads], axis=-1) if pads > 0
            else jnp.full_like(d, neg_inf))
    is_max = (d >= wmax) & (d > left)
    return is_max & (d > tau) & admissible & valid


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_local_max_cuts_cummax_matches_stacked(seed):
    """The prefix/suffix cummax sliding max must reproduce the stacked
    2w-1-copies formulation bit for bit — including duplicate d values
    (strict-left tie break) and masked/-inf positions."""
    rng = np.random.default_rng(seed)
    T, M = 3, 57                                  # non-multiple of any block
    # quantized signal -> frequent exact ties inside windows
    d = jnp.asarray(rng.integers(0, 6, (T, M)).astype(np.float32) / 5.0)
    count = rng.integers(5, M + 1, T)
    valid = jnp.asarray(np.arange(M)[None, :] < count[:, None])
    count = jnp.asarray(count.astype(np.int32))
    for w in (1, 2, 5, 11):
        got = _local_max_cuts(d, valid, w, 0.25, count)
        want = _local_max_cuts_stacked(d, valid, w, 0.25, count)
        assert np.array_equal(np.asarray(got), np.asarray(want)), (seed, w)


def test_max_subs_clipping():
    """Pathological signal with many steps respects max_subtrajs_per_traj."""
    M, w = 128, 3
    sig = (np.arange(M) // 8 % 2).astype(np.float32)[None, :]
    valid = np.ones((1, M), bool)
    seg = tsa1(jnp.asarray(sig), jnp.asarray(valid), w, 0.1, 4)
    assert int(seg.num_subs[0]) <= 4
    assert np.asarray(seg.sub_local).max() <= 3
