"""Paper Fig. 8: scalability — (a) dataset-size sweep with per-phase
breakdown (Join / RSE / Clustering / RefineResults), (b) node-count sweep
(partition parallelism via subprocess with forced host devices)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.core import geometry, segmentation, similarity, voting
from repro.core.clustering import cluster
from repro.core.types import DSCParams
from repro.data.synthetic import ais_like, default_dsc_params_for

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _phase_times(batch, params):
    """Time the pipeline phases separately (jitted, median of 2)."""
    import jax.numpy as jnp

    def timed(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        return time.perf_counter() - t0, out

    join_fn = jax.jit(lambda b: geometry.subtrajectory_join(
        b, b, params.eps_sp, params.eps_t, params.delta_t))
    t_join, join = timed(join_fn, batch)

    def rse(b, j):
        vote = voting.point_voting(j)
        nv = voting.normalized_voting(vote, b.valid)
        seg = segmentation.tsa1(nv, b.valid, params.w, params.tau,
                                params.max_subtrajs_per_traj)
        table = similarity.build_subtraj_table(
            b, seg, vote, params.max_subtrajs_per_traj)
        return seg, table, vote

    rse_fn = jax.jit(rse)
    t_rse, (seg, table, vote) = timed(rse_fn, batch, join)

    sim_fn = jax.jit(lambda j, s, t: similarity.similarity_matrix(
        j, s, s.sub_local, t, params.max_subtrajs_per_traj))
    t_sim, sim = timed(sim_fn, join, seg, table)

    clu_fn = jax.jit(lambda s, t: cluster(s, t, params))
    t_clu, _ = timed(clu_fn, sim, table)
    return {"join": t_join, "rse": t_rse + t_sim, "cluster": t_clu}


def run():
    # (a) dataset size sweep
    for frac in (0.2, 0.4, 0.6, 0.8, 1.0):
        n = int(48 * frac)
        batch, _ = ais_like(n_vessels=n, max_points=64, seed=5)
        diam, mean_dt = default_dsc_params_for(batch)
        params = DSCParams(eps_sp=0.08 * diam, eps_t=2 * mean_dt,
                           delta_t=0.0, w=6, tau=0.2, alpha_sigma=-1.0,
                           k_sigma=-1.0)
        ph = _phase_times(batch, params)
        total = sum(ph.values())
        csv_row(f"fig8a_size_{int(frac*100)}pct", total * 1e6,
                f"join={ph['join']*1e3:.1f}ms;rse={ph['rse']*1e3:.1f}ms;"
                f"cluster={ph['cluster']*1e3:.1f}ms")

    # (b) node sweep: same data, more partitions (subprocess per point)
    driver = textwrap.dedent("""
        import os, json, time, sys
        P = int(sys.argv[1])
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=%d" % max(2*P, 2))
        import jax
        from repro.core.distributed import run_dsc_distributed
        from repro.core.partitioning import partition_batch
        from repro.core.types import DSCParams
        from repro.data.synthetic import ais_like, default_dsc_params_for
        batch, _ = ais_like(n_vessels=32, max_points=64, seed=5)
        diam, mean_dt = default_dsc_params_for(batch)
        params = DSCParams(eps_sp=0.08*diam, eps_t=2*mean_dt, w=6, tau=0.2,
                           alpha_sigma=-1.0, k_sigma=-1.0)
        mesh = jax.make_mesh((P, 2), ("part", "model"))
        parts = partition_batch(batch, P)
        out = run_dsc_distributed(parts, params, mesh)   # compile
        jax.block_until_ready(out.result.member_of)
        t0 = time.perf_counter()
        out = run_dsc_distributed(parts, params, mesh)
        jax.block_until_ready(out.result.member_of)
        print("TIME", time.perf_counter() - t0)
    """)
    for P in (1, 2, 4):
        env = dict(os.environ, PYTHONPATH=SRC)
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run([sys.executable, "-c", driver, str(P)],
                              env=env, capture_output=True, text=True,
                              timeout=600)
        if proc.returncode != 0:
            csv_row(f"fig8b_nodes_{P}", -1, "FAIL")
            continue
        t = float([l for l in proc.stdout.splitlines()
                   if l.startswith("TIME")][-1].split()[1])
        csv_row(f"fig8b_nodes_{P}", t * 1e6,
                f"partitions={P};model_par=2")


if __name__ == "__main__":
    run()
