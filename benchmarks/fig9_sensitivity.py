"""Paper Fig. 9 / Table 1: sensitivity analysis — vary each parameter around
its default; measure execution time and clustering RMSE."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.core.dsc import run_dsc
from repro.core.evaluation import rmse_sim_based
from repro.core.types import DSCParams
from repro.data.synthetic import ais_like, default_dsc_params_for

# paper Table 1 (values relative to dataset statistics; defaults in bold)
SWEEPS = {
    "eps_sp": [0.10, 0.15, 0.20, 0.25, 0.30],        # x diameter%
    "eps_t": [0.5, 1.0, 1.5, 2.0, 2.5],              # x mean sample dt
    "delta_t": [0.0, 1.0, 2.0, 3.0, 4.0],            # x mean sample dt
    "w": [4, 6, 8, 10, 12],
    "tau": [0.1, 0.2, 0.4, 0.6, 0.8],
    "alpha_sigma": [-2.0, -1.0, 0.0, 1.0, 2.0],
    "k_sigma": [-2.0, -1.0, 0.0, 1.0, 2.0],
}
DEFAULTS = {"eps_sp": 0.15, "eps_t": 1.0, "delta_t": 0.0, "w": 6,
            "tau": 0.2, "alpha_sigma": 0.0, "k_sigma": 0.0}


def run():
    batch, _ = ais_like(n_vessels=32, max_points=64, seed=3)
    diam, mean_dt = default_dsc_params_for(batch)

    def make_params(over):
        d = dict(DEFAULTS)
        d.update(over)
        return DSCParams(
            eps_sp=d["eps_sp"] * diam, eps_t=d["eps_t"] * mean_dt,
            delta_t=d["delta_t"] * mean_dt, w=int(d["w"]), tau=d["tau"],
            alpha_sigma=d["alpha_sigma"], k_sigma=d["k_sigma"])

    results = {}
    for pname, values in SWEEPS.items():
        for val in values:
            params = make_params({pname: val})
            secs, out = time_fn(run_dsc, batch, params, iters=1)
            r = rmse_sim_based(np.asarray(out.sim),
                               np.asarray(out.result.member_of),
                               np.asarray(out.result.is_rep),
                               float(params.eps_sp))
            n_out = int(np.asarray(out.result.is_outlier).sum())
            results[(pname, val)] = (secs, r)
            csv_row(f"fig9_{pname}_{val}", secs * 1e6,
                    f"rmse={r:.4f};outliers={n_out}")
    return results


if __name__ == "__main__":
    run()
