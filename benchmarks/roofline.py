"""Roofline analysis: place a compiled program on the TPU v5e roofline.

Two consumers share the same model:

* :func:`roofline_position` — the reusable core: given loop-corrected
  FLOPs, HBM bytes, and collective bytes (``repro.launch.hlo_analysis``
  produces all three from a compiled module's text), return the three
  per-device time terms and which resource dominates.  The tile-plan
  autotuner (``repro.tune.autotune``) calls this per candidate geometry
  so every stored plan records *why* it won — where each tiling sits on
  the roofline, not just its wall-clock on the machine that tuned it.
* :func:`run` — the dry-run report: reads results/dryrun/*.json (written
  by ``repro.launch.dryrun``) and writes results/roofline.csv + .md,
  adding the model-analytic floors (MODEL_FLOPS = 6*N*D train / 2*N*D
  inference) whose ratio to HLO FLOPs exposes remat/replication waste.

The machine constants are TPU v5e per chip:

    compute    = FLOPs / 197e12          (bf16 MXU peak)
    memory     = HBM bytes / 819e9
    collective = collective bytes / 50e9 (per-ICI-link; 'pod'-axis traffic
                 crosses DCN and is slower — flagged, not re-priced)

FLOPs / collective bytes are the *loop-corrected* values (scan bodies
multiplied by trip counts — see repro.launch.hlo_analysis).  HBM bytes
prefer the fusion-aware estimate; the CPU dry-run materializes bf16 ops
through f32 converts, so bytes are a ~2x UPPER bound on the TPU number
(flagged per row, not silently rescaled).
"""
from __future__ import annotations

import glob
import json
import os
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def roofline_position(flops: float, hbm_bytes: float,
                      coll_bytes: float = 0.0) -> dict:
    """Place one program on the TPU v5e roofline.

    Returns the three per-device time terms (``compute_s``, ``memory_s``,
    ``collective_s``), the ``dominant`` resource, the arithmetic
    ``intensity`` (FLOPs per HBM byte), and ``bound_s`` (the roofline
    lower bound on runtime — the max of the three terms).  Inputs are the
    loop-corrected totals from ``repro.launch.hlo_analysis.analyze_hlo``;
    this is the per-candidate record the tile-plan autotuner stores.
    """
    t_c = flops / PEAK_FLOPS
    t_m = hbm_bytes / HBM_BW
    t_x = coll_bytes / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom, "bound_s": max(t_c, t_m, t_x),
        "intensity": flops / hbm_bytes if hbm_bytes > 0 else 0.0,
    }

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "results" / "dryrun"


def model_flops_per_device(rec: dict) -> float:
    from repro.configs.registry import SHAPES
    shape = rec.get("shape", "")
    if shape not in SHAPES:
        return 0.0
    sh = SHAPES[shape]
    n_active = rec.get("active_params") or rec.get("params") or 0
    devices = rec.get("devices", 1)
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        factor = 6.0
    elif sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = sh["global_batch"]
        factor = 2.0
    return factor * n_active * tokens / max(devices, 1)


def model_min_bytes_per_device(rec: dict) -> float:
    """Analytic HBM floor (bf16): the bytes a *perfect* implementation must
    still move.  train: params read (fwd+bwd) + grad write + Adam moments
    r/w (f32) ~ 14 B/param + activation stream; prefill: params + KV cache
    write; decode: params + full KV cache read per token."""
    from repro.configs.registry import SHAPES, get_arch
    shape = rec.get("shape", "")
    if shape not in SHAPES:
        return 0.0
    sh = SHAPES[shape]
    devices = max(rec.get("devices", 1), 1)
    try:
        cfg = get_arch(rec["arch"])
    except Exception:
        return 0.0
    n_params = rec.get("params") or 0
    p_loc = n_params / devices
    B, L = sh["global_batch"], sh["seq_len"]
    kv_bytes = 0.0
    if cfg.n_kv_heads and cfg.family in ("dense", "moe", "vlm", "audio",
                                         "hybrid"):
        n_kv_layers = cfg.n_layers if cfg.family != "hybrid" else \
            cfg.n_layers // (cfg.attn_every or cfg.n_layers)
        kv_bytes = (2 * n_kv_layers * cfg.n_kv_heads
                    * cfg.resolved_head_dim * L * B * 2) / devices
    act_bytes = (B * L * cfg.d_model * 2 * cfg.n_layers) / devices
    if sh["kind"] == "train":
        return 14.0 * p_loc + 2 * act_bytes
    if sh["kind"] == "prefill":
        return 2.0 * p_loc + kv_bytes + act_bytes
    # decode: every param + the whole cache, every token
    return 2.0 * p_loc + kv_bytes


def _advice(rec: dict, dom: str, ratio: float) -> str:
    arch = rec.get("arch", "")
    if dom == "collective":
        if "moe" in arch or "qwen" in arch or "moonshot" in arch:
            return ("overlap EP all_to_all with expert GEMMs "
                    "(microbatch the dispatch), cut capacity_factor")
        return ("reduce TP all-reduce volume: 2D-shard activations or "
                "switch replicated-attention layers to sequence sharding")
    if dom == "compute":
        if ratio < 0.2:
            return ("compute is mostly waste (replicated attention / "
                    "remat): re-shard heads or batch over 'model'")
        return "increase per-chip batch or quantize (bf16->int8) the GEMMs"
    return ("memory-bound: fuse attention (Pallas flash), store KV in "
            "bf16/int8, or raise arithmetic intensity with larger tiles")


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    flops = float(rec.get("corrected_flops", 0.0))
    mem_raw = float(rec.get("hbm_traffic_bytes", 0.0))
    mem_bytes = float(rec.get("hbm_traffic_fused_bytes", 0.0)) or mem_raw
    if mem_bytes == 0.0:   # legacy record fallback
        raw_flops = float(rec.get("cost", {}).get("flops", 0.0))
        raw_bytes = float(rec.get("cost", {}).get("bytes accessed", 0.0))
        scale = (flops / raw_flops) if raw_flops > 0 and flops > raw_flops \
            else 1.0
        mem_bytes = raw_bytes * scale
    coll = float(rec.get("collective_bytes", 0.0))

    pos = roofline_position(flops, mem_bytes, coll)
    t_c, t_m, t_x = pos["compute_s"], pos["memory_s"], pos["collective_s"]
    dom = pos["dominant"]
    mf = model_flops_per_device(rec)
    mb = model_min_bytes_per_device(rec)
    ratio = mf / flops if flops > 0 else 0.0
    bound = pos["bound_s"]
    # achievable floor: the slower of ideal compute and ideal HBM time
    t_ideal = max(mf / PEAK_FLOPS, mb / HBM_BW)
    roofline_frac = t_ideal / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec.get("shape", ""),
        "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops_dev": mf, "hlo_flops_dev": flops,
        "model_min_bytes_dev": mb, "hbm_bytes_dev": mem_bytes,
        "hbm_bytes_raw_dev": mem_raw,
        "useful_ratio": ratio,
        "roofline_frac": min(roofline_frac, 1.0),
        "advice": _advice(rec, dom, ratio),
        "hbm_note": "bytes are CPU-f32/fusion upper bound vs TPU",
    }


_VARIANT_MARKERS = ("_dponly", "_quant", "_cap10", "_ag16", "_rematdots",
                    "_noremat")


def run(write_files: bool = True):
    rows = []
    skips = []
    for f in sorted(glob.glob(str(DRYRUN / "*.json"))):
        if any(m in Path(f).stem for m in _VARIANT_MARKERS):
            continue           # §Perf variants live in EXPERIMENTS.md
        rec = json.loads(Path(f).read_text())
        if rec.get("status") == "SKIP":
            skips.append(rec)
            continue
        row = analyze(rec)
        if row:
            rows.append(row)
        elif rec.get("status") == "FAIL":
            skips.append(rec)

    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if write_files:
        out_csv = ROOT / "results" / "roofline.csv"
        with open(out_csv, "w") as fh:
            cols = ["arch", "shape", "mesh", "compute_s", "memory_s",
                    "collective_s", "dominant", "model_flops_dev",
                    "hlo_flops_dev", "model_min_bytes_dev",
                    "hbm_bytes_dev", "useful_ratio", "roofline_frac",
                    "advice"]
            fh.write(",".join(cols) + "\n")
            for r in rows:
                fh.write(",".join(
                    f"{r[c]:.4e}" if isinstance(r[c], float) else str(r[c])
                    for c in cols) + "\n")

        md = ROOT / "results" / "roofline.md"
        with open(md, "w") as fh:
            fh.write("| arch | shape | mesh | compute s | memory s | "
                     "collective s | dominant | useful ratio | "
                     "roofline frac |\n|---|---|---|---|---|---|---|---|"
                     "---|\n")
            for r in rows:
                fh.write(
                    f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                    f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
                    f"{r['collective_s']:.3e} | {r['dominant']} | "
                    f"{r['useful_ratio']:.3f} | "
                    f"{r['roofline_frac']:.3f} |\n")
            for s in skips:
                fh.write(f"| {s.get('arch')} | {s.get('shape', '')} | "
                         f"{s.get('mesh')} | SKIP/FAIL | | | | | |\n")
    for r in rows:
        print(f"{r['arch']:>22s} {r['shape']:>12s} {r['mesh']:>6s} "
              f"dom={r['dominant']:<10s} frac={r['roofline_frac']:.3f} "
              f"useful={r['useful_ratio']:.3f}")
    return rows, skips


if __name__ == "__main__":
    run()
