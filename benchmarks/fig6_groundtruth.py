"""Paper Fig. 6: ground-truth validation on the synthetic scenario.

DSC must recover the six subtrajectory clusters (A->O, B->O, O->A, O->B,
O->C, O->D) — purity 1.0 / F-measure 1 in the paper — while T-OPTICS (whole
trajectories) can only see the six routes.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.core.baselines.toptics import t_optics
from repro.core.dsc import run_dsc
from repro.core.evaluation import cluster_purity, leg_labels, pairwise_f1
from repro.core.types import DSCParams
from repro.data.synthetic import figure1_scenario, route_origins_dests


def run():
    batch, routes = figure1_scenario(n_per_route=4, points_per_leg=24,
                                     seed=0)
    params = DSCParams(eps_sp=0.42, eps_t=1.0, w=6, tau=0.15,
                       alpha_sigma=-1.0, k_sigma=-1.0, segmentation="tsa2")
    secs, out = time_fn(run_dsc, batch, params, iters=2)

    member_of = np.asarray(out.result.member_of)
    is_rep = np.asarray(out.result.is_rep)
    valid = np.asarray(out.table.valid)
    assign = {int(s): int(member_of[s]) if not is_rep[s] else int(s)
              for s in np.nonzero(valid)[0] if member_of[s] >= 0}
    origins, dests = route_origins_dests(routes)
    t = np.asarray(batch.t)
    v = np.asarray(batch.valid)
    truth = leg_labels(batch, np.asarray(out.seg.sub_local), origins, dests,
                       float(t[v].max()) / 2, params.max_subtrajs_per_traj)
    purity = cluster_purity(assign, truth)
    f1 = pairwise_f1(assign, truth)

    res = t_optics(batch, eps=2.0, min_pts=3, xi_eps=0.2)
    toptics_clusters = len(set(res["labels"]) - {-1})

    csv_row("fig6_dsc_purity", secs * 1e6,
            f"purity={purity:.3f};f1={f1:.3f};"
            f"clusters={int(is_rep.sum())}")
    csv_row("fig6_toptics_routes", 0.0,
            f"clusters={toptics_clusters};expected=6_routes_only")
    return {"purity": purity, "f1": f1, "toptics": toptics_clusters}


if __name__ == "__main__":
    run()
