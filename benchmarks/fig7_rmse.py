"""Paper Fig. 7: RMSE comparison — DSC vs S2T-Clustering vs TraClus across
dataset portions (25/50/75/100%), on lane traffic with weak associates."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.core.baselines.s2t import s2t_clustering
from repro.core.baselines.traclus import traclus
from repro.core.dsc import run_dsc
from repro.core.evaluation import rmse_sim_based, rmse_traclus
from repro.core.types import DSCParams
from repro.data.synthetic import crossing_scenario


def run():
    eps_sp = 0.42
    results = {}
    for frac, n_per in [(0.25, 2), (0.5, 3), (0.75, 5), (1.0, 6)]:
        batch, _, _ = crossing_scenario(n_per_route=n_per,
                                        points_per_leg=16,
                                        n_crossers=max(2, n_per),
                                        n_fringe=max(2, n_per // 2),
                                        seed=2)
        params = DSCParams(eps_sp=eps_sp, eps_t=1.0, delta_t=6.0, w=5,
                           tau=0.2, alpha_sigma=0.0, k_sigma=-1.0,
                           segmentation="tsa1")
        secs, out = time_fn(run_dsc, batch, params, iters=1)
        r_dsc = rmse_sim_based(np.asarray(out.sim),
                               np.asarray(out.result.member_of),
                               np.asarray(out.result.is_rep), eps_sp)
        n_reps = int(np.asarray(out.result.is_rep).sum())
        s2t = s2t_clustering(batch, eps_sp=eps_sp, eps_t=1.0, w=5, tau=0.2,
                             n_reps=n_reps)
        r_s2t = rmse_sim_based(s2t["sim"], s2t["member_of"], s2t["is_rep"],
                               eps_sp)
        tc = traclus(batch, eps=0.35, min_lns=3)
        r_tc = rmse_traclus(tc, eps_sp=eps_sp)
        results[frac] = (r_dsc, r_s2t, r_tc)
        csv_row(f"fig7_rmse_{int(frac*100)}pct", secs * 1e6,
                f"dsc={r_dsc:.4f};s2t={r_s2t:.4f};traclus={r_tc:.4f}")
    return results


if __name__ == "__main__":
    run()
