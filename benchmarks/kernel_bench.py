"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp refs.

On CPU, interpret mode measures correctness-path overhead, not TPU speed —
the derived column therefore reports work sizes (points x candidates, DP
cells) so TPU projections can be made from the roofline constants.

The dense-vs-pruned stjoin comparison additionally writes
``BENCH_stjoin.json`` (candidate-tile counts, pruning ratio, wall-clock,
bit-parity) so CI can accumulate the perf trajectory as an artifact.
``--smoke`` shrinks every shape for a sub-minute CI run; ``--out-dir``
redirects the JSON.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core.geometry import best_match_join
from repro.core.types import TrajectoryBatch
from repro.data.synthetic import ais_like
from repro.kernels.jaccard.ops import window_jaccard
from repro.kernels.jaccard.ref import jaccard_ref
from repro.kernels.lcss.ops import lcss_scores
from repro.kernels.lcss.ref import lcss_ref
from repro.kernels.stjoin.ops import (
    best_match_join_kernel,
    best_match_join_pruned,
)


def _clustered_workload(smoke: bool):
    """Lane-clustered AIS traffic, rows sorted by lane so candidate tiles
    (groups of ``bc`` adjacent rows) stay spatially tight — the regime the
    index is built for."""
    n_vessels, max_points = (16, 32) if smoke else (64, 64)
    batch, labels = ais_like(n_vessels=n_vessels, n_lanes=8,
                             max_points=max_points, area=100.0,
                             lane_width=0.5, seed=1)
    order = np.argsort(labels, kind="stable")
    batch = TrajectoryBatch(
        x=batch.x[order], y=batch.y[order], t=batch.t[order],
        valid=batch.valid[order],
        traj_id=batch.traj_id[order])
    return batch


def bench_stjoin_pruned(smoke: bool = False, out_dir: str = ".") -> dict:
    """Dense vs index-pruned stjoin: tiles, wall-clock, bit-parity."""
    batch = _clustered_workload(smoke)
    eps_sp, eps_t = 3.0, 600.0
    bp, bc, bm = (32, 2, 32) if smoke else (64, 2, 64)

    kw = dict(bp=bp, bc=bc, bm=bm)
    d_secs, dense = time_fn(best_match_join_kernel, batch, batch,
                            eps_sp, eps_t, iters=2, **kw)
    p_secs, out = time_fn(best_match_join_pruned, batch, batch,
                          eps_sp, eps_t, iters=2, return_stats=True, **kw)
    pruned, stats = out

    parity = (np.array_equal(np.asarray(dense.best_w),
                             np.asarray(pruned.best_w))
              and np.array_equal(np.asarray(dense.best_idx),
                                 np.asarray(pruned.best_idx)))
    kept = int(stats.kept_tiles)
    rec = {
        "workload": "ais_like clustered (lane-sorted rows)",
        "smoke": bool(smoke),
        "shape": {"T": batch.num_trajs, "M": batch.max_points,
                  "bp": bp, "bc": bc, "bm": bm},
        "eps_sp": eps_sp, "eps_t": eps_t,
        "dense_tiles": stats.dense_tiles,
        "pruned_tiles": kept,
        "pruning_ratio": 1.0 - kept / max(stats.dense_tiles, 1),
        "max_tiles_per_ref_block": int(stats.max_per_ref),
        "dense_us": d_secs * 1e6,
        "pruned_us": p_secs * 1e6,
        "bit_identical": bool(parity),
    }
    csv_row("stjoin_dense", rec["dense_us"],
            f"tiles={rec['dense_tiles']}")
    csv_row("stjoin_pruned", rec["pruned_us"],
            f"tiles={kept};ratio={rec['pruning_ratio']:.3f};"
            f"parity={parity}")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_stjoin.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    assert parity, "pruned join diverged from dense join"
    assert kept < rec["dense_tiles"], \
        "index pruned nothing on the clustered workload"
    return rec


def run(smoke: bool = False, out_dir: str = "."):
    if smoke:
        batch, _ = ais_like(n_vessels=8, max_points=32, seed=1)
    else:
        batch, _ = ais_like(n_vessels=32, max_points=64, seed=1)
    eps_sp, eps_t = 3.0, 180.0

    secs, _ = time_fn(best_match_join, batch, batch, eps_sp, eps_t, iters=2)
    work = batch.num_trajs * batch.max_points * batch.num_trajs
    csv_row("stjoin_ref_jnp", secs * 1e6, f"pairs={work}")
    secs, _ = time_fn(best_match_join_kernel, batch, batch, eps_sp, eps_t,
                      iters=2)
    csv_row("stjoin_pallas_interpret", secs * 1e6, f"pairs={work}")

    bench_stjoin_pruned(smoke=smoke, out_dir=out_dir)

    rng = np.random.default_rng(0)
    B, N, M = (2, 32, 32) if smoke else (8, 64, 64)
    mk = lambda shape: jnp.asarray(rng.normal(0, 3, shape), jnp.float32)
    rx, ry = mk((B, N)), mk((B, N))
    rt = jnp.asarray(np.sort(rng.uniform(0, 500, (B, N)), 1), jnp.float32)
    sx, sy = mk((B, M)), mk((B, M))
    st = jnp.asarray(np.sort(rng.uniform(0, 500, (B, M)), 1), jnp.float32)
    ones = jnp.ones((B, N), bool)
    secs, _ = time_fn(lcss_ref, rx, ry, rt, ones, sx, sy, st, ones,
                      2.0, 60.0, iters=2)
    csv_row("lcss_ref_jnp", secs * 1e6, f"dp_cells={B*N*M}")
    secs, _ = time_fn(lcss_scores, rx, ry, rt, ones, sx, sy, st, ones,
                      2.0, 60.0, iters=2)
    csv_row("lcss_pallas_interpret", secs * 1e6, f"dp_cells={B*N*M}")

    T, Mm, W, w = (4, 32, 2, 4) if smoke else (16, 128, 4, 8)
    masks = jnp.asarray(rng.integers(0, 2**31, (T, Mm, W)).astype(np.uint32))
    valid = jnp.ones((T, Mm), bool)
    secs, _ = time_fn(jaccard_ref, masks, w, iters=2)
    csv_row("jaccard_ref_jnp", secs * 1e6, f"positions={T*Mm};bits={W*32}")
    secs, _ = time_fn(window_jaccard, masks, valid, w=w, iters=2)
    csv_row("jaccard_pallas_interpret", secs * 1e6,
            f"positions={T*Mm};bits={W*32}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the CI smoke job")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_*.json records")
    ns = ap.parse_args()
    run(smoke=ns.smoke, out_dir=ns.out_dir)
