"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp refs.

On CPU, interpret mode measures correctness-path overhead, not TPU speed —
the derived column therefore reports work sizes (points x candidates, DP
cells) so TPU projections can be made from the roofline constants.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core.geometry import best_match_join
from repro.core.types import TrajectoryBatch
from repro.data.synthetic import ais_like
from repro.kernels.jaccard.ops import window_jaccard
from repro.kernels.jaccard.ref import jaccard_ref
from repro.kernels.lcss.ops import lcss_scores
from repro.kernels.lcss.ref import lcss_ref
from repro.kernels.stjoin.ops import best_match_join_kernel


def run():
    batch, _ = ais_like(n_vessels=32, max_points=64, seed=1)
    eps_sp, eps_t = 3.0, 180.0

    secs, _ = time_fn(best_match_join, batch, batch, eps_sp, eps_t, iters=2)
    work = batch.num_trajs * batch.max_points * batch.num_trajs
    csv_row("stjoin_ref_jnp", secs * 1e6, f"pairs={work}")
    secs, _ = time_fn(best_match_join_kernel, batch, batch, eps_sp, eps_t,
                      iters=2)
    csv_row("stjoin_pallas_interpret", secs * 1e6, f"pairs={work}")

    rng = np.random.default_rng(0)
    B, N, M = 8, 64, 64
    mk = lambda shape: jnp.asarray(rng.normal(0, 3, shape), jnp.float32)
    rx, ry = mk((B, N)), mk((B, N))
    rt = jnp.asarray(np.sort(rng.uniform(0, 500, (B, N)), 1), jnp.float32)
    sx, sy = mk((B, M)), mk((B, M))
    st = jnp.asarray(np.sort(rng.uniform(0, 500, (B, M)), 1), jnp.float32)
    ones = jnp.ones((B, N), bool)
    secs, _ = time_fn(lcss_ref, rx, ry, rt, ones, sx, sy, st, ones,
                      2.0, 60.0, iters=2)
    csv_row("lcss_ref_jnp", secs * 1e6, f"dp_cells={B*N*M}")
    secs, _ = time_fn(lcss_scores, rx, ry, rt, ones, sx, sy, st, ones,
                      2.0, 60.0, iters=2)
    csv_row("lcss_pallas_interpret", secs * 1e6, f"dp_cells={B*N*M}")

    T, Mm, W, w = 16, 128, 4, 8
    masks = jnp.asarray(rng.integers(0, 2**31, (T, Mm, W)).astype(np.uint32))
    valid = jnp.ones((T, Mm), bool)
    secs, _ = time_fn(jaccard_ref, masks, w, iters=2)
    csv_row("jaccard_ref_jnp", secs * 1e6, f"positions={T*Mm};bits={W*32}")
    secs, _ = time_fn(window_jaccard, masks, valid, w=w, iters=2)
    csv_row("jaccard_pallas_interpret", secs * 1e6,
            f"positions={T*Mm};bits={W*32}")


if __name__ == "__main__":
    run()
